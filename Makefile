# Build helpers referenced throughout the docs and runtime messages.
#
# `artifacts` lowers the JAX/Pallas kernels to HLO-text artifacts the
# Rust runtime executes through PJRT (needs jax installed; see
# python/compile/aot.py). Everything else is plain cargo.
#
# NOTE: with the default offline `xla` stub (rust/xla-stub/), building
# artifacts makes the XLA integration tests *fail* rather than skip —
# the stub cannot execute them. Only run `test-xla` after wiring the
# real `xla` crate into Cargo.toml (see README.md).

.PHONY: artifacts test test-xla bench clean

artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

test:
	cargo test --release -q

# Full suite including the PJRT execution path (real xla crate + jax).
test-xla: artifacts
	cargo test --release -q

bench:
	cargo bench

clean:
	rm -rf artifacts bench_out target
