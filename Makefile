# Build helpers referenced throughout the docs and runtime messages.
#
# `artifacts` lowers the JAX/Pallas kernels to HLO-text artifacts the
# Rust runtime executes through PJRT (needs jax installed; see
# python/compile/aot.py). Everything else is plain cargo.
#
# NOTE: with the default offline `xla` stub (rust/xla-stub/), building
# artifacts makes the XLA integration tests *fail* rather than skip —
# the stub cannot execute them. Only run `test-xla` after wiring the
# real `xla` crate into Cargo.toml (see README.md).

.PHONY: artifacts check test test-xla bench bench-smoke clean

artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

# Everything CI gates on, in one local command: formatting, lints,
# workspace tests, docs, and the bench smoke run (benches must run,
# not just compile).
check:
	cargo fmt --all -- --check
	cargo clippy --all-targets -- -D warnings
	cargo build --release --examples
	cargo test --release --workspace -q
	cargo test --release --doc -q
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
	$(MAKE) bench-smoke

test:
	cargo test --release -q

# Full suite including the PJRT execution path (real xla crate + jax).
test-xla: artifacts
	cargo test --release -q

bench:
	cargo bench

# Quick pass over the profile bench only (seconds; used by `check`/CI),
# swept over both band-engine settings so the dispatch path stays green,
# plus one `--json` run over both engines that regenerates the
# machine-readable perf/quality trajectory in bench_out/BENCH_PR5.json.
# Every smoke run doubles as the ordering-quality gate: it asserts the
# grid3d OPC stays under the recorded ceiling per leaf method
# (EXPERIMENTS.md §Perf.2), so leaf quality cannot regress silently.
bench-smoke:
	cargo bench --bench perf_profile -- --smoke --engine cpu
	cargo bench --bench perf_profile -- --smoke --engine xla
	cargo bench --bench perf_profile -- --smoke --json

clean:
	rm -rf artifacts bench_out target
