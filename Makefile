# Build helpers referenced throughout the docs and runtime messages.
#
# `artifacts` lowers the JAX/Pallas kernels to HLO-text artifacts the
# Rust runtime executes through PJRT (needs jax installed; see
# python/compile/aot.py). Everything else is plain cargo.
#
# NOTE: with the default offline `xla` stub (rust/xla-stub/), building
# artifacts makes the XLA integration tests *fail* rather than skip —
# the stub cannot execute them. Only run `test-xla` after wiring the
# real `xla` crate into Cargo.toml (see README.md).

.PHONY: artifacts check test test-trace test-threads test-xla tsan bench bench-smoke fault-smoke clean

artifacts:
	cd python && python3 -m compile.aot --out ../artifacts

# Everything CI gates on, in one local command: formatting, lints,
# workspace tests on both executors, docs, and the bench smoke run
# (benches must run, not just compile).
check:
	cargo fmt --all -- --check
	cargo clippy --all-targets -- -D warnings
	cargo build --release --examples
	cargo test --release --workspace -q
	$(MAKE) test-trace
	$(MAKE) test-threads
	cargo test --release --doc -q
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
	$(MAKE) bench-smoke
	$(MAKE) fault-smoke

test:
	cargo test --release -q

# The trace-invariant suite alone (DESIGN.md §7): nesting discipline,
# counter-delta tiling, off-vs-full bit-identity, Chrome round trip.
test-trace:
	cargo test --release -q --test trace_invariants

# The whole workspace again with the threaded executor as the default
# (DESIGN.md §3): every comm/dist test must pass on the free-running
# fabric, not just the serialized simulator.
test-threads:
	PTSCOTCH_EXECUTOR=threads cargo test --release --workspace -q

# ThreadSanitizer over the concurrency surface (comm fabrics, dist
# layer, stress + traffic suites). Needs nightly with rust-src; skips
# with a notice when no nightly toolchain is installed so `make tsan`
# stays runnable on stable-only boxes.
tsan:
	@if rustup toolchain list 2>/dev/null | grep -q nightly; then \
	  RUSTFLAGS="-Zsanitizer=thread" TSAN_OPTIONS=halt_on_error=1 \
	  PTSCOTCH_EXECUTOR=threads \
	  cargo +nightly test -Zbuild-std \
	    --target x86_64-unknown-linux-gnu \
	    --release -q --lib comm:: dist:: && \
	  RUSTFLAGS="-Zsanitizer=thread" TSAN_OPTIONS=halt_on_error=1 \
	  PTSCOTCH_EXECUTOR=threads PTSCOTCH_STRESS_DEADLINE_SECS=20 \
	  cargo +nightly test -Zbuild-std \
	    --target x86_64-unknown-linux-gnu \
	    --release -q --test comm_stress --test traffic --test service \
	    --test refiner_diff --test fault_injection --test trace_invariants; \
	else \
	  echo "tsan: no nightly toolchain installed (rustup toolchain install nightly --component rust-src); skipping"; \
	fi

# Full suite including the PJRT execution path (real xla crate + jax).
test-xla: artifacts
	cargo test --release -q

bench:
	cargo bench

# Quick pass over the profile bench only (seconds; used by `check`/CI),
# swept over both band-engine settings so the dispatch path stays green,
# once with the flow refiner forced so the flow-only path is exercised
# end-to-end (no OPC gate there — the ceilings are recorded for the
# default ladder), plus one `--json` run over both engines that
# regenerates the machine-readable perf/quality trajectory in
# bench_out/BENCH_PR10.json. Every un-pinned smoke run doubles as the
# ordering-quality gate: it asserts the grid3d OPC stays under the
# recorded ceiling per leaf method (EXPERIMENTS.md §Perf.2) and that the
# §Perf.4 service pass runs exactly one ordering cold and zero warm, so
# neither leaf quality nor the fingerprint cache can regress silently.
# The final step drives one traced ordering end-to-end (DESIGN.md §7):
# `trace=full` with `--trace-out` must produce Chrome trace JSON, and
# when jq is available the envelope is schema-checked (an event array
# whose entries all carry ph/pid, with timestamps on everything but the
# per-rank "M" metadata records).
bench-smoke:
	cargo bench --bench perf_profile -- --smoke --engine cpu
	cargo bench --bench perf_profile -- --smoke --engine xla
	cargo bench --bench perf_profile -- --smoke --refine flow
	cargo bench --bench perf_profile -- --smoke --json
	cargo build --release --bins
	mkdir -p bench_out
	./target/release/ptscotch order --graph grid3d:8x8x8 -p 4 --engine pts \
	  --strategy trace=full --trace-out bench_out/trace_smoke.json
	@if command -v jq >/dev/null 2>&1; then \
	  jq -e '.traceEvents | length > 0 and all(.ph and .pid != null) \
	    and (map(select(.ph != "M")) | length > 0 and all(.ts != null))' \
	    bench_out/trace_smoke.json >/dev/null \
	    && echo "trace smoke: Chrome JSON schema ok"; \
	else \
	  echo "trace smoke: jq not installed; skipped schema check"; \
	fi

# Fault-injection smoke (DESIGN.md §3.2): a scripted panic at rank 0's
# 60th transport op must make the CLI *fail* — cleanly, with a
# structured error, on both executors. The `!` inverts the exit status,
# so the target breaks if the fault is ever swallowed. (`order` has no
# retry ladder; only `batch`/`serve` recover.)
fault-smoke:
	cargo build --release --bins
	! PTSCOTCH_FAULT="0@60:panic" \
	  ./target/release/ptscotch order --graph grid2d:20x20 -p 2 --engine pts
	! PTSCOTCH_FAULT="0@60:panic" PTSCOTCH_EXECUTOR=threads \
	  ./target/release/ptscotch order --graph grid2d:20x20 -p 2 --engine pts

clean:
	rm -rf artifacts bench_out target
