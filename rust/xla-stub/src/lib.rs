//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The `ptscotch::runtime` module drives AOT-compiled XLA executables
//! through the [`xla` crate](https://crates.io/crates/xla) (PJRT CPU
//! client, HLO-text parsing, literal marshalling). That crate needs a
//! local XLA toolchain and network access to build, neither of which the
//! offline container provides, so this stub supplies the exact API
//! surface `runtime/mod.rs` compiles against and fails cleanly at
//! *runtime*: [`PjRtClient::cpu`] returns an error, which
//! `ptscotch::coordinator::OrderingService::new` treats as "no XLA
//! artifacts loaded" and falls back to the CPU refiners. All
//! XLA-dependent tests skip themselves when no artifacts are present.
//!
//! To run the real three-layer stack, replace the `xla` path dependency
//! in the root `Cargo.toml` with the upstream crate and run
//! `make artifacts` (see `python/compile/aot.py`).

/// Error type mirroring the upstream crate's; only its `Debug`
/// rendering is used by `ptscotch::runtime`.
#[derive(Debug)]
pub struct XlaError(pub String);

fn stub_err() -> XlaError {
    XlaError(
        "xla stub: built without the real PJRT bindings (offline); \
         CPU fallback paths remain available"
            .to_string(),
    )
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient(());

impl PjRtClient {
    /// Create a CPU PJRT client. The stub always errors, signalling the
    /// runtime loader to report "runtime unavailable".
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(stub_err())
    }

    /// Compile an HLO computation into a loaded executable.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(stub_err())
    }
}

/// Parsed HLO module proto (stub: parsing always fails).
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an HLO-text file produced by the AOT pipeline.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(stub_err())
    }
}

/// An XLA computation wrapping a parsed HLO module.
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a parsed HLO module proto.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Host-side literal (dense tensor) used to marshal kernel arguments.
pub struct Literal(());

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T>(_xs: &[T]) -> Literal {
        Literal(())
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Err(stub_err())
    }

    /// Extract element 0 of a tuple literal.
    pub fn to_tuple1(&self) -> Result<Literal, XlaError> {
        Err(stub_err())
    }

    /// Copy out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(stub_err())
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Synchronously transfer the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(stub_err())
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; returns per-device, per-output
    /// buffers.
    pub fn execute<A>(&self, _args: &[A]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(stub_err())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_stub() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err:?}").contains("stub"));
    }
}
