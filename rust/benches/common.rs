//! Shared bench harness utilities (criterion is unavailable in the
//! offline crate set, so benches are `harness = false` binaries that
//! print paper-style tables and append machine-readable CSV rows to
//! `bench_out/`).

#![allow(dead_code)] // each bench uses a subset of these helpers

use std::fs::OpenOptions;
use std::io::Write;
use std::path::Path;

/// Process counts swept by default; `PTSCOTCH_BENCH_FULL=1` extends to
/// the paper's full 2..64 range (64 simulated ranks on one core is slow).
pub fn proc_counts() -> Vec<usize> {
    if std::env::var_os("PTSCOTCH_BENCH_FULL").is_some() {
        vec![2, 4, 8, 16, 32, 64]
    } else {
        vec![2, 4, 8, 16]
    }
}

/// Graph-size scale factor (`PTSCOTCH_BENCH_SCALE`, default 1).
pub fn bench_scale() -> usize {
    std::env::var("PTSCOTCH_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

/// True when the bench runs in smoke mode — `--smoke` (the CI bench
/// smoke step and `make check`), `--test` (what `cargo bench -- --test`
/// forwards), or `PTSCOTCH_BENCH_SMOKE=1`. Smoke mode shrinks the
/// workload to seconds: it proves the bench still builds and runs, not
/// that its numbers mean anything.
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke" || a == "--test")
        || std::env::var_os("PTSCOTCH_BENCH_SMOKE").is_some()
}

/// Append one CSV row (with header on first write) to `bench_out/<file>`.
pub fn csv_row(file: &str, header: &str, row: &str) {
    let dir = Path::new("bench_out");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join(file);
    let fresh = !path.exists();
    let mut f = OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .expect("open csv");
    if fresh {
        writeln!(f, "{header}").unwrap();
    }
    writeln!(f, "{row}").unwrap();
}

/// Format an OPC the way the paper's tables do (e.g. `5.45e+12`).
pub fn sci(x: f64) -> String {
    format!("{x:.2e}")
}
