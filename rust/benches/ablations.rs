//! Ablations of the paper's design choices (DESIGN.md A1–A5).
//!
//! * **A1 band width** (§3.3): quality across band widths; the paper
//!   argues width 3 is the sweet spot — "keeping more layers of vertices
//!   in the band graph is not useful" — and that banding *improves*
//!   quality by pre-constraining FM.
//! * **A2/A3 fold-dup** (§3.2): multi-sequential best-of-p working
//!   copies vs a single working copy (`folddup=0`), plus the fold-dup
//!   threshold sweep.
//! * **A4 strictly-improving refinement** (§3.3): PT-Scotch's band
//!   multi-sequential refinement vs the ParMETIS-like strict pass on the
//!   same graphs (engine-level comparison at fixed p).
//! * **A5 refiner choice**: FM vs CPU diffusion vs AOT-XLA diffusion on
//!   the band hot path (quality and wallclock; xla == diffcpu
//!   numerically, the delta is execution path overhead).

#[path = "common.rs"]
mod common;

use ptscotch::coordinator::{Engine, OrderingRequest, OrderingService};
use ptscotch::graph::generators;
use ptscotch::runtime::XlaRuntime;
use ptscotch::strategy::Strategy;

/// Run one request through the builder API.
fn order(
    svc: &OrderingService,
    g: &ptscotch::graph::Graph,
    engine: Engine,
    strat: &Strategy,
) -> ptscotch::Result<ptscotch::coordinator::OrderingResult> {
    svc.run(&OrderingRequest::new(g).strategy(strat.clone()).engine(engine))
}

fn main() {
    let scale = common::bench_scale();
    let g = generators::grid3d(12 * scale, 12 * scale, 12 * scale);
    let svc = OrderingService::new(&XlaRuntime::default_dir());
    println!("ablation graph: grid3d {0}^3 (|V|={1})", 12 * scale, g.n());

    // --- A1: band width -------------------------------------------------
    println!("\n== A1: band width (sequential, seed fixed) ==");
    println!("{:<8} {:>12} {:>10} {:>8}", "width", "OPC", "NNZ", "t(s)");
    for w in [1u32, 2, 3, 5, 8] {
        let strat = Strategy::parse(&format!("band={w}")).unwrap();
        let rep = order(&svc, &g, Engine::Sequential, &strat).unwrap();
        println!(
            "{:<8} {:>12} {:>10} {:>8.2}",
            w,
            common::sci(rep.stats.opc),
            rep.stats.nnz,
            rep.wall_seconds
        );
        common::csv_row(
            "ablation_band.csv",
            "width,opc,nnz,seconds",
            &format!("{w},{:.6e},{},{:.3}", rep.stats.opc, rep.stats.nnz, rep.wall_seconds),
        );
    }

    // --- A2/A3: fold-dup ------------------------------------------------
    println!("\n== A2/A3: fold-dup vs single working copy (p = 8) ==");
    println!("{:<22} {:>12} {:>8}", "variant", "OPC", "t(s)");
    for (name, spec) in [
        ("fold-dup (paper)", "folddup=1"),
        ("single copy", "folddup=0"),
        ("fold-dup, thresh=50", "folddup=1,foldthresh=50"),
        ("fold-dup, thresh=400", "folddup=1,foldthresh=400"),
    ] {
        let strat = Strategy::parse(spec).unwrap();
        let rep = order(&svc, &g, Engine::PtScotch { p: 8 }, &strat).unwrap();
        println!(
            "{:<22} {:>12} {:>8.2}",
            name,
            common::sci(rep.stats.opc),
            rep.wall_seconds
        );
        common::csv_row(
            "ablation_folddup.csv",
            "variant,opc,seconds",
            &format!("{name},{:.6e},{:.3}", rep.stats.opc, rep.wall_seconds),
        );
    }

    // --- A4: refinement scheme -------------------------------------------
    println!("\n== A4: band multi-seq (PTS) vs strict-improving (PM), by p ==");
    println!("{:<4} {:>12} {:>12} {:>8}", "p", "OPC_PTS", "OPC_PM", "ratio");
    for p in [2usize, 4, 8, 16] {
        let strat = Strategy::default();
        let pts = order(&svc, &g, Engine::PtScotch { p }, &strat).unwrap();
        let pm = order(&svc, &g, Engine::ParMetisLike { p }, &strat).unwrap();
        println!(
            "{:<4} {:>12} {:>12} {:>8.3}",
            p,
            common::sci(pts.stats.opc),
            common::sci(pm.stats.opc),
            pm.stats.opc / pts.stats.opc
        );
        common::csv_row(
            "ablation_refine.csv",
            "p,opc_pts,opc_pm",
            &format!("{p},{:.6e},{:.6e}", pts.stats.opc, pm.stats.opc),
        );
    }

    // --- A5: refiner on the band hot path --------------------------------
    println!("\n== A5: band refiner (sequential engine) ==");
    println!("{:<12} {:>12} {:>8}", "refiner", "OPC", "t(s)");
    let mut variants = vec![("fm", "refiner=fm"), ("diffcpu", "refiner=diffcpu")];
    if svc.has_xla() {
        variants.push(("xla", "refiner=xla"));
    } else {
        println!("(xla variant skipped: run `make artifacts`)");
    }
    for (name, spec) in variants {
        let strat = Strategy::parse(spec).unwrap();
        let rep = order(&svc, &g, Engine::Sequential, &strat).unwrap();
        println!(
            "{:<12} {:>12} {:>8.2}",
            name,
            common::sci(rep.stats.opc),
            rep.wall_seconds
        );
        common::csv_row(
            "ablation_refiner.csv",
            "refiner,opc,seconds",
            &format!("{name},{:.6e},{:.3}", rep.stats.opc, rep.wall_seconds),
        );
    }
}
