//! Figures 6–9 reproduction: OPC and NNZ fill ratio vs process count for
//! the audikw1 and cage15 analogs, PT-Scotch vs ParMETIS-like, with the
//! sequential Scotch value as the reference line.
//!
//! Expected shape (paper): the PT-Scotch series hugs the sequential line
//! (often dipping below it as P grows — more multi-sequential working
//! copies), while the ParMETIS series climbs steeply (audikw1: 5.8e12 →
//! 1.07e13 from P=2 to 64, i.e. ~2× worse; NNZ ratio climbs similarly).
//!
//! Since the threaded executor landed (DESIGN.md §3) the table also
//! carries the PT-Scotch run's real wallclock and its speedup over the
//! sequential reference — a genuine parallel measurement when run with
//! `PTSCOTCH_EXECUTOR=threads` on a multicore host (EXPERIMENTS.md
//! §Perf.3 explains the single-core reading).

#[path = "common.rs"]
mod common;

use ptscotch::coordinator::{Engine, OrderingRequest, OrderingService};
use ptscotch::graph::generators;
use ptscotch::strategy::Strategy;

/// Run one request through the builder API.
fn order(
    svc: &OrderingService,
    g: &ptscotch::graph::Graph,
    engine: Engine,
    strat: &Strategy,
) -> ptscotch::Result<ptscotch::coordinator::OrderingResult> {
    svc.run(&OrderingRequest::new(g).strategy(strat.clone()).engine(engine))
}

fn main() {
    let scale = common::bench_scale();
    let svc = OrderingService::new_cpu_only();
    let strat = Strategy::default();
    let graphs = [
        (
            "audikw-like (figs 6–7)",
            "fig6_7.csv",
            generators::audikw_like(9 * scale, 9 * scale, 9 * scale, 0.02, 30, 1),
        ),
        (
            "cage-like (figs 8–9)",
            "fig8_9.csv",
            generators::cage_like(9000 * scale * scale, 8, 2),
        ),
    ];
    for (name, csv, g) in graphs {
        let seq = order(&svc, &g, Engine::Sequential, &strat).expect("sequential");
        println!("\n== {name}: |V|={} |E|={} ==", g.n(), g.m());
        println!(
            "sequential reference: OPC {}  fill {:.2}",
            common::sci(seq.stats.opc),
            seq.stats.fill_ratio
        );
        println!(
            "{:<4} {:>12} {:>10} {:>12} {:>10} {:>10} {:>8}",
            "p", "OPC_PTS", "fill_PTS", "OPC_PM", "fill_PM", "wall_PTS", "speedup"
        );
        for p in common::proc_counts() {
            let pts = order(&svc, &g, Engine::PtScotch { p }, &strat).expect("pts");
            let pm = order(&svc, &g, Engine::ParMetisLike { p }, &strat).ok();
            let (opm, fpm) = pm
                .as_ref()
                .map(|r| (common::sci(r.stats.opc), format!("{:.2}", r.stats.fill_ratio)))
                .unwrap_or(("†".into(), "†".into()));
            let speedup = seq.wall_seconds / pts.wall_seconds.max(1e-12);
            println!(
                "{:<4} {:>12} {:>10.2} {:>12} {:>10} {:>9.0}ms {:>7.2}x",
                p,
                common::sci(pts.stats.opc),
                pts.stats.fill_ratio,
                opm,
                fpm,
                pts.wall_seconds * 1e3,
                speedup
            );
            common::csv_row(
                csv,
                "p,opc_seq,fill_seq,opc_pts,fill_pts,opc_pm,fill_pm,\
                 wall_seq_s,wall_pts_s,speedup_pts",
                &format!(
                    "{p},{:.6e},{:.4},{:.6e},{:.4},{},{},{:.6},{:.6},{speedup:.4}",
                    seq.stats.opc,
                    seq.stats.fill_ratio,
                    pts.stats.opc,
                    pts.stats.fill_ratio,
                    pm.as_ref()
                        .map(|r| format!("{:.6e}", r.stats.opc))
                        .unwrap_or("NA".into()),
                    pm.as_ref()
                        .map(|r| format!("{:.4}", r.stats.fill_ratio))
                        .unwrap_or("NA".into()),
                    seq.wall_seconds,
                    pts.wall_seconds,
                ),
            );
        }
    }
}
