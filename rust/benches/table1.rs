//! Table 1 reproduction: the test-graph suite with vertex/edge counts,
//! average degree, and `O_SS` — the operation count of Cholesky
//! factorization on orderings computed by the *sequential* pipeline.
//!
//! Paper columns: |V|(×10³), |E|(×10³), average degree, O_SS.
//! Our rows are the structural analogs (DESIGN.md §3).

#[path = "common.rs"]
mod common;

use ptscotch::coordinator::{Engine, OrderingRequest, OrderingService};
use ptscotch::graph::generators;
use ptscotch::strategy::Strategy;

/// Run one request through the builder API.
fn order(
    svc: &OrderingService,
    g: &ptscotch::graph::Graph,
    engine: Engine,
    strat: &Strategy,
) -> ptscotch::Result<ptscotch::coordinator::OrderingResult> {
    svc.run(&OrderingRequest::new(g).strategy(strat.clone()).engine(engine))
}

fn main() {
    let scale = common::bench_scale();
    let svc = OrderingService::new_cpu_only();
    let strat = Strategy::default();
    println!("== Table 1 (analog suite, scale {scale}) ==");
    println!(
        "{:<18} {:>9} {:>10} {:>8} {:>12} {:>8}",
        "graph", "|V|", "|E|", "avg deg", "O_SS", "t(s)"
    );
    for (name, g) in generators::table1_suite(scale) {
        let rep = order(&svc, &g, Engine::Sequential, &strat).expect("sequential ordering");
        println!(
            "{:<18} {:>9} {:>10} {:>8.2} {:>12} {:>8.2}",
            name,
            g.n(),
            g.m(),
            g.avg_degree(),
            common::sci(rep.stats.opc),
            rep.wall_seconds
        );
        common::csv_row(
            "table1.csv",
            "graph,n,m,avg_degree,o_ss,nnz,seconds",
            &format!(
                "{name},{},{},{:.3},{:.6e},{},{:.3}",
                g.n(),
                g.m(),
                g.avg_degree(),
                rep.stats.opc,
                rep.stats.nnz,
                rep.wall_seconds
            ),
        );
    }
    println!("\nPaper shape check: 3D meshes dominate O_SS; the cage-like");
    println!("expander has by far the largest O_SS relative to its size");
    println!("(cage15's 4.06e+16 dwarfs audikw1's 5.48e+12 in the paper).");
}
