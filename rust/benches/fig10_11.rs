//! Figures 10–11 reproduction: memory used per process during ordering,
//! for the audikw1 and cage15 analogs.
//!
//! Expected shape (paper §4): average per-process memory falls with P
//! (good scalability despite fold-dup), but (Fig. 10) audikw1 shows high
//! *imbalance* because one process ends up owning the contiguous set of
//! very-high-degree vertices, and (Fig. 11) cage15 stops scaling beyond
//! ~8–16 processes because ghost vertices multiply.

#[path = "common.rs"]
mod common;

use ptscotch::coordinator::{Engine, OrderingRequest, OrderingService};
use ptscotch::graph::generators;
use ptscotch::strategy::Strategy;

/// Run one request through the builder API.
fn order(
    svc: &OrderingService,
    g: &ptscotch::graph::Graph,
    engine: Engine,
    strat: &Strategy,
) -> ptscotch::Result<ptscotch::coordinator::OrderingResult> {
    svc.run(&OrderingRequest::new(g).strategy(strat.clone()).engine(engine))
}

fn main() {
    let scale = common::bench_scale();
    let svc = OrderingService::new_cpu_only();
    let strat = Strategy::default();
    let graphs = [
        (
            "audikw-like (fig 10)",
            "fig10.csv",
            generators::audikw_like(9 * scale, 9 * scale, 9 * scale, 0.03, 40, 1),
        ),
        (
            "cage-like (fig 11)",
            "fig11.csv",
            generators::cage_like(9000 * scale * scale, 8, 2),
        ),
    ];
    for (name, csv, g) in graphs {
        println!("\n== {name}: |V|={} |E|={} ==", g.n(), g.m());
        println!(
            "{:<4} {:>12} {:>12} {:>12} {:>9}",
            "p", "mem min KiB", "mem avg KiB", "mem max KiB", "max/avg"
        );
        for p in common::proc_counts() {
            let rep = order(&svc, &g, Engine::PtScotch { p }, &strat).expect("pts");
            let (mn, avg, mx) = rep.mem_min_avg_max();
            println!(
                "{:<4} {:>12} {:>12.0} {:>12} {:>9.2}",
                p,
                mn / 1024,
                avg / 1024.0,
                mx / 1024,
                mx as f64 / avg.max(1.0)
            );
            common::csv_row(
                csv,
                "p,mem_min,mem_avg,mem_max",
                &format!("{p},{mn},{avg:.0},{mx}"),
            );
        }
    }
}
