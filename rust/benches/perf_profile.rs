//! §Perf phase profile: where does ordering time go, layer by layer —
//! and what quality does it buy?
//!
//! Times the individual L3 phases (coarsening, initial separator, FM,
//! band extraction, projection, minimum degree, symbolic evaluation) on
//! a mid-size 3D mesh, the distributed band BFS and band refinement
//! under both band engines (`--engine cpu|xla` pins one; see
//! EXPERIMENTS.md §Perf.1) with their bytes/messages on the wire, plus
//! the XLA (L1/L2) execution path when artifacts are present. The
//! §Perf.2 section orders the quality suite (grid3d + irregular_mesh,
//! p ∈ {1, 4}) under both leaf methods (`leafmethod=mmd|hamd`) and
//! tabulates NNZ/OPC/fill/etree height; in `--smoke` mode it asserts
//! the grid3d OPC stays under the recorded per-method ceiling, so leaf
//! quality cannot regress silently (`--refine <mode>` pins a band
//! `refine=` mode for the sweep — the ceilings are recorded for the
//! default ladder, so they are only enforced without a pin). The
//! refiner table right after it orders grid3d under every `refine=`
//! mode (fm, diffusion, flow, auto) at p ∈ {1, 4} and tabulates the
//! top-separator cut weight and balance next to the end-to-end OPC
//! (`refiners.csv`). The §Perf.3 section runs
//! `parallel_order` on grid3d under both executors
//! (`executor=sim|threads`, DESIGN.md §3) at p ∈ {1, 4, 8} and reports
//! real wallclock next to the fleet's critical path — the measured and
//! the ≥ p-core-modeled speedup columns of EXPERIMENTS.md §Perf.3. The
//! §Perf.4 section pushes a batch of identical requests through the
//! `BatchCoordinator` twice — cold (one real job, the rest coalesced)
//! and warm (pure fingerprint-cache hits) — and reports the hit rate
//! and the per-request latency of each pass, asserting the cold batch
//! ran exactly one ordering and the warm one ran zero. The §Perf.5
//! section orders grid3d at p ∈ {1, 4, 8} with `trace=phases`
//! (DESIGN.md §7) and tabulates the top-8 phases by exclusive wall
//! with their bytes/msgs columns plus the `sequential_tail_fraction`
//! — the slowest rank's leaf-order exclusive time over its run wall,
//! the Amdahl share the ROADMAP's parallel-leaf work must attack
//! (`phases.csv`). `--json` additionally writes the whole profile
//! (phases + quality + refiners + executor wallclocks + service
//! throughput + phase attribution) to `bench_out/BENCH_PR10.json`
//! (run by the CI bench/quality-smoke step). Used to drive and
//! document the optimization log in EXPERIMENTS.md §Perf.

#[path = "common.rs"]
mod common;

use ptscotch::coordinator::{BatchCoordinator, Engine, OrderingRequest, OrderingService, Served};
use ptscotch::graph::generators;
use ptscotch::order::hamd;
use ptscotch::order::mmd::minimum_degree;
use ptscotch::order::symbolic_cholesky;
use ptscotch::rng::Rng;
use ptscotch::runtime::{pack_ell_clamped, XlaRuntime};
use ptscotch::sep::band::extract_band;
use ptscotch::sep::coarsen::coarsen_hem;
use ptscotch::sep::fm::{fm_refine, FmParams};
use ptscotch::sep::initial::greedy_graph_growing;
use ptscotch::sep::{multilevel_separator, FmRefiner};
use ptscotch::strategy::{SepStrategy, Strategy};
use ptscotch::trace::profile::{COL_BYTES, COL_MSGS, COL_WALL};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Value of a `--engine <e>` / `--engine=<e>` argument, selecting which
/// band engine(s) the distributed-band profile rows run under (the CI
/// bench-smoke step sweeps both settings in separate invocations).
fn engine_arg() -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == "--engine")
        .and_then(|i| args.get(i + 1).cloned())
        .or_else(|| {
            args.iter()
                .find_map(|a| a.strip_prefix("--engine=").map(str::to_string))
        })
}

/// `--json` mode: also write every profiled row (wallclock plus, for
/// the distributed phases, bytes/messages on the wire), the
/// per-leaf-method quality table, the per-refiner quality table, the
/// sim-vs-threads executor wallclock rows, the §Perf.4 service rows
/// and the §Perf.5 phase-attribution rows
/// to `bench_out/BENCH_PR10.json` — the machine-readable perf/quality
/// trajectory the EXPERIMENTS.md BENCH log points at. CI runs this in
/// the bench-smoke step so the file regenerates on every push.
fn json_mode() -> bool {
    std::env::args().any(|a| a == "--json")
}

/// Value of a `--refine <mode>` / `--refine=<mode>` argument: pin one
/// band `refine=` mode (fm|diffusion|flow|auto) for the quality and
/// executor sweeps. The CI bench-smoke step runs once with
/// `--refine flow` so the forced-flow path is exercised end-to-end on
/// every push; the grid3d OPC ceilings are recorded for the default
/// ladder and therefore only enforced when no pin is given.
fn refine_arg() -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == "--refine")
        .and_then(|i| args.get(i + 1).cloned())
        .or_else(|| {
            args.iter()
                .find_map(|a| a.strip_prefix("--refine=").map(str::to_string))
        })
}

/// The extra `refine=` clause a `--refine` pin appends to the strategy
/// specs of the quality and executor sweeps (empty without a pin).
fn refine_clause() -> String {
    refine_arg().map(|m| format!(",refine={m}")).unwrap_or_default()
}

/// Run one request through the builder API.
fn order(
    svc: &OrderingService,
    g: &ptscotch::graph::Graph,
    engine: Engine,
    strat: &Strategy,
) -> ptscotch::Result<ptscotch::coordinator::OrderingResult> {
    svc.run(&OrderingRequest::new(g).strategy(strat.clone()).engine(engine))
}

/// One profiled phase: wallclock plus the traffic counters of the rank
/// fleet (zero for sequential phases).
struct Row {
    phase: String,
    ms: f64,
    bytes_sent: u64,
    msgs_sent: u64,
}

/// Rows accumulated for `--json` (the bench is single-threaded; the
/// mutex only satisfies `static`).
static ROWS: Mutex<Vec<Row>> = Mutex::new(Vec::new());

/// One ordering-quality measurement: a graph of the quality suite
/// ordered by `parallel_order` on `p` ranks under one leaf method,
/// evaluated with `order::symbolic` (§Perf.2).
struct QRow {
    graph: &'static str,
    n: usize,
    p: usize,
    method: &'static str,
    nnz: u64,
    opc: f64,
    fill: f64,
    height: usize,
    ms: f64,
}

/// Quality rows accumulated for the table, the CSV and `--json`.
static QROWS: Mutex<Vec<QRow>> = Mutex::new(Vec::new());

/// One band-refiner quality measurement: grid3d ordered under one
/// `refine=` mode at one rank count. Cut weight and balance are
/// separator-level quantities with no trace in the permutation, so they
/// are measured on the top bisection the sequential multilevel pipeline
/// produces under the same mode; OPC is the end-to-end ordering cost.
struct RfRow {
    refine: &'static str,
    p: usize,
    sep_weight: i64,
    imbalance: i64,
    opc: f64,
    ms: f64,
}

/// Refiner rows accumulated for the table, the CSV and `--json`.
static RFROWS: Mutex<Vec<RfRow>> = Mutex::new(Vec::new());

/// One §Perf.3 executor measurement: `parallel_order` on grid3d under
/// one executor at one rank count — real wallclock plus the fleet's
/// critical path (max per-rank busy time, the ≥ p-core model).
struct ERow {
    executor: &'static str,
    p: usize,
    wall_s: f64,
    crit_s: f64,
}

/// Executor rows accumulated for the table, the CSV and `--json`.
static EROWS: Mutex<Vec<ERow>> = Mutex::new(Vec::new());

/// One §Perf.4 service-throughput measurement: a batch of identical
/// requests through the [`BatchCoordinator`], cold (empty cache) or
/// warm (replay), with the jobs actually run, the batch hit rate and
/// the mean per-request latency (queue + run).
struct SRow {
    pass: &'static str,
    requests: usize,
    jobs_run: usize,
    hit_rate: f64,
    mean_ms: f64,
    wall_ms: f64,
}

/// Service rows accumulated for the table, the CSV and `--json`.
static SROWS: Mutex<Vec<SRow>> = Mutex::new(Vec::new());

/// One §Perf.5 phase-attribution measurement: one phase of a
/// `trace=phases` grid3d ordering at one rank count — exclusive wall
/// (summed over the profile tree and all ranks) with its traffic
/// columns, plus the run's sequential-tail fraction (identical on
/// every row of the same `p`).
struct PhRow {
    p: usize,
    phase: &'static str,
    count: u64,
    excl_ms: f64,
    bytes: u64,
    msgs: u64,
    tail: f64,
}

/// Phase-attribution rows accumulated for the table, the CSV and
/// `--json`.
static PHROWS: Mutex<Vec<PhRow>> = Mutex::new(Vec::new());

/// Mean OPC per `(p, mmd, hamd)` over the accumulated quality rows —
/// the single source for both the printed summary and the JSON
/// `quality_mean_opc` section, so they cannot diverge.
fn quality_mean_opc(qrows: &[QRow]) -> Vec<(usize, f64, f64)> {
    let mut ps: Vec<usize> = qrows.iter().map(|q| q.p).collect();
    ps.sort_unstable();
    ps.dedup();
    ps.iter()
        .map(|&p| {
            let mean = |m: &str| -> f64 {
                let sel: Vec<f64> = qrows
                    .iter()
                    .filter(|q| q.p == p && q.method == m)
                    .map(|q| q.opc)
                    .collect();
                sel.iter().sum::<f64>() / sel.len().max(1) as f64
            };
            (p, mean("mmd"), mean("hamd"))
        })
        .collect()
}

/// Smoke-mode guard rails for the grid3d quality rows at p = 1, one
/// ceiling per leaf method (EXPERIMENTS.md §Perf.2 records the rationale
/// and the measured values). The smoke grid is 10³: a working ordering
/// lands near 2.1e6 OPC, the natural (banded) order already costs
/// ~1.0e7, so a breached ceiling means leaf ordering genuinely
/// regressed — not noise (the pipeline is bit-deterministic per seed).
/// Tightened from (6.0e6, 5.5e6) once the flow stage joined the default
/// refinement ladder: separators can only have improved, so the gate
/// follows — roughly 2× headroom over the measured values remains.
const SMOKE_GRID3D_OPC_CEILING: [(&str, f64); 2] = [("mmd", 4.5e6), ("hamd", 4.0e6)];

fn record(name: &str, ms: f64, bytes_sent: u64, msgs_sent: u64) {
    println!("{name:<34} {:>10.2} ms", ms);
    common::csv_row("perf_profile.csv", "phase,ms", &format!("{name},{ms:.4}"));
    ROWS.lock().unwrap().push(Row {
        phase: name.to_string(),
        ms,
        bytes_sent,
        msgs_sent,
    });
}

fn time<R>(name: &str, reps: usize, mut f: impl FnMut() -> R) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(f());
    }
    let dt = t0.elapsed().as_secs_f64() / reps as f64;
    record(name, dt * 1e3, 0, 0);
    dt
}

/// Serialize the accumulated rows as `bench_out/BENCH_PR10.json`. Phase
/// names contain no quotes or backslashes, so the literal embedding is
/// valid JSON.
fn write_json(smoke: bool, scale: usize) {
    let rows = ROWS.lock().unwrap();
    let qrows = QROWS.lock().unwrap();
    let rfrows = RFROWS.lock().unwrap();
    let erows = EROWS.lock().unwrap();
    let srows = SROWS.lock().unwrap();
    let phrows = PHROWS.lock().unwrap();
    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut s = String::new();
    s.push_str("{\n  \"bench\": \"perf_profile\",\n");
    s.push_str(&format!("  \"smoke\": {smoke},\n"));
    s.push_str(&format!("  \"scale\": {scale},\n"));
    s.push_str(&format!("  \"unix_time\": {unix_time},\n"));
    s.push_str("  \"phases\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let sep = if i + 1 < rows.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"phase\": \"{}\", \"ms\": {:.4}, \"bytes_sent\": {}, \
             \"msgs_sent\": {}}}{sep}\n",
            r.phase, r.ms, r.bytes_sent, r.msgs_sent
        ));
    }
    s.push_str("  ],\n");
    // §Perf.2: the per-leaf-method ordering-quality table plus the
    // mean-OPC comparison the acceptance gate reads (hamd strictly
    // better than halo-blind mmd at each p).
    s.push_str("  \"quality\": [\n");
    for (i, q) in qrows.iter().enumerate() {
        let sep = if i + 1 < qrows.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"graph\": \"{}\", \"n\": {}, \"p\": {}, \"leafmethod\": \"{}\", \
             \"nnz\": {}, \"opc\": {:.6e}, \"fill_ratio\": {:.4}, \
             \"tree_height\": {}, \"ms\": {:.2}}}{sep}\n",
            q.graph, q.n, q.p, q.method, q.nnz, q.opc, q.fill, q.height, q.ms
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"quality_mean_opc\": [\n");
    let means = quality_mean_opc(&qrows);
    for (i, &(p, mmd, hamd)) in means.iter().enumerate() {
        let sep = if i + 1 < means.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"p\": {p}, \"mmd\": {mmd:.6e}, \"hamd\": {hamd:.6e}, \
             \"hamd_strictly_better\": {}}}{sep}\n",
            hamd < mmd
        ));
    }
    s.push_str("  ],\n");
    // The per-refiner quality table (`refine=fm|diffusion|flow|auto`):
    // top-separator cut weight / balance plus end-to-end OPC.
    s.push_str("  \"refiners\": [\n");
    for (i, r) in rfrows.iter().enumerate() {
        let sep = if i + 1 < rfrows.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"graph\": \"grid3d\", \"p\": {}, \"refine\": \"{}\", \
             \"sep_weight\": {}, \"imbalance\": {}, \"opc\": {:.6e}, \
             \"ms\": {:.2}}}{sep}\n",
            r.p, r.refine, r.sep_weight, r.imbalance, r.opc, r.ms
        ));
    }
    s.push_str("  ],\n");
    // §Perf.3: sim-vs-threads wallclock rows plus the speedup summary
    // (measured wallclock ratio and the critical-path model of what
    // a ≥ p-core host delivers; see EXPERIMENTS.md §Perf.3 for why
    // both columns are reported).
    s.push_str("  \"executors\": [\n");
    for (i, e) in erows.iter().enumerate() {
        let sep = if i + 1 < erows.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"executor\": \"{}\", \"p\": {}, \"wall_s\": {:.6}, \
             \"critical_path_s\": {:.6}}}{sep}\n",
            e.executor, e.p, e.wall_s, e.crit_s
        ));
    }
    s.push_str("  ],\n");
    // §Perf.4: service-throughput rows (cold vs warm batch through the
    // batch coordinator; see EXPERIMENTS.md §Perf.4).
    s.push_str("  \"service\": [\n");
    for (i, r) in srows.iter().enumerate() {
        let sep = if i + 1 < srows.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"pass\": \"{}\", \"requests\": {}, \"jobs_run\": {}, \
             \"hit_rate\": {:.4}, \"mean_ms_per_request\": {:.4}, \
             \"wall_ms\": {:.4}}}{sep}\n",
            r.pass, r.requests, r.jobs_run, r.hit_rate, r.mean_ms, r.wall_ms
        ));
    }
    s.push_str("  ],\n");
    // §Perf.5: phase-attribution rows — top-8 phases by exclusive wall
    // per rank count, from the `trace=phases` span recorder
    // (DESIGN.md §7), with the per-p sequential-tail fraction.
    s.push_str("  \"phase_attribution\": [\n");
    for (i, r) in phrows.iter().enumerate() {
        let sep = if i + 1 < phrows.len() { "," } else { "" };
        s.push_str(&format!(
            "    {{\"p\": {}, \"phase\": \"{}\", \"count\": {}, \
             \"excl_wall_ms\": {:.4}, \"bytes_sent\": {}, \"msgs_sent\": {}, \
             \"sequential_tail_fraction\": {:.4}}}{sep}\n",
            r.p, r.phase, r.count, r.excl_ms, r.bytes, r.msgs, r.tail
        ));
    }
    s.push_str("  ],\n");
    let (pmax, measured, modeled) = executor_speedup(&erows);
    s.push_str(&format!(
        "  \"speedup\": {{\"graph\": \"grid3d\", \"p\": {pmax}, \
         \"measured_wallclock\": {measured:.4}, \
         \"critical_path_model\": {modeled:.4}, \
         \"host_cores\": {}}}\n",
        std::thread::available_parallelism().map_or(1, |n| n.get())
    ));
    s.push_str("}\n");
    let dir = std::path::Path::new("bench_out");
    let _ = std::fs::create_dir_all(dir);
    let path = dir.join("BENCH_PR10.json");
    std::fs::write(&path, s).expect("write BENCH_PR10.json");
    println!("\nwrote {}", path.display());
}

/// `(p_max, measured, modeled)` speedup of the threaded executor at the
/// largest profiled rank count over its own p = 1 run: `measured` is
/// the real wallclock ratio (meaningful on a ≥ p-core host), `modeled`
/// divides the p = 1 wallclock by the fleet's critical path — the
/// schedule-independent bound a ≥ p-core host converges to, computable
/// even on one core because busy time excludes transport blocking.
fn executor_speedup(erows: &[ERow]) -> (usize, f64, f64) {
    let thr: Vec<&ERow> = erows.iter().filter(|e| e.executor == "threads").collect();
    let base = thr.iter().find(|e| e.p == 1);
    let top = thr.iter().max_by_key(|e| e.p);
    match (base, top) {
        (Some(b), Some(t)) if t.p > 1 => (
            t.p,
            b.wall_s / t.wall_s.max(1e-12),
            b.wall_s / t.crit_s.max(1e-12),
        ),
        _ => (1, 1.0, 1.0),
    }
}

/// §Perf.3 — real wallclock per executor: `parallel_order` on grid3d
/// under both executors at p ∈ {1, 4, 8}, with the critical-path
/// column that models ≥ p cores (EXPERIMENTS.md §Perf.3).
fn executor_profile(smoke: bool, scale: usize) {
    let s = scale.max(1);
    let g = if smoke {
        generators::grid3d(10, 10, 10)
    } else {
        generators::grid3d(16 * s, 16 * s, 16 * s)
    };
    let svc = OrderingService::new_cpu_only();
    println!("\n-- executor wallclock (§Perf.3, grid3d n={}) --", g.n());
    println!(
        "{:<9} {:>3} {:>12} {:>16}",
        "executor", "p", "wall_ms", "critical_path_ms"
    );
    for exec in ["sim", "threads"] {
        for p in [1usize, 4, 8] {
            let strat = Strategy::parse(&format!("executor={exec}{}", refine_clause())).unwrap();
            let rep = order(&svc, &g, Engine::PtScotch { p }, &strat)
                .expect("executor profile ordering");
            let (wall, crit) = (rep.wall_seconds, rep.critical_path_seconds());
            println!(
                "{exec:<9} {p:>3} {:>12.2} {:>16.2}",
                wall * 1e3,
                crit * 1e3
            );
            common::csv_row(
                "executors.csv",
                "executor,p,wall_s,critical_path_s",
                &format!("{exec},{p},{wall:.6},{crit:.6}"),
            );
            EROWS.lock().unwrap().push(ERow {
                executor: exec,
                p,
                wall_s: wall,
                crit_s: crit,
            });
        }
    }
    let erows = EROWS.lock().unwrap();
    let (pmax, measured, modeled) = executor_speedup(&erows);
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    println!(
        "threads p={pmax} vs p=1: measured {measured:.2}x (host has {cores} core(s)), \
         critical-path model {modeled:.2}x"
    );
}

/// §Perf.2 — order the quality suite under both leaf methods and both
/// rank counts, tabulate the paper's quality metrics, and (in smoke
/// mode) enforce the recorded grid3d OPC ceilings.
fn quality_profile(smoke: bool, scale: usize) {
    let s = scale.max(1);
    let graphs: Vec<(&'static str, ptscotch::graph::Graph)> = if smoke {
        vec![
            ("grid3d", generators::grid3d(10, 10, 10)),
            ("irregular_mesh", generators::irregular_mesh(24, 24, 7)),
        ]
    } else {
        vec![
            ("grid3d", generators::grid3d(16 * s, 16 * s, 16 * s)),
            ("irregular_mesh", generators::irregular_mesh(48 * s, 48 * s, 7)),
        ]
    };
    let svc = OrderingService::new_cpu_only();
    println!("\n-- ordering quality per leaf method (§Perf.2) --");
    println!(
        "{:<16} {:>7} {:>3} {:>6} {:>10} {:>12} {:>6} {:>7} {:>9}",
        "graph", "n", "p", "leaf", "nnz", "opc", "fill", "height", "ms"
    );
    for &(name, ref g) in &graphs {
        for p in [1usize, 4] {
            for method in ["mmd", "hamd"] {
                let strat =
                    Strategy::parse(&format!("leafmethod={method}{}", refine_clause())).unwrap();
                let t0 = Instant::now();
                let rep = order(&svc, g, Engine::PtScotch { p }, &strat).expect("ordering");
                let ms = t0.elapsed().as_secs_f64() * 1e3;
                let st = rep.stats;
                println!(
                    "{name:<16} {:>7} {p:>3} {method:>6} {:>10} {:>12.4e} {:>6.2} {:>7} {:>9.2}",
                    g.n(),
                    st.nnz,
                    st.opc,
                    st.fill_ratio,
                    st.tree_height,
                    ms
                );
                common::csv_row(
                    "leaf_quality.csv",
                    "graph,n,p,leafmethod,nnz,opc,fill_ratio,tree_height,ms",
                    &format!(
                        "{name},{},{p},{method},{},{:.6e},{:.4},{},{ms:.2}",
                        g.n(),
                        st.nnz,
                        st.opc,
                        st.fill_ratio,
                        st.tree_height
                    ),
                );
                QROWS.lock().unwrap().push(QRow {
                    graph: name,
                    n: g.n(),
                    p,
                    method,
                    nnz: st.nnz,
                    opc: st.opc,
                    fill: st.fill_ratio,
                    height: st.tree_height,
                    ms,
                });
            }
        }
    }
    let qrows = QROWS.lock().unwrap();
    for (p, mmd, hamd) in quality_mean_opc(&qrows) {
        println!(
            "mean OPC at p={p}: mmd {mmd:.4e}  hamd {hamd:.4e}  ({}, {:+.2}%)",
            if hamd < mmd {
                "hamd strictly better"
            } else {
                "hamd NOT better"
            },
            (hamd / mmd - 1.0) * 100.0
        );
    }
    if smoke && refine_arg().is_some() {
        // The ceilings below are recorded for the default refinement
        // ladder; a pinned mode (e.g. forced flow without FM) may
        // legitimately land elsewhere, so the gate stands down.
        println!("quality smoke: ceilings not enforced under a --refine pin");
    } else if smoke {
        // The quality guard rail: grid3d at p = 1 must stay under the
        // recorded per-method ceiling (the run is deterministic, so a
        // breach is a real regression, not noise).
        for &(method, ceiling) in &SMOKE_GRID3D_OPC_CEILING {
            let q = qrows
                .iter()
                .find(|q| q.graph == "grid3d" && q.p == 1 && q.method == method)
                .expect("grid3d quality row");
            assert!(
                q.opc < ceiling,
                "quality smoke FAILED: grid3d leafmethod={method} OPC {:.4e} \
                 breached the recorded ceiling {ceiling:.4e} (EXPERIMENTS.md §Perf.2)",
                q.opc
            );
        }
        println!("quality smoke: grid3d OPC under the recorded ceiling for every leaf method");
    }
}

/// §Perf.2b — band-refiner quality: order grid3d under each `refine=`
/// mode and tabulate the top-separator cut weight and balance next to
/// the end-to-end OPC. The separator columns come from the sequential
/// multilevel pipeline run under the same mode — cut weight and balance
/// are separator-level quantities with no trace in the permutation —
/// while OPC and wallclock come from the full `p`-rank ordering.
fn refiner_profile(smoke: bool, scale: usize) {
    let s = scale.max(1);
    let g = if smoke {
        generators::grid3d(10, 10, 10)
    } else {
        generators::grid3d(16 * s, 16 * s, 16 * s)
    };
    let svc = OrderingService::new_cpu_only();
    println!("\n-- band-refiner quality (§Perf.2b, grid3d n={}) --", g.n());
    println!(
        "{:<10} {:>3} {:>8} {:>10} {:>12} {:>9}",
        "refine", "p", "sep_wgt", "imbalance", "opc", "ms"
    );
    for refine in ["fm", "diffusion", "flow", "auto"] {
        let strat = Strategy::parse(&format!("refine={refine}")).unwrap();
        let sep = multilevel_separator(&g, &strat.sep, &FmRefiner::default(), &mut Rng::new(1));
        let (sep_weight, imbalance) = sep.quality_key();
        for p in [1usize, 4] {
            let t0 = Instant::now();
            let rep = order(&svc, &g, Engine::PtScotch { p }, &strat).expect("refiner ordering");
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            let opc = rep.stats.opc;
            println!("{refine:<10} {p:>3} {sep_weight:>8} {imbalance:>10} {opc:>12.4e} {ms:>9.2}");
            common::csv_row(
                "refiners.csv",
                "graph,n,p,refine,sep_weight,imbalance,opc,ms",
                &format!(
                    "grid3d,{},{p},{refine},{sep_weight},{imbalance},{opc:.6e},{ms:.2}",
                    g.n()
                ),
            );
            RFROWS.lock().unwrap().push(RfRow {
                refine,
                p,
                sep_weight,
                imbalance,
                opc,
                ms,
            });
        }
    }
}

/// §Perf.4 — service throughput: push a batch of identical requests
/// through the [`BatchCoordinator`] twice. The cold pass pays exactly
/// one full ordering (the duplicates coalesce onto the leader's job);
/// the warm pass is pure fingerprint-cache hits with zero rank work,
/// so its per-request latency is the service-overhead floor
/// (EXPERIMENTS.md §Perf.4). Both invariants are asserted, so a cache
/// or coalescing regression fails the bench even in smoke mode.
fn service_profile(smoke: bool, scale: usize) {
    let s = scale.max(1);
    let g = if smoke {
        generators::grid3d(10, 10, 10)
    } else {
        generators::grid3d(12 * s, 12 * s, 12 * s)
    };
    let g = Arc::new(g);
    let coord = BatchCoordinator::new(OrderingService::new_cpu_only());
    let batch: Vec<OrderingRequest> = (0..6)
        .map(|i| {
            OrderingRequest::from_arc(Arc::clone(&g))
                .engine(Engine::PtScotch { p: 4 })
                .tag(format!("r{i}"))
        })
        .collect();
    println!(
        "\n-- service throughput (§Perf.4, grid3d n={}, batch of {}) --",
        g.n(),
        batch.len()
    );
    println!(
        "{:<6} {:>9} {:>9} {:>9} {:>13} {:>10}",
        "pass", "requests", "jobs", "hit_rate", "mean_ms/req", "wall_ms"
    );
    for pass in ["cold", "warm"] {
        let t0 = Instant::now();
        let reports = coord.submit(batch.clone());
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let n = reports.len();
        let jobs = reports.iter().filter(|r| r.served == Served::Miss).count();
        let mean_ms = reports
            .iter()
            .map(|r| (r.queue_seconds + r.run_seconds) * 1e3)
            .sum::<f64>()
            / n.max(1) as f64;
        for r in &reports {
            assert!(r.result.is_ok(), "{pass} request {} failed", r.tag);
        }
        let hit_rate = (n - jobs) as f64 / n.max(1) as f64;
        let expected_jobs = if pass == "cold" { 1 } else { 0 };
        assert_eq!(
            jobs, expected_jobs,
            "{pass} batch must run exactly {expected_jobs} ordering(s), ran {jobs}"
        );
        println!("{pass:<6} {n:>9} {jobs:>9} {hit_rate:>9.2} {mean_ms:>13.3} {wall_ms:>10.2}");
        common::csv_row(
            "service_throughput.csv",
            "pass,requests,jobs_run,hit_rate,mean_ms_per_request,wall_ms",
            &format!("{pass},{n},{jobs},{hit_rate:.4},{mean_ms:.4},{wall_ms:.4}"),
        );
        SROWS.lock().unwrap().push(SRow {
            pass,
            requests: n,
            jobs_run: jobs,
            hit_rate,
            mean_ms,
            wall_ms,
        });
    }
    let m = coord.metrics();
    println!(
        "service totals: {} requests, {} ordering(s) run, {} hits, {} coalesced \
         (aggregate hit-rate {:.0}%; recovery: {} aborts, {} retries, {} degraded)",
        m.requests(),
        m.jobs_run,
        m.hits,
        m.coalesced,
        m.hit_rate() * 100.0,
        m.aborts,
        m.retries,
        m.degraded
    );
}

/// §Perf.5 — phase attribution: order grid3d at p ∈ {1, 4, 8} with
/// `trace=phases` (DESIGN.md §7) and tabulate the top-8 phases by
/// exclusive wall — summed over the profile tree and all ranks, so the
/// column tiles to the run totals — with their bytes/msgs columns.
/// The `seq_tail` column is the profile's sequential-tail fraction:
/// the slowest rank's leaf-order exclusive wall over its run wall, the
/// Amdahl share of the sequential leaf tail (EXPERIMENTS.md §Perf.5).
fn phases_profile(smoke: bool, scale: usize) {
    let s = scale.max(1);
    let g = if smoke {
        generators::grid3d(10, 10, 10)
    } else {
        generators::grid3d(16 * s, 16 * s, 16 * s)
    };
    let svc = OrderingService::new_cpu_only();
    println!(
        "\n-- phase attribution (§Perf.5, grid3d n={}, trace=phases) --",
        g.n()
    );
    println!(
        "{:<4} {:<18} {:>6} {:>12} {:>12} {:>8} {:>9}",
        "p", "phase", "count", "excl_ms", "bytes", "msgs", "seq_tail"
    );
    for p in [1usize, 4, 8] {
        let strat = Strategy::parse(&format!("trace=phases{}", refine_clause())).unwrap();
        let rep = order(&svc, &g, Engine::PtScotch { p }, &strat).expect("traced ordering");
        let prof = rep.profile.as_ref().expect("trace=phases builds a profile");
        let tail = prof.sequential_tail_fraction();
        let mut totals = prof.phase_totals();
        totals.sort_by(|a, b| {
            b.2[COL_WALL]
                .cmp(&a.2[COL_WALL])
                .then(a.0.name().cmp(b.0.name()))
        });
        for &(ph, count, cols) in totals.iter().take(8) {
            let ms = cols[COL_WALL] as f64 / 1e6;
            println!(
                "{p:<4} {:<18} {count:>6} {ms:>12.2} {:>12} {:>8} {tail:>9.3}",
                ph.name(),
                cols[COL_BYTES],
                cols[COL_MSGS]
            );
            common::csv_row(
                "phases.csv",
                "p,phase,count,excl_wall_ms,bytes_sent,msgs_sent,sequential_tail_fraction",
                &format!(
                    "{p},{},{count},{ms:.4},{},{},{tail:.4}",
                    ph.name(),
                    cols[COL_BYTES],
                    cols[COL_MSGS]
                ),
            );
            PHROWS.lock().unwrap().push(PhRow {
                p,
                phase: ph.name(),
                count,
                excl_ms: ms,
                bytes: cols[COL_BYTES],
                msgs: cols[COL_MSGS],
                tail,
            });
        }
    }
}

fn main() {
    // Smoke mode (CI / `make check`): a tiny graph and single reps —
    // exercises every phase end-to-end in seconds so the bench can't
    // silently rot, without pretending to measure anything.
    let smoke = common::smoke_mode();
    let scale = common::bench_scale();
    let side = if smoke { 8 } else { 24 * scale };
    let reps = |r: usize| if smoke { 1 } else { r };
    let g = generators::grid3d(side, side, side);
    println!("perf graph: grid3d {side}^3 (|V|={}, |E|={})\n", g.n(), g.m());

    println!("-- L3 phases --");
    let mut rng = Rng::new(1);
    time("coarsen_hem (1 level)", reps(5), || coarsen_hem(&g, &mut rng));
    // Build the level-1 coarse graph once for downstream phases.
    let c1 = coarsen_hem(&g, &mut Rng::new(1)).coarse;
    time("greedy_graph_growing (4 tries)", reps(5), || {
        greedy_graph_growing(&c1, 4, &mut rng)
    });
    let s0 = greedy_graph_growing(&g, 2, &mut Rng::new(2));
    time("fm_refine (whole graph)", reps(3), || {
        let mut s = s0.clone();
        fm_refine(&g, &mut s, &[], &FmParams::default(), &mut rng)
    });
    time("extract_band (w=3)", reps(5), || extract_band(&g, &s0, 3));
    let band = extract_band(&g, &s0, 3).unwrap();
    println!("   (band size {} of {})", band.band_n(), g.n());
    time("fm_refine (band only)", reps(5), || {
        let mut b = band.clone();
        fm_refine(&b.graph, &mut b.state, &b.locked, &FmParams::default(), &mut rng)
    });
    time("multilevel_separator (full)", reps(3), || {
        multilevel_separator(&g, &SepStrategy::default(), &FmRefiner::default(), &mut rng)
    });
    let leaf_side = if smoke { 4 } else { 5 * scale };
    let leaf = generators::grid3d(leaf_side, leaf_side, leaf_side);
    time("minimum_degree (leaf s³)", reps(5), || minimum_degree(&leaf));
    let no_halo = vec![false; leaf.n()];
    time("hamd (leaf s³, empty halo)", reps(5), || hamd(&leaf, &no_halo));
    let svc = OrderingService::new(&XlaRuntime::default_dir());
    let rep = order(&svc, &g, Engine::Sequential, &Strategy::default()).unwrap();
    time("symbolic_cholesky (eval)", reps(3), || {
        symbolic_cholesky(&g, &rep.ordering)
    });
    time("nested_dissection (end-to-end)", 1, || {
        order(&svc, &g, Engine::Sequential, &Strategy::default()).unwrap()
    });
    // Distributed diffusion on an oversized band — the scalable path of
    // `dist::dsep::band_refine_dist` (maxband forced tiny), kept in the
    // profile so its halo-sweep cost stays visible. Run once per band
    // engine (`engine=cpu` vs `engine=xla`, or only the engine named by
    // `--engine <e>`): with artifacts present the xla row measures the
    // per-rank fused-kernel path, without them it measures the dispatch
    // overhead of the collectively-agreed fallback to the same CPU
    // sweeps — either way the dispatch path cannot silently rot.
    {
        use ptscotch::comm;
        use ptscotch::runtime::load_shared;
        let engines: Vec<String> = match engine_arg() {
            Some(e) => vec![e],
            None => vec!["cpu".into(), "xla".into()],
        };
        let band_rt = load_shared(&XlaRuntime::default_dir()).ok();
        let (nx, ny) = if smoke { (16usize, 16usize) } else { (64 * scale, 64 * scale) };
        let g2 = Arc::new(generators::grid2d(nx, ny));
        let proj = Arc::new(generators::column_separator_part(nx, ny, nx / 2, 2));
        // Construction baseline (distribution + HaloPlan want-list
        // round), measured as its own row so the bfs/refine rows below
        // can report the traffic of their phase alone — the byte/msg
        // subtraction is exact because construction is deterministic.
        let (build_ms, build_bytes, build_msgs) = {
            let g2 = g2.clone();
            let t0 = Instant::now();
            let (res, stats) = comm::run(4, move |c| {
                use ptscotch::dist::dgraph::DGraph;
                DGraph::from_global(&c, &g2).nloc()
            });
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            std::hint::black_box(res.iter().sum::<usize>());
            record("dist graph build (p=4)", ms, stats.total_bytes(), stats.total_msgs());
            (ms, stats.total_bytes(), stats.total_msgs())
        };
        for eng in &engines {
            let strat = Strategy::parse(&format!("maxband=8,sweeps=16,engine={eng}")).unwrap();
            // Band BFS alone (the frontier / fused min-plus engine):
            // timed with its traffic, which the plan-based halo keeps to
            // one data alltoallv (or sparse frontier exchange) per level.
            {
                let g2 = g2.clone();
                let proj = proj.clone();
                let strat2 = strat.clone();
                let rt = band_rt.clone();
                let t0 = Instant::now();
                let (res, stats) = comm::run(4, move |c| {
                    use ptscotch::dist::dband::bfs_band_dist_engine;
                    use ptscotch::dist::dgraph::DGraph;
                    let dg = DGraph::from_global(&c, &g2);
                    let part: Vec<u8> = (0..dg.nloc())
                        .map(|v| proj[dg.glb(v) as usize])
                        .collect();
                    let (dist, _) = bfs_band_dist_engine(
                        &c,
                        &dg,
                        &part,
                        3,
                        strat2.dist.band_engine,
                        rt.as_ref(),
                    );
                    dist.iter().filter(|&&x| x != u32::MAX).count()
                });
                let dt = t0.elapsed().as_secs_f64();
                std::hint::black_box(res.iter().sum::<usize>());
                record(
                    &format!("dist band bfs (p=4, engine={eng})"),
                    (dt * 1e3 - build_ms).max(0.0),
                    stats.total_bytes().saturating_sub(build_bytes),
                    stats.total_msgs().saturating_sub(build_msgs),
                );
            }
            // Full oversized-band refinement — the scalable path of
            // `dist::dsep::band_refine_dist` (maxband forced tiny).
            {
                let g2 = g2.clone();
                let proj = proj.clone();
                let strat2 = strat.clone();
                let rt = band_rt.clone();
                let t0 = Instant::now();
                let (res, stats) = comm::run(4, move |c| {
                    use ptscotch::dist::dgraph::DGraph;
                    use ptscotch::sep::SEP;
                    let dg = DGraph::from_global(&c, &g2);
                    let mut part: Vec<u8> = (0..dg.nloc())
                        .map(|v| proj[dg.glb(v) as usize])
                        .collect();
                    let refiner = ptscotch::sep::FmRefiner::default();
                    let rng = Rng::new(1);
                    let mem = ptscotch::comm::MemTracker::new();
                    ptscotch::dist::dsep::band_refine_dist(
                        &c,
                        &dg,
                        &mut part,
                        &strat2,
                        &refiner,
                        rt.as_ref(),
                        &rng,
                        &mem,
                    );
                    part.iter().filter(|&&x| x == SEP).count()
                });
                let dt = t0.elapsed().as_secs_f64();
                std::hint::black_box(res.iter().sum::<usize>());
                record(
                    &format!("dist band refine (p=4, engine={eng})"),
                    (dt * 1e3 - build_ms).max(0.0),
                    stats.total_bytes().saturating_sub(build_bytes),
                    stats.total_msgs().saturating_sub(build_msgs),
                );
            }
        }
        if band_rt.is_none() && engines.iter().any(|e| e == "xla") {
            println!("   (no artifacts loaded: engine=xla measured the CPU fallback)");
        }
    }

    println!("\n-- L1/L2 (XLA path) --");
    match XlaRuntime::load(&XlaRuntime::default_dir()) {
        Err(e) => println!("artifacts unavailable ({e}); run `make artifacts`"),
        Ok(rt) => {
            // Anchor rows are clamped → excluded from the degree fit
            // (§Perf opt 1; without this every mesh band misses the
            // buckets and falls back to CPU).
            let anchors = [band.anchor0, band.anchor1];
            let d_real = (0..band.graph.n())
                .filter(|v| !anchors.contains(v))
                .map(|v| band.graph.degree(v))
                .max()
                .unwrap_or(0);
            let bucket = rt.fit_diffusion(band.graph.n(), d_real);
            let fit = bucket
                .and_then(|b| pack_ell_clamped(&band.graph, b.n, b.d, &anchors).map(|e| (b, e)));
            match fit {
                None => println!("band does not fit a bucket (n={})", band.graph.n()),
                Some((bucket, ell)) => {
                    println!(
                        "bucket n={} d={} ({} diffusion steps/call)",
                        bucket.n, bucket.d, rt.steps_per_call
                    );
                    let x = vec![0.1f32; bucket.n];
                    let mask = vec![0f32; bucket.n];
                    let vals = vec![0f32; bucket.n];
                    time("xla diffusion_step (8 iters)", 10, || {
                        rt.diffusion_step(bucket, &x, &mask, &vals, &ell).unwrap()
                    });
                    // CPU reference for the same work (8 iterations).
                    time("cpu diffusion (8 iters, ref)", 10, || {
                        let mut xc = x.clone();
                        for _ in 0..8 {
                            xc = ptscotch::runtime::ell::ell_weighted_average(&ell, &xc, 0.95);
                        }
                        xc
                    });
                    // VMEM footprint estimate per grid step (DESIGN.md §5).
                    let tile = ptscotch::runtime::EllPacked::tile_bytes(256, bucket.d);
                    let field = bucket.n * 4;
                    println!(
                        "VMEM estimate: tile {} KiB + resident field {} KiB (budget ~16 MiB)",
                        tile / 1024,
                        field / 1024
                    );
                }
            }
        }
    }

    quality_profile(smoke, scale);
    refiner_profile(smoke, scale);
    executor_profile(smoke, scale);
    phases_profile(smoke, scale);
    service_profile(smoke, scale);

    if json_mode() {
        write_json(smoke, scale);
    }
}
