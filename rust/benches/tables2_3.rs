//! Tables 2–3 reproduction: `O_PTS`, `O_PM`, `t_PTS`, `t_PM` for every
//! suite graph across process counts.
//!
//! Paper semantics preserved:
//! * daggers (†) mark configurations the comparator cannot run — in the
//!   paper those were ParMETIS MPI aborts; here they are the baseline's
//!   structural power-of-two restriction (§3.2), surfaced on the
//!   non-pow2 rows that PT-Scotch handles fine;
//! * quality (`O_PTS`) should stay flat (or improve) with P while `O_PM`
//!   degrades;
//! * absolute times are single-core wallclock (DESIGN.md §3) — the
//!   *ratio* t_PTS/t_PM ≈ 2–4× matches the paper's "about four times
//!   slower on average".

#[path = "common.rs"]
mod common;

use ptscotch::coordinator::{Engine, OrderingRequest, OrderingService};
use ptscotch::graph::generators;
use ptscotch::strategy::Strategy;

/// Run one request through the builder API.
fn order(
    svc: &OrderingService,
    g: &ptscotch::graph::Graph,
    engine: Engine,
    strat: &Strategy,
) -> ptscotch::Result<ptscotch::coordinator::OrderingResult> {
    svc.run(&OrderingRequest::new(g).strategy(strat.clone()).engine(engine))
}

fn main() {
    let scale = common::bench_scale();
    let svc = OrderingService::new_cpu_only();
    let strat = Strategy::default();
    let mut ps = common::proc_counts();
    // Non-pow2 rows demonstrating the any-P property (paper §3.2).
    ps.extend([3usize, 6]);
    ps.sort_unstable();
    println!("== Tables 2–3 (analog suite, scale {scale}) ==");
    for (name, g) in generators::table1_suite(scale) {
        println!("\n--- {name} (|V|={}, |E|={}) ---", g.n(), g.m());
        println!(
            "{:<8} {:>12} {:>12} {:>9} {:>9}",
            "p", "O_PTS", "O_PM", "t_PTS", "t_PM"
        );
        for &p in &ps {
            let pts = order(&svc, &g, Engine::PtScotch { p }, &strat).expect("pt-scotch");
            let (opm, tpm) = match order(&svc, &g, Engine::ParMetisLike { p }, &strat) {
                Ok(r) => (common::sci(r.stats.opc), format!("{:.2}", r.wall_seconds)),
                Err(_) => ("†".to_string(), "†".to_string()),
            };
            println!(
                "{:<8} {:>12} {:>12} {:>9.2} {:>9}",
                p,
                common::sci(pts.stats.opc),
                opm,
                pts.wall_seconds,
                tpm
            );
            common::csv_row(
                "tables2_3.csv",
                "graph,p,o_pts,t_pts,o_pm,t_pm",
                &format!(
                    "{name},{p},{:.6e},{:.3},{},{}",
                    pts.stats.opc,
                    pts.wall_seconds,
                    opm.replace('†', "NA"),
                    tpm.replace('†', "NA")
                ),
            );
        }
    }
    println!("\n(† = baseline cannot run: non-power-of-two process count.)");
}
