//! §4 seed-variance claim: "on 64 processors … the maximum variation of
//! ordering quality, in term of OPC, between 10 runs performed with
//! varying random seed, was less than 2.2 percent", which justifies
//! fixing the seed and not averaging.
//!
//! We sweep 10 seeds at p = 8 over two graph families and report
//! `(max − min) / min`.

#[path = "common.rs"]
mod common;

use ptscotch::coordinator::{Engine, OrderingRequest, OrderingService};
use ptscotch::graph::generators;
use ptscotch::strategy::Strategy;

/// Run one request through the builder API.
fn order(
    svc: &OrderingService,
    g: &ptscotch::graph::Graph,
    engine: Engine,
    strat: &Strategy,
) -> ptscotch::Result<ptscotch::coordinator::OrderingResult> {
    svc.run(&OrderingRequest::new(g).strategy(strat.clone()).engine(engine))
}

fn main() {
    let scale = common::bench_scale();
    let svc = OrderingService::new_cpu_only();
    let graphs = [
        ("grid3d", generators::grid3d(10 * scale, 10 * scale, 10 * scale)),
        ("audikw-like", generators::audikw_like(8 * scale, 8 * scale, 8 * scale, 0.02, 30, 1)),
    ];
    println!("== Seed variance at p = 8 (10 seeds) ==");
    for (name, g) in graphs {
        let mut opcs = Vec::new();
        for seed in 1..=10u64 {
            let strat = Strategy::parse(&format!("seed={seed}")).unwrap();
            let rep = order(&svc, &g, Engine::PtScotch { p: 8 }, &strat).expect("pts");
            opcs.push(rep.stats.opc);
        }
        let min = opcs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = opcs.iter().cloned().fold(0.0f64, f64::max);
        let var = (max - min) / min * 100.0;
        println!(
            "{name}: OPC ∈ [{}, {}]  max variation {var:.2}%  (paper: < 2.2% on larger graphs)",
            common::sci(min),
            common::sci(max)
        );
        common::csv_row(
            "seed_variance.csv",
            "graph,opc_min,opc_max,variation_pct",
            &format!("{name},{min:.6e},{max:.6e},{var:.3}"),
        );
    }
}
