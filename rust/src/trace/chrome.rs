//! Chrome trace-event JSON export of the per-rank traces — one pid per
//! rank, loadable in Perfetto (`ui.perfetto.dev`) or `chrome://tracing`
//! (DESIGN.md §7).
//!
//! The output is the JSON-object flavor of the trace-event format:
//! `{"traceEvents": [...]}` with complete ("X") events for spans
//! (microsecond timestamps relative to the fleet-shared epoch, counter
//! deltas in `args`), instant ("i") events for per-ND-node quality
//! observations, and one `process_name` metadata ("M") event per rank.

use super::profile::replay;
use super::{RankTrace, CTR_BLOCKED, CTR_BYTES, CTR_MSGS, CTR_OPS};
use crate::error::{Error, Result};
use std::path::Path;

fn us(t_ns: u64) -> String {
    format!("{:.3}", t_ns as f64 / 1e3)
}

/// Render the traces as a Chrome trace-event JSON string.
pub fn render(traces: &[RankTrace]) -> Result<String> {
    let mut out = String::from("{\"traceEvents\":[");
    let mut first = true;
    let mut push = |out: &mut String, ev: String| {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&ev);
    };
    for t in traces {
        push(
            &mut out,
            format!(
                "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{},\"args\":{{\"name\":\"rank {}\"}}}}",
                t.rank, t.rank
            ),
        );
        for s in replay(&t.events)? {
            push(
                &mut out,
                format!(
                    "{{\"name\":\"{}\",\"cat\":\"phase\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
                     \"pid\":{},\"tid\":0,\"args\":{{\"depth\":{},\"bytes\":{},\"msgs\":{},\
                     \"ops\":{},\"blocked_ns\":{}}}}}",
                    s.phase,
                    us(s.t_open_ns),
                    us(s.t_close_ns - s.t_open_ns),
                    t.rank,
                    s.depth,
                    s.incl[CTR_BYTES],
                    s.incl[CTR_MSGS],
                    s.incl[CTR_OPS],
                    s.incl[CTR_BLOCKED],
                ),
            );
        }
        for q in &t.quality {
            push(
                &mut out,
                format!(
                    "{{\"name\":\"separator\",\"cat\":\"quality\",\"ph\":\"i\",\"s\":\"t\",\
                     \"ts\":{},\"pid\":{},\"tid\":0,\"args\":{{\"depth\":{},\"sep_weight\":{},\
                     \"imbalance\":{},\"band_width\":{},\"refiner\":\"{}\",\"levels\":{}}}}}",
                    us(q.t_ns),
                    t.rank,
                    q.depth,
                    q.sep_weight,
                    q.imbalance,
                    q.band_width,
                    q.refiner,
                    q.levels,
                ),
            );
        }
    }
    out.push_str("]}");
    Ok(out)
}

/// Number of JSON events [`render`] emits for these traces: one span
/// plus one quality event each, plus one metadata event per rank.
/// Used by the round-trip tests to pin the export against the trace.
pub fn event_count(traces: &[RankTrace]) -> usize {
    traces
        .iter()
        .map(|t| 1 + t.events.len() / 2 + t.quality.len())
        .sum()
}

/// Write [`render`]'s output to `path`.
pub fn write(path: &Path, traces: &[RankTrace]) -> Result<()> {
    let s = render(traces)?;
    std::fs::write(path, s).map_err(|e| Error::Io(format!("{}: {e}", path.display())))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{install, quality, scope_at, take, Phase, TraceLevel};
    use std::time::Instant;

    #[test]
    fn render_emits_one_event_per_span_quality_and_rank() {
        install(1, TraceLevel::Phases, Instant::now(), None);
        {
            let _r = scope_at(Phase::Run, 0);
            let _l = scope_at(Phase::LeafOrder, 3);
            quality(5, 1, 2, "fm", 3);
        }
        let t = take().unwrap();
        let traces = vec![t];
        let s = render(&traces).unwrap();
        assert!(s.starts_with("{\"traceEvents\":["));
        assert!(s.ends_with("]}"));
        assert_eq!(s.matches("\"ph\":\"X\"").count(), 2);
        assert_eq!(s.matches("\"ph\":\"i\"").count(), 1);
        assert_eq!(s.matches("\"ph\":\"M\"").count(), 1);
        assert_eq!(event_count(&traces), 4);
        assert!(s.contains("\"name\":\"leaf-order\""));
        assert!(s.contains("\"pid\":1"));
        assert!(s.contains("\"refiner\":\"fm\""));
    }
}
