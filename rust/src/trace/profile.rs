//! Merging per-rank traces into a hierarchical [`PhaseProfile`] — the
//! critical-path view of a run (DESIGN.md §7).
//!
//! Each rank's open/close event stream is replayed with a stack
//! ([`replay`]) into closed [`Span`]s carrying inclusive and exclusive
//! wall time and counter deltas, then folded into one tree keyed by
//! the *phase path* (the nesting chain of phases): all spans with the
//! same path, across all ranks and ND depths, aggregate into one
//! [`PhaseNode`] with per-rank totals. Exclusive columns tile: summing
//! the exclusive column over every node of the tree reproduces the
//! root's inclusive total exactly, which for a run wrapped in a
//! [`Phase::Run`] root span equals the rank's run-total counters.

use super::{EventKind, Phase, QualityEvent, RankTrace, SpanEvent, CTRS};
use crate::error::{Error, Result};

/// Number of aggregated columns per rank in a [`PhaseNode`]:
/// `[wall_ns, bytes, msgs, ops, blocked_ns]`.
pub const COLS: usize = 5;
/// Column index of wall nanoseconds.
pub const COL_WALL: usize = 0;
/// Column index of sent bytes.
pub const COL_BYTES: usize = 1;
/// Column index of sent messages.
pub const COL_MSGS: usize = 2;
/// Column index of transport ops.
pub const COL_OPS: usize = 3;
/// Column index of blocked nanoseconds.
pub const COL_BLOCKED: usize = 4;

/// One closed span reconstructed from a rank's event stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// Phase tag.
    pub phase: Phase,
    /// ND depth tag.
    pub depth: u32,
    /// Open timestamp (ns since the trace epoch).
    pub t_open_ns: u64,
    /// Close timestamp (ns since the trace epoch).
    pub t_close_ns: u64,
    /// Inclusive counter deltas (`[bytes, msgs, ops, blocked_ns]`).
    pub incl: [u64; CTRS],
    /// Exclusive counter deltas: inclusive minus direct children.
    pub excl: [u64; CTRS],
    /// Exclusive wall ns: inclusive minus direct children.
    pub excl_wall_ns: u64,
    /// Index into the replay output of the parent span (`usize::MAX`
    /// for a top-level span).
    pub parent: usize,
}

impl Span {
    /// Inclusive wall nanoseconds.
    pub fn wall_ns(&self) -> u64 {
        self.t_close_ns - self.t_open_ns
    }
}

/// Replay a rank's open/close event stream into closed [`Span`]s
/// (in close order), validating nesting discipline as it goes: every
/// close must match the innermost open span's phase and depth,
/// timestamps and counter snapshots must be monotone, and the stack
/// must be empty at the end.
pub fn replay(events: &[SpanEvent]) -> Result<Vec<Span>> {
    let mut spans: Vec<Span> = Vec::with_capacity(events.len() / 2);
    struct Open {
        phase: Phase,
        depth: u32,
        t_open: u64,
        ctrs: [u64; CTRS],
        child_wall: u64,
        child_ctrs: [u64; CTRS],
    }
    let mut stack: Vec<Open> = Vec::new();
    let mut last_t = 0u64;
    let mut last_ctrs = [0u64; CTRS];
    let bad = |m: String| Error::Runtime(format!("malformed trace: {m}"));
    for (i, e) in events.iter().enumerate() {
        if e.t_ns < last_t {
            return Err(bad(format!("timestamp regression at event {i}")));
        }
        last_t = e.t_ns;
        for c in 0..CTRS {
            if e.ctrs[c] < last_ctrs[c] {
                return Err(bad(format!("counter {c} regression at event {i}")));
            }
        }
        last_ctrs = e.ctrs;
        match e.kind {
            EventKind::Open => {
                stack.push(Open {
                    phase: e.phase,
                    depth: e.depth,
                    t_open: e.t_ns,
                    ctrs: e.ctrs,
                    child_wall: 0,
                    child_ctrs: [0; CTRS],
                });
            }
            EventKind::Close => {
                let Some(o) = stack.pop() else {
                    return Err(bad(format!("close with empty stack at event {i}")));
                };
                if o.phase != e.phase || o.depth != e.depth {
                    return Err(bad(format!(
                        "close {}@{} does not match open {}@{} at event {i}",
                        e.phase, e.depth, o.phase, o.depth
                    )));
                }
                let wall = e.t_ns - o.t_open;
                let mut incl = [0u64; CTRS];
                let mut excl = [0u64; CTRS];
                for c in 0..CTRS {
                    incl[c] = e.ctrs[c] - o.ctrs[c];
                    excl[c] = incl[c].saturating_sub(o.child_ctrs[c]);
                }
                if let Some(p) = stack.last_mut() {
                    p.child_wall += wall;
                    for c in 0..CTRS {
                        p.child_ctrs[c] += incl[c];
                    }
                }
                spans.push(Span {
                    phase: o.phase,
                    depth: o.depth,
                    t_open_ns: o.t_open,
                    t_close_ns: e.t_ns,
                    incl,
                    excl,
                    excl_wall_ns: wall.saturating_sub(o.child_wall),
                    parent: usize::MAX, // resolved below
                });
            }
        }
    }
    if !stack.is_empty() {
        return Err(bad(format!("{} spans left open at end of trace", stack.len())));
    }
    // `parent` currently holds the parent's *stack* position at open
    // time, which is not a stable index into `spans` (close order).
    // Recompute it with a second stack replay over the same events.
    let mut idx_stack: Vec<usize> = Vec::new();
    let mut order: Vec<usize> = Vec::new(); // close-order index per open
    let mut closed = 0usize;
    for e in events {
        match e.kind {
            EventKind::Open => {
                order.push(usize::MAX);
                idx_stack.push(order.len() - 1);
            }
            EventKind::Close => {
                let me = idx_stack.pop().expect("validated above");
                order[me] = closed;
                closed += 1;
            }
        }
    }
    // Walk opens again, assigning each closed span its parent's
    // close-order index.
    let mut open_pos: Vec<usize> = Vec::new();
    let mut open_seen = 0usize;
    for e in events {
        match e.kind {
            EventKind::Open => {
                let parent = open_pos.last().map_or(usize::MAX, |&p| order[p]);
                spans[order[open_seen]].parent = parent;
                open_pos.push(open_seen);
                open_seen += 1;
            }
            EventKind::Close => {
                open_pos.pop();
            }
        }
    }
    Ok(spans)
}

/// One node of the merged phase tree: all spans sharing this phase
/// path, aggregated per rank.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PhaseNode {
    /// Phase tag of this tree position.
    pub phase: Phase,
    /// Total number of spans folded into this node, across all ranks.
    pub count: u64,
    /// Per-rank inclusive totals, indexed `[rank][COL_*]`.
    pub incl: Vec<[u64; COLS]>,
    /// Per-rank exclusive totals (inclusive minus direct children).
    pub excl: Vec<[u64; COLS]>,
    /// Child phases in first-seen order.
    pub children: Vec<PhaseNode>,
}

impl PhaseNode {
    fn new(phase: Phase, p: usize) -> Self {
        PhaseNode {
            phase,
            count: 0,
            incl: vec![[0; COLS]; p],
            excl: vec![[0; COLS]; p],
            children: Vec::new(),
        }
    }

    /// Max of an exclusive column over ranks.
    pub fn excl_max(&self, col: usize) -> u64 {
        self.excl.iter().map(|r| r[col]).max().unwrap_or(0)
    }

    /// Mean of an exclusive column over ranks.
    pub fn excl_mean(&self, col: usize) -> f64 {
        if self.excl.is_empty() {
            return 0.0;
        }
        self.excl.iter().map(|r| r[col]).sum::<u64>() as f64 / self.excl.len() as f64
    }

    /// Sum of an exclusive column over ranks.
    pub fn excl_sum(&self, col: usize) -> u64 {
        self.excl.iter().map(|r| r[col]).sum()
    }

    /// Max of an inclusive column over ranks.
    pub fn incl_max(&self, col: usize) -> u64 {
        self.incl.iter().map(|r| r[col]).max().unwrap_or(0)
    }
}

/// The merged, hierarchical phase profile of one run — per-phase
/// inclusive/exclusive wall, traffic and blocked time with per-rank
/// max vs mean, plus the run's quality events. Built from the ranks'
/// [`RankTrace`]s after the fleet joins; rendered by `Display` as the
/// per-phase table the CLI prints.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PhaseProfile {
    /// Number of ranks merged (columns of the per-rank vectors).
    pub p: usize,
    /// Top-level phase nodes (a single `run` root in practice).
    pub roots: Vec<PhaseNode>,
    /// Quality events from all ranks as `(rank, event)`.
    pub quality: Vec<(usize, QualityEvent)>,
    /// Total spans merged across all ranks.
    pub spans: u64,
}

impl PhaseProfile {
    /// Merge per-rank traces into one profile. Ranks may record
    /// different span sets (different dissection branches); a rank
    /// simply contributes zero to nodes it never entered. Fails on a
    /// malformed event stream (unbalanced or mismatched nesting).
    pub fn build(traces: &[RankTrace]) -> Result<PhaseProfile> {
        let p = traces.iter().map(|t| t.rank + 1).max().unwrap_or(0);
        let mut prof = PhaseProfile {
            p,
            roots: Vec::new(),
            quality: Vec::new(),
            spans: 0,
        };
        fn descend<'a>(
            nodes: &'a mut Vec<PhaseNode>,
            phases: &[Phase],
            p: usize,
        ) -> &'a mut PhaseNode {
            let ph = phases[0];
            let pos = match nodes.iter().position(|n| n.phase == ph) {
                Some(pos) => pos,
                None => {
                    nodes.push(PhaseNode::new(ph, p));
                    nodes.len() - 1
                }
            };
            if phases.len() == 1 {
                &mut nodes[pos]
            } else {
                descend(&mut nodes[pos].children, &phases[1..], p)
            }
        }
        for t in traces {
            let spans = replay(&t.events)?;
            // Resolve each span's phase path root-first, then walk the
            // tree creating nodes as needed.
            for (i, s) in spans.iter().enumerate() {
                let mut path = vec![spans[i].phase];
                let mut cur = *s;
                while cur.parent != usize::MAX {
                    path.push(spans[cur.parent].phase);
                    cur = spans[cur.parent];
                }
                path.reverse();
                let n = descend(&mut prof.roots, &path, p);
                n.count += 1;
                let r = t.rank;
                n.incl[r][COL_WALL] += s.wall_ns();
                n.excl[r][COL_WALL] += s.excl_wall_ns;
                for c in 0..CTRS {
                    n.incl[r][1 + c] += s.incl[c];
                    n.excl[r][1 + c] += s.excl[c];
                }
                prof.spans += 1;
            }
            for q in &t.quality {
                prof.quality.push((t.rank, *q));
            }
        }
        Ok(prof)
    }

    /// Depth-first flattening of the tree as `(node, depth)` pairs.
    pub fn flatten(&self) -> Vec<(&PhaseNode, usize)> {
        fn walk<'a>(n: &'a PhaseNode, d: usize, out: &mut Vec<(&'a PhaseNode, usize)>) {
            out.push((n, d));
            for c in &n.children {
                walk(c, d + 1, out);
            }
        }
        let mut out = Vec::new();
        for r in &self.roots {
            walk(r, 0, &mut out);
        }
        out
    }

    /// Per-phase totals aggregated across the whole tree (exclusive
    /// columns summed over every node with that phase tag and every
    /// rank), as `(phase, count, [COLS] totals)` in [`Phase::ALL`]
    /// order, omitting phases that never appear.
    pub fn phase_totals(&self) -> Vec<(Phase, u64, [u64; COLS])> {
        let mut acc: Vec<(u64, [u64; COLS])> = vec![(0, [0; COLS]); Phase::ALL.len()];
        for (n, _) in self.flatten() {
            let i = Phase::ALL.iter().position(|&p| p == n.phase).expect("fixed enum");
            acc[i].0 += n.count;
            for c in 0..COLS {
                acc[i].1[c] += n.excl_sum(c);
            }
        }
        Phase::ALL
            .iter()
            .zip(acc)
            .filter(|(_, (count, _))| *count > 0)
            .map(|(&ph, (count, cols))| (ph, count, cols))
            .collect()
    }

    /// Sum of one exclusive column over the entire tree and all ranks.
    /// For a run wrapped in a `run` root span this reproduces the
    /// run-total counter exactly (the exclusive columns tile).
    pub fn total(&self, col: usize) -> u64 {
        self.flatten().iter().map(|(n, _)| n.excl_sum(col)).sum()
    }

    /// The sequential-tail fraction: the slowest rank's total
    /// leaf-order exclusive wall time divided by the slowest rank's
    /// root inclusive wall time — the Amdahl share of the sequential
    /// leaf orderings on the critical path. 0 when nothing was traced.
    pub fn sequential_tail_fraction(&self) -> f64 {
        let root_max: u64 = self.roots.iter().map(|r| r.incl_max(COL_WALL)).max().unwrap_or(0);
        if root_max == 0 {
            return 0.0;
        }
        let mut leaf = vec![0u64; self.p];
        for (n, _) in self.flatten() {
            if n.phase == Phase::LeafOrder {
                for (r, row) in n.excl.iter().enumerate() {
                    leaf[r] += row[COL_WALL];
                }
            }
        }
        leaf.into_iter().max().unwrap_or(0) as f64 / root_max as f64
    }

    /// One-line summary for the batch CLI's `--profile` row: the top
    /// three phases by exclusive wall (per-rank max) plus the
    /// sequential-tail fraction.
    pub fn summary_row(&self) -> String {
        let mut totals = self.phase_totals();
        totals.sort_by(|a, b| b.2[COL_WALL].cmp(&a.2[COL_WALL]).then(a.0.name().cmp(b.0.name())));
        let parts: Vec<String> = totals
            .iter()
            .take(3)
            .map(|(ph, _, cols)| format!("{ph} {:.1}ms", cols[COL_WALL] as f64 / 1e6))
            .collect();
        format!(
            "{} seq_tail={:.3}",
            parts.join(" | "),
            self.sequential_tail_fraction()
        )
    }
}

fn fmt_ms(ns: u64) -> String {
    format!("{:.2}", ns as f64 / 1e6)
}

impl std::fmt::Display for PhaseProfile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "phase profile (p = {}, {} spans; wall in ms, exclusive unless noted)",
            self.p, self.spans
        )?;
        writeln!(
            f,
            "{:<34} {:>7} {:>10} {:>10} {:>10} {:>12} {:>8} {:>10}",
            "phase", "count", "incl(max)", "excl(max)", "excl(mean)", "bytes", "msgs", "blocked"
        )?;
        for (n, d) in self.flatten() {
            let name = format!("{}{}", "  ".repeat(d), n.phase);
            writeln!(
                f,
                "{:<34} {:>7} {:>10} {:>10} {:>10} {:>12} {:>8} {:>10}",
                name,
                n.count,
                fmt_ms(n.incl_max(COL_WALL)),
                fmt_ms(n.excl_max(COL_WALL)),
                format!("{:.2}", n.excl_mean(COL_WALL) / 1e6),
                n.excl_sum(COL_BYTES),
                n.excl_sum(COL_MSGS),
                fmt_ms(n.excl_max(COL_BLOCKED)),
            )?;
        }
        write!(
            f,
            "quality events: {}; sequential tail fraction: {:.3}",
            self.quality.len(),
            self.sequential_tail_fraction()
        )?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{install, scope, scope_at, take, TraceLevel};
    use std::time::Instant;

    fn trace_of(f: impl FnOnce()) -> RankTrace {
        install(0, TraceLevel::Full, Instant::now(), None);
        f();
        take().unwrap()
    }

    #[test]
    fn replay_reconstructs_nesting_and_parents() {
        let t = trace_of(|| {
            let _r = scope_at(Phase::Run, 0);
            {
                let _a = scope_at(Phase::Induce, 1);
                let _b = scope(Phase::Coarsen);
            }
            let _c = scope_at(Phase::LeafOrder, 2);
        });
        let spans = replay(&t.events).unwrap();
        assert_eq!(spans.len(), 4);
        // Close order: coarsen, induce, leaf-order, run.
        assert_eq!(spans[0].phase, Phase::Coarsen);
        assert_eq!(spans[1].phase, Phase::Induce);
        assert_eq!(spans[2].phase, Phase::LeafOrder);
        assert_eq!(spans[3].phase, Phase::Run);
        assert_eq!(spans[0].parent, 1);
        assert_eq!(spans[1].parent, 3);
        assert_eq!(spans[2].parent, 3);
        assert_eq!(spans[3].parent, usize::MAX);
        // Exclusive wall tiles to the root's inclusive wall.
        let excl_sum: u64 = spans.iter().map(|s| s.excl_wall_ns).sum();
        assert_eq!(excl_sum, spans[3].wall_ns());
    }

    #[test]
    fn replay_rejects_unbalanced_streams() {
        let mut t = trace_of(|| {
            let _r = scope(Phase::Run);
        });
        t.events.pop();
        let err = replay(&t.events).unwrap_err().to_string();
        assert!(err.contains("left open"), "{err}");
    }

    #[test]
    fn profile_merges_ranks_and_tiles_exclusive_columns() {
        let mk = |rank: usize| {
            install(rank, TraceLevel::Phases, Instant::now(), None);
            {
                let _r = scope_at(Phase::Run, 0);
                let _l = scope_at(Phase::LeafOrder, 1);
            }
            take().unwrap()
        };
        let traces = vec![mk(0), mk(1)];
        let prof = PhaseProfile::build(&traces).unwrap();
        assert_eq!(prof.p, 2);
        assert_eq!(prof.roots.len(), 1);
        assert_eq!(prof.roots[0].phase, Phase::Run);
        assert_eq!(prof.roots[0].count, 2);
        assert_eq!(prof.roots[0].children.len(), 1);
        assert_eq!(prof.roots[0].children[0].phase, Phase::LeafOrder);
        assert_eq!(prof.spans, 4);
        // Exclusive wall over the whole tree equals root inclusive sum.
        let root_incl: u64 = prof.roots[0].incl.iter().map(|r| r[COL_WALL]).sum();
        assert_eq!(prof.total(COL_WALL), root_incl);
        // The fraction is a share of the root's wall time, so it can
        // never exceed 1 (and is 0 only on a zero-resolution clock).
        assert!(prof.sequential_tail_fraction() <= 1.0);
        let table = prof.to_string();
        assert!(table.contains("run"), "{table}");
        assert!(table.contains("leaf-order"), "{table}");
        assert!(!prof.summary_row().is_empty());
    }
}
