//! Phase-attributed tracing: a deterministic, always-compiled-in,
//! near-zero-cost-when-off span recorder threaded through the whole
//! ordering stack (DESIGN.md §7).
//!
//! Every emulated rank (and the sequential engine's driver thread) can
//! carry a thread-local [`TraceSink`]: a plain `Vec` of open/close
//! [`SpanEvent`]s — no locks, no allocation beyond the `Vec` growth, no
//! shared state on the hot path. Each event snapshots the rank's
//! existing atomic traffic counters (sent bytes / sent msgs / transport
//! ops / blocked ns) through a [`CounterProbe`], so every span carries
//! its own traffic and blocked-time attribution as a *delta* between
//! its open and close snapshots. Spans observe the counters with
//! relaxed loads and never write them, which is what keeps the
//! executor-differential counter pins and the sim ≡ threads
//! bit-identity contract intact under tracing.
//!
//! The recorder is controlled by the `trace=off|phases|full` strategy
//! knob ([`TraceLevel`]): `off` leaves only one thread-local check per
//! instrumentation point, `phases` records the algorithmic phases of
//! the pipeline ([`Phase`]), and `full` additionally records every
//! collective and halo-exchange entry. After the fleet joins, the
//! per-rank [`RankTrace`]s merge into a [`PhaseProfile`] tree on
//! `OrderingReport` and can be exported as Chrome trace-event JSON
//! ([`chrome::write`]) for Perfetto.

pub mod chrome;
pub mod profile;

pub use profile::{PhaseProfile, Span};

use std::cell::RefCell;
use std::time::Instant;

/// How much the span recorder records; the `trace=` strategy knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum TraceLevel {
    /// No recording: every instrumentation point costs one
    /// thread-local check and nothing is kept. The default.
    #[default]
    Off,
    /// Record the algorithmic phases ([`Phase`]) plus quality events.
    Phases,
    /// `Phases` plus every collective and halo-exchange entry point.
    Full,
}

impl TraceLevel {
    /// Canonical lowercase name (`off`/`phases`/`full`), the spelling
    /// `Strategy`'s `Display` emits and `parse` accepts.
    pub fn name(&self) -> &'static str {
        match self {
            TraceLevel::Off => "off",
            TraceLevel::Phases => "phases",
            TraceLevel::Full => "full",
        }
    }
}

impl std::fmt::Display for TraceLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for TraceLevel {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "off" => Ok(TraceLevel::Off),
            "phases" => Ok(TraceLevel::Phases),
            "full" => Ok(TraceLevel::Full),
            other => Err(format!("unknown trace level {other:?} (off|phases|full)")),
        }
    }
}

/// The fixed phase vocabulary of the ordering pipeline. Spans are
/// tagged with one of these plus the ND recursion depth they run at.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Phase {
    /// Root span covering one whole ordering run on a rank; every
    /// other span nests inside it, so per-phase exclusive counter
    /// deltas tile exactly to the rank's run totals.
    Run,
    /// Parallel probabilistic matching rounds (`dist::matching`).
    Match,
    /// Graph coarsening — distributed (`coarsen_dist`) or sequential
    /// heavy-edge matching levels inside the multilevel driver.
    Coarsen,
    /// Fold-with-duplication of the half fleets (`dist::fold`).
    Fold,
    /// Coarsest-graph initial separator: centralization, the
    /// multi-sequential `multilevel_separator` runs and best-pick.
    InitialSep,
    /// Band extraction around the projected separator (sequential
    /// `extract_band` or the distributed band BFS).
    BandExtract,
    /// Umbrella for one distributed band-refinement pass
    /// (`band_refine_dist`): covers the centralize/scatter traffic
    /// around the per-mode refiner spans nested inside it.
    BandRefine,
    /// Vertex Fiduccia–Mattheyses band refinement.
    RefineFm,
    /// Diffusion (damped-Jacobi) band refinement, CPU or XLA.
    RefineDiffusion,
    /// Flow-based (push-relabel min vertex cut) band refinement.
    RefineFlow,
    /// Separator projection back to the finer graph (`project_state`,
    /// distributed `fetch_at` projection).
    ProjectSep,
    /// Induction of the two part subgraphs (`induce_both`, including
    /// the §3.1 overlapped variant — overlap-thread traffic lands in
    /// this span's delta because the threads join before it closes).
    Induce,
    /// Leaf ordering (halo-AMD or MMD) of an ND leaf.
    LeafOrder,
    /// One halo exchange (`DGraph::halo_exchange`/`halo_frontier`);
    /// recorded only at [`TraceLevel::Full`].
    Halo,
    /// One `comm` collective entry point (barrier, allgatherv,
    /// alltoallv, bcast, split); recorded only at [`TraceLevel::Full`].
    Collective,
}

impl Phase {
    /// Every phase, in canonical display order.
    pub const ALL: [Phase; 15] = [
        Phase::Run,
        Phase::Match,
        Phase::Coarsen,
        Phase::Fold,
        Phase::InitialSep,
        Phase::BandExtract,
        Phase::BandRefine,
        Phase::RefineFm,
        Phase::RefineDiffusion,
        Phase::RefineFlow,
        Phase::ProjectSep,
        Phase::Induce,
        Phase::LeafOrder,
        Phase::Halo,
        Phase::Collective,
    ];

    /// Canonical lowercase name used in tables and Chrome traces.
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Run => "run",
            Phase::Match => "match",
            Phase::Coarsen => "coarsen",
            Phase::Fold => "fold",
            Phase::InitialSep => "initial-sep",
            Phase::BandExtract => "band-extract",
            Phase::BandRefine => "band-refine",
            Phase::RefineFm => "refine-fm",
            Phase::RefineDiffusion => "refine-diffusion",
            Phase::RefineFlow => "refine-flow",
            Phase::ProjectSep => "project-sep",
            Phase::Induce => "induce",
            Phase::LeafOrder => "leaf-order",
            Phase::Halo => "halo",
            Phase::Collective => "collective",
        }
    }

    /// The minimum [`TraceLevel`] at which this phase is recorded:
    /// per-call transport phases (`Halo`, `Collective`) only at
    /// `full`, everything else at `phases`.
    pub fn min_level(&self) -> TraceLevel {
        match self {
            Phase::Halo | Phase::Collective => TraceLevel::Full,
            _ => TraceLevel::Phases,
        }
    }
}

impl std::fmt::Display for Phase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Open or close marker of a [`SpanEvent`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A span opened at this event's timestamp.
    Open,
    /// The innermost open span closed at this event's timestamp.
    Close,
}

/// Number of counter columns snapshotted per event; see [`SpanEvent::ctrs`].
pub const CTRS: usize = 4;
/// Index of the sent-bytes column in a counter snapshot.
pub const CTR_BYTES: usize = 0;
/// Index of the sent-messages column in a counter snapshot.
pub const CTR_MSGS: usize = 1;
/// Index of the transport-ops column in a counter snapshot.
pub const CTR_OPS: usize = 2;
/// Index of the blocked-nanoseconds column in a counter snapshot.
pub const CTR_BLOCKED: usize = 3;

/// One open/close event in a rank's trace. Spans are stored as event
/// pairs (not closed intervals) so nesting discipline is checkable
/// from the recorded data itself and reconstruction is a stack replay
/// ([`profile::replay`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    /// Open or close.
    pub kind: EventKind,
    /// Phase tag of the span this event opens or closes.
    pub phase: Phase,
    /// ND recursion depth tag (0 at the root; children of node `d` are
    /// `2d+1`/`2d+2`, matching the dissection's node numbering).
    pub depth: u32,
    /// Nanoseconds since the fleet-shared trace epoch.
    pub t_ns: u64,
    /// Monotone counter snapshot at this event:
    /// `[sent_bytes, sent_msgs, transport_ops, blocked_ns]`
    /// (see the `CTR_*` index constants). All zeros when the sink has
    /// no probe (the sequential engine).
    pub ctrs: [u64; CTRS],
}

/// A per-ND-node quality observation (separator weight, imbalance,
/// band width, refiner chosen, multilevel level count), attached to
/// the trace as an instant event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QualityEvent {
    /// Nanoseconds since the fleet-shared trace epoch.
    pub t_ns: u64,
    /// ND node tag, inherited from the innermost open span.
    pub depth: u32,
    /// Vertex weight of the separator.
    pub sep_weight: u64,
    /// Absolute part imbalance `|w0 − w1|`.
    pub imbalance: u64,
    /// Band width the refinement ran with.
    pub band_width: u32,
    /// Canonical name of the refiner that produced the separator.
    pub refiner: &'static str,
    /// Number of multilevel coarsening levels used (0 when unknown,
    /// e.g. for the distributed per-node summary).
    pub levels: u32,
}

/// Everything one rank recorded during a run: its span events in
/// emission order plus its quality events.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RankTrace {
    /// The emulated rank that recorded this trace (0 for the
    /// sequential engine).
    pub rank: usize,
    /// The level the sink recorded at.
    pub level: TraceLevel,
    /// Open/close events in emission order.
    pub events: Vec<SpanEvent>,
    /// Quality events in emission order.
    pub quality: Vec<QualityEvent>,
}

/// Reads the rank's monotone traffic counters for event snapshots.
/// Built by `comm` over the rank's `RankStats` atomics (relaxed loads
/// only — the probe never writes), absent for the sequential engine.
pub struct CounterProbe(Box<dyn Fn() -> [u64; CTRS] + Send>);

impl CounterProbe {
    /// Wrap a counter-reading closure.
    pub fn new(f: impl Fn() -> [u64; CTRS] + Send + 'static) -> Self {
        CounterProbe(Box::new(f))
    }

    fn read(&self) -> [u64; CTRS] {
        (self.0)()
    }
}

impl std::fmt::Debug for CounterProbe {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("CounterProbe")
    }
}

struct Active {
    rank: usize,
    level: TraceLevel,
    epoch: Instant,
    probe: Option<CounterProbe>,
    events: Vec<SpanEvent>,
    quality: Vec<QualityEvent>,
    /// `(phase, depth)` of every currently open span, innermost last.
    stack: Vec<(Phase, u32)>,
}

impl Active {
    fn snapshot(&self) -> [u64; CTRS] {
        match &self.probe {
            Some(p) => p.read(),
            None => [0; CTRS],
        }
    }

    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }
}

thread_local! {
    static ACTIVE: RefCell<Option<Active>> = const { RefCell::new(None) };
}

/// Install a sink on the current thread. `comm::try_run_with` calls
/// this inside each spawned rank thread (with a probe over the rank's
/// counters and the fleet-shared epoch); the sequential engine calls
/// it on its driver thread with no probe. Replaces any sink already
/// installed on the thread.
pub fn install(rank: usize, level: TraceLevel, epoch: Instant, probe: Option<CounterProbe>) {
    ACTIVE.with(|a| {
        *a.borrow_mut() = Some(Active {
            rank,
            level,
            epoch,
            probe,
            events: Vec::new(),
            quality: Vec::new(),
            stack: Vec::new(),
        });
    });
}

/// Uninstall the current thread's sink and return what it recorded;
/// `None` when no sink is installed.
pub fn take() -> Option<RankTrace> {
    ACTIVE.with(|a| {
        a.borrow_mut().take().map(|s| RankTrace {
            rank: s.rank,
            level: s.level,
            events: s.events,
            quality: s.quality,
        })
    })
}

/// The level the current thread records at ([`TraceLevel::Off`] when
/// no sink is installed).
pub fn level() -> TraceLevel {
    ACTIVE.with(|a| a.borrow().as_ref().map_or(TraceLevel::Off, |s| s.level))
}

/// RAII guard for one span: records the open event on creation and
/// the close event on drop. Inert (a single thread-local check) when
/// no sink is installed or the phase's [`Phase::min_level`] exceeds
/// the sink's level.
#[must_use = "the span closes when the guard drops"]
pub struct SpanGuard {
    armed: bool,
}

/// Open a span of `phase`, inheriting the ND depth tag of the
/// innermost open span (0 when none is open).
pub fn scope(phase: Phase) -> SpanGuard {
    open_span(phase, None)
}

/// Open a span of `phase` tagged with an explicit ND node `depth`.
pub fn scope_at(phase: Phase, depth: u32) -> SpanGuard {
    open_span(phase, Some(depth))
}

fn open_span(phase: Phase, depth: Option<u32>) -> SpanGuard {
    ACTIVE.with(|a| {
        let mut b = a.borrow_mut();
        let Some(s) = b.as_mut() else {
            return SpanGuard { armed: false };
        };
        if s.level < phase.min_level() {
            return SpanGuard { armed: false };
        }
        let depth = depth.unwrap_or_else(|| s.stack.last().map_or(0, |&(_, d)| d));
        let ctrs = s.snapshot();
        let t_ns = s.now_ns();
        s.stack.push((phase, depth));
        s.events.push(SpanEvent {
            kind: EventKind::Open,
            phase,
            depth,
            t_ns,
            ctrs,
        });
        SpanGuard { armed: true }
    })
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        ACTIVE.with(|a| {
            let mut b = a.borrow_mut();
            let Some(s) = b.as_mut() else { return };
            let Some((phase, depth)) = s.stack.pop() else {
                return;
            };
            let ctrs = s.snapshot();
            let t_ns = s.now_ns();
            s.events.push(SpanEvent {
                kind: EventKind::Close,
                phase,
                depth,
                t_ns,
                ctrs,
            });
        });
    }
}

/// Record a per-ND-node quality event (no-op without a sink). The ND
/// depth tag is inherited from the innermost open span.
pub fn quality(
    sep_weight: u64,
    imbalance: u64,
    band_width: u32,
    refiner: &'static str,
    levels: u32,
) {
    let depth = ACTIVE.with(|a| {
        a.borrow()
            .as_ref()
            .and_then(|s| s.stack.last().map(|&(_, d)| d))
            .unwrap_or(0)
    });
    quality_at(depth, sep_weight, imbalance, band_width, refiner, levels);
}

/// [`quality`] with an explicit ND depth tag, for call sites (like the
/// distributed dissection driver) whose enclosing span sits at a
/// different depth than the ND node being reported.
pub fn quality_at(
    depth: u32,
    sep_weight: u64,
    imbalance: u64,
    band_width: u32,
    refiner: &'static str,
    levels: u32,
) {
    ACTIVE.with(|a| {
        let mut b = a.borrow_mut();
        let Some(s) = b.as_mut() else { return };
        let t_ns = s.now_ns();
        s.quality.push(QualityEvent {
            t_ns,
            depth,
            sep_weight,
            imbalance,
            band_width,
            refiner,
            levels,
        });
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guards_are_inert_without_a_sink() {
        assert_eq!(level(), TraceLevel::Off);
        let g = scope(Phase::Coarsen);
        drop(g);
        assert!(take().is_none());
    }

    #[test]
    fn spans_record_nested_events_with_depth_inheritance() {
        install(3, TraceLevel::Phases, Instant::now(), None);
        {
            let _run = scope_at(Phase::Run, 0);
            {
                let _i = scope_at(Phase::Induce, 5);
                let _c = scope(Phase::Coarsen); // inherits depth 5
            }
            quality(10, 2, 3, "fm", 4);
        }
        let t = take().expect("sink installed");
        assert_eq!(t.rank, 3);
        assert_eq!(t.events.len(), 6);
        let kinds: Vec<_> = t.events.iter().map(|e| (e.kind, e.phase, e.depth)).collect();
        assert_eq!(
            kinds,
            vec![
                (EventKind::Open, Phase::Run, 0),
                (EventKind::Open, Phase::Induce, 5),
                (EventKind::Open, Phase::Coarsen, 5),
                (EventKind::Close, Phase::Coarsen, 5),
                (EventKind::Close, Phase::Induce, 5),
                (EventKind::Close, Phase::Run, 0),
            ]
        );
        assert_eq!(t.quality.len(), 1);
        assert_eq!(t.quality[0].sep_weight, 10);
        assert_eq!(t.quality[0].refiner, "fm");
        // Timestamps are monotone and counters (no probe) stay zero.
        for w in t.events.windows(2) {
            assert!(w[0].t_ns <= w[1].t_ns);
        }
        assert!(t.events.iter().all(|e| e.ctrs == [0; CTRS]));
        assert!(take().is_none());
    }

    #[test]
    fn full_only_phases_are_skipped_at_phases_level() {
        install(0, TraceLevel::Phases, Instant::now(), None);
        {
            let _c = scope(Phase::Collective);
            let _h = scope(Phase::Halo);
        }
        let t = take().unwrap();
        assert!(t.events.is_empty());
        install(0, TraceLevel::Full, Instant::now(), None);
        {
            let _c = scope(Phase::Collective);
        }
        let t = take().unwrap();
        assert_eq!(t.events.len(), 2);
    }

    #[test]
    fn probe_snapshots_land_in_events() {
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::sync::Arc;
        let ctr = Arc::new(AtomicU64::new(7));
        let c2 = ctr.clone();
        let probe = CounterProbe::new(move || [c2.load(Ordering::Relaxed), 0, 0, 0]);
        install(0, TraceLevel::Phases, Instant::now(), Some(probe));
        {
            let _g = scope(Phase::Run);
            ctr.store(19, Ordering::Relaxed);
        }
        let t = take().unwrap();
        assert_eq!(t.events[0].ctrs[CTR_BYTES], 7);
        assert_eq!(t.events[1].ctrs[CTR_BYTES], 19);
    }

    #[test]
    fn trace_level_parse_display_round_trip() {
        for l in [TraceLevel::Off, TraceLevel::Phases, TraceLevel::Full] {
            assert_eq!(l.name().parse::<TraceLevel>().unwrap(), l);
        }
        let err = "loud".parse::<TraceLevel>().unwrap_err();
        assert!(err.contains("off|phases|full"), "{err}");
    }
}
