//! `ptscotch` CLI — the leader entrypoint.
//!
//! ```text
//! ptscotch order  --graph grid2d:64x64      -p 8 --engine pts [--strategy band=3,...]
//! ptscotch order  --graph file:matrix.mtx   --engine seq
//! ptscotch suite  --scale 1 -p 2,4,8        # Table-2/3-style sweep
//! ptscotch info                             # artifact / runtime status
//! ```
//!
//! Graph specs: `grid2d:NxM`, `grid3d:NxMxK`, `grid3d27:NxMxK`,
//! `audikw:NxMxK`, `cage:N`, `qimonda:N`, `thread:N`, `file:PATH`.

use ptscotch::coordinator::{Engine, OrderingService};
use ptscotch::graph::{generators, io, Graph};
use ptscotch::runtime::XlaRuntime;
use ptscotch::strategy::Strategy;
use std::path::Path;
use std::process::ExitCode;

fn parse_graph(spec: &str) -> Result<Graph, String> {
    let (kind, arg) = spec.split_once(':').unwrap_or((spec, ""));
    let dims = |s: &str| -> Result<Vec<usize>, String> {
        s.split('x')
            .map(|t| t.parse::<usize>().map_err(|_| format!("bad dim {t}")))
            .collect()
    };
    match kind {
        "grid2d" => {
            let d = dims(arg)?;
            if d.len() != 2 {
                return Err("grid2d needs NxM".into());
            }
            Ok(generators::grid2d(d[0], d[1]))
        }
        "grid3d" => {
            let d = dims(arg)?;
            if d.len() != 3 {
                return Err("grid3d needs NxMxK".into());
            }
            Ok(generators::grid3d(d[0], d[1], d[2]))
        }
        "grid3d27" => {
            let d = dims(arg)?;
            if d.len() != 3 {
                return Err("grid3d27 needs NxMxK".into());
            }
            Ok(generators::grid3d_27pt(d[0], d[1], d[2]))
        }
        "audikw" => {
            let d = dims(arg)?;
            if d.len() != 3 {
                return Err("audikw needs NxMxK".into());
            }
            Ok(generators::audikw_like(d[0], d[1], d[2], 0.02, 40, 1))
        }
        "cage" => Ok(generators::cage_like(
            arg.parse().map_err(|_| "cage needs N")?,
            8,
            2,
        )),
        "qimonda" => Ok(generators::qimonda_like(
            arg.parse().map_err(|_| "qimonda needs N")?,
            3,
        )),
        "thread" => Ok(generators::thread_like(
            arg.parse().map_err(|_| "thread needs N")?,
            120,
            4,
        )),
        "file" => io::load(Path::new(arg)).map_err(|e| e.to_string()),
        other => Err(format!("unknown graph kind {other}")),
    }
}

fn get_flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn cmd_order(args: &[String]) -> Result<(), String> {
    let spec = get_flag(args, "--graph").ok_or("--graph required")?;
    let g = parse_graph(&spec)?;
    let p: usize = get_flag(args, "-p")
        .map(|s| s.parse().unwrap_or(1))
        .unwrap_or(1);
    let engine = match get_flag(args, "--engine").as_deref().unwrap_or("pts") {
        "seq" => Engine::Sequential,
        "pts" => Engine::PtScotch { p },
        "pm" => Engine::ParMetisLike { p },
        e => return Err(format!("unknown engine {e} (seq|pts|pm)")),
    };
    let strat = Strategy::parse(&get_flag(args, "--strategy").unwrap_or_default())
        .map_err(|e| e.to_string())?;
    let svc = OrderingService::new(&XlaRuntime::default_dir());
    eprintln!(
        "graph {spec}: |V|={} |E|={} avg-deg={:.2}; engine={engine:?} xla={}",
        g.n(),
        g.m(),
        g.avg_degree(),
        svc.has_xla()
    );
    let rep = svc.order(&g, engine, &strat).map_err(|e| e.to_string())?;
    let (mn, avg, mx) = rep.mem_min_avg_max();
    println!(
        "OPC={:.3e} NNZ={} fill={:.2} height={} time={:.2}s mem(min/avg/max)={}/{:.0}/{} B comm={} B",
        rep.stats.opc,
        rep.stats.nnz,
        rep.stats.fill_ratio,
        rep.stats.tree_height,
        rep.wall_seconds,
        mn,
        avg,
        mx,
        rep.total_comm_bytes()
    );
    Ok(())
}

fn cmd_suite(args: &[String]) -> Result<(), String> {
    let scale: usize = get_flag(args, "--scale")
        .map(|s| s.parse().unwrap_or(1))
        .unwrap_or(1);
    let ps: Vec<usize> = get_flag(args, "-p")
        .unwrap_or_else(|| "2,4".to_string())
        .split(',')
        .filter_map(|t| t.parse().ok())
        .collect();
    let svc = OrderingService::new(&XlaRuntime::default_dir());
    let strat = Strategy::parse(&get_flag(args, "--strategy").unwrap_or_default())
        .map_err(|e| e.to_string())?;
    println!(
        "{:<18} {:>8} {:>10} {:>4} {:>12} {:>9}",
        "graph", "|V|", "|E|", "p", "OPC", "t(s)"
    );
    for (name, g) in generators::table1_suite(scale) {
        for &p in &ps {
            let rep = svc
                .order(&g, Engine::PtScotch { p }, &strat)
                .map_err(|e| e.to_string())?;
            println!(
                "{:<18} {:>8} {:>10} {:>4} {:>12.4e} {:>9.2}",
                name,
                g.n(),
                g.m(),
                p,
                rep.stats.opc,
                rep.wall_seconds
            );
        }
    }
    Ok(())
}

fn cmd_info() -> Result<(), String> {
    let dir = XlaRuntime::default_dir();
    println!("artifact dir: {}", dir.display());
    match XlaRuntime::load(&dir) {
        Ok(rt) => {
            println!("runtime: loaded ({} steps/call)", rt.steps_per_call);
            for b in rt.diffusion_buckets() {
                println!("  diffusion bucket n={} d={}", b.n, b.d);
            }
        }
        Err(e) => println!("runtime: unavailable ({e}) — run `make artifacts`"),
    }
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let r = match args.first().map(String::as_str) {
        Some("order") => cmd_order(&args[1..]),
        Some("suite") => cmd_suite(&args[1..]),
        Some("info") => cmd_info(),
        _ => {
            eprintln!(
                "usage: ptscotch <order|suite|info> [--graph SPEC] [-p N] \
                 [--engine seq|pts|pm] [--strategy k=v,...]"
            );
            return ExitCode::from(2);
        }
    };
    match r {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
