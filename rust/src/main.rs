//! `ptscotch` CLI — the leader entrypoint.
//!
//! ```text
//! ptscotch order  --graph grid2d:64x64      -p 8 --engine pts [--strategy band=3,...]
//!                 [--trace-out trace.json]   # with trace=phases|full in the strategy
//! ptscotch order  --graph file:matrix.mtx   --engine seq
//! ptscotch suite  --scale 1 -p 2,4,8        # Table-2/3-style sweep
//! ptscotch batch  --requests reqs.txt [--repeat 2] [--cache 64] [--jobs 4] [--retries 2]
//! ptscotch info                             # artifact / runtime status
//! ```
//!
//! Graph specs: `grid2d:NxM`, `grid3d:NxMxK`, `grid3d27:NxMxK`,
//! `audikw:NxMxK`, `cage:N`, `qimonda:N`, `thread:N`, `file:PATH`.
//!
//! `batch` (alias `serve`) replays a request file through the
//! [`BatchCoordinator`]: one request per line,
//! `graph=<spec> [strategy=k=v;k=v] [engine=seq|pts|pm] [p=N] [tag=T]`,
//! `#` starts a comment. Repeated identical requests are served from
//! the fingerprint cache (DESIGN.md §6). Fleet-level faults (e.g.
//! injected via `PTSCOTCH_FAULT`) walk the recovery ladder — up to
//! `--retries` re-runs, then sequential degradation — and the command
//! exits nonzero if any request exhausts the ladder.

use ptscotch::coordinator::{
    BatchCoordinator, Engine, OrderingRequest, OrderingService, Route, Served, ServiceConfig,
};
use ptscotch::graph::{generators, io, Graph};
use ptscotch::runtime::XlaRuntime;
use ptscotch::strategy::Strategy;
use ptscotch::trace::chrome;
use ptscotch::trace::profile::{COL_BYTES, COL_MSGS, COL_OPS};
use std::collections::HashMap;
use std::path::Path;
use std::process::ExitCode;
use std::sync::Arc;

fn parse_graph(spec: &str) -> Result<Graph, String> {
    let (kind, arg) = spec.split_once(':').unwrap_or((spec, ""));
    let dims = |s: &str| -> Result<Vec<usize>, String> {
        s.split('x')
            .map(|t| t.parse::<usize>().map_err(|_| format!("bad dim {t}")))
            .collect()
    };
    match kind {
        "grid2d" => {
            let d = dims(arg)?;
            if d.len() != 2 {
                return Err("grid2d needs NxM".into());
            }
            Ok(generators::grid2d(d[0], d[1]))
        }
        "grid3d" => {
            let d = dims(arg)?;
            if d.len() != 3 {
                return Err("grid3d needs NxMxK".into());
            }
            Ok(generators::grid3d(d[0], d[1], d[2]))
        }
        "grid3d27" => {
            let d = dims(arg)?;
            if d.len() != 3 {
                return Err("grid3d27 needs NxMxK".into());
            }
            Ok(generators::grid3d_27pt(d[0], d[1], d[2]))
        }
        "audikw" => {
            let d = dims(arg)?;
            if d.len() != 3 {
                return Err("audikw needs NxMxK".into());
            }
            Ok(generators::audikw_like(d[0], d[1], d[2], 0.02, 40, 1))
        }
        "cage" => Ok(generators::cage_like(
            arg.parse().map_err(|_| "cage needs N")?,
            8,
            2,
        )),
        "qimonda" => Ok(generators::qimonda_like(
            arg.parse().map_err(|_| "qimonda needs N")?,
            3,
        )),
        "thread" => Ok(generators::thread_like(
            arg.parse().map_err(|_| "thread needs N")?,
            120,
            4,
        )),
        "file" => io::load(Path::new(arg)).map_err(|e| e.to_string()),
        other => Err(format!("unknown graph kind {other}")),
    }
}

fn get_flag(args: &[String], name: &str) -> Option<String> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1).cloned())
}

fn cmd_order(args: &[String]) -> Result<(), String> {
    let spec = get_flag(args, "--graph").ok_or("--graph required")?;
    let g = parse_graph(&spec)?;
    let p: usize = get_flag(args, "-p")
        .map(|s| s.parse().unwrap_or(1))
        .unwrap_or(1);
    let engine = match get_flag(args, "--engine").as_deref().unwrap_or("pts") {
        "seq" => Engine::Sequential,
        "pts" => Engine::PtScotch { p },
        "pm" => Engine::ParMetisLike { p },
        e => return Err(format!("unknown engine {e} (seq|pts|pm)")),
    };
    let strat = Strategy::parse(&get_flag(args, "--strategy").unwrap_or_default())
        .map_err(|e| e.to_string())?;
    let svc = OrderingService::new(&XlaRuntime::default_dir());
    eprintln!(
        "graph {spec}: |V|={} |E|={} avg-deg={:.2}; engine={engine:?} xla={}",
        g.n(),
        g.m(),
        g.avg_degree(),
        svc.has_xla()
    );
    let trace_out = get_flag(args, "--trace-out");
    let req = OrderingRequest::new(&g).strategy(strat).engine(engine);
    let res = svc.run(&req).map_err(|e| e.to_string())?;
    let (mn, avg, mx) = res.mem_min_avg_max();
    println!(
        "OPC={:.3e} NNZ={} fill={:.2} height={} cblk={} time={:.2}s \
         mem(min/avg/max)={}/{:.0}/{} B comm={} B",
        res.stats.opc,
        res.stats.nnz,
        res.stats.fill_ratio,
        res.stats.tree_height,
        res.blocks.cblk,
        res.wall_seconds,
        mn,
        avg,
        mx,
        res.total_comm_bytes()
    );
    if let Some(profile) = &res.profile {
        println!("{profile}");
        // The exclusive counter columns tile: summed over the whole
        // tree and all ranks they equal the run totals exactly.
        println!(
            "trace totals: bytes={} (run {}), msgs={} (run {}), ops={}",
            profile.total(COL_BYTES),
            res.total_comm_bytes(),
            profile.total(COL_MSGS),
            res.msgs_sent_per_rank.iter().sum::<u64>(),
            profile.total(COL_OPS),
        );
    }
    if let Some(out) = trace_out {
        if res.traces.is_empty() {
            return Err(format!(
                "--trace-out {out} needs trace=phases or trace=full in --strategy"
            ));
        }
        chrome::write(Path::new(&out), &res.traces).map_err(|e| e.to_string())?;
        eprintln!(
            "wrote Chrome trace: {out} ({} events)",
            chrome::event_count(&res.traces)
        );
    }
    Ok(())
}

fn cmd_suite(args: &[String]) -> Result<(), String> {
    let scale: usize = get_flag(args, "--scale")
        .map(|s| s.parse().unwrap_or(1))
        .unwrap_or(1);
    let ps: Vec<usize> = get_flag(args, "-p")
        .unwrap_or_else(|| "2,4".to_string())
        .split(',')
        .filter_map(|t| t.parse().ok())
        .collect();
    let svc = OrderingService::new(&XlaRuntime::default_dir());
    let strat = Strategy::parse(&get_flag(args, "--strategy").unwrap_or_default())
        .map_err(|e| e.to_string())?;
    println!(
        "{:<18} {:>8} {:>10} {:>4} {:>12} {:>9}",
        "graph", "|V|", "|E|", "p", "OPC", "t(s)"
    );
    for (name, g) in generators::table1_suite(scale) {
        let shared = Arc::new(g);
        for &p in &ps {
            let req = OrderingRequest::from_arc(Arc::clone(&shared))
                .strategy(strat.clone())
                .engine(Engine::PtScotch { p });
            let res = svc.run(&req).map_err(|e| e.to_string())?;
            println!(
                "{:<18} {:>8} {:>10} {:>4} {:>12.4e} {:>9.2}",
                name,
                shared.n(),
                shared.m(),
                p,
                res.stats.opc,
                res.wall_seconds
            );
        }
    }
    Ok(())
}

/// Parse one `batch` request line:
/// `graph=<spec> [strategy=k=v;k=v] [engine=seq|pts|pm] [p=N] [tag=T]`.
/// Strategy pairs use `;` between keys so the line stays
/// whitespace-tokenized. Graphs are shared per spec via `graphs`.
fn parse_request_line(
    line: &str,
    graphs: &mut HashMap<String, Arc<Graph>>,
) -> Result<OrderingRequest, String> {
    let mut graph_spec: Option<String> = None;
    let mut strat_spec = String::new();
    let mut engine_name = "pts".to_string();
    let mut p = 1usize;
    let mut tag = String::new();
    for tok in line.split_whitespace() {
        let (k, v) = tok
            .split_once('=')
            .ok_or_else(|| format!("bad token {tok} (want key=value)"))?;
        match k {
            "graph" => graph_spec = Some(v.to_string()),
            "strategy" => strat_spec = v.replace(';', ","),
            "engine" => engine_name = v.to_string(),
            "p" => p = v.parse().map_err(|_| format!("bad p {v}"))?,
            "tag" => tag = v.to_string(),
            other => {
                return Err(format!(
                    "unknown request key {other} (valid keys: graph, strategy, engine, p, tag)"
                ))
            }
        }
    }
    let spec = graph_spec.ok_or("request line needs graph=<spec>")?;
    let graph = match graphs.get(&spec) {
        Some(g) => Arc::clone(g),
        None => {
            let g = Arc::new(parse_graph(&spec)?);
            graphs.insert(spec.clone(), Arc::clone(&g));
            g
        }
    };
    let engine = match engine_name.as_str() {
        "seq" => Engine::Sequential,
        "pts" => Engine::PtScotch { p },
        "pm" => Engine::ParMetisLike { p },
        e => return Err(format!("unknown engine {e} (seq|pts|pm)")),
    };
    let strat = Strategy::parse(&strat_spec).map_err(|e| e.to_string())?;
    Ok(OrderingRequest::from_arc(graph)
        .strategy(strat)
        .engine(engine)
        .tag(if tag.is_empty() { spec } else { tag }))
}

fn cmd_batch(args: &[String]) -> Result<(), String> {
    let path = get_flag(args, "--requests").ok_or("--requests FILE required")?;
    let show_profile = args.iter().any(|a| a == "--profile");
    let text = std::fs::read_to_string(&path).map_err(|e| format!("{path}: {e}"))?;
    let repeat: usize = get_flag(args, "--repeat")
        .map(|s| s.parse().unwrap_or(1))
        .unwrap_or(1);
    let defaults = ServiceConfig::default();
    let config = ServiceConfig {
        cache_capacity: get_flag(args, "--cache")
            .map(|s| s.parse().unwrap_or(64))
            .unwrap_or(64),
        max_in_flight: get_flag(args, "--jobs")
            .map(|s| s.parse().unwrap_or(4))
            .unwrap_or(4),
        max_retries: get_flag(args, "--retries")
            .map(|s| s.parse().unwrap_or(defaults.max_retries))
            .unwrap_or(defaults.max_retries),
        ..defaults
    };
    let mut graphs = HashMap::new();
    let mut requests = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let req = parse_request_line(line, &mut graphs)
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        requests.push(req);
    }
    if requests.is_empty() {
        return Err(format!("{path}: no requests"));
    }
    let svc = OrderingService::new(&XlaRuntime::default_dir());
    let coord = BatchCoordinator::with_config(svc, config);
    println!(
        "{:<20} {:>5} {:>10} {:>10} {:>10} {:>12} {:>7}",
        "tag", "round", "served", "queue(ms)", "run(ms)", "OPC", "cblk"
    );
    let mut failed = 0u64;
    for round in 0..repeat.max(1) {
        let replies = coord.submit(requests.clone());
        for r in replies {
            // The served column shows the recovery route when the
            // ladder moved past the direct path.
            let served = match (r.served, r.route) {
                (Served::Hit, _) => "hit",
                (_, Route::Retried) => "retried",
                (_, Route::Degraded) => "degraded",
                (Served::Miss, _) => "miss",
                (Served::Coalesced, _) => "coalesced",
            };
            match &r.result {
                Ok(res) => {
                    println!(
                        "{:<20} {:>5} {:>10} {:>10.2} {:>10.2} {:>12.4e} {:>7}",
                        r.tag,
                        round,
                        served,
                        r.queue_seconds * 1e3,
                        r.run_seconds * 1e3,
                        res.stats.opc,
                        res.blocks.cblk
                    );
                    if show_profile {
                        // One per-phase summary row per reply; requests
                        // without `trace=` in their strategy have no
                        // profile to summarize.
                        match r.profile() {
                            Some(prof) => println!("  profile: {}", prof.summary_row()),
                            None => println!("  profile: (trace=off)"),
                        }
                    }
                }
                Err(e) => {
                    failed += 1;
                    println!("{:<20} {:>5} {:>10} error: {e}", r.tag, round, served);
                }
            }
        }
    }
    let m = coord.metrics();
    println!(
        "served {} requests: {} hits, {} misses, {} coalesced ({} orderings run, \
         hit-rate {:.1}%, {} evictions, {} errors; recovery: {} aborts, {} retries, \
         {} degraded)",
        m.requests(),
        m.hits,
        m.misses,
        m.coalesced,
        m.jobs_run,
        m.hit_rate() * 100.0,
        m.evictions,
        m.errors,
        m.aborts,
        m.retries,
        m.degraded
    );
    if failed > 0 {
        return Err(format!(
            "{failed} request(s) failed after exhausting the recovery ladder"
        ));
    }
    Ok(())
}

fn cmd_info() -> Result<(), String> {
    let dir = XlaRuntime::default_dir();
    println!("artifact dir: {}", dir.display());
    match XlaRuntime::load(&dir) {
        Ok(rt) => {
            println!("runtime: loaded ({} steps/call)", rt.steps_per_call);
            for b in rt.diffusion_buckets() {
                println!("  diffusion bucket n={} d={}", b.n, b.d);
            }
        }
        Err(e) => println!("runtime: unavailable ({e}) — run `make artifacts`"),
    }
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let r = match args.first().map(String::as_str) {
        Some("order") => cmd_order(&args[1..]),
        Some("suite") => cmd_suite(&args[1..]),
        Some("batch") | Some("serve") => cmd_batch(&args[1..]),
        Some("info") => cmd_info(),
        _ => {
            eprintln!(
                "usage: ptscotch <order|suite|batch|info> [--graph SPEC] [-p N] \
                 [--engine seq|pts|pm] [--strategy k=v,...] \
                 [--requests FILE --repeat K --cache N --jobs N --retries N --profile] \
                 [--trace-out FILE]"
            );
            return ExitCode::from(2);
        }
    };
    match r {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_line_parses_every_valid_key() {
        let mut graphs = HashMap::new();
        let req = parse_request_line(
            "graph=grid2d:4x4 strategy=band=5;seed=9 engine=pts p=2 tag=job-a",
            &mut graphs,
        )
        .expect("valid line");
        assert_eq!(req.tag, "job-a");
        assert_eq!(req.engine, Engine::PtScotch { p: 2 });
        assert_eq!(req.strategy.sep.band_width, 5);
        assert_eq!(req.strategy.seed, 9);
        // The shared-graph map keyed the spec.
        assert!(graphs.contains_key("grid2d:4x4"));
    }

    #[test]
    fn request_line_rejects_unknown_key_naming_the_valid_ones() {
        let mut graphs = HashMap::new();
        let err = parse_request_line("graph=grid2d:4x4 widht=3", &mut graphs)
            .expect_err("unknown key must be rejected");
        assert!(err.contains("unknown request key widht"), "{err}");
        // The error is structured: it names the bad key *and* the
        // accepted vocabulary, so a typo in a request file is
        // self-explaining.
        for key in ["graph", "strategy", "engine", "p", "tag"] {
            assert!(err.contains(key), "{err} should list {key}");
        }
    }

    #[test]
    fn request_line_rejects_bare_tokens_and_missing_graph() {
        let mut graphs = HashMap::new();
        let err = parse_request_line("grid2d:4x4", &mut graphs).expect_err("bare token");
        assert!(err.contains("key=value"), "{err}");
        let err = parse_request_line("tag=x", &mut graphs).expect_err("missing graph");
        assert!(err.contains("graph=<spec>"), "{err}");
    }
}
