//! Parallel probabilistic matching (paper §3.2 / §4.2).
//!
//! PT-Scotch's distributed heavy-edge matching cannot use the sequential
//! greedy algorithm (it is inherently serial), so the paper runs a
//! probabilistic handshake: every unmatched vertex proposes to one of
//! its heaviest unmatched neighbors, proposals crossing rank boundaries
//! travel with the halo, and a pair is matched exactly when the two
//! proposals are **mutual**. Symmetry is therefore structural — both
//! sides observe the same pair of proposals — and randomized tie-breaks
//! make mutual pairs form with constant probability per round, so the
//! process "usually converges in 5 rounds" (§4.2, the default of
//! [`crate::strategy::DistStrategy::matching_rounds`]).
//!
//! After the communication rounds, a purely local cleanup pass matches
//! leftover unmatched vertices with unmatched *local* neighbors (no
//! communication, trivially symmetric); anything still single coarsens
//! as a singleton, as in Scotch.

use super::dgraph::DGraph;
use crate::comm::Comm;
use crate::rng::Rng;

/// Compute a symmetric matching of the distributed graph.
///
/// Returns `mate`, one entry per local vertex, holding the **global id**
/// of the partner — or the vertex's own global id when unmatched.
/// Guarantees, globally: `mate[mate[v]] == v` and matched pairs are
/// adjacent. Collective; `rng` may differ freely across ranks.
pub fn parallel_match(comm: &Comm, dg: &DGraph, rounds: usize, rng: &mut Rng) -> Vec<u64> {
    let nloc = dg.nloc();
    let base = dg.base();
    const UNMATCHED: u64 = u64::MAX;
    let mut mate: Vec<u64> = vec![UNMATCHED; nloc];

    for _round in 0..rounds.max(1) {
        // Round-start matched flags, mirrored onto the halo.
        let matched: Vec<u8> = mate.iter().map(|&m| (m != UNMATCHED) as u8).collect();
        let gmatched = dg.halo_exchange(comm, &matched);

        // Each unmatched vertex proposes to a random heaviest unmatched
        // neighbor (heavy-edge preference; the random tie-break is the
        // probabilistic part that guarantees progress on regular graphs).
        let mut prop: Vec<u64> = vec![UNMATCHED; nloc];
        let mut cands: Vec<u64> = Vec::new();
        for v in 0..nloc {
            if mate[v] != UNMATCHED {
                continue;
            }
            let mut best_w = i64::MIN;
            cands.clear();
            for (&a, &w) in dg
                .neighbors_gst(v)
                .iter()
                .zip(dg.edge_weights_gst(v))
            {
                let a = a as usize;
                let (gid, taken) = if a < nloc {
                    (dg.glb(a), matched[a] != 0)
                } else {
                    (dg.ghosts[a - nloc], gmatched[a - nloc] != 0)
                };
                if taken {
                    continue;
                }
                if w > best_w {
                    best_w = w;
                    cands.clear();
                }
                if w == best_w {
                    cands.push(gid);
                }
            }
            if !cands.is_empty() {
                prop[v] = cands[rng.below(cands.len())];
            }
        }

        // Mirror proposals onto the halo and keep the mutual ones.
        let gprop = dg.halo_exchange(comm, &prop);
        for v in 0..nloc {
            let t = prop[v];
            if t == UNMATCHED {
                continue;
            }
            let t_prop = if t >= base && t < base + nloc as u64 {
                prop[(t - base) as usize]
            } else {
                let gi = dg.ghosts.binary_search(&t).expect("proposal targets a neighbor");
                gprop[gi]
            };
            if t_prop == dg.glb(v) {
                mate[v] = t;
            }
        }
    }

    // Local cleanup: leftover unmatched vertices pair with unmatched
    // local neighbors — no communication needed, symmetric within the
    // rank by construction.
    for v in 0..nloc {
        if mate[v] != UNMATCHED {
            continue;
        }
        for &a in dg.neighbors_gst(v) {
            let a = a as usize;
            if a < nloc && mate[a] == UNMATCHED {
                mate[v] = dg.glb(a);
                mate[a] = dg.glb(v);
                break;
            }
        }
    }

    // Unmatched vertices coarsen as singletons: mate = self.
    for v in 0..nloc {
        if mate[v] == UNMATCHED {
            mate[v] = dg.glb(v);
        }
    }
    mate
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm;
    use crate::graph::generators;
    use std::sync::Arc;

    /// Gather per-rank mate vectors into the global mate array.
    fn run_matching(p: usize, g: Arc<crate::graph::Graph>, rounds: usize) -> Vec<u64> {
        let n = g.n();
        let (res, _) = comm::run(p, move |c| {
            let dg = DGraph::from_global(&c, &g);
            let mut rng = Rng::new(42).derive(c.global_rank() as u64);
            let mate = parallel_match(&c, &dg, rounds, &mut rng);
            (dg.base(), mate)
        });
        let mut mate = vec![0u64; n];
        for (b, m) in res {
            for (i, &x) in m.iter().enumerate() {
                mate[b as usize + i] = x;
            }
        }
        mate
    }

    #[test]
    fn matching_is_symmetric_and_adjacent_across_ranks() {
        for p in [2usize, 4] {
            let g = Arc::new(generators::grid2d(12, 11));
            let gref = g.clone();
            let mate = run_matching(p, g, 5);
            for v in 0..gref.n() {
                let m = mate[v] as usize;
                assert_eq!(mate[m] as usize, v, "p={p}: asymmetric at {v}");
                if m != v {
                    assert!(
                        gref.neighbors(v).contains(&(m as u32)),
                        "p={p}: non-adjacent pair {v}-{m}"
                    );
                }
            }
        }
    }

    #[test]
    fn matching_is_maximal_ish() {
        // On a grid, the probabilistic rounds plus local cleanup must
        // match well over half of the vertices — enough that coarsening
        // shrinks each level substantially (§3.2's stop ratio).
        for p in [2usize, 4] {
            let g = Arc::new(generators::grid2d(16, 16));
            let n = g.n();
            let mate = run_matching(p, g, 5);
            let matched = (0..n).filter(|&v| mate[v] as usize != v).count();
            assert!(
                matched * 2 >= n,
                "p={p}: only {matched}/{n} vertices matched"
            );
        }
    }

    #[test]
    fn heavy_edges_preferred() {
        // A path with one heavy edge: its endpoints must pair together.
        let mut b = crate::graph::GraphBuilder::new(4);
        b.add_edge_w(0, 1, 1);
        b.add_edge_w(1, 2, 100);
        b.add_edge_w(2, 3, 1);
        let g = Arc::new(b.build().unwrap());
        let mate = run_matching(2, g, 8);
        assert_eq!(mate[1], 2);
        assert_eq!(mate[2], 1);
    }
}
