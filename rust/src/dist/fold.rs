//! Graph folding (paper §3.2).
//!
//! When the nested-dissection recursion splits the rank set, each
//! induced subgraph is *folded* onto one half of the ranks: every
//! vertex record (weight, payload, adjacency in global ids) is routed
//! to its new owner under a block distribution over the target half.
//! Unlike the ParMETIS comparator, whose "folding algorithm requires
//! the number of sending processes to be even" (§3.2), this fold works
//! for **any** rank count — the low half takes ⌈p/2⌉ ranks and the
//! high half ⌊p/2⌋, matching [`crate::comm::Comm::split`]'s re-ranking.
//!
//! The same primitive implements folding-with-duplication: both halves
//! receive a copy of the graph when the caller folds the *same* graph
//! onto [`FoldTarget::low_half`] and [`FoldTarget::high_half`].

use super::dgraph::{DGraph, HaloPlan};
use crate::comm::Comm;

/// A contiguous target range of ranks for one fold.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FoldTarget {
    /// First rank of the target range (inclusive).
    pub start: usize,
    /// One past the last rank of the target range.
    pub end: usize,
}

impl FoldTarget {
    /// The low half of `p` ranks: `0 .. ⌈p/2⌉`.
    pub fn low_half(p: usize) -> FoldTarget {
        FoldTarget {
            start: 0,
            end: (p + 1) / 2,
        }
    }

    /// The high half of `p` ranks: `⌈p/2⌉ .. p`.
    pub fn high_half(p: usize) -> FoldTarget {
        FoldTarget {
            start: (p + 1) / 2,
            end: p,
        }
    }

    /// Does this target contain `rank` (in the folding communicator)?
    pub fn contains(&self, rank: usize) -> bool {
        rank >= self.start && rank < self.end
    }

    /// Number of ranks in the target.
    pub fn size(&self) -> usize {
        self.end - self.start
    }
}

/// Fold the distributed graph (and its per-vertex payload) onto
/// `target`. Collective over the **current** communicator; member ranks
/// receive `Some((graph, payload))` re-based on a `vtxdist` of
/// `target.size()` blocks — ready for use on the sub-communicator
/// obtained by `comm.split`, whose ranks are the target members in
/// ascending order — and non-members receive `None`.
pub fn fold_half(
    comm: &Comm,
    dg: &DGraph,
    payload: &[u64],
    target: FoldTarget,
) -> Option<(DGraph, Vec<u64>)> {
    debug_assert_eq!(payload.len(), dg.nloc());
    assert!(target.size() > 0, "fold target must contain at least one rank");
    let t = target.size();
    let n = dg.nglb;
    // Block distribution of the (unchanged) global range over t members.
    let nvtx: Vec<u64> = (0..=t).map(|i| n * i as u64 / t as u64).collect();
    let member_of = |g: u64| nvtx.partition_point(|&b| b <= g) - 1;

    // Route each local vertex record to its new owner:
    // [gid, vwgt, payload, deg, (nbr_gid, w)*deg].
    let mut bufs: Vec<Vec<u64>> = vec![Vec::new(); comm.size()];
    for v in 0..dg.nloc() {
        let gid = dg.glb(v);
        let b = &mut bufs[target.start + member_of(gid)];
        b.push(gid);
        b.push(dg.vwgt[v] as u64);
        b.push(payload[v]);
        dg.encode_row(v, b);
    }
    let got = comm.alltoallv(bufs);
    let assembled = if target.contains(comm.rank()) {
        let me = comm.rank() - target.start;
        let nbase = nvtx[me];
        let nl = (nvtx[me + 1] - nbase) as usize;
        let mut vwgt = vec![0i64; nl];
        let mut pl = vec![0u64; nl];
        let mut rows: Vec<Vec<(u64, i64)>> = vec![Vec::new(); nl];
        for b in &got {
            let mut i = 0usize;
            while i < b.len() {
                let lv = (b[i] - nbase) as usize;
                vwgt[lv] = b[i + 1] as i64;
                pl[lv] = b[i + 2];
                let deg = b[i + 3] as usize;
                i += 4;
                let mut row = Vec::with_capacity(deg);
                for _ in 0..deg {
                    row.push((b[i], b[i + 1] as i64));
                    i += 2;
                }
                rows[lv] = row;
            }
        }
        Some((DGraph::assemble(nvtx.clone(), me, vwgt, rows), pl))
    } else {
        None
    };
    // Build the folded graph's halo plan through the *parent*
    // communicator — graph rank r maps to parent rank target.start + r,
    // and non-members merely feed the collective with empty want lists.
    // The later `Comm::split` re-ranks the target members along exactly
    // that ascending mapping, so the plan survives the split unchanged.
    let plan = HaloPlan::build(
        comm,
        target.start,
        &nvtx,
        assembled.as_ref().map(|(dg, _)| (dg.rank, dg.ghosts.as_slice())),
    );
    assembled.map(|(mut dg, pl)| {
        dg.set_plan(plan.expect("target members receive a plan"));
        (dg, pl)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm;
    use crate::graph::generators;
    use std::sync::Arc;

    #[test]
    fn halves_partition_any_p() {
        for p in [2usize, 3, 5, 8] {
            let lo = FoldTarget::low_half(p);
            let hi = FoldTarget::high_half(p);
            assert_eq!(lo.size() + hi.size(), p);
            for r in 0..p {
                assert!(lo.contains(r) ^ hi.contains(r));
            }
            assert!(lo.size() >= hi.size());
        }
    }

    #[test]
    fn folded_plan_survives_split() {
        // The halo plan built through the parent communicator must
        // drive exchanges on the sub-communicator obtained by the split
        // that follows every fold in the dissection recursion.
        let g = Arc::new(generators::grid2d(11, 7));
        for p in [3usize, 5] {
            let g = g.clone();
            let (ok, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let payload: Vec<u64> = (0..dg.nloc()).map(|v| dg.glb(v)).collect();
                let lo = FoldTarget::low_half(p);
                let f = fold_half(&c, &dg, &payload, lo);
                let sub = c.split(if lo.contains(c.rank()) { 0 } else { 1 });
                match f {
                    Some((fdg, _)) => {
                        let mine: Vec<u64> = (0..fdg.nloc()).map(|v| fdg.glb(v)).collect();
                        fdg.halo_exchange(&sub, &mine) == fdg.ghosts
                    }
                    None => true,
                }
            });
            assert!(ok.iter().all(|&x| x), "p={p}");
        }
    }

    #[test]
    fn fold_preserves_graph_on_fewer_ranks() {
        // Fold a 5-rank graph onto the 3-rank low half; centralizing on
        // the subgroup must reproduce the original graph.
        let g = Arc::new(generators::grid2d(9, 8));
        let gref = g.clone();
        let (res, _) = comm::run(5, move |c| {
            let dg = DGraph::from_global(&c, &g);
            let payload: Vec<u64> = (0..dg.nloc()).map(|v| dg.glb(v)).collect();
            let f = fold_half(&c, &dg, &payload, FoldTarget::low_half(5));
            let in_low = FoldTarget::low_half(5).contains(c.rank());
            let sub = c.split(if in_low { 0 } else { 1 });
            if in_low {
                let (fdg, fpl) = f.expect("low ranks receive the fold");
                // Payload rides along with the redistribution.
                for (v, &plv) in fpl.iter().enumerate() {
                    assert_eq!(plv, fdg.glb(v));
                }
                Some(fdg.centralize_all(&sub))
            } else {
                assert!(f.is_none());
                None
            }
        });
        for central in res.into_iter().flatten() {
            central.validate().unwrap();
            assert_eq!(central.xadj, gref.xadj);
            assert_eq!(central.adj, gref.adj);
            assert_eq!(central.ewgt, gref.ewgt);
        }
    }
}
