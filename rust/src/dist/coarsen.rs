//! Distributed coarsening (paper §3.2).
//!
//! Given a matching from [`super::matching::parallel_match`], each
//! matched pair (and each singleton) collapses into one coarse vertex
//! owned by the rank of the pair's smaller global id. Coarse vertices
//! are renumbered contiguously per rank (exclusive scan over per-rank
//! counts, preserving the ascending-block invariant of
//! [`super::dgraph::DGraph`]); fine→coarse edges are routed to the
//! coarse owner with one personalized exchange and merged there,
//! accumulating collapsed edge weights exactly like the sequential
//! heavy-edge coarsening in [`crate::sep::coarsen`].

use super::dgraph::DGraph;
use crate::comm::Comm;

/// One distributed coarsening level: the coarse graph plus the mapping
/// from fine local vertices to **global** coarse ids, used by the
/// uncoarsening projection (`coarse.fetch_at(comm, &fine2coarse, …)`).
#[derive(Clone, Debug)]
pub struct DistCoarsening {
    /// The coarse distributed graph.
    pub coarse: DGraph,
    /// Global coarse id of each fine local vertex.
    pub fine2coarse: Vec<u64>,
}

/// Collapse the distributed graph along `mate` (global-id partner per
/// local vertex, self when unmatched). Collective.
pub fn coarsen_dist(comm: &Comm, dg: &DGraph, mate: &[u64]) -> DistCoarsening {
    let p = comm.size();
    let nloc = dg.nloc();
    let base = dg.base();

    // 1. A pair's representative is its smaller global id; singletons
    //    represent themselves. Representatives get local coarse slots.
    let mut rep_slot: Vec<u64> = vec![u64::MAX; nloc];
    let mut ncoarse_loc = 0u64;
    for v in 0..nloc {
        if dg.glb(v) <= mate[v] {
            rep_slot[v] = ncoarse_loc;
            ncoarse_loc += 1;
        }
    }

    // 2. Coarse vertex distribution: exclusive scan of per-rank counts.
    let counts = comm.allgatherv(vec![ncoarse_loc]);
    let mut cvtx = vec![0u64; p + 1];
    for r in 0..p {
        cvtx[r + 1] = cvtx[r] + counts[r][0];
    }
    let cbase = cvtx[comm.rank()];

    // 3. fine2coarse. Representatives and locally paired vertices are
    //    resolved in place; a vertex whose (smaller-id) partner lives
    //    remotely fetches the coarse id from the partner's owner.
    let mut fine2coarse: Vec<u64> = vec![u64::MAX; nloc];
    let mut queries: Vec<u64> = Vec::new();
    let mut qpos: Vec<usize> = Vec::new();
    for v in 0..nloc {
        if rep_slot[v] != u64::MAX {
            fine2coarse[v] = cbase + rep_slot[v];
        } else if mate[v] >= base && mate[v] < base + nloc as u64 {
            fine2coarse[v] = cbase + rep_slot[(mate[v] - base) as usize];
        } else {
            queries.push(mate[v]);
            qpos.push(v);
        }
    }
    let my_coarse: Vec<u64> = (0..nloc)
        .map(|v| {
            if rep_slot[v] != u64::MAX {
                cbase + rep_slot[v]
            } else {
                u64::MAX // never queried: only representatives are
            }
        })
        .collect();
    let answers = dg.fetch_at(comm, &queries, &my_coarse);
    for (k, &v) in qpos.iter().enumerate() {
        debug_assert_ne!(answers[k], u64::MAX);
        fine2coarse[v] = answers[k];
    }

    // 4. Coarse ids of fine ghosts, via the halo.
    let ghost_coarse = dg.halo_exchange(comm, &fine2coarse);

    // 5. Route vertex-weight and arc contributions to the coarse owner.
    //    Vertex records: (coarse id, weight); arc records:
    //    (coarse src, coarse dst, weight). Pair-internal arcs vanish.
    let owner_of = |c: u64| cvtx.partition_point(|&b| b <= c) - 1;
    let mut vbuf: Vec<Vec<u64>> = vec![Vec::new(); p];
    let mut ebuf: Vec<Vec<u64>> = vec![Vec::new(); p];
    for v in 0..nloc {
        let cv = fine2coarse[v];
        let o = owner_of(cv);
        vbuf[o].push(cv);
        vbuf[o].push(dg.vwgt[v] as u64);
        for (&a, &w) in dg.neighbors_gst(v).iter().zip(dg.edge_weights_gst(v)) {
            let a = a as usize;
            let cw = if a < nloc {
                fine2coarse[a]
            } else {
                ghost_coarse[a - nloc]
            };
            if cw != cv {
                ebuf[o].push(cv);
                ebuf[o].push(cw);
                ebuf[o].push(w as u64);
            }
        }
    }
    let vin = comm.alltoallv(vbuf);
    let ein = comm.alltoallv(ebuf);

    // 6. Aggregate on the owner: sum vertex weights, then merge
    //    parallel coarse arcs with one flat sort over all received
    //    triples — runs of equal (src, dst) accumulate the collapsed
    //    fine-edge weights. Same deterministic dst-ascending rows as
    //    the per-vertex BTreeMaps this replaces, without the map
    //    allocation per coarse vertex.
    let nc = (cvtx[comm.rank() + 1] - cbase) as usize;
    let mut vwgt = vec![0i64; nc];
    for b in &vin {
        let mut i = 0usize;
        while i < b.len() {
            vwgt[(b[i] - cbase) as usize] += b[i + 1] as i64;
            i += 2;
        }
    }
    let narcs: usize = ein.iter().map(|b| b.len() / 3).sum();
    let mut arcs: Vec<(u32, u64, i64)> = Vec::with_capacity(narcs);
    for b in &ein {
        for t in b.chunks_exact(3) {
            arcs.push(((t[0] - cbase) as u32, t[1], t[2] as i64));
        }
    }
    arcs.sort_unstable_by_key(|&(s, d, _)| (s, d));
    let mut rows: Vec<Vec<(u64, i64)>> = vec![Vec::new(); nc];
    for &(s, d, w) in &arcs {
        match rows[s as usize].last_mut() {
            Some(last) if last.0 == d => last.1 += w,
            _ => rows[s as usize].push((d, w)),
        }
    }
    let coarse = DGraph::from_rows(comm, cvtx, vwgt, rows);
    DistCoarsening { coarse, fine2coarse }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm;
    use crate::dist::matching::parallel_match;
    use crate::graph::generators;
    use crate::rng::Rng;
    use std::sync::Arc;

    #[test]
    fn coarse_graph_conserves_weight_and_shrinks() {
        let g = Arc::new(generators::grid2d(14, 10));
        let total = g.total_vwgt();
        for p in [2usize, 3] {
            let g = g.clone();
            let (res, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let mut rng = Rng::new(7).derive(c.global_rank() as u64);
                let mate = parallel_match(&c, &dg, 5, &mut rng);
                let dc = coarsen_dist(&c, &dg, &mate);
                let central = dc.coarse.centralize_all(&c);
                central.validate().unwrap();
                (dc.coarse.nglb, central.total_vwgt())
            });
            for (nglb, tw) in &res {
                assert_eq!(*tw, total, "p={p}: weight drift");
                assert!(*nglb < 140, "p={p}: no shrink");
                assert!(*nglb as usize >= 140 / 2, "p={p}: over-collapse");
            }
        }
    }

    #[test]
    fn merged_rows_are_sorted_and_deduplicated() {
        // The flat sort-then-merge must leave every coarse row strictly
        // ascending in neighbor id (the order the BTreeMap merge it
        // replaced produced) with parallel arcs fully accumulated.
        let g = Arc::new(generators::irregular_mesh(12, 9, 11));
        let (ok, _) = comm::run(3, move |c| {
            let dg = DGraph::from_global(&c, &g);
            let mut rng = Rng::new(5).derive(c.global_rank() as u64);
            let mate = parallel_match(&c, &dg, 5, &mut rng);
            let dc = coarsen_dist(&c, &dg, &mate);
            let cg = &dc.coarse;
            (0..cg.nloc()).all(|v| {
                let row = cg.neighbors_gst(v);
                let ids: Vec<u64> = row.iter().map(|&a| cg.gst_to_glb(a)).collect();
                ids.windows(2).all(|w| w[0] < w[1])
            })
        });
        assert!(ok.iter().all(|&x| x));
    }

    #[test]
    fn projection_map_is_consistent() {
        // Every fine vertex maps to a live coarse id, and matched pairs
        // map to the same coarse vertex.
        let g = Arc::new(generators::grid3d(5, 5, 4));
        let n = g.n();
        let (res, _) = comm::run(4, move |c| {
            let dg = DGraph::from_global(&c, &g);
            let mut rng = Rng::new(3).derive(c.global_rank() as u64);
            let mate = parallel_match(&c, &dg, 5, &mut rng);
            let dc = coarsen_dist(&c, &dg, &mate);
            (dg.base(), mate, dc.fine2coarse.clone(), dc.coarse.nglb)
        });
        let mut mate = vec![0u64; n];
        let mut f2c = vec![0u64; n];
        let mut nglb = 0;
        for (b, m, f, ng) in res {
            for (i, (&mm, &ff)) in m.iter().zip(&f).enumerate() {
                mate[b as usize + i] = mm;
                f2c[b as usize + i] = ff;
            }
            nglb = ng;
        }
        for v in 0..n {
            assert!(f2c[v] < nglb, "dangling coarse id at {v}");
            assert_eq!(f2c[v], f2c[mate[v] as usize], "pair split at {v}");
        }
    }
}
