//! Distributed band-graph extraction (paper §3.3, scalable regime).
//!
//! The multi-sequential refinement of [`crate::dist::dsep`] centralizes
//! the band around the projected separator on every rank — fine while
//! bands are small, but a scalability cliff once they are not. This
//! module extracts the same width-`w` band as a [`DGraph`] *in its own
//! right*, so the diffusion kernel of [`crate::dist::ddiffusion`] can
//! refine it in place without ever centralizing:
//!
//! * band membership comes from a distributed multi-source BFS from the
//!   separator, one halo exchange per level ([`band_distances`] — the
//!   distributed analog of [`crate::graph::Graph::multi_source_bfs`]);
//! * survivors are renumbered into a fresh contiguous global range by
//!   an exclusive scan of per-rank counts, exactly like
//!   [`crate::dist::induce::induce_dist`];
//! * the two discarded sides are replaced by **two anchor vertices**
//!   appended to the last rank's block, carrying the excluded part
//!   weights and the collapsed boundary arcs — the same anchor
//!   construction as the sequential [`crate::sep::band::extract_band`],
//!   distributed.

use super::dgraph::DGraph;
use crate::comm::Comm;
use crate::sep::{P0, P1, SEP};

/// A distributed band graph: the band as a [`DGraph`] whose last two
/// global vertices are the locked anchors, plus the bookkeeping needed
/// to commit refined labels back to the parent graph.
#[derive(Clone, Debug)]
pub struct DistBand {
    /// The band graph (fresh contiguous global ids; the two anchors are
    /// the last two global vertices, owned by the last rank).
    pub dg: DGraph,
    /// Parent-graph *local* index of each local band vertex, in band
    /// local order (anchors excluded — they map to no parent vertex).
    pub orig_local: Vec<usize>,
    /// Part labels ([`P0`]/[`P1`]/[`SEP`]) of the local band vertices,
    /// including the anchors on the last rank (anchor 0 is [`P0`],
    /// anchor 1 is [`P1`]).
    pub part: Vec<u8>,
    /// Number of non-anchor band vertices globally.
    pub band_nglb: u64,
}

impl DistBand {
    /// Global id of the part-0 anchor.
    #[inline]
    pub fn anchor0_gid(&self) -> u64 {
        self.band_nglb
    }

    /// Global id of the part-1 anchor.
    #[inline]
    pub fn anchor1_gid(&self) -> u64 {
        self.band_nglb + 1
    }

    /// Whether a band-graph global id is one of the two locked anchors.
    #[inline]
    pub fn is_anchor_gid(&self, gid: u64) -> bool {
        gid >= self.band_nglb
    }

    /// Number of local band vertices owned by this rank, anchors
    /// excluded.
    #[inline]
    pub fn nloc_band(&self) -> usize {
        self.orig_local.len()
    }
}

/// Distributed multi-source BFS from the separator of `part`, capped at
/// `width` levels: one halo exchange per level. Returns one distance
/// per local vertex (`u32::MAX` outside the band). Collective.
pub fn band_distances(comm: &Comm, dg: &DGraph, part: &[u8], width: u32) -> Vec<u32> {
    let nloc = dg.nloc();
    debug_assert_eq!(part.len(), nloc);
    let mut dist: Vec<u32> = part
        .iter()
        .map(|&x| if x == SEP { 0 } else { u32::MAX })
        .collect();
    for _ in 0..width {
        let ghost_dist = dg.halo_exchange(comm, &dist);
        let prev = dist.clone();
        for v in 0..nloc {
            if prev[v] != u32::MAX {
                continue;
            }
            let mut best = u32::MAX;
            for &a in dg.neighbors_gst(v) {
                let a = a as usize;
                let da = if a < nloc {
                    prev[a]
                } else {
                    ghost_dist[a - nloc]
                };
                if da != u32::MAX && da + 1 < best {
                    best = da + 1;
                }
            }
            dist[v] = best;
        }
    }
    dist
}

/// Extract the distributed band graph of vertices whose `dist` (from
/// [`band_distances`]) is finite. Arcs leaving the band are collapsed
/// onto the anchor of the band endpoint's part — the outside endpoint
/// has the same part, since every vertex within `width ≥ 1` of the
/// separator is in the band and parts only touch through the separator.
/// Collective; every rank must pass the same global `part`/`dist`
/// semantics (each rank its own slice).
pub fn extract_dband(comm: &Comm, dg: &DGraph, part: &[u8], dist: &[u32]) -> DistBand {
    let p = comm.size();
    let nloc = dg.nloc();
    debug_assert_eq!(part.len(), nloc);
    debug_assert_eq!(dist.len(), nloc);

    let kept: Vec<usize> = (0..nloc).filter(|&v| dist[v] != u32::MAX).collect();

    // Fresh contiguous global numbering of the band vertices; the two
    // anchors extend the last rank's block.
    let counts = comm.allgatherv(vec![kept.len() as u64]);
    let mut vtx = vec![0u64; p + 1];
    for r in 0..p {
        vtx[r + 1] = vtx[r] + counts[r][0];
    }
    let band_nglb = vtx[p];
    vtx[p] += 2;
    let anchor_gid = [band_nglb, band_nglb + 1];

    let nbase = vtx[comm.rank()];
    let mut newid: Vec<u64> = vec![u64::MAX; nloc];
    for (i, &v) in kept.iter().enumerate() {
        newid[v] = nbase + i as u64;
    }
    // New ids of the parent graph's ghosts (MAX when outside the band).
    let ghost_newid = dg.halo_exchange(comm, &newid);

    // Anchor weights: the total excluded weight per part (≥ 1 to keep
    // the positive-weight invariant when a whole part fits in the band).
    let mut excl = [0i64; 2];
    for v in 0..nloc {
        if dist[v] == u32::MAX {
            // Outside the band ⇒ not SEP (separator vertices have
            // distance 0), so the label indexes a real part.
            excl[part[v] as usize] += dg.vwgt[v];
        }
    }
    let excl_g = comm.allreduce(excl, |a, b| [a[0] + b[0], a[1] + b[1]]);

    // Band rows; boundary arcs collapse per vertex onto one anchor arc.
    let mut vwgt: Vec<i64> = kept.iter().map(|&v| dg.vwgt[v]).collect();
    let mut band_part: Vec<u8> = kept.iter().map(|&v| part[v]).collect();
    let mut rows: Vec<Vec<(u64, i64)>> = Vec::with_capacity(kept.len());
    // Reciprocal arcs the anchors owe this rank's boundary vertices,
    // encoded as `[band_gid, anchor_index, weight]` triples.
    let mut anchor_arcs: Vec<u64> = Vec::new();
    for (i, &v) in kept.iter().enumerate() {
        let mut row: Vec<(u64, i64)> = Vec::with_capacity(dg.neighbors_gst(v).len());
        let mut to_anchor = 0i64;
        for (&a, &w) in dg.neighbors_gst(v).iter().zip(dg.edge_weights_gst(v)) {
            let a = a as usize;
            let nid = if a < nloc {
                newid[a]
            } else {
                ghost_newid[a - nloc]
            };
            if nid != u64::MAX {
                row.push((nid, w));
            } else {
                to_anchor += w;
            }
        }
        if to_anchor > 0 {
            // A boundary vertex is never SEP (distance 0 vertices keep
            // all neighbors within width ≥ 1), so its part picks the
            // anchor directly.
            let side = band_part[i] as usize;
            row.push((anchor_gid[side], to_anchor));
            anchor_arcs.push(nbase + i as u64);
            anchor_arcs.push(side as u64);
            anchor_arcs.push(to_anchor as u64);
        }
        rows.push(row);
    }

    // The last rank owns the anchors: it alone needs the boundary
    // contributions for the two reciprocal anchor rows, so gather them
    // point-to-point (the `centralize_root` pattern) instead of
    // replicating O(boundary) triples on every rank.
    const TAG: u64 = 0xDBA2;
    if comm.rank() != p - 1 {
        comm.send(p - 1, TAG, anchor_arcs);
    } else {
        let mut row0: Vec<(u64, i64)> = Vec::new();
        let mut row1: Vec<(u64, i64)> = Vec::new();
        let mut mine = Some(anchor_arcs);
        for r in 0..p {
            let b: Vec<u64> = if r == p - 1 {
                mine.take().expect("own contributions")
            } else {
                comm.recv(r, TAG)
            };
            for t in b.chunks_exact(3) {
                let arc = (t[0], t[2] as i64);
                if t[1] == 0 {
                    row0.push(arc);
                } else {
                    row1.push(arc);
                }
            }
        }
        vwgt.push(excl_g[0].max(1));
        vwgt.push(excl_g[1].max(1));
        band_part.push(P0);
        band_part.push(P1);
        rows.push(row0);
        rows.push(row1);
    }

    DistBand {
        dg: DGraph::from_rows(vtx, comm.rank(), vwgt, rows),
        orig_local: kept,
        part: band_part,
        band_nglb,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm;
    use crate::graph::generators;
    use crate::sep::band::extract_band;
    use crate::sep::SepState;
    use std::sync::Arc;

    /// The shared 2-thick column-separator fixture, centered.
    fn thick_column_part(nx: usize, ny: usize) -> Vec<u8> {
        generators::column_separator_part(nx, ny, nx / 2, 2)
    }

    #[test]
    fn distances_match_sequential_bfs() {
        let (nx, ny) = (17, 11);
        let g = Arc::new(generators::grid2d(nx, ny));
        let gref = g.clone();
        let full = thick_column_part(nx, ny);
        let fref = full.clone();
        for p in [2usize, 3, 4] {
            let g = g.clone();
            let full = full.clone();
            let (res, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let part: Vec<u8> = (0..dg.nloc())
                    .map(|v| full[dg.glb(v) as usize])
                    .collect();
                let d = band_distances(&c, &dg, &part, 3);
                (dg.base(), d)
            });
            let seps: Vec<usize> = (0..gref.n()).filter(|&v| fref[v] == SEP).collect();
            let want = gref.multi_source_bfs(&seps, 3);
            for (base, d) in &res {
                for (i, &di) in d.iter().enumerate() {
                    assert_eq!(di, want[*base as usize + i], "p={p} v={}", *base as usize + i);
                }
            }
        }
    }

    #[test]
    fn dband_matches_sequential_band_graph() {
        // The centralized distributed band must be isomorphic (same
        // sizes, same total weight, same anchor weights) to the
        // sequential extraction from the same projection.
        let (nx, ny) = (16, 9);
        let g = Arc::new(generators::grid2d(nx, ny));
        let gref = g.clone();
        let full = thick_column_part(nx, ny);
        let fref = full.clone();
        let width = 3u32;
        for p in [2usize, 4] {
            let g = g.clone();
            let full = full.clone();
            let (res, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let part: Vec<u8> = (0..dg.nloc())
                    .map(|v| full[dg.glb(v) as usize])
                    .collect();
                let dist = band_distances(&c, &dg, &part, width);
                let band = extract_dband(&c, &dg, &part, &dist);
                let central = band.dg.centralize_all(&c);
                (band.band_nglb, band.nloc_band(), central)
            });
            let state = SepState::from_parts(&gref, fref.clone());
            let seq = extract_band(&gref, &state, width).unwrap();
            let nb: usize = res.iter().map(|(_, nl, _)| nl).sum();
            assert_eq!(nb as u64, res[0].0, "p={p}");
            assert_eq!(nb, seq.band_n(), "p={p}");
            for (_, _, central) in &res {
                central.validate().unwrap_or_else(|e| panic!("p={p}: {e}"));
                assert_eq!(central.n(), seq.graph.n(), "p={p}");
                assert_eq!(central.m(), seq.graph.m(), "p={p}");
                assert_eq!(central.total_vwgt(), seq.graph.total_vwgt(), "p={p}");
                // Anchors are the last two vertices in both layouts.
                let na = central.n();
                assert_eq!(central.vwgt[na - 2], seq.graph.vwgt[seq.anchor0], "p={p}");
                assert_eq!(central.vwgt[na - 1], seq.graph.vwgt[seq.anchor1], "p={p}");
            }
        }
    }

    #[test]
    fn band_labels_and_origins_are_consistent() {
        let (nx, ny) = (12, 12);
        let g = Arc::new(generators::grid2d(nx, ny));
        let full = thick_column_part(nx, ny);
        let (ok, _) = comm::run(3, move |c| {
            let dg = DGraph::from_global(&c, &g);
            let part: Vec<u8> = (0..dg.nloc())
                .map(|v| full[dg.glb(v) as usize])
                .collect();
            let dist = band_distances(&c, &dg, &part, 2);
            let band = extract_dband(&c, &dg, &part, &dist);
            // Every local band vertex carries its parent label, and the
            // anchors (last rank only) carry P0/P1.
            let mut ok = band.part.len() == band.dg.nloc();
            for (i, &pv) in band.orig_local.iter().enumerate() {
                ok &= band.part[i] == part[pv];
                ok &= dist[pv] != u32::MAX;
            }
            if c.rank() == c.size() - 1 {
                let nl = band.dg.nloc();
                ok &= nl == band.nloc_band() + 2;
                ok &= band.part[nl - 2] == P0 && band.part[nl - 1] == P1;
            } else {
                ok &= band.dg.nloc() == band.nloc_band();
            }
            ok
        });
        assert!(ok.iter().all(|&x| x));
    }
}
