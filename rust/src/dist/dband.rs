//! Distributed band-graph extraction (paper §3.3, scalable regime).
//!
//! The multi-sequential refinement of [`crate::dist::dsep`] centralizes
//! the band around the projected separator on every rank — fine while
//! bands are small, but a scalability cliff once they are not. This
//! module extracts the same width-`w` band as a [`DGraph`] *in its own
//! right*, so the diffusion kernel of [`crate::dist::ddiffusion`] can
//! refine it in place without ever centralizing:
//!
//! * band membership comes from a distributed multi-source BFS from the
//!   separator ([`band_distances`] — the distributed analog of
//!   [`crate::graph::Graph::multi_source_bfs`]), **frontier-driven**:
//!   each level exchanges only the frontier's boundary membership
//!   ([`DGraph::halo_frontier`], a few bytes per crossing vertex) and
//!   relaxes only frontier neighbors, instead of shipping and
//!   rescanning the full distance vector. [`bfs_band_dist_engine`]
//!   alternatively runs the levels as fused min-plus relaxations of the
//!   AOT-compiled artifact on each rank's packed slice
//!   ([`crate::runtime::pack_ell_dist`]), with the same collectively
//!   agreed verdict and CPU fallback ladder as the diffusion engine
//!   dispatch (DESIGN.md §4.2);
//! * survivors are renumbered into a fresh contiguous global range by
//!   an exclusive scan of per-rank counts, exactly like
//!   [`crate::dist::induce::induce_dist`];
//! * the two discarded sides are replaced by **two anchor vertices**
//!   appended to the last rank's block, carrying the excluded part
//!   weights and the collapsed boundary arcs — the same anchor
//!   construction as the sequential [`crate::sep::band::extract_band`],
//!   distributed.

use super::ddiffusion::{agree_engine, AUTO_XLA_MIN_BAND};
use super::dgraph::DGraph;
use crate::comm::Comm;
use crate::runtime::{ell_minplus_reference, pack_ell_dist, EllPacked, SharedRuntime, MINPLUS_INF};
use crate::sep::{P0, P1, SEP};
use crate::strategy::BandEngine;

/// A distributed band graph: the band as a [`DGraph`] whose last two
/// global vertices are the locked anchors, plus the bookkeeping needed
/// to commit refined labels back to the parent graph.
#[derive(Clone, Debug)]
pub struct DistBand {
    /// The band graph (fresh contiguous global ids; the two anchors are
    /// the last two global vertices, owned by the last rank).
    pub dg: DGraph,
    /// Parent-graph *local* index of each local band vertex, in band
    /// local order (anchors excluded — they map to no parent vertex).
    pub orig_local: Vec<usize>,
    /// Part labels ([`P0`]/[`P1`]/[`SEP`]) of the local band vertices,
    /// including the anchors on the last rank (anchor 0 is [`P0`],
    /// anchor 1 is [`P1`]).
    pub part: Vec<u8>,
    /// Number of non-anchor band vertices globally.
    pub band_nglb: u64,
}

impl DistBand {
    /// Global id of the part-0 anchor.
    #[inline]
    pub fn anchor0_gid(&self) -> u64 {
        self.band_nglb
    }

    /// Global id of the part-1 anchor.
    #[inline]
    pub fn anchor1_gid(&self) -> u64 {
        self.band_nglb + 1
    }

    /// Whether a band-graph global id is one of the two locked anchors.
    #[inline]
    pub fn is_anchor_gid(&self, gid: u64) -> bool {
        gid >= self.band_nglb
    }

    /// Number of local band vertices owned by this rank, anchors
    /// excluded.
    #[inline]
    pub fn nloc_band(&self) -> usize {
        self.orig_local.len()
    }
}

/// Distributed multi-source BFS from the separator of `part`, capped at
/// `width` levels — the scalar CPU engine, **frontier-driven**: each
/// level exchanges only the frontier membership of boundary vertices
/// ([`DGraph::halo_frontier`], one `u32` per crossing vertex instead of
/// one value per ghost) and relaxes only the neighbors of frontier
/// vertices, local and ghost, through a ghost→local reverse adjacency
/// built once per call. No full-vector clone, no full-row rescan per
/// level. Returns one distance per local vertex (`u32::MAX` outside the
/// band), identical to the level-synchronous scan it replaces.
/// Collective.
pub fn band_distances(comm: &Comm, dg: &DGraph, part: &[u8], width: u32) -> Vec<u32> {
    let nloc = dg.nloc();
    debug_assert_eq!(part.len(), nloc);
    let mut dist: Vec<u32> = part
        .iter()
        .map(|&x| if x == SEP { 0 } else { u32::MAX })
        .collect();

    // Ghost→local reverse adjacency (CSR over ghost slots): the local
    // vertices a remote frontier vertex can relax. Built in one O(m)
    // pass; ghost rows themselves store no adjacency.
    let ngst = dg.ghosts.len();
    let mut rev_off = vec![0usize; ngst + 1];
    for &a in &dg.adj {
        if a as usize >= nloc {
            rev_off[a as usize - nloc + 1] += 1;
        }
    }
    for i in 0..ngst {
        rev_off[i + 1] += rev_off[i];
    }
    let mut rev = vec![0u32; rev_off[ngst]];
    let mut cursor = rev_off.clone();
    for v in 0..nloc {
        for &a in dg.neighbors_gst(v) {
            let a = a as usize;
            if a >= nloc {
                rev[cursor[a - nloc]] = v as u32;
                cursor[a - nloc] += 1;
            }
        }
    }

    let mut frontier: Vec<u32> = (0..nloc as u32).filter(|&v| dist[v as usize] == 0).collect();
    let mut in_frontier = vec![false; nloc];
    for level in 0..width {
        // Publish this level's frontier; learn which ghosts are remote
        // frontier. Every rank runs all `width` levels even with an
        // empty frontier — the exchange is collective.
        for &v in &frontier {
            in_frontier[v as usize] = true;
        }
        let ghost_front = dg.halo_frontier(comm, &in_frontier);
        for &v in &frontier {
            in_frontier[v as usize] = false;
        }
        let mut next: Vec<u32> = Vec::new();
        for &v in &frontier {
            for &a in dg.neighbors_gst(v as usize) {
                let a = a as usize;
                if a < nloc && dist[a] == u32::MAX {
                    dist[a] = level + 1;
                    next.push(a as u32);
                }
            }
        }
        for &gs in &ghost_front {
            for &v in &rev[rev_off[gs as usize]..rev_off[gs as usize + 1]] {
                if dist[v as usize] == u32::MAX {
                    dist[v as usize] = level + 1;
                    next.push(v);
                }
            }
        }
        frontier = next;
    }
    dist
}

/// One rank's slice of the parent graph packed for the min-plus
/// artifact: the ELL block plus the `f32` distance vector laid out
/// `[local | ghost | padding]`. Ghost rows are packed empty, so the
/// kernel leaves them at the boundary values `refresh_ghosts` re-fills
/// from each halo exchange. Shared by the XLA execution path and the
/// offline equivalence test, so the production assembly is exercised
/// without artifacts.
struct MinPlusSlice {
    /// The `(n, d)` ELL block ([`pack_ell_dist`], no clamped rows —
    /// min-plus has no anchors; empty rows keep their value natively).
    ell: EllPacked,
    /// Distances, `[local | ghosts | padding]`; [`MINPLUS_INF`] marks
    /// unreached.
    x: Vec<f32>,
}

/// Pack this rank's slice for fused min-plus levels: separator vertices
/// at distance 0, everything else (ghosts and padding included) at
/// [`MINPLUS_INF`]. Returns `None` when the slice fits no `(n, d)`
/// block — the caller then falls back to the CPU frontier BFS on
/// **every** rank (the fit verdict is agreed collectively).
fn pack_bfs_slice(dg: &DGraph, part: &[u8], n: usize, d: usize) -> Option<MinPlusSlice> {
    let ell = pack_ell_dist(dg, n, d, &[])?;
    let mut x = vec![MINPLUS_INF; n];
    for (v, &pv) in part.iter().enumerate() {
        if pv == SEP {
            x[v] = 0.0;
        }
    }
    Some(MinPlusSlice { ell, x })
}

impl MinPlusSlice {
    /// Write freshly exchanged ghost boundary distances into the slots
    /// `nloc..nloc + ngst`.
    fn refresh_ghosts(&mut self, nloc: usize, ghost_x: &[f32]) {
        self.x[nloc..nloc + ghost_x.len()].copy_from_slice(ghost_x);
    }

    /// Freeze relaxation beyond the band: computed values past `width`
    /// go back to [`MINPLUS_INF`] on the local slots. Distances ≤
    /// `width` are unaffected (a shortest path to a vertex at distance
    /// ≤ width only passes through smaller distances), while deep
    /// local propagation — which fused levels would otherwise run past
    /// the cap — stops changing, so the fixpoint test below converges
    /// within `width` exchange rounds.
    fn clamp_beyond(&mut self, nloc: usize, width: u32) {
        for xv in &mut self.x[..nloc] {
            if *xv > width as f32 {
                *xv = MINPLUS_INF;
            }
        }
    }
}

/// Convert a converged min-plus field back to the BFS contract:
/// exact distances ≤ `width`, `u32::MAX` outside the band.
fn minplus_to_dist(x: &[f32], width: u32) -> Vec<u32> {
    x.iter()
        .map(|&xv| if xv <= width as f32 { xv as u32 } else { u32::MAX })
        .collect()
}

/// Per-rank XLA execution of the band BFS (DESIGN.md §4.2 applied to
/// the min-plus kernel): pack this rank's slice of the parent graph
/// into the smallest fitting min-plus bucket, then alternate halo
/// exchanges of the distance field with `width` fused min-plus levels
/// per call, ghost rows acting as fixed boundary values. Each exchange
/// round guarantees at least one synchronous BFS level of global
/// progress, so `width` rounds suffice for exactness; the
/// `clamp_beyond` freeze lets the collectively agreed fixpoint test
/// stop earlier when the band converges before that.
/// Returns `None` — on **every** rank, the fit verdict is collective —
/// when some rank's slice fits no bucket. Collective.
fn xla_levels(
    comm: &Comm,
    dg: &DGraph,
    part: &[u8],
    width: u32,
    rt: &SharedRuntime,
) -> Option<Vec<u32>> {
    let nloc = dg.nloc();
    let ngst = dg.ghosts.len();
    let d_real = (0..nloc)
        .map(|v| dg.neighbors_gst(v).len())
        .max()
        .unwrap_or(0);
    // Never hold the runtime lock across a collective: rank threads
    // share one mutex, and a holder waiting in an allreduce would
    // deadlock against a peer waiting on the lock.
    let bucket = {
        let guard = rt.lock().unwrap();
        guard.0.fit_minplus(nloc + ngst, d_real)
    };
    let packed = bucket.and_then(|b| pack_bfs_slice(dg, part, b.n, b.d));
    let fits = comm.allreduce(packed.is_some(), |a, b| a && b);
    let (bucket, mut s) = match (fits, bucket, packed) {
        (true, Some(b), Some(s)) => (b, s),
        _ => return None, // some rank missed every bucket → CPU everywhere
    };

    for _ in 0..width {
        let ghost_x = dg.halo_exchange(comm, &s.x[..nloc]);
        s.refresh_ghosts(nloc, &ghost_x);
        let before = s.x[..nloc].to_vec();
        for _ in 0..width {
            let step = {
                let guard = rt.lock().unwrap();
                guard.0.minplus_step(bucket, &s.x, &s.ell)
            };
            s.x = match step {
                Ok(next) => next,
                // A mid-run PJRT failure must not desynchronize the
                // agreed exchange cadence — substitute the
                // bit-equivalent pure-Rust reference of the same call
                // and stay in lockstep.
                Err(_) => ell_minplus_reference(&s.ell, &s.x),
            };
        }
        s.clamp_beyond(nloc, width);
        // Collective fixpoint test: when no rank changed a (clamped)
        // local value this round, another exchange would reproduce the
        // same inputs — the capped region is exact, stop early.
        let changed = s.x[..nloc] != before[..];
        if !comm.allreduce(changed, |a, b| a || b) {
            break;
        }
    }
    Some(minplus_to_dist(&s.x[..nloc], width))
}

/// Engine-dispatching variant of [`band_distances`]: run the BFS levels
/// on the engine `engine` selects, falling back down the same ladder as
/// the diffusion dispatch (per-rank fused min-plus artifact → CPU
/// frontier BFS) whenever the runtime is absent or some rank's slice
/// fits no min-plus bucket, with the verdict agreed by allreduce before
/// any engine-specific collective runs
/// ([`super::ddiffusion::diffuse_band_dist_engine`]'s contract).
/// [`BandEngine::Auto`] gates on this rank's packed slice size (local
/// plus ghost rows) reaching [`AUTO_XLA_MIN_BAND`] — one bucket row
/// block, below which per-call dispatch overhead dominates; the
/// allreduce inside [`super::ddiffusion::agree_engine`] turns the
/// per-rank verdicts into "every rank's slice is worth it", mirroring
/// how the bucket-fit verdict is agreed. Returns the distances plus
/// whether the XLA engine actually executed; the distances are
/// identical to [`band_distances`] on every path. Collective.
pub fn bfs_band_dist_engine(
    comm: &Comm,
    dg: &DGraph,
    part: &[u8],
    width: u32,
    engine: BandEngine,
    rt: Option<&SharedRuntime>,
) -> (Vec<u32>, bool) {
    let slice_rows = (dg.nloc() + dg.ghosts.len()) as u64;
    let use_xla = agree_engine(comm, engine, rt.is_some(), slice_rows >= AUTO_XLA_MIN_BAND);
    if use_xla {
        if let Some(d) = xla_levels(comm, dg, part, width, rt.expect("agreed runtime")) {
            return (d, true);
        }
        // Collective fit miss: every rank got None; fall through to CPU.
    }
    (band_distances(comm, dg, part, width), false)
}

/// Extract the distributed band graph of vertices whose `dist` (from
/// [`band_distances`]) is finite. Arcs leaving the band are collapsed
/// onto the anchor of the band endpoint's part — the outside endpoint
/// has the same part, since every vertex within `width ≥ 1` of the
/// separator is in the band and parts only touch through the separator.
/// Collective; every rank must pass the same global `part`/`dist`
/// semantics (each rank its own slice).
pub fn extract_dband(comm: &Comm, dg: &DGraph, part: &[u8], dist: &[u32]) -> DistBand {
    let p = comm.size();
    let nloc = dg.nloc();
    debug_assert_eq!(part.len(), nloc);
    debug_assert_eq!(dist.len(), nloc);

    let kept: Vec<usize> = (0..nloc).filter(|&v| dist[v] != u32::MAX).collect();

    // Fresh contiguous global numbering of the band vertices; the two
    // anchors extend the last rank's block.
    let counts = comm.allgatherv(vec![kept.len() as u64]);
    let mut vtx = vec![0u64; p + 1];
    for r in 0..p {
        vtx[r + 1] = vtx[r] + counts[r][0];
    }
    let band_nglb = vtx[p];
    vtx[p] += 2;
    let anchor_gid = [band_nglb, band_nglb + 1];

    let nbase = vtx[comm.rank()];
    let mut newid: Vec<u64> = vec![u64::MAX; nloc];
    for (i, &v) in kept.iter().enumerate() {
        newid[v] = nbase + i as u64;
    }
    // New ids of the parent graph's ghosts (MAX when outside the band).
    let ghost_newid = dg.halo_exchange(comm, &newid);

    // Anchor weights: the total excluded weight per part (≥ 1 to keep
    // the positive-weight invariant when a whole part fits in the band).
    let mut excl = [0i64; 2];
    for v in 0..nloc {
        if dist[v] == u32::MAX {
            // Outside the band ⇒ not SEP (separator vertices have
            // distance 0), so the label indexes a real part.
            excl[part[v] as usize] += dg.vwgt[v];
        }
    }
    let excl_g = comm.allreduce(excl, |a, b| [a[0] + b[0], a[1] + b[1]]);

    // Band rows; boundary arcs collapse per vertex onto one anchor arc.
    let mut vwgt: Vec<i64> = kept.iter().map(|&v| dg.vwgt[v]).collect();
    let mut band_part: Vec<u8> = kept.iter().map(|&v| part[v]).collect();
    let mut rows: Vec<Vec<(u64, i64)>> = Vec::with_capacity(kept.len());
    // Reciprocal arcs the anchors owe this rank's boundary vertices,
    // encoded as `[band_gid, anchor_index, weight]` triples.
    let mut anchor_arcs: Vec<u64> = Vec::new();
    for (i, &v) in kept.iter().enumerate() {
        let mut row: Vec<(u64, i64)> = Vec::with_capacity(dg.neighbors_gst(v).len());
        let mut to_anchor = 0i64;
        for (&a, &w) in dg.neighbors_gst(v).iter().zip(dg.edge_weights_gst(v)) {
            let a = a as usize;
            let nid = if a < nloc {
                newid[a]
            } else {
                ghost_newid[a - nloc]
            };
            if nid != u64::MAX {
                row.push((nid, w));
            } else {
                to_anchor += w;
            }
        }
        if to_anchor > 0 {
            // A boundary vertex is never SEP (distance 0 vertices keep
            // all neighbors within width ≥ 1), so its part picks the
            // anchor directly.
            let side = band_part[i] as usize;
            row.push((anchor_gid[side], to_anchor));
            anchor_arcs.push(nbase + i as u64);
            anchor_arcs.push(side as u64);
            anchor_arcs.push(to_anchor as u64);
        }
        rows.push(row);
    }

    // The last rank owns the anchors: it alone needs the boundary
    // contributions for the two reciprocal anchor rows, so gather them
    // point-to-point (the `centralize_root` pattern) instead of
    // replicating O(boundary) triples on every rank.
    const TAG: u64 = 0xDBA2;
    if comm.rank() != p - 1 {
        comm.send(p - 1, TAG, anchor_arcs);
    } else {
        let mut row0: Vec<(u64, i64)> = Vec::new();
        let mut row1: Vec<(u64, i64)> = Vec::new();
        let mut mine = Some(anchor_arcs);
        for r in 0..p {
            let b: Vec<u64> = if r == p - 1 {
                mine.take().expect("own contributions")
            } else {
                comm.recv(r, TAG)
            };
            for t in b.chunks_exact(3) {
                let arc = (t[0], t[2] as i64);
                if t[1] == 0 {
                    row0.push(arc);
                } else {
                    row1.push(arc);
                }
            }
        }
        vwgt.push(excl_g[0].max(1));
        vwgt.push(excl_g[1].max(1));
        band_part.push(P0);
        band_part.push(P1);
        rows.push(row0);
        rows.push(row1);
    }

    DistBand {
        dg: DGraph::from_rows(comm, vtx, vwgt, rows),
        orig_local: kept,
        part: band_part,
        band_nglb,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm;
    use crate::graph::generators;
    use crate::sep::band::extract_band;
    use crate::sep::SepState;
    use std::sync::Arc;

    /// The shared 2-thick column-separator fixture, centered.
    fn thick_column_part(nx: usize, ny: usize) -> Vec<u8> {
        generators::column_separator_part(nx, ny, nx / 2, 2)
    }

    #[test]
    fn distances_match_sequential_bfs() {
        let (nx, ny) = (17, 11);
        let g = Arc::new(generators::grid2d(nx, ny));
        let gref = g.clone();
        let full = thick_column_part(nx, ny);
        let fref = full.clone();
        for p in [2usize, 3, 4] {
            let g = g.clone();
            let full = full.clone();
            let (res, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let part: Vec<u8> = (0..dg.nloc())
                    .map(|v| full[dg.glb(v) as usize])
                    .collect();
                let d = band_distances(&c, &dg, &part, 3);
                (dg.base(), d)
            });
            let seps: Vec<usize> = (0..gref.n()).filter(|&v| fref[v] == SEP).collect();
            let want = gref.multi_source_bfs(&seps, 3);
            for (base, d) in &res {
                for (i, &di) in d.iter().enumerate() {
                    assert_eq!(di, want[*base as usize + i], "p={p} v={}", *base as usize + i);
                }
            }
        }
    }

    #[test]
    fn bfs_engine_dispatch_without_runtime_matches_frontier_bfs() {
        // Offline (xla-stub / no artifacts) there is no runtime handle:
        // every engine setting must take the CPU frontier BFS and
        // produce distances identical to calling `band_distances`
        // directly, with the verdict agreed by allreduce.
        let (nx, ny) = (15, 13);
        let g = Arc::new(generators::grid2d(nx, ny));
        let full = thick_column_part(nx, ny);
        for p in [2usize, 3] {
            for engine in [BandEngine::Auto, BandEngine::Cpu, BandEngine::Xla] {
                let g = g.clone();
                let full = full.clone();
                let (ok, _) = comm::run(p, move |c| {
                    let dg = DGraph::from_global(&c, &g);
                    let part: Vec<u8> = (0..dg.nloc())
                        .map(|v| full[dg.glb(v) as usize])
                        .collect();
                    let want = band_distances(&c, &dg, &part, 3);
                    let (got, used_xla) = bfs_band_dist_engine(&c, &dg, &part, 3, engine, None);
                    !used_xla && got == want
                });
                assert!(ok.iter().all(|&x| x), "p={p} engine={engine:?}");
            }
        }
    }

    #[test]
    fn packed_minplus_reference_matches_frontier_bfs() {
        // The numeric core of the per-rank XLA BFS path, without
        // artifacts: the *production* slice assembly (`pack_bfs_slice`
        // + `refresh_ghosts` + `clamp_beyond`, exactly what
        // `xla_levels` runs) driven by the min-plus reference in the
        // same exchange/fixpoint cadence must reproduce the CPU
        // frontier BFS exactly.
        let (nx, ny) = (17, 12);
        let g = Arc::new(generators::grid2d(nx, ny));
        let full = thick_column_part(nx, ny);
        for p in [1usize, 2, 4] {
            for width in [1u32, 2, 3] {
                let g = g.clone();
                let full = full.clone();
                let (ok, _) = comm::run(p, move |c| {
                    let dg = DGraph::from_global(&c, &g);
                    let part: Vec<u8> = (0..dg.nloc())
                        .map(|v| full[dg.glb(v) as usize])
                        .collect();
                    let want = band_distances(&c, &dg, &part, width);
                    let nloc = dg.nloc();
                    let ngst = dg.ghosts.len();
                    let d = (0..nloc)
                        .map(|v| dg.neighbors_gst(v).len())
                        .max()
                        .unwrap_or(0);
                    let mut s = pack_bfs_slice(&dg, &part, nloc + ngst + 2, d).unwrap();
                    for _ in 0..width {
                        let ghost_x = dg.halo_exchange(&c, &s.x[..nloc]);
                        s.refresh_ghosts(nloc, &ghost_x);
                        let before = s.x[..nloc].to_vec();
                        for _ in 0..width {
                            s.x = ell_minplus_reference(&s.ell, &s.x);
                        }
                        s.clamp_beyond(nloc, width);
                        let changed = s.x[..nloc] != before[..];
                        if !c.allreduce(changed, |a, b| a || b) {
                            break;
                        }
                    }
                    minplus_to_dist(&s.x[..nloc], width) == want
                });
                assert!(ok.iter().all(|&x| x), "p={p} width={width}");
            }
        }
    }

    #[test]
    fn dband_matches_sequential_band_graph() {
        // The centralized distributed band must be isomorphic (same
        // sizes, same total weight, same anchor weights) to the
        // sequential extraction from the same projection.
        let (nx, ny) = (16, 9);
        let g = Arc::new(generators::grid2d(nx, ny));
        let gref = g.clone();
        let full = thick_column_part(nx, ny);
        let fref = full.clone();
        let width = 3u32;
        for p in [2usize, 4] {
            let g = g.clone();
            let full = full.clone();
            let (res, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let part: Vec<u8> = (0..dg.nloc())
                    .map(|v| full[dg.glb(v) as usize])
                    .collect();
                let dist = band_distances(&c, &dg, &part, width);
                let band = extract_dband(&c, &dg, &part, &dist);
                let central = band.dg.centralize_all(&c);
                (band.band_nglb, band.nloc_band(), central)
            });
            let state = SepState::from_parts(&gref, fref.clone());
            let seq = extract_band(&gref, &state, width).unwrap();
            let nb: usize = res.iter().map(|(_, nl, _)| nl).sum();
            assert_eq!(nb as u64, res[0].0, "p={p}");
            assert_eq!(nb, seq.band_n(), "p={p}");
            for (_, _, central) in &res {
                central.validate().unwrap_or_else(|e| panic!("p={p}: {e}"));
                assert_eq!(central.n(), seq.graph.n(), "p={p}");
                assert_eq!(central.m(), seq.graph.m(), "p={p}");
                assert_eq!(central.total_vwgt(), seq.graph.total_vwgt(), "p={p}");
                // Anchors are the last two vertices in both layouts.
                let na = central.n();
                assert_eq!(central.vwgt[na - 2], seq.graph.vwgt[seq.anchor0], "p={p}");
                assert_eq!(central.vwgt[na - 1], seq.graph.vwgt[seq.anchor1], "p={p}");
            }
        }
    }

    #[test]
    fn band_labels_and_origins_are_consistent() {
        let (nx, ny) = (12, 12);
        let g = Arc::new(generators::grid2d(nx, ny));
        let full = thick_column_part(nx, ny);
        let (ok, _) = comm::run(3, move |c| {
            let dg = DGraph::from_global(&c, &g);
            let part: Vec<u8> = (0..dg.nloc())
                .map(|v| full[dg.glb(v) as usize])
                .collect();
            let dist = band_distances(&c, &dg, &part, 2);
            let band = extract_dband(&c, &dg, &part, &dist);
            // Every local band vertex carries its parent label, and the
            // anchors (last rank only) carry P0/P1.
            let mut ok = band.part.len() == band.dg.nloc();
            for (i, &pv) in band.orig_local.iter().enumerate() {
                ok &= band.part[i] == part[pv];
                ok &= dist[pv] != u32::MAX;
            }
            if c.rank() == c.size() - 1 {
                let nl = band.dg.nloc();
                ok &= nl == band.nloc_band() + 2;
                ok &= band.part[nl - 2] == P0 && band.part[nl - 1] == P1;
            } else {
                ok &= band.dg.nloc() == band.nloc_band();
            }
            ok
        });
        assert!(ok.iter().all(|&x| x));
    }
}
