//! Distributed CSR graphs with ghost/halo indexing (paper §3.1).
//!
//! A [`DGraph`] is the PT-Scotch distributed graph structure: each rank
//! owns a contiguous block of the global vertex range (recorded in
//! `vtxdist`, exactly like the ParMETIS convention the paper's authors
//! interoperate with) and stores its local adjacency in *ghost* ("gst")
//! indexing — arc targets `< nloc` are local vertices, targets `≥ nloc`
//! address the `ghosts` table of remote neighbors. The paper's
//! halo-exchange primitive (§3.1: "a copy of the ghost vertices' data is
//! maintained on every neighboring process") is [`DGraph::halo_exchange`];
//! arbitrary remote reads (used by uncoarsening projection, §3.2) are
//! [`DGraph::fetch_at`].
//!
//! The halo update is a *persistent* communication structure: which of a
//! rank's vertices are ghosted on which neighbor is fixed the moment
//! `ghosts`/`vtxdist` are, so the exchange schedule ([`HaloPlan`]) is
//! derived **once per graph** — one collective want-list round at
//! construction — and every subsequent [`DGraph::halo_exchange`] is a
//! single data `alltoallv` with no per-call request wave and no per-call
//! want-list allocation (DESIGN.md §3.1).
//!
//! All collective methods must be called by every rank of the
//! communicator the graph lives on, in the same order — the same
//! contract as the MPI code they model.

use crate::comm::Comm;
use crate::trace;
use crate::graph::Graph;

/// Precomputed halo-exchange schedule of one [`DGraph`] (DESIGN.md
/// §3.1): for every peer rank, the local indices this rank must send
/// (owner side) and the number of ghost slots it will receive (ghost
/// side). Invariants:
///
/// * `send_idx[r]` lists this rank's local vertices ghosted on rank
///   `r`, **in the order rank `r`'s ghost table lists them** — ghosts
///   are sorted ascending and this rank's block is contiguous, so that
///   order is ascending local index;
/// * `recv_counts[r]` is the size of this rank's ghost sub-block owned
///   by rank `r`; the blocks are contiguous and ascend with `r`, so
///   concatenating the received vectors in rank order *is* the ghost
///   order — no scatter pass needed;
/// * ranks are those of the communicator the plan was built on; after a
///   [`Comm::split`], a plan built through the parent communicator with
///   the target-relative rank mapping (see `fold_half`) stays valid on
///   the sub-communicator, whose re-ranking is exactly that mapping.
#[derive(Clone, Debug)]
pub struct HaloPlan {
    /// Per peer rank: local indices whose values this rank sends.
    send_idx: Vec<Vec<u32>>,
    /// Per peer rank: number of ghost values received (ghost sub-block
    /// sizes, in rank order).
    recv_counts: Vec<usize>,
}

impl HaloPlan {
    /// Build the schedule with one collective want-list round: each
    /// rank tells every owner which global ids it ghosts, and owners
    /// record the matching local indices. `comm` spans the (possibly
    /// larger) rank set actually communicating — graph rank `r` maps to
    /// comm rank `start + r`, which is how `fold_half` builds plans for
    /// a target sub-range through the parent communicator before the
    /// `Comm::split` that re-ranks exactly along that mapping. Ranks
    /// without a block of the graph (fold non-members) pass `graph:
    /// None`, contribute empty want lists and get `None` back.
    /// Collective over `comm`.
    pub(crate) fn build(
        comm: &Comm,
        start: usize,
        vtxdist: &[u64],
        graph: Option<(usize, &[u64])>,
    ) -> Option<HaloPlan> {
        let t = vtxdist.len() - 1;
        let p = comm.size();
        debug_assert!(start + t <= p);
        let mut want: Vec<Vec<u64>> = vec![Vec::new(); p];
        let mut recv_counts = vec![0usize; t];
        if let Some((_, ghosts)) = graph {
            for &g in ghosts {
                let o = vtxdist.partition_point(|&b| b <= g) - 1;
                want[start + o].push(g);
            }
            for (r, c) in recv_counts.iter_mut().enumerate() {
                *c = want[start + r].len();
            }
        }
        let reqs = comm.alltoallv(want);
        graph.map(|(rank, _)| {
            let base = vtxdist[rank];
            let send_idx = (0..t)
                .map(|r| reqs[start + r].iter().map(|&g| (g - base) as u32).collect())
                .collect();
            HaloPlan {
                send_idx,
                recv_counts,
            }
        })
    }

    /// Local indices sent to rank `r`, in rank `r`'s ghost order.
    #[inline]
    pub fn send_indices(&self, r: usize) -> &[u32] {
        &self.send_idx[r]
    }

    /// Number of ghost values received from rank `r`.
    #[inline]
    pub fn recv_count(&self, r: usize) -> usize {
        self.recv_counts[r]
    }

    /// Approximate heap footprint of the schedule in bytes.
    pub fn footprint_bytes(&self) -> usize {
        self.send_idx
            .iter()
            .map(|v| v.len() * std::mem::size_of::<u32>())
            .sum::<usize>()
            + self.recv_counts.len() * std::mem::size_of::<usize>()
    }
}

/// A distributed graph: one rank's block of a globally numbered CSR
/// graph, plus the ghost table addressing remote neighbors.
///
/// Invariants:
/// * rank `r` owns global ids `vtxdist[r] .. vtxdist[r + 1]` (contiguous
///   blocks, ascending with rank), so `glb(v) = base() + v`;
/// * `ghosts` is sorted ascending and deduplicated — consequently ghost
///   entries grouped by owner appear in ascending-rank order, which
///   [`DGraph::halo_exchange`] exploits;
/// * `adj` stores gst indices: `a < nloc()` is local vertex `a`, and
///   `a ≥ nloc()` is remote vertex `ghosts[a - nloc()]`.
#[derive(Clone, Debug)]
pub struct DGraph {
    /// Global-range boundaries per rank; length `p + 1`, `vtxdist[0] == 0`.
    pub vtxdist: Vec<u64>,
    /// This rank's index into `vtxdist` (its rank in the owning comm).
    pub rank: usize,
    /// Total number of global vertices (`vtxdist[p]`).
    pub nglb: u64,
    /// Local adjacency offsets; length `nloc() + 1`.
    pub xadj: Vec<usize>,
    /// Arc targets in gst indexing (local index or `nloc + ghost index`).
    pub adj: Vec<u32>,
    /// Local vertex weights.
    pub vwgt: Vec<i64>,
    /// Edge weights parallel to `adj`.
    pub ewgt: Vec<i64>,
    /// Global ids of ghost vertices, sorted ascending.
    pub ghosts: Vec<u64>,
    /// Persistent halo-exchange schedule. Always present on graphs
    /// returned by the constructors; `Option` only stages construction
    /// in `fold_half`, where the plan is built through the parent
    /// communicator after assembly.
    plan: Option<HaloPlan>,
}

impl DGraph {
    /// Number of local (owned) vertices.
    #[inline]
    pub fn nloc(&self) -> usize {
        self.vwgt.len()
    }

    /// First global id owned by this rank.
    #[inline]
    pub fn base(&self) -> u64 {
        self.vtxdist[self.rank]
    }

    /// Global id of local vertex `v`.
    #[inline]
    pub fn glb(&self, v: usize) -> u64 {
        self.base() + v as u64
    }

    /// Owning rank of global id `g` (binary search over `vtxdist`).
    #[inline]
    pub fn owner(&self, g: u64) -> usize {
        debug_assert!(g < self.nglb);
        self.vtxdist.partition_point(|&b| b <= g) - 1
    }

    /// Neighbor list of local vertex `v` in gst indexing.
    #[inline]
    pub fn neighbors_gst(&self, v: usize) -> &[u32] {
        &self.adj[self.xadj[v]..self.xadj[v + 1]]
    }

    /// Edge weights parallel to [`DGraph::neighbors_gst`].
    #[inline]
    pub fn edge_weights_gst(&self, v: usize) -> &[i64] {
        &self.ewgt[self.xadj[v]..self.xadj[v + 1]]
    }

    /// Global id of a gst index (local or ghost).
    #[inline]
    pub fn gst_to_glb(&self, a: u32) -> u64 {
        let a = a as usize;
        if a < self.nloc() {
            self.glb(a)
        } else {
            self.ghosts[a - self.nloc()]
        }
    }

    /// The persistent halo-exchange schedule of this graph.
    #[inline]
    pub fn halo_plan(&self) -> &HaloPlan {
        self.plan.as_ref().expect("halo plan built at construction")
    }

    /// Install the halo plan built for this graph (the `fold_half`
    /// staging step; every other constructor builds it inline).
    pub(crate) fn set_plan(&mut self, plan: HaloPlan) {
        self.plan = Some(plan);
    }

    /// Approximate heap footprint in bytes, for the per-rank memory
    /// tracking that reproduces Figures 10–11.
    pub fn footprint_bytes(&self) -> usize {
        self.vtxdist.len() * std::mem::size_of::<u64>()
            + self.xadj.len() * std::mem::size_of::<usize>()
            + self.adj.len() * std::mem::size_of::<u32>()
            + self.vwgt.len() * std::mem::size_of::<i64>()
            + self.ewgt.len() * std::mem::size_of::<i64>()
            + self.ghosts.len() * std::mem::size_of::<u64>()
            + self.plan.as_ref().map_or(0, HaloPlan::footprint_bytes)
    }

    /// Assemble a `DGraph` from per-local-vertex rows of
    /// `(neighbor global id, edge weight)` pairs and build its halo
    /// plan with the one collective want-list round of
    /// [`HaloPlan::build`]. `vwgt.len()` must equal the size of this
    /// rank's `vtxdist` block. Collective.
    pub(crate) fn from_rows(
        comm: &Comm,
        vtxdist: Vec<u64>,
        vwgt: Vec<i64>,
        rows: Vec<Vec<(u64, i64)>>,
    ) -> DGraph {
        debug_assert_eq!(comm.size() + 1, vtxdist.len());
        let mut dg = DGraph::assemble(vtxdist, comm.rank(), vwgt, rows);
        let plan = HaloPlan::build(comm, 0, &dg.vtxdist, Some((dg.rank, dg.ghosts.as_slice())))
            .expect("every rank owns a block");
        dg.set_plan(plan);
        dg
    }

    /// The communication-free part of [`DGraph::from_rows`]: build the
    /// ghost table and gst-indexed adjacency, leaving the halo plan
    /// unset. `fold_half` uses this to stage target-member graphs
    /// before the plan round on the parent communicator.
    pub(crate) fn assemble(
        vtxdist: Vec<u64>,
        rank: usize,
        vwgt: Vec<i64>,
        rows: Vec<Vec<(u64, i64)>>,
    ) -> DGraph {
        let nglb = *vtxdist.last().expect("vtxdist non-empty");
        let base = vtxdist[rank];
        let nloc = vwgt.len();
        debug_assert_eq!(nloc as u64, vtxdist[rank + 1] - base);
        debug_assert_eq!(rows.len(), nloc);
        let local = |g: u64| g >= base && g < base + nloc as u64;
        let mut ghosts: Vec<u64> = rows
            .iter()
            .flatten()
            .map(|&(g, _)| g)
            .filter(|&g| !local(g))
            .collect();
        ghosts.sort_unstable();
        ghosts.dedup();
        let mut xadj = Vec::with_capacity(nloc + 1);
        xadj.push(0usize);
        let mut adj = Vec::new();
        let mut ewgt = Vec::new();
        for row in &rows {
            for &(g, w) in row {
                let idx = if local(g) {
                    (g - base) as u32
                } else {
                    (nloc + ghosts.binary_search(&g).expect("ghost registered")) as u32
                };
                adj.push(idx);
                ewgt.push(w);
            }
            xadj.push(adj.len());
        }
        DGraph {
            vtxdist,
            rank,
            nglb,
            xadj,
            adj,
            vwgt,
            ewgt,
            ghosts,
            plan: None,
        }
    }

    /// Block-distribute a centralized graph over the communicator: rank
    /// `r` of `p` owns global ids `⌊r·n/p⌋ .. ⌊(r+1)·n/p⌋` (§3.1). Every
    /// rank calls this with the same `g`.
    pub fn from_global(comm: &Comm, g: &Graph) -> DGraph {
        let p = comm.size();
        let n = g.n() as u64;
        let vtxdist: Vec<u64> = (0..=p).map(|r| n * r as u64 / p as u64).collect();
        let rank = comm.rank();
        let base = vtxdist[rank] as usize;
        let nloc = (vtxdist[rank + 1] - vtxdist[rank]) as usize;
        let vwgt: Vec<i64> = (0..nloc).map(|v| g.vwgt[base + v]).collect();
        let rows: Vec<Vec<(u64, i64)>> = (0..nloc)
            .map(|v| {
                g.neighbors(base + v)
                    .iter()
                    .zip(g.edge_weights(base + v))
                    .map(|(&u, &w)| (u as u64, w))
                    .collect()
            })
            .collect();
        DGraph::from_rows(comm, vtxdist, vwgt, rows)
    }

    /// Exchange one value per ghost vertex with the owners (§3.1's halo
    /// update). `vals` holds this rank's local values; the result is
    /// parallel to [`DGraph::ghosts`]. Runs on the precomputed
    /// [`HaloPlan`]: exactly **one** data `alltoallv` per call — owners
    /// already know what to send, so there is no request wave and no
    /// per-call want-list allocation. Collective.
    pub fn halo_exchange<T: Clone + Send + 'static>(&self, comm: &Comm, vals: &[T]) -> Vec<T> {
        let _span = trace::scope(trace::Phase::Halo);
        debug_assert_eq!(vals.len(), self.nloc());
        let plan = self.halo_plan();
        debug_assert_eq!(plan.send_idx.len(), comm.size());
        let out: Vec<Vec<T>> = plan
            .send_idx
            .iter()
            .map(|idx| idx.iter().map(|&v| vals[v as usize].clone()).collect())
            .collect();
        // Received blocks land in rank order = ghost order (plan
        // invariant), so concatenation is the whole scatter.
        comm.alltoallv(out).concat()
    }

    /// Sparse companion of [`DGraph::halo_exchange`] for frontier
    /// algorithms: publish only the *membership* of local vertices in
    /// `in_frontier` and learn which **ghost indices** are frontier on
    /// their owner. On the wire each boundary frontier vertex costs one
    /// `u32` (its position in the owner's send list) instead of every
    /// ghost costing a full value — the level-by-level exchange of the
    /// frontier-driven band BFS (`dist::dband::band_distances`).
    /// Collective.
    pub fn halo_frontier(&self, comm: &Comm, in_frontier: &[bool]) -> Vec<u32> {
        let _span = trace::scope(trace::Phase::Halo);
        debug_assert_eq!(in_frontier.len(), self.nloc());
        let plan = self.halo_plan();
        debug_assert_eq!(plan.send_idx.len(), comm.size());
        let out: Vec<Vec<u32>> = plan
            .send_idx
            .iter()
            .map(|idx| {
                idx.iter()
                    .enumerate()
                    .filter(|&(_, &v)| in_frontier[v as usize])
                    .map(|(j, _)| j as u32)
                    .collect()
            })
            .collect();
        let got = comm.alltoallv(out);
        // Position j in rank r's send list is ghost slot off_r + j:
        // send lists are parallel to this rank's per-owner ghost blocks.
        let mut res = Vec::new();
        let mut off = 0u32;
        for (r, js) in got.into_iter().enumerate() {
            res.extend(js.into_iter().map(|j| off + j));
            off += plan.recv_counts[r] as u32;
        }
        res
    }

    /// Fetch `vals[local(idx[k])]` from the owner of each global id in
    /// `idx` (remote reads for uncoarsening projection, §3.2). `vals` is
    /// this rank's local value array; the result is parallel to `idx`.
    /// Unlike the halo, the queried ids are call-specific, so the
    /// request wave cannot be precomputed — but replies scatter straight
    /// into the output through the per-owner position lists, with no
    /// intermediate `Option` staging. Collective — ranks with empty
    /// `idx` still participate.
    pub fn fetch_at<T: Clone + Default + Send + 'static>(
        &self,
        comm: &Comm,
        idx: &[u64],
        vals: &[T],
    ) -> Vec<T> {
        debug_assert_eq!(vals.len(), self.nloc());
        let p = comm.size();
        let mut want: Vec<Vec<u64>> = vec![Vec::new(); p];
        let mut pos: Vec<Vec<usize>> = vec![Vec::new(); p];
        for (k, &g) in idx.iter().enumerate() {
            let o = self.owner(g);
            want[o].push(g);
            pos[o].push(k);
        }
        let reqs = comm.alltoallv(want);
        let base = self.base();
        let reply: Vec<Vec<T>> = reqs
            .iter()
            .map(|ids| {
                ids.iter()
                    .map(|&g| vals[(g - base) as usize].clone())
                    .collect()
            })
            .collect();
        let got = comm.alltoallv(reply);
        // Every k ∈ 0..idx.len() appears in exactly one position list,
        // so full-length replies imply each slot is written exactly
        // once (moves, not clones). The per-owner length check keeps
        // the old "every queried id answered" guarantee in release
        // builds — a short reply must panic, not leave defaults behind.
        let mut out: Vec<T> = vec![T::default(); idx.len()];
        for (r, vals_r) in got.into_iter().enumerate() {
            assert_eq!(vals_r.len(), pos[r].len(), "rank {r} answered short");
            for (&k, v) in pos[r].iter().zip(vals_r) {
                out[k] = v;
            }
        }
        out
    }

    /// Append local vertex `v`'s adjacency row to a wire blob as
    /// `[deg, (nbr_glb, weight)*deg]` — the one row encoding shared by
    /// every serializer in the `dist` layer (centralize, fold, band
    /// gather), so the stride arithmetic lives in a single place.
    pub(crate) fn encode_row(&self, v: usize, blob: &mut Vec<u64>) {
        let row = self.neighbors_gst(v);
        blob.push(row.len() as u64);
        for (&a, &w) in row.iter().zip(self.edge_weights_gst(v)) {
            blob.push(self.gst_to_glb(a));
            blob.push(w as u64);
        }
    }

    /// This rank's centralization blob: for each local v,
    /// `[vwgt, deg, (nbr_glb, w)*deg]`.
    fn central_blob(&self) -> Vec<u64> {
        let mut blob: Vec<u64> = Vec::new();
        for v in 0..self.nloc() {
            blob.push(self.vwgt[v] as u64);
            self.encode_row(v, &mut blob);
        }
        blob
    }

    /// Decode rank-ordered centralization blobs into a [`Graph`]. Ranks
    /// own ascending contiguous blocks, so concatenating the blobs in
    /// rank order yields the global vertex order.
    fn decode_central(n: usize, all: &[Vec<u64>]) -> Graph {
        let mut xadj = Vec::with_capacity(n + 1);
        xadj.push(0usize);
        let mut adj: Vec<u32> = Vec::new();
        let mut vwgt = Vec::with_capacity(n);
        let mut ewgt: Vec<i64> = Vec::new();
        for b in all {
            let mut i = 0usize;
            while i < b.len() {
                vwgt.push(b[i] as i64);
                let deg = b[i + 1] as usize;
                i += 2;
                for _ in 0..deg {
                    adj.push(b[i] as u32);
                    ewgt.push(b[i + 1] as i64);
                    i += 2;
                }
                xadj.push(adj.len());
            }
        }
        debug_assert_eq!(vwgt.len(), n);
        Graph {
            xadj,
            adj,
            vwgt,
            ewgt,
        }
    }

    /// Gather the whole distributed graph on **every** rank as a
    /// centralized [`Graph`] indexed by global id — the terminal state of
    /// folding-with-duplication (§3.2), where each process holds a full
    /// copy of the (small) coarsest graph. Collective.
    pub fn centralize_all(&self, comm: &Comm) -> Graph {
        let all = comm.allgatherv(self.central_blob());
        Self::decode_central(self.nglb as usize, &all)
    }

    /// Like [`DGraph::centralize_all`], but only `root` reconstructs the
    /// graph — the single-working-copy mode of the comparator and the
    /// `folddup=0` ablation (§3.2). A true gather-to-root: non-roots
    /// send their blob point-to-point and return `None`, so the traffic
    /// telemetry shows the (cheaper) no-duplication communication
    /// pattern instead of a broadcast-everywhere. Collective.
    pub fn centralize_root(&self, comm: &Comm, root: usize) -> Option<Graph> {
        const TAG: u64 = 0xCE27;
        let blob = self.central_blob();
        if comm.rank() != root {
            comm.send(root, TAG, blob);
            return None;
        }
        let p = comm.size();
        let mut mine = Some(blob);
        let mut all: Vec<Vec<u64>> = Vec::with_capacity(p);
        for r in 0..p {
            if r == root {
                all.push(mine.take().expect("own blob"));
            } else {
                all.push(comm.recv(r, TAG));
            }
        }
        Some(Self::decode_central(self.nglb as usize, &all))
    }

    /// Reinterpret a single-rank distributed graph (no ghosts) as a
    /// centralized [`Graph`] — used when the nested-dissection recursion
    /// bottoms out on a one-rank communicator (§3.1).
    pub fn to_local(&self) -> Graph {
        debug_assert!(
            self.ghosts.is_empty(),
            "to_local requires a fully local graph"
        );
        Graph {
            xadj: self.xadj.clone(),
            adj: self.adj.clone(),
            vwgt: self.vwgt.clone(),
            ewgt: self.ewgt.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm;
    use crate::graph::generators;
    use std::sync::Arc;

    #[test]
    fn global_local_index_inversion() {
        let g = Arc::new(generators::grid2d(9, 7));
        let (res, _) = comm::run(4, move |c| {
            let dg = DGraph::from_global(&c, &g);
            // glb/base/owner must invert each other on every local id.
            for v in 0..dg.nloc() {
                let gid = dg.glb(v);
                assert_eq!(gid, dg.base() + v as u64);
                assert_eq!(dg.owner(gid), c.rank());
            }
            // Ghost table is sorted, deduplicated and strictly remote.
            for w in dg.ghosts.windows(2) {
                assert!(w[0] < w[1]);
            }
            for &gh in &dg.ghosts {
                assert_ne!(dg.owner(gh), c.rank());
            }
            dg.nloc()
        });
        assert_eq!(res.iter().sum::<usize>(), 63);
    }

    #[test]
    fn halo_exchange_roundtrip_returns_ghost_ids() {
        // Publishing each vertex's own global id through the halo must
        // hand every rank exactly its ghost table back.
        let g = Arc::new(generators::grid3d(5, 4, 3));
        for p in [2usize, 3, 5] {
            let g = g.clone();
            let (ok, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let mine: Vec<u64> = (0..dg.nloc()).map(|v| dg.glb(v)).collect();
                let got = dg.halo_exchange(&c, &mine);
                got == dg.ghosts
            });
            assert!(ok.iter().all(|&x| x), "p={p}");
        }
    }

    #[test]
    fn centralize_all_reconstructs_original() {
        let g = Arc::new(generators::irregular_mesh(8, 6, 3));
        let gref = g.clone();
        let (res, _) = comm::run(3, move |c| {
            let dg = DGraph::from_global(&c, &g);
            dg.centralize_all(&c)
        });
        for central in &res {
            central.validate().unwrap();
            assert_eq!(central.xadj, gref.xadj);
            assert_eq!(central.adj, gref.adj);
            assert_eq!(central.vwgt, gref.vwgt);
            assert_eq!(central.ewgt, gref.ewgt);
        }
    }

    #[test]
    fn fetch_at_reads_remote_values() {
        let g = Arc::new(generators::grid2d(10, 3));
        let (ok, _) = comm::run(3, move |c| {
            let dg = DGraph::from_global(&c, &g);
            // Every rank asks for vertex weights scattered over all ranks.
            let idx: Vec<u64> = (0..dg.nglb).step_by(3).collect();
            let vals: Vec<i64> = (0..dg.nloc()).map(|v| dg.glb(v) as i64 * 10).collect();
            let got = dg.fetch_at(&c, &idx, &vals);
            got.iter()
                .zip(&idx)
                .all(|(&gv, &i)| gv == i as i64 * 10)
        });
        assert!(ok.iter().all(|&x| x));
    }

    #[test]
    fn halo_exchange_is_one_alltoallv_per_call() {
        // The HaloPlan acceptance check: construction pays exactly one
        // want-list alltoallv, and every halo_exchange after it exactly
        // one data alltoallv — (p-1) messages per rank each, nothing
        // else on the wire.
        let g = Arc::new(generators::grid2d(12, 9));
        for p in [2usize, 4] {
            let g = g.clone();
            let calls = 7u64;
            let (_, stats) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let vals: Vec<u64> = (0..dg.nloc()).map(|v| dg.glb(v)).collect();
                for _ in 0..calls {
                    let got = dg.halo_exchange(&c, &vals);
                    assert_eq!(got, dg.ghosts);
                }
            });
            let per_a2av = (p * (p - 1)) as u64;
            assert_eq!(stats.total_msgs(), (calls + 1) * per_a2av, "p={p}");
        }
    }

    #[test]
    fn halo_plan_schedule_invariants() {
        let g = Arc::new(generators::irregular_mesh(9, 8, 5));
        let (ok, _) = comm::run(4, move |c| {
            let dg = DGraph::from_global(&c, &g);
            let plan = dg.halo_plan();
            // Receive blocks tile the ghost table exactly.
            let mut ok = (0..4).map(|r| plan.recv_count(r)).sum::<usize>() == dg.ghosts.len();
            for r in 0..4 {
                // Send lists address local vertices, strictly ascending
                // (the order the peer's sorted ghost table lists this
                // rank's contiguous block), and never this rank itself.
                let idx = plan.send_indices(r);
                ok &= idx.windows(2).all(|w| w[0] < w[1]);
                ok &= idx.iter().all(|&v| (v as usize) < dg.nloc());
                ok &= r != c.rank() || idx.is_empty();
                ok &= r != c.rank() || plan.recv_count(r) == 0;
            }
            ok
        });
        assert!(ok.iter().all(|&x| x));
    }

    #[test]
    fn halo_frontier_reports_remote_frontier_ghosts() {
        // Publishing an arbitrary membership must hand back exactly the
        // ghost indices whose owner vertex is a member, ascending.
        let g = Arc::new(generators::grid3d(5, 4, 3));
        for p in [2usize, 3, 5] {
            let g = g.clone();
            let (ok, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let member = |gid: u64| gid % 3 == 0;
                let flags: Vec<bool> = (0..dg.nloc()).map(|v| member(dg.glb(v))).collect();
                let got = dg.halo_frontier(&c, &flags);
                let want: Vec<u32> = (0..dg.ghosts.len() as u32)
                    .filter(|&i| member(dg.ghosts[i as usize]))
                    .collect();
                got == want
            });
            assert!(ok.iter().all(|&x| x), "p={p}");
        }
    }

    #[test]
    fn single_rank_to_local_matches_source() {
        let g = Arc::new(generators::grid2d(6, 6));
        let gref = g.clone();
        let (res, _) = comm::run(1, move |c| {
            let dg = DGraph::from_global(&c, &g);
            assert!(dg.ghosts.is_empty());
            dg.to_local()
        });
        assert_eq!(res[0].xadj, gref.xadj);
        assert_eq!(res[0].adj, gref.adj);
    }
}
