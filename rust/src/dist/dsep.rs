//! Distributed vertex-separator computation (paper §3.2–§3.3).
//!
//! The PT-Scotch separator pipeline, as opposed to the ParMETIS-like
//! comparator in [`crate::baseline`]:
//!
//! 1. **distributed coarsening** with parallel probabilistic matching
//!    until the graph has at most `folddup_threshold` vertices per
//!    process (§3.2, default 100);
//! 2. **folding with duplication** taken to its terminal state: every
//!    rank receives a full copy of the coarsest graph
//!    ([`crate::dist::dgraph::DGraph::centralize_all`]) and runs the
//!    sequential multilevel separator on it with a decorrelated seed;
//!    the best result by [`crate::sep::SepState::quality_key`] wins
//!    (§3.2: independent multilevel runs "increase the final quality"
//!    — disabled by `folddup=0`, which degrades to a single rank-0
//!    working copy like the comparator);
//! 3. **uncoarsening with band refinement** (§3.3): at every level the
//!    projected separator is surrounded by a distributed band of width
//!    `band_width` ([`crate::dist::dband`]). Small bands (global size at
//!    most `max_centralized_band`) are centralized on every rank with
//!    two anchor vertices standing for the excluded parts, each rank
//!    refines its copy with a different seed, and the best refined band
//!    — if it beats the projection — is committed back to the
//!    distributed part array. Larger bands are refined **in place** by
//!    the distributed diffusion kernel ([`crate::dist::ddiffusion`]) —
//!    no band is ever left as an unrefined projection.

use super::coarsen::{coarsen_dist, DistCoarsening};
use super::dband::{bfs_band_dist_engine, extract_dband};
use super::ddiffusion::{diffuse_band_dist_engine, dist_quality_key, DIST_DIFFUSION_DAMPING};
use super::dgraph::DGraph;
use super::matching::parallel_match;
use crate::comm::{Comm, MemTracker};
use crate::graph::GraphBuilder;
use crate::rng::Rng;
use crate::runtime::SharedRuntime;
use crate::sep::band::BandGraph;
use crate::sep::{multilevel_separator, refine_band_with_mode, BandRefiner, SepState, P0, P1, SEP};
use crate::strategy::{SepStrategy, Strategy};
use crate::trace;
use std::collections::HashMap;

/// Compute a vertex separator of the distributed graph; returns one
/// part label ([`P0`]/[`P1`]/[`SEP`]) per local vertex. Collective.
/// `rng` is a shared root — per-phase streams are derived from it mixed
/// with the global rank, so sibling subgroups and ranks stay
/// decorrelated while the whole run remains reproducible (§4).
/// `xla` is the optional shared XLA runtime handle forwarded to the
/// distributed band-diffusion engine dispatch (DESIGN.md §4.2).
#[allow(clippy::too_many_arguments)]
pub fn dist_separator(
    comm: &Comm,
    dg: &DGraph,
    strat: &Strategy,
    refiner: &dyn BandRefiner,
    xla: Option<&SharedRuntime>,
    rng: &Rng,
    mem: &MemTracker,
) -> Vec<u8> {
    let p = comm.size();
    let grank = comm.global_rank() as u64;
    if p == 1 {
        let local = dg.to_local();
        let mut r = rng.derive(0x5E0 ^ grank);
        return multilevel_separator(&local, &strat.sep, refiner, &mut r).part;
    }

    // Phase 1: distributed coarsening (§3.2). The fine graph of level
    // `i` is `dg` itself for i = 0 and `coarse_graphs[i - 1]` after —
    // each level's graph is stored exactly once.
    let stop_at = (strat.dist.folddup_threshold * p).max(2 * strat.sep.coarse_target) as u64;
    let mut coarse_graphs: Vec<DGraph> = Vec::new();
    let mut maps: Vec<Vec<u64>> = Vec::new();
    loop {
        let fine: &DGraph = coarse_graphs.last().unwrap_or(dg);
        if fine.nglb <= stop_at {
            break;
        }
        let round = coarse_graphs.len() as u64;
        let mut r = rng.derive(0xC0A2 ^ (round << 16) ^ grank);
        let mate = {
            let _span = trace::scope(trace::Phase::Match);
            parallel_match(comm, fine, strat.dist.matching_rounds, &mut r)
        };
        let DistCoarsening { coarse, fine2coarse } = {
            let _span = trace::scope(trace::Phase::Coarsen);
            coarsen_dist(comm, fine, &mate)
        };
        if coarse.nglb as f64 > fine.nglb as f64 * 0.95 {
            break; // matching stalled (near-clique); stop coarsening
        }
        mem.grow(coarse.footprint_bytes());
        coarse_graphs.push(coarse);
        maps.push(fine2coarse);
    }

    // Phase 2: multi-sequential initial separator on the duplicated
    // coarsest graph (§3.2's fold-with-duplication endpoint).
    let coarsest: &DGraph = coarse_graphs.last().unwrap_or(dg);
    let init_span = trace::scope(trace::Phase::InitialSep);
    let seps: Vec<u8> = if strat.dist.fold_dup {
        let central = coarsest.centralize_all(comm);
        mem.grow(central.footprint_bytes());
        let mut r = rng.derive(0xD00D ^ grank);
        let s = multilevel_separator(&central, &strat.sep, refiner, &mut r);
        mem.shrink(central.footprint_bytes());
        best_pick(comm, s.quality_key(), s.part)
    } else {
        // Ablation A3 / comparator mode: one working copy on rank 0 —
        // non-roots feed the gather but skip the reconstruction.
        match coarsest.centralize_root(comm, 0) {
            Some(central) => {
                mem.grow(central.footprint_bytes());
                let mut r = rng.derive(0xD00D);
                let s = multilevel_separator(&central, &strat.sep, refiner, &mut r);
                mem.shrink(central.footprint_bytes());
                comm.bcast(0, Some(s.part))
            }
            None => comm.bcast(0, None),
        }
    };
    let mut part: Vec<u8> = (0..coarsest.nloc())
        .map(|v| seps[coarsest.glb(v) as usize])
        .collect();
    drop(init_span);

    // Phase 3: uncoarsen, refining on distributed band graphs (§3.3).
    for li in (0..maps.len()).rev() {
        let coarse = &coarse_graphs[li];
        let fine: &DGraph = if li == 0 { dg } else { &coarse_graphs[li - 1] };
        let coarse_part = part;
        part = {
            let _span = trace::scope(trace::Phase::ProjectSep);
            coarse.fetch_at(comm, &maps[li], &coarse_part)
        };
        band_refine_dist(
            comm,
            fine,
            &mut part,
            strat,
            refiner,
            xla,
            &rng.derive(0xBA2D ^ li as u64),
            mem,
        );
    }
    for g in &coarse_graphs {
        mem.shrink(g.footprint_bytes());
    }
    debug_assert!(dist_validate_separator(comm, dg, &part));
    part
}

/// Check the distributed separator invariant — no edge (local or
/// crossing a rank boundary) joins a [`P0`] vertex to a [`P1`] vertex,
/// and all labels are in range. Collective; returns the global verdict
/// on every rank.
pub fn dist_validate_separator(comm: &Comm, dg: &DGraph, part: &[u8]) -> bool {
    let nloc = dg.nloc();
    let mut ok = part.len() == nloc;
    if ok {
        let ghost_part = dg.halo_exchange(comm, part);
        'outer: for v in 0..nloc {
            if part[v] > SEP {
                ok = false;
                break;
            }
            if part[v] == SEP {
                continue;
            }
            for &a in dg.neighbors_gst(v) {
                let a = a as usize;
                let pu = if a < nloc {
                    part[a]
                } else {
                    ghost_part[a - nloc]
                };
                if pu != SEP && pu != part[v] {
                    ok = false;
                    break 'outer;
                }
            }
        }
    } else {
        // Keep the collective call pattern aligned across ranks.
        let _ = dg.halo_exchange(comm, &vec![0u8; nloc]);
    }
    comm.allreduce(ok, |a, b| a && b)
}

/// Pick the globally best `(quality key, part vector)` among the ranks'
/// candidates: minimal key, ties to the lowest rank. Collective.
fn best_pick(comm: &Comm, key: (i64, i64), part: Vec<u8>) -> Vec<u8> {
    let keys = comm.allgatherv(vec![key]);
    let winner = (0..comm.size())
        .min_by_key(|&r| (keys[r][0], r))
        .expect("at least one rank");
    if comm.rank() == winner {
        comm.bcast(winner, Some(part))
    } else {
        comm.bcast(winner, None)
    }
}

/// One band refinement step during uncoarsening (§3.3): extract the
/// distributed band of vertices within `band_width` of the separator,
/// then refine it — **multi-sequentially** on centralized copies when
/// the band is small enough (at most `max_centralized_band` vertices
/// globally), or **in place** with the distributed diffusion kernel
/// when it is not (executed per rank on the XLA runtime `xla` when the
/// `engine=` strategy knob and the bucket fit allow it — see
/// `dist::ddiffusion::diffuse_band_dist_engine`). Either way the result
/// is committed only when it strictly beats the projection, so the
/// separator never degrades. Collective.
#[allow(clippy::too_many_arguments)]
pub fn band_refine_dist(
    comm: &Comm,
    dg: &DGraph,
    part: &mut [u8],
    strat: &Strategy,
    refiner: &dyn BandRefiner,
    xla: Option<&SharedRuntime>,
    rng: &Rng,
    mem: &MemTracker,
) {
    // Umbrella span for the whole §3.3 step: the centralized path's
    // gather/refine/commit traffic lands here when no inner span is
    // open, so the profile never loses band-refinement bytes.
    let _span = trace::scope(trace::Phase::BandRefine);
    let nloc = dg.nloc();
    let width = strat.sep.band_width;

    // Pre-gate: an empty separator (disconnected oddity) has no band.
    let sep_total =
        comm.allreduce_sum(part.iter().filter(|&&x| x == SEP).count() as i64) as usize;
    if sep_total == 0 {
        return;
    }

    // Distributed multi-source BFS from the separator, capped at
    // `width`: frontier-driven on the CPU engine (one sparse frontier
    // exchange per level), or fused min-plus levels of the AOT artifact
    // per rank when the `engine=` knob and the bucket fit allow it —
    // the verdict is collective, like the diffusion dispatch below.
    let (dist, _used_xla) = {
        let _span = trace::scope(trace::Phase::BandExtract);
        bfs_band_dist_engine(comm, dg, part, width, strat.dist.band_engine, xla)
    };

    // Gate on the global band size *before* shipping any adjacency:
    // small bands take the centralized multi-sequential path, large
    // bands the scalable distributed diffusion path.
    let band: Vec<usize> = (0..nloc).filter(|&v| dist[v] != u32::MAX).collect();
    let global_band = comm.allreduce_sum(band.len() as i64) as usize;
    if global_band > strat.dist.max_centralized_band {
        let _span = trace::scope(trace::Phase::RefineDiffusion);
        band_refine_diffusion_dist(comm, dg, part, strat, xla, mem, &dist);
        return;
    }
    band_refine_centralized(comm, dg, part, &strat.sep, refiner, rng, mem, &band, &dist);
}

/// Scalable band refinement (§3.3 taken to large bands): extract the
/// band as a distributed graph in its own right, run the diffusion
/// kernel on it with halo exchanges of the scalar field — per rank on
/// the XLA runtime when the engine dispatch allows, scalar CPU sweeps
/// otherwise — and commit the recovered separator when it strictly
/// beats the projection. This is the path that replaces the old "keep
/// the projection" fallback for bands exceeding `max_centralized_band`.
/// Collective.
fn band_refine_diffusion_dist(
    comm: &Comm,
    dg: &DGraph,
    part: &mut [u8],
    strat: &Strategy,
    xla: Option<&SharedRuntime>,
    mem: &MemTracker,
    dist: &[u32],
) {
    let band = extract_dband(comm, dg, part, dist);
    let footprint = band.dg.footprint_bytes();
    mem.grow(footprint);
    let before = dist_quality_key(comm, &band.dg, &band.part);
    let (refined, _used_xla) = diffuse_band_dist_engine(
        comm,
        &band,
        strat.dist.diffusion_sweeps,
        DIST_DIFFUSION_DAMPING,
        strat.dist.band_engine,
        xla,
    );
    // Distributed repair/validation pass: the cover is valid by
    // construction, but a refinement that cannot be proven valid (or
    // does not strictly beat the projection) is discarded — the
    // projection itself is always a valid state to keep.
    let valid = dist_validate_separator(comm, &band.dg, &refined);
    let after = dist_quality_key(comm, &band.dg, &refined);
    mem.shrink(footprint);
    if !valid || after >= before {
        return;
    }
    for (i, &pv) in band.orig_local.iter().enumerate() {
        part[pv] = refined[i];
    }
}

/// Multi-sequential band refinement on small bands (§3.3): centralize
/// the band on every rank with anchor vertices standing for the
/// excluded parts, refine every copy with a decorrelated seed under the
/// `refine=` mode dispatch (so each rank also competes the
/// deterministic flow cut against its seeded FM/diffusion result when
/// the mode allows), and commit the best strictly-improving result.
/// Collective.
#[allow(clippy::too_many_arguments)]
fn band_refine_centralized(
    comm: &Comm,
    dg: &DGraph,
    part: &mut [u8],
    sep_strat: &SepStrategy,
    refiner: &dyn BandRefiner,
    rng: &Rng,
    mem: &MemTracker,
    band: &[usize],
    dist: &[u32],
) {
    let nloc = dg.nloc();

    // Serialize this rank's band slice:
    // [nband, excl0, excl1, then per band vertex:
    //  gid, part, vwgt, deg, (nbr_gid, w)*deg].
    let mut excl = [0i64; 2];
    for v in 0..nloc {
        if dist[v] == u32::MAX {
            // Outside the band ⇒ not SEP (separator vertices have
            // distance 0), so the label indexes a real part.
            excl[part[v] as usize] += dg.vwgt[v];
        }
    }
    let mut blob: Vec<u64> = vec![band.len() as u64, excl[0] as u64, excl[1] as u64];
    for &v in band {
        blob.push(dg.glb(v));
        blob.push(part[v] as u64);
        blob.push(dg.vwgt[v] as u64);
        dg.encode_row(v, &mut blob);
    }
    let all = comm.allgatherv(blob);

    // First pass: the global band vertex list, in rank order (every
    // rank reconstructs the identical band graph).
    let mut gids: Vec<u64> = Vec::new();
    let mut parts: Vec<u8> = Vec::new();
    let mut vws: Vec<i64> = Vec::new();
    let mut excl_g = [0i64; 2];
    for b in &all {
        let nb = b[0] as usize;
        excl_g[0] += b[1] as i64;
        excl_g[1] += b[2] as i64;
        let mut i = 3usize;
        for _ in 0..nb {
            gids.push(b[i]);
            parts.push(b[i + 1] as u8);
            vws.push(b[i + 2] as i64);
            let deg = b[i + 3] as usize;
            i += 4 + 2 * deg;
        }
    }
    let nb = gids.len();
    let idx: HashMap<u64, u32> = gids
        .iter()
        .enumerate()
        .map(|(i, &g)| (g, i as u32))
        .collect();

    // Second pass: edges. In-band pairs are added once (lower index
    // side); arcs leaving the band attach to the anchor of the band
    // vertex's part — the outside endpoint has the same part, since
    // every vertex within `width ≥ 1` of the separator is in the band
    // and parts only touch through the separator.
    let anchor0 = nb;
    let anchor1 = nb + 1;
    let mut builder = GraphBuilder::new(nb + 2);
    for (k, &w) in vws.iter().enumerate() {
        builder.set_vwgt(k, w);
    }
    builder.set_vwgt(anchor0, excl_g[0].max(1));
    builder.set_vwgt(anchor1, excl_g[1].max(1));
    let mut k = 0usize;
    for b in &all {
        let nbr = b[0] as usize;
        let mut i = 3usize;
        for _ in 0..nbr {
            let deg = b[i + 3] as usize;
            for e in 0..deg {
                let t = b[i + 4 + 2 * e];
                let w = b[i + 5 + 2 * e] as i64;
                match idx.get(&t) {
                    Some(&j) if (j as usize) > k => builder.add_edge_w(k, j as usize, w),
                    Some(_) => {} // added from the lower-index side
                    None => {
                        let a = if parts[k] == P0 { anchor0 } else { anchor1 };
                        builder.add_edge_w(k, a, w);
                    }
                }
            }
            i += 4 + 2 * deg;
            k += 1;
        }
    }
    let graph = builder.build().expect("band graph is structurally valid");
    mem.grow(graph.footprint_bytes());
    let mut band_part = parts.clone();
    band_part.push(P0);
    band_part.push(P1);
    let state = SepState::from_parts(&graph, band_part);
    let before = state.quality_key();
    let mut locked = vec![false; nb + 2];
    locked[anchor0] = true;
    locked[anchor1] = true;
    let footprint = graph.footprint_bytes();
    let mut bg = BandGraph {
        graph,
        orig: gids.iter().map(|&g| g as usize).collect(),
        anchor0,
        anchor1,
        state,
        locked,
    };

    // Multi-sequential refinement: every rank refines the same band
    // with a different seed; the best strictly-improving copy wins. The
    // `refine=` dispatch layers the flow candidate on top per rank —
    // flow is deterministic, so it adds no collective traffic and
    // preserves the sim ≡ threads bit-identity.
    let mut r = rng.derive(0xF17 ^ comm.global_rank() as u64);
    refine_band_with_mode(&mut bg, refiner, sep_strat, &mut r);
    debug_assert!(bg.state.validate(&bg.graph).is_ok());
    let keys = comm.allgatherv(vec![bg.state.quality_key()]);
    let winner = (0..comm.size())
        .min_by_key(|&rk| (keys[rk][0], rk))
        .expect("at least one rank");
    let wkey = keys[winner][0];
    mem.shrink(footprint);
    if wkey >= before {
        return; // nobody beat the projected separator
    }
    let labels: Vec<u8> = if comm.rank() == winner {
        comm.bcast(winner, Some(bg.state.part[..nb].to_vec()))
    } else {
        comm.bcast(winner, None)
    };
    let base = dg.base();
    for (i, &gid) in gids.iter().enumerate() {
        if gid >= base && gid < base + nloc as u64 {
            part[(gid - base) as usize] = labels[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm;
    use crate::graph::generators;
    use crate::sep::FmRefiner;
    use crate::strategy::DistStrategy;
    use std::sync::Arc;

    #[test]
    fn separator_valid_and_balanced_on_grid() {
        let g = Arc::new(generators::grid2d(20, 20));
        let gref = g.clone();
        for p in [2usize, 4] {
            let g = g.clone();
            let (res, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let strat = Strategy::default();
                let refiner = FmRefiner::default();
                let rng = Rng::new(1);
                let mem = MemTracker::new();
                let part = dist_separator(&c, &dg, &strat, &refiner, None, &rng, &mem);
                assert!(dist_validate_separator(&c, &dg, &part));
                (dg.base(), part)
            });
            let mut full = vec![0u8; gref.n()];
            for (b, lp) in &res {
                for (i, &x) in lp.iter().enumerate() {
                    full[*b as usize + i] = x;
                }
            }
            let state = SepState::from_parts(&gref, full);
            state.validate(&gref).unwrap();
            assert!(state.wgts[0] > 0 && state.wgts[1] > 0, "p={p}: empty side");
            // A 20×20 grid separates with ~20–35 vertices at this scale.
            assert!(
                state.sep_weight() <= 60,
                "p={p}: separator weight {}",
                state.sep_weight()
            );
        }
    }

    #[test]
    fn oversized_band_is_diffusion_refined_not_kept() {
        // The acceptance case for the scalable path: on a 64×64 grid
        // with `max_centralized_band` forced tiny, the old code kept the
        // projection untouched; the diffusion path must now produce a
        // valid separator no larger than the projected one — and
        // actually shrink this deliberately 2-thick projection.
        let (nx, ny) = (64usize, 64usize);
        let g = Arc::new(generators::grid2d(nx, ny));
        let proj = generators::column_separator_part(nx, ny, nx / 2, 2);
        for p in [4usize, 5] {
            let g = g.clone();
            let proj = proj.clone();
            let (res, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let mut part: Vec<u8> = (0..dg.nloc())
                    .map(|v| proj[dg.glb(v) as usize])
                    .collect();
                let strat = Strategy {
                    dist: DistStrategy {
                        max_centralized_band: 8, // band is ~8·64 ≫ 8
                        ..DistStrategy::default()
                    },
                    ..Strategy::default()
                };
                let refiner = FmRefiner::default();
                let rng = Rng::new(3);
                let mem = MemTracker::new();
                band_refine_dist(&c, &dg, &mut part, &strat, &refiner, None, &rng, &mem);
                let valid = dist_validate_separator(&c, &dg, &part);
                let sep_now =
                    c.allreduce_sum(part.iter().filter(|&&x| x == SEP).count() as i64);
                (valid, sep_now)
            });
            for &(valid, sep_now) in &res {
                assert!(valid, "p={p}: refined separator invalid");
                assert!(sep_now <= 2 * ny as i64, "p={p}: separator grew to {sep_now}");
                assert!(sep_now > 0, "p={p}: separator vanished");
            }
            // The 2-thick projection (128 vertices) must actually shrink.
            assert!(
                res[0].1 < 2 * ny as i64,
                "p={p}: diffusion did not improve the projection ({})",
                res[0].1
            );
        }
    }

    #[test]
    fn validate_rejects_crossing_edge() {
        let g = Arc::new(generators::path(6, 1));
        let (ok, _) = comm::run(2, move |c| {
            let dg = DGraph::from_global(&c, &g);
            // P0 | P1 split with no separator: the 2–3 edge crosses.
            let part: Vec<u8> = (0..dg.nloc())
                .map(|v| if dg.glb(v) < 3 { P0 } else { P1 })
                .collect();
            dist_validate_separator(&c, &dg, &part)
        });
        assert!(ok.iter().all(|&x| !x));
    }
}
