//! The distributed layer (S10–S16): PT-Scotch's parallel ordering
//! algorithms on the in-process rank fleet of [`crate::comm`].
//!
//! This module mirrors the paper's MPI code structure one-to-one
//! (DESIGN.md §4):
//!
//! * [`dgraph`] — distributed CSR graphs with contiguous per-rank
//!   blocks, ghost/halo indexing and the halo-exchange / remote-fetch /
//!   centralize primitives (§3.1). The halo update runs on a
//!   **persistent exchange schedule** ([`dgraph::HaloPlan`]) derived
//!   once at construction, so every exchange is a single data
//!   `alltoallv` (DESIGN.md §3.1);
//! * [`matching`] — parallel probabilistic heavy-edge matching via
//!   mutual proposals (§3.2/§4.2);
//! * [`coarsen`] — distributed coarsening along a matching, with
//!   owner-routed edge merging (§3.2);
//! * [`fold`] — folding onto either half of the rank range, for any
//!   rank count; the building block of folding-with-duplication (§3.2);
//! * [`induce`] — distributed induced subgraphs with payload carrying,
//!   optionally built two-at-a-time by an overlap thread (§3.1); the
//!   halo variant ([`induce::induce_dist_halo`]) additionally keeps
//!   each side's one-ring of already-numbered separator vertices as
//!   flagged halo members ([`induce::HALO_BIT`]) for halo-aware leaf
//!   ordering;
//! * [`dband`] — distributed band-graph extraction: the width-`w` band
//!   around a projected separator as a [`dgraph::DGraph`] in its own
//!   right, with two anchor vertices standing for the excluded parts
//!   (§3.3). Band membership comes from a frontier-driven distributed
//!   BFS, or from fused min-plus levels of the AOT artifact per rank
//!   ([`dband::bfs_band_dist_engine`], the same `engine=` dispatch as
//!   the diffusion sweeps);
//! * [`ddiffusion`] — the diffusion kernel on distributed bands: local
//!   Jacobi sweeps interleaved with halo exchanges of the scalar field,
//!   then a sign-change scan and a distributed separator-recovery cover
//!   (§3.3/§5) — the scalable refinement used when a band is too large
//!   to centralize. Sweeps execute on the scalar CPU path or, per rank,
//!   on the AOT-compiled XLA diffusion kernel over the local band slice
//!   (`engine=` knob; [`crate::runtime::pack_ell_dist`], DESIGN.md
//!   §4.2);
//! * [`dsep`] — the distributed separator pipeline: parallel
//!   coarsening, multi-sequential initial separators on duplicated
//!   coarsest graphs, and band refinement during uncoarsening —
//!   multi-sequential on small centralized bands, distributed diffusion
//!   on large ones (§3.2–§3.3);
//! * [`dnd`] — parallel nested dissection driving it all down to
//!   sequential (halo) minimum-degree leaves (§3.1, re-exported here
//!   as [`parallel_order`]); separator rings are carried as halo
//!   vertices so the single-rank sequential finish orders its leaves
//!   with the same halo a sequential run would see.
//!
//! Every collective function in this module must be called by all ranks
//! of its communicator in the same order — exactly the contract of the
//! MPI routines it models. The ParMETIS-like comparator in
//! [`crate::baseline`] reuses [`dgraph`], [`matching`], [`coarsen`],
//! [`fold`] and [`induce`], differing only in the separator policy —
//! which is precisely how the paper frames the comparison.

pub mod coarsen;
pub mod dband;
pub mod ddiffusion;
pub mod dgraph;
pub mod dnd;
pub mod dsep;
pub mod fold;
pub mod induce;
pub mod matching;

pub use dnd::{parallel_order, ParallelOrderResult};
