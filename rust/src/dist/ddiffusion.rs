//! Distributed diffusion refinement on band graphs (paper §3.3 / §5).
//!
//! The paper's answer to "sequential FM does not parallelize" is to run
//! FM redundantly on *centralized* band copies — which stops scaling the
//! moment a band no longer fits one process. The diffusion kernel of
//! [`crate::sep::diffusion`] has no such limit: each Jacobi sweep is a
//! local weighted average plus one halo exchange of the scalar field, so
//! it runs directly on the distributed band of
//! [`crate::dist::dband::DistBand`]. The numeric semantics are exactly
//! those of the sequential reference — the per-vertex update is
//! [`crate::sep::diffusion::damped_average`], the bipartition is
//! [`crate::sep::diffusion::sign_label`], and the separator-recovery
//! cover applies [`crate::sep::diffusion::cover_prefers_first`], whose
//! antisymmetry lets every rank decide only for its own endpoints while
//! still covering every crossing halo edge exactly once.

use super::dband::DistBand;
use crate::comm::Comm;
use crate::dist::dgraph::DGraph;
use crate::sep::diffusion::{cover_prefers_first, damped_average, field_from_labels, sign_label};
use crate::sep::SEP;

/// Damping factor of the distributed sweeps; matches the sequential
/// reference default ([`crate::sep::diffusion::CpuDiffusionRefiner`]).
pub const DIST_DIFFUSION_DAMPING: f32 = 0.95;

/// Global `(separator weight, imbalance)` quality key of a distributed
/// part labeling — the distributed analog of
/// [`crate::sep::SepState::quality_key`]. Collective.
pub fn dist_quality_key(comm: &Comm, dg: &DGraph, part: &[u8]) -> (i64, i64) {
    let mut wgts = [0i64; 3];
    for (v, &p) in part.iter().enumerate() {
        wgts[p as usize] += dg.vwgt[v];
    }
    let g = comm.allreduce(wgts, |a, b| [a[0] + b[0], a[1] + b[1], a[2] + b[2]]);
    (g[2], (g[0] - g[1]).abs())
}

/// Run `sweeps` damped Jacobi iterations of the two-liquid diffusion on
/// the distributed band, re-clamping the anchors to ∓1 after every
/// sweep, then recover a valid separator by sign bipartition plus the
/// shared crossing-edge cover. Returns one refined label per local band
/// vertex (anchors included on their owner, always [`crate::sep::P0`] /
/// [`crate::sep::P1`]). Collective.
pub fn diffuse_band_dist(comm: &Comm, band: &DistBand, sweeps: usize, damping: f32) -> Vec<u8> {
    let dg = &band.dg;
    let nloc = dg.nloc();
    // The anchors are by construction the last two local vertices of the
    // last rank (see `extract_dband`), so clamping is two direct writes.
    let owns_anchors = comm.rank() == comm.size() - 1;
    if owns_anchors {
        debug_assert!(nloc >= 2 && dg.glb(nloc - 2) == band.anchor0_gid());
        debug_assert_eq!(dg.glb(nloc - 1), band.anchor1_gid());
    }
    let clamp = |x: &mut [f32]| {
        if owns_anchors {
            x[nloc - 2] = -1.0;
            x[nloc - 1] = 1.0;
        }
    };

    // Local Jacobi sweeps interleaved with halo exchanges of the field —
    // the same f32 arithmetic as the sequential reference, reduction
    // order aside.
    let mut x = field_from_labels(&band.part);
    let mut next = vec![0f32; nloc];
    for _ in 0..sweeps {
        clamp(&mut x);
        let ghost_x = dg.halo_exchange(comm, &x);
        for v in 0..nloc {
            let mut num = 0f32;
            let mut den = 0f32;
            for (&a, &w) in dg.neighbors_gst(v).iter().zip(dg.edge_weights_gst(v)) {
                let a = a as usize;
                let xa = if a < nloc { x[a] } else { ghost_x[a - nloc] };
                let w = w as f32;
                num += w * xa;
                den += w;
            }
            next[v] = damped_average(num, den, damping);
        }
        std::mem::swap(&mut x, &mut next);
    }
    clamp(&mut x);

    // Sign-change scan: bipartition by sign, then cover every crossing
    // edge with its weaker endpoint. Each rank marks only its own
    // vertices; the antisymmetric rule guarantees the remote endpoint of
    // a halo edge is marked by its owner exactly when this side is not.
    let sign: Vec<u8> = x.iter().map(|&xv| sign_label(xv)).collect();
    let ghost_x = dg.halo_exchange(comm, &x);
    // Ghost signs follow from the ghost field — the owner's sign is
    // sign_label of the very value it published (anchors included:
    // their clamped ∓1 signs correctly), so no second exchange.
    let ghost_sign: Vec<u8> = ghost_x.iter().map(|&xv| sign_label(xv)).collect();
    let mut part = sign.clone();
    for v in 0..nloc {
        let gid_v = dg.glb(v);
        if band.is_anchor_gid(gid_v) {
            continue; // anchors are locked
        }
        for &a in dg.neighbors_gst(v) {
            let a = a as usize;
            let (sign_u, x_u, gid_u) = if a < nloc {
                (sign[a], x[a], dg.glb(a))
            } else {
                (ghost_sign[a - nloc], ghost_x[a - nloc], dg.ghosts[a - nloc])
            };
            if sign_u == sign[v] {
                continue;
            }
            if cover_prefers_first(
                x[v].abs(),
                x_u.abs(),
                false,
                band.is_anchor_gid(gid_u),
                gid_v,
                gid_u,
            ) {
                part[v] = SEP;
                break;
            }
        }
    }
    part
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm;
    use crate::dist::dband::{band_distances, extract_dband};
    use crate::dist::dsep::dist_validate_separator;
    use crate::graph::generators;
    use std::sync::Arc;

    /// The shared 2-thick column-separator fixture, centered.
    fn thick_column_part(nx: usize, ny: usize) -> Vec<u8> {
        generators::column_separator_part(nx, ny, nx / 2, 2)
    }

    #[test]
    fn diffused_band_separator_is_valid_and_no_worse() {
        let (nx, ny) = (24, 18);
        let g = Arc::new(generators::grid2d(nx, ny));
        let full = thick_column_part(nx, ny);
        for p in [2usize, 4] {
            let g = g.clone();
            let full = full.clone();
            let (res, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let part: Vec<u8> = (0..dg.nloc())
                    .map(|v| full[dg.glb(v) as usize])
                    .collect();
                let dist = band_distances(&c, &dg, &part, 3);
                let band = extract_dband(&c, &dg, &part, &dist);
                let before = dist_quality_key(&c, &band.dg, &band.part);
                let refined = diffuse_band_dist(&c, &band, 32, DIST_DIFFUSION_DAMPING);
                let valid = dist_validate_separator(&c, &band.dg, &refined);
                let after = dist_quality_key(&c, &band.dg, &refined);
                (valid, before, after)
            });
            for &(valid, before, after) in &res {
                assert!(valid, "p={p}: invalid diffused separator");
                // A 2-thick column separator leaves room to improve; at
                // minimum the diffused cover must not be worse than the
                // trivial 1-column optimum bound from below.
                assert!(after.0 <= before.0, "p={p}: sep grew {after:?} vs {before:?}");
                assert!(after.0 > 0, "p={p}: empty separator");
            }
        }
    }

    #[test]
    fn diffusion_matches_across_rank_counts() {
        // The refined labels are a deterministic function of the band,
        // independent of how many ranks computed them (reduction order
        // aside — identical here because the per-vertex arc order is the
        // parent CSR order in every distribution).
        let (nx, ny) = (16, 12);
        let g = Arc::new(generators::grid2d(nx, ny));
        let full = thick_column_part(nx, ny);
        let mut per_p: Vec<Vec<u8>> = Vec::new();
        for p in [1usize, 2, 3] {
            let g = g.clone();
            let full = full.clone();
            let (res, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let part: Vec<u8> = (0..dg.nloc())
                    .map(|v| full[dg.glb(v) as usize])
                    .collect();
                let dist = band_distances(&c, &dg, &part, 2);
                let band = extract_dband(&c, &dg, &part, &dist);
                let refined = diffuse_band_dist(&c, &band, 16, DIST_DIFFUSION_DAMPING);
                // Label per band *global* id, so layouts are comparable.
                (band.dg.base(), band.band_nglb, refined)
            });
            let nglb = res[0].1 + 2;
            let mut all = vec![0u8; nglb as usize];
            for (base, _, labels) in &res {
                for (i, &l) in labels.iter().enumerate() {
                    all[*base as usize + i] = l;
                }
            }
            per_p.push(all);
        }
        assert_eq!(per_p[0], per_p[1]);
        assert_eq!(per_p[0], per_p[2]);
    }

    #[test]
    fn quality_key_sums_across_ranks() {
        let g = Arc::new(generators::grid2d(10, 10));
        let full = thick_column_part(10, 10);
        let (res, _) = comm::run(4, move |c| {
            let dg = DGraph::from_global(&c, &g);
            let part: Vec<u8> = (0..dg.nloc())
                .map(|v| full[dg.glb(v) as usize])
                .collect();
            dist_quality_key(&c, &dg, &part)
        });
        // Columns 5 and 6 are SEP (20 vertices); P0 has 5 columns, P1 3.
        for key in &res {
            assert_eq!(*key, (20, 20));
        }
    }
}
