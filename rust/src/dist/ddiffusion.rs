//! Distributed diffusion refinement on band graphs (paper §3.3 / §5).
//!
//! The paper's answer to "sequential FM does not parallelize" is to run
//! FM redundantly on *centralized* band copies — which stops scaling the
//! moment a band no longer fits one process. The diffusion kernel of
//! [`crate::sep::diffusion`] has no such limit: each Jacobi sweep is a
//! local weighted average plus one halo exchange of the scalar field, so
//! it runs directly on the distributed band of
//! [`crate::dist::dband::DistBand`]. The numeric semantics are exactly
//! those of the sequential reference — the per-vertex update is
//! [`crate::sep::diffusion::damped_average`], the bipartition is
//! [`crate::sep::diffusion::sign_label`], and the separator-recovery
//! cover applies [`crate::sep::diffusion::cover_prefers_first`], whose
//! antisymmetry lets every rank decide only for its own endpoints while
//! still covering every crossing halo edge exactly once.
//!
//! Two execution engines produce the diffusion field (DESIGN.md §4.2):
//!
//! * **CPU sweeps** ([`diffuse_band_dist`]) — the scalar reference: one
//!   damped Jacobi sweep per halo exchange;
//! * **per-rank XLA kernel** (via [`diffuse_band_dist_engine`]) — each
//!   rank packs its local band slice plus ghost rows into a fixed ELL
//!   bucket ([`crate::runtime::pack_ell_dist`]) and runs the same
//!   AOT-compiled fused kernel the sequential refiner uses. Ghost rows
//!   execute clamped to the boundary values of the previous halo
//!   exchange, so one exchange covers `steps_per_call` fused sweeps.
//!
//! The engine choice is the `engine=` strategy knob
//! ([`crate::strategy::BandEngine`]); the dispatcher agrees on the
//! choice collectively (so the halo-exchange cadence can never split
//! across ranks) and falls back to the CPU sweeps whenever artifacts
//! are absent or some rank's slice fits no bucket.

use super::dband::DistBand;
use crate::comm::Comm;
use crate::dist::dgraph::DGraph;
use crate::runtime::{ell_fused_reference, pack_ell_dist, SharedRuntime};
use crate::sep::diffusion::{cover_prefers_first, damped_average, field_from_labels, sign_label};
use crate::sep::SEP;
use crate::strategy::BandEngine;

/// Damping factor of the distributed sweeps; matches the sequential
/// reference default ([`crate::sep::diffusion::CpuDiffusionRefiner`])
/// and the value baked into the AOT artifacts
/// (`python/compile/model.py::DAMPING`).
pub const DIST_DIFFUSION_DAMPING: f32 = 0.95;

/// Minimum problem size for which [`BandEngine::Auto`] dispatches to
/// the XLA kernel: one bucket row block. Below it, per-call dispatch
/// overhead dominates the fused work, so Auto keeps the CPU path;
/// `engine=xla` overrides. The diffusion dispatch measures the global
/// band (non-anchor vertices); the BFS dispatch
/// ([`crate::dist::dband::bfs_band_dist_engine`]) measures each rank's
/// packed slice (local + ghost rows), every rank having to clear the
/// bar for the collective verdict.
pub const AUTO_XLA_MIN_BAND: u64 = 256;

/// Collectively agree whether the XLA engine runs: `xla_ready` is this
/// rank's "a runtime is loaded (and any artifact-baked constants
/// match)", `auto_size_ok` the problem-size gate [`BandEngine::Auto`]
/// applies on top of it. The allreduce makes the verdict identical on
/// every rank, so no engine-specific collective can ever split the
/// exchange cadence — the rule shared by
/// [`diffuse_band_dist_engine`] and
/// [`crate::dist::dband::bfs_band_dist_engine`]. Collective.
pub(crate) fn agree_engine(
    comm: &Comm,
    engine: BandEngine,
    xla_ready: bool,
    auto_size_ok: bool,
) -> bool {
    let want = match engine {
        BandEngine::Cpu => false,
        BandEngine::Xla => xla_ready,
        BandEngine::Auto => xla_ready && auto_size_ok,
    };
    comm.allreduce(want, |a, b| a && b)
}

/// Global `(separator weight, imbalance)` quality key of a distributed
/// part labeling — the distributed analog of
/// [`crate::sep::SepState::quality_key`]. Collective.
pub fn dist_quality_key(comm: &Comm, dg: &DGraph, part: &[u8]) -> (i64, i64) {
    let mut wgts = [0i64; 3];
    for (v, &p) in part.iter().enumerate() {
        wgts[p as usize] += dg.vwgt[v];
    }
    let g = comm.allreduce(wgts, |a, b| [a[0] + b[0], a[1] + b[1], a[2] + b[2]]);
    (g[2], (g[0] - g[1]).abs())
}

/// Write the anchors' clamp values into a local field slice. The
/// anchors are by construction the last two local vertices of the last
/// rank (see `extract_dband`), so clamping is two direct writes.
fn clamp_anchors(comm: &Comm, band: &DistBand, x: &mut [f32]) {
    let nloc = band.dg.nloc();
    if comm.rank() == comm.size() - 1 {
        debug_assert!(nloc >= 2 && band.dg.glb(nloc - 2) == band.anchor0_gid());
        debug_assert_eq!(band.dg.glb(nloc - 1), band.anchor1_gid());
        x[nloc - 2] = -1.0;
        x[nloc - 1] = 1.0;
    }
}

/// CPU reference sweeps: `sweeps` damped Jacobi iterations, each one
/// local weighted average plus one halo exchange of the scalar field,
/// with the anchors re-clamped to ∓1 after every sweep. Returns the
/// final local field (anchors clamped). Collective.
fn cpu_sweeps(comm: &Comm, band: &DistBand, sweeps: usize, damping: f32) -> Vec<f32> {
    let dg = &band.dg;
    let nloc = dg.nloc();
    // Local Jacobi sweeps interleaved with halo exchanges of the field —
    // the same f32 arithmetic as the sequential reference, reduction
    // order aside.
    let mut x = field_from_labels(&band.part);
    let mut next = vec![0f32; nloc];
    for _ in 0..sweeps {
        clamp_anchors(comm, band, &mut x);
        let ghost_x = dg.halo_exchange(comm, &x);
        for v in 0..nloc {
            let mut num = 0f32;
            let mut den = 0f32;
            for (&a, &w) in dg.neighbors_gst(v).iter().zip(dg.edge_weights_gst(v)) {
                let a = a as usize;
                let xa = if a < nloc { x[a] } else { ghost_x[a - nloc] };
                let w = w as f32;
                num += w * xa;
                den += w;
            }
            next[v] = damped_average(num, den, damping);
        }
        std::mem::swap(&mut x, &mut next);
    }
    clamp_anchors(comm, band, &mut x);
    x
}

/// Local clamp set and width requirement of this rank's band slice:
/// anchor rows (owned by the last rank) execute clamped like ghosts, so
/// only the *unclamped* local rows bound the bucket width.
fn slice_requirements(band: &DistBand) -> (Vec<usize>, usize) {
    let dg = &band.dg;
    let nloc = dg.nloc();
    let clamped: Vec<usize> = (0..nloc)
        .filter(|&v| band.is_anchor_gid(dg.glb(v)))
        .collect();
    let d_real = (0..nloc)
        .filter(|v| !clamped.contains(v))
        .map(|v| dg.neighbors_gst(v).len())
        .max()
        .unwrap_or(0);
    (clamped, d_real)
}

/// One rank's packed band slice plus the kernel's argument vectors —
/// assembled once per band and reused across fused calls. Shared by the
/// XLA execution path and the offline equivalence test, so the
/// production assembly is exercised without artifacts.
struct PackedSlice {
    /// The `(n, d)` ELL block of the slice ([`pack_ell_dist`]).
    ell: crate::runtime::EllPacked,
    /// Field vector, laid out `[local | ghosts | padding]`.
    x: Vec<f32>,
    /// Fixed-value clamp mask: 1 on ghosts and anchors.
    mask: Vec<f32>,
    /// Clamp values: the anchors' ∓1, ghost slots refreshed per call.
    vals: Vec<f32>,
}

/// Pack this rank's band slice into an `(n, d)` ELL block and build the
/// kernel's initial field and clamp vectors: anchors clamped to their
/// ∓1 labels, ghost rows clamped to boundary values that
/// [`PackedSlice::refresh_ghosts`] re-fills from each halo exchange.
fn pack_band_slice(band: &DistBand, n: usize, d: usize, clamped: &[usize]) -> Option<PackedSlice> {
    let dg = &band.dg;
    let nloc = dg.nloc();
    let ell = pack_ell_dist(dg, n, d, clamped)?;
    let mut x = vec![0f32; n];
    let x0 = field_from_labels(&band.part);
    x[..nloc].copy_from_slice(&x0);
    let mut mask = vec![0f32; n];
    let mut vals = vec![0f32; n];
    for &v in clamped {
        mask[v] = 1.0;
        vals[v] = x0[v]; // the anchors' ∓1 (anchor labels are P0/P1)
    }
    mask[nloc..nloc + dg.ghosts.len()].fill(1.0);
    Some(PackedSlice { ell, x, mask, vals })
}

impl PackedSlice {
    /// Write freshly exchanged ghost boundary values into both the
    /// field and the clamp-value slots (`nloc..nloc + ngst`).
    fn refresh_ghosts(&mut self, nloc: usize, ghost_x: &[f32]) {
        for (i, &gx) in ghost_x.iter().enumerate() {
            self.x[nloc + i] = gx;
            self.vals[nloc + i] = gx;
        }
    }
}

/// Per-rank XLA execution of the diffusion sweeps (DESIGN.md §4.2):
/// pack this rank's band slice plus its ghost rows into the smallest
/// fitting ELL bucket, then alternate halo exchanges of the field with
/// fused `steps_per_call`-sweep kernel calls, ghosts and anchors
/// executing clamped. Returns `None` — on **every** rank, the fit
/// verdict is collective — when some rank's slice fits no bucket.
/// Collective.
fn xla_sweeps(comm: &Comm, band: &DistBand, sweeps: usize, rt: &SharedRuntime) -> Option<Vec<f32>> {
    let dg = &band.dg;
    let nloc = dg.nloc();
    let ngst = dg.ghosts.len();
    let (clamped, d_real) = slice_requirements(band);
    // Never hold the runtime lock across a collective: rank threads
    // share one mutex, and a holder waiting in an allreduce would
    // deadlock against a peer waiting on the lock.
    let (bucket, steps_per_call) = {
        let guard = rt.lock().unwrap();
        let rt = &guard.0;
        (rt.fit_diffusion(nloc + ngst, d_real), rt.steps_per_call)
    };
    let packed = bucket.and_then(|b| pack_band_slice(band, b.n, b.d, &clamped));
    let fits = comm.allreduce(packed.is_some(), |a, b| a && b);
    let (bucket, mut s) = match (fits, bucket, packed) {
        (true, Some(b), Some(s)) => (b, s),
        _ => return None, // some rank missed every bucket → CPU everywhere
    };

    let calls = sweeps.div_ceil(steps_per_call.max(1)).max(1);
    for _ in 0..calls {
        // Re-fill the ghost boundary values from their owners, then run
        // one fused call: the kernel clamps ghosts/anchors before every
        // internal sweep and once after the last.
        let ghost_x = dg.halo_exchange(comm, &s.x[..nloc]);
        s.refresh_ghosts(nloc, &ghost_x);
        let step = {
            let guard = rt.lock().unwrap();
            guard.0.diffusion_step(bucket, &s.x, &s.mask, &s.vals, &s.ell)
        };
        s.x = match step {
            Ok(next) => next,
            // A mid-run PJRT failure must not desynchronize the agreed
            // halo cadence — substitute the bit-equivalent pure-Rust
            // reference of the same fused call and stay in lockstep
            // (outside the lock: other ranks' fallbacks stay parallel).
            Err(_) => ell_fused_reference(
                &s.ell,
                &s.x,
                &s.mask,
                &s.vals,
                steps_per_call,
                DIST_DIFFUSION_DAMPING,
            ),
        };
    }
    let mut x = s.x;
    x.truncate(nloc);
    Some(x)
}

/// Recover a valid separator from a converged diffusion field: sign
/// bipartition plus the shared crossing-edge cover. Each rank marks only
/// its own vertices; the antisymmetric rule guarantees the remote
/// endpoint of a halo edge is marked by its owner exactly when this side
/// is not. Returns one label per local band vertex (anchors included on
/// their owner, always [`crate::sep::P0`] / [`crate::sep::P1`]).
/// Collective.
fn recover_separator(comm: &Comm, band: &DistBand, x: &[f32]) -> Vec<u8> {
    let dg = &band.dg;
    let nloc = dg.nloc();
    let sign: Vec<u8> = x.iter().map(|&xv| sign_label(xv)).collect();
    let ghost_x = dg.halo_exchange(comm, x);
    // Ghost signs follow from the ghost field — the owner's sign is
    // sign_label of the very value it published (anchors included:
    // their clamped ∓1 signs correctly), so no second exchange.
    let ghost_sign: Vec<u8> = ghost_x.iter().map(|&xv| sign_label(xv)).collect();
    let mut part = sign.clone();
    for v in 0..nloc {
        let gid_v = dg.glb(v);
        if band.is_anchor_gid(gid_v) {
            continue; // anchors are locked
        }
        for &a in dg.neighbors_gst(v) {
            let a = a as usize;
            let (sign_u, x_u, gid_u) = if a < nloc {
                (sign[a], x[a], dg.glb(a))
            } else {
                (ghost_sign[a - nloc], ghost_x[a - nloc], dg.ghosts[a - nloc])
            };
            if sign_u == sign[v] {
                continue;
            }
            if cover_prefers_first(
                x[v].abs(),
                x_u.abs(),
                false,
                band.is_anchor_gid(gid_u),
                gid_v,
                gid_u,
            ) {
                part[v] = SEP;
                break;
            }
        }
    }
    part
}

/// Run `sweeps` damped Jacobi iterations of the two-liquid diffusion on
/// the distributed band with the scalar CPU engine, re-clamping the
/// anchors to ∓1 after every sweep, then recover a valid separator by
/// sign bipartition plus the shared crossing-edge cover. Returns one
/// refined label per local band vertex. Collective.
pub fn diffuse_band_dist(comm: &Comm, band: &DistBand, sweeps: usize, damping: f32) -> Vec<u8> {
    let x = cpu_sweeps(comm, band, sweeps, damping);
    recover_separator(comm, band, &x)
}

/// Engine-dispatching variant of [`diffuse_band_dist`]: run the sweeps
/// on the engine `engine` selects, falling back down the ladder
/// (per-rank XLA kernel → CPU sweeps) whenever the runtime is absent,
/// the damping differs from the artifact-baked
/// [`DIST_DIFFUSION_DAMPING`], or some rank's band slice fits no
/// bucket. The engine verdict is agreed collectively before any
/// engine-specific collective runs, so the halo-exchange cadence never
/// splits across ranks. Returns the refined labels plus whether the XLA
/// engine actually executed. Collective.
pub fn diffuse_band_dist_engine(
    comm: &Comm,
    band: &DistBand,
    sweeps: usize,
    damping: f32,
    engine: BandEngine,
    rt: Option<&SharedRuntime>,
) -> (Vec<u8>, bool) {
    // The artifacts bake DIST_DIFFUSION_DAMPING in; a caller sweeping a
    // different damping must get the CPU engine it can parameterize.
    // Collective agreement (a rank could in principle lack the runtime
    // handle others hold — never let the sweep cadence diverge).
    let use_xla = agree_engine(
        comm,
        engine,
        rt.is_some() && damping == DIST_DIFFUSION_DAMPING,
        band.band_nglb >= AUTO_XLA_MIN_BAND,
    );
    if use_xla {
        if let Some(x) = xla_sweeps(comm, band, sweeps, rt.expect("agreed runtime")) {
            return (recover_separator(comm, band, &x), true);
        }
        // Collective fit miss: every rank got None; fall through to CPU.
    }
    (diffuse_band_dist(comm, band, sweeps, damping), false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm;
    use crate::dist::dband::{band_distances, extract_dband};
    use crate::dist::dsep::dist_validate_separator;
    use crate::graph::generators;
    use std::sync::Arc;

    /// The shared 2-thick column-separator fixture, centered.
    fn thick_column_part(nx: usize, ny: usize) -> Vec<u8> {
        generators::column_separator_part(nx, ny, nx / 2, 2)
    }

    #[test]
    fn diffused_band_separator_is_valid_and_no_worse() {
        let (nx, ny) = (24, 18);
        let g = Arc::new(generators::grid2d(nx, ny));
        let full = thick_column_part(nx, ny);
        for p in [2usize, 4] {
            let g = g.clone();
            let full = full.clone();
            let (res, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let part: Vec<u8> = (0..dg.nloc())
                    .map(|v| full[dg.glb(v) as usize])
                    .collect();
                let dist = band_distances(&c, &dg, &part, 3);
                let band = extract_dband(&c, &dg, &part, &dist);
                let before = dist_quality_key(&c, &band.dg, &band.part);
                let refined = diffuse_band_dist(&c, &band, 32, DIST_DIFFUSION_DAMPING);
                let valid = dist_validate_separator(&c, &band.dg, &refined);
                let after = dist_quality_key(&c, &band.dg, &refined);
                (valid, before, after)
            });
            for &(valid, before, after) in &res {
                assert!(valid, "p={p}: invalid diffused separator");
                // A 2-thick column separator leaves room to improve; at
                // minimum the diffused cover must not be worse than the
                // trivial 1-column optimum bound from below.
                assert!(after.0 <= before.0, "p={p}: sep grew {after:?} vs {before:?}");
                assert!(after.0 > 0, "p={p}: empty separator");
            }
        }
    }

    #[test]
    fn diffusion_matches_across_rank_counts() {
        // The refined labels are a deterministic function of the band,
        // independent of how many ranks computed them (reduction order
        // aside — identical here because the per-vertex arc order is the
        // parent CSR order in every distribution).
        let (nx, ny) = (16, 12);
        let g = Arc::new(generators::grid2d(nx, ny));
        let full = thick_column_part(nx, ny);
        let mut per_p: Vec<Vec<u8>> = Vec::new();
        for p in [1usize, 2, 3] {
            let g = g.clone();
            let full = full.clone();
            let (res, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let part: Vec<u8> = (0..dg.nloc())
                    .map(|v| full[dg.glb(v) as usize])
                    .collect();
                let dist = band_distances(&c, &dg, &part, 2);
                let band = extract_dband(&c, &dg, &part, &dist);
                let refined = diffuse_band_dist(&c, &band, 16, DIST_DIFFUSION_DAMPING);
                // Label per band *global* id, so layouts are comparable.
                (band.dg.base(), band.band_nglb, refined)
            });
            let nglb = res[0].1 + 2;
            let mut all = vec![0u8; nglb as usize];
            for (base, _, labels) in &res {
                for (i, &l) in labels.iter().enumerate() {
                    all[*base as usize + i] = l;
                }
            }
            per_p.push(all);
        }
        assert_eq!(per_p[0], per_p[1]);
        assert_eq!(per_p[0], per_p[2]);
    }

    #[test]
    fn quality_key_sums_across_ranks() {
        let g = Arc::new(generators::grid2d(10, 10));
        let full = thick_column_part(10, 10);
        let (res, _) = comm::run(4, move |c| {
            let dg = DGraph::from_global(&c, &g);
            let part: Vec<u8> = (0..dg.nloc())
                .map(|v| full[dg.glb(v) as usize])
                .collect();
            dist_quality_key(&c, &dg, &part)
        });
        // Columns 5 and 6 are SEP (20 vertices); P0 has 5 columns, P1 3.
        for key in &res {
            assert_eq!(*key, (20, 20));
        }
    }

    #[test]
    fn engine_dispatch_without_runtime_matches_cpu() {
        // Offline (xla-stub / no artifacts) there is no runtime handle:
        // every engine setting must take the CPU path and produce labels
        // identical to calling `diffuse_band_dist` directly.
        let (nx, ny) = (20, 14);
        let g = Arc::new(generators::grid2d(nx, ny));
        let full = thick_column_part(nx, ny);
        for p in [2usize, 3] {
            for engine in [BandEngine::Auto, BandEngine::Cpu, BandEngine::Xla] {
                let g = g.clone();
                let full = full.clone();
                let (ok, _) = comm::run(p, move |c| {
                    let dg = DGraph::from_global(&c, &g);
                    let part: Vec<u8> = (0..dg.nloc())
                        .map(|v| full[dg.glb(v) as usize])
                        .collect();
                    let dist = band_distances(&c, &dg, &part, 2);
                    let band = extract_dband(&c, &dg, &part, &dist);
                    let want = diffuse_band_dist(&c, &band, 12, DIST_DIFFUSION_DAMPING);
                    let (got, used_xla) = diffuse_band_dist_engine(
                        &c,
                        &band,
                        12,
                        DIST_DIFFUSION_DAMPING,
                        engine,
                        None,
                    );
                    !used_xla && got == want
                });
                assert!(ok.iter().all(|&x| x), "p={p} engine={engine:?}");
            }
        }
    }

    #[test]
    fn packed_slice_fused_reference_matches_cpu_sweeps() {
        // The numeric core of the per-rank XLA path, without artifacts:
        // the *production* slice assembly (`slice_requirements` +
        // `pack_band_slice` + `refresh_ghosts`, exactly what
        // `xla_sweeps` runs) driven by the fused-call reference at one
        // step per call (one halo exchange per sweep, the CPU cadence)
        // must reproduce `cpu_sweeps` bit-for-bit — same neighbor
        // order, same f32 arithmetic.
        let (nx, ny) = (18, 13);
        let g = Arc::new(generators::grid2d(nx, ny));
        let full = thick_column_part(nx, ny);
        for p in [1usize, 2, 4] {
            let g = g.clone();
            let full = full.clone();
            let (ok, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let part: Vec<u8> = (0..dg.nloc())
                    .map(|v| full[dg.glb(v) as usize])
                    .collect();
                let dist = band_distances(&c, &dg, &part, 3);
                let band = extract_dband(&c, &dg, &part, &dist);
                let bdg = &band.dg;
                let nloc = bdg.nloc();
                let ngst = bdg.ghosts.len();
                let (clamped, d) = slice_requirements(&band);
                let mut s = pack_band_slice(&band, nloc + ngst + 3, d, &clamped).unwrap();
                let sweeps = 9usize;
                let want = cpu_sweeps(&c, &band, sweeps, DIST_DIFFUSION_DAMPING);
                for _ in 0..sweeps {
                    let ghost_x = bdg.halo_exchange(&c, &s.x[..nloc]);
                    s.refresh_ghosts(nloc, &ghost_x);
                    s.x = ell_fused_reference(
                        &s.ell,
                        &s.x,
                        &s.mask,
                        &s.vals,
                        1,
                        DIST_DIFFUSION_DAMPING,
                    );
                }
                s.x[..nloc] == want[..]
            });
            assert!(ok.iter().all(|&x| x), "p={p}");
        }
    }
}
