//! Distributed induced subgraphs (paper §3.1).
//!
//! After a separator splits the vertex set, nested dissection recurses
//! on the subgraphs induced by the two parts. In the distributed
//! setting each rank keeps its own part-`k` vertices, the survivors are
//! renumbered into a fresh contiguous global range (exclusive scan of
//! per-rank counts), and cross edges to dropped vertices disappear. The
//! caller's per-vertex payload (original vertex ids, §2.2's inverse
//! permutation bookkeeping) rides along so leaf orderings can be mapped
//! back to root ids.
//!
//! The paper overlaps the construction of the two induced subgraphs
//! with an extra thread per process (§3.1); [`crate::dist::dnd`] does
//! the same on [`crate::comm::Comm::overlap_context`] clones when
//! `Strategy.dist.overlap_folds` is set.

use super::dgraph::DGraph;
use crate::comm::Comm;

/// An induced distributed subgraph plus the payload of its vertices.
#[derive(Clone, Debug)]
pub struct DistInduced {
    /// The induced distributed graph (fresh contiguous global ids).
    pub dg: DGraph,
    /// Payload of each kept local vertex, in new local order.
    pub orig: Vec<u64>,
}

/// Build the distributed subgraph induced by `keep` (one flag per local
/// vertex), carrying `payload` along. Collective.
pub fn induce_dist(comm: &Comm, dg: &DGraph, keep: &[bool], payload: &[u64]) -> DistInduced {
    debug_assert_eq!(keep.len(), dg.nloc());
    debug_assert_eq!(payload.len(), dg.nloc());
    let p = comm.size();
    let nloc = dg.nloc();

    let kept: Vec<usize> = (0..nloc).filter(|&v| keep[v]).collect();

    // Fresh contiguous global numbering of the survivors.
    let counts = comm.allgatherv(vec![kept.len() as u64]);
    let mut vtx = vec![0u64; p + 1];
    for r in 0..p {
        vtx[r + 1] = vtx[r] + counts[r][0];
    }
    let nbase = vtx[comm.rank()];
    let mut newid: Vec<u64> = vec![u64::MAX; nloc];
    for (i, &v) in kept.iter().enumerate() {
        newid[v] = nbase + i as u64;
    }
    // New ids of fine ghosts (MAX when the ghost was dropped).
    let ghost_newid = dg.halo_exchange(comm, &newid);

    let vwgt: Vec<i64> = kept.iter().map(|&v| dg.vwgt[v]).collect();
    let orig: Vec<u64> = kept.iter().map(|&v| payload[v]).collect();
    let rows: Vec<Vec<(u64, i64)>> = kept
        .iter()
        .map(|&v| {
            dg.neighbors_gst(v)
                .iter()
                .zip(dg.edge_weights_gst(v))
                .filter_map(|(&a, &w)| {
                    let a = a as usize;
                    let nid = if a < nloc {
                        newid[a]
                    } else {
                        ghost_newid[a - nloc]
                    };
                    (nid != u64::MAX).then_some((nid, w))
                })
                .collect()
        })
        .collect();
    DistInduced {
        dg: DGraph::from_rows(comm, vtx, vwgt, rows),
        orig,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm;
    use crate::graph::generators;
    use std::sync::Arc;

    #[test]
    fn induced_half_grid_matches_sequential() {
        // Keep the left half of a grid (x < nx/2) on 3 ranks; the
        // centralized result must equal the sequential induced subgraph.
        let nx = 10;
        let g = Arc::new(generators::grid2d(nx, 6));
        let gref = g.clone();
        let (res, _) = comm::run(3, move |c| {
            let dg = DGraph::from_global(&c, &g);
            let keep: Vec<bool> = (0..dg.nloc())
                .map(|v| (dg.glb(v) as usize % nx) < nx / 2)
                .collect();
            let payload: Vec<u64> = (0..dg.nloc()).map(|v| dg.glb(v)).collect();
            let ind = induce_dist(&c, &dg, &keep, &payload);
            let central = ind.dg.centralize_all(&c);
            central.validate().unwrap();
            (central, ind.orig.clone())
        });
        let seq = crate::graph::InducedGraph::build(&gref, |v| (v % nx) < nx / 2);
        for (central, _) in &res {
            assert_eq!(central.n(), seq.graph.n());
            assert_eq!(central.m(), seq.graph.m());
        }
        // Payloads concatenated in rank order enumerate the kept ids.
        let mut orig: Vec<u64> = res.iter().flat_map(|(_, o)| o.clone()).collect();
        orig.sort_unstable();
        let want: Vec<u64> = (0..gref.n() as u64)
            .filter(|&v| (v as usize % nx) < nx / 2)
            .collect();
        assert_eq!(orig, want);
    }
}
