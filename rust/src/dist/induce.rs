//! Distributed induced subgraphs (paper §3.1).
//!
//! After a separator splits the vertex set, nested dissection recurses
//! on the subgraphs induced by the two parts. In the distributed
//! setting each rank keeps its own part-`k` vertices, the survivors are
//! renumbered into a fresh contiguous global range (exclusive scan of
//! per-rank counts), and cross edges to dropped vertices disappear. The
//! caller's per-vertex payload (original vertex ids, §2.2's inverse
//! permutation bookkeeping) rides along so leaf orderings can be mapped
//! back to root ids.
//!
//! The paper overlaps the construction of the two induced subgraphs
//! with an extra thread per process (§3.1); [`crate::dist::dnd`] does
//! the same on [`crate::comm::Comm::overlap_context`] clones when
//! `Strategy.dist.overlap_folds` is set.

use super::dgraph::DGraph;
use crate::comm::Comm;

/// Payload bit marking a vertex as **halo** in the distributed
/// dissection recursion ([`crate::dist::dnd`]): an already-numbered
/// separator vertex carried along (never re-partitioned, never
/// re-emitted) so the single-rank sequential finish can hand
/// [`crate::order::hamd::hamd`] the same separator ring a sequential run
/// would see. Root vertex ids occupy the low bits; bit 63 is free on
/// any graph this container can hold.
pub const HALO_BIT: u64 = 1 << 63;

/// An induced distributed subgraph plus the payload of its vertices.
#[derive(Clone, Debug)]
pub struct DistInduced {
    /// The induced distributed graph (fresh contiguous global ids).
    pub dg: DGraph,
    /// Payload of each kept local vertex, in new local order (the halo
    /// variant sets [`HALO_BIT`] on its halo members).
    pub orig: Vec<u64>,
}

/// Shared assembly core of the two inductions: fresh contiguous global
/// renumbering of the `kept` local vertices (exclusive scan of
/// per-rank counts), new-id halo exchange, and CSR assembly. An arc
/// survives when its far endpoint was kept anywhere (its new id
/// exists) *and* `arc_keep(v, a)` accepts it — callers supply a
/// symmetric predicate over the local source `v` and its gst neighbor
/// `a` so both directions of an edge agree. Collective.
fn induce_assemble(
    comm: &Comm,
    dg: &DGraph,
    kept: &[usize],
    arc_keep: impl Fn(usize, usize) -> bool,
) -> DGraph {
    let p = comm.size();
    let nloc = dg.nloc();
    let counts = comm.allgatherv(vec![kept.len() as u64]);
    let mut vtx = vec![0u64; p + 1];
    for r in 0..p {
        vtx[r + 1] = vtx[r] + counts[r][0];
    }
    let nbase = vtx[comm.rank()];
    let mut newid: Vec<u64> = vec![u64::MAX; nloc];
    for (i, &v) in kept.iter().enumerate() {
        newid[v] = nbase + i as u64;
    }
    // New ids of fine ghosts (MAX when the ghost was dropped).
    let ghost_newid = dg.halo_exchange(comm, &newid);

    let vwgt: Vec<i64> = kept.iter().map(|&v| dg.vwgt[v]).collect();
    let rows: Vec<Vec<(u64, i64)>> = kept
        .iter()
        .map(|&v| {
            dg.neighbors_gst(v)
                .iter()
                .zip(dg.edge_weights_gst(v))
                .filter_map(|(&a, &w)| {
                    let a = a as usize;
                    let nid = if a < nloc {
                        newid[a]
                    } else {
                        ghost_newid[a - nloc]
                    };
                    (nid != u64::MAX && arc_keep(v, a)).then_some((nid, w))
                })
                .collect()
        })
        .collect();
    DGraph::from_rows(comm, vtx, vwgt, rows)
}

/// Build the distributed subgraph induced by `keep` (one flag per local
/// vertex), carrying `payload` along. Collective.
pub fn induce_dist(comm: &Comm, dg: &DGraph, keep: &[bool], payload: &[u64]) -> DistInduced {
    debug_assert_eq!(keep.len(), dg.nloc());
    debug_assert_eq!(payload.len(), dg.nloc());
    let kept: Vec<usize> = (0..dg.nloc()).filter(|&v| keep[v]).collect();
    let orig: Vec<u64> = kept.iter().map(|&v| payload[v]).collect();
    DistInduced {
        dg: induce_assemble(comm, dg, &kept, |_, _| true),
        orig,
    }
}

/// Build the distributed subgraph induced by the `keep_core` vertices
/// **plus their one-ring halo**: every `halo_cand` vertex adjacent to
/// at least one core vertex (its own or a remote one) is kept too,
/// with [`HALO_BIT`] set on its payload. Halo–halo edges are dropped —
/// they can influence no core degree and no element, so carrying them
/// through the recursion would only bloat every level below.
/// Collective.
pub fn induce_dist_halo(
    comm: &Comm,
    dg: &DGraph,
    keep_core: &[bool],
    halo_cand: &[bool],
    payload: &[u64],
) -> DistInduced {
    debug_assert_eq!(keep_core.len(), dg.nloc());
    debug_assert_eq!(halo_cand.len(), dg.nloc());
    debug_assert_eq!(payload.len(), dg.nloc());
    let nloc = dg.nloc();

    // Core membership of the ghosts decides both which halo candidates
    // survive and which arcs do (one flag exchange per call).
    let core_flags: Vec<u8> = keep_core.iter().map(|&c| c as u8).collect();
    let ghost_core = dg.halo_exchange(comm, &core_flags);
    let is_core_gst = |a: usize| -> bool {
        if a < nloc {
            keep_core[a]
        } else {
            ghost_core[a - nloc] != 0
        }
    };

    let kept: Vec<usize> = (0..nloc)
        .filter(|&v| {
            keep_core[v]
                || (halo_cand[v] && dg.neighbors_gst(v).iter().any(|&a| is_core_gst(a as usize)))
        })
        .collect();
    let orig: Vec<u64> = kept
        .iter()
        .map(|&v| {
            if keep_core[v] {
                payload[v]
            } else {
                payload[v] | HALO_BIT
            }
        })
        .collect();
    // An arc survives when at least one endpoint is core (a symmetric
    // rule: the reverse arc evaluates identically), which is exactly
    // the halo–halo-edge drop. Core and halo vertices interleave
    // freely within a rank's renumbered block.
    DistInduced {
        dg: induce_assemble(comm, dg, &kept, |v, a| keep_core[v] || is_core_gst(a)),
        orig,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm;
    use crate::graph::generators;
    use std::sync::Arc;

    #[test]
    fn induced_half_grid_matches_sequential() {
        // Keep the left half of a grid (x < nx/2) on 3 ranks; the
        // centralized result must equal the sequential induced subgraph.
        let nx = 10;
        let g = Arc::new(generators::grid2d(nx, 6));
        let gref = g.clone();
        let (res, _) = comm::run(3, move |c| {
            let dg = DGraph::from_global(&c, &g);
            let keep: Vec<bool> = (0..dg.nloc())
                .map(|v| (dg.glb(v) as usize % nx) < nx / 2)
                .collect();
            let payload: Vec<u64> = (0..dg.nloc()).map(|v| dg.glb(v)).collect();
            let ind = induce_dist(&c, &dg, &keep, &payload);
            let central = ind.dg.centralize_all(&c);
            central.validate().unwrap();
            (central, ind.orig.clone())
        });
        let seq = crate::graph::InducedGraph::build(&gref, |v| (v % nx) < nx / 2);
        for (central, _) in &res {
            assert_eq!(central.n(), seq.graph.n());
            assert_eq!(central.m(), seq.graph.m());
        }
        // Payloads concatenated in rank order enumerate the kept ids.
        let mut orig: Vec<u64> = res.iter().flat_map(|(_, o)| o.clone()).collect();
        orig.sort_unstable();
        let want: Vec<u64> = (0..gref.n() as u64)
            .filter(|&v| (v as usize % nx) < nx / 2)
            .collect();
        assert_eq!(orig, want);
    }

    #[test]
    fn halo_induction_matches_sequential_ring() {
        // Core = left half of a grid, every other vertex a halo
        // candidate: the distributed result must match the sequential
        // `induce_with_halo` (same vertex count, same edge count —
        // halo–halo edges dropped on both sides), and exactly the ring
        // must carry HALO_BIT.
        let nx = 9;
        let ny = 7;
        let g = Arc::new(generators::grid2d(nx, ny));
        let gref = g.clone();
        let (res, _) = comm::run(3, move |c| {
            let dg = DGraph::from_global(&c, &g);
            let keep_core: Vec<bool> = (0..dg.nloc())
                .map(|v| (dg.glb(v) as usize % nx) < nx / 2)
                .collect();
            let halo_cand: Vec<bool> = keep_core.iter().map(|&k| !k).collect();
            let payload: Vec<u64> = (0..dg.nloc()).map(|v| dg.glb(v)).collect();
            let ind = induce_dist_halo(&c, &dg, &keep_core, &halo_cand, &payload);
            let central = ind.dg.centralize_all(&c);
            central.validate().unwrap();
            (central, ind.orig.clone())
        });
        let core: Vec<usize> = (0..gref.n()).filter(|v| v % nx < nx / 2).collect();
        let seq = crate::graph::induce_with_halo(&gref, &core);
        for (central, _) in &res {
            assert_eq!(central.n(), seq.graph.n());
            assert_eq!(central.m(), seq.graph.m());
        }
        let mut halo_ids: Vec<u64> = res
            .iter()
            .flat_map(|(_, o)| o.iter().copied())
            .filter(|&x| x & HALO_BIT != 0)
            .map(|x| x & !HALO_BIT)
            .collect();
        halo_ids.sort_unstable();
        let mut want: Vec<u64> = seq.orig[seq.n_core..].iter().map(|&v| v as u64).collect();
        want.sort_unstable();
        assert_eq!(halo_ids, want);
    }
}
