//! Parallel nested dissection (paper §3.1).
//!
//! The PT-Scotch ordering driver: recursively compute a distributed
//! separator ([`crate::dist::dsep::dist_separator`]), emit the
//! separator's ordering fragment at the **top** of the current index
//! range (§2.2: separators take the highest available indices), build
//! the two induced subgraphs (optionally overlapped with an extra
//! thread per process, §3.1), fold each onto one half of the ranks
//! (any rank count — the comparator's power-of-two restriction does not
//! apply, §3.2), split the communicator, and recurse. When a branch
//! reaches a single rank, the sequential nested dissection of
//! [`crate::order::nd`] (multilevel separators + minimum-degree leaves)
//! finishes the job. Fragments are finally allgathered and assembled
//! into one inverse permutation, identical on every rank.

use super::dgraph::DGraph;
use super::dsep::dist_separator;
use super::fold::{fold_half, FoldTarget};
use super::induce::{induce_dist, DistInduced};
use crate::comm::{Comm, MemTracker};
use crate::graph::Graph;
use crate::order::{assemble_fragments, nested_dissection, OrderFragment, Ordering};
use crate::rng::Rng;
use crate::runtime::SharedRuntime;
use crate::sep::{BandRefiner, P0, P1, SEP};
use crate::strategy::Strategy;
use crate::Result;

/// Result of a parallel ordering run on one rank.
#[derive(Clone, Debug)]
pub struct ParallelOrderResult {
    /// The assembled global ordering (identical on every rank).
    pub ordering: Ordering,
    /// Peak tracked graph memory on this rank, in bytes (Figures 10–11).
    pub peak_mem: i64,
    /// Number of distributed dissection levels this rank participated in.
    pub dist_levels: usize,
}

/// Order `g` with PT-Scotch parallel nested dissection on the ranks of
/// `comm` (any count, including 1). Collective; every rank receives the
/// same valid [`Ordering`]. `xla` is the optional shared XLA runtime
/// handle used by the distributed band-diffusion engine dispatch
/// (DESIGN.md §4.2); pass `None` to pin the scalar CPU sweeps.
pub fn parallel_order(
    comm: &Comm,
    g: &Graph,
    strat: &Strategy,
    refiner: &dyn BandRefiner,
    xla: Option<&SharedRuntime>,
) -> ParallelOrderResult {
    let mem = MemTracker::new();
    let dg = DGraph::from_global(comm, g);
    mem.grow(dg.footprint_bytes());
    let payload: Vec<u64> = (0..dg.nloc()).map(|v| dg.glb(v)).collect();
    let base_rng = Rng::new(strat.seed);
    let mut frags = Vec::new();
    let mut dist_levels = 0usize;
    let separator = |c: &Comm, d: &DGraph, r: &Rng, m: &MemTracker| {
        dist_separator(c, d, strat, refiner, xla, r, m)
    };
    dissect(
        comm,
        dg,
        payload,
        0,
        strat,
        refiner,
        &separator,
        strat.dist.overlap_folds,
        &base_rng,
        &mem,
        &mut frags,
        &mut dist_levels,
        0,
    );
    let ordering = gather_and_assemble(comm, g.n(), &frags)
        .expect("parallel nested dissection covers all vertices");
    ParallelOrderResult {
        ordering,
        peak_mem: mem.peak(),
        dist_levels,
    }
}

/// Gather every rank's ordering fragments and assemble the global
/// inverse permutation (§2.2: fragments tile the index range exactly).
/// The wire format is shared by the PT-Scotch and baseline engines so
/// it lives in one place. Collective; identical result on every rank.
pub(crate) fn gather_and_assemble(
    comm: &Comm,
    n: usize,
    frags: &[OrderFragment],
) -> Result<Ordering> {
    let mut blob: Vec<u64> = Vec::new();
    for f in frags {
        blob.push(f.start as u64);
        blob.push(f.verts.len() as u64);
        blob.extend(f.verts.iter().map(|&v| v as u64));
    }
    let all = comm.allgatherv(blob);
    let mut all_frags = Vec::new();
    for b in &all {
        let mut i = 0usize;
        while i < b.len() {
            let (start, len) = (b[i] as usize, b[i + 1] as usize);
            i += 2;
            all_frags.push(OrderFragment {
                start,
                verts: b[i..i + len].iter().map(|&v| v as usize).collect(),
            });
            i += len;
        }
    }
    assemble_fragments(n, all_frags)
}

/// Build the two induced subgraphs, overlapping them with an extra
/// thread per rank on tag-scoped communicator clones when the strategy
/// asks for it (§3.1: the overlap "can be disabled when the
/// communication system is not thread-safe" and never changes results —
/// `induce_dist` is deterministic).
fn induce_both(
    comm: &Comm,
    dg: &DGraph,
    keep0: &[bool],
    keep1: &[bool],
    payload: &[u64],
    overlap: bool,
) -> (DistInduced, DistInduced) {
    if overlap {
        let c0 = comm.overlap_context(0);
        let c1 = comm.overlap_context(1);
        std::thread::scope(|s| {
            let h = s.spawn(move || induce_dist(&c1, dg, keep1, payload));
            let i0 = induce_dist(&c0, dg, keep0, payload);
            let i1 = h.join().expect("overlap induce thread");
            (i0, i1)
        })
    } else {
        (
            induce_dist(comm, dg, keep0, payload),
            induce_dist(comm, dg, keep1, payload),
        )
    }
}

/// The recursive dissection driver, shared by the PT-Scotch engine and
/// the ParMETIS-like baseline — which, as the paper frames it, differ
/// only in how they bipartition. `separator` is the per-level policy
/// (called with a depth-derived rng root); `overlap` toggles the §3.1
/// induced-subgraph overlap thread. All fragment/start-offset
/// arithmetic and memory accounting live here, in one copy.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dissect(
    comm: &Comm,
    dg: DGraph,
    payload: Vec<u64>,
    start: usize,
    strat: &Strategy,
    refiner: &dyn BandRefiner,
    separator: &dyn Fn(&Comm, &DGraph, &Rng, &MemTracker) -> Vec<u8>,
    overlap: bool,
    base_rng: &Rng,
    mem: &MemTracker,
    frags: &mut Vec<OrderFragment>,
    dist_levels: &mut usize,
    depth: u64,
) {
    // The caller tracked `dg`'s footprint; shrink it wherever `dg` dies
    // so `peak_mem` reports peak *live* memory, not cumulative growth.
    let dg_bytes = dg.footprint_bytes();
    if comm.size() == 1 {
        // One rank left: finish sequentially (§3.1's leaf case).
        let local = dg.to_local();
        mem.grow(local.footprint_bytes());
        let mut rng = base_rng.derive(0x1EAF ^ (depth << 8));
        let ord = nested_dissection(&local, strat, refiner, &mut rng);
        frags.push(OrderFragment {
            start,
            verts: ord.iperm.iter().map(|&lv| payload[lv] as usize).collect(),
        });
        mem.shrink(local.footprint_bytes() + dg_bytes);
        return;
    }
    if dg.nglb == 0 {
        mem.shrink(dg_bytes);
        return;
    }
    *dist_levels += 1;
    let part = separator(comm, &dg, &base_rng.derive(depth), mem);
    // One fused reduction for all three part counts — the per-level
    // collective count feeds the communication telemetry the benches
    // report, so don't pay three rounds for one vector.
    let mine = [
        part.iter().filter(|&&x| x == P0).count() as i64,
        part.iter().filter(|&&x| x == P1).count() as i64,
        part.iter().filter(|&&x| x == SEP).count() as i64,
    ];
    let total = comm.allreduce(mine, |a, b| [a[0] + b[0], a[1] + b[1], a[2] + b[2]]);
    let counts = [total[0] as usize, total[1] as usize, total[2] as usize];
    let degenerate = counts[0] == 0
        || counts[1] == 0
        || counts[2] as f64 > dg.nglb as f64 * strat.nd.max_sep_fraction;
    if degenerate {
        // Near-clique or disconnected oddity: centralize and let rank 0
        // of this subgroup order the whole range sequentially.
        let central = dg.centralize_all(comm);
        mem.grow(central.footprint_bytes());
        let all_payload = comm.allgatherv(payload.clone()).concat();
        if comm.rank() == 0 {
            let mut rng = base_rng.derive(0xD0 ^ depth);
            let ord = nested_dissection(&central, strat, refiner, &mut rng);
            frags.push(OrderFragment {
                start,
                verts: ord
                    .iperm
                    .iter()
                    .map(|&lv| all_payload[lv] as usize)
                    .collect(),
            });
        }
        mem.shrink(central.footprint_bytes() + dg_bytes);
        return;
    }
    // Separator fragment: the highest indices of the range (§2.2), laid
    // out by ascending rank within the separator block.
    let my_sep: Vec<usize> = (0..dg.nloc()).filter(|&v| part[v] == SEP).collect();
    let sep_offset = comm.exscan_sum(my_sep.len() as u64) as usize;
    if !my_sep.is_empty() {
        frags.push(OrderFragment {
            start: start + counts[0] + counts[1] + sep_offset,
            verts: my_sep.iter().map(|&v| payload[v] as usize).collect(),
        });
    }
    let keep0: Vec<bool> = part.iter().map(|&x| x == P0).collect();
    let keep1: Vec<bool> = part.iter().map(|&x| x == P1).collect();
    let (ind0, ind1) = induce_both(comm, &dg, &keep0, &keep1, &payload, overlap);
    mem.grow(ind0.dg.footprint_bytes() + ind1.dg.footprint_bytes());
    drop(dg);
    drop(payload);
    mem.shrink(dg_bytes);
    // Fold part 0 onto the low half of the ranks and part 1 onto the
    // high half (any p — no power-of-two restriction, §3.2), then split
    // and recurse on whichever half this rank joined.
    let p = comm.size();
    let f0 = fold_half(comm, &ind0.dg, &ind0.orig, FoldTarget::low_half(p));
    let f1 = fold_half(comm, &ind1.dg, &ind1.orig, FoldTarget::high_half(p));
    let b0 = ind0.dg.footprint_bytes();
    let b1 = ind1.dg.footprint_bytes();
    drop(ind0);
    drop(ind1);
    mem.shrink(b0 + b1);
    let in_low = FoldTarget::low_half(p).contains(comm.rank());
    let sub = comm.split(if in_low { 0 } else { 1 });
    match (in_low, f0, f1) {
        (true, Some((dg0, pl0)), _) => {
            mem.grow(dg0.footprint_bytes());
            dissect(
                &sub,
                dg0,
                pl0,
                start,
                strat,
                refiner,
                separator,
                overlap,
                base_rng,
                mem,
                frags,
                dist_levels,
                depth * 2 + 1,
            );
        }
        (false, _, Some((dg1, pl1))) => {
            mem.grow(dg1.footprint_bytes());
            dissect(
                &sub,
                dg1,
                pl1,
                start + counts[0],
                strat,
                refiner,
                separator,
                overlap,
                base_rng,
                mem,
                frags,
                dist_levels,
                depth * 2 + 2,
            );
        }
        _ => unreachable!("fold targets partition the rank range"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm;
    use crate::graph::generators;
    use crate::order::symbolic_cholesky;
    use crate::sep::FmRefiner;
    use std::sync::Arc;

    fn order_at(p: usize, g: Arc<Graph>, spec: &str) -> Vec<ParallelOrderResult> {
        let strat = Strategy::parse(spec).unwrap();
        let (res, _) = comm::run(p, move |c| {
            let refiner = FmRefiner::default();
            parallel_order(&c, &g, &strat, &refiner, None)
        });
        res
    }

    #[test]
    fn valid_permutation_on_grid3d_across_1_2_4_ranks() {
        // The acceptance case: a 3D grid ordered on 1, 2 and 4 emulated
        // ranks must always yield a valid permutation.
        let g = Arc::new(generators::grid3d(7, 7, 7));
        for p in [1usize, 2, 4] {
            let res = order_at(p, g.clone(), "");
            assert_eq!(res.len(), p);
            for r in &res {
                r.ordering.validate().unwrap();
                assert_eq!(r.ordering.iperm, res[0].ordering.iperm, "p={p}");
            }
        }
    }

    #[test]
    fn works_on_non_power_of_two_ranks() {
        // The headline structural advantage over the comparator (§3.2).
        let g = Arc::new(generators::grid2d(18, 18));
        for p in [3usize, 5, 6] {
            let res = order_at(p, g.clone(), "");
            for r in &res {
                r.ordering.validate().unwrap();
            }
            assert!(res[0].dist_levels >= 1, "p={p}");
        }
    }

    #[test]
    fn quality_tracks_sequential() {
        let g = Arc::new(generators::grid2d(24, 24));
        let seq = order_at(1, g.clone(), "");
        let s_seq = symbolic_cholesky(&g, &seq[0].ordering);
        let par = order_at(4, g.clone(), "");
        let s_par = symbolic_cholesky(&g, &par[0].ordering);
        assert!(
            s_par.opc <= s_seq.opc * 1.6,
            "p=4 OPC {} vs sequential {}",
            s_par.opc,
            s_seq.opc
        );
    }

    #[test]
    fn deterministic_under_seed_and_overlap_toggle() {
        let g = Arc::new(generators::grid2d(16, 16));
        let a = order_at(4, g.clone(), "seed=5,overlap=1");
        let b = order_at(4, g.clone(), "seed=5,overlap=0");
        let c = order_at(4, g.clone(), "seed=5,overlap=1");
        assert_eq!(a[0].ordering.iperm, b[0].ordering.iperm);
        assert_eq!(a[0].ordering.iperm, c[0].ordering.iperm);
    }

    #[test]
    fn peak_memory_is_tracked() {
        let g = Arc::new(generators::grid2d(20, 20));
        let res = order_at(4, g, "");
        for r in &res {
            assert!(r.peak_mem > 0);
        }
    }
}
