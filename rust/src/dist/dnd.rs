//! Parallel nested dissection (paper §3.1).
//!
//! The PT-Scotch ordering driver: recursively compute a distributed
//! separator ([`crate::dist::dsep::dist_separator`]), emit the
//! separator's ordering fragment at the **top** of the current index
//! range (§2.2: separators take the highest available indices), build
//! the two induced subgraphs (optionally overlapped with an extra
//! thread per process, §3.1), fold each onto one half of the ranks
//! (any rank count — the comparator's power-of-two restriction does not
//! apply, §3.2), split the communicator, and recurse. When a branch
//! reaches a single rank, the sequential nested dissection of
//! [`crate::order::nd`] (multilevel separators + minimum-degree leaves)
//! finishes the job. Fragments are finally allgathered and assembled
//! into one inverse permutation, identical on every rank.
//!
//! **Halo carrying.** Under `leafmethod=hamd` (the default), each
//! distributed level keeps the ring of its freshly emitted separator
//! alive in the induced subgraphs as *halo* vertices
//! ([`crate::dist::induce::HALO_BIT`] on the payload): they are
//! excluded from every further separator and never re-emitted, but
//! they ride through folds and splits so that when a branch reaches
//! one rank, [`crate::order::nd::nested_dissection_with_halo`] sees
//! the same already-numbered separator ring a sequential run would —
//! and HAMD leaves get identical quality in both regimes. The
//! halo-blind `leafmethod=mmd` comparator never reads a ring, so it
//! takes the plain induction and carries nothing.

use super::dgraph::DGraph;
use super::dsep::dist_separator;
use super::fold::{fold_half, FoldTarget};
use super::induce::{induce_dist, induce_dist_halo, DistInduced, HALO_BIT};
use crate::comm::{Comm, MemTracker};
use crate::graph::Graph;
use crate::order::{assemble_fragments, nested_dissection_with_halo, OrderFragment, Ordering};
use crate::rng::Rng;
use crate::runtime::SharedRuntime;
use crate::sep::{BandRefiner, P0, P1, SEP};
use crate::strategy::{LeafMethod, Strategy};
use crate::trace;
use crate::Result;

/// Result of a parallel ordering run on one rank.
#[derive(Clone, Debug)]
pub struct ParallelOrderResult {
    /// The assembled global ordering (identical on every rank).
    pub ordering: Ordering,
    /// Peak tracked graph memory on this rank, in bytes (Figures 10–11).
    pub peak_mem: i64,
    /// Number of distributed dissection levels this rank participated in.
    pub dist_levels: usize,
}

/// Order `g` with PT-Scotch parallel nested dissection on the ranks of
/// `comm` (any count, including 1). Collective; every rank receives the
/// same valid [`Ordering`]. `xla` is the optional shared XLA runtime
/// handle used by the distributed band-diffusion engine dispatch
/// (DESIGN.md §4.2); pass `None` to pin the scalar CPU sweeps.
pub fn parallel_order(
    comm: &Comm,
    g: &Graph,
    strat: &Strategy,
    refiner: &dyn BandRefiner,
    xla: Option<&SharedRuntime>,
) -> ParallelOrderResult {
    // Root span of the whole distributed run: with a recorder installed
    // every other span nests under it, so the exclusive counter columns
    // of the profile tree tile exactly to the run totals (DESIGN.md §7).
    let _run = trace::scope_at(trace::Phase::Run, 0);
    let mem = MemTracker::new();
    let dg = DGraph::from_global(comm, g);
    mem.grow(dg.footprint_bytes());
    let payload: Vec<u64> = (0..dg.nloc()).map(|v| dg.glb(v)).collect();
    let base_rng = Rng::new(strat.seed);
    let mut frags = Vec::new();
    let mut dist_levels = 0usize;
    let separator = |c: &Comm, d: &DGraph, r: &Rng, m: &MemTracker| {
        dist_separator(c, d, strat, refiner, xla, r, m)
    };
    dissect(
        comm,
        dg,
        payload,
        0,
        strat,
        refiner,
        &separator,
        strat.dist.overlap_folds,
        &base_rng,
        &mem,
        &mut frags,
        &mut dist_levels,
        0,
    );
    let ordering = gather_and_assemble(comm, g.n(), &frags)
        .expect("parallel nested dissection covers all vertices");
    ParallelOrderResult {
        ordering,
        peak_mem: mem.peak(),
        dist_levels,
    }
}

/// Gather every rank's ordering fragments and assemble the global
/// inverse permutation (§2.2: fragments tile the index range exactly).
/// The wire format is shared by the PT-Scotch and baseline engines so
/// it lives in one place. Collective; identical result on every rank.
pub(crate) fn gather_and_assemble(
    comm: &Comm,
    n: usize,
    frags: &[OrderFragment],
) -> Result<Ordering> {
    let mut blob: Vec<u64> = Vec::new();
    for f in frags {
        blob.push(f.start as u64);
        blob.push(f.verts.len() as u64);
        blob.extend(f.verts.iter().map(|&v| v as u64));
    }
    let all = comm.allgatherv(blob);
    let mut all_frags = Vec::new();
    for b in &all {
        let mut i = 0usize;
        while i < b.len() {
            let (start, len) = (b[i] as usize, b[i + 1] as usize);
            i += 2;
            all_frags.push(OrderFragment {
                start,
                verts: b[i..i + len].iter().map(|&v| v as usize).collect(),
            });
            i += len;
        }
    }
    assemble_fragments(n, all_frags)
}

/// Part label of a carried halo vertex during one dissection level:
/// not in either side, not in the fresh separator — only a halo
/// candidate for the two inductions.
const HALO_PART: u8 = 3;

/// Build the two induced subgraphs — each side's core plus, when
/// `halo_cand` is `Some` (`leafmethod=hamd`), its separator/halo ring;
/// `None` (`leafmethod=mmd`, which never reads a halo) takes the plain
/// induction and skips the ring's exchange and carriage entirely.
/// Overlapped with an extra thread per rank on tag-scoped communicator
/// clones when the strategy asks for it (§3.1: the overlap "can be
/// disabled when the communication system is not thread-safe" and
/// never changes results — both inductions are deterministic).
fn induce_both(
    comm: &Comm,
    dg: &DGraph,
    keep0: &[bool],
    keep1: &[bool],
    halo_cand: Option<&[bool]>,
    payload: &[u64],
    overlap: bool,
) -> (DistInduced, DistInduced) {
    let one = |c: &Comm, keep: &[bool]| match halo_cand {
        Some(cand) => induce_dist_halo(c, dg, keep, cand, payload),
        None => induce_dist(c, dg, keep, payload),
    };
    if overlap {
        let c0 = comm.overlap_context(0);
        let c1 = comm.overlap_context(1);
        std::thread::scope(|s| {
            // `move` takes the owned `c1`; `one` and the slices are
            // shared-reference captures and copy into the thread. Both
            // bodies run under `Comm::guard` so a panic in either
            // transport thread raises the fleet abort immediately —
            // the sibling may be parked in a blocking pop that only
            // the abort wakeup can release (DESIGN.md §3.2).
            let h = s.spawn(move || c1.guard(|| one(&c1, keep1)));
            let i0 = c0.guard(|| one(&c0, keep0));
            // Propagate the thread's own unwind payload: an injected
            // panic (or the abort payload) must reach the rank-level
            // classifier intact, not stringified by an `expect`.
            let i1 = match h.join() {
                Ok(v) => v,
                Err(payload) => std::panic::resume_unwind(payload),
            };
            (i0, i1)
        })
    } else {
        (one(comm, keep0), one(comm, keep1))
    }
}

/// The recursive dissection driver, shared by the PT-Scotch engine and
/// the ParMETIS-like baseline — which, as the paper frames it, differ
/// only in how they bipartition. `separator` is the per-level policy
/// (called with a depth-derived rng root); `overlap` toggles the §3.1
/// induced-subgraph overlap thread. All fragment/start-offset
/// arithmetic and memory accounting live here, in one copy.
#[allow(clippy::too_many_arguments)]
pub(crate) fn dissect(
    comm: &Comm,
    dg: DGraph,
    payload: Vec<u64>,
    start: usize,
    strat: &Strategy,
    refiner: &dyn BandRefiner,
    separator: &dyn Fn(&Comm, &DGraph, &Rng, &MemTracker) -> Vec<u8>,
    overlap: bool,
    base_rng: &Rng,
    mem: &MemTracker,
    frags: &mut Vec<OrderFragment>,
    dist_levels: &mut usize,
    depth: u64,
) {
    // The caller tracked `dg`'s footprint; shrink it wherever `dg` dies
    // so `peak_mem` reports peak *live* memory, not cumulative growth.
    let dg_bytes = dg.footprint_bytes();
    // Under `leafmethod=mmd` no level ever sets HALO_BIT, so the whole
    // halo machinery (flag scan, count allreduce, ring induction) is
    // skipped — the strategy is identical on every rank, so the branch
    // is collectively consistent.
    let carry_halo = strat.nd.leaf_method == LeafMethod::Hamd;
    let halo_flags: Vec<bool> = if carry_halo {
        payload.iter().map(|&x| x & HALO_BIT != 0).collect()
    } else {
        vec![false; payload.len()]
    };
    if comm.size() == 1 {
        // One rank left: finish sequentially (§3.1's leaf case). The
        // carried halo ring flows into the sequential recursion so its
        // HAMD leaves see the distributed-level separators too.
        let local = dg.to_local();
        mem.grow(local.footprint_bytes());
        let mut rng = base_rng.derive(0x1EAF ^ (depth << 8));
        let ord = nested_dissection_with_halo(&local, &halo_flags, strat, refiner, &mut rng);
        frags.push(OrderFragment {
            start,
            verts: ord
                .iter()
                .map(|&lv| (payload[lv] & !HALO_BIT) as usize)
                .collect(),
        });
        mem.shrink(local.footprint_bytes() + dg_bytes);
        return;
    }
    if dg.nglb == 0 {
        mem.shrink(dg_bytes);
        return;
    }
    *dist_levels += 1;
    // The separator may only cut the core vertices: below the first
    // level the subgraph also carries the enclosing separators' halo
    // ring, which is already numbered. When a halo exists anywhere
    // (agreed collectively — induction is collective), the separator
    // runs on the core-induced subgraph and its labels scatter back.
    let nhalo_glb = if carry_halo {
        let nhalo_loc = halo_flags.iter().filter(|&&h| h).count();
        comm.allreduce_sum(nhalo_loc as i64)
    } else {
        0
    };
    let part: Vec<u8> = if nhalo_glb == 0 {
        separator(comm, &dg, &base_rng.derive(depth), mem)
    } else {
        let keep_core: Vec<bool> = halo_flags.iter().map(|&h| !h).collect();
        let idx_payload: Vec<u64> = (0..dg.nloc() as u64).collect();
        let core = induce_dist(comm, &dg, &keep_core, &idx_payload);
        mem.grow(core.dg.footprint_bytes());
        let core_part = separator(comm, &core.dg, &base_rng.derive(depth), mem);
        mem.shrink(core.dg.footprint_bytes());
        let mut full = vec![HALO_PART; dg.nloc()];
        for (i, &lv) in core.orig.iter().enumerate() {
            full[lv as usize] = core_part[i];
        }
        full
    };
    // One fused reduction for all three part counts — the per-level
    // collective count feeds the communication telemetry the benches
    // report, so don't pay three rounds for one vector. Halo vertices
    // carry their own label and count toward nothing: the index range
    // of this subproblem holds exactly its core vertices.
    let mine = [
        part.iter().filter(|&&x| x == P0).count() as i64,
        part.iter().filter(|&&x| x == P1).count() as i64,
        part.iter().filter(|&&x| x == SEP).count() as i64,
    ];
    let total = comm.allreduce(mine, |a, b| [a[0] + b[0], a[1] + b[1], a[2] + b[2]]);
    let counts = [total[0] as usize, total[1] as usize, total[2] as usize];
    let ncore_glb = counts[0] + counts[1] + counts[2];
    if comm.rank() == 0 {
        // One quality event per ND node, from the subgroup's rank 0
        // only, so merged traces carry each separator exactly once.
        trace::quality_at(
            depth as u32,
            counts[2] as u64,
            counts[0].abs_diff(counts[1]) as u64,
            strat.sep.band_width,
            strat.sep.refine.name(),
            0,
        );
    }
    let degenerate = counts[0] == 0
        || counts[1] == 0
        || counts[2] as f64 > ncore_glb as f64 * strat.nd.max_sep_fraction;
    if degenerate {
        // Near-clique or disconnected oddity: centralize and let rank 0
        // of this subgroup order the whole range sequentially (halo
        // ring included, exactly like the single-rank finish).
        let central = dg.centralize_all(comm);
        mem.grow(central.footprint_bytes());
        let all_payload = comm.allgatherv(payload.clone()).concat();
        if comm.rank() == 0 {
            let halo_all: Vec<bool> = all_payload.iter().map(|&x| x & HALO_BIT != 0).collect();
            let mut rng = base_rng.derive(0xD0 ^ depth);
            let ord = nested_dissection_with_halo(&central, &halo_all, strat, refiner, &mut rng);
            frags.push(OrderFragment {
                start,
                verts: ord
                    .iter()
                    .map(|&lv| (all_payload[lv] & !HALO_BIT) as usize)
                    .collect(),
            });
        }
        mem.shrink(central.footprint_bytes() + dg_bytes);
        return;
    }
    // Separator fragment: the highest indices of the range (§2.2), laid
    // out by ascending rank within the separator block.
    let my_sep: Vec<usize> = (0..dg.nloc()).filter(|&v| part[v] == SEP).collect();
    let sep_offset = comm.exscan_sum(my_sep.len() as u64) as usize;
    if !my_sep.is_empty() {
        frags.push(OrderFragment {
            start: start + counts[0] + counts[1] + sep_offset,
            verts: my_sep.iter().map(|&v| payload[v] as usize).collect(),
        });
    }
    // Under `leafmethod=hamd` each side keeps its core vertices plus
    // the adjacent ring of the fresh separator and of the inherited
    // halo (HALO_BIT set by the induction; ring members not adjacent
    // to the side are dropped). The halo-blind `leafmethod=mmd` never
    // reads a ring, so it takes the plain induction — same recursion
    // shape, no ring exchange or carriage.
    let keep0: Vec<bool> = part.iter().map(|&x| x == P0).collect();
    let keep1: Vec<bool> = part.iter().map(|&x| x == P1).collect();
    let halo_cand: Option<Vec<bool>> =
        carry_halo.then(|| part.iter().map(|&x| x == SEP || x == HALO_PART).collect());
    let (ind0, ind1) = {
        // The §3.1 overlap thread is sinkless: its traffic lands on the
        // shared rank counters and is attributed to this span when it
        // closes (the `thread::scope` join happens inside the call).
        let _span = trace::scope_at(trace::Phase::Induce, depth as u32);
        induce_both(
            comm,
            &dg,
            &keep0,
            &keep1,
            halo_cand.as_deref(),
            &payload,
            overlap,
        )
    };
    mem.grow(ind0.dg.footprint_bytes() + ind1.dg.footprint_bytes());
    drop(dg);
    drop(payload);
    mem.shrink(dg_bytes);
    // Fold part 0 onto the low half of the ranks and part 1 onto the
    // high half (any p — no power-of-two restriction, §3.2), then split
    // and recurse on whichever half this rank joined.
    let p = comm.size();
    let fold_span = trace::scope_at(trace::Phase::Fold, depth as u32);
    let f0 = fold_half(comm, &ind0.dg, &ind0.orig, FoldTarget::low_half(p));
    let f1 = fold_half(comm, &ind1.dg, &ind1.orig, FoldTarget::high_half(p));
    drop(fold_span);
    let b0 = ind0.dg.footprint_bytes();
    let b1 = ind1.dg.footprint_bytes();
    drop(ind0);
    drop(ind1);
    mem.shrink(b0 + b1);
    let in_low = FoldTarget::low_half(p).contains(comm.rank());
    let sub = comm.split(if in_low { 0 } else { 1 });
    match (in_low, f0, f1) {
        (true, Some((dg0, pl0)), _) => {
            mem.grow(dg0.footprint_bytes());
            dissect(
                &sub,
                dg0,
                pl0,
                start,
                strat,
                refiner,
                separator,
                overlap,
                base_rng,
                mem,
                frags,
                dist_levels,
                depth * 2 + 1,
            );
        }
        (false, _, Some((dg1, pl1))) => {
            mem.grow(dg1.footprint_bytes());
            dissect(
                &sub,
                dg1,
                pl1,
                start + counts[0],
                strat,
                refiner,
                separator,
                overlap,
                base_rng,
                mem,
                frags,
                dist_levels,
                depth * 2 + 2,
            );
        }
        _ => unreachable!("fold targets partition the rank range"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm;
    use crate::graph::generators;
    use crate::order::symbolic_cholesky;
    use crate::sep::FmRefiner;
    use std::sync::Arc;

    fn order_at(p: usize, g: Arc<Graph>, spec: &str) -> Vec<ParallelOrderResult> {
        let strat = Strategy::parse(spec).unwrap();
        let (res, _) = comm::run(p, move |c| {
            let refiner = FmRefiner::default();
            parallel_order(&c, &g, &strat, &refiner, None)
        });
        res
    }

    #[test]
    fn valid_permutation_on_grid3d_across_1_2_4_ranks() {
        // The acceptance case: a 3D grid ordered on 1, 2 and 4 emulated
        // ranks must always yield a valid permutation.
        let g = Arc::new(generators::grid3d(7, 7, 7));
        for p in [1usize, 2, 4] {
            let res = order_at(p, g.clone(), "");
            assert_eq!(res.len(), p);
            for r in &res {
                r.ordering.validate().unwrap();
                assert_eq!(r.ordering.iperm, res[0].ordering.iperm, "p={p}");
            }
        }
    }

    #[test]
    fn works_on_non_power_of_two_ranks() {
        // The headline structural advantage over the comparator (§3.2).
        let g = Arc::new(generators::grid2d(18, 18));
        for p in [3usize, 5, 6] {
            let res = order_at(p, g.clone(), "");
            for r in &res {
                r.ordering.validate().unwrap();
            }
            assert!(res[0].dist_levels >= 1, "p={p}");
        }
    }

    #[test]
    fn quality_tracks_sequential() {
        let g = Arc::new(generators::grid2d(24, 24));
        let seq = order_at(1, g.clone(), "");
        let s_seq = symbolic_cholesky(&g, &seq[0].ordering);
        let par = order_at(4, g.clone(), "");
        let s_par = symbolic_cholesky(&g, &par[0].ordering);
        assert!(
            s_par.opc <= s_seq.opc * 1.6,
            "p=4 OPC {} vs sequential {}",
            s_par.opc,
            s_seq.opc
        );
    }

    #[test]
    fn deterministic_under_seed_and_overlap_toggle() {
        let g = Arc::new(generators::grid2d(16, 16));
        let a = order_at(4, g.clone(), "seed=5,overlap=1");
        let b = order_at(4, g.clone(), "seed=5,overlap=0");
        let c = order_at(4, g.clone(), "seed=5,overlap=1");
        assert_eq!(a[0].ordering.iperm, b[0].ordering.iperm);
        assert_eq!(a[0].ordering.iperm, c[0].ordering.iperm);
    }

    #[test]
    fn hamd_leaves_with_carried_halo_stay_valid_across_p() {
        // The halo ring rides through inductions, folds and splits; on
        // any rank count the result must stay a valid permutation,
        // identical on every rank.
        let g = Arc::new(generators::grid3d(8, 8, 8));
        for p in [2usize, 3, 5] {
            let res = order_at(p, g.clone(), "leafmethod=hamd");
            for r in &res {
                r.ordering.validate().unwrap();
                assert_eq!(r.ordering.iperm, res[0].ordering.iperm, "p={p}");
            }
        }
    }

    #[test]
    fn carried_halo_never_hurts_vs_halo_blind_leaves() {
        // Distributed ordering with halo-aware HAMD leaves must at
        // least match the halo-blind MMD leaves (the bench asserts the
        // strict improvement at scale; tier 1 pins "not worse").
        let g = Arc::new(generators::grid3d(9, 9, 9));
        let h = order_at(4, g.clone(), "leafmethod=hamd");
        let m = order_at(4, g.clone(), "leafmethod=mmd");
        let s_h = symbolic_cholesky(&g, &h[0].ordering);
        let s_m = symbolic_cholesky(&g, &m[0].ordering);
        assert!(
            s_h.opc <= s_m.opc * 1.05,
            "hamd {} vs mmd {}",
            s_h.opc,
            s_m.opc
        );
    }

    #[test]
    fn peak_memory_is_tracked() {
        let g = Arc::new(generators::grid2d(20, 20));
        let res = order_at(4, g, "");
        for r in &res {
            assert!(r.peak_mem > 0);
        }
    }
}
