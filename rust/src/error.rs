//! Crate-wide error type.

use std::fmt;

/// Errors produced by the ptscotch library.
///
/// `Clone` so the batch coordinator can hand one failed job's error to
/// every request coalesced onto that job (DESIGN.md §6).
#[derive(Clone, Debug)]
pub enum Error {
    /// Malformed graph structure (asymmetric adjacency, out-of-range ids…).
    InvalidGraph(String),
    /// Invalid ordering / permutation.
    InvalidOrdering(String),
    /// Invalid strategy or configuration value.
    InvalidStrategy(String),
    /// Distributed-layer error (rank mismatch, fold failure…).
    Dist(String),
    /// The ParMETIS-like baseline only supports power-of-two process
    /// counts (the limitation the paper calls out in §3.2).
    NonPowerOfTwo(usize),
    /// I/O or parse error.
    Io(String),
    /// XLA/PJRT runtime error.
    Runtime(String),
    /// No AOT artifact available for the requested kernel/size bucket.
    NoArtifact(String),
    /// A rank thread of the in-process fleet panicked. The abort
    /// protocol (DESIGN.md §3.2) unwound every surviving rank instead
    /// of letting the process die or the fleet hang, so the fallible
    /// run entry points surface this as an error.
    RankPanicked {
        /// Global rank whose program panicked.
        rank: usize,
        /// The panic message (for injected faults, a description of
        /// the scripted trigger).
        message: String,
    },
    /// A blocking transport wait exceeded the configured stall
    /// deadline: some rank stopped making progress without panicking
    /// (DESIGN.md §3.2). The whole fleet is unwound and the run fails
    /// with this error instead of hanging.
    FleetStalled {
        /// Global rank whose wait timed out (or whose injected stall
        /// expired unnoticed).
        rank: usize,
        /// Description of the transport operation that stalled.
        op: String,
    },
    /// A configuration environment variable (`PTSCOTCH_EXECUTOR`,
    /// `PTSCOTCH_FAULT`, …) held an unusable value. Surfaced through
    /// the service and CLI instead of aborting the process.
    BadEnv(String),
}

impl Error {
    /// Is this a fleet-level fault — a rank panic or a stalled fleet —
    /// that a service-level retry may recover from? Deterministic
    /// errors (bad strategy, missing artifact, …) would simply recur,
    /// so the recovery ladder (DESIGN.md §6) only re-runs on these.
    pub fn is_fleet_fault(&self) -> bool {
        matches!(
            self,
            Error::RankPanicked { .. } | Error::FleetStalled { .. }
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidGraph(m) => write!(f, "invalid graph: {m}"),
            Error::InvalidOrdering(m) => write!(f, "invalid ordering: {m}"),
            Error::InvalidStrategy(m) => write!(f, "invalid strategy: {m}"),
            Error::Dist(m) => write!(f, "distributed error: {m}"),
            Error::NonPowerOfTwo(p) => {
                write!(f, "baseline requires a power-of-two process count, got {p}")
            }
            Error::Io(m) => write!(f, "io error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::NoArtifact(m) => write!(f, "no artifact: {m}"),
            Error::RankPanicked { rank, message } => {
                write!(f, "rank {rank} panicked: {message}")
            }
            Error::FleetStalled { rank, op } => {
                write!(f, "fleet stalled: rank {rank} exceeded the stall deadline in {op}")
            }
            Error::BadEnv(m) => write!(f, "bad environment: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
