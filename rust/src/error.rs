//! Crate-wide error type.

use std::fmt;

/// Errors produced by the ptscotch library.
///
/// `Clone` so the batch coordinator can hand one failed job's error to
/// every request coalesced onto that job (DESIGN.md §6).
#[derive(Clone, Debug)]
pub enum Error {
    /// Malformed graph structure (asymmetric adjacency, out-of-range ids…).
    InvalidGraph(String),
    /// Invalid ordering / permutation.
    InvalidOrdering(String),
    /// Invalid strategy or configuration value.
    InvalidStrategy(String),
    /// Distributed-layer error (rank mismatch, fold failure…).
    Dist(String),
    /// The ParMETIS-like baseline only supports power-of-two process
    /// counts (the limitation the paper calls out in §3.2).
    NonPowerOfTwo(usize),
    /// I/O or parse error.
    Io(String),
    /// XLA/PJRT runtime error.
    Runtime(String),
    /// No AOT artifact available for the requested kernel/size bucket.
    NoArtifact(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidGraph(m) => write!(f, "invalid graph: {m}"),
            Error::InvalidOrdering(m) => write!(f, "invalid ordering: {m}"),
            Error::InvalidStrategy(m) => write!(f, "invalid strategy: {m}"),
            Error::Dist(m) => write!(f, "distributed error: {m}"),
            Error::NonPowerOfTwo(p) => {
                write!(f, "baseline requires a power-of-two process count, got {p}")
            }
            Error::Io(m) => write!(f, "io error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::NoArtifact(m) => write!(f, "no artifact: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
