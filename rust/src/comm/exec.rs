//! Executor selection for the rank fleet (DESIGN.md §3).
//!
//! The same rank programs run on two interchangeable executors:
//!
//! * [`Executor::Sim`] — the *serialized-transport simulator*: every
//!   mailbox operation goes through one global lock and one global
//!   condition variable, so transport activity is sequentially ordered
//!   one operation at a time. It is the obviously-correct reference
//!   fabric and the oracle of the differential test harness
//!   (`rust/tests/executor_diff.rs`). Default everywhere, so tests run
//!   against the oracle unless explicitly switched.
//! * [`Executor::Threads`] — the *free-running threaded executor*: one
//!   channel-backed mailbox per ordered (receiver, sender) peer pair,
//!   each with its own lock and condition variable, so disjoint pairs
//!   never contend and a receiver wakes only on its own traffic. This
//!   is the performance fabric that turns p-rank runs into real
//!   parallelism on multicore hosts.
//!
//! Both executors drive one OS thread per rank and expose the exact
//! same [`crate::comm::Comm`] API; the determinism contract (DESIGN.md
//! §3) guarantees bit-identical results either way, which the
//! differential suite enforces on every tested (graph, p, seed) triple.
//!
//! Selection: the `executor=` strategy knob when the run goes through
//! the coordinator, else the `PTSCOTCH_EXECUTOR` environment variable
//! (`sim` | `threads`), else [`Executor::Sim`].

use std::fmt;
use std::str::FromStr;

/// Which executor drives the rank fleet of [`crate::comm::run_on`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum Executor {
    /// Serialized-transport simulator: one global mailbox lock, the
    /// deterministic differential oracle (default).
    #[default]
    Sim,
    /// Free-running OS-thread-per-rank executor with one mailbox per
    /// (receiver, sender) peer pair.
    Threads,
}

/// Environment variable consulted by [`Executor::from_env`] (and thus
/// by [`crate::comm::run`]): `sim` or `threads`, case-insensitive.
pub const EXECUTOR_ENV: &str = "PTSCOTCH_EXECUTOR";

impl Executor {
    /// The lower-case knob/row name of this executor.
    ///
    /// ```
    /// use ptscotch::comm::Executor;
    /// assert_eq!(Executor::Sim.name(), "sim");
    /// assert_eq!(Executor::Threads.name(), "threads");
    /// ```
    pub fn name(self) -> &'static str {
        match self {
            Executor::Sim => "sim",
            Executor::Threads => "threads",
        }
    }

    /// Resolve the executor from [`EXECUTOR_ENV`]; unset or empty means
    /// [`Executor::Sim`]. A set-but-unrecognized value is a structured
    /// [`crate::Error::BadEnv`] surfaced through the service and CLI —
    /// a misspelled executor silently falling back to the simulator
    /// would invalidate every "threaded" measurement taken under it,
    /// and a `panic!` here used to kill the whole process instead of
    /// failing the one request.
    pub fn from_env() -> crate::Result<Executor> {
        match std::env::var(EXECUTOR_ENV) {
            Ok(v) if v.trim().is_empty() => Ok(Executor::Sim),
            Ok(v) => v
                .parse()
                .map_err(|e: String| crate::Error::BadEnv(format!("{EXECUTOR_ENV}: {e}"))),
            Err(_) => Ok(Executor::Sim),
        }
    }
}

impl fmt::Display for Executor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for Executor {
    type Err = String;

    fn from_str(s: &str) -> Result<Executor, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "sim" => Ok(Executor::Sim),
            "threads" => Ok(Executor::Threads),
            other => Err(format!("unknown executor {other:?} (sim|threads)")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_both_names() {
        assert_eq!("sim".parse::<Executor>().unwrap(), Executor::Sim);
        assert_eq!("threads".parse::<Executor>().unwrap(), Executor::Threads);
        assert_eq!(" Threads ".parse::<Executor>().unwrap(), Executor::Threads);
        assert!("hybrid".parse::<Executor>().is_err());
    }

    #[test]
    fn default_is_the_oracle() {
        assert_eq!(Executor::default(), Executor::Sim);
    }

    #[test]
    fn display_round_trips() {
        for e in [Executor::Sim, Executor::Threads] {
            assert_eq!(e.to_string().parse::<Executor>().unwrap(), e);
        }
    }
}
