//! In-process MPI-like communicator (S9).
//!
//! PT-Scotch is an MPI program; this container has no MPI (and one core),
//! so we reproduce the *programming model* instead of the transport: one
//! OS thread per rank, typed point-to-point messages with tag matching,
//! the collectives the algorithms need (barrier, allgatherv, allreduce,
//! alltoallv, broadcast, exclusive scan), communicator splitting for the
//! recursive nested-dissection subgroups, and per-rank traffic counters
//! that substitute for wallclock in the scalability analysis
//! (DESIGN.md §3). The distributed algorithms in [`crate::dist`] only see
//! this API and would map 1:1 onto MPI.

pub mod stats;

pub use stats::{MemTracker, StatsSnapshot};

use std::any::Any;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering as AOrd};
use std::sync::{Arc, Condvar, Mutex};

/// One in-flight message.
struct Packet {
    src: usize, // global rank
    tag: u64,
    data: Box<dyn Any + Send>,
}

/// Per-thread mailbox: a deque of packets plus a wakeup condvar.
#[derive(Default)]
struct Mailbox {
    queue: Mutex<VecDeque<Packet>>,
    avail: Condvar,
}

/// Shared transport: one mailbox per global rank + traffic counters.
struct Transport {
    boxes: Vec<Mailbox>,
    sent_bytes: Vec<AtomicU64>,
    sent_msgs: Vec<AtomicU64>,
}

/// A communicator handle held by one rank (thread). Sub-communicators
/// created by [`Comm::split`] share the transport but re-rank members.
pub struct Comm {
    /// Global rank (thread index) of this endpoint.
    grank: usize,
    /// Rank within this communicator.
    rank: usize,
    /// Global ranks of the members, ascending; `members[rank] == grank`.
    members: Arc<Vec<usize>>,
    /// Tag namespace of this communicator (prevents cross-group mixups
    /// when sibling subgroups run concurrently).
    scope: u64,
    /// Monotonic per-communicator collective counter (all members call
    /// collectives in the same order, so it stays in sync).
    op_seq: std::cell::Cell<u64>,
    transport: Arc<Transport>,
}

/// Spawn `p` ranks, run `f(comm)` on each, join, and return the results
/// in rank order together with the traffic statistics.
pub fn run<R, F>(p: usize, f: F) -> (Vec<R>, StatsSnapshot)
where
    R: Send + 'static,
    F: Fn(Comm) -> R + Send + Sync + 'static,
{
    assert!(p >= 1, "need at least one rank");
    let transport = Arc::new(Transport {
        boxes: (0..p).map(|_| Mailbox::default()).collect(),
        sent_bytes: (0..p).map(|_| AtomicU64::new(0)).collect(),
        sent_msgs: (0..p).map(|_| AtomicU64::new(0)).collect(),
    });
    let members = Arc::new((0..p).collect::<Vec<_>>());
    let f = Arc::new(f);
    let mut handles = Vec::with_capacity(p);
    for r in 0..p {
        let comm = Comm {
            grank: r,
            rank: r,
            members: members.clone(),
            scope: 0x5c07c4,
            op_seq: std::cell::Cell::new(0),
            transport: transport.clone(),
        };
        let f = f.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("rank{r}"))
                .stack_size(16 << 20)
                .spawn(move || f(comm))
                .expect("spawn rank thread"),
        );
    }
    let results: Vec<R> = handles
        .into_iter()
        .map(|h| h.join().expect("rank thread panicked"))
        .collect();
    let stats = StatsSnapshot {
        bytes_sent: transport
            .sent_bytes
            .iter()
            .map(|a| a.load(AOrd::Relaxed))
            .collect(),
        msgs_sent: transport
            .sent_msgs
            .iter()
            .map(|a| a.load(AOrd::Relaxed))
            .collect(),
    };
    (results, stats)
}

impl Comm {
    /// Rank within this communicator.
    #[inline]
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of ranks in this communicator.
    #[inline]
    pub fn size(&self) -> usize {
        self.members.len()
    }

    /// Global (thread) rank — stable across splits; used to derive
    /// deterministic per-rank RNG streams.
    #[inline]
    pub fn global_rank(&self) -> usize {
        self.grank
    }

    fn scoped(&self, tag: u64) -> u64 {
        // Mix the scope into user tags; reserve the top bit for collectives.
        (self
            .scope
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(tag))
            & !(1 << 63)
    }

    fn next_coll_tag(&self) -> u64 {
        let s = self.op_seq.get();
        self.op_seq.set(s + 1);
        (1 << 63)
            | (self
                .scope
                .wrapping_mul(0x2545_F491_4F6C_DD1D)
                .wrapping_add(s)
                >> 1)
    }

    fn send_raw(&self, to_local: usize, tag: u64, data: Box<dyn Any + Send>, bytes: usize) {
        let dst = self.members[to_local];
        let t = &self.transport;
        t.sent_bytes[self.grank].fetch_add(bytes as u64, AOrd::Relaxed);
        t.sent_msgs[self.grank].fetch_add(1, AOrd::Relaxed);
        let mut q = t.boxes[dst].queue.lock().unwrap();
        q.push_back(Packet {
            src: self.grank,
            tag,
            data,
        });
        t.boxes[dst].avail.notify_all();
    }

    fn recv_raw(&self, from_local: usize, tag: u64) -> Box<dyn Any + Send> {
        let src = self.members[from_local];
        let mbox = &self.transport.boxes[self.grank];
        let mut q = mbox.queue.lock().unwrap();
        loop {
            if let Some(pos) = q.iter().position(|p| p.src == src && p.tag == tag) {
                return q.remove(pos).unwrap().data;
            }
            q = mbox.avail.wait(q).unwrap();
        }
    }

    /// Send a typed vector to `to` (local rank) with a user tag.
    pub fn send<T: Send + 'static>(&self, to: usize, tag: u64, data: Vec<T>) {
        let bytes = data.len() * std::mem::size_of::<T>();
        self.send_raw(to, self.scoped(tag), Box::new(data), bytes);
    }

    /// Receive a typed vector from `from` (local rank) with a user tag.
    /// Panics on type mismatch — a programming error, like an MPI
    /// datatype mismatch.
    pub fn recv<T: Send + 'static>(&self, from: usize, tag: u64) -> Vec<T> {
        *self
            .recv_raw(from, self.scoped(tag))
            .downcast::<Vec<T>>()
            .expect("message type mismatch")
    }

    /// Barrier over this communicator (gather-to-root + broadcast).
    pub fn barrier(&self) {
        let tag = self.next_coll_tag();
        if self.rank == 0 {
            for r in 1..self.size() {
                let _: Box<dyn Any + Send> = self.recv_raw(r, tag);
            }
            for r in 1..self.size() {
                self.send_raw(r, tag, Box::new(Vec::<u8>::new()), 0);
            }
        } else if self.size() > 1 {
            self.send_raw(0, tag, Box::new(Vec::<u8>::new()), 0);
            let _ = self.recv_raw(0, tag);
        }
    }

    /// Gather each rank's vector on every rank (returned in rank order).
    pub fn allgatherv<T: Clone + Send + 'static>(&self, mine: Vec<T>) -> Vec<Vec<T>> {
        let tag = self.next_coll_tag();
        let p = self.size();
        if p == 1 {
            return vec![mine];
        }
        if self.rank == 0 {
            let mut all = Vec::with_capacity(p);
            all.push(mine);
            for r in 1..p {
                all.push(*self.recv_raw(r, tag).downcast::<Vec<T>>().unwrap());
            }
            let bytes: usize = all.iter().map(|v| v.len() * std::mem::size_of::<T>()).sum();
            for r in 1..p {
                self.send_raw(r, tag, Box::new(all.clone()), bytes);
            }
            all
        } else {
            let bytes = mine.len() * std::mem::size_of::<T>();
            self.send_raw(0, tag, Box::new(mine), bytes);
            *self.recv_raw(0, tag).downcast::<Vec<Vec<T>>>().unwrap()
        }
    }

    /// All-reduce with an arbitrary associative fold over per-rank values.
    pub fn allreduce<T, F>(&self, mine: T, fold: F) -> T
    where
        T: Clone + Send + 'static,
        F: Fn(T, T) -> T,
    {
        let all = self.allgatherv(vec![mine]);
        let mut it = all.into_iter().map(|mut v| v.pop().expect("one value"));
        let first = it.next().expect("at least one rank");
        it.fold(first, fold)
    }

    /// Sum-all-reduce of an `i64`.
    pub fn allreduce_sum(&self, v: i64) -> i64 {
        self.allreduce(v, |a, b| a + b)
    }

    /// Exclusive prefix sum across ranks (rank 0 gets 0).
    pub fn exscan_sum(&self, v: u64) -> u64 {
        let all = self.allgatherv(vec![v]);
        all.iter().take(self.rank).map(|x| x[0]).sum()
    }

    /// Personalized all-to-all: `out[r]` goes to rank `r`; returns the
    /// vectors received from each rank (in rank order).
    pub fn alltoallv<T: Send + 'static>(&self, out: Vec<Vec<T>>) -> Vec<Vec<T>> {
        assert_eq!(out.len(), self.size());
        let tag = self.next_coll_tag();
        let p = self.size();
        let mut mine: Option<Vec<T>> = None;
        // Deterministic order: send ascending, then receive ascending.
        for (r, data) in out.into_iter().enumerate() {
            if r == self.rank {
                mine = Some(data);
                continue;
            }
            let bytes = data.len() * std::mem::size_of::<T>();
            self.send_raw(r, tag, Box::new(data), bytes);
        }
        let mut result: Vec<Vec<T>> = Vec::with_capacity(p);
        for r in 0..p {
            if r == self.rank {
                result.push(mine.take().expect("own slot"));
            } else {
                result.push(*self.recv_raw(r, tag).downcast::<Vec<T>>().unwrap());
            }
        }
        result
    }

    /// Broadcast from `root` to every rank.
    pub fn bcast<T: Clone + Send + 'static>(&self, root: usize, data: Option<Vec<T>>) -> Vec<T> {
        let tag = self.next_coll_tag();
        if self.rank == root {
            let data = data.expect("root must supply data");
            let bytes = data.len() * std::mem::size_of::<T>();
            for r in 0..self.size() {
                if r != root {
                    self.send_raw(r, tag, Box::new(data.clone()), bytes);
                }
            }
            data
        } else {
            *self.recv_raw(root, tag).downcast::<Vec<T>>().unwrap()
        }
    }

    /// Split into sub-communicators by color. Collective. Members of each
    /// color are re-ranked by ascending parent rank. Sibling groups get
    /// distinct tag scopes derived from the color.
    pub fn split(&self, color: usize) -> Comm {
        let colors = self.allgatherv(vec![color]);
        let members: Vec<usize> = (0..self.size())
            .filter(|&r| colors[r][0] == color)
            .map(|r| self.members[r])
            .collect();
        let rank = members
            .iter()
            .position(|&g| g == self.grank)
            .expect("caller is a member of its own color");
        Comm {
            grank: self.grank,
            rank,
            members: Arc::new(members),
            scope: self.scope.wrapping_mul(31).wrapping_add(color as u64 + 1),
            op_seq: std::cell::Cell::new(0),
            transport: self.transport.clone(),
        }
    }

    /// A derived endpoint with a distinct tag scope for use by an overlap
    /// thread on the *same* rank (§3.1 builds the two induced subgraphs
    /// concurrently). The clone talks to the same peers; tag scoping
    /// keeps the two contexts' messages apart.
    pub fn overlap_context(&self, ctx: u64) -> Comm {
        Comm {
            grank: self.grank,
            rank: self.rank,
            members: self.members.clone(),
            scope: self.scope.wrapping_mul(131).wrapping_add(ctx + 7),
            op_seq: std::cell::Cell::new(0),
            transport: self.transport.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2p_roundtrip() {
        let (res, stats) = run(2, |c| {
            if c.rank() == 0 {
                c.send(1, 7, vec![1u64, 2, 3]);
                0u64
            } else {
                let v: Vec<u64> = c.recv(0, 7);
                v.iter().sum()
            }
        });
        assert_eq!(res, vec![0, 6]);
        assert_eq!(stats.msgs_sent[0], 1);
        assert_eq!(stats.bytes_sent[0], 24);
    }

    #[test]
    fn tag_matching_out_of_order() {
        let (res, _) = run(2, |c| {
            if c.rank() == 0 {
                c.send(1, 1, vec![10i32]);
                c.send(1, 2, vec![20i32]);
                0
            } else {
                // Receive in reverse tag order.
                let b: Vec<i32> = c.recv(0, 2);
                let a: Vec<i32> = c.recv(0, 1);
                a[0] + b[0] * 100
            }
        });
        assert_eq!(res[1], 2010);
    }

    #[test]
    fn allgatherv_orders_by_rank() {
        let (res, _) = run(4, |c| {
            let all = c.allgatherv(vec![c.rank() as u64 * 10]);
            all.iter().map(|v| v[0]).collect::<Vec<_>>()
        });
        for r in res {
            assert_eq!(r, vec![0, 10, 20, 30]);
        }
    }

    #[test]
    fn allreduce_and_exscan() {
        let (res, _) = run(5, |c| {
            let sum = c.allreduce_sum(c.rank() as i64 + 1);
            let ex = c.exscan_sum((c.rank() as u64 + 1) * 2);
            (sum, ex)
        });
        for (r, (sum, ex)) in res.iter().enumerate() {
            assert_eq!(*sum, 15);
            assert_eq!(*ex, (0..r).map(|k| (k as u64 + 1) * 2).sum::<u64>());
        }
    }

    #[test]
    fn alltoallv_personalizes() {
        let (res, _) = run(3, |c| {
            let out: Vec<Vec<u32>> = (0..3)
                .map(|dst| vec![(c.rank() * 10 + dst) as u32])
                .collect();
            let inn = c.alltoallv(out);
            inn.iter().map(|v| v[0]).collect::<Vec<u32>>()
        });
        assert_eq!(res[0], vec![0, 10, 20]);
        assert_eq!(res[1], vec![1, 11, 21]);
        assert_eq!(res[2], vec![2, 12, 22]);
    }

    #[test]
    fn bcast_from_nonzero_root() {
        let (res, _) = run(4, |c| {
            let data = if c.rank() == 2 {
                Some(vec![9u8, 8])
            } else {
                None
            };
            c.bcast(2, data)
        });
        for r in res {
            assert_eq!(r, vec![9, 8]);
        }
    }

    #[test]
    fn split_creates_independent_groups() {
        let (res, _) = run(6, |c| {
            let half = if c.rank() < 3 { 0 } else { 1 };
            let sub = c.split(half);
            // Each subgroup sums its own members' global ranks.
            let s = sub.allreduce_sum(c.rank() as i64);
            (sub.rank(), sub.size(), s)
        });
        assert_eq!(res[0], (0, 3, 3)); // 0+1+2
        assert_eq!(res[4], (1, 3, 12)); // 3+4+5
    }

    #[test]
    fn split_uneven_sizes() {
        // ⌈5/2⌉ = 3 and ⌊5/2⌋ = 2 — the any-P property PT-Scotch claims.
        let (res, _) = run(5, |c| {
            let half = if c.rank() < 3 { 0 } else { 1 };
            let sub = c.split(half);
            sub.size()
        });
        assert_eq!(res, vec![3, 3, 3, 2, 2]);
    }

    #[test]
    fn barrier_completes() {
        let (res, _) = run(4, |c| {
            for _ in 0..10 {
                c.barrier();
            }
            true
        });
        assert!(res.iter().all(|&x| x));
    }

    #[test]
    fn nested_splits() {
        let (res, _) = run(8, |c| {
            let s1 = c.split(c.rank() / 4);
            let s2 = s1.split(s1.rank() / 2);
            (s2.size(), s2.allreduce_sum(1))
        });
        for r in res {
            assert_eq!(r, (2, 2));
        }
    }

    #[test]
    fn overlap_contexts_do_not_cross_talk() {
        let (res, _) = run(2, |c| {
            let ca = c.overlap_context(0);
            let cb = c.overlap_context(1);
            if c.rank() == 0 {
                cb.send(1, 3, vec![2u8]);
                ca.send(1, 3, vec![1u8]);
                0u8
            } else {
                let a: Vec<u8> = ca.recv(0, 3);
                let b: Vec<u8> = cb.recv(0, 3);
                a[0] * 10 + b[0]
            }
        });
        assert_eq!(res[1], 12);
    }
}
