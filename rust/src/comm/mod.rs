//! In-process MPI-like communicator (S9), now with two executors.
//!
//! PT-Scotch is an MPI program; this container has no MPI, so we
//! reproduce the *programming model* instead of the transport: one OS
//! thread per rank, typed point-to-point messages with tag matching,
//! the collectives the algorithms need (barrier, allgatherv, allreduce,
//! alltoallv, broadcast, exclusive scan), communicator splitting for
//! the recursive nested-dissection subgroups, and per-rank traffic
//! counters plus busy/blocked wallclock that feed the scalability
//! analysis (DESIGN.md §3). The distributed algorithms in
//! [`crate::dist`] only see this API and would map 1:1 onto MPI.
//!
//! The same rank programs run on either of two executors
//! ([`Executor`], DESIGN.md §3):
//!
//! * **`Executor::Sim`** (default) — the serialized-transport
//!   simulator: every mailbox operation happens under one global state
//!   lock, so transport activity forms a single total order. This is
//!   the obviously-correct oracle the differential harness
//!   (`rust/tests/executor_diff.rs`) pins the threaded executor
//!   against.
//! * **`Executor::Threads`** — the free-running executor: one
//!   channel-backed mailbox per ordered (receiver, sender) peer pair,
//!   each with its own lock and wakeup, so disjoint peer pairs never
//!   contend and real parallel speedup is measurable on multicore
//!   hosts.
//!
//! **Determinism contract.** Results are schedule-independent by
//! construction — every receive names its source rank, tags are scoped
//! per communicator, and collectives are sequence-numbered — so both
//! executors produce bit-identical results and identical
//! `sent_bytes`/`sent_msgs` tallies for the same program
//! (`rust/tests/traffic.rs` pins this). Only the wallclock columns of
//! [`StatsSnapshot`] may differ between executors.

pub mod exec;
pub mod stats;

pub use exec::Executor;
pub use stats::{MemTracker, StatsSnapshot};

use std::any::Any;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering as AOrd};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// One in-flight message. The source rank is implicit in the mailbox
/// the packet sits in (one queue per ordered (receiver, sender) pair).
struct Packet {
    tag: u64,
    data: Box<dyn Any + Send>,
}

/// A threaded-executor mailbox: one (receiver, sender) pair's deque
/// plus its private wakeup condvar.
#[derive(Default)]
struct Mailbox {
    queue: Mutex<VecDeque<Packet>>,
    avail: Condvar,
}

/// The message fabric under the rank fleet — the part of the transport
/// the [`Executor`] choice swaps out. Both variants hold `p * p` queues
/// indexed `dst * p + src`; they differ in locking granularity.
enum Fabric {
    /// Serialized oracle: all queues behind one state lock (a total
    /// order over every mailbox operation), one wakeup condvar per
    /// receiving rank so a push only wakes that receiver's waiters.
    Sim {
        /// All `p * p` queues, guarded by the single global lock.
        state: Mutex<Vec<VecDeque<Packet>>>,
        /// Per-receiver wakeup (all share the `state` mutex).
        avail: Vec<Condvar>,
    },
    /// Free-running fabric: one independently locked mailbox per
    /// ordered (receiver, sender) pair.
    Threads {
        /// The `p * p` peer mailboxes.
        boxes: Vec<Mailbox>,
    },
}

/// Per-global-rank transport telemetry. Byte/message tallies are
/// atomics so the free-running executor stays race-free without
/// changing the exact values the sequential accounting produced;
/// blocked/wall nanoseconds feed the critical-path speedup model.
#[derive(Default)]
struct RankStats {
    sent_bytes: AtomicU64,
    sent_msgs: AtomicU64,
    blocked_ns: AtomicU64,
    wall_ns: AtomicU64,
}

/// Shared transport: the executor-selected fabric plus per-rank
/// telemetry.
struct Transport {
    p: usize,
    fabric: Fabric,
    ranks: Vec<RankStats>,
}

impl Transport {
    fn new(exec: Executor, p: usize) -> Transport {
        let fabric = match exec {
            Executor::Sim => Fabric::Sim {
                state: Mutex::new((0..p * p).map(|_| VecDeque::new()).collect()),
                avail: (0..p).map(|_| Condvar::new()).collect(),
            },
            Executor::Threads => Fabric::Threads {
                boxes: (0..p * p).map(|_| Mailbox::default()).collect(),
            },
        };
        Transport {
            p,
            fabric,
            ranks: (0..p).map(|_| RankStats::default()).collect(),
        }
    }

    /// Deposit a packet into the (dst, src) queue and wake dst's
    /// waiters. Never blocks (queues are unbounded), so no send/send
    /// deadlock is possible.
    fn push(&self, dst: usize, src: usize, tag: u64, data: Box<dyn Any + Send>) {
        let slot = dst * self.p + src;
        match &self.fabric {
            Fabric::Sim { state, avail } => {
                let mut q = state.lock().unwrap();
                q[slot].push_back(Packet { tag, data });
                // notify_all, not notify_one: the rank's main thread and
                // its overlap thread may both wait on this receiver for
                // different tags.
                avail[dst].notify_all();
            }
            Fabric::Threads { boxes } => {
                let mbox = &boxes[slot];
                mbox.queue.lock().unwrap().push_back(Packet { tag, data });
                mbox.avail.notify_all();
            }
        }
    }

    /// Take the first packet matching `tag` out of the (dst, src)
    /// queue, blocking until one arrives. Time spent waiting is charged
    /// to `dst`'s `blocked_ns` (the busy-time column of the stats).
    fn pop(&self, dst: usize, src: usize, tag: u64) -> Box<dyn Any + Send> {
        let slot = dst * self.p + src;
        match &self.fabric {
            Fabric::Sim { state, avail } => {
                let mut q = state.lock().unwrap();
                loop {
                    if let Some(pos) = q[slot].iter().position(|pk| pk.tag == tag) {
                        return q[slot].remove(pos).unwrap().data;
                    }
                    let t0 = Instant::now();
                    q = avail[dst].wait(q).unwrap();
                    self.ranks[dst]
                        .blocked_ns
                        .fetch_add(t0.elapsed().as_nanos() as u64, AOrd::Relaxed);
                }
            }
            Fabric::Threads { boxes } => {
                let mbox = &boxes[slot];
                let mut q = mbox.queue.lock().unwrap();
                loop {
                    if let Some(pos) = q.iter().position(|pk| pk.tag == tag) {
                        return q.remove(pos).unwrap().data;
                    }
                    let t0 = Instant::now();
                    q = mbox.avail.wait(q).unwrap();
                    self.ranks[dst]
                        .blocked_ns
                        .fetch_add(t0.elapsed().as_nanos() as u64, AOrd::Relaxed);
                }
            }
        }
    }

    fn snapshot(&self) -> StatsSnapshot {
        let col = |f: fn(&RankStats) -> &AtomicU64| -> Vec<u64> {
            self.ranks.iter().map(|r| f(r).load(AOrd::Relaxed)).collect()
        };
        StatsSnapshot {
            bytes_sent: col(|r| &r.sent_bytes),
            msgs_sent: col(|r| &r.sent_msgs),
            wall_ns: col(|r| &r.wall_ns),
            blocked_ns: col(|r| &r.blocked_ns),
        }
    }
}

/// A communicator handle held by one rank (thread). Sub-communicators
/// created by [`Comm::split`] share the transport but re-rank members.
pub struct Comm {
    /// Global rank (thread index) of this endpoint.
    grank: usize,
    /// Rank within this communicator.
    rank: usize,
    /// Global ranks of the members, ascending; `members[rank] == grank`.
    members: Arc<Vec<usize>>,
    /// Tag namespace of this communicator (prevents cross-group mixups
    /// when sibling subgroups run concurrently).
    scope: u64,
    /// Monotonic per-communicator collective counter (all members call
    /// collectives in the same order, so it stays in sync).
    op_seq: std::cell::Cell<u64>,
    transport: Arc<Transport>,
}

/// Spawn `p` ranks on the executor named by `PTSCOTCH_EXECUTOR`
/// (`sim` default — see [`Executor::from_env`], which panics loudly on
/// an unrecognized value), run `f(comm)` on each, join, and return the
/// results in rank order together with the traffic statistics.
pub fn run<R, F>(p: usize, f: F) -> (Vec<R>, StatsSnapshot)
where
    R: Send + 'static,
    F: Fn(Comm) -> R + Send + Sync + 'static,
{
    run_on(Executor::from_env(), p, f)
}

/// Spawn `p` ranks on an explicit [`Executor`], run `f(comm)` on each,
/// join, and return the results in rank order together with the
/// traffic statistics. Both executors drive one OS thread per rank;
/// they differ only in the fabric under the mailboxes (DESIGN.md §3),
/// so `f` needs no executor awareness and results are bit-identical
/// across executors.
pub fn run_on<R, F>(exec: Executor, p: usize, f: F) -> (Vec<R>, StatsSnapshot)
where
    R: Send + 'static,
    F: Fn(Comm) -> R + Send + Sync + 'static,
{
    assert!(p >= 1, "need at least one rank");
    let transport = Arc::new(Transport::new(exec, p));
    let members = Arc::new((0..p).collect::<Vec<_>>());
    let f = Arc::new(f);
    let mut handles = Vec::with_capacity(p);
    for r in 0..p {
        let comm = Comm {
            grank: r,
            rank: r,
            members: members.clone(),
            scope: 0x5c07c4,
            op_seq: std::cell::Cell::new(0),
            transport: transport.clone(),
        };
        let f = f.clone();
        let t = transport.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("rank{r}"))
                .stack_size(16 << 20)
                .spawn(move || {
                    let t0 = Instant::now();
                    let out = f(comm);
                    t.ranks[r]
                        .wall_ns
                        .store(t0.elapsed().as_nanos() as u64, AOrd::Relaxed);
                    out
                })
                .expect("spawn rank thread"),
        );
    }
    let results: Vec<R> = handles
        .into_iter()
        .map(|h| h.join().expect("rank thread panicked"))
        .collect();
    let stats = transport.snapshot();
    (results, stats)
}

impl Comm {
    /// Rank within this communicator.
    #[inline]
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of ranks in this communicator.
    #[inline]
    pub fn size(&self) -> usize {
        self.members.len()
    }

    /// Global (thread) rank — stable across splits; used to derive
    /// deterministic per-rank RNG streams.
    #[inline]
    pub fn global_rank(&self) -> usize {
        self.grank
    }

    fn scoped(&self, tag: u64) -> u64 {
        // Mix the scope into user tags; reserve the top bit for collectives.
        (self
            .scope
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(tag))
            & !(1 << 63)
    }

    fn next_coll_tag(&self) -> u64 {
        let s = self.op_seq.get();
        self.op_seq.set(s + 1);
        (1 << 63)
            | (self
                .scope
                .wrapping_mul(0x2545_F491_4F6C_DD1D)
                .wrapping_add(s)
                >> 1)
    }

    fn send_raw(&self, to_local: usize, tag: u64, data: Box<dyn Any + Send>, bytes: usize) {
        let dst = self.members[to_local];
        let t = &self.transport;
        t.ranks[self.grank]
            .sent_bytes
            .fetch_add(bytes as u64, AOrd::Relaxed);
        t.ranks[self.grank].sent_msgs.fetch_add(1, AOrd::Relaxed);
        t.push(dst, self.grank, tag, data);
    }

    fn recv_raw(&self, from_local: usize, tag: u64) -> Box<dyn Any + Send> {
        let src = self.members[from_local];
        self.transport.pop(self.grank, src, tag)
    }

    /// Send a typed vector to `to` (local rank) with a user tag.
    pub fn send<T: Send + 'static>(&self, to: usize, tag: u64, data: Vec<T>) {
        let bytes = data.len() * std::mem::size_of::<T>();
        self.send_raw(to, self.scoped(tag), Box::new(data), bytes);
    }

    /// Receive a typed vector from `from` (local rank) with a user tag.
    /// Panics on type mismatch — a programming error, like an MPI
    /// datatype mismatch.
    pub fn recv<T: Send + 'static>(&self, from: usize, tag: u64) -> Vec<T> {
        *self
            .recv_raw(from, self.scoped(tag))
            .downcast::<Vec<T>>()
            .expect("message type mismatch")
    }

    /// Barrier over this communicator (gather-to-root + broadcast).
    pub fn barrier(&self) {
        let tag = self.next_coll_tag();
        if self.rank == 0 {
            for r in 1..self.size() {
                let _: Box<dyn Any + Send> = self.recv_raw(r, tag);
            }
            for r in 1..self.size() {
                self.send_raw(r, tag, Box::new(Vec::<u8>::new()), 0);
            }
        } else if self.size() > 1 {
            self.send_raw(0, tag, Box::new(Vec::<u8>::new()), 0);
            let _ = self.recv_raw(0, tag);
        }
    }

    /// Gather each rank's vector on every rank (returned in rank order).
    pub fn allgatherv<T: Clone + Send + 'static>(&self, mine: Vec<T>) -> Vec<Vec<T>> {
        let tag = self.next_coll_tag();
        let p = self.size();
        if p == 1 {
            return vec![mine];
        }
        if self.rank == 0 {
            let mut all = Vec::with_capacity(p);
            all.push(mine);
            for r in 1..p {
                all.push(*self.recv_raw(r, tag).downcast::<Vec<T>>().unwrap());
            }
            let bytes: usize = all.iter().map(|v| v.len() * std::mem::size_of::<T>()).sum();
            for r in 1..p {
                self.send_raw(r, tag, Box::new(all.clone()), bytes);
            }
            all
        } else {
            let bytes = mine.len() * std::mem::size_of::<T>();
            self.send_raw(0, tag, Box::new(mine), bytes);
            *self.recv_raw(0, tag).downcast::<Vec<Vec<T>>>().unwrap()
        }
    }

    /// All-reduce with an arbitrary associative fold over per-rank values.
    pub fn allreduce<T, F>(&self, mine: T, fold: F) -> T
    where
        T: Clone + Send + 'static,
        F: Fn(T, T) -> T,
    {
        let all = self.allgatherv(vec![mine]);
        let mut it = all.into_iter().map(|mut v| v.pop().expect("one value"));
        let first = it.next().expect("at least one rank");
        it.fold(first, fold)
    }

    /// Sum-all-reduce of an `i64`.
    pub fn allreduce_sum(&self, v: i64) -> i64 {
        self.allreduce(v, |a, b| a + b)
    }

    /// Exclusive prefix sum across ranks (rank 0 gets 0).
    pub fn exscan_sum(&self, v: u64) -> u64 {
        let all = self.allgatherv(vec![v]);
        all.iter().take(self.rank).map(|x| x[0]).sum()
    }

    /// Personalized all-to-all: `out[r]` goes to rank `r`; returns the
    /// vectors received from each rank (in rank order).
    pub fn alltoallv<T: Send + 'static>(&self, out: Vec<Vec<T>>) -> Vec<Vec<T>> {
        assert_eq!(out.len(), self.size());
        let tag = self.next_coll_tag();
        let p = self.size();
        let mut mine: Option<Vec<T>> = None;
        // Deterministic order: send ascending, then receive ascending.
        for (r, data) in out.into_iter().enumerate() {
            if r == self.rank {
                mine = Some(data);
                continue;
            }
            let bytes = data.len() * std::mem::size_of::<T>();
            self.send_raw(r, tag, Box::new(data), bytes);
        }
        let mut result: Vec<Vec<T>> = Vec::with_capacity(p);
        for r in 0..p {
            if r == self.rank {
                result.push(mine.take().expect("own slot"));
            } else {
                result.push(*self.recv_raw(r, tag).downcast::<Vec<T>>().unwrap());
            }
        }
        result
    }

    /// Broadcast from `root` to every rank.
    pub fn bcast<T: Clone + Send + 'static>(&self, root: usize, data: Option<Vec<T>>) -> Vec<T> {
        let tag = self.next_coll_tag();
        if self.rank == root {
            let data = data.expect("root must supply data");
            let bytes = data.len() * std::mem::size_of::<T>();
            for r in 0..self.size() {
                if r != root {
                    self.send_raw(r, tag, Box::new(data.clone()), bytes);
                }
            }
            data
        } else {
            *self.recv_raw(root, tag).downcast::<Vec<T>>().unwrap()
        }
    }

    /// Split into sub-communicators by color. Collective. Members of each
    /// color are re-ranked by ascending parent rank. Sibling groups get
    /// distinct tag scopes derived from the color.
    pub fn split(&self, color: usize) -> Comm {
        let colors = self.allgatherv(vec![color]);
        let members: Vec<usize> = (0..self.size())
            .filter(|&r| colors[r][0] == color)
            .map(|r| self.members[r])
            .collect();
        let rank = members
            .iter()
            .position(|&g| g == self.grank)
            .expect("caller is a member of its own color");
        Comm {
            grank: self.grank,
            rank,
            members: Arc::new(members),
            scope: self.scope.wrapping_mul(31).wrapping_add(color as u64 + 1),
            op_seq: std::cell::Cell::new(0),
            transport: self.transport.clone(),
        }
    }

    /// A derived endpoint with a distinct tag scope for use by an overlap
    /// thread on the *same* rank (§3.1 builds the two induced subgraphs
    /// concurrently). The clone talks to the same peers; tag scoping
    /// keeps the two contexts' messages apart.
    pub fn overlap_context(&self, ctx: u64) -> Comm {
        Comm {
            grank: self.grank,
            rank: self.rank,
            members: self.members.clone(),
            scope: self.scope.wrapping_mul(131).wrapping_add(ctx + 7),
            op_seq: std::cell::Cell::new(0),
            transport: self.transport.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Both executors, so every transport test pins the per-peer
    /// threaded fabric as well as the serialized oracle.
    const EXECUTORS: [Executor; 2] = [Executor::Sim, Executor::Threads];

    #[test]
    fn p2p_roundtrip() {
        for exec in EXECUTORS {
            let (res, stats) = run_on(exec, 2, |c| {
                if c.rank() == 0 {
                    c.send(1, 7, vec![1u64, 2, 3]);
                    0u64
                } else {
                    let v: Vec<u64> = c.recv(0, 7);
                    v.iter().sum()
                }
            });
            assert_eq!(res, vec![0, 6], "{exec}");
            assert_eq!(stats.msgs_sent[0], 1, "{exec}");
            assert_eq!(stats.bytes_sent[0], 24, "{exec}");
        }
    }

    #[test]
    fn tag_matching_out_of_order() {
        for exec in EXECUTORS {
            let (res, _) = run_on(exec, 2, |c| {
                if c.rank() == 0 {
                    c.send(1, 1, vec![10i32]);
                    c.send(1, 2, vec![20i32]);
                    0
                } else {
                    // Receive in reverse tag order.
                    let b: Vec<i32> = c.recv(0, 2);
                    let a: Vec<i32> = c.recv(0, 1);
                    a[0] + b[0] * 100
                }
            });
            assert_eq!(res[1], 2010, "{exec}");
        }
    }

    #[test]
    fn allgatherv_orders_by_rank() {
        for exec in EXECUTORS {
            let (res, _) = run_on(exec, 4, |c| {
                let all = c.allgatherv(vec![c.rank() as u64 * 10]);
                all.iter().map(|v| v[0]).collect::<Vec<_>>()
            });
            for r in res {
                assert_eq!(r, vec![0, 10, 20, 30], "{exec}");
            }
        }
    }

    #[test]
    fn allreduce_and_exscan() {
        let (res, _) = run(5, |c| {
            let sum = c.allreduce_sum(c.rank() as i64 + 1);
            let ex = c.exscan_sum((c.rank() as u64 + 1) * 2);
            (sum, ex)
        });
        for (r, (sum, ex)) in res.iter().enumerate() {
            assert_eq!(*sum, 15);
            assert_eq!(*ex, (0..r).map(|k| (k as u64 + 1) * 2).sum::<u64>());
        }
    }

    #[test]
    fn alltoallv_personalizes() {
        for exec in EXECUTORS {
            let (res, _) = run_on(exec, 3, |c| {
                let out: Vec<Vec<u32>> = (0..3)
                    .map(|dst| vec![(c.rank() * 10 + dst) as u32])
                    .collect();
                let inn = c.alltoallv(out);
                inn.iter().map(|v| v[0]).collect::<Vec<u32>>()
            });
            assert_eq!(res[0], vec![0, 10, 20], "{exec}");
            assert_eq!(res[1], vec![1, 11, 21], "{exec}");
            assert_eq!(res[2], vec![2, 12, 22], "{exec}");
        }
    }

    #[test]
    fn bcast_from_nonzero_root() {
        for exec in EXECUTORS {
            let (res, _) = run_on(exec, 4, |c| {
                let data = if c.rank() == 2 {
                    Some(vec![9u8, 8])
                } else {
                    None
                };
                c.bcast(2, data)
            });
            for r in res {
                assert_eq!(r, vec![9, 8], "{exec}");
            }
        }
    }

    #[test]
    fn split_creates_independent_groups() {
        for exec in EXECUTORS {
            let (res, _) = run_on(exec, 6, |c| {
                let half = if c.rank() < 3 { 0 } else { 1 };
                let sub = c.split(half);
                // Each subgroup sums its own members' global ranks.
                let s = sub.allreduce_sum(c.rank() as i64);
                (sub.rank(), sub.size(), s)
            });
            assert_eq!(res[0], (0, 3, 3), "{exec}"); // 0+1+2
            assert_eq!(res[4], (1, 3, 12), "{exec}"); // 3+4+5
        }
    }

    #[test]
    fn split_uneven_sizes() {
        // ⌈5/2⌉ = 3 and ⌊5/2⌋ = 2 — the any-P property PT-Scotch claims.
        let (res, _) = run(5, |c| {
            let half = if c.rank() < 3 { 0 } else { 1 };
            let sub = c.split(half);
            sub.size()
        });
        assert_eq!(res, vec![3, 3, 3, 2, 2]);
    }

    #[test]
    fn barrier_completes() {
        for exec in EXECUTORS {
            let (res, _) = run_on(exec, 4, |c| {
                for _ in 0..10 {
                    c.barrier();
                }
                true
            });
            assert!(res.iter().all(|&x| x), "{exec}");
        }
    }

    #[test]
    fn nested_splits() {
        for exec in EXECUTORS {
            let (res, _) = run_on(exec, 8, |c| {
                let s1 = c.split(c.rank() / 4);
                let s2 = s1.split(s1.rank() / 2);
                (s2.size(), s2.allreduce_sum(1))
            });
            for r in res {
                assert_eq!(r, (2, 2), "{exec}");
            }
        }
    }

    #[test]
    fn overlap_contexts_do_not_cross_talk() {
        for exec in EXECUTORS {
            let (res, _) = run_on(exec, 2, |c| {
                let ca = c.overlap_context(0);
                let cb = c.overlap_context(1);
                if c.rank() == 0 {
                    cb.send(1, 3, vec![2u8]);
                    ca.send(1, 3, vec![1u8]);
                    0u8
                } else {
                    let a: Vec<u8> = ca.recv(0, 3);
                    let b: Vec<u8> = cb.recv(0, 3);
                    a[0] * 10 + b[0]
                }
            });
            assert_eq!(res[1], 12, "{exec}");
        }
    }

    #[test]
    fn overlap_thread_on_same_rank_under_both_executors() {
        // The §3.1 overlap pattern: a scoped thread on the same rank
        // drives comm through a tag-scoped clone while the main thread
        // communicates too. A lost wakeup in either fabric hangs here.
        for exec in EXECUTORS {
            let (res, _) = run_on(exec, 2, |c| {
                let ca = c.overlap_context(0);
                let cb = c.overlap_context(1);
                std::thread::scope(|s| {
                    let h = s.spawn(move || {
                        if cb.rank() == 0 {
                            cb.send(1, 9, vec![5u32]);
                            0u32
                        } else {
                            cb.recv::<u32>(0, 9)[0]
                        }
                    });
                    let main = if ca.rank() == 0 {
                        ca.send(1, 9, vec![7u32]);
                        0u32
                    } else {
                        ca.recv::<u32>(0, 9)[0]
                    };
                    main * 100 + h.join().expect("overlap thread")
                })
            });
            assert_eq!(res[1], 705, "{exec}");
        }
    }

    #[test]
    fn executors_report_identical_traffic_counters() {
        // The determinism contract on the telemetry: same program, same
        // per-rank byte/message tallies on both fabrics.
        let program = |c: Comm| {
            let all = c.allgatherv(vec![c.rank() as u64; c.rank() + 1]);
            let s: u64 = all.iter().map(|v| v.iter().sum::<u64>()).sum();
            let inn = c.alltoallv((0..c.size()).map(|d| vec![d as u32; 3]).collect());
            c.barrier();
            s + inn.concat().iter().map(|&x| x as u64).sum::<u64>()
        };
        let (rs, ss) = run_on(Executor::Sim, 5, program);
        let (rt, st) = run_on(Executor::Threads, 5, program);
        assert_eq!(rs, rt);
        assert_eq!(ss.bytes_sent, st.bytes_sent);
        assert_eq!(ss.msgs_sent, st.msgs_sent);
    }

    #[test]
    fn wall_and_blocked_time_are_recorded() {
        for exec in EXECUTORS {
            let (_, stats) = run_on(exec, 2, |c| {
                if c.rank() == 0 {
                    // Receiver waits for a deliberately late message, so
                    // its blocked time must register.
                    c.recv::<u8>(1, 1)
                } else {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    c.send(0, 1, vec![1u8]);
                    Vec::new()
                }
            });
            assert!(stats.wall_ns.iter().all(|&w| w > 0), "{exec}");
            assert!(
                stats.blocked_ns[0] > 0,
                "{exec}: rank 0 waited ≥20ms but recorded no blocked time"
            );
            // Busy time never exceeds wall time for a single-threaded rank.
            assert!(stats.busy_ns()[0] <= stats.wall_ns[0], "{exec}");
        }
    }
}
