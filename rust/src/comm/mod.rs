//! In-process MPI-like communicator (S9), now with two executors.
//!
//! PT-Scotch is an MPI program; this container has no MPI, so we
//! reproduce the *programming model* instead of the transport: one OS
//! thread per rank, typed point-to-point messages with tag matching,
//! the collectives the algorithms need (barrier, allgatherv, allreduce,
//! alltoallv, broadcast, exclusive scan), communicator splitting for
//! the recursive nested-dissection subgroups, and per-rank traffic
//! counters plus busy/blocked wallclock that feed the scalability
//! analysis (DESIGN.md §3). The distributed algorithms in
//! [`crate::dist`] only see this API and would map 1:1 onto MPI.
//!
//! The same rank programs run on either of two executors
//! ([`Executor`], DESIGN.md §3):
//!
//! * **`Executor::Sim`** (default) — the serialized-transport
//!   simulator: every mailbox operation happens under one global state
//!   lock, so transport activity forms a single total order. This is
//!   the obviously-correct oracle the differential harness
//!   (`rust/tests/executor_diff.rs`) pins the threaded executor
//!   against.
//! * **`Executor::Threads`** — the free-running executor: one
//!   channel-backed mailbox per ordered (receiver, sender) peer pair,
//!   each with its own lock and wakeup, so disjoint peer pairs never
//!   contend and real parallel speedup is measurable on multicore
//!   hosts.
//!
//! **Determinism contract.** Results are schedule-independent by
//! construction — every receive names its source rank, tags are scoped
//! per communicator, and collectives are sequence-numbered — so both
//! executors produce bit-identical results and identical
//! `sent_bytes`/`sent_msgs`/`transport_ops` tallies for the same
//! program (`rust/tests/traffic.rs` pins this). Only the wallclock
//! columns of [`StatsSnapshot`] may differ between executors.
//!
//! **Fault model (DESIGN.md §3.2).** A rank panic no longer kills the
//! process or hangs its peers: each rank body runs under
//! `catch_unwind`, a dying rank raises a fleet-wide abort flag on the
//! shared transport and wakes every mailbox condvar *at panic time*
//! (injected panics raise in [`FaultPlan`]'s op hook, intra-rank
//! overlap threads through [`Comm::guard`], everything else at the
//! rank's top-level catch), and every subsequent or blocked transport
//! operation on surviving ranks unwinds with a dedicated abort
//! payload. The fallible entry points ([`try_run_on`] /
//! [`try_run_with`]) surface this as `Err(Error::RankPanicked)`; a
//! configurable stall deadline on every blocking wait turns fleet-wide
//! no-progress into `Err(Error::FleetStalled)` instead of a hang. The
//! deadline is opt-in: [`run`]/[`run_on`] arm none (a long compute
//! phase is not a stall), scripted-fault configs arm
//! [`DEFAULT_STALL_DEADLINE`], and any transport progress anywhere in
//! the fleet restarts a waiter's clock. Deterministic scripted faults
//! — panics, delays, stalls at a given rank's Nth transport op — are
//! injected through [`FaultPlan`] (or the [`FAULT_ENV`] env spec) to
//! test all of this without flaky sleeps. One caveat rides on the op
//! coordinate: a rank's op counter is shared by all of its transport
//! threads, so with the §3.1 overlap thread enabled (`overlap=1`, the
//! default strategy) the mapping from op index to *program point* is
//! schedule-dependent — point-precise injection should pin
//! `overlap=0` (see `comm::fault`).

pub mod exec;
pub mod fault;
pub mod stats;

pub use exec::Executor;
pub use fault::{FaultAction, FaultPlan, FAULT_ENV};
pub use stats::{MemTracker, StatsSnapshot};

use crate::trace::{self, TraceLevel};
use crate::{Error, Result};
use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering as AOrd};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

/// Stall deadline armed when one is wanted but none was configured:
/// how long a blocking wait may go **without any fleet-wide transport
/// progress** before the fleet is declared stalled and unwound with
/// [`Error::FleetStalled`]. Progress anywhere in the fleet restarts
/// the clock, so a legitimately imbalanced fleet (one rank waiting
/// minutes on a slow peer that is still computing *and talking*) is
/// not misreported. Generous on purpose — it is a liveness backstop,
/// not a performance knob; tests that want fast failure lower it via
/// [`RunConfig`]. This value is used by the service layer and by any
/// fleet whose [`RunConfig`] scripts faults but leaves the deadline at
/// [`NO_STALL_DEADLINE`] (so an injected stall can always trip it).
pub const DEFAULT_STALL_DEADLINE: Duration = Duration::from_secs(60);

/// Sentinel "no deadline": blocking waits are bounded only by the
/// abort protocol (a panicking rank still wakes and unwinds every
/// waiter). This is the default for the infallible [`run`]/[`run_on`]
/// paths — a long-running ordering with a minutes-long all-compute
/// phase (e.g. sequential leaf ordering of a folded branch) must never
/// be misdeclared stalled just because no one configured a deadline.
pub const NO_STALL_DEADLINE: Duration = Duration::MAX;

/// Per-fleet run configuration for the fallible entry points: the
/// fault-injection plan (if any) and the stall deadline.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Scripted fault plan; `None` (or an empty plan) injects nothing
    /// and costs one branch per transport op.
    pub fault: Option<FaultPlan>,
    /// How long a blocking wait may last without fleet-wide transport
    /// progress before the fleet is declared stalled.
    /// [`NO_STALL_DEADLINE`] (the default) disables the deadline —
    /// except that a config carrying a fault plan arms
    /// [`DEFAULT_STALL_DEADLINE`] instead, so a scripted stall cannot
    /// hang the fleet it was injected into.
    pub stall_deadline: Duration,
    /// Span-recorder level installed on every rank thread
    /// (DESIGN.md §7): [`TraceLevel::Off`] (the default) records
    /// nothing; otherwise each rank gets a thread-local sink whose
    /// [`crate::trace::RankTrace`] rides back on
    /// [`StatsSnapshot::traces`] after the fleet joins. The recorder
    /// only *observes* the per-rank counters (relaxed loads), so
    /// results and traffic tallies stay bit-identical to an untraced
    /// run.
    pub trace: TraceLevel,
}

impl Default for RunConfig {
    fn default() -> RunConfig {
        RunConfig {
            fault: None,
            stall_deadline: NO_STALL_DEADLINE,
            trace: TraceLevel::Off,
        }
    }
}

impl RunConfig {
    /// Default config with the fault plan taken from [`FAULT_ENV`]
    /// (`Err(Error::BadEnv)` if the variable is set but malformed).
    /// The deadline stays [`NO_STALL_DEADLINE`]; when the env scripts
    /// faults, fleet construction arms [`DEFAULT_STALL_DEADLINE`].
    pub fn from_env() -> Result<RunConfig> {
        Ok(RunConfig {
            fault: FaultPlan::from_env()?,
            ..RunConfig::default()
        })
    }
}

/// Unwind payload of a scripted [`FaultAction::Panic`]; carries the op
/// index so the reported `RankPanicked` message names the trigger.
/// Raised via `resume_unwind` so the panic hook stays quiet — an
/// injected fault is expected, not a bug worth a backtrace on stderr.
struct InjectedPanic {
    op: u64,
}

/// Unwind payload used to tear down surviving ranks once the fleet is
/// aborting. Recognized (and swallowed) by the `catch_unwind` in
/// [`try_run_with`]; the root-cause error is already in the abort cell.
struct FleetAbort;

/// Fleet-wide abort state: a fast flag checked on every transport op,
/// the first-raiser-wins root-cause error, and a condvar that parked
/// (injected-stall) ranks wait on.
#[derive(Default)]
struct AbortCell {
    flag: AtomicBool,
    err: Mutex<Option<Error>>,
    cv: Condvar,
}

/// One blocking wait's stall clock (see [`Transport::stall_left`]):
/// when it expires and the fleet progress count it was armed against.
/// `deadline: None` means the wait is unbounded ([`NO_STALL_DEADLINE`]).
struct StallClock {
    deadline: Option<Instant>,
    seen_progress: u64,
}

/// Lock a mutex, ignoring poisoning. The transport must stay usable
/// while ranks unwind through it during an abort — the data under
/// these locks (message queues, the abort cell) is never left in a
/// torn state by the operations that can unwind.
fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One in-flight message. The source rank is implicit in the mailbox
/// the packet sits in (one queue per ordered (receiver, sender) pair).
struct Packet {
    tag: u64,
    data: Box<dyn Any + Send>,
}

/// A threaded-executor mailbox: one (receiver, sender) pair's deque
/// plus its private wakeup condvar.
#[derive(Default)]
struct Mailbox {
    queue: Mutex<VecDeque<Packet>>,
    avail: Condvar,
}

/// The message fabric under the rank fleet — the part of the transport
/// the [`Executor`] choice swaps out. Both variants hold `p * p` queues
/// indexed `dst * p + src`; they differ in locking granularity.
enum Fabric {
    /// Serialized oracle: all queues behind one state lock (a total
    /// order over every mailbox operation), one wakeup condvar per
    /// receiving rank so a push only wakes that receiver's waiters.
    Sim {
        /// All `p * p` queues, guarded by the single global lock.
        state: Mutex<Vec<VecDeque<Packet>>>,
        /// Per-receiver wakeup (all share the `state` mutex).
        avail: Vec<Condvar>,
    },
    /// Free-running fabric: one independently locked mailbox per
    /// ordered (receiver, sender) pair.
    Threads {
        /// The `p * p` peer mailboxes.
        boxes: Vec<Mailbox>,
    },
}

/// Per-global-rank transport telemetry. Byte/message tallies are
/// atomics so the free-running executor stays race-free without
/// changing the exact values the sequential accounting produced;
/// blocked/wall nanoseconds feed the critical-path speedup model.
#[derive(Default)]
struct RankStats {
    sent_bytes: AtomicU64,
    sent_msgs: AtomicU64,
    blocked_ns: AtomicU64,
    wall_ns: AtomicU64,
    transport_ops: AtomicU64,
}

/// Shared transport: the executor-selected fabric plus per-rank
/// telemetry, the fault-injection plan, and the fleet abort state.
struct Transport {
    p: usize,
    fabric: Fabric,
    ranks: Vec<RankStats>,
    /// Non-empty scripted fault plan, if any (empty plans are dropped
    /// at construction so the hot path pays one `Option` branch).
    fault: Option<FaultPlan>,
    /// Per-blocking-wait no-progress deadline (see
    /// [`DEFAULT_STALL_DEADLINE`] / [`NO_STALL_DEADLINE`]).
    stall_deadline: Duration,
    /// Fleet-wide transport progress: bumped on every packet deposit
    /// and every successful dequeue. Blocked waiters restart their
    /// stall clock whenever this moves, so only true no-progress
    /// states trip [`Error::FleetStalled`].
    progress: AtomicU64,
    abort: AbortCell,
}

impl Transport {
    fn new(exec: Executor, p: usize, cfg: RunConfig) -> Transport {
        let fabric = match exec {
            Executor::Sim => Fabric::Sim {
                state: Mutex::new((0..p * p).map(|_| VecDeque::new()).collect()),
                avail: (0..p).map(|_| Condvar::new()).collect(),
            },
            Executor::Threads => Fabric::Threads {
                boxes: (0..p * p).map(|_| Mailbox::default()).collect(),
            },
        };
        let fault = cfg.fault.filter(|plan| !plan.is_empty());
        // A plan that scripts faults arms the default deadline when the
        // caller left it disabled: an injected stall must be able to
        // trip *something*, and an injected panic's abort still beats
        // the deadline by waking every waiter.
        let stall_deadline = if fault.is_some() && cfg.stall_deadline == NO_STALL_DEADLINE {
            DEFAULT_STALL_DEADLINE
        } else {
            cfg.stall_deadline
        };
        Transport {
            p,
            fabric,
            ranks: (0..p).map(|_| RankStats::default()).collect(),
            fault,
            stall_deadline,
            progress: AtomicU64::new(0),
            abort: AbortCell::default(),
        }
    }

    /// Has some rank raised the fleet abort?
    #[inline]
    fn aborted(&self) -> bool {
        self.abort.flag.load(AOrd::Acquire)
    }

    /// The root-cause error of the abort, if one was raised.
    fn abort_error(&self) -> Option<Error> {
        plock(&self.abort.err).clone()
    }

    /// Raise the fleet abort: record the root cause (first raiser
    /// wins), set the flag, and wake *every* waiter — parked stalls on
    /// the abort condvar and blocked receivers on every mailbox
    /// condvar. Each notify happens while holding the lock its waiters
    /// wait under (waiters re-check the flag under that same lock
    /// before sleeping), so no wakeup can be lost.
    fn raise(&self, err: Error) {
        {
            let mut cell = plock(&self.abort.err);
            if cell.is_none() {
                *cell = Some(err);
            }
            self.abort.flag.store(true, AOrd::Release);
            self.abort.cv.notify_all();
        }
        match &self.fabric {
            Fabric::Sim { state, avail } => {
                let _g = plock(state);
                for cv in avail {
                    cv.notify_all();
                }
            }
            Fabric::Threads { boxes } => {
                for mbox in boxes {
                    let _g = plock(&mbox.queue);
                    mbox.avail.notify_all();
                }
            }
        }
    }

    /// Unwind the calling rank with the abort payload. Only called
    /// once the abort flag is set (the root cause is already recorded).
    fn unwind_abort(&self) -> ! {
        resume_unwind(Box::new(FleetAbort))
    }

    /// Per-transport-op bookkeeping and fault hook: advance `rank`'s op
    /// counter, bail out if the fleet is aborting, and fire any
    /// scripted fault armed at this `(rank, op)` point. Called at the
    /// top of every push and pop; with no plan and no abort this is one
    /// relaxed increment and two loads.
    fn op_event(&self, rank: usize) {
        let op = self.ranks[rank].transport_ops.fetch_add(1, AOrd::Relaxed);
        if self.aborted() {
            self.unwind_abort();
        }
        if let Some(plan) = &self.fault {
            match plan.check(rank, op) {
                None => {}
                Some(FaultAction::Delay(ms)) => {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                Some(FaultAction::Panic) => {
                    // Raise the abort *at the panic site*, before the
                    // unwind starts: a rank may run several transport
                    // threads (the §3.1 overlap), and a sibling parked
                    // in a blocking pop is only released by the abort
                    // wakeup — deferring the raise to the rank's
                    // top-level `catch_unwind` would wedge the fleet
                    // until the stall deadline (the scope join cannot
                    // complete while the sibling blocks) and misreport
                    // the root cause as `FleetStalled`.
                    self.raise(Error::RankPanicked {
                        rank,
                        message: format!("injected panic at transport op {op}"),
                    });
                    resume_unwind(Box::new(InjectedPanic { op }));
                }
                Some(FaultAction::Stall) => self.stall(rank, op),
            }
        }
    }

    /// A blocked receive ran past the stall deadline: raise
    /// [`Error::FleetStalled`] naming the waiting rank and the stuck
    /// operation, then unwind. Callers must have dropped the queue
    /// guard first ([`Transport::raise`] re-acquires it to notify).
    fn raise_stall(&self, dst: usize, src: usize, tag: u64) -> ! {
        self.raise(Error::FleetStalled {
            rank: dst,
            op: format!("recv from rank {src} (tag {tag:#x})"),
        });
        self.unwind_abort()
    }

    /// Start a stall clock for one blocking wait: expiry instant (if a
    /// deadline is armed) plus the progress count it was computed at.
    fn stall_clock(&self) -> StallClock {
        StallClock {
            // `checked_add` turns NO_STALL_DEADLINE (and anything else
            // past the Instant horizon) into "no deadline".
            deadline: Instant::now().checked_add(self.stall_deadline),
            seen_progress: self.progress.load(AOrd::Relaxed),
        }
    }

    /// Time this wait may still block: `None` means unbounded,
    /// `Some(ZERO)` means the deadline expired. Any fleet-wide
    /// transport progress since the clock was last read restarts it —
    /// the deadline measures *no-progress* time, so one rank waiting
    /// long on a busy, still-communicating fleet never trips it.
    fn stall_left(&self, clock: &mut StallClock) -> Option<Duration> {
        let prog = self.progress.load(AOrd::Relaxed);
        if prog != clock.seen_progress && clock.deadline.is_some() {
            clock.seen_progress = prog;
            clock.deadline = Instant::now().checked_add(self.stall_deadline);
        }
        clock
            .deadline
            .map(|dl| dl.saturating_duration_since(Instant::now()))
    }

    /// Execute an injected stall: park on the abort condvar until the
    /// fleet aborts for some other reason, or this rank's own stall
    /// deadline expires — in which case the stalled rank itself raises
    /// [`Error::FleetStalled`] — then unwind. (An armed deadline is
    /// guaranteed here: a fault plan arms [`DEFAULT_STALL_DEADLINE`]
    /// unless the caller configured its own.)
    fn stall(&self, rank: usize, op: u64) -> ! {
        let mut clock = self.stall_clock();
        let mut g = plock(&self.abort.err);
        loop {
            if self.aborted() {
                drop(g);
                self.unwind_abort();
            }
            match self.stall_left(&mut clock) {
                Some(left) if left.is_zero() => {
                    drop(g);
                    self.raise(Error::FleetStalled {
                        rank,
                        op: format!("injected stall at transport op {op}"),
                    });
                    self.unwind_abort();
                }
                Some(left) => {
                    g = self
                        .abort
                        .cv
                        .wait_timeout(g, left)
                        .unwrap_or_else(PoisonError::into_inner)
                        .0;
                }
                None => {
                    g = self.abort.cv.wait(g).unwrap_or_else(PoisonError::into_inner);
                }
            }
        }
    }

    /// Deposit a packet into the (dst, src) queue and wake dst's
    /// waiters. Never blocks (queues are unbounded), so no send/send
    /// deadlock is possible — but it does unwind if the fleet is
    /// aborting, so no rank keeps computing into a dead fleet.
    fn push(&self, dst: usize, src: usize, tag: u64, data: Box<dyn Any + Send>) {
        self.op_event(src);
        let slot = dst * self.p + src;
        match &self.fabric {
            Fabric::Sim { state, avail } => {
                let mut q = plock(state);
                q[slot].push_back(Packet { tag, data });
                // notify_all, not notify_one: the rank's main thread and
                // its overlap thread may both wait on this receiver for
                // different tags.
                avail[dst].notify_all();
            }
            Fabric::Threads { boxes } => {
                let mbox = &boxes[slot];
                plock(&mbox.queue).push_back(Packet { tag, data });
                mbox.avail.notify_all();
            }
        }
        self.progress.fetch_add(1, AOrd::Relaxed);
    }

    /// Take the first packet matching `tag` out of the (dst, src)
    /// queue, blocking until one arrives, the fleet aborts (unwinds
    /// with the abort payload), or the stall clock runs out — the
    /// armed deadline with no fleet-wide progress — (raises
    /// [`Error::FleetStalled`] and unwinds). Time spent waiting is
    /// charged to `dst`'s `blocked_ns` (the busy-time column).
    ///
    /// The abort flag is checked *under the queue lock* before every
    /// wait, and [`Transport::raise`] notifies under that same lock
    /// after setting the flag, so a waiter either sees the flag or is
    /// woken by the notify — never a lost wakeup.
    fn pop(&self, dst: usize, src: usize, tag: u64) -> Box<dyn Any + Send> {
        self.op_event(dst);
        let slot = dst * self.p + src;
        let mut clock = self.stall_clock();
        match &self.fabric {
            Fabric::Sim { state, avail } => {
                let mut q = plock(state);
                loop {
                    if let Some(pos) = q[slot].iter().position(|pk| pk.tag == tag) {
                        let data = q[slot].remove(pos).unwrap().data;
                        self.progress.fetch_add(1, AOrd::Relaxed);
                        return data;
                    }
                    if self.aborted() {
                        drop(q);
                        self.unwind_abort();
                    }
                    let left = self.stall_left(&mut clock);
                    if left == Some(Duration::ZERO) {
                        drop(q);
                        self.raise_stall(dst, src, tag);
                    }
                    let t0 = Instant::now();
                    q = match left {
                        Some(d) => {
                            avail[dst]
                                .wait_timeout(q, d)
                                .unwrap_or_else(PoisonError::into_inner)
                                .0
                        }
                        None => avail[dst].wait(q).unwrap_or_else(PoisonError::into_inner),
                    };
                    self.ranks[dst]
                        .blocked_ns
                        .fetch_add(t0.elapsed().as_nanos() as u64, AOrd::Relaxed);
                }
            }
            Fabric::Threads { boxes } => {
                let mbox = &boxes[slot];
                let mut q = plock(&mbox.queue);
                loop {
                    if let Some(pos) = q.iter().position(|pk| pk.tag == tag) {
                        let data = q.remove(pos).unwrap().data;
                        self.progress.fetch_add(1, AOrd::Relaxed);
                        return data;
                    }
                    if self.aborted() {
                        drop(q);
                        self.unwind_abort();
                    }
                    let left = self.stall_left(&mut clock);
                    if left == Some(Duration::ZERO) {
                        drop(q);
                        self.raise_stall(dst, src, tag);
                    }
                    let t0 = Instant::now();
                    q = match left {
                        Some(d) => {
                            mbox.avail
                                .wait_timeout(q, d)
                                .unwrap_or_else(PoisonError::into_inner)
                                .0
                        }
                        None => mbox.avail.wait(q).unwrap_or_else(PoisonError::into_inner),
                    };
                    self.ranks[dst]
                        .blocked_ns
                        .fetch_add(t0.elapsed().as_nanos() as u64, AOrd::Relaxed);
                }
            }
        }
    }

    fn snapshot(&self) -> StatsSnapshot {
        let col = |f: fn(&RankStats) -> &AtomicU64| -> Vec<u64> {
            self.ranks.iter().map(|r| f(r).load(AOrd::Relaxed)).collect()
        };
        StatsSnapshot {
            bytes_sent: col(|r| &r.sent_bytes),
            msgs_sent: col(|r| &r.sent_msgs),
            wall_ns: col(|r| &r.wall_ns),
            blocked_ns: col(|r| &r.blocked_ns),
            transport_ops: col(|r| &r.transport_ops),
            traces: Vec::new(),
        }
    }
}

/// Render a caught rank-thread unwind payload as a panic message.
fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(inj) = payload.downcast_ref::<InjectedPanic>() {
        format!("injected panic at transport op {}", inj.op)
    } else if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// A communicator handle held by one rank (thread). Sub-communicators
/// created by [`Comm::split`] share the transport but re-rank members.
pub struct Comm {
    /// Global rank (thread index) of this endpoint.
    grank: usize,
    /// Rank within this communicator.
    rank: usize,
    /// Global ranks of the members, ascending; `members[rank] == grank`.
    members: Arc<Vec<usize>>,
    /// Tag namespace of this communicator (prevents cross-group mixups
    /// when sibling subgroups run concurrently).
    scope: u64,
    /// Monotonic per-communicator collective counter (all members call
    /// collectives in the same order, so it stays in sync).
    op_seq: std::cell::Cell<u64>,
    transport: Arc<Transport>,
}

/// Spawn `p` ranks on the executor named by `PTSCOTCH_EXECUTOR`
/// (`sim` default — see [`Executor::from_env`]), run `f(comm)` on
/// each, join, and return the results in rank order together with the
/// traffic statistics. Infallible wrapper: a bad environment, rank
/// panic, or stalled fleet panics here (see [`try_run_on`] for the
/// structured-error variant the service layer uses).
pub fn run<R, F>(p: usize, f: F) -> (Vec<R>, StatsSnapshot)
where
    R: Send + 'static,
    F: Fn(Comm) -> R + Send + Sync + 'static,
{
    let exec = Executor::from_env().unwrap_or_else(|e| panic!("{e}"));
    run_on(exec, p, f)
}

/// Spawn `p` ranks on an explicit [`Executor`], run `f(comm)` on each,
/// join, and return the results in rank order together with the
/// traffic statistics. Both executors drive one OS thread per rank;
/// they differ only in the fabric under the mailboxes (DESIGN.md §3),
/// so `f` needs no executor awareness and results are bit-identical
/// across executors.
///
/// Infallible wrapper over [`try_run_on`] for callers (tests, benches)
/// that treat any fleet failure as fatal: a rank panic, stalled fleet,
/// or malformed [`FAULT_ENV`] spec panics with the structured error's
/// message instead of returning it.
pub fn run_on<R, F>(exec: Executor, p: usize, f: F) -> (Vec<R>, StatsSnapshot)
where
    R: Send + 'static,
    F: Fn(Comm) -> R + Send + Sync + 'static,
{
    try_run_on(exec, p, f).unwrap_or_else(|e| panic!("fleet failed: {e}"))
}

/// Fallible [`run_on`]: the fault plan comes from [`FAULT_ENV`]
/// (`Err(Error::BadEnv)` if set but malformed). No stall deadline is
/// armed unless the env scripts faults (then
/// [`DEFAULT_STALL_DEADLINE`]) — long fleets with no configured
/// deadline are bounded only by the abort protocol. See
/// [`try_run_with`].
pub fn try_run_on<R, F>(exec: Executor, p: usize, f: F) -> Result<(Vec<R>, StatsSnapshot)>
where
    R: Send + 'static,
    F: Fn(Comm) -> R + Send + Sync + 'static,
{
    try_run_with(exec, p, RunConfig::from_env()?, f)
}

/// Spawn `p` ranks under an explicit [`RunConfig`] and return either
/// every rank's result or the first fleet-level fault:
///
/// * `Err(Error::RankPanicked)` — some rank's program (or an injected
///   [`FaultAction::Panic`]) panicked. The panic is caught in that
///   rank's thread, every surviving rank is unwound through the abort
///   protocol (DESIGN.md §3.2), and the process neither aborts nor
///   hangs.
/// * `Err(Error::FleetStalled)` — some rank blocked for
///   `cfg.stall_deadline` with no fleet-wide transport progress at
///   all (any progress restarts the waiter's clock, and
///   [`NO_STALL_DEADLINE`] — the default — disables the check unless
///   a fault plan arms it).
///
/// On `Ok`, results are bit-identical across executors and unaffected
/// by injected [`FaultAction::Delay`]s (the determinism contract).
pub fn try_run_with<R, F>(
    exec: Executor,
    p: usize,
    cfg: RunConfig,
    f: F,
) -> Result<(Vec<R>, StatsSnapshot)>
where
    R: Send + 'static,
    F: Fn(Comm) -> R + Send + Sync + 'static,
{
    assert!(p >= 1, "need at least one rank");
    let trace_level = cfg.trace;
    let transport = Arc::new(Transport::new(exec, p, cfg));
    let members = Arc::new((0..p).collect::<Vec<_>>());
    let f = Arc::new(f);
    // Fleet-shared trace epoch: every rank's span timestamps are
    // relative to this instant, so the merged Chrome trace aligns.
    let epoch = Instant::now();
    let trace_out: Arc<Mutex<Vec<Option<trace::RankTrace>>>> =
        Arc::new(Mutex::new((0..p).map(|_| None).collect()));
    let mut handles = Vec::with_capacity(p);
    for r in 0..p {
        let comm = Comm {
            grank: r,
            rank: r,
            members: members.clone(),
            scope: 0x5c07c4,
            op_seq: std::cell::Cell::new(0),
            transport: transport.clone(),
        };
        let f = f.clone();
        let t = transport.clone();
        let slot = trace_out.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("rank{r}"))
                .stack_size(16 << 20)
                .spawn(move || {
                    if trace_level != TraceLevel::Off {
                        // The sink lives on the rank's main thread;
                        // §3.1 overlap threads have none, so their
                        // traffic attributes to the enclosing span via
                        // the shared per-rank counters. The probe only
                        // reads the atomics — it never perturbs them.
                        let tp = t.clone();
                        trace::install(
                            r,
                            trace_level,
                            epoch,
                            Some(trace::CounterProbe::new(move || {
                                let s = &tp.ranks[r];
                                [
                                    s.sent_bytes.load(AOrd::Relaxed),
                                    s.sent_msgs.load(AOrd::Relaxed),
                                    s.transport_ops.load(AOrd::Relaxed),
                                    s.blocked_ns.load(AOrd::Relaxed),
                                ]
                            })),
                        );
                    }
                    let t0 = Instant::now();
                    let out = catch_unwind(AssertUnwindSafe(|| f(comm)));
                    t.ranks[r]
                        .wall_ns
                        .store(t0.elapsed().as_nanos() as u64, AOrd::Relaxed);
                    if trace_level != TraceLevel::Off {
                        slot.lock().unwrap_or_else(PoisonError::into_inner)[r] = trace::take();
                    }
                    match out {
                        Ok(v) => Some(v),
                        Err(payload) => {
                            // The abort payload is the *consequence* of a
                            // fleet abort, not a new root cause.
                            if !payload.is::<FleetAbort>() {
                                t.raise(Error::RankPanicked {
                                    rank: r,
                                    message: panic_message(payload.as_ref()),
                                });
                            }
                            None
                        }
                    }
                })
                .expect("spawn rank thread"),
        );
    }
    let results: Vec<Option<R>> = handles
        .into_iter()
        .map(|h| h.join().unwrap_or(None))
        .collect();
    let mut stats = transport.snapshot();
    if let Some(err) = transport.abort_error() {
        return Err(err);
    }
    stats.traces = trace_out
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .iter_mut()
        .filter_map(Option::take)
        .collect();
    let results = results
        .into_iter()
        .map(|r| r.expect("rank returned no result yet no abort was raised"))
        .collect();
    Ok((results, stats))
}

impl Comm {
    /// Rank within this communicator.
    #[inline]
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of ranks in this communicator.
    #[inline]
    pub fn size(&self) -> usize {
        self.members.len()
    }

    /// Global (thread) rank — stable across splits; used to derive
    /// deterministic per-rank RNG streams.
    #[inline]
    pub fn global_rank(&self) -> usize {
        self.grank
    }

    fn scoped(&self, tag: u64) -> u64 {
        // Mix the scope into user tags; reserve the top bit for collectives.
        (self
            .scope
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(tag))
            & !(1 << 63)
    }

    fn next_coll_tag(&self) -> u64 {
        let s = self.op_seq.get();
        self.op_seq.set(s + 1);
        (1 << 63)
            | (self
                .scope
                .wrapping_mul(0x2545_F491_4F6C_DD1D)
                .wrapping_add(s)
                >> 1)
    }

    fn send_raw(&self, to_local: usize, tag: u64, data: Box<dyn Any + Send>, bytes: usize) {
        let dst = self.members[to_local];
        let t = &self.transport;
        t.ranks[self.grank]
            .sent_bytes
            .fetch_add(bytes as u64, AOrd::Relaxed);
        t.ranks[self.grank].sent_msgs.fetch_add(1, AOrd::Relaxed);
        t.push(dst, self.grank, tag, data);
    }

    fn recv_raw(&self, from_local: usize, tag: u64) -> Box<dyn Any + Send> {
        let src = self.members[from_local];
        self.transport.pop(self.grank, src, tag)
    }

    /// Send a typed vector to `to` (local rank) with a user tag.
    pub fn send<T: Send + 'static>(&self, to: usize, tag: u64, data: Vec<T>) {
        let bytes = data.len() * std::mem::size_of::<T>();
        self.send_raw(to, self.scoped(tag), Box::new(data), bytes);
    }

    /// Receive a typed vector from `from` (local rank) with a user tag.
    /// Panics on type mismatch — a programming error, like an MPI
    /// datatype mismatch.
    pub fn recv<T: Send + 'static>(&self, from: usize, tag: u64) -> Vec<T> {
        *self
            .recv_raw(from, self.scoped(tag))
            .downcast::<Vec<T>>()
            .expect("message type mismatch")
    }

    /// Barrier over this communicator (gather-to-root + broadcast).
    pub fn barrier(&self) {
        let _span = trace::scope(trace::Phase::Collective);
        let tag = self.next_coll_tag();
        if self.rank == 0 {
            for r in 1..self.size() {
                let _: Box<dyn Any + Send> = self.recv_raw(r, tag);
            }
            for r in 1..self.size() {
                self.send_raw(r, tag, Box::new(Vec::<u8>::new()), 0);
            }
        } else if self.size() > 1 {
            self.send_raw(0, tag, Box::new(Vec::<u8>::new()), 0);
            let _ = self.recv_raw(0, tag);
        }
    }

    /// Gather each rank's vector on every rank (returned in rank order).
    pub fn allgatherv<T: Clone + Send + 'static>(&self, mine: Vec<T>) -> Vec<Vec<T>> {
        let _span = trace::scope(trace::Phase::Collective);
        let tag = self.next_coll_tag();
        let p = self.size();
        if p == 1 {
            return vec![mine];
        }
        if self.rank == 0 {
            let mut all = Vec::with_capacity(p);
            all.push(mine);
            for r in 1..p {
                all.push(*self.recv_raw(r, tag).downcast::<Vec<T>>().unwrap());
            }
            let bytes: usize = all.iter().map(|v| v.len() * std::mem::size_of::<T>()).sum();
            for r in 1..p {
                self.send_raw(r, tag, Box::new(all.clone()), bytes);
            }
            all
        } else {
            let bytes = mine.len() * std::mem::size_of::<T>();
            self.send_raw(0, tag, Box::new(mine), bytes);
            *self.recv_raw(0, tag).downcast::<Vec<Vec<T>>>().unwrap()
        }
    }

    /// All-reduce with an arbitrary associative fold over per-rank values.
    pub fn allreduce<T, F>(&self, mine: T, fold: F) -> T
    where
        T: Clone + Send + 'static,
        F: Fn(T, T) -> T,
    {
        let all = self.allgatherv(vec![mine]);
        let mut it = all.into_iter().map(|mut v| v.pop().expect("one value"));
        let first = it.next().expect("at least one rank");
        it.fold(first, fold)
    }

    /// Sum-all-reduce of an `i64`.
    pub fn allreduce_sum(&self, v: i64) -> i64 {
        self.allreduce(v, |a, b| a + b)
    }

    /// Exclusive prefix sum across ranks (rank 0 gets 0).
    pub fn exscan_sum(&self, v: u64) -> u64 {
        let all = self.allgatherv(vec![v]);
        all.iter().take(self.rank).map(|x| x[0]).sum()
    }

    /// Personalized all-to-all: `out[r]` goes to rank `r`; returns the
    /// vectors received from each rank (in rank order).
    pub fn alltoallv<T: Send + 'static>(&self, out: Vec<Vec<T>>) -> Vec<Vec<T>> {
        let _span = trace::scope(trace::Phase::Collective);
        assert_eq!(out.len(), self.size());
        let tag = self.next_coll_tag();
        let p = self.size();
        let mut mine: Option<Vec<T>> = None;
        // Deterministic order: send ascending, then receive ascending.
        for (r, data) in out.into_iter().enumerate() {
            if r == self.rank {
                mine = Some(data);
                continue;
            }
            let bytes = data.len() * std::mem::size_of::<T>();
            self.send_raw(r, tag, Box::new(data), bytes);
        }
        let mut result: Vec<Vec<T>> = Vec::with_capacity(p);
        for r in 0..p {
            if r == self.rank {
                result.push(mine.take().expect("own slot"));
            } else {
                result.push(*self.recv_raw(r, tag).downcast::<Vec<T>>().unwrap());
            }
        }
        result
    }

    /// Broadcast from `root` to every rank.
    pub fn bcast<T: Clone + Send + 'static>(&self, root: usize, data: Option<Vec<T>>) -> Vec<T> {
        let _span = trace::scope(trace::Phase::Collective);
        let tag = self.next_coll_tag();
        if self.rank == root {
            let data = data.expect("root must supply data");
            let bytes = data.len() * std::mem::size_of::<T>();
            for r in 0..self.size() {
                if r != root {
                    self.send_raw(r, tag, Box::new(data.clone()), bytes);
                }
            }
            data
        } else {
            *self.recv_raw(root, tag).downcast::<Vec<T>>().unwrap()
        }
    }

    /// Split into sub-communicators by color. Collective. Members of each
    /// color are re-ranked by ascending parent rank. Sibling groups get
    /// distinct tag scopes derived from the color.
    pub fn split(&self, color: usize) -> Comm {
        let _span = trace::scope(trace::Phase::Collective);
        let colors = self.allgatherv(vec![color]);
        let members: Vec<usize> = (0..self.size())
            .filter(|&r| colors[r][0] == color)
            .map(|r| self.members[r])
            .collect();
        let rank = members
            .iter()
            .position(|&g| g == self.grank)
            .expect("caller is a member of its own color");
        Comm {
            grank: self.grank,
            rank,
            members: Arc::new(members),
            scope: self.scope.wrapping_mul(31).wrapping_add(color as u64 + 1),
            op_seq: std::cell::Cell::new(0),
            transport: self.transport.clone(),
        }
    }

    /// Run `f` under this rank's abort protocol: if `f` panics, the
    /// fleet abort is raised (naming this rank, first raiser wins)
    /// *before* the unwind continues. Wrap the body of every
    /// intra-rank transport thread — and the code running concurrently
    /// with it — in this: a rank whose §3.1 overlap thread dies would
    /// otherwise leave its sibling parked in a blocking pop that only
    /// the abort wakeup can release, wedging the fleet until the stall
    /// deadline (and misreporting the root cause as `FleetStalled`).
    /// An unwind that is itself the abort payload passes through
    /// untouched — the root cause is already recorded.
    pub fn guard<R>(&self, f: impl FnOnce() -> R) -> R {
        match catch_unwind(AssertUnwindSafe(f)) {
            Ok(v) => v,
            Err(payload) => {
                if !payload.is::<FleetAbort>() {
                    self.transport.raise(Error::RankPanicked {
                        rank: self.grank,
                        message: panic_message(payload.as_ref()),
                    });
                }
                resume_unwind(payload)
            }
        }
    }

    /// A derived endpoint with a distinct tag scope for use by an overlap
    /// thread on the *same* rank (§3.1 builds the two induced subgraphs
    /// concurrently). The clone talks to the same peers; tag scoping
    /// keeps the two contexts' messages apart.
    pub fn overlap_context(&self, ctx: u64) -> Comm {
        Comm {
            grank: self.grank,
            rank: self.rank,
            members: self.members.clone(),
            scope: self.scope.wrapping_mul(131).wrapping_add(ctx + 7),
            op_seq: std::cell::Cell::new(0),
            transport: self.transport.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Both executors, so every transport test pins the per-peer
    /// threaded fabric as well as the serialized oracle.
    const EXECUTORS: [Executor; 2] = [Executor::Sim, Executor::Threads];

    #[test]
    fn p2p_roundtrip() {
        for exec in EXECUTORS {
            let (res, stats) = run_on(exec, 2, |c| {
                if c.rank() == 0 {
                    c.send(1, 7, vec![1u64, 2, 3]);
                    0u64
                } else {
                    let v: Vec<u64> = c.recv(0, 7);
                    v.iter().sum()
                }
            });
            assert_eq!(res, vec![0, 6], "{exec}");
            assert_eq!(stats.msgs_sent[0], 1, "{exec}");
            assert_eq!(stats.bytes_sent[0], 24, "{exec}");
        }
    }

    #[test]
    fn tag_matching_out_of_order() {
        for exec in EXECUTORS {
            let (res, _) = run_on(exec, 2, |c| {
                if c.rank() == 0 {
                    c.send(1, 1, vec![10i32]);
                    c.send(1, 2, vec![20i32]);
                    0
                } else {
                    // Receive in reverse tag order.
                    let b: Vec<i32> = c.recv(0, 2);
                    let a: Vec<i32> = c.recv(0, 1);
                    a[0] + b[0] * 100
                }
            });
            assert_eq!(res[1], 2010, "{exec}");
        }
    }

    #[test]
    fn allgatherv_orders_by_rank() {
        for exec in EXECUTORS {
            let (res, _) = run_on(exec, 4, |c| {
                let all = c.allgatherv(vec![c.rank() as u64 * 10]);
                all.iter().map(|v| v[0]).collect::<Vec<_>>()
            });
            for r in res {
                assert_eq!(r, vec![0, 10, 20, 30], "{exec}");
            }
        }
    }

    #[test]
    fn allreduce_and_exscan() {
        let (res, _) = run(5, |c| {
            let sum = c.allreduce_sum(c.rank() as i64 + 1);
            let ex = c.exscan_sum((c.rank() as u64 + 1) * 2);
            (sum, ex)
        });
        for (r, (sum, ex)) in res.iter().enumerate() {
            assert_eq!(*sum, 15);
            assert_eq!(*ex, (0..r).map(|k| (k as u64 + 1) * 2).sum::<u64>());
        }
    }

    #[test]
    fn alltoallv_personalizes() {
        for exec in EXECUTORS {
            let (res, _) = run_on(exec, 3, |c| {
                let out: Vec<Vec<u32>> = (0..3)
                    .map(|dst| vec![(c.rank() * 10 + dst) as u32])
                    .collect();
                let inn = c.alltoallv(out);
                inn.iter().map(|v| v[0]).collect::<Vec<u32>>()
            });
            assert_eq!(res[0], vec![0, 10, 20], "{exec}");
            assert_eq!(res[1], vec![1, 11, 21], "{exec}");
            assert_eq!(res[2], vec![2, 12, 22], "{exec}");
        }
    }

    #[test]
    fn bcast_from_nonzero_root() {
        for exec in EXECUTORS {
            let (res, _) = run_on(exec, 4, |c| {
                let data = if c.rank() == 2 {
                    Some(vec![9u8, 8])
                } else {
                    None
                };
                c.bcast(2, data)
            });
            for r in res {
                assert_eq!(r, vec![9, 8], "{exec}");
            }
        }
    }

    #[test]
    fn split_creates_independent_groups() {
        for exec in EXECUTORS {
            let (res, _) = run_on(exec, 6, |c| {
                let half = if c.rank() < 3 { 0 } else { 1 };
                let sub = c.split(half);
                // Each subgroup sums its own members' global ranks.
                let s = sub.allreduce_sum(c.rank() as i64);
                (sub.rank(), sub.size(), s)
            });
            assert_eq!(res[0], (0, 3, 3), "{exec}"); // 0+1+2
            assert_eq!(res[4], (1, 3, 12), "{exec}"); // 3+4+5
        }
    }

    #[test]
    fn split_uneven_sizes() {
        // ⌈5/2⌉ = 3 and ⌊5/2⌋ = 2 — the any-P property PT-Scotch claims.
        let (res, _) = run(5, |c| {
            let half = if c.rank() < 3 { 0 } else { 1 };
            let sub = c.split(half);
            sub.size()
        });
        assert_eq!(res, vec![3, 3, 3, 2, 2]);
    }

    #[test]
    fn barrier_completes() {
        for exec in EXECUTORS {
            let (res, _) = run_on(exec, 4, |c| {
                for _ in 0..10 {
                    c.barrier();
                }
                true
            });
            assert!(res.iter().all(|&x| x), "{exec}");
        }
    }

    #[test]
    fn nested_splits() {
        for exec in EXECUTORS {
            let (res, _) = run_on(exec, 8, |c| {
                let s1 = c.split(c.rank() / 4);
                let s2 = s1.split(s1.rank() / 2);
                (s2.size(), s2.allreduce_sum(1))
            });
            for r in res {
                assert_eq!(r, (2, 2), "{exec}");
            }
        }
    }

    #[test]
    fn overlap_contexts_do_not_cross_talk() {
        for exec in EXECUTORS {
            let (res, _) = run_on(exec, 2, |c| {
                let ca = c.overlap_context(0);
                let cb = c.overlap_context(1);
                if c.rank() == 0 {
                    cb.send(1, 3, vec![2u8]);
                    ca.send(1, 3, vec![1u8]);
                    0u8
                } else {
                    let a: Vec<u8> = ca.recv(0, 3);
                    let b: Vec<u8> = cb.recv(0, 3);
                    a[0] * 10 + b[0]
                }
            });
            assert_eq!(res[1], 12, "{exec}");
        }
    }

    #[test]
    fn overlap_thread_on_same_rank_under_both_executors() {
        // The §3.1 overlap pattern: a scoped thread on the same rank
        // drives comm through a tag-scoped clone while the main thread
        // communicates too. A lost wakeup in either fabric hangs here.
        for exec in EXECUTORS {
            let (res, _) = run_on(exec, 2, |c| {
                let ca = c.overlap_context(0);
                let cb = c.overlap_context(1);
                std::thread::scope(|s| {
                    let h = s.spawn(move || {
                        if cb.rank() == 0 {
                            cb.send(1, 9, vec![5u32]);
                            0u32
                        } else {
                            cb.recv::<u32>(0, 9)[0]
                        }
                    });
                    let main = if ca.rank() == 0 {
                        ca.send(1, 9, vec![7u32]);
                        0u32
                    } else {
                        ca.recv::<u32>(0, 9)[0]
                    };
                    main * 100 + h.join().expect("overlap thread")
                })
            });
            assert_eq!(res[1], 705, "{exec}");
        }
    }

    #[test]
    fn executors_report_identical_traffic_counters() {
        // The determinism contract on the telemetry: same program, same
        // per-rank byte/message tallies on both fabrics.
        let program = |c: Comm| {
            let all = c.allgatherv(vec![c.rank() as u64; c.rank() + 1]);
            let s: u64 = all.iter().map(|v| v.iter().sum::<u64>()).sum();
            let inn = c.alltoallv((0..c.size()).map(|d| vec![d as u32; 3]).collect());
            c.barrier();
            s + inn.concat().iter().map(|&x| x as u64).sum::<u64>()
        };
        let (rs, ss) = run_on(Executor::Sim, 5, program);
        let (rt, st) = run_on(Executor::Threads, 5, program);
        assert_eq!(rs, rt);
        assert_eq!(ss.bytes_sent, st.bytes_sent);
        assert_eq!(ss.msgs_sent, st.msgs_sent);
        // The fault-plan coordinate system: op counts are part of the
        // determinism contract too.
        assert_eq!(ss.transport_ops, st.transport_ops);
    }

    #[test]
    fn injected_panic_is_isolated_on_both_executors() {
        // A scripted panic mid-collective must come back as a
        // structured error — no process abort, no hang — with every
        // surviving rank unwound through the abort protocol.
        for exec in EXECUTORS {
            let cfg = RunConfig {
                fault: Some(FaultPlan::new().panic_at(1, 3)),
                ..RunConfig::default()
            };
            let out = try_run_with(exec, 3, cfg, |c| {
                let mut acc = 0i64;
                for _ in 0..8 {
                    acc += c.allreduce_sum(c.rank() as i64);
                }
                acc
            });
            match out {
                Err(Error::RankPanicked { rank, message }) => {
                    assert_eq!(rank, 1, "{exec}");
                    assert!(message.contains("injected panic"), "{exec}: {message}");
                }
                other => panic!("{exec}: expected RankPanicked, got {other:?}"),
            }
        }
    }

    #[test]
    fn blocked_peer_unwinds_instead_of_hanging() {
        // Rank 1 dies at its very first transport op, before anything
        // reaches rank 0's mailbox. Rank 0 is already parked in a
        // blocking recv under a long stall deadline, so only the abort
        // wakeup can release it — a lost wakeup hangs this test.
        for exec in EXECUTORS {
            let t0 = Instant::now();
            let cfg = RunConfig {
                fault: Some(FaultPlan::new().panic_at(1, 0)),
                ..RunConfig::default()
            };
            let out = try_run_with(exec, 2, cfg, |c| {
                if c.rank() == 0 {
                    c.recv::<u8>(1, 1)
                } else {
                    c.send(0, 1, vec![1u8]);
                    Vec::new()
                }
            });
            assert!(
                matches!(out, Err(Error::RankPanicked { rank: 1, .. })),
                "{exec}: got {out:?}"
            );
            assert!(
                t0.elapsed() < DEFAULT_STALL_DEADLINE,
                "{exec}: abort propagated by deadline, not by wakeup"
            );
        }
    }

    #[test]
    fn overlap_thread_injected_panic_reports_rank_panicked() {
        // Both of a rank's transport threads are live when the scripted
        // panic fires, so whichever thread draws the armed op index,
        // the sibling is (or soon will be) parked in a blocking pop.
        // The panic-time raise must wake it immediately: the result is
        // RankPanicked with the injected message — never FleetStalled,
        // never a wait for the 30s deadline.
        for exec in EXECUTORS {
            for op in [1u64, 3, 5, 8] {
                let t0 = Instant::now();
                let cfg = RunConfig {
                    fault: Some(FaultPlan::new().panic_at(1, op)),
                    stall_deadline: Duration::from_secs(30),
                    ..RunConfig::default()
                };
                let out = try_run_with(exec, 2, cfg, |c| {
                    let ca = c.overlap_context(0);
                    let cb = c.overlap_context(1);
                    std::thread::scope(|s| {
                        let h = s.spawn(move || {
                            cb.guard(|| (0..4).map(|i| cb.allreduce_sum(i)).sum::<i64>())
                        });
                        let main = ca.guard(|| (0..4).map(|i| ca.allreduce_sum(i)).sum::<i64>());
                        let bg = match h.join() {
                            Ok(v) => v,
                            Err(payload) => resume_unwind(payload),
                        };
                        main + bg
                    })
                });
                match out {
                    Err(Error::RankPanicked { rank, message }) => {
                        assert_eq!(rank, 1, "{exec} op={op}");
                        assert!(message.contains("injected panic"), "{exec} op={op}: {message}");
                    }
                    other => panic!("{exec} op={op}: expected RankPanicked, got {other:?}"),
                }
                assert!(
                    t0.elapsed() < Duration::from_secs(10),
                    "{exec} op={op}: abort propagated by deadline, not by wakeup"
                );
            }
        }
    }

    #[test]
    fn guarded_overlap_thread_panic_wakes_blocked_sibling() {
        // A *genuine* bug (not an injected fault) in the overlap thread
        // of rank 1, while rank 1's main thread and both of rank 0's
        // threads are parked in receives nobody will answer. Only the
        // guard's panic-time raise can release them — joining the
        // scope cannot complete while the main thread blocks.
        for exec in EXECUTORS {
            let t0 = Instant::now();
            let cfg = RunConfig {
                fault: None,
                stall_deadline: Duration::from_secs(30),
                ..RunConfig::default()
            };
            let out = try_run_with(exec, 2, cfg, |c| {
                let ca = c.overlap_context(0);
                let cb = c.overlap_context(1);
                std::thread::scope(|s| {
                    let h = s.spawn(move || {
                        cb.guard(|| {
                            if cb.rank() == 1 {
                                panic!("overlap bug on rank 1");
                            }
                            cb.recv::<u8>(1, 5)
                        })
                    });
                    let from = 1 - ca.rank();
                    ca.guard(|| ca.recv::<u8>(from, 6));
                    match h.join() {
                        Ok(v) => v,
                        Err(payload) => resume_unwind(payload),
                    }
                })
            });
            match out {
                Err(Error::RankPanicked { rank, message }) => {
                    assert_eq!(rank, 1, "{exec}");
                    assert!(message.contains("overlap bug"), "{exec}: {message}");
                }
                other => panic!("{exec}: expected RankPanicked, got {other:?}"),
            }
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "{exec}: abort propagated by deadline, not by wakeup"
            );
        }
    }

    #[test]
    fn fleet_progress_restarts_the_stall_clock() {
        // Rank 0 waits well past the armed deadline for its message
        // while ranks 1 and 2 keep exchanging traffic: every exchange
        // restarts rank 0's clock, so the wait must NOT trip
        // FleetStalled. (`stall_deadline_detects_orphan_recv` is the
        // control: the same wait with zero fleet progress does trip.)
        for exec in EXECUTORS {
            let cfg = RunConfig {
                fault: None,
                stall_deadline: Duration::from_millis(400),
                ..RunConfig::default()
            };
            let out = try_run_with(exec, 3, cfg, |c| match c.rank() {
                0 => c.recv::<u8>(1, 99)[0],
                1 => {
                    for i in 0..5u8 {
                        std::thread::sleep(Duration::from_millis(100));
                        c.send(2, 7, vec![i]);
                        let _ = c.recv::<u8>(2, 8);
                    }
                    c.send(0, 99, vec![42u8]);
                    0
                }
                _ => {
                    for _ in 0..5 {
                        let v: Vec<u8> = c.recv(1, 7);
                        c.send(1, 8, v);
                    }
                    0
                }
            });
            let (res, _) = out.unwrap_or_else(|e| panic!("{exec}: spurious stall: {e}"));
            assert_eq!(res[0], 42, "{exec}");
        }
    }

    #[test]
    fn stall_deadline_detects_orphan_recv() {
        // Rank 0 waits for a message nobody will ever send; rank 1
        // returns cleanly. The stall deadline must convert the would-be
        // infinite hang into a structured error naming the waiter.
        for exec in EXECUTORS {
            let cfg = RunConfig {
                fault: None,
                stall_deadline: Duration::from_millis(200),
                ..RunConfig::default()
            };
            let out = try_run_with(exec, 2, cfg, |c| {
                if c.rank() == 0 {
                    c.recv::<u8>(1, 42)
                } else {
                    Vec::new()
                }
            });
            match out {
                Err(Error::FleetStalled { rank, op }) => {
                    assert_eq!(rank, 0, "{exec}");
                    assert!(op.contains("recv from rank 1"), "{exec}: {op}");
                }
                other => panic!("{exec}: expected FleetStalled, got {other:?}"),
            }
        }
    }

    #[test]
    fn injected_stall_trips_the_deadline() {
        // Only rank 1 ever blocks (rank 0 returns without touching the
        // transport), so the stalled rank itself must raise the error —
        // deterministically — when its own deadline expires.
        for exec in EXECUTORS {
            let cfg = RunConfig {
                fault: Some(FaultPlan::new().stall_at(1, 2)),
                stall_deadline: Duration::from_millis(200),
                ..RunConfig::default()
            };
            let out = try_run_with(exec, 2, cfg, |c| {
                if c.rank() == 1 {
                    c.send(0, 1, vec![1u8]); // op 0
                    c.send(0, 2, vec![2u8]); // op 1
                    c.send(0, 3, vec![3u8]); // op 2 — stalls before the push
                }
                c.rank()
            });
            match out {
                Err(Error::FleetStalled { rank, op }) => {
                    assert_eq!(rank, 1, "{exec}");
                    assert!(op.contains("injected stall"), "{exec}: {op}");
                }
                other => panic!("{exec}: expected FleetStalled, got {other:?}"),
            }
        }
    }

    #[test]
    fn injected_delay_keeps_results_and_traffic_bit_identical() {
        let program = |c: Comm| {
            let all = c.allgatherv(vec![c.rank() as u64; 4]);
            c.barrier();
            all.concat().iter().sum::<u64>()
        };
        for exec in EXECUTORS {
            let (clean, cs) = run_on(exec, 3, program);
            let cfg = RunConfig {
                fault: Some(FaultPlan::new().delay_at(0, 1, 15).delay_at(2, 2, 10)),
                ..RunConfig::default()
            };
            let (slow, ss) = try_run_with(exec, 3, cfg, program).unwrap();
            assert_eq!(clean, slow, "{exec}");
            assert_eq!(cs.bytes_sent, ss.bytes_sent, "{exec}");
            assert_eq!(cs.msgs_sent, ss.msgs_sent, "{exec}");
            assert_eq!(cs.transport_ops, ss.transport_ops, "{exec}");
        }
    }

    #[test]
    fn fleet_failure_panics_through_the_infallible_wrapper() {
        // `run_on` keeps its pre-fault-model contract for callers that
        // treat failure as fatal: the structured error surfaces as a
        // panic, not a hang.
        let caught = std::panic::catch_unwind(|| {
            let cfg = RunConfig {
                fault: Some(FaultPlan::new().panic_at(0, 0)),
                ..RunConfig::default()
            };
            // Equivalent of run_on with an explicit plan.
            try_run_with(Executor::Sim, 2, cfg, |c| c.allreduce_sum(1))
                .unwrap_or_else(|e| panic!("fleet failed: {e}"))
        });
        let msg = panic_message(caught.expect_err("must panic").as_ref());
        assert!(msg.contains("rank 0 panicked"), "{msg}");
    }

    #[test]
    fn wall_and_blocked_time_are_recorded() {
        for exec in EXECUTORS {
            let (_, stats) = run_on(exec, 2, |c| {
                if c.rank() == 0 {
                    // Receiver waits for a deliberately late message, so
                    // its blocked time must register.
                    c.recv::<u8>(1, 1)
                } else {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                    c.send(0, 1, vec![1u8]);
                    Vec::new()
                }
            });
            assert!(stats.wall_ns.iter().all(|&w| w > 0), "{exec}");
            assert!(
                stats.blocked_ns[0] > 0,
                "{exec}: rank 0 waited ≥20ms but recorded no blocked time"
            );
            // Busy time never exceeds wall time for a single-threaded rank.
            assert!(stats.busy_ns()[0] <= stats.wall_ns[0], "{exec}");
        }
    }
}
