//! Deterministic fault injection for the comm fabric (DESIGN.md §3.2).
//!
//! A [`FaultPlan`] is a *script*: a set of one-shot triggers keyed on
//! `(rank, transport-op index)`. Every transport operation a rank
//! performs — each mailbox push and each (possibly blocking) pop —
//! advances that rank's op counter, and when the counter hits an armed
//! trigger the scripted [`FaultAction`] fires:
//!
//! * [`FaultAction::Panic`] — the rank unwinds as if its program
//!   panicked, exercising the panic-isolation and abort-propagation
//!   path (`Error::RankPanicked`);
//! * [`FaultAction::Delay`] — the rank sleeps before the op proceeds.
//!   By the determinism contract (DESIGN.md §3) a delay must never
//!   change results or traffic counters, only wallclock — the
//!   fault-injection suite pins this bit-for-bit;
//! * [`FaultAction::Stall`] — the rank stops making progress without
//!   panicking, exercising the stall-deadline path
//!   (`Error::FleetStalled`).
//!
//! Op-count triggers make injection *deterministic*: a rank's op
//! counter is schedule-independent, so the same plan on the same
//! program fires at the same count on either executor, with no flaky
//! sleeps. **Caveat:** the counter is shared by *all* of a rank's
//! transport threads. While a rank runs single-threaded the Nth op is
//! always the same program point; when the §3.1 overlap thread is on
//! (strategy `overlap=1`, the default) the two threads' ops interleave
//! into the shared counter in schedule-dependent order, so a trigger
//! at `(rank, op)` still fires exactly once at the rank's Nth op — and
//! panic isolation and abort propagation hold regardless of which
//! thread draws it — but the *program point* it lands on can differ
//! between runs. Tests that assert point-precise behavior pin
//! `overlap=0`. Plans come from code ([`FaultPlan::panic_at`] and
//! friends) or from the [`FAULT_ENV`] environment variable; an
//! absent/empty plan costs one branch per transport op.
//!
//! Triggers are **one-shot**: a trigger that fired stays consumed for
//! the lifetime of the plan, across every fleet sharing it (clones
//! share trigger state). This is what makes the service-level recovery
//! ladder testable — a one-shot panic fails the first attempt and lets
//! the retry complete (DESIGN.md §6).

use crate::{Error, Result};
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering as AOrd};
use std::sync::Arc;

/// Environment variable holding a fault spec applied to every fleet
/// launched without a programmatic plan. Grammar (entries joined by
/// `;`): `RANK@OP:panic`, `RANK@OP:stall`, `RANK@OP:delay(MS)` — e.g.
/// `PTSCOTCH_FAULT="1@50:panic;0@10:delay(5)"`. A malformed spec is
/// surfaced as [`Error::BadEnv`] through the fallible run entry points,
/// the service and the CLI.
pub const FAULT_ENV: &str = "PTSCOTCH_FAULT";

/// What an armed trigger does when its `(rank, op)` point is reached.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Unwind the rank as if its program panicked. The fleet reports
    /// `Error::RankPanicked` with an "injected panic" message.
    Panic,
    /// Sleep this many milliseconds before the op proceeds. Results
    /// must be bit-identical to the fault-free run.
    Delay(u64),
    /// Park the rank until the fleet aborts; if nothing else trips the
    /// stall deadline first, the parked rank raises
    /// `Error::FleetStalled` itself when its own deadline expires.
    Stall,
}

impl fmt::Display for FaultAction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultAction::Panic => f.write_str("panic"),
            FaultAction::Delay(ms) => write!(f, "delay({ms})"),
            FaultAction::Stall => f.write_str("stall"),
        }
    }
}

/// One armed `(rank, op) → action` trigger with its consumed flag.
#[derive(Debug)]
struct Trigger {
    rank: usize,
    op: u64,
    action: FaultAction,
    fired: AtomicBool,
}

/// A scripted, deterministic fault-injection plan (module docs above).
///
/// Cloning is cheap and **shares** trigger state: a plan handed to a
/// service fires each trigger exactly once across all the fleets (and
/// retries) that service runs.
///
/// ```
/// use ptscotch::comm::{FaultAction, FaultPlan};
///
/// let plan = FaultPlan::parse("1@5:panic;0@3:delay(10);2@7:stall").unwrap();
/// assert_eq!(plan.len(), 3);
/// // Programmatic construction is equivalent:
/// let same = FaultPlan::new().panic_at(1, 5).delay_at(0, 3, 10).stall_at(2, 7);
/// assert_eq!(same.len(), 3);
/// assert!(FaultPlan::new().is_empty());
/// assert!(FaultPlan::parse("1@5:reboot").is_err());
/// ```
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    triggers: Arc<Vec<Trigger>>,
}

impl FaultPlan {
    /// An empty plan (injects nothing; useful as a builder seed).
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    fn push(mut self, rank: usize, op: u64, action: FaultAction) -> FaultPlan {
        Arc::get_mut(&mut self.triggers)
            .expect("extend a FaultPlan before cloning/sharing it")
            .push(Trigger {
                rank,
                op,
                action,
                fired: AtomicBool::new(false),
            });
        self
    }

    /// Arm a one-shot panic at `rank`'s `op`-th transport operation.
    pub fn panic_at(self, rank: usize, op: u64) -> FaultPlan {
        self.push(rank, op, FaultAction::Panic)
    }

    /// Arm a one-shot `millis`-millisecond delay at `rank`'s `op`-th
    /// transport operation.
    pub fn delay_at(self, rank: usize, op: u64, millis: u64) -> FaultPlan {
        self.push(rank, op, FaultAction::Delay(millis))
    }

    /// Arm a one-shot stall at `rank`'s `op`-th transport operation.
    pub fn stall_at(self, rank: usize, op: u64) -> FaultPlan {
        self.push(rank, op, FaultAction::Stall)
    }

    /// Number of triggers in the plan (fired or not).
    pub fn len(&self) -> usize {
        self.triggers.len()
    }

    /// Does the plan hold no triggers at all?
    pub fn is_empty(&self) -> bool {
        self.triggers.is_empty()
    }

    /// Consume and return the action of the first unfired trigger armed
    /// at `(rank, op)`, if any. Several triggers may share a `(rank,
    /// op)` point; each call consumes at most one, so a plan with k
    /// identical panic triggers fails exactly k fleet runs.
    pub(crate) fn check(&self, rank: usize, op: u64) -> Option<FaultAction> {
        for t in self.triggers.iter() {
            if t.rank == rank && t.op == op && !t.fired.swap(true, AOrd::AcqRel) {
                return Some(t.action);
            }
        }
        None
    }

    /// Parse a [`FAULT_ENV`]-grammar spec (see the constant's docs).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let bad = |entry: &str, why: &str| {
            Error::BadEnv(format!(
                "{FAULT_ENV}: bad fault entry {entry:?}: {why} \
                 (grammar: RANK@OP:panic|stall|delay(MS), entries joined by ';')"
            ))
        };
        let mut plan = FaultPlan::new();
        for entry in spec.split(';').map(str::trim).filter(|e| !e.is_empty()) {
            let (pos, action) = entry
                .split_once(':')
                .ok_or_else(|| bad(entry, "missing ':'"))?;
            let (rank, op) = pos
                .split_once('@')
                .ok_or_else(|| bad(entry, "missing '@'"))?;
            let rank: usize = rank
                .trim()
                .parse()
                .map_err(|_| bad(entry, "rank is not a number"))?;
            let op: u64 = op
                .trim()
                .parse()
                .map_err(|_| bad(entry, "op index is not a number"))?;
            let action = match action.trim() {
                "panic" => FaultAction::Panic,
                "stall" => FaultAction::Stall,
                other => {
                    let ms = other
                        .strip_prefix("delay(")
                        .and_then(|s| s.strip_suffix(')'))
                        .ok_or_else(|| bad(entry, "unknown action"))?;
                    FaultAction::Delay(
                        ms.trim()
                            .parse()
                            .map_err(|_| bad(entry, "delay millis is not a number"))?,
                    )
                }
            };
            plan = plan.push(rank, op, action);
        }
        Ok(plan)
    }

    /// The plan named by [`FAULT_ENV`]: `Ok(None)` when the variable is
    /// unset or empty, [`Error::BadEnv`] when it is set but malformed.
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var(FAULT_ENV) {
            Ok(v) if v.trim().is_empty() => Ok(None),
            Ok(v) => FaultPlan::parse(&v).map(Some),
            Err(_) => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_every_action() {
        let plan = FaultPlan::parse(" 1@5:panic ; 0@3:delay( 10 ) ; 2@7:stall ").unwrap();
        assert_eq!(plan.len(), 3);
        assert_eq!(plan.check(1, 5), Some(FaultAction::Panic));
        assert_eq!(plan.check(0, 3), Some(FaultAction::Delay(10)));
        assert_eq!(plan.check(2, 7), Some(FaultAction::Stall));
        assert_eq!(plan.check(1, 6), None);
    }

    #[test]
    fn triggers_are_one_shot_and_shared_across_clones() {
        let plan = FaultPlan::new().panic_at(0, 4).panic_at(0, 4);
        let alias = plan.clone();
        // Two triggers at the same point: each check consumes one,
        // through either handle.
        assert_eq!(plan.check(0, 4), Some(FaultAction::Panic));
        assert_eq!(alias.check(0, 4), Some(FaultAction::Panic));
        assert_eq!(plan.check(0, 4), None);
        assert_eq!(alias.check(0, 4), None);
    }

    #[test]
    fn malformed_specs_are_bad_env() {
        for spec in [
            "nonsense",
            "1@2",
            "1@2:reboot",
            "x@2:panic",
            "1@y:panic",
            "1@2:delay(ms)",
            "1@2:delay(5",
        ] {
            let err = FaultPlan::parse(spec).unwrap_err();
            assert!(
                matches!(err, Error::BadEnv(_)),
                "{spec:?}: expected BadEnv, got {err}"
            );
        }
    }

    #[test]
    fn empty_spec_parses_to_empty_plan() {
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" ; ; ").unwrap().is_empty());
    }

    #[test]
    fn actions_display_in_spec_grammar() {
        assert_eq!(FaultAction::Panic.to_string(), "panic");
        assert_eq!(FaultAction::Delay(25).to_string(), "delay(25)");
        assert_eq!(FaultAction::Stall.to_string(), "stall");
    }
}
