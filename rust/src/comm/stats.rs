//! Per-rank traffic and memory accounting.
//!
//! On this single-core container, wallclock speedup is unmeasurable, so
//! the scalability analysis of EXPERIMENTS.md reports what the paper's
//! timing curves are made of: per-rank communication volume/counts and
//! peak tracked memory (Figures 10–11 are per-process memory plots).

/// Immutable snapshot of the transport counters after a run.
#[derive(Clone, Debug)]
pub struct StatsSnapshot {
    /// Bytes sent by each global rank.
    pub bytes_sent: Vec<u64>,
    /// Messages sent by each global rank.
    pub msgs_sent: Vec<u64>,
}

impl StatsSnapshot {
    /// Total bytes sent by all ranks.
    pub fn total_bytes(&self) -> u64 {
        self.bytes_sent.iter().sum()
    }

    /// Total messages sent by all ranks.
    pub fn total_msgs(&self) -> u64 {
        self.msgs_sent.iter().sum()
    }

    /// Maximum bytes sent by any one rank (load-imbalance indicator).
    pub fn max_bytes(&self) -> u64 {
        self.bytes_sent.iter().copied().max().unwrap_or(0)
    }
}

/// Per-rank memory tracker for the graph working set. The distributed
/// pipeline calls [`MemTracker::grow`]/[`MemTracker::shrink`] as graph
/// fragments are created and dropped and records the running peak —
/// reproducing the quantity plotted in Figures 10–11.
#[derive(Debug, Default)]
pub struct MemTracker {
    live: std::cell::Cell<i64>,
    peak: std::cell::Cell<i64>,
}

impl MemTracker {
    /// New tracker with zero usage.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register `bytes` of newly live graph data.
    pub fn grow(&self, bytes: usize) {
        let live = self.live.get() + bytes as i64;
        self.live.set(live);
        if live > self.peak.get() {
            self.peak.set(live);
        }
    }

    /// Register `bytes` of released graph data.
    pub fn shrink(&self, bytes: usize) {
        self.live.set(self.live.get() - bytes as i64);
    }

    /// Current live bytes.
    pub fn live(&self) -> i64 {
        self.live.get()
    }

    /// Peak live bytes observed.
    pub fn peak(&self) -> i64 {
        self.peak.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let s = StatsSnapshot {
            bytes_sent: vec![10, 30, 20],
            msgs_sent: vec![1, 2, 3],
        };
        assert_eq!(s.total_bytes(), 60);
        assert_eq!(s.total_msgs(), 6);
        assert_eq!(s.max_bytes(), 30);
    }

    #[test]
    fn mem_tracker_peak() {
        let t = MemTracker::new();
        t.grow(100);
        t.grow(50);
        t.shrink(120);
        t.grow(10);
        assert_eq!(t.live(), 40);
        assert_eq!(t.peak(), 150);
    }
}
