//! Per-rank traffic, wallclock and memory accounting.
//!
//! The scalability analysis of EXPERIMENTS.md reports what the paper's
//! timing curves are made of: per-rank communication volume/counts,
//! peak tracked memory (Figures 10–11 are per-process memory plots),
//! and — since the threaded executor landed (DESIGN.md §3) — per-rank
//! wallclock split into busy and transport-blocked time. On a multicore
//! host the threaded executor's wallclock is a direct speedup
//! measurement; on a single core the **critical path** (the maximum
//! per-rank busy time) models what ≥ p cores would deliver.

/// Immutable snapshot of the transport counters after a run.
#[derive(Clone, Debug)]
pub struct StatsSnapshot {
    /// Bytes sent by each global rank.
    pub bytes_sent: Vec<u64>,
    /// Messages sent by each global rank.
    pub msgs_sent: Vec<u64>,
    /// Wallclock nanoseconds of each rank's program, thread start to
    /// return.
    pub wall_ns: Vec<u64>,
    /// Nanoseconds each rank spent blocked inside the transport waiting
    /// for a message that had not arrived yet. With the §3.1 overlap
    /// thread active, both threads of a rank charge the same counter,
    /// so a rank's blocked time may exceed its wallclock.
    pub blocked_ns: Vec<u64>,
    /// Transport operations (mailbox pushes + pops) performed by each
    /// global rank. Schedule-independent like the traffic counters —
    /// identical across executors — and the coordinate system of the
    /// fault-injection plan (DESIGN.md §3.2): a trigger armed at
    /// `(rank, op)` fires at that rank's `op`-th operation.
    pub transport_ops: Vec<u64>,
    /// Per-rank span traces, in rank order; non-empty only when the
    /// fleet ran with a [`crate::comm::RunConfig`] `trace` level other
    /// than off (DESIGN.md §7). The recorder observes the counters
    /// above without perturbing them, so every other column is
    /// bit-identical to an untraced run.
    pub traces: Vec<crate::trace::RankTrace>,
}

impl StatsSnapshot {
    /// Total bytes sent by all ranks.
    pub fn total_bytes(&self) -> u64 {
        self.bytes_sent.iter().sum()
    }

    /// Total messages sent by all ranks.
    pub fn total_msgs(&self) -> u64 {
        self.msgs_sent.iter().sum()
    }

    /// Maximum bytes sent by any one rank (load-imbalance indicator).
    pub fn max_bytes(&self) -> u64 {
        self.bytes_sent.iter().copied().max().unwrap_or(0)
    }

    /// Per-rank busy nanoseconds: wallclock minus transport-blocked
    /// time, clamped at zero (overlap threads can over-charge blocking;
    /// see [`StatsSnapshot::blocked_ns`]).
    pub fn busy_ns(&self) -> Vec<u64> {
        self.wall_ns
            .iter()
            .zip(&self.blocked_ns)
            .map(|(&w, &b)| w.saturating_sub(b))
            .collect()
    }

    /// Wallclock of the slowest rank, in seconds — the fleet's measured
    /// elapsed time from inside the rank programs.
    pub fn max_wall_seconds(&self) -> f64 {
        self.wall_ns.iter().copied().max().unwrap_or(0) as f64 / 1e9
    }

    /// The critical path of the fleet in seconds: the maximum per-rank
    /// *busy* time. On a host with at least one core per rank this is
    /// the wallclock the threaded executor converges to; on fewer cores
    /// it models the speedup the same program would show there.
    pub fn critical_path_seconds(&self) -> f64 {
        self.busy_ns().into_iter().max().unwrap_or(0) as f64 / 1e9
    }
}

/// Per-rank memory tracker for the graph working set. The distributed
/// pipeline calls [`MemTracker::grow`]/[`MemTracker::shrink`] as graph
/// fragments are created and dropped and records the running peak —
/// reproducing the quantity plotted in Figures 10–11.
#[derive(Debug, Default)]
pub struct MemTracker {
    live: std::cell::Cell<i64>,
    peak: std::cell::Cell<i64>,
}

impl MemTracker {
    /// New tracker with zero usage.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register `bytes` of newly live graph data.
    pub fn grow(&self, bytes: usize) {
        let live = self.live.get() + bytes as i64;
        self.live.set(live);
        if live > self.peak.get() {
            self.peak.set(live);
        }
    }

    /// Register `bytes` of released graph data.
    pub fn shrink(&self, bytes: usize) {
        self.live.set(self.live.get() - bytes as i64);
    }

    /// Current live bytes.
    pub fn live(&self) -> i64 {
        self.live.get()
    }

    /// Peak live bytes observed.
    pub fn peak(&self) -> i64 {
        self.peak.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_aggregates() {
        let s = StatsSnapshot {
            bytes_sent: vec![10, 30, 20],
            msgs_sent: vec![1, 2, 3],
            wall_ns: vec![5_000, 9_000, 7_000],
            blocked_ns: vec![1_000, 9_500, 3_000],
            transport_ops: vec![2, 4, 6],
            traces: Vec::new(),
        };
        assert_eq!(s.total_bytes(), 60);
        assert_eq!(s.total_msgs(), 6);
        assert_eq!(s.max_bytes(), 30);
        // Busy clamps at zero when overlap threads over-charge blocking.
        assert_eq!(s.busy_ns(), vec![4_000, 0, 4_000]);
        assert!((s.max_wall_seconds() - 9e-6).abs() < 1e-12);
        assert!((s.critical_path_seconds() - 4e-6).abs() < 1e-12);
    }

    /// Regression test for the `busy_ns` underflow: a heavily delayed
    /// rank (fault-injection delay runs with the §3.1 overlap thread
    /// active) can legitimately report `blocked_ns > wall_ns`; the
    /// subtraction must clamp at zero instead of wrapping to ~2^64.
    #[test]
    fn busy_ns_saturates_when_blocked_exceeds_wall() {
        let s = StatsSnapshot {
            bytes_sent: vec![0, 0],
            msgs_sent: vec![0, 0],
            wall_ns: vec![1_000, 4_000],
            blocked_ns: vec![250_000, 1_000],
            transport_ops: vec![0, 0],
            traces: Vec::new(),
        };
        assert_eq!(s.busy_ns(), vec![0, 3_000]);
        // The critical path must come out of the *clamped* column.
        assert!((s.critical_path_seconds() - 3e-6).abs() < 1e-12);
    }

    #[test]
    fn mem_tracker_peak() {
        let t = MemTracker::new();
        t.grow(100);
        t.grow(50);
        t.shrink(120);
        t.grow(10);
        assert_eq!(t.live(), 40);
        assert_eq!(t.peak(), 150);
    }
}
