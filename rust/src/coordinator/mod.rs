//! Coordinator (S20): the strategy-driven front door of the system.
//!
//! [`OrderingService`] owns the XLA runtime (loaded once, reused across
//! jobs — Python never runs at request time), picks the band refiner per
//! strategy, launches the rank fleet on the selected executor
//! (`executor=sim|threads`, DESIGN.md §3), and returns orderings
//! with the paper's quality metrics and per-rank telemetry. Work is
//! described by an [`OrderingRequest`] (graph + strategy + engine + tag)
//! and answered with an [`OrderingResult`] bundling the permutation, the
//! solver-facing [`BlockOrdering`] and the [`OrderingReport`]. The
//! [`service`] module stacks the batch driver with its
//! graph-fingerprint cache on top (DESIGN.md §6). The CLI
//! (`rust/src/main.rs`), examples and all benches go through this API.

pub mod metrics;
pub mod service;

pub use metrics::{OrderingReport, PhaseTimer, ServiceMetrics, ServiceSnapshot};
pub use service::{BatchCoordinator, RequestReport, Route, Served, ServiceConfig};

use crate::baseline::parmetis_like_order;
use crate::comm;
use crate::dist::parallel_order;
use crate::graph::Graph;
use crate::order::{
    block_ordering, nested_dissection, symbolic_cholesky, BlockOrdering, Ordering,
};
use crate::rng::Rng;
use crate::runtime::{load_shared, DiffusionRefiner, SharedRuntime};
use crate::sep::diffusion::CpuDiffusionRefiner;
use crate::sep::{BandRefiner, FmRefiner};
use crate::strategy::{BandEngine, RefinerKind, Strategy};
use crate::trace::{self, PhaseProfile, TraceLevel};
use crate::{Error, Result};
use std::ops::Deref;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

/// Which ordering engine to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Sequential Scotch-like pipeline (reference / Table 1 `O_SS`).
    Sequential,
    /// PT-Scotch parallel nested dissection on `p` simulated ranks.
    PtScotch { p: usize },
    /// ParMETIS-like baseline on `p` simulated ranks (power of two).
    ParMetisLike { p: usize },
}

impl Engine {
    /// `(discriminant, process count)` — the engine's contribution to
    /// the request fingerprint.
    fn fingerprint_words(self) -> (u64, u64) {
        match self {
            Engine::Sequential => (0, 1),
            Engine::PtScotch { p } => (1, p as u64),
            Engine::ParMetisLike { p } => (2, p as u64),
        }
    }
}

/// One unit of work for the service: *which graph*, ordered *how*, *on
/// what engine*. Built fluently:
///
/// ```
/// use ptscotch::coordinator::{Engine, OrderingRequest, OrderingService};
/// use ptscotch::graph::generators;
///
/// let g = generators::grid2d(12, 12);
/// let req = OrderingRequest::new(&g)
///     .parse_strategy("seed=7,executor=sim")?
///     .engine(Engine::PtScotch { p: 4 })
///     .tag("demo");
/// let res = OrderingService::new_cpu_only().run(&req)?;
/// assert_eq!(res.ordering.n(), 144);
/// res.blocks.validate(144)?;
/// # Ok::<(), ptscotch::Error>(())
/// ```
///
/// The graph is held behind an [`Arc`] so queued and coalesced jobs
/// share one CSR; [`OrderingRequest::fingerprint`] is the cache key the
/// batch coordinator dedupes on (DESIGN.md §6).
#[derive(Clone, Debug)]
pub struct OrderingRequest {
    /// The graph to order (shared, never copied per job).
    pub graph: Arc<Graph>,
    /// The ordering strategy; its canonical `Display` form enters the
    /// fingerprint, so equal-valued strategies dedupe.
    pub strategy: Strategy,
    /// The engine (and its process count).
    pub engine: Engine,
    /// Free-form client label, carried through to the per-request
    /// [`RequestReport`]; never part of the fingerprint.
    pub tag: String,
}

impl OrderingRequest {
    /// Start a request for `graph` (cloned once into shared ownership)
    /// with the default strategy on the sequential engine.
    pub fn new(graph: &Graph) -> OrderingRequest {
        OrderingRequest::from_arc(Arc::new(graph.clone()))
    }

    /// Start a request for an already-shared graph without copying it.
    pub fn from_arc(graph: Arc<Graph>) -> OrderingRequest {
        OrderingRequest {
            graph,
            strategy: Strategy::default(),
            engine: Engine::Sequential,
            tag: String::new(),
        }
    }

    /// Use this strategy.
    pub fn strategy(mut self, strategy: Strategy) -> OrderingRequest {
        self.strategy = strategy;
        self
    }

    /// Parse and use this `key=value,…` strategy spec.
    pub fn parse_strategy(mut self, spec: &str) -> Result<OrderingRequest> {
        self.strategy = Strategy::parse(spec)?;
        Ok(self)
    }

    /// Run on this engine.
    pub fn engine(mut self, engine: Engine) -> OrderingRequest {
        self.engine = engine;
        self
    }

    /// Attach a client label.
    pub fn tag(mut self, tag: impl Into<String>) -> OrderingRequest {
        self.tag = tag.into();
        self
    }

    /// Content fingerprint of the request: a 128-bit FNV-1a over the
    /// graph CSR arrays, the canonical strategy string and the engine
    /// discriminant + process count. Two requests with equal
    /// fingerprints describe the same computation, so the service may
    /// serve one's cached result for the other (DESIGN.md §6).
    pub fn fingerprint(&self) -> u128 {
        const OFFSET: u128 = 0x6c62_272e_07bb_0142_62b8_2175_6295_c58d;
        const PRIME: u128 = 0x0000_0000_0100_0000_0000_0000_0000_013b;
        let mut h = OFFSET;
        let mut mix = |w: u64| {
            h = (h ^ w as u128).wrapping_mul(PRIME);
        };
        let g = &self.graph;
        mix(g.n() as u64);
        for &x in &g.xadj {
            mix(x as u64);
        }
        for &a in &g.adj {
            mix(a as u64);
        }
        for &w in &g.vwgt {
            mix(w as u64);
        }
        for &w in &g.ewgt {
            mix(w as u64);
        }
        let canon = self.strategy.to_string();
        mix(canon.len() as u64);
        for b in canon.bytes() {
            mix(b as u64);
        }
        let (kind, p) = self.engine.fingerprint_words();
        mix(kind);
        mix(p);
        h
    }
}

/// The unified answer to an [`OrderingRequest`]: the permutation, the
/// solver-facing block structure, and the quality/telemetry report.
/// `Deref`s to [`OrderingReport`] so report fields read directly
/// (`res.stats`, `res.wall_seconds`, …).
#[derive(Clone, Debug)]
pub struct OrderingResult {
    /// The computed ordering (`perm`/`iperm`).
    pub ordering: Ordering,
    /// Supernode column ranges + block forest, the Tacho-facing
    /// contract ([`BlockOrdering`]).
    pub blocks: BlockOrdering,
    /// Quality metrics and fleet telemetry.
    pub report: OrderingReport,
}

impl Deref for OrderingResult {
    type Target = OrderingReport;

    fn deref(&self) -> &OrderingReport {
        &self.report
    }
}

/// The ordering service: reusable across jobs.
pub struct OrderingService {
    runtime: Option<SharedRuntime>,
    /// Programmatic fault-injection plan for every fleet this service
    /// launches; `None` defers to the `PTSCOTCH_FAULT` env spec.
    fault: Option<comm::FaultPlan>,
    /// Stall deadline handed to every fleet (DESIGN.md §3.2).
    stall_deadline: std::time::Duration,
}

impl OrderingService {
    /// Build a service without XLA artifacts (FM / CPU-diffusion only).
    pub fn new_cpu_only() -> OrderingService {
        OrderingService {
            runtime: None,
            fault: None,
            stall_deadline: comm::DEFAULT_STALL_DEADLINE,
        }
    }

    /// Build a service, loading AOT artifacts from `dir` if present.
    /// Missing artifacts are not an error unless a strategy later
    /// demands the XLA refiner.
    pub fn new(dir: &Path) -> OrderingService {
        OrderingService {
            runtime: load_shared(dir).ok(),
            ..OrderingService::new_cpu_only()
        }
    }

    /// Is the XLA runtime loaded?
    pub fn has_xla(&self) -> bool {
        self.runtime.is_some()
    }

    /// Inject scripted faults into every fleet this service launches
    /// (overrides the `PTSCOTCH_FAULT` env spec). Triggers are one-shot
    /// and shared across runs, so a single-trigger plan fails exactly
    /// one fleet — the shape the recovery-ladder tests rely on.
    pub fn with_fault_plan(mut self, plan: comm::FaultPlan) -> OrderingService {
        self.fault = Some(plan);
        self
    }

    /// Use this stall deadline for every fleet (default
    /// [`comm::DEFAULT_STALL_DEADLINE`]). The deadline measures time
    /// with **zero fleet-wide transport progress** — any message
    /// deposited or consumed anywhere restarts every waiter's clock —
    /// so an imbalanced-but-communicating fleet never trips it; an
    /// ordering whose all-compute phases (e.g. sequential leaf
    /// ordering of a huge folded branch) can exceed the deadline with
    /// no transport at all should raise it, or disable the backstop
    /// entirely with [`comm::NO_STALL_DEADLINE`].
    pub fn with_stall_deadline(mut self, deadline: std::time::Duration) -> OrderingService {
        self.stall_deadline = deadline;
        self
    }

    /// The fleet run configuration: the programmatic fault plan if one
    /// was set, else whatever `PTSCOTCH_FAULT` names (a malformed spec
    /// is `Error::BadEnv`), plus the request's `trace=` level.
    fn run_config(&self, trace: TraceLevel) -> Result<comm::RunConfig> {
        let fault = match &self.fault {
            Some(plan) => Some(plan.clone()),
            None => comm::FaultPlan::from_env()?,
        };
        Ok(comm::RunConfig {
            fault,
            stall_deadline: self.stall_deadline,
            trace,
        })
    }

    /// Materialize the refiner for a strategy.
    pub fn refiner(&self, strat: &Strategy) -> Result<Box<dyn BandRefiner + Send + Sync>> {
        match strat.refiner {
            RefinerKind::Fm => Ok(Box::new(FmRefiner {
                params: strat.sep.fm.clone(),
            })),
            RefinerKind::DiffusionCpu => Ok(Box::new(CpuDiffusionRefiner {
                fm: strat.sep.fm.clone(),
                ..CpuDiffusionRefiner::default()
            })),
            RefinerKind::DiffusionXla => {
                let rt = self.runtime.clone().ok_or_else(|| {
                    Error::NoArtifact(
                        "strategy requests the XLA refiner but no artifacts are loaded \
                         (run `make artifacts`)"
                            .into(),
                    )
                })?;
                let mut r = DiffusionRefiner::new(rt);
                r.fm = strat.sep.fm.clone();
                Ok(Box::new(r))
            }
        }
    }

    /// Execute one [`OrderingRequest`] to completion — the unified
    /// entry point behind the CLI, examples, benches and the batch
    /// coordinator. Returns the permutation, the solver-facing block
    /// structure and the quality/telemetry report. The rank fleet of
    /// the distributed engines runs on the executor named by the
    /// `executor=` strategy knob, falling back to `PTSCOTCH_EXECUTOR`
    /// and then to the serialized simulator (DESIGN.md §3).
    pub fn run(&self, req: &OrderingRequest) -> Result<OrderingResult> {
        let g: &Graph = &req.graph;
        let strat = &req.strategy;
        strat.validate()?;
        g.validate()?;
        let exec = match strat.dist.executor {
            Some(e) => e,
            None => comm::Executor::from_env()?,
        };
        let t0 = Instant::now();
        type Telemetry = (Ordering, Vec<i64>, comm::StatsSnapshot);
        let (ordering, peak_mem, fleet): Telemetry = match req.engine {
            Engine::Sequential => {
                let refiner = self.refiner(strat)?;
                let mut rng = Rng::new(strat.seed);
                // The sequential engine runs no fleet, so the span
                // recorder is installed right here on the caller's
                // thread — no counter probe (there is no transport, so
                // every counter column stays zero) and an explicit run
                // root so the profile tiles like the distributed one.
                if strat.trace != TraceLevel::Off {
                    trace::install(0, strat.trace, Instant::now(), None);
                }
                let o = {
                    let _run = trace::scope_at(trace::Phase::Run, 0);
                    nested_dissection(g, strat, refiner.as_ref(), &mut rng)
                };
                let fleet = comm::StatsSnapshot {
                    bytes_sent: vec![0],
                    msgs_sent: vec![0],
                    wall_ns: Vec::new(),
                    blocked_ns: Vec::new(),
                    transport_ops: Vec::new(),
                    traces: trace::take().into_iter().collect(),
                };
                (o, vec![g.footprint_bytes() as i64], fleet)
            }
            Engine::PtScotch { p } => {
                let ga = Arc::clone(&req.graph);
                let strat2 = strat.clone();
                let service_refiner: Arc<dyn BandRefiner + Send + Sync> =
                    Arc::from(self.refiner(strat)?);
                // Hand the loaded runtime to the rank fleet so the
                // distributed diffusion path can execute the fused
                // kernel per rank; `engine=cpu` pins the scalar
                // sweeps without consulting the runtime at all.
                let band_rt = match strat.dist.band_engine {
                    BandEngine::Cpu => None,
                    BandEngine::Auto | BandEngine::Xla => self.runtime.clone(),
                };
                let cfg = self.run_config(strat.trace)?;
                let (res, stats) = comm::try_run_with(exec, p, cfg, move |c| {
                    let r = parallel_order(
                        &c,
                        &ga,
                        &strat2,
                        service_refiner.as_ref(),
                        band_rt.as_ref(),
                    );
                    (r.ordering, r.peak_mem)
                })?;
                let mems = res.iter().map(|(_, m)| *m).collect();
                let o = res.into_iter().next().expect("rank 0 result").0;
                (o, mems, stats)
            }
            Engine::ParMetisLike { p } => {
                if !p.is_power_of_two() {
                    return Err(Error::NonPowerOfTwo(p));
                }
                let ga = Arc::clone(&req.graph);
                let strat2 = strat.clone();
                let cfg = self.run_config(strat.trace)?;
                let (res, stats) = comm::try_run_with(exec, p, cfg, move |c| {
                    let r = parmetis_like_order(&c, &ga, &strat2)?;
                    Ok::<_, Error>((r.ordering, r.peak_mem))
                })?;
                let mut orderings = Vec::new();
                let mut mems = Vec::new();
                for r in res {
                    let (o, m) = r?;
                    orderings.push(o);
                    mems.push(m);
                }
                (orderings.into_iter().next().expect("rank 0"), mems, stats)
            }
        };
        let wall = t0.elapsed();
        ordering.validate()?;
        let stats = symbolic_cholesky(g, &ordering);
        let blocks = block_ordering(g, &ordering);
        debug_assert!(blocks.validate(g.n()).is_ok());
        // Merge the per-rank traces into the hierarchical profile. A
        // malformed stream is an internal invariant violation (spans
        // are RAII guards), so the error propagates rather than being
        // silently dropped.
        let profile = if fleet.traces.is_empty() {
            None
        } else {
            Some(PhaseProfile::build(&fleet.traces)?)
        };
        Ok(OrderingResult {
            ordering,
            blocks,
            report: OrderingReport {
                stats,
                executor: exec,
                wall_seconds: wall.as_secs_f64(),
                peak_mem_per_rank: peak_mem,
                bytes_sent_per_rank: fleet.bytes_sent,
                msgs_sent_per_rank: fleet.msgs_sent,
                wall_ns_per_rank: fleet.wall_ns,
                blocked_ns_per_rank: fleet.blocked_ns,
                transport_ops_per_rank: fleet.transport_ops,
                traces: fleet.traces,
                profile,
            },
        })
    }

    /// One-shot positional entry point, superseded by the
    /// [`OrderingRequest`] builder + [`OrderingService::run`].
    #[deprecated(since = "0.1.0", note = "build an OrderingRequest and call run()")]
    pub fn order(&self, g: &Graph, engine: Engine, strat: &Strategy) -> Result<OrderingResult> {
        self.run(&OrderingRequest::new(g).strategy(strat.clone()).engine(engine))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn sequential_engine_reports_quality() {
        let g = generators::grid2d(16, 16);
        let svc = OrderingService::new_cpu_only();
        let res = svc.run(&OrderingRequest::new(&g)).unwrap();
        res.ordering.validate().unwrap();
        res.blocks.validate(g.n()).unwrap();
        assert!(res.stats.opc > 0.0);
        assert!(res.stats.nnz >= g.n() as u64);
        assert!(res.wall_seconds >= 0.0);
    }

    #[test]
    fn ptscotch_engine_multirank() {
        let g = generators::grid2d(18, 18);
        let svc = OrderingService::new_cpu_only();
        let res = svc
            .run(&OrderingRequest::new(&g).engine(Engine::PtScotch { p: 4 }))
            .unwrap();
        res.ordering.validate().unwrap();
        res.blocks.validate(g.n()).unwrap();
        assert_eq!(res.peak_mem_per_rank.len(), 4);
        assert!(res.bytes_sent_per_rank.iter().sum::<u64>() > 0);
    }

    #[test]
    fn executor_knob_drives_the_fleet_with_identical_results() {
        let g = generators::grid2d(14, 14);
        let svc = OrderingService::new_cpu_only();
        let run = |spec: &str| {
            svc.run(
                &OrderingRequest::new(&g)
                    .parse_strategy(spec)
                    .unwrap()
                    .engine(Engine::PtScotch { p: 3 }),
            )
            .unwrap()
        };
        let sim = run("executor=sim,seed=7");
        let thr = run("executor=threads,seed=7");
        assert_eq!(sim.executor, crate::comm::Executor::Sim);
        assert_eq!(thr.executor, crate::comm::Executor::Threads);
        assert_eq!(sim.ordering.iperm, thr.ordering.iperm);
        assert_eq!(sim.blocks, thr.blocks);
        assert_eq!(sim.bytes_sent_per_rank, thr.bytes_sent_per_rank);
        assert_eq!(sim.msgs_sent_per_rank, thr.msgs_sent_per_rank);
        // The fleet's per-rank wallclock columns exist for both.
        assert_eq!(sim.wall_ns_per_rank.len(), 3);
        assert_eq!(thr.wall_ns_per_rank.len(), 3);
        assert!(thr.critical_path_seconds() > 0.0);
    }

    #[test]
    fn parmetis_engine_requires_pow2() {
        let g = generators::grid2d(10, 10);
        let svc = OrderingService::new_cpu_only();
        let err = svc
            .run(&OrderingRequest::new(&g).engine(Engine::ParMetisLike { p: 6 }))
            .unwrap_err();
        assert!(matches!(err, Error::NonPowerOfTwo(6)));
    }

    #[test]
    fn xla_strategy_without_artifacts_errors() {
        let g = generators::grid2d(8, 8);
        let svc = OrderingService::new_cpu_only();
        let req = OrderingRequest::new(&g).parse_strategy("refiner=xla").unwrap();
        let err = svc.run(&req).unwrap_err();
        assert!(matches!(err, Error::NoArtifact(_)));
    }

    #[test]
    fn cpu_diffusion_strategy_works() {
        let g = generators::grid2d(14, 14);
        let svc = OrderingService::new_cpu_only();
        let req = OrderingRequest::new(&g).parse_strategy("refiner=diffcpu").unwrap();
        let res = svc.run(&req).unwrap();
        res.ordering.validate().unwrap();
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_order_shim_matches_run() {
        let g = generators::grid2d(12, 12);
        let svc = OrderingService::new_cpu_only();
        let strat = Strategy::parse("seed=5").unwrap();
        let old = svc.order(&g, Engine::Sequential, &strat).unwrap();
        let new = svc.run(&OrderingRequest::new(&g).strategy(strat)).unwrap();
        assert_eq!(old.ordering, new.ordering);
        assert_eq!(old.blocks, new.blocks);
    }

    #[test]
    fn fingerprint_separates_graph_strategy_and_engine() {
        let g = generators::grid2d(10, 10);
        let base = OrderingRequest::new(&g);
        let fp = base.fingerprint();
        // Equal content — even via an independent clone of the graph —
        // fingerprints equal; the tag never participates.
        assert_eq!(OrderingRequest::new(&g).fingerprint(), fp);
        assert_eq!(base.clone().tag("other").fingerprint(), fp);
        // Any content change separates.
        assert_ne!(base.clone().parse_strategy("seed=8").unwrap().fingerprint(), fp);
        assert_ne!(base.clone().engine(Engine::PtScotch { p: 2 }).fingerprint(), fp);
        assert_ne!(
            base.clone().engine(Engine::PtScotch { p: 4 }).fingerprint(),
            base.clone().engine(Engine::ParMetisLike { p: 4 }).fingerprint()
        );
        assert_ne!(OrderingRequest::new(&generators::grid2d(10, 11)).fingerprint(), fp);
        // Equal-valued strategies built differently dedupe through the
        // canonical form.
        let a = base.clone().parse_strategy("seed=1,band=3").unwrap();
        let b = base.parse_strategy("band=3,seed=1").unwrap();
        assert_eq!(a.fingerprint(), b.fingerprint());
    }
}
