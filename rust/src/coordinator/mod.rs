//! Coordinator (S20): the strategy-driven front door of the system.
//!
//! [`OrderingService`] owns the XLA runtime (loaded once, reused across
//! jobs — Python never runs at request time), picks the band refiner per
//! strategy, launches the rank fleet on the selected executor
//! (`executor=sim|threads`, DESIGN.md §3), and returns orderings
//! with the paper's quality metrics and per-rank telemetry. The CLI
//! (`rust/src/main.rs`), examples and all benches go through this API.

pub mod metrics;

pub use metrics::{OrderingReport, PhaseTimer};

use crate::baseline::parmetis_like_order;
use crate::comm;
use crate::dist::parallel_order;
use crate::graph::Graph;
use crate::order::{nested_dissection, symbolic_cholesky, Ordering};
use crate::rng::Rng;
use crate::runtime::{load_shared, DiffusionRefiner, SharedRuntime};
use crate::sep::diffusion::CpuDiffusionRefiner;
use crate::sep::{BandRefiner, FmRefiner};
use crate::strategy::{BandEngine, RefinerKind, Strategy};
use crate::{Error, Result};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

/// Which ordering engine to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// Sequential Scotch-like pipeline (reference / Table 1 `O_SS`).
    Sequential,
    /// PT-Scotch parallel nested dissection on `p` simulated ranks.
    PtScotch { p: usize },
    /// ParMETIS-like baseline on `p` simulated ranks (power of two).
    ParMetisLike { p: usize },
}

/// The ordering service: reusable across jobs.
pub struct OrderingService {
    runtime: Option<SharedRuntime>,
}

impl OrderingService {
    /// Build a service without XLA artifacts (FM / CPU-diffusion only).
    pub fn new_cpu_only() -> OrderingService {
        OrderingService { runtime: None }
    }

    /// Build a service, loading AOT artifacts from `dir` if present.
    /// Missing artifacts are not an error unless a strategy later
    /// demands the XLA refiner.
    pub fn new(dir: &Path) -> OrderingService {
        let runtime = load_shared(dir).ok();
        OrderingService { runtime }
    }

    /// Is the XLA runtime loaded?
    pub fn has_xla(&self) -> bool {
        self.runtime.is_some()
    }

    /// Materialize the refiner for a strategy.
    pub fn refiner(&self, strat: &Strategy) -> Result<Box<dyn BandRefiner + Send + Sync>> {
        match strat.refiner {
            RefinerKind::Fm => Ok(Box::new(FmRefiner {
                params: strat.sep.fm.clone(),
            })),
            RefinerKind::DiffusionCpu => Ok(Box::new(CpuDiffusionRefiner {
                fm: strat.sep.fm.clone(),
                ..CpuDiffusionRefiner::default()
            })),
            RefinerKind::DiffusionXla => {
                let rt = self.runtime.clone().ok_or_else(|| {
                    Error::NoArtifact(
                        "strategy requests the XLA refiner but no artifacts are loaded \
                         (run `make artifacts`)"
                            .into(),
                    )
                })?;
                let mut r = DiffusionRefiner::new(rt);
                r.fm = strat.sep.fm.clone();
                Ok(Box::new(r))
            }
        }
    }

    /// Order `g` with the selected engine and strategy; returns the
    /// ordering plus the full quality/telemetry report. The rank fleet
    /// of the distributed engines runs on the executor named by the
    /// `executor=` strategy knob, falling back to `PTSCOTCH_EXECUTOR`
    /// and then to the serialized simulator (DESIGN.md §3).
    pub fn order(&self, g: &Graph, engine: Engine, strat: &Strategy) -> Result<OrderingReport> {
        strat.validate()?;
        g.validate()?;
        let exec = strat.dist.executor.unwrap_or_else(comm::Executor::from_env);
        let t0 = Instant::now();
        type Telemetry = (Ordering, Vec<i64>, comm::StatsSnapshot);
        let (ordering, peak_mem, fleet): Telemetry = match engine {
            Engine::Sequential => {
                let refiner = self.refiner(strat)?;
                let mut rng = Rng::new(strat.seed);
                let o = nested_dissection(g, strat, refiner.as_ref(), &mut rng);
                let fleet = comm::StatsSnapshot {
                    bytes_sent: vec![0],
                    msgs_sent: vec![0],
                    wall_ns: Vec::new(),
                    blocked_ns: Vec::new(),
                };
                (o, vec![g.footprint_bytes() as i64], fleet)
            }
            Engine::PtScotch { p } => {
                let ga = Arc::new(g.clone());
                let strat2 = strat.clone();
                let service_refiner: Arc<dyn BandRefiner + Send + Sync> =
                    Arc::from(self.refiner(strat)?);
                // Hand the loaded runtime to the rank fleet so the
                // distributed diffusion path can execute the fused
                // kernel per rank; `engine=cpu` pins the scalar
                // sweeps without consulting the runtime at all.
                let band_rt = match strat.dist.band_engine {
                    BandEngine::Cpu => None,
                    BandEngine::Auto | BandEngine::Xla => self.runtime.clone(),
                };
                let (res, stats) = comm::run_on(exec, p, move |c| {
                    let r = parallel_order(
                        &c,
                        &ga,
                        &strat2,
                        service_refiner.as_ref(),
                        band_rt.as_ref(),
                    );
                    (r.ordering, r.peak_mem)
                });
                let mems = res.iter().map(|(_, m)| *m).collect();
                let o = res.into_iter().next().expect("rank 0 result").0;
                (o, mems, stats)
            }
            Engine::ParMetisLike { p } => {
                if !p.is_power_of_two() {
                    return Err(Error::NonPowerOfTwo(p));
                }
                let ga = Arc::new(g.clone());
                let strat2 = strat.clone();
                let (res, stats) = comm::run_on(exec, p, move |c| {
                    let r = parmetis_like_order(&c, &ga, &strat2)?;
                    Ok::<_, Error>((r.ordering, r.peak_mem))
                });
                let mut orderings = Vec::new();
                let mut mems = Vec::new();
                for r in res {
                    let (o, m) = r?;
                    orderings.push(o);
                    mems.push(m);
                }
                (orderings.into_iter().next().expect("rank 0"), mems, stats)
            }
        };
        let wall = t0.elapsed();
        ordering.validate()?;
        let stats = symbolic_cholesky(g, &ordering);
        Ok(OrderingReport {
            ordering,
            stats,
            executor: exec,
            wall_seconds: wall.as_secs_f64(),
            peak_mem_per_rank: peak_mem,
            bytes_sent_per_rank: fleet.bytes_sent,
            msgs_sent_per_rank: fleet.msgs_sent,
            wall_ns_per_rank: fleet.wall_ns,
            blocked_ns_per_rank: fleet.blocked_ns,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn sequential_engine_reports_quality() {
        let g = generators::grid2d(16, 16);
        let svc = OrderingService::new_cpu_only();
        let rep = svc
            .order(&g, Engine::Sequential, &Strategy::default())
            .unwrap();
        rep.ordering.validate().unwrap();
        assert!(rep.stats.opc > 0.0);
        assert!(rep.stats.nnz >= g.n() as u64);
        assert!(rep.wall_seconds >= 0.0);
    }

    #[test]
    fn ptscotch_engine_multirank() {
        let g = generators::grid2d(18, 18);
        let svc = OrderingService::new_cpu_only();
        let rep = svc
            .order(&g, Engine::PtScotch { p: 4 }, &Strategy::default())
            .unwrap();
        rep.ordering.validate().unwrap();
        assert_eq!(rep.peak_mem_per_rank.len(), 4);
        assert!(rep.bytes_sent_per_rank.iter().sum::<u64>() > 0);
    }

    #[test]
    fn executor_knob_drives_the_fleet_with_identical_results() {
        let g = generators::grid2d(14, 14);
        let svc = OrderingService::new_cpu_only();
        let run = |spec: &str| {
            svc.order(&g, Engine::PtScotch { p: 3 }, &Strategy::parse(spec).unwrap())
                .unwrap()
        };
        let sim = run("executor=sim,seed=7");
        let thr = run("executor=threads,seed=7");
        assert_eq!(sim.executor, crate::comm::Executor::Sim);
        assert_eq!(thr.executor, crate::comm::Executor::Threads);
        assert_eq!(sim.ordering.iperm, thr.ordering.iperm);
        assert_eq!(sim.bytes_sent_per_rank, thr.bytes_sent_per_rank);
        assert_eq!(sim.msgs_sent_per_rank, thr.msgs_sent_per_rank);
        // The fleet's per-rank wallclock columns exist for both.
        assert_eq!(sim.wall_ns_per_rank.len(), 3);
        assert_eq!(thr.wall_ns_per_rank.len(), 3);
        assert!(thr.critical_path_seconds() > 0.0);
    }

    #[test]
    fn parmetis_engine_requires_pow2() {
        let g = generators::grid2d(10, 10);
        let svc = OrderingService::new_cpu_only();
        let err = svc
            .order(&g, Engine::ParMetisLike { p: 6 }, &Strategy::default())
            .unwrap_err();
        assert!(matches!(err, Error::NonPowerOfTwo(6)));
    }

    #[test]
    fn xla_strategy_without_artifacts_errors() {
        let g = generators::grid2d(8, 8);
        let svc = OrderingService::new_cpu_only();
        let strat = Strategy::parse("refiner=xla").unwrap();
        let err = svc.order(&g, Engine::Sequential, &strat).unwrap_err();
        assert!(matches!(err, Error::NoArtifact(_)));
    }

    #[test]
    fn cpu_diffusion_strategy_works() {
        let g = generators::grid2d(14, 14);
        let svc = OrderingService::new_cpu_only();
        let strat = Strategy::parse("refiner=diffcpu").unwrap();
        let rep = svc.order(&g, Engine::Sequential, &strat).unwrap();
        rep.ordering.validate().unwrap();
    }
}
