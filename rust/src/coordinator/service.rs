//! Ordering-as-a-service: the batch coordinator (DESIGN.md §6).
//!
//! [`BatchCoordinator`] turns the one-shot [`OrderingService`] into a
//! service: it accepts a queue of [`OrderingRequest`]s, dedupes them by
//! content fingerprint (graph CSR bytes + canonical strategy + engine/p,
//! [`OrderingRequest::fingerprint`]), serves repeats from an LRU cache
//! with **bit-identical** results and zero rank work, and schedules the
//! remaining misses as concurrent jobs over a shared pool of worker
//! threads (each job launching its own rank fleet through
//! [`OrderingService::run`]). This is the production shape for the
//! same-mesh-ordered-again-and-again workload: one full ordering, then
//! cache hits — the multi-client analogue of the multi-sequential
//! selection the band refinement already uses per separator.
//!
//! Determinism makes the cache sound: a request's result is a pure
//! function of its fingerprint (same seed → same permutation on every
//! executor, DESIGN.md §3), so replaying a cached
//! [`OrderingResult`] is indistinguishable from recomputing it.
//!
//! **Recovery ladder (DESIGN.md §6).** Fleet-level faults — a rank
//! panic or a stalled fleet (DESIGN.md §3.2) — are transient from the
//! service's point of view, so a job that hits one is re-run with
//! exponential backoff up to [`ServiceConfig::max_retries`] times and,
//! as a last resort, degraded to the sequential `p=1` engine. Every
//! reply records its attempts and final [`Route`]; failures are never
//! cached, and neither are degraded results (a sequential ordering is
//! not bit-identical to the parallel one the fingerprint promises).

use super::metrics::{ServiceMetrics, ServiceSnapshot};
use super::{Engine, OrderingRequest, OrderingResult, OrderingService};
use crate::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Tuning knobs of the batch coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServiceConfig {
    /// Maximum cached results; the least-recently-used entry is
    /// evicted beyond this. `0` disables the cache entirely (requests
    /// still coalesce within a batch).
    pub cache_capacity: usize,
    /// Maximum ordering jobs in flight at once. Each job runs its own
    /// rank fleet, so this bounds total thread pressure per batch.
    pub max_in_flight: usize,
    /// How many times a job is re-run after a fleet-level fault
    /// (`RankPanicked`/`FleetStalled`) before the ladder moves on to
    /// degradation. Deterministic errors are never retried.
    pub max_retries: u32,
    /// Base of the exponential retry backoff: retry k sleeps
    /// `retry_backoff_ms << (k-1)` milliseconds. `0` disables the
    /// sleep (used by tests).
    pub retry_backoff_ms: u64,
    /// After the retry budget is exhausted, fall back to the
    /// sequential `p=1` engine instead of failing the request.
    pub degrade: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            cache_capacity: 64,
            max_in_flight: 4,
            max_retries: 2,
            retry_backoff_ms: 10,
            degrade: true,
        }
    }
}

/// How a reply was ultimately produced — the rung of the recovery
/// ladder (DESIGN.md §6) the request came to rest on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Replayed from the fingerprint cache; no fleet ran.
    Cached,
    /// The requested engine succeeded on the first attempt (or failed
    /// with a deterministic, non-retryable error).
    Direct,
    /// The requested engine succeeded after one or more fault retries.
    Retried,
    /// The retry budget was exhausted; the reply comes from (or the
    /// final error was produced by) the sequential fallback.
    Degraded,
}

/// How one request was satisfied.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Served {
    /// Straight from the fingerprint cache: zero rank work.
    Hit,
    /// Led a new ordering job on the rank pool.
    Miss,
    /// Joined an identical job already scheduled in the same batch.
    Coalesced,
}

/// The service-side story of one request: how it was served, how long
/// it queued and ran, and the (shared) result. The `result` of every
/// member of one coalesced job is the same [`Arc`]; a cache hit's is
/// the `Arc` stored at insert time — bit-identical by construction.
#[derive(Clone, Debug)]
pub struct RequestReport {
    /// The client label from [`OrderingRequest::tag`].
    pub tag: String,
    /// The request's content fingerprint (the cache key).
    pub fingerprint: u128,
    /// Hit, miss, or coalesced.
    pub served: Served,
    /// Seconds between batch submission and this request's job being
    /// picked up by a worker (cache decision time for hits).
    pub queue_seconds: f64,
    /// Seconds the job ran (0 for cache hits; for coalesced riders,
    /// the led job's run time — the wait they actually experienced).
    pub run_seconds: f64,
    /// Fleet runs performed for this reply: 0 for cache hits, 1 for a
    /// clean first attempt, more when the recovery ladder re-ran or
    /// degraded the job.
    pub attempts: u32,
    /// The recovery-ladder rung that produced the reply.
    pub route: Route,
    /// The ordering, block structure and report — or the job's error,
    /// replicated to every coalesced rider (errors are never cached).
    pub result: Result<Arc<OrderingResult>>,
}

impl RequestReport {
    /// The merged phase profile of the reply — present only when the
    /// job succeeded and its strategy ran with `trace=phases|full`
    /// (DESIGN.md §7). Cache hits return whatever the run that
    /// populated the entry recorded.
    pub fn profile(&self) -> Option<&crate::trace::PhaseProfile> {
        self.result
            .as_ref()
            .ok()
            .and_then(|r| r.report.profile.as_ref())
    }
}

/// LRU fingerprint store. Stamp-based: `get`/`insert` advance a clock
/// and eviction removes the smallest stamp — an O(capacity) scan, which
/// is negligible next to even one leaf ordering.
struct Cache {
    capacity: usize,
    clock: u64,
    entries: HashMap<u128, (u64, Arc<OrderingResult>)>,
}

impl Cache {
    fn new(capacity: usize) -> Cache {
        Cache {
            capacity,
            clock: 0,
            entries: HashMap::new(),
        }
    }

    fn get(&mut self, fp: u128) -> Option<Arc<OrderingResult>> {
        self.clock += 1;
        let clock = self.clock;
        self.entries.get_mut(&fp).map(|e| {
            e.0 = clock;
            Arc::clone(&e.1)
        })
    }

    /// Insert and evict down to capacity; returns how many entries
    /// were evicted.
    fn insert(&mut self, fp: u128, res: Arc<OrderingResult>) -> u64 {
        if self.capacity == 0 {
            return 0;
        }
        self.clock += 1;
        self.entries.insert(fp, (self.clock, res));
        let mut evicted = 0;
        while self.entries.len() > self.capacity {
            let oldest = *self
                .entries
                .iter()
                .min_by_key(|(_, (stamp, _))| *stamp)
                .map(|(k, _)| k)
                .expect("over-capacity cache is non-empty");
            self.entries.remove(&oldest);
            evicted += 1;
        }
        evicted
    }
}

/// One scheduled ordering job and the batch slots riding on it.
struct Job {
    fingerprint: u128,
    request: OrderingRequest,
    /// `(batch slot, tag, served)` of the leader and every coalesced
    /// rider — all receive clones of the same `Arc`'d outcome.
    members: Vec<(usize, String, Served)>,
}

/// `(outcome, queue seconds, run seconds, attempts, route)` of one
/// executed job.
type JobOutcome = (Result<Arc<OrderingResult>>, f64, f64, u32, Route);

/// The batch driver: a fingerprint cache and a bounded worker pool in
/// front of an [`OrderingService`].
///
/// ```
/// use ptscotch::coordinator::{BatchCoordinator, OrderingRequest, OrderingService, Served};
/// use ptscotch::graph::generators;
///
/// let coord = BatchCoordinator::new(OrderingService::new_cpu_only());
/// let g = generators::grid2d(10, 10);
/// let batch = vec![
///     OrderingRequest::new(&g).tag("cold"),
///     OrderingRequest::new(&g).tag("dup"),
/// ];
/// let replies = coord.submit(batch);
/// assert_eq!(replies[0].served, Served::Miss);
/// assert_eq!(replies[1].served, Served::Coalesced); // same fingerprint
/// // A later batch with the same request hits the cache.
/// let warm = coord.submit(vec![OrderingRequest::new(&g).tag("warm")]);
/// assert_eq!(warm[0].served, Served::Hit);
/// assert_eq!(coord.metrics().jobs_run, 1); // one full ordering total
/// ```
pub struct BatchCoordinator {
    service: OrderingService,
    config: ServiceConfig,
    cache: Mutex<Cache>,
    metrics: ServiceMetrics,
}

impl BatchCoordinator {
    /// Wrap `service` with the default cache/concurrency configuration.
    pub fn new(service: OrderingService) -> BatchCoordinator {
        BatchCoordinator::with_config(service, ServiceConfig::default())
    }

    /// Wrap `service` with an explicit configuration.
    pub fn with_config(service: OrderingService, config: ServiceConfig) -> BatchCoordinator {
        BatchCoordinator {
            service,
            config,
            cache: Mutex::new(Cache::new(config.cache_capacity)),
            metrics: ServiceMetrics::default(),
        }
    }

    /// The wrapped one-shot service.
    pub fn service(&self) -> &OrderingService {
        &self.service
    }

    /// The configuration this coordinator runs with.
    pub fn config(&self) -> ServiceConfig {
        self.config
    }

    /// A snapshot of the lifetime hit/miss/job counters.
    pub fn metrics(&self) -> ServiceSnapshot {
        self.metrics.snapshot()
    }

    /// Serve one request through the cache (a batch of one).
    pub fn request(&self, req: OrderingRequest) -> RequestReport {
        self.submit(vec![req])
            .pop()
            .expect("one reply per request")
    }

    /// Run one job down the recovery ladder (DESIGN.md §6): attempt
    /// the requested engine; on a fleet-level fault retry with
    /// exponential backoff up to [`ServiceConfig::max_retries`] times;
    /// then, if configured, degrade to the sequential `p=1` engine.
    /// Deterministic errors (bad strategy, missing artifact, …) exit
    /// immediately — re-running them would reproduce the same failure.
    /// Returns `(outcome, attempts, route)`.
    fn run_with_recovery(
        &self,
        req: &OrderingRequest,
    ) -> (Result<Arc<OrderingResult>>, u32, Route) {
        let mut attempts: u32 = 0;
        let exhausted = loop {
            attempts += 1;
            match self.service.run(req) {
                Ok(res) => {
                    let route = if attempts == 1 {
                        Route::Direct
                    } else {
                        Route::Retried
                    };
                    return (Ok(Arc::new(res)), attempts, route);
                }
                Err(e) if e.is_fleet_fault() => {
                    self.metrics.aborts.fetch_add(1, AtomicOrdering::Relaxed);
                    if attempts <= self.config.max_retries {
                        self.metrics.retries.fetch_add(1, AtomicOrdering::Relaxed);
                        let backoff = self.config.retry_backoff_ms << (attempts - 1).min(10);
                        if backoff > 0 {
                            thread::sleep(Duration::from_millis(backoff));
                        }
                        continue;
                    }
                    break e;
                }
                Err(e) => {
                    let route = if attempts == 1 {
                        Route::Direct
                    } else {
                        Route::Retried
                    };
                    return (Err(e), attempts, route);
                }
            }
        };
        if self.config.degrade && req.engine != Engine::Sequential {
            self.metrics.degraded.fetch_add(1, AtomicOrdering::Relaxed);
            attempts += 1;
            let seq = req.clone().engine(Engine::Sequential);
            let outcome = self.service.run(&seq).map(Arc::new);
            return (outcome, attempts, Route::Degraded);
        }
        let route = if attempts == 1 {
            Route::Direct
        } else {
            Route::Retried
        };
        (Err(exhausted), attempts, route)
    }

    /// Serve a batch: fingerprint every request, answer repeats from
    /// the cache, coalesce in-batch duplicates onto one job, and run
    /// the remaining jobs concurrently (at most
    /// [`ServiceConfig::max_in_flight`] at a time). Replies come back
    /// in request order, one per request, errors included — a bad
    /// request never poisons its batch.
    pub fn submit(&self, requests: Vec<OrderingRequest>) -> Vec<RequestReport> {
        let t_batch = Instant::now();
        let n = requests.len();
        let mut reports: Vec<Option<RequestReport>> = (0..n).map(|_| None).collect();
        let mut jobs: Vec<Job> = Vec::new();
        {
            // Admission, under one cache lock: hits answered on the
            // spot, the rest planned into deduplicated jobs.
            let mut job_of: HashMap<u128, usize> = HashMap::new();
            let mut cache = self.cache.lock().expect("cache lock");
            for (slot, req) in requests.into_iter().enumerate() {
                let fp = req.fingerprint();
                if let Some(cached) = cache.get(fp) {
                    self.metrics.hits.fetch_add(1, AtomicOrdering::Relaxed);
                    reports[slot] = Some(RequestReport {
                        tag: req.tag,
                        fingerprint: fp,
                        served: Served::Hit,
                        queue_seconds: t_batch.elapsed().as_secs_f64(),
                        run_seconds: 0.0,
                        attempts: 0,
                        route: Route::Cached,
                        result: Ok(cached),
                    });
                    continue;
                }
                match job_of.get(&fp) {
                    Some(&j) => {
                        self.metrics.coalesced.fetch_add(1, AtomicOrdering::Relaxed);
                        jobs[j].members.push((slot, req.tag, Served::Coalesced));
                    }
                    None => {
                        self.metrics.misses.fetch_add(1, AtomicOrdering::Relaxed);
                        job_of.insert(fp, jobs.len());
                        let tag = req.tag.clone();
                        jobs.push(Job {
                            fingerprint: fp,
                            request: req,
                            members: vec![(slot, tag, Served::Miss)],
                        });
                    }
                }
            }
        }

        // Execution: a bounded pool of workers drains the job list.
        let outcomes: Vec<Mutex<Option<JobOutcome>>> =
            jobs.iter().map(|_| Mutex::new(None)).collect();
        if !jobs.is_empty() {
            let next = AtomicUsize::new(0);
            let workers = self.config.max_in_flight.max(1).min(jobs.len());
            thread::scope(|s| {
                for _ in 0..workers {
                    s.spawn(|| loop {
                        let j = next.fetch_add(1, AtomicOrdering::Relaxed);
                        if j >= jobs.len() {
                            break;
                        }
                        let job = &jobs[j];
                        let queue_seconds = t_batch.elapsed().as_secs_f64();
                        let t_run = Instant::now();
                        let (outcome, attempts, route) = self.run_with_recovery(&job.request);
                        let run_seconds = t_run.elapsed().as_secs_f64();
                        self.metrics.jobs_run.fetch_add(1, AtomicOrdering::Relaxed);
                        match &outcome {
                            // A degraded (sequential-fallback) result is
                            // served but never cached: it is not the
                            // bit-identical parallel ordering the
                            // fingerprint promises future hits.
                            Ok(res) if route != Route::Degraded => {
                                let evicted = self
                                    .cache
                                    .lock()
                                    .expect("cache lock")
                                    .insert(job.fingerprint, Arc::clone(res));
                                self.metrics
                                    .evictions
                                    .fetch_add(evicted, AtomicOrdering::Relaxed);
                            }
                            Ok(_) => {}
                            Err(_) => {
                                self.metrics.errors.fetch_add(1, AtomicOrdering::Relaxed);
                            }
                        }
                        *outcomes[j].lock().expect("outcome slot") =
                            Some((outcome, queue_seconds, run_seconds, attempts, route));
                    });
                }
            });
        }

        // Reply assembly, in request order.
        for (job, slot) in jobs.into_iter().zip(outcomes) {
            let (outcome, queue_seconds, run_seconds, attempts, route) = slot
                .into_inner()
                .expect("outcome slot")
                .expect("every job ran");
            for (idx, tag, served) in job.members {
                reports[idx] = Some(RequestReport {
                    tag,
                    fingerprint: job.fingerprint,
                    served,
                    queue_seconds,
                    run_seconds,
                    attempts,
                    route,
                    result: outcome.clone(),
                });
            }
        }
        reports
            .into_iter()
            .map(|r| r.expect("every slot answered"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Engine;
    use crate::graph::generators;

    fn coord(capacity: usize) -> BatchCoordinator {
        BatchCoordinator::with_config(
            OrderingService::new_cpu_only(),
            ServiceConfig {
                cache_capacity: capacity,
                max_in_flight: 3,
                ..ServiceConfig::default()
            },
        )
    }

    #[test]
    fn replayed_batch_runs_exactly_one_job() {
        let c = coord(16);
        let g = generators::grid2d(12, 12);
        let batch: Vec<_> = (0..5)
            .map(|i| OrderingRequest::new(&g).tag(format!("r{i}")))
            .collect();
        let replies = c.submit(batch);
        assert_eq!(replies.len(), 5);
        assert_eq!(replies[0].served, Served::Miss);
        for r in &replies[1..] {
            assert_eq!(r.served, Served::Coalesced);
        }
        // Later batches hit the cache instead.
        let warm = c.submit(vec![OrderingRequest::new(&g).tag("again")]);
        assert_eq!(warm[0].served, Served::Hit);
        let m = c.metrics();
        assert_eq!(m.jobs_run, 1);
        assert_eq!((m.hits, m.misses, m.coalesced), (1, 1, 4));
        assert!((m.hit_rate() - 5.0 / 6.0).abs() < 1e-12);
        // Everyone shares the same result allocation.
        let first = replies[0].result.as_ref().unwrap();
        for r in replies[1..].iter().chain(warm.iter()) {
            assert!(Arc::ptr_eq(first, r.result.as_ref().unwrap()));
        }
    }

    #[test]
    fn distinct_requests_each_run() {
        let c = coord(16);
        let g1 = generators::grid2d(10, 10);
        let g2 = generators::grid2d(11, 10);
        let replies = c.submit(vec![
            OrderingRequest::new(&g1),
            OrderingRequest::new(&g2),
            OrderingRequest::new(&g1).parse_strategy("seed=9").unwrap(),
            OrderingRequest::new(&g1).engine(Engine::PtScotch { p: 2 }),
        ]);
        assert!(replies.iter().all(|r| r.served == Served::Miss));
        assert_eq!(c.metrics().jobs_run, 4);
        // All four fingerprints are distinct.
        let mut fps: Vec<u128> = replies.iter().map(|r| r.fingerprint).collect();
        fps.sort_unstable();
        fps.dedup();
        assert_eq!(fps.len(), 4);
    }

    #[test]
    fn lru_evicts_the_coldest_entry() {
        let c = coord(2);
        let graphs = [
            generators::grid2d(8, 8),
            generators::grid2d(9, 8),
            generators::grid2d(10, 8),
        ];
        for g in &graphs {
            c.submit(vec![OrderingRequest::new(g)]);
        }
        // Capacity 2: the first graph was evicted when the third landed.
        assert_eq!(c.metrics().evictions, 1);
        c.submit(vec![OrderingRequest::new(&graphs[2])]); // still cached
        c.submit(vec![OrderingRequest::new(&graphs[0])]); // evicted: re-runs
        let m = c.metrics();
        assert_eq!(m.hits, 1);
        assert_eq!(m.jobs_run, 4);
    }

    #[test]
    fn zero_capacity_disables_caching_but_not_coalescing() {
        let c = coord(0);
        let g = generators::grid2d(9, 9);
        let replies = c.submit(vec![OrderingRequest::new(&g), OrderingRequest::new(&g)]);
        assert_eq!(replies[1].served, Served::Coalesced);
        let again = c.request(OrderingRequest::new(&g));
        assert_eq!(again.served, Served::Miss);
        assert_eq!(c.metrics().jobs_run, 2);
    }

    #[test]
    fn errors_propagate_to_riders_and_are_not_cached() {
        let c = coord(16);
        let g = generators::grid2d(8, 8);
        let bad = |tag: &str| {
            OrderingRequest::new(&g)
                .parse_strategy("refiner=xla")
                .unwrap()
                .tag(tag)
        };
        let replies = c.submit(vec![bad("a"), bad("b")]);
        for r in &replies {
            assert!(matches!(
                r.result.as_ref().unwrap_err(),
                crate::Error::NoArtifact(_)
            ));
        }
        // The failure was not cached: a retry runs (and fails) again.
        let retry = c.request(bad("c"));
        assert_eq!(retry.served, Served::Miss);
        let m = c.metrics();
        assert_eq!(m.errors, 2);
        assert_eq!(m.hits, 0);
    }

    #[test]
    fn empty_batch_is_a_no_op() {
        let c = coord(4);
        assert!(c.submit(Vec::new()).is_empty());
        assert_eq!(c.metrics(), ServiceSnapshot::default());
    }

    #[test]
    fn clean_requests_route_direct_and_hits_route_cached() {
        let c = coord(8);
        let g = generators::grid2d(9, 9);
        let miss = c.request(OrderingRequest::new(&g));
        assert_eq!((miss.attempts, miss.route), (1, Route::Direct));
        let hit = c.request(OrderingRequest::new(&g));
        assert_eq!((hit.attempts, hit.route), (0, Route::Cached));
        let m = c.metrics();
        assert_eq!((m.retries, m.aborts, m.degraded, m.errors), (0, 0, 0, 0));
    }

    #[test]
    fn one_shot_fault_is_retried_to_success() {
        // The injected panic fires on the first fleet only (one-shot
        // trigger); the retry must complete the batch cleanly.
        let svc = OrderingService::new_cpu_only()
            .with_fault_plan(crate::comm::FaultPlan::new().panic_at(1, 25));
        let c = BatchCoordinator::with_config(
            svc,
            ServiceConfig {
                max_retries: 1,
                retry_backoff_ms: 0,
                ..ServiceConfig::default()
            },
        );
        let g = generators::grid2d(12, 12);
        let req = OrderingRequest::new(&g)
            .parse_strategy("seed=11,executor=sim,overlap=0")
            .unwrap()
            .engine(Engine::PtScotch { p: 3 });
        let reply = c.request(req.clone());
        assert_eq!((reply.attempts, reply.route), (2, Route::Retried));
        let recovered = reply.result.expect("retry recovers the request");
        let m = c.metrics();
        assert_eq!((m.retries, m.aborts, m.degraded, m.errors), (1, 1, 0, 0));
        // The recovered result is the same ordering a clean service
        // produces — the fault left no trace in the output.
        let clean = BatchCoordinator::new(OrderingService::new_cpu_only());
        let reference = clean.request(req).result.unwrap();
        assert_eq!(recovered.ordering, reference.ordering);
    }

    #[test]
    fn exhausted_retries_degrade_to_sequential_and_skip_the_cache() {
        // Two one-shot triggers at the same point: with max_retries=1
        // the first attempt and its single retry both die, then the
        // ladder degrades to the sequential engine (no fleet, no
        // faults left to fire).
        let plan = crate::comm::FaultPlan::new().panic_at(0, 5).panic_at(0, 5);
        let svc = OrderingService::new_cpu_only().with_fault_plan(plan);
        let c = BatchCoordinator::with_config(
            svc,
            ServiceConfig {
                max_retries: 1,
                retry_backoff_ms: 0,
                ..ServiceConfig::default()
            },
        );
        let g = generators::grid2d(12, 12);
        let req = OrderingRequest::new(&g)
            .parse_strategy("seed=11,executor=sim,overlap=0")
            .unwrap()
            .engine(Engine::PtScotch { p: 2 });
        let reply = c.request(req.clone());
        assert_eq!((reply.attempts, reply.route), (3, Route::Degraded));
        let degraded = reply.result.expect("degradation serves the request");
        let m = c.metrics();
        assert_eq!((m.retries, m.aborts, m.degraded, m.errors), (1, 2, 1, 0));
        // The degraded reply equals the sequential reference…
        let clean = BatchCoordinator::new(OrderingService::new_cpu_only());
        let seq_ref = clean
            .request(req.clone().engine(Engine::Sequential))
            .result
            .unwrap();
        assert_eq!(degraded.ordering, seq_ref.ordering);
        // …and was NOT cached under the parallel fingerprint: the same
        // request misses again (and now succeeds — the plan is spent).
        let again = c.request(req);
        assert_eq!(again.served, Served::Miss);
        assert_eq!(again.route, Route::Direct);
    }

    #[test]
    fn deterministic_errors_are_never_retried() {
        let c = BatchCoordinator::with_config(
            OrderingService::new_cpu_only(),
            ServiceConfig {
                max_retries: 3,
                retry_backoff_ms: 0,
                ..ServiceConfig::default()
            },
        );
        let g = generators::grid2d(8, 8);
        let reply = c.request(
            OrderingRequest::new(&g)
                .parse_strategy("refiner=xla")
                .unwrap(),
        );
        assert_eq!((reply.attempts, reply.route), (1, Route::Direct));
        assert!(reply.result.is_err());
        let m = c.metrics();
        assert_eq!((m.retries, m.aborts, m.errors), (0, 0, 1));
    }
}
