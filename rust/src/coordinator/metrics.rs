//! Reports, service counters and phase timers.

use crate::comm::Executor;
use crate::order::SymbolicStats;
use crate::trace::{PhaseProfile, RankTrace};
use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::time::Instant;

/// Everything a bench or example needs to print one paper-style row.
/// The permutation itself lives next door in
/// [`crate::coordinator::OrderingResult`], which the service caches and
/// `Deref`s to this report. `Clone` so cached results can be shared.
#[derive(Clone, Debug)]
pub struct OrderingReport {
    /// Symbolic-factorization quality (NNZ, OPC, fill, tree height).
    pub stats: SymbolicStats,
    /// The executor that drove (or, for the sequential engine, would
    /// have driven) the rank fleet (DESIGN.md §3).
    pub executor: Executor,
    /// Wallclock of the ordering. Under `executor=threads` on a
    /// multicore host this is a real parallel time; under the
    /// serialized simulator see DESIGN.md §3 on the time-vs-traffic
    /// substitution and [`OrderingReport::critical_path_seconds`].
    pub wall_seconds: f64,
    /// Peak tracked graph memory per rank (Figures 10–11).
    pub peak_mem_per_rank: Vec<i64>,
    /// Bytes sent per rank.
    pub bytes_sent_per_rank: Vec<u64>,
    /// Messages sent per rank.
    pub msgs_sent_per_rank: Vec<u64>,
    /// Per-rank wallclock in nanoseconds (empty for the sequential
    /// engine, which runs no fleet).
    pub wall_ns_per_rank: Vec<u64>,
    /// Per-rank transport-blocked nanoseconds (empty for the
    /// sequential engine).
    pub blocked_ns_per_rank: Vec<u64>,
    /// Transport operations (pushes + pops) per rank — the coordinate
    /// system of the fault-injection plan (DESIGN.md §3.2), identical
    /// across executors like the traffic counters.
    pub transport_ops_per_rank: Vec<u64>,
    /// Raw per-rank span traces — non-empty only when the run's
    /// `trace=` knob was `phases` or `full` (DESIGN.md §7). Feed them
    /// to [`crate::trace::chrome::write`] for a Perfetto-loadable
    /// timeline.
    pub traces: Vec<RankTrace>,
    /// The merged hierarchical phase profile built from
    /// [`OrderingReport::traces`]; `None` when tracing was off.
    pub profile: Option<PhaseProfile>,
}

impl OrderingReport {
    /// `(min, avg, max)` of peak memory per rank, in bytes.
    pub fn mem_min_avg_max(&self) -> (i64, f64, i64) {
        let v = &self.peak_mem_per_rank;
        let min = v.iter().copied().min().unwrap_or(0);
        let max = v.iter().copied().max().unwrap_or(0);
        let avg = if v.is_empty() {
            0.0
        } else {
            v.iter().sum::<i64>() as f64 / v.len() as f64
        };
        (min, avg, max)
    }

    /// Total communication volume in bytes.
    pub fn total_comm_bytes(&self) -> u64 {
        self.bytes_sent_per_rank.iter().sum()
    }

    /// The fleet's critical path in seconds: the maximum per-rank busy
    /// time (wallclock minus transport-blocked time). This is the
    /// wallclock a host with one core per rank would approach; with no
    /// fleet telemetry (sequential engine) it falls back to
    /// [`OrderingReport::wall_seconds`].
    pub fn critical_path_seconds(&self) -> f64 {
        let max_busy = self
            .wall_ns_per_rank
            .iter()
            .zip(&self.blocked_ns_per_rank)
            .map(|(&w, &b)| w.saturating_sub(b))
            .max();
        match max_busy {
            Some(ns) if ns > 0 => ns as f64 / 1e9,
            _ => self.wall_seconds,
        }
    }
}

/// Aggregate counters of the batch coordinator, updated atomically by
/// concurrent jobs (DESIGN.md §6). Read them as a coherent
/// [`ServiceSnapshot`] via [`ServiceMetrics::snapshot`].
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    /// Requests served straight from the fingerprint cache.
    pub hits: AtomicU64,
    /// Requests whose fingerprint was absent: they became (or joined)
    /// a job. Every miss that *led* the job is counted here…
    pub misses: AtomicU64,
    /// …while in-batch duplicates that merely rode an already
    /// scheduled job are counted here instead.
    pub coalesced: AtomicU64,
    /// Cache entries evicted by the LRU policy.
    pub evictions: AtomicU64,
    /// Full orderings actually executed on the rank pool — the number
    /// the replay acceptance test pins to 1.
    pub jobs_run: AtomicU64,
    /// Jobs whose final outcome was an error — the recovery ladder
    /// (DESIGN.md §6) was exhausted. Errors are never cached.
    pub errors: AtomicU64,
    /// Fleet-level faults observed (`RankPanicked`/`FleetStalled`),
    /// whether or not a retry later recovered them.
    pub aborts: AtomicU64,
    /// Re-runs performed by the recovery ladder after a fleet fault.
    pub retries: AtomicU64,
    /// Jobs that exhausted their retries and fell back to the
    /// sequential `p=1` engine as a last resort.
    pub degraded: AtomicU64,
}

impl ServiceMetrics {
    /// Copy the counters into a plain snapshot.
    pub fn snapshot(&self) -> ServiceSnapshot {
        let ld = |c: &AtomicU64| c.load(AtomicOrdering::Relaxed);
        ServiceSnapshot {
            hits: ld(&self.hits),
            misses: ld(&self.misses),
            coalesced: ld(&self.coalesced),
            evictions: ld(&self.evictions),
            jobs_run: ld(&self.jobs_run),
            errors: ld(&self.errors),
            aborts: ld(&self.aborts),
            retries: ld(&self.retries),
            degraded: ld(&self.degraded),
        }
    }
}

/// A point-in-time copy of [`ServiceMetrics`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServiceSnapshot {
    /// Requests served from the cache.
    pub hits: u64,
    /// Requests that led a new job.
    pub misses: u64,
    /// Requests that joined an in-flight job.
    pub coalesced: u64,
    /// LRU evictions.
    pub evictions: u64,
    /// Full orderings executed.
    pub jobs_run: u64,
    /// Jobs that failed after exhausting the recovery ladder.
    pub errors: u64,
    /// Fleet-level faults observed.
    pub aborts: u64,
    /// Recovery-ladder re-runs.
    pub retries: u64,
    /// Jobs degraded to the sequential fallback.
    pub degraded: u64,
}

impl ServiceSnapshot {
    /// Total requests seen.
    pub fn requests(&self) -> u64 {
        self.hits + self.misses + self.coalesced
    }

    /// Fraction of requests that did no ordering work of their own
    /// (cache hits plus coalesced riders); 0 for an empty history.
    pub fn hit_rate(&self) -> f64 {
        let total = self.requests();
        if total == 0 {
            0.0
        } else {
            (self.hits + self.coalesced) as f64 / total as f64
        }
    }
}

/// A simple named phase timer for the §Perf profiles.
pub struct PhaseTimer {
    t0: Instant,
    /// Completed phases: (name, seconds).
    pub phases: Vec<(String, f64)>,
}

impl Default for PhaseTimer {
    fn default() -> Self {
        Self::new()
    }
}

impl PhaseTimer {
    /// Start the clock.
    pub fn new() -> PhaseTimer {
        PhaseTimer {
            t0: Instant::now(),
            phases: Vec::new(),
        }
    }

    /// Close the current phase under `name` and restart the clock.
    pub fn lap(&mut self, name: &str) {
        let dt = self.t0.elapsed().as_secs_f64();
        self.phases.push((name.to_string(), dt));
        self.t0 = Instant::now();
    }

    /// Render a one-line summary.
    pub fn summary(&self) -> String {
        self.phases
            .iter()
            .map(|(n, s)| format!("{n}={s:.3}s"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::order::SymbolicStats;

    #[test]
    fn mem_stats_aggregate() {
        let r = OrderingReport {
            stats: SymbolicStats {
                nnz: 1,
                opc: 1.0,
                fill_ratio: 1.0,
                tree_height: 1,
            },
            executor: Executor::Sim,
            wall_seconds: 0.25,
            peak_mem_per_rank: vec![10, 30, 20],
            bytes_sent_per_rank: vec![5, 6],
            msgs_sent_per_rank: vec![1, 1],
            wall_ns_per_rank: vec![4_000, 10_000],
            blocked_ns_per_rank: vec![1_000, 7_000],
            transport_ops_per_rank: vec![2, 2],
            traces: Vec::new(),
            profile: None,
        };
        let (min, avg, max) = r.mem_min_avg_max();
        assert_eq!((min, max), (10, 30));
        assert!((avg - 20.0).abs() < 1e-12);
        assert_eq!(r.total_comm_bytes(), 11);
        // Critical path = max(4000-1000, 10000-7000) ns = 3 µs.
        assert!((r.critical_path_seconds() - 3e-6).abs() < 1e-15);
        // Without fleet telemetry it falls back to the wallclock.
        let seq = OrderingReport {
            wall_ns_per_rank: Vec::new(),
            blocked_ns_per_rank: Vec::new(),
            ..r
        };
        assert!((seq.critical_path_seconds() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut t = PhaseTimer::new();
        t.lap("a");
        t.lap("b");
        assert_eq!(t.phases.len(), 2);
        assert!(t.summary().contains("a="));
    }

    #[test]
    fn service_metrics_snapshot_and_hit_rate() {
        let m = ServiceMetrics::default();
        assert_eq!(m.snapshot(), ServiceSnapshot::default());
        assert_eq!(m.snapshot().hit_rate(), 0.0);
        m.hits.fetch_add(3, AtomicOrdering::Relaxed);
        m.misses.fetch_add(1, AtomicOrdering::Relaxed);
        m.coalesced.fetch_add(1, AtomicOrdering::Relaxed);
        m.jobs_run.fetch_add(1, AtomicOrdering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.requests(), 5);
        assert!((s.hit_rate() - 0.8).abs() < 1e-12);
    }
}
