//! Sequential nested dissection (§1, §3.1): recursively bisect with a
//! multilevel vertex separator, give the separator the highest available
//! indices, and order leaf subgraphs with a (halo) minimum-degree
//! method.
//!
//! By nested-dissection structure the ring around any leaf — the
//! vertices of the **root** graph adjacent to the leaf but outside it —
//! consists exactly of separator vertices of enclosing levels (the two
//! sides of a separator are never adjacent), i.e. of vertices numbered
//! *after* the leaf. The leaf orderer therefore reconstructs the ring
//! from the root graph ([`crate::graph::induce_with_halo`]) and hands
//! it to [`crate::order::hamd::hamd`] as the halo, instead of ordering the
//! leaf as if the separators around it did not exist
//! (`leafmethod=hamd`, the default; `leafmethod=mmd` keeps the
//! halo-blind exact-degree comparator).

use super::hamd::hamd;
use super::mmd::minimum_degree;
use super::Ordering;
use crate::graph::{induce_with_halo, Graph, InducedGraph};
use crate::rng::Rng;
use crate::sep::{multilevel_separator, BandRefiner, P0, P1, SEP};
use crate::strategy::{LeafMethod, Strategy};
use crate::trace;

/// One pending subproblem: a subgraph (with its map back to root ids) and
/// the global start index of its ordering range (§2.2). `graph` is
/// `None` exactly when the frame is already a `leafmethod=hamd` leaf —
/// that path re-cuts the leaf from the root graph, so materializing
/// the child CSR would be pure waste (leaves cover most of the graph).
struct Frame {
    graph: Option<Graph>,
    orig: Vec<usize>,
    start: usize,
}

/// Compute a nested-dissection ordering of `g`.
pub fn nested_dissection(
    g: &Graph,
    strat: &Strategy,
    refiner: &dyn BandRefiner,
    rng: &mut Rng,
) -> Ordering {
    let iperm = nested_dissection_with_halo(g, &vec![false; g.n()], strat, refiner, rng);
    let o = Ordering::from_iperm(iperm).expect("nested dissection covers all vertices");
    debug_assert!(o.validate().is_ok());
    o
}

/// Nested-dissection ordering of the **non-halo** vertices of `g`.
///
/// `halo[v]` marks vertices that surround the subproblem but are
/// numbered elsewhere (the distributed recursion's already-emitted
/// separators, [`crate::dist::dnd`]): they are excluded from every
/// separator and from the result, but leaves ordered with
/// `leafmethod=hamd` see them — like every enclosing separator — as
/// halo. Returns the inverse-permutation fragment: position `k` holds
/// the `g`-local id of the `k`-th ordered core vertex, `ncore` entries
/// total. With an all-`false` halo this is the full ordering
/// [`nested_dissection`] wraps.
pub fn nested_dissection_with_halo(
    g: &Graph,
    halo: &[bool],
    strat: &Strategy,
    refiner: &dyn BandRefiner,
    rng: &mut Rng,
) -> Vec<usize> {
    let n = g.n();
    debug_assert_eq!(halo.len(), n);
    let ncore = halo.iter().filter(|&&h| !h).count();
    let mut iperm = vec![usize::MAX; ncore];
    // A subproblem that is already a hamd leaf never reads its own CSR
    // (the leaf is re-cut from the root with its halo ring), so skip
    // building one for it.
    let hamd_leaf =
        |len: usize| strat.nd.leaf_method == LeafMethod::Hamd && len <= strat.nd.leaf_threshold;
    let root = if hamd_leaf(ncore) {
        Frame {
            graph: None,
            orig: (0..n).filter(|&v| !halo[v]).collect(),
            start: 0,
        }
    } else if ncore == n {
        Frame {
            graph: Some(g.clone()),
            orig: (0..n).collect(),
            start: 0,
        }
    } else {
        let core = InducedGraph::build(g, |v| !halo[v]);
        Frame {
            graph: Some(core.graph),
            orig: core.orig,
            start: 0,
        }
    };
    let mut stack = vec![root];
    while let Some(Frame { graph, orig, start }) = stack.pop() {
        let nl = orig.len();
        if nl == 0 {
            continue;
        }
        if nl <= strat.nd.leaf_threshold {
            order_leaf(g, graph.as_ref(), &orig, start, &mut iperm, strat);
            continue;
        }
        let graph = graph.expect("frames above the leaf threshold carry their subgraph");
        let state = multilevel_separator(&graph, &strat.sep, refiner, rng);
        let mut counts = [0usize; 3];
        for &p in &state.part {
            counts[p as usize] += 1;
        }
        let (n0, n1, ns) = (counts[0], counts[1], counts[2]);
        // Degenerate separator (empty side, or the separator swallowed
        // the graph, e.g. on cliques): the whole remaining subgraph is
        // one leaf — emitted through the same fragment path as every
        // other leaf, halo ring included.
        if n0 == 0 || n1 == 0 || ns as f64 > nl as f64 * strat.nd.max_sep_fraction {
            order_leaf(g, Some(&graph), &orig, start, &mut iperm, strat);
            continue;
        }
        // Separator vertices take the highest indices of the range.
        let mut k = start + n0 + n1;
        for v in 0..nl {
            if state.part[v] == SEP {
                iperm[k] = orig[v];
                k += 1;
            }
        }
        // Recurse on the two parts; both frames inherit composed maps.
        // The side sizes are already known from the label counts, so a
        // side that is a hamd leaf builds only its orig list and a
        // materialized side takes `InducedGraph::build`'s own map.
        let child = |pk: u8, nk: usize, start_k: usize| -> Frame {
            if hamd_leaf(nk) {
                Frame {
                    graph: None,
                    orig: (0..nl)
                        .filter(|&v| state.part[v] == pk)
                        .map(|v| orig[v])
                        .collect(),
                    start: start_k,
                }
            } else {
                let ind = InducedGraph::build(&graph, |v| state.part[v] == pk);
                Frame {
                    graph: Some(ind.graph),
                    orig: ind.orig.iter().map(|&lv| orig[lv]).collect(),
                    start: start_k,
                }
            }
        };
        stack.push(child(P1, n1, start + n0));
        stack.push(child(P0, n0, start));
    }
    iperm
}

/// Order one leaf and write its fragment. `root` is the graph the
/// recursion started from: under `leafmethod=hamd` the leaf is re-cut
/// from it together with its one-ring of enclosing-separator (and
/// initial-halo) vertices, so the minimum-degree process sees the
/// boundary it really has. `leafmethod=mmd` orders the bare `graph`
/// (always materialized for mmd frames; only hamd leaves skip it).
fn order_leaf(
    root: &Graph,
    graph: Option<&Graph>,
    orig: &[usize],
    start: usize,
    iperm: &mut [usize],
    strat: &Strategy,
) {
    let _span = trace::scope(trace::Phase::LeafOrder);
    let ord: Vec<usize> = match strat.nd.leaf_method {
        LeafMethod::Mmd => minimum_degree(graph.expect("mmd leaves carry their subgraph")),
        LeafMethod::Hamd => {
            // Core local ids in `induce_with_halo` follow the order of
            // the `orig` slice, so the HAMD order indexes `orig`
            // directly.
            let h = induce_with_halo(root, orig);
            hamd(&h.graph, &h.halo_mask()).order
        }
    };
    debug_assert_eq!(ord.len(), orig.len());
    for (k, &lv) in ord.iter().enumerate() {
        iperm[start + k] = orig[lv];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::order::symbolic_cholesky;
    use crate::sep::FmRefiner;

    fn nd(g: &Graph, seed: u64) -> Ordering {
        let strat = Strategy::default();
        let refiner = FmRefiner::default();
        nested_dissection(g, &strat, &refiner, &mut Rng::new(seed))
    }

    #[test]
    fn produces_valid_ordering() {
        let g = generators::grid2d(20, 20);
        let o = nd(&g, 1);
        o.validate().unwrap();
    }

    #[test]
    fn grid2d_opc_near_asymptotic() {
        // For 2D grids ND is O(n^{3/2}) operations; check we are within a
        // sane constant of that at n = 1024 (and far below natural order).
        let g = generators::grid2d(32, 32);
        let o = nd(&g, 2);
        let s = symbolic_cholesky(&g, &o);
        let natural = symbolic_cholesky(&g, &Ordering::identity(1024));
        assert!(s.opc < natural.opc / 3.0, "nd {} vs natural {}", s.opc, natural.opc);
        let bound = 80.0 * (1024f64).powf(1.5);
        assert!(s.opc < bound, "opc {} above asymptotic sanity bound {bound}", s.opc);
    }

    #[test]
    fn beats_or_matches_minimum_degree_on_grid3d() {
        let g = generators::grid3d(10, 10, 10);
        let o = nd(&g, 3);
        let snd = symbolic_cholesky(&g, &o);
        let md = Ordering::from_iperm(minimum_degree(&g)).unwrap();
        let smd = symbolic_cholesky(&g, &md);
        // ND should be competitive on 3D meshes (paper Table 1 context).
        assert!(
            snd.opc <= smd.opc * 1.3,
            "nd {} vs md {}",
            snd.opc,
            smd.opc
        );
    }

    #[test]
    fn small_graph_is_pure_md() {
        let g = generators::path(50, 1);
        let o = nd(&g, 4);
        o.validate().unwrap();
        let s = symbolic_cholesky(&g, &o);
        assert_eq!(s.nnz, 99); // MD gets zero fill on a path
    }

    #[test]
    fn handles_disconnected_graph() {
        let mut b = crate::graph::GraphBuilder::new(300);
        for v in 1..150 {
            b.add_edge(v - 1, v);
        }
        for v in 151..300 {
            b.add_edge(v - 1, v);
        }
        let g = b.build().unwrap();
        let o = nd(&g, 5);
        o.validate().unwrap();
    }

    #[test]
    fn handles_clique_fallback() {
        let g = generators::complete(200);
        let o = nd(&g, 6);
        o.validate().unwrap();
        let s = symbolic_cholesky(&g, &o);
        assert_eq!(s.nnz, (200 * 201 / 2) as u64);
    }

    #[test]
    fn deterministic_under_seed() {
        let g = generators::irregular_mesh(18, 18, 4);
        let a = nd(&g, 7);
        let b = nd(&g, 7);
        assert_eq!(a.iperm, b.iperm);
    }

    #[test]
    fn clique_fallback_fires_through_the_leaf_path_for_both_methods() {
        // A clique far above the leaf threshold: the separator is
        // degenerate at every level, so the empty-separator fallback
        // must emit the whole subgraph through the leaf fragment path —
        // under both leaf methods, with the exact dense fill.
        let g = generators::complete(150);
        for spec in ["leafmethod=mmd,leaf=20", "leafmethod=hamd,leaf=20"] {
            let strat = Strategy::parse(spec).unwrap();
            let refiner = FmRefiner::default();
            let o = nested_dissection(&g, &strat, &refiner, &mut Rng::new(11));
            o.validate().unwrap();
            let s = symbolic_cholesky(&g, &o);
            assert_eq!(s.nnz, (150 * 151 / 2) as u64, "{spec}");
        }
    }

    #[test]
    fn hamd_leaves_do_not_trail_mmd_on_grid3d() {
        // The halo-aware default must at least match the halo-blind
        // comparator on a 3D mesh (the acceptance suite asserts strict
        // improvement at bench scale; this pins "never worse" in tier 1).
        let g = generators::grid3d(9, 9, 9);
        let refiner = FmRefiner::default();
        let mut stats = Vec::new();
        for spec in ["leafmethod=hamd", "leafmethod=mmd"] {
            let strat = Strategy::parse(spec).unwrap();
            let o = nested_dissection(&g, &strat, &refiner, &mut Rng::new(3));
            o.validate().unwrap();
            stats.push(symbolic_cholesky(&g, &o).opc);
        }
        assert!(
            stats[0] <= stats[1] * 1.05,
            "hamd {} vs mmd {}",
            stats[0],
            stats[1]
        );
    }

    #[test]
    fn with_halo_orders_exactly_the_core() {
        // Keep the left 6 columns of a grid as core; columns 6..9 are
        // halo. The fragment must be a permutation of the core ids.
        let g = generators::grid2d(10, 8);
        let halo: Vec<bool> = (0..80).map(|v| v % 10 >= 6).collect();
        let strat = Strategy::default();
        let refiner = FmRefiner::default();
        let frag =
            nested_dissection_with_halo(&g, &halo, &strat, &refiner, &mut Rng::new(5));
        let mut got = frag.clone();
        got.sort_unstable();
        let want: Vec<usize> = (0..80).filter(|v| v % 10 < 6).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn separator_gets_highest_indices() {
        // On a 2-row ladder the top-level separator must occupy the last
        // indices of the range; verify by checking that the first-level
        // separator vertices all have perm ≥ n - sep_count.
        let g = generators::grid2d(40, 2);
        let mut strat = Strategy::default();
        strat.nd.leaf_threshold = 10; // force actual dissection at n = 80
        let refiner = FmRefiner::default();
        let mut rng = Rng::new(8);
        let state = multilevel_separator(&g, &strat.sep, &refiner, &mut rng);
        let o = nested_dissection(&g, &strat, &refiner, &mut Rng::new(8));
        // The same seed reproduces the same top separator inside nd().
        let ns = state.sep_count();
        if ns > 0 {
            for v in state.sep_vertices() {
                assert!(
                    o.perm[v] >= g.n() - ns,
                    "separator vertex {v} at position {}",
                    o.perm[v]
                );
            }
        }
    }
}
