//! Sequential nested dissection (§1, §3.1): recursively bisect with a
//! multilevel vertex separator, give the separator the highest available
//! indices, and order leaf subgraphs with minimum degree.

use super::mmd::minimum_degree;
use super::Ordering;
use crate::graph::{Graph, InducedGraph};
use crate::rng::Rng;
use crate::sep::{multilevel_separator, BandRefiner, P0, P1, SEP};
use crate::strategy::Strategy;

/// One pending subproblem: a subgraph (with its map back to root ids) and
/// the global start index of its ordering range (§2.2).
struct Frame {
    graph: Graph,
    orig: Vec<usize>,
    start: usize,
}

/// Compute a nested-dissection ordering of `g`.
pub fn nested_dissection(
    g: &Graph,
    strat: &Strategy,
    refiner: &dyn BandRefiner,
    rng: &mut Rng,
) -> Ordering {
    let n = g.n();
    let mut iperm = vec![usize::MAX; n];
    let mut stack = vec![Frame {
        graph: g.clone(),
        orig: (0..n).collect(),
        start: 0,
    }];
    while let Some(Frame { graph, orig, start }) = stack.pop() {
        let nl = graph.n();
        if nl == 0 {
            continue;
        }
        if nl <= strat.nd.leaf_threshold {
            order_leaf(&graph, &orig, start, &mut iperm);
            continue;
        }
        let state = multilevel_separator(&graph, &strat.sep, refiner, rng);
        let mut counts = [0usize; 3];
        for &p in &state.part {
            counts[p as usize] += 1;
        }
        let (n0, n1, ns) = (counts[0], counts[1], counts[2]);
        // Degenerate separator (empty side, or the separator swallowed the
        // graph, e.g. on cliques): fall back to minimum degree.
        if n0 == 0 || n1 == 0 || ns as f64 > nl as f64 * strat.nd.max_sep_fraction {
            order_leaf(&graph, &orig, start, &mut iperm);
            continue;
        }
        // Separator vertices take the highest indices of the range.
        let mut k = start + n0 + n1;
        for v in 0..nl {
            if state.part[v] == SEP {
                iperm[k] = orig[v];
                k += 1;
            }
        }
        // Recurse on the two parts; both frames inherit composed maps.
        let part1 = InducedGraph::build(&graph, |v| state.part[v] == P1);
        let orig1: Vec<usize> = part1.orig.iter().map(|&lv| orig[lv]).collect();
        stack.push(Frame {
            graph: part1.graph,
            orig: orig1,
            start: start + n0,
        });
        let part0 = InducedGraph::build(&graph, |v| state.part[v] == P0);
        let orig0: Vec<usize> = part0.orig.iter().map(|&lv| orig[lv]).collect();
        stack.push(Frame {
            graph: part0.graph,
            orig: orig0,
            start,
        });
    }
    let o = Ordering::from_iperm(iperm).expect("nested dissection covers all vertices");
    debug_assert!(o.validate().is_ok());
    o
}

/// Order a leaf subgraph with minimum degree and write its fragment.
fn order_leaf(graph: &Graph, orig: &[usize], start: usize, iperm: &mut [usize]) {
    let ord = minimum_degree(graph);
    for (k, &lv) in ord.iter().enumerate() {
        iperm[start + k] = orig[lv];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::order::symbolic_cholesky;
    use crate::sep::FmRefiner;

    fn nd(g: &Graph, seed: u64) -> Ordering {
        let strat = Strategy::default();
        let refiner = FmRefiner::default();
        nested_dissection(g, &strat, &refiner, &mut Rng::new(seed))
    }

    #[test]
    fn produces_valid_ordering() {
        let g = generators::grid2d(20, 20);
        let o = nd(&g, 1);
        o.validate().unwrap();
    }

    #[test]
    fn grid2d_opc_near_asymptotic() {
        // For 2D grids ND is O(n^{3/2}) operations; check we are within a
        // sane constant of that at n = 1024 (and far below natural order).
        let g = generators::grid2d(32, 32);
        let o = nd(&g, 2);
        let s = symbolic_cholesky(&g, &o);
        let natural = symbolic_cholesky(&g, &Ordering::identity(1024));
        assert!(s.opc < natural.opc / 3.0, "nd {} vs natural {}", s.opc, natural.opc);
        let bound = 80.0 * (1024f64).powf(1.5);
        assert!(s.opc < bound, "opc {} above asymptotic sanity bound {bound}", s.opc);
    }

    #[test]
    fn beats_or_matches_minimum_degree_on_grid3d() {
        let g = generators::grid3d(10, 10, 10);
        let o = nd(&g, 3);
        let snd = symbolic_cholesky(&g, &o);
        let md = Ordering::from_iperm(minimum_degree(&g)).unwrap();
        let smd = symbolic_cholesky(&g, &md);
        // ND should be competitive on 3D meshes (paper Table 1 context).
        assert!(
            snd.opc <= smd.opc * 1.3,
            "nd {} vs md {}",
            snd.opc,
            smd.opc
        );
    }

    #[test]
    fn small_graph_is_pure_md() {
        let g = generators::path(50, 1);
        let o = nd(&g, 4);
        o.validate().unwrap();
        let s = symbolic_cholesky(&g, &o);
        assert_eq!(s.nnz, 99); // MD gets zero fill on a path
    }

    #[test]
    fn handles_disconnected_graph() {
        let mut b = crate::graph::GraphBuilder::new(300);
        for v in 1..150 {
            b.add_edge(v - 1, v);
        }
        for v in 151..300 {
            b.add_edge(v - 1, v);
        }
        let g = b.build().unwrap();
        let o = nd(&g, 5);
        o.validate().unwrap();
    }

    #[test]
    fn handles_clique_fallback() {
        let g = generators::complete(200);
        let o = nd(&g, 6);
        o.validate().unwrap();
        let s = symbolic_cholesky(&g, &o);
        assert_eq!(s.nnz, (200 * 201 / 2) as u64);
    }

    #[test]
    fn deterministic_under_seed() {
        let g = generators::irregular_mesh(18, 18, 4);
        let a = nd(&g, 7);
        let b = nd(&g, 7);
        assert_eq!(a.iperm, b.iperm);
    }

    #[test]
    fn separator_gets_highest_indices() {
        // On a 2-row ladder the top-level separator must occupy the last
        // indices of the range; verify by checking that the first-level
        // separator vertices all have perm ≥ n - sep_count.
        let g = generators::grid2d(40, 2);
        let mut strat = Strategy::default();
        strat.nd.leaf_threshold = 10; // force actual dissection at n = 80
        let refiner = FmRefiner::default();
        let mut rng = Rng::new(8);
        let state = multilevel_separator(&g, &strat.sep, &refiner, &mut rng);
        let o = nested_dissection(&g, &strat, &refiner, &mut Rng::new(8));
        // The same seed reproduces the same top separator inside nd().
        let ns = state.sep_count();
        if ns > 0 {
            for v in state.sep_vertices() {
                assert!(
                    o.perm[v] >= g.n() - ns,
                    "separator vertex {v} at position {}",
                    o.perm[v]
                );
            }
        }
    }
}
