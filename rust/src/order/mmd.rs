//! Minimum-degree ordering on a quotient graph.
//!
//! Nested dissection hands its leaf subgraphs to a minimum-degree method
//! (the paper couples ND with halo-AMD [10] —
//! [`crate::order::hamd::hamd`], the default; minimum degree "is thus
//! only used in a sequential context", §3.1). This is a clean quotient-graph implementation with
//! exact external degrees recomputed at selection time over the shared
//! degree buckets ([`crate::order::degrees::DegreeLists`]) — quadratic
//! worst case but effectively fast at leaf sizes, and usable standalone
//! as the halo-blind whole-graph comparator (`leafmethod=mmd`).

use super::degrees::DegreeLists;
use crate::graph::Graph;

/// State of one vertex id in the quotient graph.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum NodeState {
    /// Still a variable (uneliminated).
    Variable,
    /// Eliminated: its id now names an element (clique placeholder).
    Element,
    /// Element that has been absorbed into a newer element.
    Absorbed,
}

/// Quotient-graph storage.
struct Quotient {
    state: Vec<NodeState>,
    /// Direct variable neighbors (may hold stale ids, purged on touch).
    adjv: Vec<Vec<u32>>,
    /// Adjacent elements (may hold absorbed ids, purged on touch).
    adje: Vec<Vec<u32>>,
    /// Member variables of each element (indexed by element id).
    evars: Vec<Vec<u32>>,
    /// Stamp array for set unions.
    stamp: Vec<u64>,
    tag: u64,
}

impl Quotient {
    fn new(g: &Graph) -> Quotient {
        let n = g.n();
        Quotient {
            state: vec![NodeState::Variable; n],
            adjv: (0..n).map(|v| g.neighbors(v).to_vec()).collect(),
            adje: vec![Vec::new(); n],
            evars: vec![Vec::new(); n],
            stamp: vec![0; n],
            tag: 0,
        }
    }

    /// Reachable variable set of `v` (its external neighborhood through
    /// direct edges and elements). Compacts `adjv[v]` / `adje[v]` on the
    /// way. Returns the reach list; its length is the exact degree.
    fn reach(&mut self, v: usize) -> Vec<u32> {
        self.tag += 1;
        let tag = self.tag;
        self.stamp[v] = tag; // exclude self
        let mut out = Vec::with_capacity(self.adjv[v].len() + 4);
        let mut new_adjv = Vec::with_capacity(self.adjv[v].len());
        let adjv = std::mem::take(&mut self.adjv[v]);
        for &u in &adjv {
            let ui = u as usize;
            if self.state[ui] != NodeState::Variable {
                continue;
            }
            new_adjv.push(u);
            if self.stamp[ui] != tag {
                self.stamp[ui] = tag;
                out.push(u);
            }
        }
        self.adjv[v] = new_adjv;
        let mut new_adje = Vec::with_capacity(self.adje[v].len());
        let adje = std::mem::take(&mut self.adje[v]);
        for &e in &adje {
            if self.state[e as usize] != NodeState::Element {
                continue;
            }
            new_adje.push(e);
            for &u in &self.evars[e as usize] {
                let ui = u as usize;
                if self.state[ui] == NodeState::Variable && self.stamp[ui] != tag {
                    self.stamp[ui] = tag;
                    out.push(u);
                }
            }
        }
        self.adje[v] = new_adje;
        out
    }
}

/// Compute a minimum-degree elimination order; returns vertex ids in
/// elimination sequence (i.e. an inverse permutation).
pub fn minimum_degree(g: &Graph) -> Vec<usize> {
    let n = g.n();
    let mut q = Quotient::new(g);
    // Degree buckets file every vertex under a LOWER bound of its true
    // external degree; the exact degree is recomputed at selection
    // time. The buckets support true remove/re-file, so no stale
    // entries and no version counters exist.
    let mut lists = DegreeLists::new(n);
    for v in 0..n {
        lists.insert(v, g.degree(v));
    }

    let mut order = Vec::with_capacity(n);
    while let Some((v, _)) = lists.pop_min() {
        debug_assert_eq!(q.state[v], NodeState::Variable);
        let reach = q.reach(v);
        let deg = reach.len();
        // Lazy discipline: if the exact degree exceeds the smallest
        // remaining bound, some other vertex may truly be smaller —
        // re-file at the exact degree instead of eliminating.
        if let Some(next_deg) = lists.min_degree() {
            if deg > next_deg {
                lists.insert(v, deg);
                continue;
            }
        }
        // Eliminate v: absorb its elements, publish the new element.
        order.push(v);
        q.state[v] = NodeState::Element;
        for k in 0..q.adje[v].len() {
            let e = q.adje[v][k] as usize;
            q.state[e] = NodeState::Absorbed;
            q.evars[e].clear();
        }
        q.adjv[v].clear();
        q.adje[v].clear();
        for &u in &reach {
            let ui = u as usize;
            q.adje[ui].push(v as u32);
            // The new bound: u is adjacent to the other `deg - 1`
            // members of the new element — still a lower bound of its
            // true degree, re-filed in O(1).
            lists.update(ui, deg.saturating_sub(1));
        }
        q.evars[v] = reach;
    }
    debug_assert_eq!(order.len(), n);
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, GraphBuilder};
    use crate::order::{symbolic_cholesky, Ordering};

    fn order_of(g: &Graph) -> Ordering {
        Ordering::from_iperm(minimum_degree(g)).unwrap()
    }

    #[test]
    fn orders_every_vertex_once() {
        let g = generators::grid2d(7, 7);
        let o = order_of(&g);
        o.validate().unwrap();
    }

    #[test]
    fn star_center_goes_last() {
        let mut b = GraphBuilder::new(8);
        for v in 1..8 {
            b.add_edge(0, v);
        }
        let g = b.build().unwrap();
        let ord = minimum_degree(&g);
        // The hub may only be eliminated once its degree has dropped to 1,
        // i.e. after at least 6 of the 7 leaves.
        let hub_pos = ord.iter().position(|&v| v == 0).unwrap();
        assert!(hub_pos >= 6, "hub eliminated too early at {hub_pos}");
        let s = symbolic_cholesky(&g, &order_of(&g));
        assert_eq!(s.nnz, (7 * 2 + 1) as u64); // no fill
    }

    #[test]
    fn path_has_no_fill() {
        let g = generators::path(50, 1);
        let s = symbolic_cholesky(&g, &order_of(&g));
        assert_eq!(s.nnz, 99); // 2 per column except one root
    }

    #[test]
    fn tree_has_no_fill() {
        // Perfect binary tree on 31 vertices: MD must find a no-fill order.
        let mut b = GraphBuilder::new(31);
        for v in 1..31 {
            b.add_edge(v, (v - 1) / 2);
        }
        let g = b.build().unwrap();
        let s = symbolic_cholesky(&g, &order_of(&g));
        assert_eq!(s.nnz, 61); // n + (n-1) edges, zero fill
    }

    #[test]
    fn beats_identity_on_grid() {
        let g = generators::grid2d(12, 12);
        let md = symbolic_cholesky(&g, &order_of(&g));
        let id = symbolic_cholesky(&g, &Ordering::identity(144));
        assert!(
            md.opc < id.opc,
            "MD opc {} should beat natural opc {}",
            md.opc,
            id.opc
        );
    }

    #[test]
    fn handles_disconnected() {
        let mut b = GraphBuilder::new(7);
        b.add_edge(0, 1);
        b.add_edge(2, 3);
        b.add_edge(3, 4);
        // 5, 6 isolated
        let g = b.build().unwrap();
        let o = order_of(&g);
        o.validate().unwrap();
    }

    #[test]
    fn clique_any_order_is_fine() {
        let g = generators::complete(9);
        let s = symbolic_cholesky(&g, &order_of(&g));
        assert_eq!(s.nnz, (9 * 10 / 2) as u64); // dense lower triangle
    }

    #[test]
    fn grid3d_reasonable_quality() {
        let g = generators::grid3d(6, 6, 6);
        let md = symbolic_cholesky(&g, &order_of(&g));
        let id = symbolic_cholesky(&g, &Ordering::identity(216));
        assert!(md.opc <= id.opc * 1.05);
    }
}
