//! Elimination trees (Liu's algorithm with path compression).
//!
//! The elimination tree of the permuted matrix drives the symbolic
//! factorization that produces the paper's NNZ and OPC quality metrics,
//! and its depth/shape reflects the elimination concurrency that nested
//! dissection is meant to expose.

use super::Ordering;
use crate::graph::Graph;

/// Parent of each column in the elimination tree of `PAPᵀ`, in **new**
/// (permuted) indices; roots have parent `usize::MAX`.
pub fn etree(g: &Graph, order: &Ordering) -> Vec<usize> {
    let n = g.n();
    let mut parent = vec![usize::MAX; n];
    let mut ancestor = vec![usize::MAX; n]; // path-compressed ancestors
    for i in 0..n {
        let old_i = order.iperm[i];
        for &u in g.neighbors(old_i) {
            let mut k = order.perm[u as usize];
            if k >= i {
                continue;
            }
            // Walk from k to the root of its subtree, compressing.
            while ancestor[k] != usize::MAX && ancestor[k] != i {
                let next = ancestor[k];
                ancestor[k] = i;
                k = next;
            }
            if ancestor[k] == usize::MAX {
                ancestor[k] = i;
                parent[k] = i;
            }
        }
    }
    parent
}

/// Height of the elimination tree (longest root-to-leaf path, in nodes).
/// A proxy for the critical path of the numeric factorization — nested
/// dissection keeps it O(separator-levels), minimum degree does not.
pub fn etree_height(parent: &[usize]) -> usize {
    let n = parent.len();
    let mut height = vec![0usize; n];
    let mut best = 0;
    // parent[i] > i for all i, so one forward pass suffices.
    for i in 0..n {
        let h = height[i] + 1;
        best = best.max(h);
        if parent[i] != usize::MAX {
            height[parent[i]] = height[parent[i]].max(h);
        }
    }
    best
}

/// A postorder of the elimination tree (children before parents).
pub fn postorder(parent: &[usize]) -> Vec<usize> {
    let n = parent.len();
    let mut first_child = vec![usize::MAX; n];
    let mut next_sibling = vec![usize::MAX; n];
    let mut roots = Vec::new();
    // Build child lists in reverse so traversal is in ascending order.
    for i in (0..n).rev() {
        match parent[i] {
            usize::MAX => roots.push(i),
            p => {
                next_sibling[i] = first_child[p];
                first_child[p] = i;
            }
        }
    }
    let mut post = Vec::with_capacity(n);
    let mut stack: Vec<(usize, bool)> = Vec::new();
    for &r in roots.iter().rev() {
        stack.push((r, false));
        while let Some((v, expanded)) = stack.pop() {
            if expanded {
                post.push(v);
                continue;
            }
            stack.push((v, true));
            let mut c = first_child[v];
            let mut kids = Vec::new();
            while c != usize::MAX {
                kids.push(c);
                c = next_sibling[c];
            }
            for &k in kids.iter().rev() {
                stack.push((k, false));
            }
        }
    }
    post
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn etree_of_path_identity_is_a_path() {
        // Tridiagonal matrix with natural order: parent[i] = i+1.
        let g = generators::path(6, 1);
        let o = Ordering::identity(6);
        let p = etree(&g, &o);
        assert_eq!(p, vec![1, 2, 3, 4, 5, usize::MAX]);
        assert_eq!(etree_height(&p), 6);
    }

    #[test]
    fn etree_of_star_center_last() {
        // Star with center ordered last: every leaf's parent is the center.
        let mut b = crate::graph::GraphBuilder::new(5);
        for v in 0..4 {
            b.add_edge(v, 4);
        }
        let g = b.build().unwrap();
        let o = Ordering::identity(5);
        let p = etree(&g, &o);
        assert_eq!(p, vec![4, 4, 4, 4, usize::MAX]);
        assert_eq!(etree_height(&p), 2);
    }

    #[test]
    fn etree_respects_permutation() {
        // Path 0-1-2 ordered [1, 0, 2]: after permutation, column of old-1
        // is eliminated first and links to both others.
        let g = generators::path(3, 1);
        let o = Ordering::from_iperm(vec![1, 0, 2]).unwrap();
        let p = etree(&g, &o);
        // new0 = old1 neighbors old0(new1), old2(new2): parent[0] = 1.
        // new1 = old0: L(2,1) fill from path through eliminated old1.
        assert_eq!(p[0], 1);
        assert_eq!(p[1], 2);
        assert_eq!(p[2], usize::MAX);
    }

    #[test]
    fn postorder_children_before_parents() {
        let g = generators::grid2d(6, 6);
        let o = Ordering::identity(36);
        let p = etree(&g, &o);
        let post = postorder(&p);
        assert_eq!(post.len(), 36);
        let mut pos = vec![0usize; 36];
        for (k, &v) in post.iter().enumerate() {
            pos[v] = k;
        }
        for i in 0..36 {
            if p[i] != usize::MAX {
                assert!(pos[i] < pos[p[i]], "child {i} after parent {}", p[i]);
            }
        }
    }

    #[test]
    fn disconnected_graph_has_forest() {
        let mut b = crate::graph::GraphBuilder::new(4);
        b.add_edge(0, 1);
        b.add_edge(2, 3);
        let g = b.build().unwrap();
        let o = Ordering::identity(4);
        let p = etree(&g, &o);
        let roots = p.iter().filter(|&&x| x == usize::MAX).count();
        assert_eq!(roots, 2);
        assert_eq!(postorder(&p).len(), 4);
    }
}
