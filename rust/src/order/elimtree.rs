//! Elimination trees (Liu's algorithm with path compression).
//!
//! The elimination tree of the permuted matrix drives the symbolic
//! factorization that produces the paper's NNZ and OPC quality metrics,
//! and its depth/shape reflects the elimination concurrency that nested
//! dissection is meant to expose.

use super::Ordering;
use crate::graph::Graph;
use crate::{Error, Result};

/// Parent of each column in the elimination tree of `PAPᵀ`, in **new**
/// (permuted) indices; roots have parent `usize::MAX`.
pub fn etree(g: &Graph, order: &Ordering) -> Vec<usize> {
    let n = g.n();
    let mut parent = vec![usize::MAX; n];
    let mut ancestor = vec![usize::MAX; n]; // path-compressed ancestors
    for i in 0..n {
        let old_i = order.iperm[i];
        for &u in g.neighbors(old_i) {
            let mut k = order.perm[u as usize];
            if k >= i {
                continue;
            }
            // Walk from k to the root of its subtree, compressing.
            while ancestor[k] != usize::MAX && ancestor[k] != i {
                let next = ancestor[k];
                ancestor[k] = i;
                k = next;
            }
            if ancestor[k] == usize::MAX {
                ancestor[k] = i;
                parent[k] = i;
            }
        }
    }
    parent
}

/// Height of the elimination tree (longest root-to-leaf path, in nodes).
/// A proxy for the critical path of the numeric factorization — nested
/// dissection keeps it O(separator-levels), minimum degree does not.
pub fn etree_height(parent: &[usize]) -> usize {
    let n = parent.len();
    let mut height = vec![0usize; n];
    let mut best = 0;
    // parent[i] > i for all i, so one forward pass suffices.
    for i in 0..n {
        let h = height[i] + 1;
        best = best.max(h);
        if parent[i] != usize::MAX {
            height[parent[i]] = height[parent[i]].max(h);
        }
    }
    best
}

/// A postorder of the elimination tree (children before parents).
pub fn postorder(parent: &[usize]) -> Vec<usize> {
    let n = parent.len();
    let mut first_child = vec![usize::MAX; n];
    let mut next_sibling = vec![usize::MAX; n];
    let mut roots = Vec::new();
    // Build child lists in reverse so traversal is in ascending order.
    for i in (0..n).rev() {
        match parent[i] {
            usize::MAX => roots.push(i),
            p => {
                next_sibling[i] = first_child[p];
                first_child[p] = i;
            }
        }
    }
    let mut post = Vec::with_capacity(n);
    let mut stack: Vec<(usize, bool)> = Vec::new();
    for &r in roots.iter().rev() {
        stack.push((r, false));
        while let Some((v, expanded)) = stack.pop() {
            if expanded {
                post.push(v);
                continue;
            }
            stack.push((v, true));
            let mut c = first_child[v];
            let mut kids = Vec::new();
            while c != usize::MAX {
                kids.push(c);
                c = next_sibling[c];
            }
            for &k in kids.iter().rev() {
                stack.push((k, false));
            }
        }
    }
    post
}

/// The solver-facing block structure of an ordering — what downstream
/// sparse factorization consumers (e.g. Tacho's `GraphTools_Scotch`
/// wrapper) read off a Scotch ordering besides `perm`/`peri`: the
/// supernode column ranges (`rangtab`) and the parent of each column
/// block in the separator/elimination tree (`treetab`).
///
/// All indices are in **new** (permuted) column space. Blocks are
/// maximal chains of the elimination tree: consecutive columns
/// `i, i+1` share a block iff `parent[i] = i+1`, so every block's
/// columns eliminate into the next and only the last column's parent
/// leaves the block. Because elimination-tree parents always point to
/// higher columns, block parents always point to higher block indices
/// — the block forest is **postordered by construction**, which is the
/// contract [`BlockOrdering::validate`] enforces.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlockOrdering {
    /// Number of column blocks (Scotch `cblkptr`).
    pub cblk: usize,
    /// Column range of block `b`: new columns `range[b]..range[b+1]`
    /// (Scotch `rangtab`; `cblk + 1` entries, `range[0] = 0`,
    /// strictly increasing, `range[cblk] = n`).
    pub range: Vec<usize>,
    /// Parent block of block `b` in the separator/elimination forest
    /// (Scotch `treetab`); roots hold `usize::MAX`. Always
    /// `tree[b] > b` for non-roots: children precede parents.
    pub tree: Vec<usize>,
}

impl BlockOrdering {
    /// Build the block structure from an elimination-tree parent vector
    /// (as produced by [`etree`], in permuted indices).
    pub fn from_etree(parent: &[usize]) -> BlockOrdering {
        let n = parent.len();
        let mut range = Vec::new();
        range.push(0);
        for i in 1..n {
            if parent[i - 1] != i {
                range.push(i);
            }
        }
        if n > 0 {
            range.push(n);
        }
        let cblk = range.len() - 1;
        // Map each column to its block (ranges are sorted), then point
        // each block at the block holding its last column's parent.
        let mut block_of = vec![0usize; n];
        for b in 0..cblk {
            for col in range[b]..range[b + 1] {
                block_of[col] = b;
            }
        }
        let tree = (0..cblk)
            .map(|b| {
                let last = range[b + 1] - 1;
                match parent[last] {
                    usize::MAX => usize::MAX,
                    p => block_of[p],
                }
            })
            .collect();
        BlockOrdering { cblk, range, tree }
    }

    /// Number of ordered columns covered by the blocks.
    pub fn n(&self) -> usize {
        *self.range.last().expect("range always holds at least [0]")
    }

    /// The block containing new column `col`.
    pub fn block_of(&self, col: usize) -> usize {
        debug_assert!(col < self.n());
        match self.range.binary_search(&col) {
            Ok(b) if b == self.cblk => self.cblk - 1,
            Ok(b) => b,
            Err(i) => i - 1,
        }
    }

    /// Check the solver-facing contract: `range` is a strictly
    /// increasing tiling of `0..n` with `cblk + 1` entries, `tree` has
    /// `cblk` entries, and the block forest is **postordered** — every
    /// non-root parent satisfies `b < tree[b] < cblk`, so children
    /// always precede their parents (what a supernodal factorization
    /// scheduler relies on).
    pub fn validate(&self, n: usize) -> Result<()> {
        if self.range.len() != self.cblk + 1 {
            return Err(Error::InvalidOrdering(format!(
                "range has {} entries for cblk = {}",
                self.range.len(),
                self.cblk
            )));
        }
        if self.range[0] != 0 || self.n() != n {
            return Err(Error::InvalidOrdering(format!(
                "range spans {}..{} but the ordering has {n} columns",
                self.range[0],
                self.n()
            )));
        }
        for b in 0..self.cblk {
            if self.range[b] >= self.range[b + 1] {
                return Err(Error::InvalidOrdering(format!(
                    "block {b} has empty or reversed range"
                )));
            }
        }
        if self.tree.len() != self.cblk {
            return Err(Error::InvalidOrdering(format!(
                "tree has {} entries for cblk = {}",
                self.tree.len(),
                self.cblk
            )));
        }
        for (b, &p) in self.tree.iter().enumerate() {
            if p != usize::MAX && (p <= b || p >= self.cblk) {
                return Err(Error::InvalidOrdering(format!(
                    "block {b} has non-postordered parent {p}"
                )));
            }
        }
        Ok(())
    }
}

/// Compute the [`BlockOrdering`] of `g` under `order` — the
/// elimination tree of the permuted matrix, chain-merged into
/// supernodal column blocks. Works for any valid ordering, so the
/// sequential ([`crate::order::nd`]) and distributed
/// ([`crate::dist::parallel_order`]) engines share this one emission
/// path.
pub fn block_ordering(g: &Graph, order: &Ordering) -> BlockOrdering {
    BlockOrdering::from_etree(&etree(g, order))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn etree_of_path_identity_is_a_path() {
        // Tridiagonal matrix with natural order: parent[i] = i+1.
        let g = generators::path(6, 1);
        let o = Ordering::identity(6);
        let p = etree(&g, &o);
        assert_eq!(p, vec![1, 2, 3, 4, 5, usize::MAX]);
        assert_eq!(etree_height(&p), 6);
    }

    #[test]
    fn etree_of_star_center_last() {
        // Star with center ordered last: every leaf's parent is the center.
        let mut b = crate::graph::GraphBuilder::new(5);
        for v in 0..4 {
            b.add_edge(v, 4);
        }
        let g = b.build().unwrap();
        let o = Ordering::identity(5);
        let p = etree(&g, &o);
        assert_eq!(p, vec![4, 4, 4, 4, usize::MAX]);
        assert_eq!(etree_height(&p), 2);
    }

    #[test]
    fn etree_respects_permutation() {
        // Path 0-1-2 ordered [1, 0, 2]: after permutation, column of old-1
        // is eliminated first and links to both others.
        let g = generators::path(3, 1);
        let o = Ordering::from_iperm(vec![1, 0, 2]).unwrap();
        let p = etree(&g, &o);
        // new0 = old1 neighbors old0(new1), old2(new2): parent[0] = 1.
        // new1 = old0: L(2,1) fill from path through eliminated old1.
        assert_eq!(p[0], 1);
        assert_eq!(p[1], 2);
        assert_eq!(p[2], usize::MAX);
    }

    #[test]
    fn postorder_children_before_parents() {
        let g = generators::grid2d(6, 6);
        let o = Ordering::identity(36);
        let p = etree(&g, &o);
        let post = postorder(&p);
        assert_eq!(post.len(), 36);
        let mut pos = vec![0usize; 36];
        for (k, &v) in post.iter().enumerate() {
            pos[v] = k;
        }
        for i in 0..36 {
            if p[i] != usize::MAX {
                assert!(pos[i] < pos[p[i]], "child {i} after parent {}", p[i]);
            }
        }
    }

    #[test]
    fn disconnected_graph_has_forest() {
        let mut b = crate::graph::GraphBuilder::new(4);
        b.add_edge(0, 1);
        b.add_edge(2, 3);
        let g = b.build().unwrap();
        let o = Ordering::identity(4);
        let p = etree(&g, &o);
        let roots = p.iter().filter(|&&x| x == usize::MAX).count();
        assert_eq!(roots, 2);
        assert_eq!(postorder(&p).len(), 4);
    }

    #[test]
    fn blocks_of_path_chain_into_one_supernode() {
        // etree of the natural-order path is one chain: a single block.
        let g = generators::path(6, 1);
        let b = block_ordering(&g, &Ordering::identity(6));
        assert_eq!(b.cblk, 1);
        assert_eq!(b.range, vec![0, 6]);
        assert_eq!(b.tree, vec![usize::MAX]);
        b.validate(6).unwrap();
    }

    #[test]
    fn blocks_of_star_are_leaves_plus_center() {
        // Leaves 0..3 each form their own block parented on the center's
        // block; leaf 3 chains into the center (parent[3] = 4).
        let mut bld = crate::graph::GraphBuilder::new(5);
        for v in 0..4 {
            bld.add_edge(v, 4);
        }
        let g = bld.build().unwrap();
        let b = block_ordering(&g, &Ordering::identity(5));
        assert_eq!(b.range, vec![0, 1, 2, 3, 5]);
        assert_eq!(b.tree, vec![3, 3, 3, usize::MAX]);
        b.validate(5).unwrap();
    }

    #[test]
    fn blocks_of_forest_have_one_root_per_tree() {
        let mut bld = crate::graph::GraphBuilder::new(4);
        bld.add_edge(0, 1);
        bld.add_edge(2, 3);
        let g = bld.build().unwrap();
        let b = block_ordering(&g, &Ordering::identity(4));
        b.validate(4).unwrap();
        let roots = b.tree.iter().filter(|&&p| p == usize::MAX).count();
        assert_eq!(roots, 2);
        assert_eq!(b.range, vec![0, 2, 4]);
    }

    #[test]
    fn empty_graph_has_zero_blocks() {
        let b = BlockOrdering::from_etree(&[]);
        assert_eq!(b.cblk, 0);
        assert_eq!(b.range, vec![0]);
        assert!(b.tree.is_empty());
        b.validate(0).unwrap();
    }

    #[test]
    fn block_of_locates_columns() {
        let b = BlockOrdering {
            cblk: 3,
            range: vec![0, 2, 3, 7],
            tree: vec![2, 2, usize::MAX],
        };
        b.validate(7).unwrap();
        let owners: Vec<usize> = (0..7).map(|c| b.block_of(c)).collect();
        assert_eq!(owners, vec![0, 0, 1, 2, 2, 2, 2]);
    }

    #[test]
    fn validate_rejects_non_postordered_tree() {
        let mut b = BlockOrdering {
            cblk: 2,
            range: vec![0, 3, 5],
            tree: vec![1, usize::MAX],
        };
        b.validate(5).unwrap();
        b.tree = vec![usize::MAX, 0]; // parent before child
        assert!(b.validate(5).is_err());
        b.tree = vec![2, usize::MAX]; // parent out of range
        assert!(b.validate(5).is_err());
    }

    #[test]
    fn blocks_cover_grid_under_nd_ordering() {
        let g = generators::grid2d(8, 8);
        let strat = crate::strategy::Strategy::parse("seed=3").unwrap();
        let refiner = crate::sep::FmRefiner::default();
        let o = crate::order::nd::nested_dissection(
            &g,
            &strat,
            &refiner,
            &mut crate::rng::Rng::new(strat.seed),
        );
        let b = block_ordering(&g, &o);
        b.validate(64).unwrap();
        // Nested dissection on a grid must expose more than one supernode.
        assert!(b.cblk > 1, "cblk = {}", b.cblk);
        // Every column's block parent chain stays consistent with the etree.
        let parent = etree(&g, &o);
        for i in 0..64 {
            if parent[i] != usize::MAX {
                let (bi, bp) = (b.block_of(i), b.block_of(parent[i]));
                assert!(bp == bi || bp > bi, "column {i}: block {bi} -> {bp}");
            }
        }
    }
}
