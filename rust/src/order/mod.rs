//! Orderings and their quality evaluation (S7–S8): permutation
//! containers, elimination trees, symbolic Cholesky factorization (the
//! paper's NNZ and OPC metrics), the minimum-degree leaf orderers
//! (exact-degree [`mmd`] and halo-approximate [`hamd`], over the shared
//! [`degrees`] buckets) and sequential nested dissection.

pub mod degrees;
pub mod elimtree;
pub mod hamd;
pub mod mmd;
pub mod nd;
pub mod symbolic;

pub use elimtree::{block_ordering, BlockOrdering};
pub use hamd::{hamd, HamdOrder};
pub use nd::{nested_dissection, nested_dissection_with_halo};
pub use symbolic::{symbolic_cholesky, SymbolicStats};

use crate::{Error, Result};

/// A symmetric permutation of the vertices/unknowns.
///
/// `perm[old] = new` (direct permutation) and `iperm[new] = old` (inverse
/// permutation). PT-Scotch materializes orderings as *inverse* permutation
/// fragments because those can be built fully distributed (§2.2); the
/// direct permutation is derived at assembly.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Ordering {
    /// `perm[old] = new`.
    pub perm: Vec<usize>,
    /// `iperm[new] = old`.
    pub iperm: Vec<usize>,
}

impl Ordering {
    /// The identity ordering on `n` unknowns.
    pub fn identity(n: usize) -> Ordering {
        let id: Vec<usize> = (0..n).collect();
        Ordering {
            perm: id.clone(),
            iperm: id,
        }
    }

    /// Build from an inverse permutation (`iperm[new] = old`).
    pub fn from_iperm(iperm: Vec<usize>) -> Result<Ordering> {
        let n = iperm.len();
        let mut perm = vec![usize::MAX; n];
        for (new, &old) in iperm.iter().enumerate() {
            if old >= n {
                return Err(Error::InvalidOrdering(format!(
                    "iperm[{new}] = {old} out of range"
                )));
            }
            if perm[old] != usize::MAX {
                return Err(Error::InvalidOrdering(format!("duplicate old index {old}")));
            }
            perm[old] = new;
        }
        Ok(Ordering { perm, iperm })
    }

    /// Build from a direct permutation (`perm[old] = new`).
    pub fn from_perm(perm: Vec<usize>) -> Result<Ordering> {
        let n = perm.len();
        let mut iperm = vec![usize::MAX; n];
        for (old, &new) in perm.iter().enumerate() {
            if new >= n {
                return Err(Error::InvalidOrdering(format!(
                    "perm[{old}] = {new} out of range"
                )));
            }
            if iperm[new] != usize::MAX {
                return Err(Error::InvalidOrdering(format!("duplicate new index {new}")));
            }
            iperm[new] = old;
        }
        Ok(Ordering { perm, iperm })
    }

    /// Number of unknowns.
    pub fn n(&self) -> usize {
        self.perm.len()
    }

    /// Check that `perm` and `iperm` are mutually inverse bijections.
    pub fn validate(&self) -> Result<()> {
        if self.perm.len() != self.iperm.len() {
            return Err(Error::InvalidOrdering("perm/iperm length mismatch".into()));
        }
        for old in 0..self.perm.len() {
            let new = self.perm[old];
            if new >= self.iperm.len() || self.iperm[new] != old {
                return Err(Error::InvalidOrdering(format!(
                    "perm/iperm disagree at old = {old}"
                )));
            }
        }
        Ok(())
    }
}

/// An inverse-permutation *fragment*: the sub-ordering of one subgraph,
/// starting at a global index (§2.2). The distributed ordering is the
/// assembly of all fragments by ascending start index.
#[derive(Clone, Debug)]
pub struct OrderFragment {
    /// Global start index of this fragment in the inverse permutation.
    pub start: usize,
    /// Original global vertex ids, in local inverse-permutation order.
    pub verts: Vec<usize>,
}

/// Assemble fragments into a complete ordering of `n` unknowns.
/// Fragments must tile `0..n` exactly.
pub fn assemble_fragments(n: usize, mut frags: Vec<OrderFragment>) -> Result<Ordering> {
    frags.sort_by_key(|f| f.start);
    let mut iperm = Vec::with_capacity(n);
    for f in &frags {
        if f.start != iperm.len() {
            return Err(Error::InvalidOrdering(format!(
                "fragment starts at {} but {} indices are filled",
                f.start,
                iperm.len()
            )));
        }
        iperm.extend_from_slice(&f.verts);
    }
    if iperm.len() != n {
        return Err(Error::InvalidOrdering(format!(
            "fragments cover {} of {n} indices",
            iperm.len()
        )));
    }
    Ordering::from_iperm(iperm)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_roundtrip() {
        let o = Ordering::identity(5);
        o.validate().unwrap();
        assert_eq!(o.perm, o.iperm);
    }

    #[test]
    fn from_iperm_inverts() {
        let o = Ordering::from_iperm(vec![2, 0, 1]).unwrap();
        o.validate().unwrap();
        assert_eq!(o.perm, vec![1, 2, 0]);
    }

    #[test]
    fn from_perm_inverts() {
        let o = Ordering::from_perm(vec![1, 2, 0]).unwrap();
        o.validate().unwrap();
        assert_eq!(o.iperm, vec![2, 0, 1]);
    }

    #[test]
    fn rejects_duplicates_and_range() {
        assert!(Ordering::from_iperm(vec![0, 0]).is_err());
        assert!(Ordering::from_iperm(vec![0, 5]).is_err());
        assert!(Ordering::from_perm(vec![1, 1]).is_err());
    }

    #[test]
    fn assemble_tiles_fragments() {
        let frags = vec![
            OrderFragment {
                start: 2,
                verts: vec![0, 3],
            },
            OrderFragment {
                start: 0,
                verts: vec![2, 1],
            },
        ];
        let o = assemble_fragments(4, frags).unwrap();
        assert_eq!(o.iperm, vec![2, 1, 0, 3]);
        o.validate().unwrap();
    }

    #[test]
    fn assemble_rejects_gap_and_overlap() {
        let gap = vec![OrderFragment {
            start: 1,
            verts: vec![0],
        }];
        assert!(assemble_fragments(2, gap).is_err());
        let short = vec![OrderFragment {
            start: 0,
            verts: vec![0],
        }];
        assert!(assemble_fragments(2, short).is_err());
    }
}
