//! Symbolic Cholesky factorization: column counts of the factor `L` of
//! the permuted matrix, yielding the paper's two quality metrics (§4):
//!
//! * **NNZ** — number of non-zeros of the factored reordered matrix;
//! * **OPC** — operation count of Cholesky factorization, `Σ_c n_c²`
//!   where `n_c` is the number of non-zeros of column `c`, diagonal
//!   included.
//!
//! Column counts are obtained by the row-subtree property: `L(i,j) ≠ 0`
//! iff `j` lies on an elimination-tree path from some `k ∈ adj(i), k < i`
//! up to `i`. Walking each row's subtree with stamping costs
//! `O(nnz(L))` — exact, and fast enough for every graph in the bench
//! suite (the asymptotically optimal Gilbert–Ng–Peyton variant can be
//! swapped in without changing the interface).

use super::elimtree::{etree, etree_height};
use super::Ordering;
use crate::graph::Graph;

/// Result of a symbolic factorization.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SymbolicStats {
    /// Non-zeros of `L`, diagonal included (the paper's NNZ).
    pub nnz: u64,
    /// Cholesky operation count `Σ n_c²` (the paper's OPC).
    pub opc: f64,
    /// Fill ratio: `NNZ(L) / NNZ(tril(A))` with diagonals included.
    pub fill_ratio: f64,
    /// Elimination-tree height (factorization critical path proxy).
    pub tree_height: usize,
}

/// Symbolically factor `PAPᵀ` where `A` is the adjacency structure of `g`
/// (plus a full diagonal) and `P` is `order`.
pub fn symbolic_cholesky(g: &Graph, order: &Ordering) -> SymbolicStats {
    debug_assert!(order.validate().is_ok());
    let n = g.n();
    let parent = etree(g, order);
    let mut count = vec![1u64; n]; // diagonal of every column
    let mut stamp = vec![usize::MAX; n];
    for i in 0..n {
        stamp[i] = i; // row i never walks past itself
        let old_i = order.iperm[i];
        for &u in g.neighbors(old_i) {
            let mut j = order.perm[u as usize];
            if j >= i {
                continue;
            }
            // Walk up the etree until an already-stamped column.
            while stamp[j] != i {
                stamp[j] = i;
                count[j] += 1; // L(i,j) ≠ 0
                j = parent[j];
                debug_assert!(j != usize::MAX, "walk fell off the tree");
            }
        }
    }
    let nnz: u64 = count.iter().sum();
    let opc: f64 = count.iter().map(|&c| (c as f64) * (c as f64)).sum();
    let nnz_a = (g.arcs() / 2 + n) as f64;
    SymbolicStats {
        nnz,
        opc,
        fill_ratio: nnz as f64 / nnz_a,
        tree_height: etree_height(&parent),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, GraphBuilder};

    /// Brute-force symbolic factorization by explicit elimination:
    /// O(n³)-ish, for cross-checking on small graphs.
    fn brute_force(g: &Graph, order: &Ordering) -> (u64, f64) {
        let n = g.n();
        // adjacency sets in new indices
        let mut rows: Vec<std::collections::BTreeSet<usize>> =
            vec![std::collections::BTreeSet::new(); n];
        for v in 0..n {
            for &u in g.neighbors(v) {
                let (a, b) = (order.perm[v], order.perm[u as usize]);
                if a != b {
                    rows[a.max(b)].insert(a.min(b));
                }
            }
        }
        // Column structures of L by elimination.
        let mut cols: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, row) in rows.iter().enumerate() {
            for &j in row {
                cols[j].push(i);
            }
        }
        // Fill: eliminating column j connects all later nonzeros of col j
        // to the smallest one (the parent) — standard symbolic elimination.
        let mut nnz = 0u64;
        let mut opc = 0f64;
        let mut colsets: Vec<std::collections::BTreeSet<usize>> = cols
            .iter()
            .map(|c| c.iter().copied().collect())
            .collect();
        for j in 0..n {
            let below: Vec<usize> = colsets[j].iter().copied().filter(|&i| i > j).collect();
            let c = below.len() as u64 + 1;
            nnz += c;
            opc += (c as f64) * (c as f64);
            if let Some(&p) = below.first() {
                for &i in &below[1..] {
                    colsets[p].insert(i);
                }
            }
        }
        (nnz, opc)
    }

    #[test]
    fn tridiagonal_has_no_fill() {
        let g = generators::path(10, 1);
        let o = Ordering::identity(10);
        let s = symbolic_cholesky(&g, &o);
        // L is bidiagonal: 2 per column except the last.
        assert_eq!(s.nnz, 19);
        assert_eq!(s.opc, 9.0 * 4.0 + 1.0);
        assert!((s.fill_ratio - 1.0).abs() < 1e-12);
    }

    #[test]
    fn arrow_matrix_orderings_differ() {
        // Star graph: center first = dense fill; center last = none.
        let mut b = GraphBuilder::new(6);
        for v in 1..6 {
            b.add_edge(0, v);
        }
        let g = b.build().unwrap();
        let center_last = Ordering::from_iperm(vec![1, 2, 3, 4, 5, 0]).unwrap();
        let center_first = Ordering::identity(6);
        let good = symbolic_cholesky(&g, &center_last);
        let bad = symbolic_cholesky(&g, &center_first);
        assert_eq!(good.nnz, 11); // 5 leaf cols of 2 + center col of 1
        assert_eq!(bad.nnz, 21); // full lower triangle
        assert!(bad.opc > good.opc);
    }

    #[test]
    fn matches_brute_force_on_grid() {
        let g = generators::grid2d(5, 5);
        for seed in [1u64, 2, 3] {
            let mut rng = crate::rng::Rng::new(seed);
            let o = Ordering::from_iperm(rng.permutation(25)).unwrap();
            let s = symbolic_cholesky(&g, &o);
            let (nnz, opc) = brute_force(&g, &o);
            assert_eq!(s.nnz, nnz, "seed {seed}");
            assert_eq!(s.opc, opc, "seed {seed}");
        }
    }

    #[test]
    fn matches_brute_force_on_irregular() {
        let g = generators::irregular_mesh(6, 5, 4);
        let mut rng = crate::rng::Rng::new(7);
        let o = Ordering::from_iperm(rng.permutation(30)).unwrap();
        let s = symbolic_cholesky(&g, &o);
        let (nnz, opc) = brute_force(&g, &o);
        assert_eq!(s.nnz, nnz);
        assert_eq!(s.opc, opc);
    }

    #[test]
    fn disconnected_components_are_independent() {
        let mut b = GraphBuilder::new(6);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(3, 4);
        b.add_edge(4, 5);
        let g = b.build().unwrap();
        let s = symbolic_cholesky(&g, &Ordering::identity(6));
        assert_eq!(s.nnz, 2 * 5); // two tridiagonal 3×3 factors
    }

    #[test]
    fn opc_is_at_least_nnz() {
        let g = generators::grid3d(4, 4, 4);
        let o = Ordering::identity(64);
        let s = symbolic_cholesky(&g, &o);
        assert!(s.opc >= s.nnz as f64);
        assert!(s.tree_height >= 1);
        assert!(s.fill_ratio >= 1.0);
    }
}
