//! Degree-bucket lists shared by the minimum-degree orderers.
//!
//! Both [`crate::order::mmd`] and [`crate::order::hamd`] repeatedly need
//! "give me a vertex of minimum (approximate) degree" plus O(1)
//! decrease/increase of any vertex's key — the access pattern degree
//! lists serve exactly (and a binary heap only approximates through
//! lazy deletion and stale-entry purging). The structure is the classic
//! doubly-linked bucket array: `head[d]` chains the vertices currently
//! filed under degree `d`, and a monotone `min` cursor restarts only
//! when an insert undercuts it.

/// Doubly-linked degree buckets over a fixed id universe `0..n`.
///
/// Degrees are clamped to `n` (a degree can never meaningfully exceed
/// the number of other vertices, and the clamp keeps the bucket array
/// bounded). Every operation is O(1) except the min scan, which
/// amortizes over the monotone cursor.
#[derive(Clone, Debug)]
pub struct DegreeLists {
    /// `head[d]` = first vertex filed under degree `d`, or `NIL`.
    head: Vec<i32>,
    /// Forward links of the per-degree chains.
    next: Vec<i32>,
    /// Backward links; `prev[v] < 0` encodes "v heads bucket `-prev-1`".
    prev: Vec<i32>,
    /// Current filed degree of each member (unspecified for absentees).
    deg: Vec<u32>,
    /// Membership flag.
    present: Vec<bool>,
    /// Lower bound on the smallest non-empty bucket.
    min: usize,
    /// Number of filed vertices.
    len: usize,
}

const NIL: i32 = -1;

impl DegreeLists {
    /// Empty lists over the id universe `0..n`.
    pub fn new(n: usize) -> DegreeLists {
        DegreeLists {
            head: vec![NIL; n + 1],
            next: vec![NIL; n],
            prev: vec![NIL; n],
            deg: vec![0; n],
            present: vec![false; n],
            min: 0,
            len: 0,
        }
    }

    /// Number of filed vertices.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Are the lists empty?
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Is `v` currently filed?
    pub fn contains(&self, v: usize) -> bool {
        self.present[v]
    }

    /// File `v` under degree `d` (clamped to `n`). `v` must be absent.
    pub fn insert(&mut self, v: usize, d: usize) {
        debug_assert!(!self.present[v], "insert of filed vertex {v}");
        let d = d.min(self.head.len() - 1);
        let h = self.head[d];
        self.next[v] = h;
        self.prev[v] = -(d as i32) - 1;
        if h != NIL {
            self.prev[h as usize] = v as i32;
        }
        self.head[d] = v as i32;
        self.deg[v] = d as u32;
        self.present[v] = true;
        self.len += 1;
        if d < self.min {
            self.min = d;
        }
    }

    /// Unfile `v`. `v` must be present.
    pub fn remove(&mut self, v: usize) {
        debug_assert!(self.present[v], "remove of absent vertex {v}");
        let (p, nx) = (self.prev[v], self.next[v]);
        if nx != NIL {
            self.prev[nx as usize] = p;
        }
        if p >= 0 {
            self.next[p as usize] = nx;
        } else {
            self.head[(-p - 1) as usize] = nx;
        }
        self.present[v] = false;
        self.len -= 1;
    }

    /// Re-file `v` under degree `d` (insert if absent).
    pub fn update(&mut self, v: usize, d: usize) {
        if self.present[v] {
            if self.deg[v] as usize == d.min(self.head.len() - 1) {
                return;
            }
            self.remove(v);
        }
        self.insert(v, d);
    }

    /// Smallest filed degree, advancing the cursor past empty buckets.
    pub fn min_degree(&mut self) -> Option<usize> {
        if self.len == 0 {
            return None;
        }
        while self.head[self.min] == NIL {
            self.min += 1;
        }
        Some(self.min)
    }

    /// Unfile and return a vertex of minimum degree with its degree.
    pub fn pop_min(&mut self) -> Option<(usize, usize)> {
        let d = self.min_degree()?;
        let v = self.head[d] as usize;
        self.remove(v);
        Some((v, d))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_degree_order() {
        let mut l = DegreeLists::new(5);
        l.insert(0, 3);
        l.insert(1, 1);
        l.insert(2, 2);
        assert_eq!(l.pop_min(), Some((1, 1)));
        assert_eq!(l.pop_min(), Some((2, 2)));
        assert_eq!(l.pop_min(), Some((0, 3)));
        assert_eq!(l.pop_min(), None);
    }

    #[test]
    fn update_moves_between_buckets() {
        let mut l = DegreeLists::new(4);
        l.insert(0, 3);
        l.insert(1, 3);
        l.update(0, 1); // decrease below the cursor
        assert_eq!(l.min_degree(), Some(1));
        assert_eq!(l.pop_min(), Some((0, 1)));
        l.update(1, 2);
        assert_eq!(l.pop_min(), Some((1, 2)));
        assert!(l.is_empty());
    }

    #[test]
    fn update_of_absent_inserts() {
        let mut l = DegreeLists::new(3);
        l.update(2, 0);
        assert!(l.contains(2));
        assert_eq!(l.len(), 1);
    }

    #[test]
    fn remove_from_middle_of_chain() {
        let mut l = DegreeLists::new(4);
        for v in 0..4 {
            l.insert(v, 2);
        }
        l.remove(2); // interior of the bucket-2 chain
        l.remove(3); // head of the chain
        let mut seen = Vec::new();
        while let Some((v, d)) = l.pop_min() {
            assert_eq!(d, 2);
            seen.push(v);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1]);
    }

    #[test]
    fn degrees_clamp_to_universe() {
        let mut l = DegreeLists::new(2);
        l.insert(0, 1_000_000);
        assert_eq!(l.pop_min(), Some((0, 2)));
    }
}
