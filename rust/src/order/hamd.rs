//! Halo approximate minimum degree (HAMD) ordering.
//!
//! The paper couples nested dissection with *halo* approximate minimum
//! degree on the leaves (§3.1, [10]): a leaf subgraph is ordered
//! together with the ring of already-numbered separator vertices around
//! it (the **halo**), so boundary vertices see their true environment —
//! a halo neighbor inflates the degree of the leaf vertices it touches
//! and joins the cliques (elements) their eliminations create, but is
//! itself never selected for elimination (its number lives higher up,
//! in a separator fragment).
//!
//! The engine is a quotient-graph AMD in the Amestoy–Davis–Duff mold
//! (see "Parallelizing the Approximate Minimum Degree Ordering
//! Algorithm", PAPERS.md):
//!
//! * **approximate external degrees** — after eliminating pivot `p`
//!   with element `Lp`, each `i ∈ Lp` gets the ADD bound
//!   `d̂ᵢ = min(active − wᵢ,  d_prev + |Lp \ i|,  |Aᵢ \ Lp| + |Lp \ i|
//!   + Σ_{e ∋ i, e ≠ p} |Lₑ \ Lp|)` — never cheaper than one scan of
//!   `i`'s lists, never a full reach recomputation;
//! * **supervariables** — vertices of `Lp` with identical quotient
//!   adjacency (detected by a commutative hash, confirmed by list
//!   comparison) merge into one supervariable; members are emitted
//!   consecutively when their principal is eliminated;
//! * **element absorption** — the elements adjacent to `p` are absorbed
//!   into the new element, and *aggressive absorption* additionally
//!   swallows any element whose variables all lie in `Lp ∪ {p}`
//!   (`|Lₑ \ Lp| = 0`);
//! * **degree buckets** ([`crate::order::degrees::DegreeLists`]) —
//!   O(1) re-filing under the new approximate degree, no heap.
//!
//! Degrees are counted in *member* units (a supervariable of `k`
//! merged vertices weighs `k`), the count the OPC estimate cares
//! about; input vertex weights play no role at leaf scale.

use super::degrees::DegreeLists;
use crate::graph::Graph;

/// State of one id in the quotient graph.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Node {
    /// Principal supervariable (core or halo), still uneliminated.
    Var,
    /// Variable merged into another supervariable (non-principal).
    Merged,
    /// Eliminated pivot: the id now names an element.
    Elem,
    /// Element absorbed into a newer element.
    Dead,
}

/// Result of a HAMD run: the elimination order of the non-halo
/// vertices, plus the supervariable blocks it was emitted in.
#[derive(Clone, Debug)]
pub struct HamdOrder {
    /// Core (non-halo) vertex ids in elimination sequence — an inverse
    /// permutation fragment over exactly the non-halo vertices.
    pub order: Vec<usize>,
    /// `(start, len)` ranges of `order`, one per eliminated pivot: the
    /// members of one supervariable, emitted consecutively.
    pub blocks: Vec<(usize, usize)>,
}

/// Commutative single-id mixer for the supervariable hash (order of the
/// adjacency lists must not matter, so contributions are summed).
#[inline]
fn mix(x: usize) -> u64 {
    (x as u64 ^ 0xA24B_AED4_963E_E407).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Compute a halo-AMD elimination order of `g`.
///
/// `halo[v]` marks the halo vertices: they contribute to degrees and
/// participate in elements exactly like ordinary variables, but are
/// never selected for elimination and never appear in the result. With
/// an all-`false` halo this is a plain approximate-minimum-degree
/// ordering of the whole graph.
pub fn hamd(g: &Graph, halo: &[bool]) -> HamdOrder {
    let n = g.n();
    debug_assert_eq!(halo.len(), n);
    let ncore = halo.iter().filter(|&&h| !h).count();

    let mut kind = vec![Node::Var; n];
    // Supervariable weights in member units.
    let mut wgt: Vec<i64> = vec![1; n];
    // Quotient adjacency: principal-variable and element lists (both
    // may hold stale ids, purged whenever a list is touched).
    let mut adjv: Vec<Vec<u32>> = (0..n).map(|v| g.neighbors(v).to_vec()).collect();
    let mut adje: Vec<Vec<u32>> = vec![Vec::new(); n];
    // Member variables of each element / merged members of each
    // supervariable.
    let mut evars: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut members: Vec<Vec<u32>> = (0..n).map(|v| vec![v as u32]).collect();
    // Approximate external degree (exact at initialization).
    let mut degree: Vec<i64> = (0..n).map(|v| g.degree(v) as i64).collect();
    let mut hashes: Vec<u64> = vec![0; n];
    // Stamp workspace for Lp membership; `ew`/`etag` hold the per-round
    // |Le \ Lp| counters of the ADD external sum.
    let mut stamp = vec![0u64; n];
    let mut tag = 0u64;
    let mut ew: Vec<i64> = vec![0; n];
    let mut etag = vec![0u64; n];
    let mut eround = 0u64;
    // Total weight of uneliminated variables, core and halo — the
    // `active − wᵢ` term of the degree bound.
    let mut active: i64 = n as i64;

    let mut lists = DegreeLists::new(n);
    for v in 0..n {
        if !halo[v] {
            lists.insert(v, degree[v] as usize);
        }
    }

    let mut order = Vec::with_capacity(ncore);
    let mut blocks = Vec::new();
    while let Some((p, _)) = lists.pop_min() {
        debug_assert_eq!(kind[p], Node::Var);
        debug_assert!(!halo[p]);

        // Lp: the principal variables reachable from p through direct
        // edges and through its adjacent elements (which p absorbs).
        tag += 1;
        stamp[p] = tag;
        let mut lp: Vec<u32> = Vec::new();
        for &u in &adjv[p] {
            let ui = u as usize;
            if kind[ui] == Node::Var && stamp[ui] != tag {
                stamp[ui] = tag;
                lp.push(u);
            }
        }
        for &e in &adje[p] {
            let ei = e as usize;
            if kind[ei] != Node::Elem {
                continue;
            }
            for &u in &evars[ei] {
                let ui = u as usize;
                if kind[ui] == Node::Var && stamp[ui] != tag {
                    stamp[ui] = tag;
                    lp.push(u);
                }
            }
            kind[ei] = Node::Dead; // absorbed into the new element p
            evars[ei] = Vec::new();
        }
        let lp_wgt: i64 = lp.iter().map(|&u| wgt[u as usize]).sum();

        // Eliminate p: emit its members as one consecutive block and
        // publish the new element.
        kind[p] = Node::Elem;
        active -= wgt[p];
        let bstart = order.len();
        for &m in &members[p] {
            order.push(m as usize);
        }
        blocks.push((bstart, order.len() - bstart));
        members[p] = Vec::new();
        adjv[p] = Vec::new();
        adje[p] = Vec::new();
        evars[p] = lp.clone();

        // Round 1 over Lp: set ew[e] = |Le \ Lp| (in weight) for every
        // live element adjacent to Lp, purging lists on the way.
        eround += 1;
        for &i in &lp {
            let ii = i as usize;
            adje[ii].retain(|&e| kind[e as usize] == Node::Elem);
            for &e in &adje[ii] {
                let ei = e as usize;
                if etag[ei] != eround {
                    etag[ei] = eround;
                    evars[ei].retain(|&u| kind[u as usize] == Node::Var);
                    ew[ei] = evars[ei].iter().map(|&u| wgt[u as usize]).sum();
                }
                ew[ei] -= wgt[ii];
            }
        }

        // Round 2 over Lp: approximate degrees, aggressive absorption,
        // adjacency pruning and the supervariable hash.
        for &i in &lp {
            let ii = i as usize;
            let mut hash = mix(p);
            let mut ext_sum: i64 = 0;
            let mut new_adje: Vec<u32> = Vec::with_capacity(adje[ii].len() + 1);
            for &e in &adje[ii] {
                let ei = e as usize;
                if kind[ei] != Node::Elem {
                    continue; // absorbed earlier in this very round
                }
                if ew[ei] <= 0 {
                    // Aggressive absorption: Le ⊆ Lp ∪ {p}, so element
                    // e is redundant next to the new element p.
                    kind[ei] = Node::Dead;
                    evars[ei] = Vec::new();
                    continue;
                }
                ext_sum += ew[ei];
                new_adje.push(e);
                hash = hash.wrapping_add(mix(ei));
            }
            new_adje.push(p as u32);
            adje[ii] = new_adje;

            let mut a_ext: i64 = 0;
            let mut new_adjv: Vec<u32> = Vec::with_capacity(adjv[ii].len());
            for &u in &adjv[ii] {
                let ui = u as usize;
                // Drop eliminated/merged ids and the members of Lp —
                // those are now reachable through element p.
                if kind[ui] != Node::Var || stamp[ui] == tag {
                    continue;
                }
                a_ext += wgt[ui];
                new_adjv.push(u);
                hash = hash.wrapping_add(mix(ui));
            }
            adjv[ii] = new_adjv;

            let ext_p = lp_wgt - wgt[ii]; // |Lp \ i|
            let d = (active - wgt[ii])
                .min(degree[ii] + ext_p)
                .min(a_ext + ext_p + ext_sum)
                .max(0);
            degree[ii] = d;
            hashes[ii] = hash;
            if !halo[ii] {
                lists.update(ii, d as usize);
            }
        }

        // Supervariable detection: equal hash → compare the (pruned)
        // lists; indistinguishable pairs merge. Core merges with core,
        // halo with halo — a halo member must never ride into a core
        // supervariable's emitted block.
        let mut cand: Vec<u32> = lp
            .iter()
            .copied()
            .filter(|&u| kind[u as usize] == Node::Var)
            .collect();
        cand.sort_unstable_by_key(|&u| (hashes[u as usize], u));
        let mut gs = 0;
        while gs < cand.len() {
            let mut ge = gs + 1;
            while ge < cand.len() && hashes[cand[ge] as usize] == hashes[cand[gs] as usize] {
                ge += 1;
            }
            let mut a = gs;
            while a < ge {
                let ii = cand[a] as usize;
                a += 1;
                if kind[ii] != Node::Var {
                    continue;
                }
                adjv[ii].sort_unstable();
                adje[ii].sort_unstable();
                for &cj in &cand[a..ge] {
                    let jj = cj as usize;
                    if kind[jj] != Node::Var || halo[ii] != halo[jj] {
                        continue;
                    }
                    adjv[jj].sort_unstable();
                    adje[jj].sort_unstable();
                    if adjv[ii] != adjv[jj] || adje[ii] != adje[jj] {
                        continue;
                    }
                    // Merge j into i.
                    wgt[ii] += wgt[jj];
                    let mj = std::mem::take(&mut members[jj]);
                    members[ii].extend(mj);
                    kind[jj] = Node::Merged;
                    adjv[jj] = Vec::new();
                    adje[jj] = Vec::new();
                    degree[ii] = (degree[ii] - wgt[jj]).max(0);
                    if !halo[jj] {
                        lists.remove(jj);
                    }
                }
                if !halo[ii] {
                    lists.update(ii, degree[ii] as usize);
                }
            }
            gs = ge;
        }
    }

    debug_assert_eq!(order.len(), ncore, "HAMD must emit every core vertex");
    HamdOrder { order, blocks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, GraphBuilder};
    use crate::order::{symbolic_cholesky, Ordering};

    fn no_halo(n: usize) -> Vec<bool> {
        vec![false; n]
    }

    fn order_of(g: &Graph) -> Ordering {
        Ordering::from_iperm(hamd(g, &no_halo(g.n())).order).unwrap()
    }

    #[test]
    fn orders_every_vertex_once() {
        let g = generators::grid2d(9, 9);
        order_of(&g).validate().unwrap();
    }

    #[test]
    fn path_has_no_fill() {
        let g = generators::path(60, 1);
        let s = symbolic_cholesky(&g, &order_of(&g));
        assert_eq!(s.nnz, 119);
    }

    #[test]
    fn tree_has_no_fill() {
        let mut b = GraphBuilder::new(31);
        for v in 1..31 {
            b.add_edge(v, (v - 1) / 2);
        }
        let g = b.build().unwrap();
        let s = symbolic_cholesky(&g, &order_of(&g));
        assert_eq!(s.nnz, 61);
    }

    #[test]
    fn clique_fill_is_exact() {
        let g = generators::complete(12);
        let s = symbolic_cholesky(&g, &order_of(&g));
        assert_eq!(s.nnz, (12 * 13 / 2) as u64);
    }

    #[test]
    fn handles_disconnected_and_isolated() {
        let mut b = GraphBuilder::new(7);
        b.add_edge(0, 1);
        b.add_edge(2, 3);
        b.add_edge(3, 4);
        let g = b.build().unwrap();
        order_of(&g).validate().unwrap();
    }

    #[test]
    fn halo_vertices_are_never_emitted() {
        // Path 0-1-2-3-4 with {0, 4} as halo: only 1,2,3 are ordered.
        let g = generators::path(5, 1);
        let halo = vec![true, false, false, false, true];
        let r = hamd(&g, &halo);
        let mut got = r.order.clone();
        got.sort_unstable();
        assert_eq!(got, vec![1, 2, 3]);
    }

    #[test]
    fn halo_degree_pushes_boundary_vertices_later() {
        // Star with hub 0 and leaves 1..=6, where leaf 1 additionally
        // touches a 3-clique of halo vertices: its halo-aware degree
        // (4) exceeds every other leaf's (1), so it must not be
        // eliminated first.
        let mut b = GraphBuilder::new(10);
        for v in 1..=6 {
            b.add_edge(0, v);
        }
        for h in 7..10 {
            b.add_edge(1, h);
            for h2 in (h + 1)..10 {
                b.add_edge(h, h2);
            }
        }
        let g = b.build().unwrap();
        let mut halo = vec![false; 10];
        for h in 7..10 {
            halo[h] = true;
        }
        let r = hamd(&g, &halo);
        assert_ne!(r.order[0], 1, "halo-loaded leaf eliminated first");
        let mut got = r.order.clone();
        got.sort_unstable();
        assert_eq!(got, (0..=6).collect::<Vec<_>>());
    }

    #[test]
    fn indistinguishable_twins_emit_consecutively() {
        // Vertices 0 and 1 both see exactly {2, 3, 4} (and not each
        // other): after the first pivot among {2,3,4} they hash equal,
        // merge, and must occupy consecutive positions.
        let mut b = GraphBuilder::new(5);
        for t in [0usize, 1] {
            for u in [2usize, 3, 4] {
                b.add_edge(t, u);
            }
        }
        b.add_edge(2, 3);
        let g = b.build().unwrap();
        let r = hamd(&g, &no_halo(5));
        let pos0 = r.order.iter().position(|&v| v == 0).unwrap();
        let pos1 = r.order.iter().position(|&v| v == 1).unwrap();
        assert_eq!(
            pos0.abs_diff(pos1),
            1,
            "twins split apart: {:?}",
            r.order
        );
        assert!(
            r.blocks.iter().any(|&(_, len)| len >= 2),
            "no supervariable block was formed: {:?}",
            r.blocks
        );
    }

    #[test]
    fn blocks_tile_the_order() {
        let g = generators::irregular_mesh(10, 8, 3);
        let r = hamd(&g, &no_halo(g.n()));
        let mut covered = 0;
        for &(s, l) in &r.blocks {
            assert_eq!(s, covered, "blocks out of sequence");
            assert!(l >= 1);
            covered += l;
        }
        assert_eq!(covered, g.n());
    }

    #[test]
    fn quality_tracks_exact_minimum_degree_on_grid() {
        let g = generators::grid2d(14, 14);
        let s_amd = symbolic_cholesky(&g, &order_of(&g));
        let md = Ordering::from_iperm(crate::order::mmd::minimum_degree(&g)).unwrap();
        let s_md = symbolic_cholesky(&g, &md);
        assert!(
            s_amd.opc <= s_md.opc * 1.10,
            "AMD opc {} vs exact MD {}",
            s_amd.opc,
            s_md.opc
        );
    }

    #[test]
    fn deterministic() {
        let g = generators::irregular_mesh(12, 12, 9);
        let a = hamd(&g, &no_halo(g.n()));
        let b = hamd(&g, &no_halo(g.n()));
        assert_eq!(a.order, b.order);
        assert_eq!(a.blocks, b.blocks);
    }
}
