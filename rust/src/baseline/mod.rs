//! ParMETIS-like baseline orderer (S17).
//!
//! The paper's comparator degrades with process count for identifiable
//! reasons, all of which this baseline reproduces faithfully (DESIGN.md
//! §3):
//!
//! * **power-of-two only** — "its folding algorithm requires the number
//!   of sending processes to be even, such that the parallel graph
//!   ordering routine of ParMETIS can only work on numbers of processes
//!   which are powers of two" (§3.2). [`parmetis_like_order`] returns
//!   [`Error::NonPowerOfTwo`] otherwise;
//! * **folding without duplication** — a single working copy of the
//!   coarsest graph (on rank 0 here, the degenerate fold), so no
//!   best-of-k selection among independent multilevel runs;
//! * **strictly-improving parallel refinement** — "only moves that
//!   strictly improve the partition are allowed, which hinders the
//!   ability of the FM algorithm to escape from local minima … and leads
//!   to severe loss of partition quality when the number of processes
//!   (and thus of potential remote neighbors) increases" (§3.3). The
//!   [`pmrefine`] pass additionally refuses moves whose pulled set spans
//!   processes — the communication-avoidance that creates the
//!   p-dependence.

pub mod pmrefine;

use crate::comm::{Comm, MemTracker};
use crate::dist::coarsen::{coarsen_dist, DistCoarsening};
use crate::dist::dgraph::DGraph;
use crate::dist::dnd::ParallelOrderResult;
use crate::dist::matching::parallel_match;
use crate::graph::Graph;
use crate::order::OrderFragment;
use crate::rng::Rng;
use crate::sep::{multilevel_separator, FmRefiner};
use crate::strategy::Strategy;
use crate::{Error, Result};

/// Order `g` with the ParMETIS-like parallel nested dissection.
/// Collective; fails unless `comm.size()` is a power of two.
///
/// Reuses the shared dissection driver of [`crate::dist::dnd`] — the
/// engines differ only in the separator policy (and the baseline never
/// overlaps the induced-subgraph builds), exactly how the paper frames
/// the comparison.
pub fn parmetis_like_order(
    comm: &Comm,
    g: &Graph,
    strat: &Strategy,
) -> Result<ParallelOrderResult> {
    let p = comm.size();
    if !p.is_power_of_two() {
        return Err(Error::NonPowerOfTwo(p));
    }
    let mem = MemTracker::new();
    let dg = DGraph::from_global(comm, g);
    mem.grow(dg.footprint_bytes());
    let payload: Vec<u64> = (0..dg.nloc()).map(|v| dg.glb(v)).collect();
    let base_rng = Rng::new(strat.seed);
    let mut frags: Vec<OrderFragment> = Vec::new();
    let mut dist_levels = 0usize;
    let leaf_refiner = FmRefiner {
        params: strat.sep.fm.clone(),
    };
    let separator = |c: &Comm, d: &DGraph, r: &Rng, m: &MemTracker| {
        baseline_separator(c, d, strat, r, m)
    };
    crate::dist::dnd::dissect(
        comm,
        dg,
        payload,
        0,
        strat,
        &leaf_refiner,
        &separator,
        false, // the comparator does not overlap the induced builds
        &base_rng,
        &mem,
        &mut frags,
        &mut dist_levels,
        0,
    );
    let ordering = crate::dist::dnd::gather_and_assemble(comm, g.n(), &frags)?;
    Ok(ParallelOrderResult {
        ordering,
        peak_mem: mem.peak(),
        dist_levels,
    })
}

/// Baseline distributed separator: parallel coarsening, single working
/// copy on rank 0 (fold without duplication), sequential initial
/// separator there, then uncoarsening with strictly-improving parallel
/// refinement only — no band graphs, no multi-sequential best-pick.
fn baseline_separator(
    comm: &Comm,
    dg: &DGraph,
    strat: &Strategy,
    base_rng: &Rng,
    mem: &MemTracker,
) -> Vec<u8> {
    let p = comm.size();
    let stop_at = (strat.dist.folddup_threshold * p).max(2 * strat.sep.coarse_target) as u64;
    let mut levels: Vec<(DGraph, DistCoarsening)> = Vec::new();
    let mut cur = dg.clone();
    let mut round = 0u64;
    while cur.nglb > stop_at {
        let mut rng = base_rng.derive(0xBA5E ^ round ^ ((comm.global_rank() as u64) << 40));
        let mate = parallel_match(comm, &cur, strat.dist.matching_rounds, &mut rng);
        let dc = coarsen_dist(comm, &cur, &mate);
        if dc.coarse.nglb as f64 > cur.nglb as f64 * 0.95 {
            break;
        }
        mem.grow(dc.coarse.footprint_bytes());
        let prev = std::mem::replace(&mut cur, dc.coarse.clone());
        levels.push((prev, dc));
        round += 1;
    }
    // Single working copy: rank 0 computes, everyone receives.
    let central = cur.centralize_all(comm);
    mem.grow(central.footprint_bytes());
    let seps: Vec<u8> = if comm.rank() == 0 {
        let mut rng = base_rng.derive(0x0E11);
        let refiner = FmRefiner {
            params: strat.sep.fm.clone(),
        };
        let state = multilevel_separator(&central, &strat.sep, &refiner, &mut rng);
        comm.bcast(0, Some(state.part.clone()))
    } else {
        comm.bcast(0, None)
    };
    mem.shrink(central.footprint_bytes());
    let mut part: Vec<u8> = (0..cur.nloc())
        .map(|v| seps[cur.glb(v) as usize])
        .collect();
    // Uncoarsen with strictly-improving parallel refinement only.
    for (fine, dc) in levels.iter().rev() {
        let coarse_part = part;
        part = dc.coarse.fetch_at(comm, &dc.fine2coarse, &coarse_part);
        pmrefine::strict_refine(comm, fine, &mut part, &strat.sep.fm, 8);
    }
    part
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm;
    use crate::graph::generators;
    use crate::order::symbolic_cholesky;
    use std::sync::Arc;

    #[test]
    fn rejects_non_power_of_two() {
        let g = Arc::new(generators::grid2d(10, 10));
        let (res, _) = comm::run(3, move |c| {
            let strat = Strategy::default();
            matches!(
                parmetis_like_order(&c, &g, &strat),
                Err(Error::NonPowerOfTwo(3))
            )
        });
        assert!(res.iter().all(|&ok| ok));
    }

    #[test]
    fn orders_validly_on_pow2() {
        let g = Arc::new(generators::grid2d(20, 20));
        let gref = g.clone();
        let (res, _) = comm::run(4, move |c| {
            let strat = Strategy::default();
            parmetis_like_order(&c, &g, &strat).unwrap().ordering
        });
        for o in &res {
            o.validate().unwrap();
            assert_eq!(o.iperm, res[0].iperm);
        }
        let s = symbolic_cholesky(&gref, &res[0]);
        assert!(s.opc > 0.0);
    }

    #[test]
    fn ptscotch_beats_baseline_at_p8() {
        // The paper's headline claim, in miniature: at higher process
        // counts PT-Scotch orders at least as well as the ParMETIS-like
        // flow.
        let g = Arc::new(generators::grid2d(30, 30));
        let gref = g.clone();
        let (res, _) = comm::run(8, move |c| {
            let strat = Strategy::default();
            let pm = parmetis_like_order(&c, &g, &strat).unwrap().ordering;
            let refiner = FmRefiner::default();
            let pts = crate::dist::parallel_order(&c, &g, &strat, &refiner, None).ordering;
            (pm, pts)
        });
        let (pm, pts) = &res[0];
        let s_pm = symbolic_cholesky(&gref, pm);
        let s_pts = symbolic_cholesky(&gref, pts);
        // Allow slack — on tiny instances the gap is noisy — but the
        // baseline must not win by a large margin.
        assert!(
            s_pts.opc <= s_pm.opc * 1.15,
            "PTS {} vs PM {}",
            s_pts.opc,
            s_pm.opc
        );
    }
}
