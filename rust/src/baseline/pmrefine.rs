//! Strictly-improving parallel separator refinement — the ParMETIS-style
//! pass the paper describes in §3.3: "in order to relax the strong
//! sequential constraint that would require some communication every
//! time a vertex to be migrated has neighbors on other processes, only
//! moves that strictly improve the partition are allowed".
//!
//! Mechanics: rounds alternate a single target part (all movers go the
//! same way, so no 0–1 edge can appear between two movers); a separator
//! vertex moves only if (a) its gain is strictly positive, (b) balance
//! permits, and (c) none of the vertices it would pull into the
//! separator lives on another process (the communication-avoidance that
//! makes quality decay as the number of remote neighbors grows with P).

use crate::comm::Comm;
use crate::dist::dgraph::DGraph;
use crate::sep::fm::FmParams;
use crate::sep::SEP;

/// Run up to `max_rounds` strictly-improving rounds; stops after two
/// consecutive rounds without global improvement. Collective.
pub fn strict_refine(
    comm: &Comm,
    dg: &DGraph,
    part: &mut [u8],
    fm: &FmParams,
    max_rounds: usize,
) {
    let nloc = dg.nloc();
    let ghost_vwgt = dg.halo_exchange(comm, &dg.vwgt);
    let total: i64 = comm.allreduce_sum(dg.vwgt.iter().sum());
    let max_vwgt = comm.allreduce(dg.vwgt.iter().copied().max().unwrap_or(0), i64::max);
    let max_imb = ((fm.balance_eps * total as f64) as i64).max(2 * max_vwgt);

    let mut stale = 0usize;
    for round in 0..max_rounds {
        let to: u8 = (round % 2) as u8;
        let other = 1 - to;
        let ghost_part = dg.halo_exchange(comm, &part.to_vec());
        // Global weights at round start.
        let mut w = [0i64; 3];
        for v in 0..nloc {
            w[part[v] as usize] += dg.vwgt[v];
        }
        let w = [
            comm.allreduce_sum(w[0]),
            comm.allreduce_sum(w[1]),
            comm.allreduce_sum(w[2]),
        ];
        let sep_before = w[2];

        // Budget: how much weight may move into `to` this round while
        // respecting balance (conservative, computed once).
        let mut budget = max_imb - (w[to as usize] - w[other as usize]);

        // Collect strictly-improving local-only moves.
        let mut pulled_remote: Vec<Vec<u64>> = vec![Vec::new(); comm.size()];
        let mut moved_any = false;
        for v in 0..nloc {
            if part[v] != SEP {
                continue;
            }
            let mut pulled_w = 0i64;
            let mut remote_pull = false;
            for &cid in dg.neighbors_gst(v) {
                let c = cid as usize;
                let (pu, wu) = if c < nloc {
                    (part[c], dg.vwgt[c])
                } else {
                    (ghost_part[c - nloc], ghost_vwgt[c - nloc])
                };
                if pu == other {
                    pulled_w += wu;
                    if c >= nloc {
                        remote_pull = true;
                    }
                }
            }
            let gain = dg.vwgt[v] - pulled_w;
            if gain <= 0 || remote_pull {
                continue; // not strictly improving, or needs communication
            }
            if budget - 2 * dg.vwgt[v] < -max_imb {
                continue; // would overshoot balance
            }
            // Apply: v joins `to`, local pulled neighbors join SEP.
            part[v] = to;
            budget -= 2 * dg.vwgt[v];
            moved_any = true;
            for &cid in dg.neighbors_gst(v) {
                let c = cid as usize;
                if c < nloc {
                    if part[c] == other {
                        part[c] = SEP;
                    }
                } else if ghost_part[c - nloc] == other {
                    // Cannot happen: remote pulls were rejected above.
                    pulled_remote[dg.owner(dg.ghosts[c - nloc])].push(dg.ghosts[c - nloc]);
                }
            }
        }
        debug_assert!(pulled_remote.iter().all(|b| b.is_empty()));
        let _ = moved_any;

        // Global improvement check.
        let mut ws = 0i64;
        for v in 0..nloc {
            if part[v] == SEP {
                ws += dg.vwgt[v];
            }
        }
        let sep_after = comm.allreduce_sum(ws);
        if sep_after >= sep_before {
            stale += 1;
            if stale >= 2 {
                break;
            }
        } else {
            stale = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm;
    use crate::dist::dsep::dist_validate_separator;
    use crate::graph::generators;
    use crate::sep::{SepState, P0, P1};
    use std::sync::Arc;

    #[test]
    fn strict_refine_keeps_invariant_and_improves_or_keeps() {
        let nx = 14;
        let g = Arc::new(generators::grid2d(nx, 10));
        let gref = g.clone();
        let (res, _) = comm::run(4, move |c| {
            let dg = DGraph::from_global(&c, &g);
            // Wide initial separator: two columns.
            let mut part: Vec<u8> = (0..dg.nloc())
                .map(|v| {
                    let x = dg.glb(v) as usize % nx;
                    if x < 6 {
                        P0
                    } else if x == 6 || x == 7 {
                        SEP
                    } else {
                        P1
                    }
                })
                .collect();
            strict_refine(&c, &dg, &mut part, &FmParams::default(), 8);
            assert!(dist_validate_separator(&c, &dg, &part));
            (dg.base(), part)
        });
        let mut full = vec![0u8; gref.n()];
        for (base, lp) in &res {
            for (i, &x) in lp.iter().enumerate() {
                full[*base as usize + i] = x;
            }
        }
        let state = SepState::from_parts(&gref, full);
        state.validate(&gref).unwrap();
        // Strict improvement from a 2-column separator must shrink it.
        assert!(state.sep_weight() <= 20, "sep {}", state.sep_weight());
    }

    #[test]
    fn leaves_optimal_separator_alone() {
        let nx = 9;
        let g = Arc::new(generators::grid2d(nx, 7));
        let (res, _) = comm::run(2, move |c| {
            let dg = DGraph::from_global(&c, &g);
            let mut part: Vec<u8> = (0..dg.nloc())
                .map(|v| {
                    let x = dg.glb(v) as usize % nx;
                    use std::cmp::Ordering::*;
                    match x.cmp(&4) {
                        Less => P0,
                        Equal => SEP,
                        Greater => P1,
                    }
                })
                .collect();
            let before = part.clone();
            strict_refine(&c, &dg, &mut part, &FmParams::default(), 6);
            part == before
        });
        assert!(res.iter().all(|&same| same), "optimal column must be stable");
    }

    #[test]
    fn more_ranks_refine_less() {
        // The degradation mechanism: with more ranks, more pulls are
        // remote, so fewer moves are permitted. Compare separator weight
        // after refinement from the same bad start at p=2 vs p=8.
        let nx = 16;
        let run_at = |p: usize| {
            let g = Arc::new(generators::grid2d(nx, 12));
            let gref = g.clone();
            let (res, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let mut part: Vec<u8> = (0..dg.nloc())
                    .map(|v| {
                        let x = dg.glb(v) as usize % nx;
                        if x < 7 {
                            P0
                        } else if x <= 9 {
                            SEP
                        } else {
                            P1
                        }
                    })
                    .collect();
                strict_refine(&c, &dg, &mut part, &FmParams::default(), 8);
                (dg.base(), part)
            });
            let mut full = vec![0u8; gref.n()];
            for (base, lp) in &res {
                for (i, &x) in lp.iter().enumerate() {
                    full[*base as usize + i] = x;
                }
            }
            SepState::from_parts(&gref, full).sep_weight()
        };
        let w2 = run_at(2);
        let w8 = run_at(8);
        assert!(w8 >= w2, "p=8 ({w8}) should refine no better than p=2 ({w2})");
    }
}
