//! Centralized graph substrate (S1): compressed-sparse-row graphs with
//! vertex and edge weights, as used by the sequential Scotch-like pipeline
//! and as the per-process fragment representation of the distributed layer.

pub mod builder;
pub mod generators;
pub mod induced;
pub mod io;

pub use builder::GraphBuilder;
pub use induced::{induce_with_halo, HaloInduced, InducedGraph};

use crate::{Error, Result};

/// An undirected weighted graph in CSR form.
///
/// Invariants (checked by [`Graph::validate`]):
/// * `xadj.len() == n + 1`, `xadj[0] == 0`, non-decreasing;
/// * every `adj` entry is `< n` and never equal to its own vertex;
/// * adjacency is symmetric with matching edge weights;
/// * `vwgt` and `ewgt` are strictly positive.
#[derive(Clone, Debug, Default)]
pub struct Graph {
    /// Per-vertex adjacency start offsets; length `n + 1`.
    pub xadj: Vec<usize>,
    /// Concatenated neighbor lists; length `2·m` (each edge stored twice).
    pub adj: Vec<u32>,
    /// Vertex weights (coarsened vertices accumulate weight).
    pub vwgt: Vec<i64>,
    /// Edge weights, parallel to `adj` (collapsed edges accumulate weight).
    pub ewgt: Vec<i64>,
}

impl Graph {
    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.vwgt.len()
    }

    /// Number of undirected edges.
    #[inline]
    pub fn m(&self) -> usize {
        self.adj.len() / 2
    }

    /// Number of directed arcs (`2·m`).
    #[inline]
    pub fn arcs(&self) -> usize {
        self.adj.len()
    }

    /// Neighbor list of `v`.
    #[inline]
    pub fn neighbors(&self, v: usize) -> &[u32] {
        &self.adj[self.xadj[v]..self.xadj[v + 1]]
    }

    /// Edge weights parallel to [`Graph::neighbors`].
    #[inline]
    pub fn edge_weights(&self, v: usize) -> &[i64] {
        &self.ewgt[self.xadj[v]..self.xadj[v + 1]]
    }

    /// Degree of `v`.
    #[inline]
    pub fn degree(&self, v: usize) -> usize {
        self.xadj[v + 1] - self.xadj[v]
    }

    /// Sum of all vertex weights.
    pub fn total_vwgt(&self) -> i64 {
        self.vwgt.iter().sum()
    }

    /// Maximum vertex weight (0 for the empty graph).
    pub fn max_vwgt(&self) -> i64 {
        self.vwgt.iter().copied().max().unwrap_or(0)
    }

    /// Maximum degree (0 for the empty graph).
    pub fn max_degree(&self) -> usize {
        (0..self.n()).map(|v| self.degree(v)).max().unwrap_or(0)
    }

    /// Average degree.
    pub fn avg_degree(&self) -> f64 {
        if self.n() == 0 {
            0.0
        } else {
            self.arcs() as f64 / self.n() as f64
        }
    }

    /// Build an unweighted graph (unit vertex and edge weights) from CSR
    /// arrays. The arrays are validated.
    pub fn from_csr(xadj: Vec<usize>, adj: Vec<u32>) -> Result<Self> {
        let n = xadj.len().saturating_sub(1);
        let g = Graph {
            vwgt: vec![1; n],
            ewgt: vec![1; adj.len()],
            xadj,
            adj,
        };
        g.validate()?;
        Ok(g)
    }

    /// Build a weighted graph from CSR arrays, with validation.
    pub fn from_csr_weighted(
        xadj: Vec<usize>,
        adj: Vec<u32>,
        vwgt: Vec<i64>,
        ewgt: Vec<i64>,
    ) -> Result<Self> {
        let g = Graph {
            xadj,
            adj,
            vwgt,
            ewgt,
        };
        g.validate()?;
        Ok(g)
    }

    /// Approximate heap footprint in bytes (used by the per-rank memory
    /// tracking that reproduces Figures 10–11).
    pub fn footprint_bytes(&self) -> usize {
        self.xadj.len() * std::mem::size_of::<usize>()
            + self.adj.len() * std::mem::size_of::<u32>()
            + self.vwgt.len() * std::mem::size_of::<i64>()
            + self.ewgt.len() * std::mem::size_of::<i64>()
    }

    /// Full structural validation of the CSR invariants.
    pub fn validate(&self) -> Result<()> {
        let n = self.n();
        if self.xadj.len() != n + 1 {
            return Err(Error::InvalidGraph(format!(
                "xadj.len() = {} but n + 1 = {}",
                self.xadj.len(),
                n + 1
            )));
        }
        if self.xadj[0] != 0 || *self.xadj.last().unwrap() != self.adj.len() {
            return Err(Error::InvalidGraph("xadj bounds mismatch".into()));
        }
        if self.ewgt.len() != self.adj.len() {
            return Err(Error::InvalidGraph("ewgt length mismatch".into()));
        }
        for v in 0..n {
            if self.xadj[v] > self.xadj[v + 1] {
                return Err(Error::InvalidGraph(format!("xadj decreasing at {v}")));
            }
            if self.vwgt[v] <= 0 {
                return Err(Error::InvalidGraph(format!("vwgt[{v}] <= 0")));
            }
        }
        for (i, &u) in self.adj.iter().enumerate() {
            if (u as usize) >= n {
                return Err(Error::InvalidGraph(format!("adj[{i}] = {u} out of range")));
            }
            if self.ewgt[i] <= 0 {
                return Err(Error::InvalidGraph(format!("ewgt[{i}] <= 0")));
            }
        }
        for v in 0..n {
            for (&u, &w) in self.neighbors(v).iter().zip(self.edge_weights(v)) {
                let u = u as usize;
                if u == v {
                    return Err(Error::InvalidGraph(format!("self-loop at {v}")));
                }
                // Symmetry: v must appear in u's list with the same weight.
                let pos = self.neighbors(u).iter().position(|&x| x as usize == v);
                match pos {
                    None => {
                        return Err(Error::InvalidGraph(format!(
                            "edge {v}->{u} has no reverse arc"
                        )))
                    }
                    Some(k) => {
                        if self.ewgt[self.xadj[u] + k] != w {
                            return Err(Error::InvalidGraph(format!(
                                "edge weight mismatch on {v}<->{u}"
                            )));
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Connected components; returns `(component id per vertex, count)`.
    pub fn components(&self) -> (Vec<u32>, usize) {
        let n = self.n();
        let mut comp = vec![u32::MAX; n];
        let mut nc = 0usize;
        let mut stack = Vec::new();
        for s in 0..n {
            if comp[s] != u32::MAX {
                continue;
            }
            comp[s] = nc as u32;
            stack.push(s);
            while let Some(v) = stack.pop() {
                for &u in self.neighbors(v) {
                    let u = u as usize;
                    if comp[u] == u32::MAX {
                        comp[u] = nc as u32;
                        stack.push(u);
                    }
                }
            }
            nc += 1;
        }
        (comp, nc)
    }

    /// BFS distances from a set of sources, cut off at `max_dist`
    /// (unreached vertices get `u32::MAX`). This is the reference
    /// implementation of the band-membership computation; the XLA min-plus
    /// kernel reproduces it on packed band graphs.
    pub fn multi_source_bfs(&self, sources: &[usize], max_dist: u32) -> Vec<u32> {
        let mut dist = vec![u32::MAX; self.n()];
        let mut frontier: Vec<usize> = Vec::with_capacity(sources.len());
        for &s in sources {
            if dist[s] == u32::MAX {
                dist[s] = 0;
                frontier.push(s);
            }
        }
        let mut next = Vec::new();
        let mut d = 0;
        while !frontier.is_empty() && d < max_dist {
            d += 1;
            for &v in &frontier {
                for &u in self.neighbors(v) {
                    let u = u as usize;
                    if dist[u] == u32::MAX {
                        dist[u] = d;
                        next.push(u);
                    }
                }
            }
            std::mem::swap(&mut frontier, &mut next);
            next.clear();
        }
        dist
    }

    /// A pseudo-peripheral vertex: start anywhere, repeatedly jump to the
    /// farthest vertex of a BFS until eccentricity stops growing.
    pub fn pseudo_peripheral(&self, start: usize) -> usize {
        let mut v = start;
        let mut ecc = 0u32;
        for _ in 0..8 {
            let dist = self.multi_source_bfs(&[v], u32::MAX);
            let (far, fd) = dist
                .iter()
                .enumerate()
                .filter(|(_, &d)| d != u32::MAX)
                .max_by_key(|(_, &d)| d)
                .map(|(i, &d)| (i, d))
                .unwrap_or((v, 0));
            if fd <= ecc {
                break;
            }
            ecc = fd;
            v = far;
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 0 - 1 - 2 path.
    fn path3() -> Graph {
        Graph::from_csr(vec![0, 1, 3, 4], vec![1, 0, 2, 1]).unwrap()
    }

    #[test]
    fn basic_accessors() {
        let g = path3();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 2);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.degree(1), 2);
        assert_eq!(g.total_vwgt(), 3);
        assert_eq!(g.max_degree(), 2);
        assert!((g.avg_degree() - 4.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn validate_rejects_asymmetric() {
        let g = Graph {
            xadj: vec![0, 1, 1],
            adj: vec![1],
            vwgt: vec![1, 1],
            ewgt: vec![1],
        };
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_rejects_self_loop() {
        let g = Graph {
            xadj: vec![0, 1],
            adj: vec![0],
            vwgt: vec![1],
            ewgt: vec![1],
        };
        assert!(g.validate().is_err());
    }

    #[test]
    fn validate_rejects_weight_mismatch() {
        let g = Graph {
            xadj: vec![0, 1, 2],
            adj: vec![1, 0],
            vwgt: vec![1, 1],
            ewgt: vec![2, 3],
        };
        assert!(g.validate().is_err());
    }

    #[test]
    fn components_of_disconnected() {
        // Two disjoint edges: 0-1, 2-3.
        let g = Graph::from_csr(vec![0, 1, 2, 3, 4], vec![1, 0, 3, 2]).unwrap();
        let (comp, nc) = g.components();
        assert_eq!(nc, 2);
        assert_eq!(comp[0], comp[1]);
        assert_eq!(comp[2], comp[3]);
        assert_ne!(comp[0], comp[2]);
    }

    #[test]
    fn bfs_distances() {
        let g = path3();
        let d = g.multi_source_bfs(&[0], u32::MAX);
        assert_eq!(d, vec![0, 1, 2]);
        let d = g.multi_source_bfs(&[0], 1);
        assert_eq!(d, vec![0, 1, u32::MAX]);
        let d = g.multi_source_bfs(&[0, 2], u32::MAX);
        assert_eq!(d, vec![0, 1, 0]);
    }

    #[test]
    fn pseudo_peripheral_on_path() {
        let g = path3();
        let p = g.pseudo_peripheral(1);
        assert!(p == 0 || p == 2);
    }

    #[test]
    fn footprint_positive() {
        assert!(path3().footprint_bytes() > 0);
    }
}
