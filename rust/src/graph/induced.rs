//! Induced subgraphs with original-vertex maps.
//!
//! Nested dissection recurses on the subgraphs induced by the two
//! separated parts; each carries `orig`, the map from subgraph-local
//! vertex ids back to the ids of the parent graph, so leaf orderings can
//! be assembled into the global inverse permutation (paper §2.2).

use super::{Graph, GraphBuilder};
use std::collections::HashMap;

/// A subgraph plus the map back to the parent graph's vertex ids.
#[derive(Clone, Debug)]
pub struct InducedGraph {
    /// The induced subgraph.
    pub graph: Graph,
    /// `orig[local] = parent-graph vertex id`.
    pub orig: Vec<usize>,
}

impl InducedGraph {
    /// Build the subgraph induced by the vertices where `keep(v)` is true.
    ///
    /// Edge and vertex weights are carried over; edges with one endpoint
    /// outside the kept set are dropped.
    pub fn build(g: &Graph, keep: impl Fn(usize) -> bool) -> InducedGraph {
        let n = g.n();
        let mut local = vec![u32::MAX; n];
        let mut orig = Vec::new();
        for v in 0..n {
            if keep(v) {
                local[v] = orig.len() as u32;
                orig.push(v);
            }
        }
        let nl = orig.len();
        let mut xadj = Vec::with_capacity(nl + 1);
        xadj.push(0usize);
        let mut adj = Vec::new();
        let mut ewgt = Vec::new();
        let mut vwgt = Vec::with_capacity(nl);
        for &ov in &orig {
            for (&u, &w) in g.neighbors(ov).iter().zip(g.edge_weights(ov)) {
                let lu = local[u as usize];
                if lu != u32::MAX {
                    adj.push(lu);
                    ewgt.push(w);
                }
            }
            xadj.push(adj.len());
            vwgt.push(g.vwgt[ov]);
        }
        InducedGraph {
            graph: Graph {
                xadj,
                adj,
                vwgt,
                ewgt,
            },
            orig,
        }
    }

    /// Number of vertices in the induced subgraph.
    pub fn n(&self) -> usize {
        self.graph.n()
    }
}

/// A subgraph induced by a core vertex set **plus its one-ring halo**:
/// the out-of-core neighbors of the core, appended after the core
/// vertices. Built by [`induce_with_halo`] for halo-aware leaf ordering
/// (`order::hamd`): in nested dissection the ring around a leaf
/// consists exactly of already-numbered separator vertices, which HAMD
/// must see but never order.
#[derive(Clone, Debug)]
pub struct HaloInduced {
    /// The induced subgraph: core vertices first (`0..n_core`, in the
    /// order the core list gave them), halo vertices after.
    pub graph: Graph,
    /// `orig[local] = parent-graph vertex id`, core then halo.
    pub orig: Vec<usize>,
    /// Number of core vertices; `n_core..graph.n()` are the halo.
    pub n_core: usize,
}

impl HaloInduced {
    /// Per-vertex halo mask (`true` for the appended ring vertices) in
    /// the shape [`crate::order::hamd::hamd`] consumes.
    pub fn halo_mask(&self) -> Vec<bool> {
        (0..self.graph.n()).map(|v| v >= self.n_core).collect()
    }
}

/// Build the subgraph induced by the `core` vertices of `g` together
/// with their one-ring halo.
///
/// Core vertices keep the order of the `core` slice (local id `i` is
/// `core[i]`); every non-core neighbor of a core vertex becomes a halo
/// vertex appended after the core block. Core–core and core–halo edges
/// are carried over with their weights; **halo–halo edges are
/// dropped** — halo vertices are never eliminated, so edges among them
/// can influence no core degree and no element.
pub fn induce_with_halo(g: &Graph, core: &[usize]) -> HaloInduced {
    let n_core = core.len();
    let mut local: HashMap<usize, u32> = HashMap::with_capacity(n_core * 2);
    let mut orig: Vec<usize> = core.to_vec();
    for (i, &cv) in core.iter().enumerate() {
        local.insert(cv, i as u32);
    }
    debug_assert_eq!(local.len(), n_core, "duplicate core vertex");
    for &cv in core {
        for &u in g.neighbors(cv) {
            let u = u as usize;
            if let std::collections::hash_map::Entry::Vacant(slot) = local.entry(u) {
                slot.insert(orig.len() as u32);
                orig.push(u);
            }
        }
    }
    let mut b = GraphBuilder::new(orig.len());
    for (i, &ov) in orig.iter().enumerate() {
        b.set_vwgt(i, g.vwgt[ov]);
    }
    for (lv, &cv) in core.iter().enumerate() {
        for (&u, &w) in g.neighbors(cv).iter().zip(g.edge_weights(cv)) {
            let lu = local[&(u as usize)] as usize;
            // Core–core edges are seen from both endpoints: add once.
            // Core–halo edges are seen from the core side only.
            if lu >= n_core || lu > lv {
                b.add_edge_w(lv, lu, w);
            }
        }
    }
    HaloInduced {
        graph: b.build().expect("halo-induced subgraph is valid"),
        orig,
        n_core,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn induces_half_of_a_path() {
        // Path 0-1-2-3-4, keep {0,1,2}.
        let g = generators::path(5, 1);
        let ind = InducedGraph::build(&g, |v| v < 3);
        assert_eq!(ind.n(), 3);
        assert_eq!(ind.orig, vec![0, 1, 2]);
        assert_eq!(ind.graph.m(), 2);
        ind.graph.validate().unwrap();
    }

    #[test]
    fn preserves_weights() {
        let mut b = crate::graph::GraphBuilder::new(3);
        b.set_vwgt(1, 7);
        b.add_edge_w(0, 1, 3);
        b.add_edge_w(1, 2, 4);
        let g = b.build().unwrap();
        let ind = InducedGraph::build(&g, |v| v >= 1);
        assert_eq!(ind.graph.vwgt, vec![7, 1]);
        assert_eq!(ind.graph.edge_weights(0), &[4]);
    }

    #[test]
    fn empty_selection() {
        let g = generators::path(4, 1);
        let ind = InducedGraph::build(&g, |_| false);
        assert_eq!(ind.n(), 0);
        assert_eq!(ind.graph.m(), 0);
    }

    #[test]
    fn grid_interior_is_valid() {
        let g = generators::grid2d(8, 8);
        let ind = InducedGraph::build(&g, |v| (v % 8) > 0 && (v % 8) < 7);
        ind.graph.validate().unwrap();
        assert_eq!(ind.n(), 48);
    }

    #[test]
    fn halo_ring_of_a_path_interior() {
        // Path 0-1-2-3-4, core {1,2,3}: halo is {0,4}.
        let g = generators::path(5, 1);
        let h = induce_with_halo(&g, &[1, 2, 3]);
        h.graph.validate().unwrap();
        assert_eq!(h.n_core, 3);
        assert_eq!(h.orig, vec![1, 2, 3, 0, 4]);
        assert_eq!(h.graph.m(), 4); // 1-2, 2-3 plus the two ring edges
        assert_eq!(h.halo_mask(), vec![false, false, false, true, true]);
    }

    #[test]
    fn halo_halo_edges_are_dropped() {
        // Triangle 0-1-2 plus pendant 3 on 0; core {3, 0}: halo {1,2}
        // but the 1-2 edge must not survive.
        let mut b = crate::graph::GraphBuilder::new(4);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(2, 0);
        b.add_edge(0, 3);
        let g = b.build().unwrap();
        let h = induce_with_halo(&g, &[3, 0]);
        h.graph.validate().unwrap();
        assert_eq!(h.n_core, 2);
        assert_eq!(h.graph.n(), 4);
        assert_eq!(h.graph.m(), 3); // 3-0, 0-1, 0-2; no 1-2
    }

    #[test]
    fn halo_preserves_weights_and_no_ring_when_closed() {
        let mut b = crate::graph::GraphBuilder::new(3);
        b.set_vwgt(2, 9);
        b.add_edge_w(0, 1, 5);
        b.add_edge_w(1, 2, 7);
        let g = b.build().unwrap();
        let h = induce_with_halo(&g, &[1, 0]);
        assert_eq!(h.orig, vec![1, 0, 2]);
        assert_eq!(h.graph.vwgt, vec![1, 1, 9]);
        // Local 0 = orig 1: neighbors are local 1 (w 5) and halo 2 (w 7).
        let mut pairs: Vec<(u32, i64)> = h
            .graph
            .neighbors(0)
            .iter()
            .copied()
            .zip(h.graph.edge_weights(0).iter().copied())
            .collect();
        pairs.sort_unstable();
        assert_eq!(pairs, vec![(1, 5), (2, 7)]);
        // Core covering the whole graph leaves no halo.
        let full = induce_with_halo(&g, &[0, 1, 2]);
        assert_eq!(full.n_core, 3);
        assert_eq!(full.graph.n(), 3);
    }
}
