//! Induced subgraphs with original-vertex maps.
//!
//! Nested dissection recurses on the subgraphs induced by the two
//! separated parts; each carries `orig`, the map from subgraph-local
//! vertex ids back to the ids of the parent graph, so leaf orderings can
//! be assembled into the global inverse permutation (paper §2.2).

use super::Graph;

/// A subgraph plus the map back to the parent graph's vertex ids.
#[derive(Clone, Debug)]
pub struct InducedGraph {
    /// The induced subgraph.
    pub graph: Graph,
    /// `orig[local] = parent-graph vertex id`.
    pub orig: Vec<usize>,
}

impl InducedGraph {
    /// Build the subgraph induced by the vertices where `keep(v)` is true.
    ///
    /// Edge and vertex weights are carried over; edges with one endpoint
    /// outside the kept set are dropped.
    pub fn build(g: &Graph, keep: impl Fn(usize) -> bool) -> InducedGraph {
        let n = g.n();
        let mut local = vec![u32::MAX; n];
        let mut orig = Vec::new();
        for v in 0..n {
            if keep(v) {
                local[v] = orig.len() as u32;
                orig.push(v);
            }
        }
        let nl = orig.len();
        let mut xadj = Vec::with_capacity(nl + 1);
        xadj.push(0usize);
        let mut adj = Vec::new();
        let mut ewgt = Vec::new();
        let mut vwgt = Vec::with_capacity(nl);
        for &ov in &orig {
            for (&u, &w) in g.neighbors(ov).iter().zip(g.edge_weights(ov)) {
                let lu = local[u as usize];
                if lu != u32::MAX {
                    adj.push(lu);
                    ewgt.push(w);
                }
            }
            xadj.push(adj.len());
            vwgt.push(g.vwgt[ov]);
        }
        InducedGraph {
            graph: Graph {
                xadj,
                adj,
                vwgt,
                ewgt,
            },
            orig,
        }
    }

    /// Number of vertices in the induced subgraph.
    pub fn n(&self) -> usize {
        self.graph.n()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn induces_half_of_a_path() {
        // Path 0-1-2-3-4, keep {0,1,2}.
        let g = generators::path(5, 1);
        let ind = InducedGraph::build(&g, |v| v < 3);
        assert_eq!(ind.n(), 3);
        assert_eq!(ind.orig, vec![0, 1, 2]);
        assert_eq!(ind.graph.m(), 2);
        ind.graph.validate().unwrap();
    }

    #[test]
    fn preserves_weights() {
        let mut b = crate::graph::GraphBuilder::new(3);
        b.set_vwgt(1, 7);
        b.add_edge_w(0, 1, 3);
        b.add_edge_w(1, 2, 4);
        let g = b.build().unwrap();
        let ind = InducedGraph::build(&g, |v| v >= 1);
        assert_eq!(ind.graph.vwgt, vec![7, 1]);
        assert_eq!(ind.graph.edge_weights(0), &[4]);
    }

    #[test]
    fn empty_selection() {
        let g = generators::path(4, 1);
        let ind = InducedGraph::build(&g, |_| false);
        assert_eq!(ind.n(), 0);
        assert_eq!(ind.graph.m(), 0);
    }

    #[test]
    fn grid_interior_is_valid() {
        let g = generators::grid2d(8, 8);
        let ind = InducedGraph::build(&g, |v| (v % 8) > 0 && (v % 8) < 7);
        ind.graph.validate().unwrap();
        assert_eq!(ind.n(), 48);
    }
}
