//! Graph I/O (S3): CHACO/Metis `.graph` and MatrixMarket readers/writers.
//!
//! These let real paper matrices (audikw1, cage15, …) drop into every
//! bench and example when available; the offline runs use the generator
//! analogs instead (DESIGN.md §3).

use super::{Graph, GraphBuilder};
use crate::{Error, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::path::Path;

/// Read a CHACO / Metis `.graph` file.
///
/// Format: header `n m [fmt [ncon]]`, then one line per vertex listing
/// 1-based neighbor ids; `fmt` bit 0 = edge weights, bit 1 = vertex
/// weights (`10` = vwgt only, `1` = ewgt only, `11` = both).
pub fn read_chaco<R: Read>(r: R) -> Result<Graph> {
    let reader = BufReader::new(r);
    let mut lines = reader.lines().filter_map(|l| {
        let l = l.ok()?;
        let t = l.trim().to_string();
        if t.is_empty() || t.starts_with('%') || t.starts_with('#') {
            None
        } else {
            Some(t)
        }
    });
    let header = lines
        .next()
        .ok_or_else(|| Error::Io("empty .graph file".into()))?;
    let h: Vec<usize> = header
        .split_whitespace()
        .map(|t| t.parse().map_err(|_| Error::Io(format!("bad header token {t}"))))
        .collect::<Result<_>>()?;
    if h.len() < 2 {
        return Err(Error::Io("header needs n and m".into()));
    }
    let (n, m) = (h[0], h[1]);
    let fmt = h.get(2).copied().unwrap_or(0);
    let has_ewgt = fmt % 10 == 1;
    let has_vwgt = (fmt / 10) % 10 == 1;
    let mut b = GraphBuilder::new(n);
    let mut v = 0usize;
    for line in lines {
        if v >= n {
            return Err(Error::Io("more vertex lines than n".into()));
        }
        let toks: Vec<i64> = line
            .split_whitespace()
            .map(|t| t.parse().map_err(|_| Error::Io(format!("bad token {t}"))))
            .collect::<Result<_>>()?;
        let mut i = 0;
        if has_vwgt {
            if toks.is_empty() {
                return Err(Error::Io(format!("missing vwgt on line of vertex {v}")));
            }
            b.set_vwgt(v, toks[0]);
            i = 1;
        }
        while i < toks.len() {
            let u = toks[i] as usize;
            if u == 0 || u > n {
                return Err(Error::Io(format!("neighbor {u} out of range")));
            }
            let w = if has_ewgt {
                i += 1;
                *toks
                    .get(i)
                    .ok_or_else(|| Error::Io("missing edge weight".into()))?
            } else {
                1
            };
            // Each undirected edge appears on both endpoint lines; only add
            // from the smaller endpoint to avoid double-weighting.
            if u - 1 > v {
                b.add_edge_w(v, u - 1, w);
            }
            i += 1;
        }
        v += 1;
    }
    if v != n {
        return Err(Error::Io(format!("expected {n} vertex lines, got {v}")));
    }
    let g = b.build()?;
    if g.m() != m {
        return Err(Error::Io(format!(
            "header claims {m} edges, file has {}",
            g.m()
        )));
    }
    Ok(g)
}

/// Write a graph in CHACO `.graph` format (with weights iff non-unit).
pub fn write_chaco<W: Write>(g: &Graph, mut w: W) -> Result<()> {
    let has_vwgt = g.vwgt.iter().any(|&x| x != 1);
    let has_ewgt = g.ewgt.iter().any(|&x| x != 1);
    let fmt = (has_vwgt as usize) * 10 + has_ewgt as usize;
    if fmt != 0 {
        writeln!(w, "{} {} {:02}", g.n(), g.m(), fmt)?;
    } else {
        writeln!(w, "{} {}", g.n(), g.m())?;
    }
    let mut line = String::new();
    for v in 0..g.n() {
        line.clear();
        if has_vwgt {
            line.push_str(&g.vwgt[v].to_string());
        }
        for (&u, &ew) in g.neighbors(v).iter().zip(g.edge_weights(v)) {
            if !line.is_empty() {
                line.push(' ');
            }
            line.push_str(&(u + 1).to_string());
            if has_ewgt {
                line.push(' ');
                line.push_str(&ew.to_string());
            }
        }
        writeln!(w, "{line}")?;
    }
    Ok(())
}

/// Read a MatrixMarket coordinate file as the adjacency structure of a
/// symmetric matrix (diagonal dropped, pattern symmetrized, values
/// ignored — ordering is purely structural).
pub fn read_matrix_market<R: Read>(r: R) -> Result<Graph> {
    let reader = BufReader::new(r);
    let mut lines = reader.lines().filter_map(|l| {
        let l = l.ok()?;
        let t = l.trim().to_string();
        if t.is_empty() {
            None
        } else {
            Some(t)
        }
    });
    let banner = lines
        .next()
        .ok_or_else(|| Error::Io("empty MatrixMarket file".into()))?;
    if !banner.starts_with("%%MatrixMarket") {
        return Err(Error::Io("missing MatrixMarket banner".into()));
    }
    let mut size_line = None;
    for l in lines.by_ref() {
        if l.starts_with('%') {
            continue;
        }
        size_line = Some(l);
        break;
    }
    let size_line = size_line.ok_or_else(|| Error::Io("missing size line".into()))?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .take(3)
        .map(|t| t.parse().map_err(|_| Error::Io(format!("bad size token {t}"))))
        .collect::<Result<_>>()?;
    if dims.len() != 3 {
        return Err(Error::Io("size line needs rows cols nnz".into()));
    }
    let (rows, cols) = (dims[0], dims[1]);
    if rows != cols {
        return Err(Error::Io("matrix must be square".into()));
    }
    let mut b = GraphBuilder::new(rows);
    for l in lines {
        if l.starts_with('%') {
            continue;
        }
        let mut it = l.split_whitespace();
        let i: usize = it
            .next()
            .and_then(|t| t.parse().ok())
            .ok_or_else(|| Error::Io("bad entry row".into()))?;
        let j: usize = it
            .next()
            .and_then(|t| t.parse().ok())
            .ok_or_else(|| Error::Io("bad entry col".into()))?;
        if i == 0 || j == 0 || i > rows || j > rows {
            return Err(Error::Io(format!("entry ({i},{j}) out of range")));
        }
        if i != j {
            b.add_edge_w(i - 1, j - 1, 1);
        }
    }
    // Duplicate (i,j)/(j,i) entries are merged by the builder; reset the
    // merged weights to 1 (pattern graph).
    let mut g = b.build()?;
    for w in g.ewgt.iter_mut() {
        *w = 1;
    }
    Ok(g)
}

/// Load a graph from a path, dispatching on extension (`.graph`/`.chaco`
/// vs `.mtx`).
pub fn load(path: &Path) -> Result<Graph> {
    let f = std::fs::File::open(path)?;
    match path.extension().and_then(|e| e.to_str()) {
        Some("mtx") => read_matrix_market(f),
        _ => read_chaco(f),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn chaco_roundtrip_unweighted() {
        let g = generators::grid2d(5, 4);
        let mut buf = Vec::new();
        write_chaco(&g, &mut buf).unwrap();
        let h = read_chaco(&buf[..]).unwrap();
        assert_eq!(g.xadj, h.xadj);
        assert_eq!(g.adj, h.adj);
        assert_eq!(g.vwgt, h.vwgt);
        assert_eq!(g.ewgt, h.ewgt);
    }

    #[test]
    fn chaco_roundtrip_weighted() {
        let mut b = crate::graph::GraphBuilder::new(3);
        b.set_vwgt(0, 4);
        b.set_vwgt(2, 9);
        b.add_edge_w(0, 1, 3);
        b.add_edge_w(1, 2, 5);
        let g = b.build().unwrap();
        let mut buf = Vec::new();
        write_chaco(&g, &mut buf).unwrap();
        let h = read_chaco(&buf[..]).unwrap();
        assert_eq!(g.vwgt, h.vwgt);
        assert_eq!(g.ewgt, h.ewgt);
        assert_eq!(g.adj, h.adj);
    }

    #[test]
    fn chaco_rejects_bad_edge_count() {
        let text = "2 5\n2\n1\n";
        assert!(read_chaco(text.as_bytes()).is_err());
    }

    #[test]
    fn matrix_market_reads_symmetric_pattern() {
        let text = "%%MatrixMarket matrix coordinate pattern symmetric\n\
                    % comment\n\
                    3 3 4\n1 1\n2 1\n3 2\n3 3\n";
        let g = read_matrix_market(text.as_bytes()).unwrap();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 2); // diagonal dropped
        assert_eq!(g.neighbors(0), &[1]);
        assert_eq!(g.neighbors(2), &[1]);
    }

    #[test]
    fn matrix_market_merges_both_triangles() {
        let text = "%%MatrixMarket matrix coordinate real general\n\
                    2 2 3\n1 2 1.5\n2 1 2.5\n1 1 3.0\n";
        let g = read_matrix_market(text.as_bytes()).unwrap();
        assert_eq!(g.m(), 1);
        assert_eq!(g.ewgt, vec![1, 1]);
    }

    #[test]
    fn matrix_market_rejects_rectangular() {
        let text = "%%MatrixMarket matrix coordinate real general\n2 3 1\n1 2 1.0\n";
        assert!(read_matrix_market(text.as_bytes()).is_err());
    }
}
