//! Incremental construction of CSR graphs from edge lists.
//!
//! Used by the generators, the I/O readers, the coarse-graph builders and
//! the distributed induced-subgraph / fold routines. Duplicate edges are
//! merged by *summing* their weights (the behavior coarsening needs).

use super::Graph;
use crate::{Error, Result};

/// Accumulates undirected edges and vertex weights, then emits a CSR
/// [`Graph`] with sorted, deduplicated adjacency lists.
#[derive(Clone, Debug)]
pub struct GraphBuilder {
    n: usize,
    vwgt: Vec<i64>,
    /// Directed arc triples `(u, v, w)`; both directions are recorded.
    arcs: Vec<(u32, u32, i64)>,
}

impl GraphBuilder {
    /// Builder for a graph with `n` vertices, unit vertex weights.
    pub fn new(n: usize) -> Self {
        GraphBuilder {
            n,
            vwgt: vec![1; n],
            arcs: Vec::new(),
        }
    }

    /// Number of vertices.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Set the weight of one vertex.
    pub fn set_vwgt(&mut self, v: usize, w: i64) {
        self.vwgt[v] = w;
    }

    /// Add `w` to the weight of one vertex.
    pub fn add_vwgt(&mut self, v: usize, w: i64) {
        self.vwgt[v] += w;
    }

    /// Add an undirected edge with weight 1. Self-loops are ignored.
    pub fn add_edge(&mut self, u: usize, v: usize) {
        self.add_edge_w(u, v, 1);
    }

    /// Add an undirected weighted edge. Self-loops are ignored; duplicate
    /// edges have their weights summed at build time.
    pub fn add_edge_w(&mut self, u: usize, v: usize, w: i64) {
        if u == v {
            return;
        }
        debug_assert!(u < self.n && v < self.n);
        self.arcs.push((u as u32, v as u32, w));
        self.arcs.push((v as u32, u as u32, w));
    }

    /// Current number of recorded arcs (2× edges, before dedup).
    pub fn arc_count(&self) -> usize {
        self.arcs.len()
    }

    /// Emit the validated CSR graph.
    pub fn build(mut self) -> Result<Graph> {
        let n = self.n;
        if self.vwgt.iter().any(|&w| w <= 0) {
            return Err(Error::InvalidGraph("non-positive vertex weight".into()));
        }
        // Sort arcs by (src, dst) then merge duplicates, summing weights.
        self.arcs.sort_unstable_by_key(|&(u, v, _)| (u, v));
        let mut xadj = vec![0usize; n + 1];
        let mut adj: Vec<u32> = Vec::with_capacity(self.arcs.len());
        let mut ewgt: Vec<i64> = Vec::with_capacity(self.arcs.len());
        let mut i = 0;
        while i < self.arcs.len() {
            let (u, v, mut w) = self.arcs[i];
            i += 1;
            while i < self.arcs.len() && self.arcs[i].0 == u && self.arcs[i].1 == v {
                w += self.arcs[i].2;
                i += 1;
            }
            adj.push(v);
            ewgt.push(w);
            xadj[u as usize + 1] += 1;
        }
        for v in 0..n {
            xadj[v + 1] += xadj[v];
        }
        let g = Graph {
            xadj,
            adj,
            vwgt: self.vwgt,
            ewgt,
        };
        g.validate()?;
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_triangle() {
        let mut b = GraphBuilder::new(3);
        b.add_edge(0, 1);
        b.add_edge(1, 2);
        b.add_edge(2, 0);
        let g = b.build().unwrap();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 3);
        assert_eq!(g.neighbors(0), &[1, 2]);
    }

    #[test]
    fn merges_duplicate_edges() {
        let mut b = GraphBuilder::new(2);
        b.add_edge_w(0, 1, 2);
        b.add_edge_w(1, 0, 3);
        let g = b.build().unwrap();
        assert_eq!(g.m(), 1);
        assert_eq!(g.edge_weights(0), &[5]);
        assert_eq!(g.edge_weights(1), &[5]);
    }

    #[test]
    fn ignores_self_loops() {
        let mut b = GraphBuilder::new(2);
        b.add_edge(0, 0);
        b.add_edge(0, 1);
        let g = b.build().unwrap();
        assert_eq!(g.m(), 1);
    }

    #[test]
    fn isolated_vertices_ok() {
        let b = GraphBuilder::new(4);
        let g = b.build().unwrap();
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 0);
        assert_eq!(g.degree(2), 0);
    }

    #[test]
    fn vertex_weights_respected() {
        let mut b = GraphBuilder::new(2);
        b.set_vwgt(0, 5);
        b.add_vwgt(1, 2);
        b.add_edge(0, 1);
        let g = b.build().unwrap();
        assert_eq!(g.vwgt, vec![5, 3]);
        assert_eq!(g.total_vwgt(), 8);
    }

    #[test]
    fn rejects_zero_vwgt() {
        let mut b = GraphBuilder::new(1);
        b.set_vwgt(0, 0);
        assert!(b.build().is_err());
    }
}
