//! Graph generators (S2): regular meshes plus synthetic analogs of the
//! paper's test matrices (Table 1).
//!
//! The paper evaluates on matrices from CEA, the Parasol project and the
//! University of Florida collection (audikw1, cage15, brgm, qimonda07,
//! thread, …). Those files are not redistributable/downloadable in this
//! offline environment, so we generate structural analogs that match the
//! properties ordering quality actually depends on — dimensionality
//! (2D/3D mesh vs expander vs circuit), degree distribution and locality —
//! as documented in DESIGN.md §3. Real matrices can be substituted via
//! [`crate::graph::io`] when available.

use super::{Graph, GraphBuilder};
use crate::rng::Rng;

/// Path graph on `n` vertices with edge weight `w` (test helper).
pub fn path(n: usize, w: i64) -> Graph {
    let mut b = GraphBuilder::new(n);
    for v in 1..n {
        b.add_edge_w(v - 1, v, w);
    }
    b.build().expect("path is valid")
}

/// Cycle graph on `n ≥ 3` vertices.
pub fn cycle(n: usize) -> Graph {
    let mut b = GraphBuilder::new(n);
    for v in 0..n {
        b.add_edge(v, (v + 1) % n);
    }
    b.build().expect("cycle is valid")
}

/// Complete graph on `n` vertices (small tests only).
pub fn complete(n: usize) -> Graph {
    let mut b = GraphBuilder::new(n);
    for u in 0..n {
        for v in (u + 1)..n {
            b.add_edge(u, v);
        }
    }
    b.build().expect("complete is valid")
}

/// 5-point 2D grid `nx × ny` — the classic nested-dissection test family
/// (separators are O(√n); OPC optimum is O(n^{3/2})).
pub fn grid2d(nx: usize, ny: usize) -> Graph {
    let idx = |x: usize, y: usize| y * nx + x;
    let mut b = GraphBuilder::new(nx * ny);
    for y in 0..ny {
        for x in 0..nx {
            if x + 1 < nx {
                b.add_edge(idx(x, y), idx(x + 1, y));
            }
            if y + 1 < ny {
                b.add_edge(idx(x, y), idx(x, y + 1));
            }
        }
    }
    b.build().expect("grid2d is valid")
}

/// Part-label fixture for separator tests and benches: a vertical
/// column separator on a [`grid2d`] — the `thickness` columns starting
/// at `mid` are the separator (label 2, `sep::SEP`), columns left of it
/// part 0 and columns right of it part 1 (`sep::P0`/`sep::P1`). A valid
/// separator by construction, and deliberately suboptimal for
/// `thickness > 1` — the canonical "refinable projection" input of the
/// band-refinement tests.
pub fn column_separator_part(nx: usize, ny: usize, mid: usize, thickness: usize) -> Vec<u8> {
    assert!(mid + thickness < nx, "separator must leave part 1 nonempty");
    (0..nx * ny)
        .map(|v| {
            let x = v % nx;
            if x < mid {
                0
            } else if x < mid + thickness {
                2
            } else {
                1
            }
        })
        .collect()
}

/// 7-point 3D grid `nx × ny × nz` — the mesh family behind the paper's
/// conesphere / coupole / brgm analogs (separators O(n^{2/3})).
pub fn grid3d(nx: usize, ny: usize, nz: usize) -> Graph {
    let idx = |x: usize, y: usize, z: usize| (z * ny + y) * nx + x;
    let mut b = GraphBuilder::new(nx * ny * nz);
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                if x + 1 < nx {
                    b.add_edge(idx(x, y, z), idx(x + 1, y, z));
                }
                if y + 1 < ny {
                    b.add_edge(idx(x, y, z), idx(x, y + 1, z));
                }
                if z + 1 < nz {
                    b.add_edge(idx(x, y, z), idx(x, y, z + 1));
                }
            }
        }
    }
    b.build().expect("grid3d is valid")
}

/// 27-point 3D grid (all neighbors in the surrounding cube) — a denser
/// finite-element-like mesh, average degree ≈ 26.
pub fn grid3d_27pt(nx: usize, ny: usize, nz: usize) -> Graph {
    let idx = |x: usize, y: usize, z: usize| (z * ny + y) * nx + x;
    let mut b = GraphBuilder::new(nx * ny * nz);
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let v = idx(x, y, z);
                for dz in 0..=1usize {
                    for dy in -(1isize)..=1 {
                        for dx in -(1isize)..=1 {
                            if dz == 0 && (dy < 0 || (dy == 0 && dx <= 0)) {
                                continue; // enumerate each pair once
                            }
                            let (nx_, ny_, nz_) = (
                                x as isize + dx,
                                y as isize + dy,
                                z as isize + dz as isize,
                            );
                            if nx_ < 0
                                || ny_ < 0
                                || nx_ >= nx as isize
                                || ny_ >= ny as isize
                                || nz_ >= nz as isize
                            {
                                continue;
                            }
                            b.add_edge(v, idx(nx_ as usize, ny_ as usize, nz_ as usize));
                        }
                    }
                }
            }
        }
    }
    b.build().expect("grid3d_27pt is valid")
}

/// `audikw1` analog: a 27-point 3D mesh with one *contiguous* cluster of
/// very-high-degree vertices (the paper attributes audikw1's per-process
/// memory imbalance, Fig. 10, to "a set of contiguous vertices of very
/// high degree"). `cluster_frac` of the vertices (a contiguous id range)
/// get ≈ `cluster_extra` additional intra-cluster edges each.
pub fn audikw_like(
    nx: usize,
    ny: usize,
    nz: usize,
    cluster_frac: f64,
    cluster_extra: usize,
    seed: u64,
) -> Graph {
    let n = nx * ny * nz;
    let base = grid3d_27pt(nx, ny, nz);
    let mut b = GraphBuilder::new(n);
    for v in 0..n {
        for &u in base.neighbors(v) {
            if (u as usize) > v {
                b.add_edge(v, u as usize);
            }
        }
    }
    let mut rng = Rng::new(seed);
    let csize = ((n as f64 * cluster_frac) as usize).max(2).min(n);
    let cstart = (n - csize) / 2; // contiguous range in the middle
    for v in cstart..cstart + csize {
        for _ in 0..cluster_extra {
            let u = cstart + rng.below(csize);
            if u != v {
                b.add_edge(v, u);
            }
        }
    }
    b.build().expect("audikw_like is valid")
}

/// `cage15` analog: a low-degree expander-like graph built as the union of
/// `half_deg` random perfect matchings over a Hamiltonian cycle. DNA
/// electrophoresis matrices behave like small-world expanders: small
/// separators do not exist, orderings are expensive, and distributing the
/// graph produces many ghost vertices (the Fig. 11 effect).
pub fn cage_like(n: usize, half_deg: usize, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new(n);
    for v in 0..n {
        b.add_edge(v, (v + 1) % n); // connectivity backbone
    }
    for _ in 0..half_deg {
        let p = rng.permutation(n);
        for pair in p.chunks_exact(2) {
            b.add_edge(pair[0], pair[1]);
        }
    }
    b.build().expect("cage_like is valid")
}

/// `qimonda07` analog: a circuit-simulation-like graph — very sparse
/// (average degree ≈ 6.8), mostly local wiring along a linear placement
/// with a few long-range nets.
pub fn qimonda_like(n: usize, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new(n);
    for v in 1..n {
        b.add_edge(v - 1, v); // local chain
    }
    // ~2.2 extra local edges per vertex within a window, plus ~0.2 global.
    for v in 0..n {
        for _ in 0..2 {
            let off = 2 + rng.below(14);
            if v + off < n {
                b.add_edge(v, v + off);
            }
        }
        if rng.below(5) == 0 {
            let u = rng.below(n);
            if u != v {
                b.add_edge(v, u);
            }
        }
    }
    b.build().expect("qimonda_like is valid")
}

/// `thread` analog: a small, very dense connector problem — average degree
/// ≈ `band` via a banded dense structure with random skips.
pub fn thread_like(n: usize, band: usize, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new(n);
    for v in 0..n {
        let lim = (v + band / 2).min(n - 1);
        for u in (v + 1)..=lim {
            // Dense band with 80% fill.
            if rng.below(5) != 0 {
                b.add_edge(v, u);
            }
        }
    }
    b.build().expect("thread_like is valid")
}

/// Random geometric-ish mesh used for property tests: a jittered grid with
/// some diagonal edges (irregular but planar-ish).
pub fn irregular_mesh(nx: usize, ny: usize, seed: u64) -> Graph {
    let base = grid2d(nx, ny);
    let mut rng = Rng::new(seed);
    let idx = |x: usize, y: usize| y * nx + x;
    let mut b = GraphBuilder::new(nx * ny);
    for v in 0..base.n() {
        for &u in base.neighbors(v) {
            if (u as usize) > v {
                b.add_edge(v, u as usize);
            }
        }
    }
    for y in 0..ny.saturating_sub(1) {
        for x in 0..nx.saturating_sub(1) {
            if rng.coin() {
                b.add_edge(idx(x, y), idx(x + 1, y + 1));
            } else {
                b.add_edge(idx(x + 1, y), idx(x, y + 1));
            }
        }
    }
    b.build().expect("irregular_mesh is valid")
}

/// The named analog suite mirroring Table 1 of the paper, at a scale that
/// fits this container's single-core budget. Sizes are configurable via
/// `scale` (1 = bench default).
pub fn table1_suite(scale: usize) -> Vec<(&'static str, Graph)> {
    let s = scale.max(1);
    vec![
        ("grid3d-s", grid3d(12 * s, 12 * s, 12 * s)),
        ("audikw-like", audikw_like(10 * s, 10 * s, 10 * s, 0.02, 40, 1)),
        ("cage-like", cage_like(12_000 * s * s, 8, 2)),
        ("conesphere-like", grid3d_27pt(9 * s, 9 * s, 9 * s)),
        ("qimonda-like", qimonda_like(30_000 * s * s, 3)),
        ("thread-like", thread_like(2_000 * s, 120, 4)),
        ("grid2d-l", grid2d(110 * s, 110 * s)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid2d_counts() {
        let g = grid2d(4, 3);
        assert_eq!(g.n(), 12);
        assert_eq!(g.m(), 3 * 3 + 4 * 2); // horizontal + vertical
        g.validate().unwrap();
        let (_, nc) = g.components();
        assert_eq!(nc, 1);
    }

    #[test]
    fn grid3d_counts() {
        let g = grid3d(3, 3, 3);
        assert_eq!(g.n(), 27);
        assert_eq!(g.m(), 2 * 9 * 3); // 3 directions × 2·9 edges
        g.validate().unwrap();
    }

    #[test]
    fn grid3d_27pt_degree() {
        let g = grid3d_27pt(5, 5, 5);
        g.validate().unwrap();
        // interior vertex (2,2,2) has full 26-neighborhood
        let center = (2 * 5 + 2) * 5 + 2;
        assert_eq!(g.degree(center), 26);
        let (_, nc) = g.components();
        assert_eq!(nc, 1);
    }

    #[test]
    fn audikw_like_has_high_degree_cluster() {
        let g = audikw_like(8, 8, 8, 0.05, 30, 7);
        g.validate().unwrap();
        assert!(g.max_degree() > 40, "max degree {}", g.max_degree());
        let (_, nc) = g.components();
        assert_eq!(nc, 1);
    }

    #[test]
    fn cage_like_is_connected_low_degree() {
        let g = cage_like(2000, 8, 5);
        g.validate().unwrap();
        let (_, nc) = g.components();
        assert_eq!(nc, 1);
        let avg = g.avg_degree();
        assert!((8.0..=20.0).contains(&avg), "avg degree {avg}");
    }

    #[test]
    fn qimonda_like_sparse() {
        let g = qimonda_like(5000, 9);
        g.validate().unwrap();
        let avg = g.avg_degree();
        assert!((4.0..=9.0).contains(&avg), "avg degree {avg}");
        let (_, nc) = g.components();
        assert_eq!(nc, 1);
    }

    #[test]
    fn thread_like_dense() {
        let g = thread_like(500, 100, 3);
        g.validate().unwrap();
        assert!(g.avg_degree() > 50.0);
    }

    #[test]
    fn irregular_mesh_valid_connected() {
        let g = irregular_mesh(10, 10, 17);
        g.validate().unwrap();
        let (_, nc) = g.components();
        assert_eq!(nc, 1);
    }

    #[test]
    fn generators_are_deterministic() {
        let a = cage_like(500, 4, 42);
        let b = cage_like(500, 4, 42);
        assert_eq!(a.xadj, b.xadj);
        assert_eq!(a.adj, b.adj);
    }
}
