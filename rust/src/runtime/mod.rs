//! XLA/PJRT runtime (S18): loads the AOT-compiled JAX/Pallas artifacts
//! and runs them from the coordinator's band-refinement hot path.
//!
//! The interchange format is **HLO text** (`artifacts/*.hlo.txt`) — see
//! `python/compile/aot.py` and /opt/xla-example: jax ≥ 0.5 emits protos
//! with 64-bit instruction ids that the crate's XLA build rejects, while
//! the text parser reassigns ids cleanly. One executable is compiled per
//! `(kernel, size-bucket)`; band graphs are packed into the bucket's ELL
//! layout ([`pack_ell`]) and padded rows carry zero weights, so the
//! kernel needs no dynamic shapes. Python never runs at order time.
//!
//! Two call paths share the executables: the sequential band refiner
//! ([`DiffusionRefiner`]) packs whole centralized bands, and the
//! distributed diffusion path (`dist::ddiffusion`) packs **one rank's
//! band slice** — local plus ghost rows ([`pack_ell_dist`]) — executing
//! the same fused kernel per rank with ghost rows clamped to the halo
//! boundary values (DESIGN.md §4.2).

pub mod ell;
pub mod refiner;

pub use ell::{
    ell_fused_reference, ell_minplus_reference, pack_ell, pack_ell_clamped, pack_ell_dist,
    EllPacked, MINPLUS_INF,
};
pub use refiner::DiffusionRefiner;

use crate::{Error, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// A `Send` wrapper around [`XlaRuntime`].
///
/// SAFETY: the `xla` crate's client/executable types are `!Send` because
/// they hold `Rc` refcounts and raw PJRT pointers. All of those objects
/// live strictly *inside* one `XlaRuntime` value: our methods take
/// `&self`, build every `Literal` locally, and convert results to plain
/// `Vec<f32>` before returning, so no `Rc` clone or PJRT handle ever
/// escapes. Accessed exclusively through `Mutex<SendRuntime>` (see
/// [`SharedRuntime`]), all refcount traffic is serialized, which is the
/// soundness condition `Rc` needs when a value migrates across threads.
pub struct SendRuntime(pub XlaRuntime);
unsafe impl Send for SendRuntime {}

/// The shareable runtime handle used by refiners across rank threads.
pub type SharedRuntime = Arc<Mutex<SendRuntime>>;

/// Load artifacts and wrap them for cross-thread sharing.
pub fn load_shared(dir: &Path) -> Result<SharedRuntime> {
    Ok(Arc::new(Mutex::new(SendRuntime(XlaRuntime::load(dir)?))))
}

/// Identifies one compiled artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Bucket {
    /// Padded vertex count (rows of the ELL block).
    pub n: usize,
    /// Padded neighbor-list width (columns of the ELL block).
    pub d: usize,
}

/// Smallest bucket of `buckets` that fits an `(n, d)` problem — the
/// shared fit rule behind [`XlaRuntime::fit_diffusion`] and
/// [`XlaRuntime::fit_minplus`]. `(n, d)` is the row/width requirement of
/// the graph to pack: the vertex count (local + ghost rows for a
/// distributed slice) and the maximum unclamped degree.
///
/// ```
/// use ptscotch::runtime::{fit_bucket, Bucket};
///
/// let buckets = [Bucket { n: 256, d: 32 }, Bucket { n: 1024, d: 32 }];
/// // The smallest fitting bucket wins…
/// assert_eq!(fit_bucket(&buckets, 200, 6), Some(Bucket { n: 256, d: 32 }));
/// assert_eq!(fit_bucket(&buckets, 300, 32), Some(Bucket { n: 1024, d: 32 }));
/// // …and an oversize problem fits none (the caller falls back to CPU).
/// assert_eq!(fit_bucket(&buckets, 2000, 6), None);
/// assert_eq!(fit_bucket(&buckets, 64, 40), None);
/// ```
pub fn fit_bucket(buckets: &[Bucket], n: usize, d: usize) -> Option<Bucket> {
    buckets
        .iter()
        .copied()
        .filter(|b| b.n >= n && b.d >= d)
        .min()
}

/// A loaded artifact registry plus the PJRT CPU client.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    /// Diffusion executables by bucket; each runs `steps_per_call`
    /// damped-averaging iterations.
    diffusion: BTreeMap<Bucket, xla::PjRtLoadedExecutable>,
    /// One-step min-plus (BFS) executables by bucket.
    minplus: BTreeMap<Bucket, xla::PjRtLoadedExecutable>,
    /// Iterations fused into one diffusion call (baked at AOT time).
    pub steps_per_call: usize,
}

impl XlaRuntime {
    /// Load every artifact listed in `<dir>/manifest.txt`. Lines:
    /// `kernel n d k file`, `#` comments allowed.
    pub fn load(dir: &Path) -> Result<XlaRuntime> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .map_err(|e| Error::NoArtifact(format!("{}: {e}", manifest.display())))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PJRT client: {e:?}")))?;
        let mut rt = XlaRuntime {
            client,
            diffusion: BTreeMap::new(),
            minplus: BTreeMap::new(),
            steps_per_call: 8,
        };
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 5 {
                return Err(Error::NoArtifact(format!("bad manifest line: {line}")));
            }
            let (kernel, n, d, k, file) = (
                f[0],
                f[1].parse::<usize>()
                    .map_err(|_| Error::NoArtifact(format!("bad n in {line}")))?,
                f[2].parse::<usize>()
                    .map_err(|_| Error::NoArtifact(format!("bad d in {line}")))?,
                f[3].parse::<usize>()
                    .map_err(|_| Error::NoArtifact(format!("bad k in {line}")))?,
                f[4],
            );
            let path: PathBuf = dir.join(file);
            let exe = rt.compile_file(&path)?;
            let bucket = Bucket { n, d };
            match kernel {
                "diffusion" => {
                    rt.steps_per_call = k;
                    rt.diffusion.insert(bucket, exe);
                }
                "minplus" => {
                    rt.minplus.insert(bucket, exe);
                }
                other => {
                    return Err(Error::NoArtifact(format!("unknown kernel {other}")));
                }
            }
        }
        Ok(rt)
    }

    fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::NoArtifact("non-utf8 path".into()))?,
        )
        .map_err(|e| Error::Runtime(format!("parse {}: {e:?}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {}: {e:?}", path.display())))
    }

    /// Buckets with a diffusion executable, ascending.
    pub fn diffusion_buckets(&self) -> Vec<Bucket> {
        self.diffusion.keys().copied().collect()
    }

    /// Smallest diffusion bucket that fits `(n, d)` (see [`fit_bucket`]
    /// for the fit rule). `n` counts every packed row — for a
    /// distributed band slice that is local **plus ghost** rows — and
    /// `d` the maximum unclamped degree.
    ///
    /// ```no_run
    /// use ptscotch::runtime::XlaRuntime;
    ///
    /// let rt = XlaRuntime::load(&XlaRuntime::default_dir()).unwrap();
    /// if let Some(bucket) = rt.fit_diffusion(300, 8) {
    ///     assert!(bucket.n >= 300 && bucket.d >= 8);
    /// }
    /// ```
    pub fn fit_diffusion(&self, n: usize, d: usize) -> Option<Bucket> {
        let buckets: Vec<Bucket> = self.diffusion.keys().copied().collect();
        fit_bucket(&buckets, n, d)
    }

    /// Run `steps_per_call` diffusion iterations on a packed band graph.
    ///
    /// `x` is the field, `fixed_mask`/`fixed_vals` clamp the anchors
    /// (mask 1 = clamped). All vectors must have length `bucket.n`; the
    /// ELL arrays must be `bucket.n × bucket.d` row-major.
    pub fn diffusion_step(
        &self,
        bucket: Bucket,
        x: &[f32],
        fixed_mask: &[f32],
        fixed_vals: &[f32],
        ell: &EllPacked,
    ) -> Result<Vec<f32>> {
        let exe = self
            .diffusion
            .get(&bucket)
            .ok_or_else(|| Error::NoArtifact(format!("diffusion bucket {bucket:?}")))?;
        debug_assert_eq!(x.len(), bucket.n);
        debug_assert_eq!(ell.nbr.len(), bucket.n * bucket.d);
        let (n, d) = (bucket.n as i64, bucket.d as i64);
        let lx = xla::Literal::vec1(x);
        let lm = xla::Literal::vec1(fixed_mask);
        let lv = xla::Literal::vec1(fixed_vals);
        let ln = xla::Literal::vec1(&ell.nbr)
            .reshape(&[n, d])
            .map_err(|e| Error::Runtime(format!("reshape nbr: {e:?}")))?;
        let lw = xla::Literal::vec1(&ell.w)
            .reshape(&[n, d])
            .map_err(|e| Error::Runtime(format!("reshape w: {e:?}")))?;
        let out = exe
            .execute::<xla::Literal>(&[lx, lm, lv, ln, lw])
            .map_err(|e| Error::Runtime(format!("execute: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("sync: {e:?}")))?;
        let t = out
            .to_tuple1()
            .map_err(|e| Error::Runtime(format!("tuple: {e:?}")))?;
        t.to_vec::<f32>()
            .map_err(|e| Error::Runtime(format!("to_vec: {e:?}")))
    }

    /// Run one min-plus (BFS relaxation) step: `dist' = min(dist,
    /// min_nbr(dist)+1)` with masked (padded) entries contributing +inf.
    pub fn minplus_step(
        &self,
        bucket: Bucket,
        dist: &[f32],
        ell: &EllPacked,
    ) -> Result<Vec<f32>> {
        let exe = self
            .minplus
            .get(&bucket)
            .ok_or_else(|| Error::NoArtifact(format!("minplus bucket {bucket:?}")))?;
        let (n, d) = (bucket.n as i64, bucket.d as i64);
        let lx = xla::Literal::vec1(dist);
        let ln = xla::Literal::vec1(&ell.nbr)
            .reshape(&[n, d])
            .map_err(|e| Error::Runtime(format!("reshape nbr: {e:?}")))?;
        let lw = xla::Literal::vec1(&ell.w)
            .reshape(&[n, d])
            .map_err(|e| Error::Runtime(format!("reshape w: {e:?}")))?;
        let out = exe
            .execute::<xla::Literal>(&[lx, ln, lw])
            .map_err(|e| Error::Runtime(format!("execute: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("sync: {e:?}")))?;
        let t = out
            .to_tuple1()
            .map_err(|e| Error::Runtime(format!("tuple: {e:?}")))?;
        t.to_vec::<f32>()
            .map_err(|e| Error::Runtime(format!("to_vec: {e:?}")))
    }

    /// Smallest min-plus bucket that fits `(n, d)` (see [`fit_bucket`]).
    pub fn fit_minplus(&self, n: usize, d: usize) -> Option<Bucket> {
        let buckets: Vec<Bucket> = self.minplus.keys().copied().collect();
        fit_bucket(&buckets, n, d)
    }

    /// Default artifact directory: `$PTSCOTCH_ARTIFACTS` or `artifacts/`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("PTSCOTCH_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Execution tests against real artifacts live in
    // rust/tests/xla_integration.rs (they need `make artifacts` first).

    #[test]
    fn load_missing_dir_is_clean_error() {
        match XlaRuntime::load(Path::new("/nonexistent/dir")) {
            Err(Error::NoArtifact(_)) => {}
            Err(e) => panic!("wrong error kind: {e}"),
            Ok(_) => panic!("load must fail on a missing dir"),
        }
    }

    #[test]
    fn bucket_ordering_picks_smallest_fit() {
        // BTreeMap ordering: (n, d) lexicographic. fit must prefer the
        // smallest n that fits, regardless of the listing order.
        let b1 = Bucket { n: 256, d: 32 };
        let b2 = Bucket { n: 1024, d: 32 };
        assert!(b1 < b2);
        assert_eq!(fit_bucket(&[b2, b1], 300, 16), Some(b2));
        assert_eq!(fit_bucket(&[b2, b1], 100, 16), Some(b1));
        assert_eq!(fit_bucket(&[b2, b1], 100, 64), None);
    }
}
