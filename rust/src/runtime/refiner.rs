//! The XLA-backed diffusion band refiner — the three-layer hot path.
//!
//! Band refinement is where PT-Scotch spends its quality budget (§3.3);
//! the diffusion smoother (the paper's cited scalable alternative [28])
//! is the numeric part, and here it runs on the AOT-compiled Pallas/JAX
//! artifact through PJRT. Packing, separator reconstruction and the FM
//! polish stay in Rust; Python is never involved at order time. Band
//! graphs that fit no bucket (too large / too high degree) fall back to
//! the bit-identical CPU reference ([`CpuDiffusionRefiner`]).

use super::ell::pack_ell_clamped;
use super::SharedRuntime;
use crate::rng::Rng;
use crate::sep::band::BandGraph;
use crate::sep::diffusion::{field_to_separator, initial_field, CpuDiffusionRefiner};
use crate::sep::fm::{fm_refine, FmParams};
use crate::sep::BandRefiner;
use std::sync::atomic::{AtomicU64, Ordering as AOrd};

/// Diffusion refiner running on the XLA runtime.
///
/// The runtime is shared behind a mutex: PJRT executions from the
/// multi-sequential per-rank refinements are serialized, which is
/// harmless on this single-core container and keeps the client single-
/// threaded (the paper's multi-centralized copies are genuinely
/// independent processes; see DESIGN.md §3).
pub struct DiffusionRefiner {
    runtime: SharedRuntime,
    /// Total diffusion iterations (rounded up to whole artifact calls).
    pub iterations: usize,
    /// FM polish parameters.
    pub fm: FmParams,
    cpu_fallback: CpuDiffusionRefiner,
    /// Telemetry: XLA executions and CPU fallbacks (for the perf logs).
    pub xla_calls: AtomicU64,
    /// Telemetry: band graphs that fit no bucket.
    pub fallbacks: AtomicU64,
}

impl DiffusionRefiner {
    /// Wrap a loaded runtime.
    pub fn new(runtime: SharedRuntime) -> DiffusionRefiner {
        DiffusionRefiner {
            runtime,
            iterations: 32,
            fm: FmParams::default(),
            cpu_fallback: CpuDiffusionRefiner::default(),
            xla_calls: AtomicU64::new(0),
            fallbacks: AtomicU64::new(0),
        }
    }

    /// Run the diffusion field through the artifact; `None` → no bucket.
    fn xla_field(&self, band: &BandGraph) -> Option<Vec<f32>> {
        let g = &band.graph;
        let guard = self.runtime.lock().unwrap();
        let rt = &guard.0;
        // Anchor rows are clamped, so their (huge) degree is irrelevant
        // to the bucket fit — only real band vertices bound `d`.
        let anchors = [band.anchor0, band.anchor1];
        let d_real = (0..g.n())
            .filter(|v| !anchors.contains(v))
            .map(|v| g.degree(v))
            .max()
            .unwrap_or(0);
        let bucket = rt.fit_diffusion(g.n(), d_real)?;
        let ell = pack_ell_clamped(g, bucket.n, bucket.d, &anchors)?;
        let mut x = vec![0f32; bucket.n];
        x[..g.n()].copy_from_slice(&initial_field(&band.state));
        let mut mask = vec![0f32; bucket.n];
        let mut vals = vec![0f32; bucket.n];
        mask[band.anchor0] = 1.0;
        vals[band.anchor0] = -1.0;
        mask[band.anchor1] = 1.0;
        vals[band.anchor1] = 1.0;
        // Anchors must be clamped before the first gather.
        x[band.anchor0] = -1.0;
        x[band.anchor1] = 1.0;
        let calls = self.iterations.div_ceil(rt.steps_per_call);
        for _ in 0..calls {
            x = rt.diffusion_step(bucket, &x, &mask, &vals, &ell).ok()?;
            self.xla_calls.fetch_add(1, AOrd::Relaxed);
        }
        x.truncate(g.n());
        Some(x)
    }
}

impl BandRefiner for DiffusionRefiner {
    fn refine_band(&self, band: &mut BandGraph, rng: &mut Rng) {
        match self.xla_field(band) {
            Some(x) => {
                let candidate = field_to_separator(band, &x);
                debug_assert!(candidate.validate(&band.graph).is_ok());
                if candidate.quality_key() < band.state.quality_key() {
                    band.state = candidate;
                }
                fm_refine(&band.graph, &mut band.state, &band.locked, &self.fm, rng);
            }
            None => {
                self.fallbacks.fetch_add(1, AOrd::Relaxed);
                self.cpu_fallback.refine_band(band, rng);
            }
        }
    }

    fn name(&self) -> &'static str {
        "diffusion+fm(xla)"
    }
}

// Execution tests against real artifacts live in
// rust/tests/xla_integration.rs; unit tests here only cover wiring that
// needs no artifacts.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::sep::{SepState, P0, P1, SEP};

    #[test]
    fn falls_back_without_runtime_buckets() {
        // A runtime with an empty manifest directory cannot be built;
        // instead simulate "no bucket fits" by loading nothing: the
        // refiner must then behave exactly like the CPU fallback.
        let g = crate::graph::generators::grid2d(9, 5);
        let part: Vec<u8> = (0..45)
            .map(|v| {
                let x = v % 9;
                use std::cmp::Ordering::*;
                match x.cmp(&4) {
                    Less => P0,
                    Equal => SEP,
                    Greater => P1,
                }
            })
            .collect();
        let state = SepState::from_parts(&g, part);
        let mut band = crate::sep::band::extract_band(&g, &state, 2).unwrap();
        let cpu = CpuDiffusionRefiner::default();
        let mut rng = Rng::new(3);
        let before = band.state.quality_key();
        cpu.refine_band(&mut band, &mut rng);
        band.state.validate(&band.graph).unwrap();
        assert!(band.state.quality_key() <= before);
    }
}
