//! ELL packing of band graphs for the XLA kernels.
//!
//! The Pallas kernel consumes a fixed-shape `(n, d)` padded neighbor
//! table (`nbr`, i32) with parallel weights (`w`, f32, 0 on padding), the
//! TPU-friendly layout chosen in DESIGN.md §Hardware-Adaptation: rows are
//! unit-stride VMEM tiles, the gather never leaves the block, and padded
//! lanes vanish under the weighted reduction.
//!
//! Two packings exist: [`pack_ell`]/[`pack_ell_clamped`] lay out a
//! centralized [`Graph`] (the sequential hot path), and [`pack_ell_dist`]
//! lays out one rank's slice of a [`DGraph`] — local rows first, then one
//! row per ghost vertex, all in the graph's native gst indexing. Ghost
//! rows are packed **empty** (weight 0) and executed **clamped**
//! (`fixed_mask` 1), so the kernel treats them as fixed boundary values
//! that the caller re-fills from a halo exchange between fused calls
//! (DESIGN.md §4.2).

use crate::dist::dgraph::DGraph;
use crate::graph::Graph;

/// A graph packed into a fixed `(n, d)` ELL block.
#[derive(Clone, Debug)]
pub struct EllPacked {
    /// Bucket rows (`≥ graph.n()`; padded rows are all-zero weight).
    pub n: usize,
    /// Bucket columns (`≥ max degree`).
    pub d: usize,
    /// Row-major neighbor indices; padding points at row 0 with weight 0.
    pub nbr: Vec<i32>,
    /// Row-major edge weights; 0 marks padding.
    pub w: Vec<f32>,
}

impl EllPacked {
    /// VMEM-footprint estimate of one `(rows, d)` tile in bytes — used by
    /// the §Perf analysis (nbr i32 + w f32 + x f32 gathered + out f32).
    pub fn tile_bytes(rows: usize, d: usize) -> usize {
        rows * d * (4 + 4) + rows * (4 + 4)
    }
}

/// Pack `g` into an `(n, d)` ELL block. Returns `None` if the graph does
/// not fit (too many vertices or a vertex degree exceeding `d`) — the
/// caller falls back to the CPU path.
pub fn pack_ell(g: &Graph, n: usize, d: usize) -> Option<EllPacked> {
    pack_ell_clamped(g, n, d, &[])
}

/// Like [`pack_ell`], but rows in `clamped` are packed **empty** (all
/// weights 0) and excluded from the degree-fit check.
///
/// This is the band-anchor case (§Perf opt 1): an anchor is connected to
/// the whole last band layer, so its degree far exceeds any bucket width
/// — but its *output* is always overwritten by the fixed-value clamp, so
/// its row never needs computing. Its value is still gathered correctly
/// by its neighbors' rows. Without this, every mesh band fell back to
/// the CPU path.
///
/// ```
/// use ptscotch::graph::GraphBuilder;
/// use ptscotch::runtime::{pack_ell, pack_ell_clamped};
///
/// // Two 2-paths plus a hub (vertex 4) adjacent to everything: the
/// // hub's degree 4 exceeds the bucket width 2, so the plain packing
/// // refuses…
/// let mut b = GraphBuilder::new(5);
/// b.add_edge(0, 1);
/// b.add_edge(2, 3);
/// for v in 0..4 {
///     b.add_edge(4, v);
/// }
/// let g = b.build().unwrap();
/// assert!(pack_ell(&g, 8, 2).is_none());
///
/// // …but clamping the hub (an anchor whose output is overwritten
/// // anyway) packs its row empty and the bucket fits. Its neighbors
/// // still gather its clamped value through their own rows.
/// let e = pack_ell_clamped(&g, 8, 2, &[4]).unwrap();
/// assert_eq!(e.w[4 * e.d..5 * e.d], [0.0, 0.0]); // hub row is empty
/// assert!(e.nbr[..2].contains(&4)); // vertex 0 still points at the hub
/// ```
pub fn pack_ell_clamped(g: &Graph, n: usize, d: usize, clamped: &[usize]) -> Option<EllPacked> {
    if g.n() > n {
        return None;
    }
    let is_clamped = |v: usize| clamped.contains(&v);
    let fit = (0..g.n()).all(|v| is_clamped(v) || g.degree(v) <= d);
    if !fit {
        return None;
    }
    let mut nbr = vec![0i32; n * d];
    let mut w = vec![0f32; n * d];
    for v in 0..g.n() {
        if is_clamped(v) {
            continue; // output overwritten by the clamp; row stays empty
        }
        let row = v * d;
        for (k, (&u, &ew)) in g
            .neighbors(v)
            .iter()
            .zip(g.edge_weights(v))
            .enumerate()
        {
            nbr[row + k] = u as i32;
            w[row + k] = ew as f32;
        }
    }
    Some(EllPacked { n, d, nbr, w })
}

/// Pack one rank's slice of a distributed band graph into an `(n, d)`
/// ELL block: local rows `0..nloc` first, then one row per ghost vertex
/// (`nloc..nloc + ngst`), exactly the graph's gst indexing — so the
/// packed neighbor table needs **no renumbering** and the field vector
/// is `[local values | ghost values | padding]`.
///
/// Ghost rows and the rows in `clamped` (the anchors, on their owner
/// rank) are packed empty and excluded from the degree-fit check: both
/// are executed under the kernel's fixed-value clamp, so their outputs
/// are never computed — ghosts hold the boundary values the caller
/// re-fills from a halo exchange between fused kernel calls, anchors
/// hold ∓1. Returns `None` when the slice does not fit (too many rows
/// or an unclamped local vertex whose degree exceeds `d`); the caller
/// then falls back to the CPU sweep path on **every** rank (the fit
/// verdict must be agreed collectively — see
/// `dist::ddiffusion::diffuse_band_dist_engine`).
pub fn pack_ell_dist(dg: &DGraph, n: usize, d: usize, clamped: &[usize]) -> Option<EllPacked> {
    let nloc = dg.nloc();
    let rows = nloc + dg.ghosts.len();
    if rows > n {
        return None;
    }
    let is_clamped = |v: usize| clamped.contains(&v);
    let fit = (0..nloc).all(|v| is_clamped(v) || dg.neighbors_gst(v).len() <= d);
    if !fit {
        return None;
    }
    let mut nbr = vec![0i32; n * d];
    let mut w = vec![0f32; n * d];
    for v in 0..nloc {
        if is_clamped(v) {
            continue; // output overwritten by the clamp; row stays empty
        }
        let row = v * d;
        for (k, (&a, &ew)) in dg
            .neighbors_gst(v)
            .iter()
            .zip(dg.edge_weights_gst(v))
            .enumerate()
        {
            nbr[row + k] = a as i32;
            w[row + k] = ew as f32;
        }
    }
    // Ghost rows stay all-zero: clamped boundary values, never computed.
    Some(EllPacked { n, d, nbr, w })
}

/// Pure-Rust reference of one fused artifact call: `steps` rounds of the
/// anchor clamp `x = mask·vals + (1−mask)·x` followed by the damped
/// weighted average, then one final clamp — bit-for-bit the semantics of
/// `python/compile/model.py::diffusion_steps` up to reduction order.
///
/// Used to keep a rank in collective lockstep when a PJRT execution
/// fails mid-run (the fit verdict was already agreed, so bailing out
/// unilaterally would desynchronize the halo-exchange cadence), and by
/// the tests pinning the artifact contract.
pub fn ell_fused_reference(
    e: &EllPacked,
    x: &[f32],
    fixed_mask: &[f32],
    fixed_vals: &[f32],
    steps: usize,
    damping: f32,
) -> Vec<f32> {
    let clamp = |x: &mut [f32]| {
        for v in 0..e.n {
            x[v] = fixed_mask[v] * fixed_vals[v] + (1.0 - fixed_mask[v]) * x[v];
        }
    };
    let mut x = x.to_vec();
    for _ in 0..steps {
        clamp(&mut x);
        x = ell_weighted_average(e, &x, damping);
    }
    clamp(&mut x);
    x
}

/// The min-plus kernels' "+infinity": unreached distances. Matches the
/// `3.0e38` the Pallas kernel and its oracle use for masked lanes
/// (`python/compile/kernels/ell_spmv.py::_minplus_kernel`) — close to
/// but below `f32::MAX`, and `MINPLUS_INF + 1.0 == MINPLUS_INF` in f32,
/// so relaxation through an unreached neighbor can never overflow or
/// win a min.
pub const MINPLUS_INF: f32 = 3.0e38;

/// Pure-Rust reference of one min-plus (BFS relaxation) artifact call:
/// `out[v] = min(dist[v], min over unpadded lanes of dist[nbr] + 1)` —
/// bit-for-bit the semantics of `python/compile/model.py::minplus_step`
/// (hop counts: the `+1` is per arc regardless of weight; weights only
/// gate padding, `w > 0`). Rows packed empty (ghost rows of
/// [`pack_ell_dist`], padding) therefore keep their value — exactly the
/// fixed-boundary behavior the distributed band BFS relies on between
/// halo exchanges.
///
/// Used to keep a rank in collective lockstep when a PJRT execution
/// fails mid-run (the fit verdict was already agreed), and by the tests
/// pinning the artifact contract.
pub fn ell_minplus_reference(e: &EllPacked, dist: &[f32]) -> Vec<f32> {
    debug_assert_eq!(dist.len(), e.n);
    let mut out = vec![0f32; e.n];
    for v in 0..e.n {
        let row = v * e.d;
        let mut best = dist[v];
        for k in 0..e.d {
            if e.w[row + k] > 0.0 {
                let c = dist[e.nbr[row + k] as usize] + 1.0;
                if c < best {
                    best = c;
                }
            }
        }
        out[v] = best;
    }
    out
}

/// Reference (pure-Rust) evaluation of the packed weighted-average
/// operator — must agree with both [`crate::sep::diffusion`] on the
/// unpacked graph and the XLA artifact on the packed one.
pub fn ell_weighted_average(e: &EllPacked, x: &[f32], damping: f32) -> Vec<f32> {
    let mut out = vec![0f32; e.n];
    for v in 0..e.n {
        let row = v * e.d;
        let mut num = 0f32;
        let mut den = 0f32;
        for k in 0..e.d {
            let wv = e.w[row + k];
            num += wv * x[e.nbr[row + k] as usize];
            den += wv;
        }
        out[v] = if den > 0.0 { damping * num / den } else { 0.0 };
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::sep::diffusion::diffusion_iterations;

    #[test]
    fn pack_rejects_oversize() {
        let g = generators::grid2d(20, 20);
        assert!(pack_ell(&g, 100, 8).is_none()); // n too small
        assert!(pack_ell(&g, 400, 2).is_none()); // degree too small
        assert!(pack_ell(&g, 400, 8).is_some());
    }

    #[test]
    fn packed_average_matches_csr_reference() {
        let g = generators::irregular_mesh(9, 7, 3);
        let n = g.n();
        let e = pack_ell(&g, 128, 16).unwrap();
        let mut x = vec![0f32; 128];
        for v in 0..n {
            x[v] = (v as f32 * 0.37).sin();
        }
        // One CSR-side iteration with no anchors (use a fake isolated
        // anchor pair at padded rows which stay 0).
        let csr = {
            let mut next = vec![0f32; n];
            for v in 0..n {
                let mut num = 0f32;
                let mut den = 0f32;
                for (&u, &w) in g.neighbors(v).iter().zip(g.edge_weights(v)) {
                    num += w as f32 * x[u as usize];
                    den += w as f32;
                }
                next[v] = if den > 0.0 { 0.9 * num / den } else { 0.0 };
            }
            next
        };
        let ell = ell_weighted_average(&e, &x, 0.9);
        for v in 0..n {
            assert!(
                (csr[v] - ell[v]).abs() < 1e-5,
                "row {v}: {} vs {}",
                csr[v],
                ell[v]
            );
        }
        // Padded rows produce exactly 0.
        for v in n..128 {
            assert_eq!(ell[v], 0.0);
        }
    }

    #[test]
    fn pack_dist_slice_layout_and_fit() {
        use crate::comm;
        use std::sync::Arc;
        let g = Arc::new(generators::grid2d(10, 8));
        let (ok, _) = comm::run(3, move |c| {
            let dg = DGraph::from_global(&c, &g);
            let nloc = dg.nloc();
            let ngst = dg.ghosts.len();
            let rows = nloc + ngst;
            // Too few rows or too narrow a width must refuse (every grid
            // vertex has degree ≥ 2, the interior 4).
            let mut ok = pack_ell_dist(&dg, rows - 1, 8, &[]).is_none();
            ok &= pack_ell_dist(&dg, rows + 4, 1, &[]).is_none();
            let e = pack_ell_dist(&dg, rows + 4, 4, &[]).unwrap();
            // Local rows carry the slice's arcs verbatim in gst
            // indexing, zero-padded to the bucket width.
            for v in 0..nloc {
                let row = v * e.d;
                let deg = dg.neighbors_gst(v).len();
                for (k, (&a, &w)) in dg
                    .neighbors_gst(v)
                    .iter()
                    .zip(dg.edge_weights_gst(v))
                    .enumerate()
                {
                    ok &= e.nbr[row + k] == a as i32 && e.w[row + k] == w as f32;
                }
                ok &= e.w[row + deg..row + e.d].iter().all(|&w| w == 0.0);
            }
            // Ghost rows and padding are empty: fixed boundary values,
            // never computed.
            for r in nloc..e.n {
                ok &= e.w[r * e.d..(r + 1) * e.d].iter().all(|&w| w == 0.0);
            }
            ok
        });
        assert!(ok.iter().all(|&x| x));
    }

    #[test]
    fn minplus_reference_hops_and_fixed_rows() {
        // Path 0–1–2 with non-unit weights: hops must still cost 1
        // (weights only gate padding), and the empty padded row must
        // keep its value — the ghost-row boundary contract.
        let mut b = crate::graph::GraphBuilder::new(3);
        b.add_edge_w(0, 1, 7);
        b.add_edge_w(1, 2, 3);
        let g = b.build().unwrap();
        let e = pack_ell(&g, 4, 2).unwrap();
        let d0 = vec![0.0, MINPLUS_INF, MINPLUS_INF, MINPLUS_INF];
        let d1 = ell_minplus_reference(&e, &d0);
        assert_eq!(d1, vec![0.0, 1.0, MINPLUS_INF, MINPLUS_INF]);
        let d2 = ell_minplus_reference(&e, &d1);
        assert_eq!(d2, vec![0.0, 1.0, 2.0, MINPLUS_INF]);
    }

    #[test]
    fn fused_reference_clamps_and_averages() {
        // One fused call at steps=1 must equal: clamp, one weighted
        // average, clamp — pinning the artifact's clamp placement.
        let g = generators::grid2d(4, 3);
        let e = pack_ell(&g, 16, 4).unwrap();
        let mut x = vec![0f32; 16];
        x[0] = -1.0;
        x[11] = 1.0;
        let mut mask = vec![0f32; 16];
        let mut vals = vec![0f32; 16];
        mask[0] = 1.0;
        vals[0] = -1.0;
        mask[11] = 1.0;
        vals[11] = 1.0;
        let got = ell_fused_reference(&e, &x, &mask, &vals, 1, 0.95);
        let mut want = ell_weighted_average(&e, &x, 0.95);
        want[0] = -1.0;
        want[11] = 1.0;
        assert_eq!(got, want);
        // Clamped rows always exit at their fixed values.
        assert_eq!(got[0], -1.0);
        assert_eq!(got[11], 1.0);
    }

    #[test]
    fn ell_iterations_match_band_reference() {
        // Full loop equivalence against sep::diffusion on a band graph.
        let g = generators::grid2d(10, 6);
        let part: Vec<u8> = (0..60)
            .map(|v| {
                let x = v % 10;
                use std::cmp::Ordering::*;
                match x.cmp(&5) {
                    Less => crate::sep::P0,
                    Equal => crate::sep::SEP,
                    Greater => crate::sep::P1,
                }
            })
            .collect();
        let state = crate::sep::SepState::from_parts(&g, part);
        let band = crate::sep::band::extract_band(&g, &state, 2).unwrap();
        let nb = band.graph.n();
        let e = pack_ell(&band.graph, 64, 16).unwrap();
        let x0 = crate::sep::diffusion::initial_field(&band.state);
        let want =
            diffusion_iterations(&band.graph, x0.clone(), band.anchor0, band.anchor1, 4, 0.95);
        // ELL loop with anchor clamping between steps.
        let mut x = vec![0f32; 64];
        x[..nb].copy_from_slice(&x0);
        for _ in 0..4 {
            x[band.anchor0] = -1.0;
            x[band.anchor1] = 1.0;
            x = ell_weighted_average(&e, &x, 0.95);
        }
        x[band.anchor0] = -1.0;
        x[band.anchor1] = 1.0;
        for v in 0..nb {
            assert!(
                (x[v] - want[v]).abs() < 1e-5,
                "vertex {v}: {} vs {}",
                x[v],
                want[v]
            );
        }
    }
}
