//! ELL packing of band graphs for the XLA kernels.
//!
//! The Pallas kernel consumes a fixed-shape `(n, d)` padded neighbor
//! table (`nbr`, i32) with parallel weights (`w`, f32, 0 on padding), the
//! TPU-friendly layout chosen in DESIGN.md §Hardware-Adaptation: rows are
//! unit-stride VMEM tiles, the gather never leaves the block, and padded
//! lanes vanish under the weighted reduction.

use crate::graph::Graph;

/// A graph packed into a fixed `(n, d)` ELL block.
#[derive(Clone, Debug)]
pub struct EllPacked {
    /// Bucket rows (`≥ graph.n()`; padded rows are all-zero weight).
    pub n: usize,
    /// Bucket columns (`≥ max degree`).
    pub d: usize,
    /// Row-major neighbor indices; padding points at row 0 with weight 0.
    pub nbr: Vec<i32>,
    /// Row-major edge weights; 0 marks padding.
    pub w: Vec<f32>,
}

impl EllPacked {
    /// VMEM-footprint estimate of one `(rows, d)` tile in bytes — used by
    /// the §Perf analysis (nbr i32 + w f32 + x f32 gathered + out f32).
    pub fn tile_bytes(rows: usize, d: usize) -> usize {
        rows * d * (4 + 4) + rows * (4 + 4)
    }
}

/// Pack `g` into an `(n, d)` ELL block. Returns `None` if the graph does
/// not fit (too many vertices or a vertex degree exceeding `d`) — the
/// caller falls back to the CPU path.
pub fn pack_ell(g: &Graph, n: usize, d: usize) -> Option<EllPacked> {
    pack_ell_clamped(g, n, d, &[])
}

/// Like [`pack_ell`], but rows in `clamped` are packed **empty** (all
/// weights 0) and excluded from the degree-fit check.
///
/// This is the band-anchor case (§Perf opt 1): an anchor is connected to
/// the whole last band layer, so its degree far exceeds any bucket width
/// — but its *output* is always overwritten by the fixed-value clamp, so
/// its row never needs computing. Its value is still gathered correctly
/// by its neighbors' rows. Without this, every mesh band fell back to
/// the CPU path.
pub fn pack_ell_clamped(g: &Graph, n: usize, d: usize, clamped: &[usize]) -> Option<EllPacked> {
    if g.n() > n {
        return None;
    }
    let is_clamped = |v: usize| clamped.contains(&v);
    let fit = (0..g.n()).all(|v| is_clamped(v) || g.degree(v) <= d);
    if !fit {
        return None;
    }
    let mut nbr = vec![0i32; n * d];
    let mut w = vec![0f32; n * d];
    for v in 0..g.n() {
        if is_clamped(v) {
            continue; // output overwritten by the clamp; row stays empty
        }
        let row = v * d;
        for (k, (&u, &ew)) in g
            .neighbors(v)
            .iter()
            .zip(g.edge_weights(v))
            .enumerate()
        {
            nbr[row + k] = u as i32;
            w[row + k] = ew as f32;
        }
    }
    Some(EllPacked { n, d, nbr, w })
}

/// Reference (pure-Rust) evaluation of the packed weighted-average
/// operator — must agree with both [`crate::sep::diffusion`] on the
/// unpacked graph and the XLA artifact on the packed one.
pub fn ell_weighted_average(e: &EllPacked, x: &[f32], damping: f32) -> Vec<f32> {
    let mut out = vec![0f32; e.n];
    for v in 0..e.n {
        let row = v * e.d;
        let mut num = 0f32;
        let mut den = 0f32;
        for k in 0..e.d {
            let wv = e.w[row + k];
            num += wv * x[e.nbr[row + k] as usize];
            den += wv;
        }
        out[v] = if den > 0.0 { damping * num / den } else { 0.0 };
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::sep::diffusion::diffusion_iterations;

    #[test]
    fn pack_rejects_oversize() {
        let g = generators::grid2d(20, 20);
        assert!(pack_ell(&g, 100, 8).is_none()); // n too small
        assert!(pack_ell(&g, 400, 2).is_none()); // degree too small
        assert!(pack_ell(&g, 400, 8).is_some());
    }

    #[test]
    fn packed_average_matches_csr_reference() {
        let g = generators::irregular_mesh(9, 7, 3);
        let n = g.n();
        let e = pack_ell(&g, 128, 16).unwrap();
        let mut x = vec![0f32; 128];
        for v in 0..n {
            x[v] = (v as f32 * 0.37).sin();
        }
        // One CSR-side iteration with no anchors (use a fake isolated
        // anchor pair at padded rows which stay 0).
        let csr = {
            let mut next = vec![0f32; n];
            for v in 0..n {
                let mut num = 0f32;
                let mut den = 0f32;
                for (&u, &w) in g.neighbors(v).iter().zip(g.edge_weights(v)) {
                    num += w as f32 * x[u as usize];
                    den += w as f32;
                }
                next[v] = if den > 0.0 { 0.9 * num / den } else { 0.0 };
            }
            next
        };
        let ell = ell_weighted_average(&e, &x, 0.9);
        for v in 0..n {
            assert!(
                (csr[v] - ell[v]).abs() < 1e-5,
                "row {v}: {} vs {}",
                csr[v],
                ell[v]
            );
        }
        // Padded rows produce exactly 0.
        for v in n..128 {
            assert_eq!(ell[v], 0.0);
        }
    }

    #[test]
    fn ell_iterations_match_band_reference() {
        // Full loop equivalence against sep::diffusion on a band graph.
        let g = generators::grid2d(10, 6);
        let part: Vec<u8> = (0..60)
            .map(|v| {
                let x = v % 10;
                use std::cmp::Ordering::*;
                match x.cmp(&5) {
                    Less => crate::sep::P0,
                    Equal => crate::sep::SEP,
                    Greater => crate::sep::P1,
                }
            })
            .collect();
        let state = crate::sep::SepState::from_parts(&g, part);
        let band = crate::sep::band::extract_band(&g, &state, 2).unwrap();
        let nb = band.graph.n();
        let e = pack_ell(&band.graph, 64, 16).unwrap();
        let x0 = crate::sep::diffusion::initial_field(&band.state);
        let want =
            diffusion_iterations(&band.graph, x0.clone(), band.anchor0, band.anchor1, 4, 0.95);
        // ELL loop with anchor clamping between steps.
        let mut x = vec![0f32; 64];
        x[..nb].copy_from_slice(&x0);
        for _ in 0..4 {
            x[band.anchor0] = -1.0;
            x[band.anchor1] = 1.0;
            x = ell_weighted_average(&e, &x, 0.95);
        }
        x[band.anchor0] = -1.0;
        x[band.anchor1] = 1.0;
        for v in 0..nb {
            assert!(
                (x[v] - want[v]).abs() < 1e-5,
                "vertex {v}: {} vs {}",
                x[v],
                want[v]
            );
        }
    }
}
