//! Vertex Fiduccia–Mattheyses separator refinement (Hendrickson &
//! Rothberg [16] style), the local optimization at the core of both the
//! sequential pipeline and the multi-sequential band refinement (§3.3).
//!
//! A *move* takes a separator vertex `v` into part `p`; every neighbor of
//! `v` in the opposite part is pulled into the separator, which exactly
//! preserves the no-0–1-edge invariant. The gain of the move is the
//! separator-weight decrease `vwgt[v] − Σ vwgt[pulled]`. Negative-gain
//! moves are allowed (hill climbing) with rollback to the best visited
//! state; `locked` vertices (the band-graph anchors) can neither move nor
//! be pulled into the separator — this is what confines refined separators
//! to the band (§3.3's "pre-constrained banding").

use super::{SepState, SEP};
use crate::graph::Graph;
use crate::rng::Rng;
use std::collections::BinaryHeap;

/// FM tuning parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct FmParams {
    /// Maximum refinement passes (each pass ends in a rollback-to-best).
    pub max_passes: usize,
    /// Consecutive non-improving moves tolerated before a pass ends.
    pub max_neg_moves: usize,
    /// Relative part-imbalance tolerance: `|w0−w1| ≤ max(eps·total, 2·max_vwgt)`.
    pub balance_eps: f64,
}

impl Default for FmParams {
    fn default() -> Self {
        FmParams {
            max_passes: 8,
            max_neg_moves: 80,
            balance_eps: 0.05,
        }
    }
}

/// Gain of moving separator vertex `v` to part `p`.
#[inline]
fn move_gain(g: &Graph, part: &[u8], v: usize, p: u8) -> i64 {
    let other = 1 - p;
    let mut pulled = 0i64;
    for &u in g.neighbors(v) {
        if part[u as usize] == other {
            pulled += g.vwgt[u as usize];
        }
    }
    g.vwgt[v] - pulled
}

/// Refine `state` in place; returns the final separator weight.
///
/// `locked[v]` marks vertices that must keep their current part (band
/// anchors). Passing an empty slice means nothing is locked.
pub fn fm_refine(
    g: &Graph,
    state: &mut SepState,
    locked: &[bool],
    params: &FmParams,
    rng: &mut Rng,
) -> i64 {
    let n = g.n();
    debug_assert!(locked.is_empty() || locked.len() == n);
    let is_locked = |v: usize| !locked.is_empty() && locked[v];
    let total = g.total_vwgt();
    let max_imb = ((params.balance_eps * total as f64) as i64).max(2 * g.max_vwgt());

    let mut version: Vec<u32> = vec![0; n];
    // Heap entries: (gain, random tie-break, vertex, target part, version).
    let mut heap: BinaryHeap<(i64, u64, u32, u8, u32)> = BinaryHeap::new();
    let mut moved = vec![false; n];
    // Rollback log: (vertex, previous part).
    let mut log: Vec<(u32, u8)> = Vec::new();

    for _pass in 0..params.max_passes {
        heap.clear();
        log.clear();
        for f in moved.iter_mut() {
            *f = false;
        }
        for v in 0..n {
            if state.part[v] == SEP && !is_locked(v) {
                for p in 0..2u8 {
                    heap.push((
                        move_gain(g, &state.part, v, p),
                        rng.next_u64(),
                        v as u32,
                        p,
                        version[v],
                    ));
                }
            }
        }
        let pass_start_key = state.quality_key();
        let mut best_key = pass_start_key;
        let mut best_len = 0usize;
        let mut neg_streak = 0usize;

        'moves: while let Some((gain, _tie, v32, p, ver)) = heap.pop() {
            let v = v32 as usize;
            if ver != version[v] || state.part[v] != SEP || moved[v] || is_locked(v) {
                continue;
            }
            debug_assert_eq!(gain, move_gain(g, &state.part, v, p));
            let other = 1 - p;
            // Pulled weight + locked-pull check.
            let mut pulled = 0i64;
            for &u in g.neighbors(v) {
                let u = u as usize;
                if state.part[u] == other {
                    if is_locked(u) {
                        continue 'moves; // would drag an anchor into the separator
                    }
                    pulled += g.vwgt[u];
                }
            }
            // Balance feasibility.
            let mut w = state.wgts;
            w[p as usize] += g.vwgt[v];
            w[other as usize] -= pulled;
            w[2] += pulled - g.vwgt[v];
            let imb_new = (w[0] - w[1]).abs();
            if imb_new > max_imb && imb_new >= state.imbalance() {
                continue;
            }

            // Apply the move.
            log.push((v32, SEP));
            state.part[v] = p;
            moved[v] = true;
            let mut touched: Vec<usize> = Vec::new();
            let mut pulled_list: Vec<usize> = Vec::new();
            for &u in g.neighbors(v) {
                let u = u as usize;
                if state.part[u] == other {
                    log.push((u as u32, other));
                    state.part[u] = SEP;
                    pulled_list.push(u);
                    touched.push(u);
                } else if state.part[u] == SEP {
                    touched.push(u);
                }
            }
            state.wgts = w;
            // Pulled vertices' separator neighbors also see changed gains.
            for &u in &pulled_list {
                for &t in g.neighbors(u) {
                    let t = t as usize;
                    if state.part[t] == SEP {
                        touched.push(t);
                    }
                }
            }
            touched.sort_unstable();
            touched.dedup();
            for &t in &touched {
                if state.part[t] == SEP && !moved[t] && !is_locked(t) {
                    version[t] = version[t].wrapping_add(1);
                    for q in 0..2u8 {
                        heap.push((
                            move_gain(g, &state.part, t, q),
                            rng.next_u64(),
                            t as u32,
                            q,
                            version[t],
                        ));
                    }
                }
            }

            // Best-state tracking with hill-climbing budget.
            let key = state.quality_key();
            if key < best_key {
                best_key = key;
                best_len = log.len();
                neg_streak = 0;
            } else {
                neg_streak += 1;
                if neg_streak > params.max_neg_moves {
                    break;
                }
            }
        }

        // Roll back to the best prefix of the move log.
        while log.len() > best_len {
            let (v32, old) = log.pop().unwrap();
            let v = v32 as usize;
            let cur = state.part[v];
            state.wgts[cur as usize] -= g.vwgt[v];
            state.wgts[old as usize] += g.vwgt[v];
            state.part[v] = old;
        }
        debug_assert!(state.validate(g).is_ok());
        if best_key >= pass_start_key {
            break; // pass brought no improvement
        }
    }
    state.sep_weight()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, GraphBuilder};
    use crate::sep::initial::greedy_graph_growing;
    use crate::sep::{P0, P1};

    fn refine(g: &Graph, state: &mut SepState, seed: u64) -> i64 {
        fm_refine(g, state, &[], &FmParams::default(), &mut Rng::new(seed))
    }

    #[test]
    fn fm_never_worsens() {
        let g = generators::grid2d(16, 16);
        let mut rng = Rng::new(5);
        let mut s = greedy_graph_growing(&g, 2, &mut rng);
        let before = s.quality_key();
        refine(&g, &mut s, 6);
        s.validate(&g).unwrap();
        assert!(s.quality_key() <= before);
    }

    #[test]
    fn fm_finds_single_cut_vertex() {
        // Two 10-cliques joined by one articulation vertex 20: the optimal
        // separator is exactly {20}.
        let mut b = GraphBuilder::new(21);
        for u in 0..10 {
            for v in (u + 1)..10 {
                b.add_edge(u, v);
            }
        }
        for u in 10..20 {
            for v in (u + 1)..20 {
                b.add_edge(u, v);
            }
        }
        for u in 0..10 {
            b.add_edge(u, 20);
        }
        for u in 10..20 {
            b.add_edge(u, 20);
        }
        let g = b.build().unwrap();
        // Start from a deliberately bad separator: all of clique 1's
        // boundary-adjacent half in the separator.
        let mut part = vec![P0; 21];
        for v in 10..20 {
            part[v] = P1;
        }
        part[20] = SEP;
        part[0] = SEP;
        part[1] = SEP;
        let mut s = SepState::from_parts(&g, part);
        s.validate(&g).unwrap();
        refine(&g, &mut s, 7);
        s.validate(&g).unwrap();
        assert_eq!(s.sep_weight(), 1);
        assert_eq!(s.part[20], SEP);
    }

    #[test]
    fn fm_respects_locked_vertices() {
        let g = generators::path(7, 1);
        // Separator at vertex 1 (unbalanced); optimum would move it to 3.
        let mut part = vec![P0, SEP, P1, P1, P1, P1, P1];
        part[0] = P0;
        let mut s = SepState::from_parts(&g, part);
        s.validate(&g).unwrap();
        // Lock everything: nothing may change.
        let locked = vec![true; 7];
        let before = s.part.clone();
        fm_refine(&g, &mut s, &locked, &FmParams::default(), &mut Rng::new(8));
        assert_eq!(s.part, before);
    }

    #[test]
    fn fm_improves_off_center_path_separator() {
        let g = generators::path(31, 1);
        let mut part = vec![P1; 31];
        part[0] = P0;
        part[1] = SEP;
        for v in 2..31 {
            part[v] = P1;
        }
        let mut s = SepState::from_parts(&g, part);
        s.validate(&g).unwrap();
        let imb_before = s.imbalance();
        fm_refine(
            &g,
            &mut s,
            &[],
            &FmParams {
                max_passes: 30,
                max_neg_moves: 200,
                balance_eps: 0.05,
            },
            &mut Rng::new(9),
        );
        s.validate(&g).unwrap();
        assert_eq!(s.sep_weight(), 1);
        assert!(s.imbalance() < imb_before, "imbalance {} not improved", s.imbalance());
        assert!(s.imbalance() <= 3);
    }

    #[test]
    fn fm_grid_reaches_near_optimal_column() {
        let g = generators::grid2d(12, 12);
        let mut rng = Rng::new(10);
        let mut s = greedy_graph_growing(&g, 3, &mut rng);
        refine(&g, &mut s, 11);
        s.validate(&g).unwrap();
        // Optimal vertex separator of a 12×12 grid is one 12-vertex column.
        assert!(s.sep_weight() <= 14, "sep weight {}", s.sep_weight());
    }

    #[test]
    fn fm_handles_empty_separator() {
        let g = generators::path(4, 1);
        let mut s = SepState::from_parts(&g, vec![P0, P0, P0, P0]);
        let w = refine(&g, &mut s, 12);
        assert_eq!(w, 0);
        s.validate(&g).unwrap();
    }

    #[test]
    fn fm_is_deterministic() {
        let g = generators::irregular_mesh(14, 14, 3);
        let mut rng = Rng::new(13);
        let s0 = greedy_graph_growing(&g, 3, &mut rng);
        let mut a = s0.clone();
        let mut b = s0;
        refine(&g, &mut a, 14);
        refine(&g, &mut b, 14);
        assert_eq!(a.part, b.part);
    }
}
