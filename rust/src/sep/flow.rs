//! Flow-based band refinement — the third band refiner (DESIGN.md §4).
//!
//! The band around a projected separator is small by construction, which
//! makes an *exact* minimum vertex cut affordable there: grow a source
//! and a sink supernode by BFS from the two anchor sides inside the band
//! graph, run FIFO push-relabel with gap relabeling on the vertex-split
//! network to a max flow, recover the minimum vertex cut from the
//! residual reachability set, and pick the most-balanced minimum cut
//! among the cuts the residual graph admits (a sweep over the strongly
//! connected components of the residual graph in reverse topological
//! order). The candidate is committed only when strictly better under
//! the existing [`SepState::quality_key`], like every other refiner.
//!
//! The whole pass is deterministic — no RNG is consulted — so it
//! preserves the `executor=sim` ≡ `executor=threads` bit-identity
//! contract when dispatched from the distributed best-of-p selection.

use super::band::BandGraph;
use super::{BandRefiner, SepState, P0, P1, SEP};
use crate::rng::Rng;

/// Maximum-flow solver: FIFO push-relabel with gap relabeling, run to a
/// full max flow (excess is drained back to the source, so the residual
/// capacities describe a feasible maximum flow, not a preflow).
///
/// Arcs are stored in forward/reverse pairs (`e ^ 1` is the reverse of
/// `e`); `cap` holds *residual* capacities after [`MaxFlow::run`].
pub struct MaxFlow {
    n: usize,
    to: Vec<u32>,
    cap: Vec<i64>,
    adj: Vec<Vec<u32>>,
}

impl MaxFlow {
    /// An empty network on `n` nodes.
    pub fn new(n: usize) -> MaxFlow {
        MaxFlow {
            n,
            to: Vec::new(),
            cap: Vec::new(),
            adj: vec![Vec::new(); n],
        }
    }

    /// Add a directed arc `u -> v` of capacity `cap` (plus its zero-
    /// capacity reverse arc). Returns the forward arc id.
    pub fn add_arc(&mut self, u: usize, v: usize, cap: i64) -> usize {
        let e = self.to.len();
        self.to.push(v as u32);
        self.cap.push(cap);
        self.to.push(u as u32);
        self.cap.push(0);
        self.adj[u].push(e as u32);
        self.adj[v].push(e as u32 + 1);
        e
    }

    /// Compute the maximum `s -> t` flow and leave the residual
    /// capacities in `cap`. FIFO push-relabel with gap relabeling;
    /// heights are bounded by `2n`, and emptying a height bucket below
    /// `n` lifts every node stranded above the gap straight past `n`.
    pub fn run(&mut self, s: usize, t: usize) -> i64 {
        let n = self.n;
        if s == t {
            return 0;
        }
        let mut h = vec![0usize; n];
        let mut excess = vec![0i64; n];
        let mut count = vec![0usize; 2 * n + 2];
        let mut cur = vec![0usize; n];
        count[0] = n - 1;
        h[s] = n;
        count[n] += 1;
        let mut queue = std::collections::VecDeque::new();
        let mut queued = vec![false; n];
        let src_arcs = self.adj[s].clone();
        for &e in &src_arcs {
            let e = e as usize;
            let c = self.cap[e];
            if c <= 0 {
                continue;
            }
            let v = self.to[e] as usize;
            self.cap[e] = 0;
            self.cap[e ^ 1] += c;
            excess[v] += c;
            excess[s] -= c;
            if v != s && v != t && !queued[v] {
                queued[v] = true;
                queue.push_back(v);
            }
        }
        while let Some(u) = queue.pop_front() {
            queued[u] = false;
            while excess[u] > 0 {
                if cur[u] == self.adj[u].len() {
                    // Relabel (with the gap heuristic).
                    let old = h[u];
                    let mut nh = 2 * n + 1;
                    for &e in &self.adj[u] {
                        let e = e as usize;
                        if self.cap[e] > 0 {
                            nh = nh.min(h[self.to[e] as usize] + 1);
                        }
                    }
                    count[old] -= 1;
                    h[u] = nh;
                    count[nh] += 1;
                    cur[u] = 0;
                    if count[old] == 0 && old < n {
                        for v in 0..n {
                            if v != s && old < h[v] && h[v] < n {
                                count[h[v]] -= 1;
                                h[v] = n + 1;
                                count[n + 1] += 1;
                            }
                        }
                    }
                    if nh == 2 * n + 1 {
                        break; // no residual arc at all (isolated excess)
                    }
                    continue;
                }
                let e = self.adj[u][cur[u]] as usize;
                let v = self.to[e] as usize;
                if self.cap[e] > 0 && h[u] == h[v] + 1 {
                    let delta = excess[u].min(self.cap[e]);
                    self.cap[e] -= delta;
                    self.cap[e ^ 1] += delta;
                    excess[u] -= delta;
                    excess[v] += delta;
                    if v != s && v != t && !queued[v] {
                        queued[v] = true;
                        queue.push_back(v);
                    }
                } else {
                    cur[u] += 1;
                }
            }
        }
        excess[t]
    }

    /// Nodes reachable from `src` through residual arcs (`cap > 0`).
    /// After [`MaxFlow::run`] this is the source side of the canonical
    /// minimum cut.
    pub fn residual_reachable(&self, src: usize) -> Vec<bool> {
        let mut seen = vec![false; self.n];
        seen[src] = true;
        let mut stack = vec![src];
        while let Some(u) = stack.pop() {
            for &e in &self.adj[u] {
                let e = e as usize;
                if self.cap[e] > 0 {
                    let v = self.to[e] as usize;
                    if !seen[v] {
                        seen[v] = true;
                        stack.push(v);
                    }
                }
            }
        }
        seen
    }

    /// Nodes that can reach `dst` through residual arcs. After
    /// [`MaxFlow::run`] the complement is the sink side of the widest
    /// minimum cut.
    pub fn residual_coreachable(&self, dst: usize) -> Vec<bool> {
        let mut seen = vec![false; self.n];
        seen[dst] = true;
        let mut stack = vec![dst];
        while let Some(v) = stack.pop() {
            // `e` runs v -> w; its pair `e ^ 1` is the arc w -> v, so w
            // can step to v exactly when that pair is residual.
            for &e in &self.adj[v] {
                let e = e as usize;
                if self.cap[e ^ 1] > 0 {
                    let w = self.to[e] as usize;
                    if !seen[w] {
                        seen[w] = true;
                        stack.push(w);
                    }
                }
            }
        }
        seen
    }

    /// Strongly connected components of the residual graph (arcs with
    /// `cap > 0`), as `(component id per node, component count)`.
    /// Component ids follow Tarjan emission order, which is reverse
    /// topological on the condensation: every residual arc between two
    /// distinct components points from a higher id to a lower one.
    fn residual_sccs(&self) -> (Vec<u32>, usize) {
        let n = self.n;
        const UNSEEN: u32 = u32::MAX;
        let mut index = vec![UNSEEN; n];
        let mut low = vec![0u32; n];
        let mut on_stack = vec![false; n];
        let mut stack: Vec<u32> = Vec::new();
        let mut comp = vec![UNSEEN; n];
        let mut ncomp = 0usize;
        let mut next = 0u32;
        let mut call: Vec<(u32, u32)> = Vec::new();
        for root in 0..n {
            if index[root] != UNSEEN {
                continue;
            }
            call.push((root as u32, 0));
            while let Some(frame) = call.last_mut() {
                let v = frame.0 as usize;
                if index[v] == UNSEEN {
                    index[v] = next;
                    low[v] = next;
                    next += 1;
                    stack.push(v as u32);
                    on_stack[v] = true;
                }
                let mut descended = false;
                while (frame.1 as usize) < self.adj[v].len() {
                    let e = self.adj[v][frame.1 as usize] as usize;
                    frame.1 += 1;
                    if self.cap[e] <= 0 {
                        continue;
                    }
                    let w = self.to[e] as usize;
                    if index[w] == UNSEEN {
                        call.push((w as u32, 0));
                        descended = true;
                        break;
                    } else if on_stack[w] {
                        low[v] = low[v].min(index[w]);
                    }
                }
                if descended {
                    continue;
                }
                call.pop();
                if let Some(parent) = call.last() {
                    let p = parent.0 as usize;
                    low[p] = low[p].min(low[v]);
                }
                if low[v] == index[v] {
                    loop {
                        let w = stack.pop().expect("Tarjan stack underflow") as usize;
                        on_stack[w] = false;
                        comp[w] = ncomp as u32;
                        if w == v {
                            break;
                        }
                    }
                    ncomp += 1;
                }
            }
        }
        (comp, ncomp)
    }
}

/// Terminal labels of the vertex-cut instance built over a band graph.
const TERM0: u8 = 0;
const TERM1: u8 = 1;
const FREE: u8 = 2;

/// Grow the source/sink supernodes by BFS from the two anchor sides:
/// side `s`'s terminal set is its anchor, its vertices on the *farthest*
/// BFS layer from the current separator, and any side-`s` vertex the
/// separator cannot reach inside the band. Everything else (separator
/// included) is free to end up on either side of the new cut. Returns
/// `None` for degenerate bands (empty separator, or a side without any
/// non-anchor vertex) so the caller keeps the existing state.
fn grow_terminals(band: &BandGraph) -> Option<Vec<u8>> {
    let g = &band.graph;
    let n = g.n();
    let seps = band.state.sep_vertices();
    if seps.is_empty() {
        return None;
    }
    let dist = g.multi_source_bfs(&seps, u32::MAX);
    let mut dmax = [0u32; 2];
    let mut side_n = [0usize; 2];
    for v in 0..n {
        if v == band.anchor0 || v == band.anchor1 {
            continue;
        }
        let p = band.state.part[v];
        if p == SEP {
            continue;
        }
        side_n[p as usize] += 1;
        if dist[v] != u32::MAX {
            dmax[p as usize] = dmax[p as usize].max(dist[v]);
        }
    }
    if side_n[0] == 0 || side_n[1] == 0 {
        return None;
    }
    let mut term = vec![FREE; n];
    term[band.anchor0] = TERM0;
    term[band.anchor1] = TERM1;
    for v in 0..n {
        if v == band.anchor0 || v == band.anchor1 {
            continue;
        }
        let p = band.state.part[v];
        if p == SEP {
            continue;
        }
        if dist[v] == u32::MAX || dist[v] == dmax[p as usize] {
            term[v] = p;
        }
    }
    Some(term)
}

/// Compute a minimum-vertex-cut separator candidate for the band:
/// the most-balanced minimum cut between the BFS-grown terminal sides,
/// or `None` when the band is degenerate (see [`grow_terminals`]).
/// Deterministic; edge weights are irrelevant to a vertex cut and are
/// ignored. The candidate always satisfies the separator invariant and
/// its separator weight never exceeds the current one (the current
/// separator is itself a valid terminal cut).
pub fn flow_candidate(band: &BandGraph) -> Option<SepState> {
    let g = &band.graph;
    let n = g.n();
    let term = grow_terminals(band)?;
    // Vertex-split network: free vertex i gets nodes 2i (in) / 2i+1
    // (out) joined by an arc of capacity vwgt; undirected band edges
    // become arc pairs of effectively-infinite capacity, so only node
    // arcs can saturate and the min cut is a vertex set.
    let mut free_idx = vec![u32::MAX; n];
    let mut free: Vec<u32> = Vec::new();
    let mut free_wgt = 0i64;
    for v in 0..n {
        if term[v] == FREE {
            free_idx[v] = free.len() as u32;
            free.push(v as u32);
            free_wgt += g.vwgt[v];
        }
    }
    let nf = free.len();
    let (s, t) = (2 * nf, 2 * nf + 1);
    let big = free_wgt + 1;
    let mut mf = MaxFlow::new(2 * nf + 2);
    for (i, &v) in free.iter().enumerate() {
        mf.add_arc(2 * i, 2 * i + 1, g.vwgt[v as usize]);
    }
    for v in 0..n {
        for &u in g.neighbors(v) {
            let u = u as usize;
            match (term[v], term[u]) {
                (FREE, FREE) => {
                    // Each ordered pair appears once, covering both
                    // directions of the undirected edge.
                    let (i, j) = (free_idx[v] as usize, free_idx[u] as usize);
                    mf.add_arc(2 * i + 1, 2 * j, big);
                }
                (TERM0, FREE) => {
                    mf.add_arc(s, 2 * free_idx[u] as usize, big);
                }
                (FREE, TERM1) => {
                    mf.add_arc(2 * free_idx[v] as usize + 1, t, big);
                }
                (TERM0, TERM1) | (TERM1, TERM0) => {
                    debug_assert!(false, "terminal sides touch: {v} -- {u}");
                    return None;
                }
                _ => {}
            }
        }
    }
    let flow = mf.run(s, t);
    debug_assert!(flow <= band.state.sep_weight());

    // Most-balanced minimum cut: any residual-closed set S with s ∈ S
    // and t ∉ S induces a minimum cut (crossing arcs are saturated, and
    // only node arcs can saturate). Sweep the residual SCCs in reverse
    // topological order, greedily growing S from reach(s) toward the
    // complement of coreach(t), and keep the prefix whose induced cut
    // has the best quality key.
    let reach = mf.residual_reachable(s);
    let coreach = mf.residual_coreachable(t);
    let (comp, ncomp) = mf.residual_sccs();
    let nn = 2 * nf + 2;
    let mut comp_in_s = vec![false; ncomp];
    let mut comp_co = vec![false; ncomp];
    for x in 0..nn {
        let c = comp[x] as usize;
        if reach[x] {
            comp_in_s[c] = true;
        }
        if coreach[x] {
            comp_co[c] = true;
        }
        debug_assert!(!(reach[x] && coreach[x]), "s reaches t in the residual");
    }
    // Nodes per component, grouped by counting sort on component id.
    let mut comp_start = vec![0usize; ncomp + 1];
    for &c in &comp {
        comp_start[c as usize + 1] += 1;
    }
    for c in 0..ncomp {
        comp_start[c + 1] += comp_start[c];
    }
    let mut comp_nodes = vec![0u32; nn];
    let mut fill = comp_start.clone();
    for x in 0..nn {
        let c = comp[x] as usize;
        comp_nodes[fill[c]] = x as u32;
        fill[c] += 1;
    }

    // Per-node S membership and the induced labels. A free vertex is on
    // the source side when its *out* node is in S (closure then forces
    // every neighbor's in-node into S), in the cut when only its
    // in-node is, and on the sink side otherwise. Every closed S labels
    // the cut as exactly the saturated crossing node arcs, so each
    // prefix of the sweep is a minimum cut of weight `flow` and the
    // sweep only trades balance.
    let mut node_in_s: Vec<bool> = reach[..2 * nf].to_vec();
    let label = |node_in_s: &[bool], i: usize| -> usize {
        if node_in_s[2 * i + 1] {
            0
        } else if node_in_s[2 * i] {
            2
        } else {
            1
        }
    };
    let mut wgts = [0i64; 3];
    for v in 0..n {
        match term[v] {
            TERM0 => wgts[0] += g.vwgt[v],
            TERM1 => wgts[1] += g.vwgt[v],
            _ => {}
        }
    }
    for (i, &v) in free.iter().enumerate() {
        wgts[label(&node_in_s, i)] += g.vwgt[v as usize];
    }
    debug_assert_eq!(wgts[2], flow);
    let key_of = |wgts: &[i64; 3]| (wgts[2], (wgts[0] - wgts[1]).abs());
    let mut best_key = key_of(&wgts);
    let mut best_len = 0usize;
    let mut added: Vec<u32> = Vec::new();
    // One pass suffices: Tarjan emission order guarantees every
    // residual out-neighbor component of c has a smaller id, so its
    // membership is already decided when c is considered.
    for c in 0..ncomp {
        if comp_in_s[c] || comp_co[c] {
            continue;
        }
        let nodes = &comp_nodes[comp_start[c]..comp_start[c + 1]];
        let addable = nodes.iter().all(|&x| {
            mf.adj[x as usize].iter().all(|&e| {
                let e = e as usize;
                if mf.cap[e] <= 0 {
                    return true;
                }
                let d = comp[mf.to[e] as usize] as usize;
                d == c || comp_in_s[d]
            })
        });
        if !addable {
            continue;
        }
        comp_in_s[c] = true;
        for &x in nodes {
            let x = x as usize;
            debug_assert!(x < 2 * nf, "s/t joined a growable SCC");
            let i = x / 2;
            let v = free[i] as usize;
            wgts[label(&node_in_s, i)] -= g.vwgt[v];
            node_in_s[x] = true;
            wgts[label(&node_in_s, i)] += g.vwgt[v];
        }
        added.push(c as u32);
        debug_assert_eq!(wgts[2], flow);
        let key = key_of(&wgts);
        if key < best_key {
            best_key = key;
            best_len = added.len();
        }
    }

    // Replay the best prefix from the canonical cut.
    node_in_s.copy_from_slice(&reach[..2 * nf]);
    for &c in &added[..best_len] {
        let c = c as usize;
        for &x in &comp_nodes[comp_start[c]..comp_start[c + 1]] {
            node_in_s[x as usize] = true;
        }
    }
    let mut part = vec![SEP; n];
    for v in 0..n {
        match term[v] {
            TERM0 => part[v] = P0,
            TERM1 => part[v] = P1,
            _ => {
                part[v] = match label(&node_in_s, free_idx[v] as usize) {
                    0 => P0,
                    2 => SEP,
                    _ => P1,
                }
            }
        }
    }
    let cand = SepState::from_parts(g, part);
    debug_assert!(cand.validate(g).is_ok());
    debug_assert_eq!(cand.sep_weight(), flow);
    Some(cand)
}

/// Run the flow pass on a band and commit the candidate iff it is
/// strictly better under the quality key. Returns whether the state
/// changed.
pub fn flow_refine_band(band: &mut BandGraph) -> bool {
    let Some(cand) = flow_candidate(band) else {
        return false;
    };
    if cand.quality_key() < band.state.quality_key() {
        band.state = cand;
        true
    } else {
        false
    }
}

/// [`BandRefiner`] adapter for the flow pass (`refine=flow`); ignores
/// the RNG — the pass is fully deterministic.
#[derive(Clone, Debug, Default)]
pub struct FlowRefiner;

impl BandRefiner for FlowRefiner {
    fn refine_band(&self, band: &mut BandGraph, _rng: &mut Rng) {
        flow_refine_band(band);
    }

    fn name(&self) -> &'static str {
        "flow"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{generators, Graph, GraphBuilder};
    use crate::sep::band::extract_band;

    #[test]
    fn maxflow_path_network() {
        // s -> a (5) -> b (3) -> t (7): bottleneck 3.
        let mut mf = MaxFlow::new(4);
        mf.add_arc(0, 1, 5);
        mf.add_arc(1, 2, 3);
        mf.add_arc(2, 3, 7);
        assert_eq!(mf.run(0, 3), 3);
        let reach = mf.residual_reachable(0);
        let coreach = mf.residual_coreachable(3);
        // The a -> b arc is the saturated cut.
        assert_eq!(reach, vec![true, true, false, false]);
        assert_eq!(coreach, vec![false, false, true, true]);
    }

    #[test]
    fn maxflow_grid_network() {
        // Two s->t paths of bottlenecks 2 and 4, plus a wide cross arc
        // 1 -> 4 that lets 1's surplus bypass its own bottleneck: the
        // min cut is {1 -> 2 (2), 4 -> t (10)}, so the max flow is 12.
        let mut mf = MaxFlow::new(6);
        let (s, t) = (0, 5);
        mf.add_arc(s, 1, 10);
        mf.add_arc(1, 2, 2);
        mf.add_arc(2, t, 10);
        mf.add_arc(s, 3, 10);
        mf.add_arc(3, 4, 4);
        mf.add_arc(4, t, 10);
        mf.add_arc(1, 4, 10);
        assert_eq!(mf.run(s, t), 12);
    }

    #[test]
    fn maxflow_disconnected_is_zero() {
        let mut mf = MaxFlow::new(4);
        mf.add_arc(0, 1, 5);
        mf.add_arc(2, 3, 5);
        assert_eq!(mf.run(0, 3), 0);
        assert!(mf.residual_reachable(0)[1]);
        assert!(!mf.residual_reachable(0)[3]);
    }

    #[test]
    fn gap_relabeling_terminates_on_staircase() {
        // Adversarial staircase: many parallel high-capacity stubs feed
        // one unit bottleneck, so almost all preflow must climb back
        // above n to return to the source — the regime gap relabeling
        // short-circuits. The test passing at all is the termination
        // assertion; the value pins correctness.
        let k = 60;
        let mut mf = MaxFlow::new(k + 3);
        let (s, b, t) = (0, k + 1, k + 2);
        for i in 0..k {
            mf.add_arc(s, 1 + i, 7);
            mf.add_arc(1 + i, b, 7);
        }
        mf.add_arc(b, t, 1);
        assert_eq!(mf.run(s, t), 1);
    }

    #[test]
    fn maxflow_descending_staircase_value() {
        // Chain with strictly descending capacities k, k-1, …, 1: every
        // relabel wave walks the whole chain; flow = 1.
        let k = 40;
        let mut mf = MaxFlow::new(k + 1);
        for i in 0..k {
            mf.add_arc(i, i + 1, (k - i) as i64);
        }
        assert_eq!(mf.run(0, k), 1);
    }

    /// Band over the whole of `g` for a given part labeling.
    fn whole_band(g: &Graph, part: Vec<u8>) -> BandGraph {
        let state = SepState::from_parts(g, part);
        state.validate(g).unwrap();
        extract_band(g, &state, u32::MAX - 1).unwrap()
    }

    #[test]
    fn flow_candidate_on_path_band_finds_unit_cut() {
        // Unit path, separator parked off-center at v2: every single
        // vertex is a weight-1 cut; flow must find weight 1.
        let g = generators::path(9, 1);
        let mut part = vec![P1; 9];
        part[0] = P0;
        part[1] = P0;
        part[2] = SEP;
        let band = whole_band(&g, part);
        let cand = flow_candidate(&band).unwrap();
        cand.validate(&band.graph).unwrap();
        assert_eq!(cand.sep_weight(), 1);
    }

    #[test]
    fn most_balanced_selection_prefers_center_cut() {
        // All min cuts on the unit path have weight 1; the most-balanced
        // one is the middle vertex, far from the starting separator.
        let g = generators::path(9, 1);
        let mut part = vec![P1; 9];
        part[0] = P0;
        part[1] = P0;
        part[2] = SEP;
        let band = whole_band(&g, part);
        let cand = flow_candidate(&band).unwrap();
        assert_eq!(cand.sep_weight(), 1);
        assert_eq!(cand.imbalance(), 0, "parts: {:?}", cand.part);
        assert_eq!(cand.part[4], SEP);
    }

    #[test]
    fn flow_candidate_respects_vertex_weights() {
        // Heavy separator vertex: the min cut dodges it.
        let mut b = GraphBuilder::new(5);
        for v in 0..4 {
            b.add_edge(v, v + 1);
        }
        b.set_vwgt(2, 5);
        let g = b.build().unwrap();
        let band = whole_band(&g, vec![P0, P0, SEP, P1, P1]);
        let cand = flow_candidate(&band).unwrap();
        cand.validate(&band.graph).unwrap();
        assert_eq!(cand.sep_weight(), 1);
        assert_ne!(cand.part[2], SEP);
    }

    #[test]
    fn flow_candidate_on_grid_band() {
        // 7×5 grid, mid-column separator, whole-graph band: the min
        // vertex cut between the outer columns is one full column (5),
        // and the balanced choice is the middle column.
        let g = generators::grid2d(7, 5);
        let part = generators::column_separator_part(7, 5, 3, 1);
        let band = whole_band(&g, part);
        let cand = flow_candidate(&band).unwrap();
        cand.validate(&band.graph).unwrap();
        assert_eq!(cand.sep_weight(), 5);
        assert_eq!(cand.imbalance(), 0);
    }

    #[test]
    fn flow_candidate_on_clique_bridge() {
        // Two 10-cliques joined through an articulation vertex; the
        // starting separator is fat ({x, a0}), the min cut is width 1.
        let n = 21; // 0..10 clique A, 10..20 clique B, 20 = bridge x
        let mut b = GraphBuilder::new(n);
        for i in 0..10 {
            for j in (i + 1)..10 {
                b.add_edge(i, j);
                b.add_edge(10 + i, 10 + j);
            }
        }
        b.add_edge(0, 20);
        b.add_edge(10, 20);
        let g = b.build().unwrap();
        let mut part = vec![P0; n];
        for v in 10..20 {
            part[v] = P1;
        }
        part[20] = SEP;
        part[0] = SEP; // fatten the separator with a0
        let band = whole_band(&g, part);
        assert_eq!(band.state.sep_weight(), 2);
        let cand = flow_candidate(&band).unwrap();
        cand.validate(&band.graph).unwrap();
        assert_eq!(cand.sep_weight(), 1);
        assert_eq!(cand.imbalance(), 0);
    }

    #[test]
    fn flow_candidate_on_disconnected_band() {
        // Two disjoint paths with a redundant separator vertex on each:
        // the components are already disconnected, so the min cut is
        // empty and the whole separator weight (2) is recoverable.
        let mut b = GraphBuilder::new(8);
        for v in 0..3 {
            b.add_edge(v, v + 1);
        }
        for v in 4..7 {
            b.add_edge(v, v + 1);
        }
        let g = b.build().unwrap();
        let band = whole_band(&g, vec![P0, P0, P0, SEP, SEP, P1, P1, P1]);
        let cand = flow_candidate(&band).unwrap();
        cand.validate(&band.graph).unwrap();
        assert_eq!(cand.sep_weight(), 0);
    }

    #[test]
    fn anchors_and_terminals_stay_on_their_sides() {
        let g = generators::grid2d(9, 5);
        let part = generators::column_separator_part(9, 5, 4, 1);
        let state = SepState::from_parts(&g, part);
        let band = extract_band(&g, &state, 2).unwrap();
        let term = grow_terminals(&band).unwrap();
        assert_eq!(term[band.anchor0], TERM0);
        assert_eq!(term[band.anchor1], TERM1);
        let cand = flow_candidate(&band).unwrap();
        assert_eq!(cand.part[band.anchor0], P0);
        assert_eq!(cand.part[band.anchor1], P1);
        for v in 0..band.band_n() {
            if term[v] != FREE {
                assert_eq!(cand.part[v], term[v], "terminal {v} switched sides");
            }
        }
    }

    #[test]
    fn flow_refine_band_commits_only_strict_improvements() {
        // Unit path with the separator already on the centered min cut:
        // nothing strictly better exists, so no commit.
        let g = generators::path(9, 1);
        let mut part = vec![P0; 9];
        part[4] = SEP;
        for v in 5..9 {
            part[v] = P1;
        }
        let mut band = whole_band(&g, part);
        let before = band.state.part.clone();
        assert!(!flow_refine_band(&mut band));
        assert_eq!(band.state.part, before);

        // Off-center separator: the balanced unit cut wins and commits.
        let mut part = vec![P1; 9];
        part[0] = P0;
        part[1] = P0;
        part[2] = SEP;
        let mut band = whole_band(&g, part);
        assert!(flow_refine_band(&mut band));
        assert_eq!(band.state.quality_key(), (1, 0));
    }

    #[test]
    fn degenerate_bands_yield_no_candidate() {
        // A band whose part-1 side is only the anchor: bail out.
        let g = generators::path(4, 1);
        let state = SepState::from_parts(&g, vec![P0, P0, P0, SEP]);
        let band = extract_band(&g, &state, u32::MAX - 1).unwrap();
        assert!(flow_candidate(&band).is_none());
    }

    #[test]
    fn flow_never_worse_on_random_meshes() {
        use crate::sep::initial::greedy_graph_growing;
        for seed in 1..6u64 {
            let g = generators::irregular_mesh(13, 11, seed);
            let mut rng = Rng::new(seed);
            let state = greedy_graph_growing(&g, 3, &mut rng);
            for width in [1u32, 2, 3] {
                let Some(mut band) = extract_band(&g, &state, width) else {
                    continue;
                };
                let before = band.state.quality_key();
                flow_refine_band(&mut band);
                band.state.validate(&band.graph).unwrap();
                assert!(
                    band.state.quality_key() <= before,
                    "flow degraded the band: seed {seed} width {width}"
                );
            }
        }
    }
}
