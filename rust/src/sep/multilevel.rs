//! The sequential multilevel vertex-separator V-cycle (§3.2–§3.3):
//! HEM-coarsen until the graph is small, compute an initial separator by
//! greedy graph growing + FM, then uncoarsen, refining on width-limited
//! band graphs at every level.

use super::band::band_refine_step;
use super::coarsen::{coarsen_hem, Coarsening};
use super::fm::fm_refine;
use super::initial::greedy_graph_growing;
use super::{BandRefiner, SepState};
use crate::graph::Graph;
use crate::rng::Rng;
use crate::strategy::SepStrategy;
use crate::trace;

/// Project a coarse separator state to the fine graph through `map`
/// (both children of a coarse vertex inherit its label).
pub fn project_state(fine: &Graph, coarse_state: &SepState, map: &[u32]) -> SepState {
    let part: Vec<u8> = (0..fine.n())
        .map(|v| coarse_state.part[map[v] as usize])
        .collect();
    SepState::from_parts(fine, part)
}

/// Compute a vertex separator of `g` with the full multilevel scheme.
pub fn multilevel_separator(
    g: &Graph,
    strat: &SepStrategy,
    refiner: &dyn BandRefiner,
    rng: &mut Rng,
) -> SepState {
    // Coarsening chain. Stop when small enough or when matching stalls
    // (coarsening ratio too close to 1, e.g. on near-cliques).
    let mut levels: Vec<Coarsening> = Vec::new();
    let coarsen_span = trace::scope(trace::Phase::Coarsen);
    let mut cur = g;
    while cur.n() > strat.coarse_target {
        let c = coarsen_hem(cur, rng);
        if c.coarse.n() as f64 > cur.n() as f64 * strat.min_coarsen_ratio {
            break; // stalled
        }
        levels.push(c);
        cur = &levels.last().unwrap().coarse;
    }
    drop(coarsen_span);

    // Initial separator on the coarsest graph: best of `ggg_tries`
    // greedy-growing seeds, each FM-refined on the whole (tiny) graph.
    let coarsest: &Graph = levels.last().map(|c| &c.coarse).unwrap_or(g);
    let mut state = {
        let _span = trace::scope(trace::Phase::InitialSep);
        let mut best: Option<SepState> = None;
        for _ in 0..strat.ggg_tries.max(1) {
            let mut s = greedy_graph_growing(coarsest, 1, rng);
            fm_refine(coarsest, &mut s, &[], &strat.fm, rng);
            if best
                .as_ref()
                .map(|b| s.quality_key() < b.quality_key())
                .unwrap_or(true)
            {
                best = Some(s);
            }
        }
        best.expect("ggg produced a state")
    };
    debug_assert!(state.validate(coarsest).is_ok());

    // Uncoarsening with band refinement at every level.
    for li in (0..levels.len()).rev() {
        let fine: &Graph = if li == 0 { g } else { &levels[li - 1].coarse };
        state = {
            let _span = trace::scope(trace::Phase::ProjectSep);
            project_state(fine, &state, &levels[li].map)
        };
        if !band_refine_step(fine, &mut state, strat, refiner, rng) {
            // Empty separator (disconnected component split): nothing to
            // refine at this level.
            continue;
        }
    }
    debug_assert!(state.validate(g).is_ok());
    trace::quality(
        state.sep_weight().max(0) as u64,
        state.imbalance().max(0) as u64,
        strat.band_width,
        strat.refine.name(),
        levels.len() as u32 + 1,
    );
    state
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::sep::FmRefiner;
    use crate::strategy::SepStrategy;

    fn run(g: &Graph, seed: u64) -> SepState {
        let strat = SepStrategy::default();
        let refiner = FmRefiner::default();
        multilevel_separator(g, &strat, &refiner, &mut Rng::new(seed))
    }

    #[test]
    fn grid2d_separator_near_sqrt() {
        let g = generators::grid2d(32, 32);
        let s = run(&g, 1);
        s.validate(&g).unwrap();
        // Optimal is a 32-vertex line; multilevel should be within ~1.6×.
        assert!(s.sep_weight() <= 52, "sep weight {}", s.sep_weight());
        let total = g.total_vwgt();
        assert!(s.imbalance() <= total / 8, "imbalance {}", s.imbalance());
    }

    #[test]
    fn grid3d_separator_near_n23() {
        let g = generators::grid3d(12, 12, 12);
        let s = run(&g, 2);
        s.validate(&g).unwrap();
        // Optimal is a 144-vertex plane; allow 2×.
        assert!(s.sep_weight() <= 290, "sep weight {}", s.sep_weight());
        assert!(s.wgts[0] > 0 && s.wgts[1] > 0);
    }

    #[test]
    fn handles_small_graphs_directly() {
        let g = generators::path(10, 1);
        let s = run(&g, 3);
        s.validate(&g).unwrap();
        assert!(s.sep_weight() <= 1);
    }

    #[test]
    fn handles_near_clique() {
        // Coarsening stalls on cliques; initial separator must still work.
        let g = generators::complete(40);
        let s = run(&g, 4);
        s.validate(&g).unwrap();
        // Any separator of K40 has ≥ 38 vertices or an empty side; just
        // require validity and nonempty parts if a separator exists.
        assert_eq!(s.wgts[0] + s.wgts[1] + s.wgts[2], 40);
    }

    #[test]
    fn deterministic_under_seed() {
        let g = generators::irregular_mesh(24, 24, 8);
        let a = run(&g, 7);
        let b = run(&g, 7);
        assert_eq!(a.part, b.part);
    }

    #[test]
    fn weighted_graph_balance_is_weighted() {
        let mut b = crate::graph::GraphBuilder::new(9);
        for v in 1..9 {
            b.add_edge(v - 1, v);
        }
        // One huge vertex at the end: balance must account for weight.
        b.set_vwgt(8, 100);
        let g = b.build().unwrap();
        let s = run(&g, 5);
        s.validate(&g).unwrap();
    }

    #[test]
    fn project_state_preserves_labels() {
        let g = generators::grid2d(8, 8);
        let mut rng = Rng::new(6);
        let c = coarsen_hem(&g, &mut rng);
        let coarse_state = greedy_graph_growing(&c.coarse, 2, &mut rng);
        let fine_state = project_state(&g, &coarse_state, &c.map);
        for v in 0..g.n() {
            assert_eq!(fine_state.part[v], coarse_state.part[c.map[v] as usize]);
        }
        // Projection preserves the separator invariant: crossing fine
        // edges would imply crossing coarse edges.
        fine_state.validate(&g).unwrap();
    }
}
