//! Banded diffusion separator smoothing — the "parallel diffusion-based
//! method" the paper cites as refinement future work ([28], §5) and that
//! we implement as the numeric hot-spot of the three-layer stack.
//!
//! Two liquids flow from the two anchors (part 0 = −1, part 1 = +1)
//! through the band graph; after `k` damped averaging iterations the sign
//! field induces a smooth bipartition whose crossing edges are covered to
//! produce a valid vertex separator, then polished with FM.
//!
//! This module is the **pure-Rust reference**: [`diffusion_iterations`]
//! defines the exact numeric semantics that the L1 Pallas kernel
//! (`python/compile/kernels/ell_spmv.py`) and the L2 JAX model reproduce;
//! `runtime::DiffusionRefiner` swaps the iteration loop for the
//! AOT-compiled XLA executable and is tested to match this function.

use super::band::BandGraph;
use super::fm::{fm_refine, FmParams};
use super::{BandRefiner, SepState, P0, P1, SEP};
use crate::graph::Graph;
use crate::rng::Rng;

/// Initial diffusion field from raw part labels: −1 on part 0, +1 on
/// part 1, 0 on the separator. Shared by the sequential path (over a
/// [`SepState`]) and the distributed path (over one rank's label slice,
/// `dist::ddiffusion`).
pub fn field_from_labels(part: &[u8]) -> Vec<f32> {
    part.iter()
        .map(|&p| match p {
            P0 => -1.0,
            P1 => 1.0,
            _ => 0.0,
        })
        .collect()
}

/// Initial diffusion field for a band state: −1 on part 0, +1 on part 1,
/// 0 on the separator.
pub fn initial_field(state: &SepState) -> Vec<f32> {
    field_from_labels(&state.part)
}

/// One Jacobi update: the damped weighted average `damping · num / den`,
/// decaying zero-degree vertices (`den == 0`) to 0. This is the single
/// per-vertex rule of the diffusion kernel — the sequential sweep
/// ([`diffusion_iterations`]), the distributed sweep
/// (`dist::ddiffusion`) and the XLA artifact all apply exactly this
/// f32 arithmetic.
#[inline]
pub fn damped_average(num: f32, den: f32, damping: f32) -> f32 {
    if den > 0.0 {
        damping * num / den
    } else {
        0.0
    }
}

/// Sign rule of the diffusion bipartition: negative field values join
/// part 0, the rest part 1 (the separator is re-grown by edge covering).
#[inline]
pub fn sign_label(x: f32) -> u8 {
    if x < 0.0 {
        P0
    } else {
        P1
    }
}

/// Crossing-edge cover rule, shared by the sequential and distributed
/// recovery passes: given a crossing edge, returns `true` when the
/// *first* endpoint should join the separator. The weaker endpoint
/// (smaller `|x|`) is chosen, ties broken by the smaller id; locked
/// endpoints (anchors) never join. The rule is a pure antisymmetric
/// function of per-endpoint data, so two ranks evaluating it from
/// opposite ends of a halo edge always agree.
#[inline]
pub fn cover_prefers_first(
    abs_a: f32,
    abs_b: f32,
    locked_a: bool,
    locked_b: bool,
    id_a: u64,
    id_b: u64,
) -> bool {
    if locked_a {
        false
    } else if locked_b {
        true
    } else {
        abs_a < abs_b || (abs_a == abs_b && id_a < id_b)
    }
}

/// `k` damped weighted-averaging iterations with the anchor values
/// re-clamped to ∓1 after every step:
///
/// `x'[v] = damping · (Σ_u w(u,v)·x[u]) / Σ_u w(u,v)`
///
/// Zero-degree vertices decay to 0. All arithmetic is f32 to match the
/// XLA artifact bit-for-bit up to reduction order.
pub fn diffusion_iterations(
    g: &Graph,
    mut x: Vec<f32>,
    anchor0: usize,
    anchor1: usize,
    k: usize,
    damping: f32,
) -> Vec<f32> {
    let n = g.n();
    debug_assert_eq!(x.len(), n);
    let mut next = vec![0f32; n];
    for _ in 0..k {
        x[anchor0] = -1.0;
        x[anchor1] = 1.0;
        for v in 0..n {
            let mut num = 0f32;
            let mut den = 0f32;
            for (&u, &w) in g.neighbors(v).iter().zip(g.edge_weights(v)) {
                let w = w as f32;
                num += w * x[u as usize];
                den += w;
            }
            next[v] = damped_average(num, den, damping);
        }
        std::mem::swap(&mut x, &mut next);
    }
    x[anchor0] = -1.0;
    x[anchor1] = 1.0;
    x
}

/// Convert a diffusion field into a valid separator state on the band:
/// parts by sign ([`sign_label`]), then a vertex cover of crossing edges
/// via the antisymmetric [`cover_prefers_first`] rule (the endpoint with
/// the smaller |x| joins the separator; locked vertices — the anchors —
/// never do). Decisions are pure functions of the sign labeling, so the
/// distributed recovery pass (`dist::ddiffusion`) produces the same
/// cover when each rank evaluates only its own endpoints.
pub fn field_to_separator(band: &BandGraph, x: &[f32]) -> SepState {
    let g = &band.graph;
    let n = g.n();
    let mut sign: Vec<u8> = x.iter().map(|&xv| sign_label(xv)).collect();
    sign[band.anchor0] = P0;
    sign[band.anchor1] = P1;
    let mut part = sign.clone();
    for v in 0..n {
        if band.locked[v] {
            continue;
        }
        for &u in g.neighbors(v) {
            let u = u as usize;
            if sign[u] == sign[v] {
                continue;
            }
            // Crossing edge in the sign labeling: cover it from this
            // endpoint iff the shared rule prefers it.
            if cover_prefers_first(
                x[v].abs(),
                x[u].abs(),
                band.locked[v],
                band.locked[u],
                v as u64,
                u as u64,
            ) {
                part[v] = SEP;
                break;
            }
        }
    }
    // Trim pass (sequential only): the pure rule over-covers chains of
    // crossing edges — a covered vertex whose crossing edges are all
    // guarded by a SEP neighbor can return to its side. Greedy in vertex
    // order, so each revert sees the current labels and every crossing
    // edge keeps at least one SEP endpoint.
    for v in 0..n {
        if part[v] != SEP {
            continue;
        }
        let redundant = g.neighbors(v).iter().all(|&u| {
            let u = u as usize;
            sign[u] == sign[v] || part[u] == SEP
        });
        if redundant {
            part[v] = sign[v];
        }
    }
    SepState::from_parts(g, part)
}

/// Pure-CPU diffusion band refiner: diffusion iterations (reference
/// implementation), sign-cover, FM polish. `runtime::DiffusionRefiner`
/// is the XLA-backed equivalent used on the request path.
#[derive(Clone, Debug)]
pub struct CpuDiffusionRefiner {
    /// Number of diffusion iterations (paper-scale band graphs converge
    /// within a few dozen).
    pub iterations: usize,
    /// Damping factor in (0, 1]; keeps the field contractive.
    pub damping: f32,
    /// FM polish parameters.
    pub fm: FmParams,
}

impl Default for CpuDiffusionRefiner {
    fn default() -> Self {
        CpuDiffusionRefiner {
            iterations: 32,
            damping: 0.95,
            fm: FmParams::default(),
        }
    }
}

impl BandRefiner for CpuDiffusionRefiner {
    fn refine_band(&self, band: &mut BandGraph, rng: &mut Rng) {
        let x0 = initial_field(&band.state);
        let x = diffusion_iterations(
            &band.graph,
            x0,
            band.anchor0,
            band.anchor1,
            self.iterations,
            self.damping,
        );
        let candidate = field_to_separator(band, &x);
        debug_assert!(candidate.validate(&band.graph).is_ok());
        if candidate.quality_key() < band.state.quality_key() {
            band.state = candidate;
        }
        fm_refine(&band.graph, &mut band.state, &band.locked, &self.fm, rng);
    }

    fn name(&self) -> &'static str {
        "diffusion+fm(cpu)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::sep::band::extract_band;
    use crate::sep::initial::greedy_graph_growing;

    fn grid_band() -> BandGraph {
        let g = generators::grid2d(13, 9);
        let part: Vec<u8> = (0..13 * 9)
            .map(|v| {
                let x = v % 13;
                if x < 6 {
                    P0
                } else if x == 6 {
                    SEP
                } else {
                    P1
                }
            })
            .collect();
        let s = SepState::from_parts(&g, part);
        extract_band(&g, &s, 3).unwrap()
    }

    #[test]
    fn field_converges_to_signed_halves() {
        let band = grid_band();
        let x0 = initial_field(&band.state);
        let x = diffusion_iterations(&band.graph, x0, band.anchor0, band.anchor1, 64, 0.95);
        // Vertices adjacent to anchor0 must be clearly negative, and
        // symmetrically for anchor1.
        for (&u, _) in band
            .graph
            .neighbors(band.anchor0)
            .iter()
            .zip(band.graph.edge_weights(band.anchor0))
        {
            assert!(x[u as usize] < -0.2, "x[{u}] = {}", x[u as usize]);
        }
        for &u in band.graph.neighbors(band.anchor1) {
            assert!(x[u as usize] > 0.2);
        }
    }

    #[test]
    fn field_to_separator_is_valid() {
        let band = grid_band();
        let x0 = initial_field(&band.state);
        let x = diffusion_iterations(&band.graph, x0, band.anchor0, band.anchor1, 16, 0.9);
        let s = field_to_separator(&band, &x);
        s.validate(&band.graph).unwrap();
        assert!(s.sep_weight() > 0);
        assert_eq!(s.part[band.anchor0], P0);
        assert_eq!(s.part[band.anchor1], P1);
    }

    #[test]
    fn cpu_refiner_improves_or_keeps_quality() {
        let g = generators::irregular_mesh(18, 18, 11);
        let mut rng = Rng::new(21);
        let s = greedy_graph_growing(&g, 3, &mut rng);
        let mut band = extract_band(&g, &s, 3).unwrap();
        let before = band.state.quality_key();
        let r = CpuDiffusionRefiner::default();
        r.refine_band(&mut band, &mut rng);
        band.state.validate(&band.graph).unwrap();
        assert!(band.state.quality_key() <= before);
    }

    #[test]
    fn zero_degree_vertices_decay() {
        // Band whose anchors are isolated (width covers everything).
        let g = generators::path(3, 1);
        let s = SepState::from_parts(&g, vec![P0, SEP, P1]);
        let band = extract_band(&g, &s, 5).unwrap();
        let x0 = initial_field(&band.state);
        let x = diffusion_iterations(&band.graph, x0, band.anchor0, band.anchor1, 8, 0.9);
        // Anchors clamp to ±1 regardless.
        assert_eq!(x[band.anchor0], -1.0);
        assert_eq!(x[band.anchor1], 1.0);
    }

    #[test]
    fn iterations_deterministic() {
        let band = grid_band();
        let x0 = initial_field(&band.state);
        let a = diffusion_iterations(&band.graph, x0.clone(), band.anchor0, band.anchor1, 20, 0.95);
        let b = diffusion_iterations(&band.graph, x0, band.anchor0, band.anchor1, 20, 0.95);
        assert_eq!(a, b);
    }
}
