//! Banded diffusion separator smoothing — the "parallel diffusion-based
//! method" the paper cites as refinement future work ([28], §5) and that
//! we implement as the numeric hot-spot of the three-layer stack.
//!
//! Two liquids flow from the two anchors (part 0 = −1, part 1 = +1)
//! through the band graph; after `k` damped averaging iterations the sign
//! field induces a smooth bipartition whose crossing edges are covered to
//! produce a valid vertex separator, then polished with FM.
//!
//! This module is the **pure-Rust reference**: [`diffusion_iterations`]
//! defines the exact numeric semantics that the L1 Pallas kernel
//! (`python/compile/kernels/ell_spmv.py`) and the L2 JAX model reproduce;
//! `runtime::DiffusionRefiner` swaps the iteration loop for the
//! AOT-compiled XLA executable and is tested to match this function.

use super::band::BandGraph;
use super::fm::{fm_refine, FmParams};
use super::{BandRefiner, SepState, P0, P1, SEP};
use crate::graph::Graph;
use crate::rng::Rng;

/// Initial diffusion field for a band state: −1 on part 0, +1 on part 1,
/// 0 on the separator.
pub fn initial_field(state: &SepState) -> Vec<f32> {
    state
        .part
        .iter()
        .map(|&p| match p {
            P0 => -1.0,
            P1 => 1.0,
            _ => 0.0,
        })
        .collect()
}

/// `k` damped weighted-averaging iterations with the anchor values
/// re-clamped to ∓1 after every step:
///
/// `x'[v] = damping · (Σ_u w(u,v)·x[u]) / Σ_u w(u,v)`
///
/// Zero-degree vertices decay to 0. All arithmetic is f32 to match the
/// XLA artifact bit-for-bit up to reduction order.
pub fn diffusion_iterations(
    g: &Graph,
    mut x: Vec<f32>,
    anchor0: usize,
    anchor1: usize,
    k: usize,
    damping: f32,
) -> Vec<f32> {
    let n = g.n();
    debug_assert_eq!(x.len(), n);
    let mut next = vec![0f32; n];
    for _ in 0..k {
        x[anchor0] = -1.0;
        x[anchor1] = 1.0;
        for v in 0..n {
            let mut num = 0f32;
            let mut den = 0f32;
            for (&u, &w) in g.neighbors(v).iter().zip(g.edge_weights(v)) {
                let w = w as f32;
                num += w * x[u as usize];
                den += w;
            }
            next[v] = if den > 0.0 { damping * num / den } else { 0.0 };
        }
        std::mem::swap(&mut x, &mut next);
    }
    x[anchor0] = -1.0;
    x[anchor1] = 1.0;
    x
}

/// Convert a diffusion field into a valid separator state on the band:
/// parts by sign, then a one-pass vertex cover of crossing edges (the
/// endpoint with the smaller |x| joins the separator; locked vertices —
/// the anchors — never do).
pub fn field_to_separator(band: &BandGraph, x: &[f32]) -> SepState {
    let g = &band.graph;
    let n = g.n();
    let mut part: Vec<u8> = (0..n)
        .map(|v| if x[v] < 0.0 { P0 } else { P1 })
        .collect();
    part[band.anchor0] = P0;
    part[band.anchor1] = P1;
    for v in 0..n {
        if part[v] == SEP {
            continue;
        }
        for &u in g.neighbors(v) {
            let u = u as usize;
            if part[u] == SEP || part[u] == part[v] {
                continue;
            }
            // Crossing edge: cover it with the weaker endpoint.
            let pick_v = if band.locked[v] {
                false
            } else if band.locked[u] {
                true
            } else {
                let (av, au) = (x[v].abs(), x[u].abs());
                av < au || (av == au && v < u)
            };
            if pick_v {
                part[v] = SEP;
                break;
            } else {
                part[u] = SEP;
            }
        }
    }
    SepState::from_parts(g, part)
}

/// Pure-CPU diffusion band refiner: diffusion iterations (reference
/// implementation), sign-cover, FM polish. `runtime::DiffusionRefiner`
/// is the XLA-backed equivalent used on the request path.
#[derive(Clone, Debug)]
pub struct CpuDiffusionRefiner {
    /// Number of diffusion iterations (paper-scale band graphs converge
    /// within a few dozen).
    pub iterations: usize,
    /// Damping factor in (0, 1]; keeps the field contractive.
    pub damping: f32,
    /// FM polish parameters.
    pub fm: FmParams,
}

impl Default for CpuDiffusionRefiner {
    fn default() -> Self {
        CpuDiffusionRefiner {
            iterations: 32,
            damping: 0.95,
            fm: FmParams::default(),
        }
    }
}

impl BandRefiner for CpuDiffusionRefiner {
    fn refine_band(&self, band: &mut BandGraph, rng: &mut Rng) {
        let x0 = initial_field(&band.state);
        let x = diffusion_iterations(
            &band.graph,
            x0,
            band.anchor0,
            band.anchor1,
            self.iterations,
            self.damping,
        );
        let candidate = field_to_separator(band, &x);
        debug_assert!(candidate.validate(&band.graph).is_ok());
        if candidate.quality_key() < band.state.quality_key() {
            band.state = candidate;
        }
        fm_refine(&band.graph, &mut band.state, &band.locked, &self.fm, rng);
    }

    fn name(&self) -> &'static str {
        "diffusion+fm(cpu)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::sep::band::extract_band;
    use crate::sep::initial::greedy_graph_growing;

    fn grid_band() -> BandGraph {
        let g = generators::grid2d(13, 9);
        let part: Vec<u8> = (0..13 * 9)
            .map(|v| {
                let x = v % 13;
                if x < 6 {
                    P0
                } else if x == 6 {
                    SEP
                } else {
                    P1
                }
            })
            .collect();
        let s = SepState::from_parts(&g, part);
        extract_band(&g, &s, 3).unwrap()
    }

    #[test]
    fn field_converges_to_signed_halves() {
        let band = grid_band();
        let x0 = initial_field(&band.state);
        let x = diffusion_iterations(&band.graph, x0, band.anchor0, band.anchor1, 64, 0.95);
        // Vertices adjacent to anchor0 must be clearly negative, and
        // symmetrically for anchor1.
        for (&u, _) in band
            .graph
            .neighbors(band.anchor0)
            .iter()
            .zip(band.graph.edge_weights(band.anchor0))
        {
            assert!(x[u as usize] < -0.2, "x[{u}] = {}", x[u as usize]);
        }
        for &u in band.graph.neighbors(band.anchor1) {
            assert!(x[u as usize] > 0.2);
        }
    }

    #[test]
    fn field_to_separator_is_valid() {
        let band = grid_band();
        let x0 = initial_field(&band.state);
        let x = diffusion_iterations(&band.graph, x0, band.anchor0, band.anchor1, 16, 0.9);
        let s = field_to_separator(&band, &x);
        s.validate(&band.graph).unwrap();
        assert!(s.sep_weight() > 0);
        assert_eq!(s.part[band.anchor0], P0);
        assert_eq!(s.part[band.anchor1], P1);
    }

    #[test]
    fn cpu_refiner_improves_or_keeps_quality() {
        let g = generators::irregular_mesh(18, 18, 11);
        let mut rng = Rng::new(21);
        let s = greedy_graph_growing(&g, 3, &mut rng);
        let mut band = extract_band(&g, &s, 3).unwrap();
        let before = band.state.quality_key();
        let r = CpuDiffusionRefiner::default();
        r.refine_band(&mut band, &mut rng);
        band.state.validate(&band.graph).unwrap();
        assert!(band.state.quality_key() <= before);
    }

    #[test]
    fn zero_degree_vertices_decay() {
        // Band whose anchors are isolated (width covers everything).
        let g = generators::path(3, 1);
        let s = SepState::from_parts(&g, vec![P0, SEP, P1]);
        let band = extract_band(&g, &s, 5).unwrap();
        let x0 = initial_field(&band.state);
        let x = diffusion_iterations(&band.graph, x0, band.anchor0, band.anchor1, 8, 0.9);
        // Anchors clamp to ±1 regardless.
        assert_eq!(x[band.anchor0], -1.0);
        assert_eq!(x[band.anchor1], 1.0);
    }

    #[test]
    fn iterations_deterministic() {
        let band = grid_band();
        let x0 = initial_field(&band.state);
        let a = diffusion_iterations(&band.graph, x0.clone(), band.anchor0, band.anchor1, 20, 0.95);
        let b = diffusion_iterations(&band.graph, x0, band.anchor0, band.anchor1, 20, 0.95);
        assert_eq!(a, b);
    }
}
