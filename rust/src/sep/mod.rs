//! Vertex-separator computation (S5–S6): the sequential Scotch-like
//! multilevel bisection pipeline, reused verbatim by the distributed layer
//! in its multi-sequential phases (paper §3.2–§3.3).

pub mod band;
pub mod coarsen;
pub mod diffusion;
pub mod flow;
pub mod fm;
pub mod initial;
pub mod multilevel;

pub use band::{extract_band, refine_band_with_mode, BandGraph};
pub use coarsen::{coarsen_hem, Coarsening};
pub use flow::{flow_candidate, flow_refine_band, FlowRefiner};
pub use fm::{fm_refine, FmParams};
pub use multilevel::multilevel_separator;

use crate::graph::Graph;
use crate::rng::Rng;
use crate::{Error, Result};

/// Part labels: the two separated parts and the separator itself.
pub const P0: u8 = 0;
/// Second part.
pub const P1: u8 = 1;
/// Separator label.
pub const SEP: u8 = 2;

/// A vertex-separator state over a graph: each vertex is in part 0,
/// part 1 or the separator; `wgts` caches the three part weights.
///
/// Invariant: no edge joins a part-0 vertex to a part-1 vertex (every
/// 0–1 path passes through the separator).
#[derive(Clone, Debug)]
pub struct SepState {
    /// Per-vertex label among [`P0`], [`P1`], [`SEP`].
    pub part: Vec<u8>,
    /// Cached weights of part 0, part 1 and the separator.
    pub wgts: [i64; 3],
}

impl SepState {
    /// Build a state from labels, computing the cached weights.
    pub fn from_parts(g: &Graph, part: Vec<u8>) -> SepState {
        let mut wgts = [0i64; 3];
        for (v, &p) in part.iter().enumerate() {
            wgts[p as usize] += g.vwgt[v];
        }
        SepState { part, wgts }
    }

    /// Everything in part 0 (the trivial all-one-side state).
    pub fn all_in_p0(g: &Graph) -> SepState {
        SepState {
            part: vec![P0; g.n()],
            wgts: [g.total_vwgt(), 0, 0],
        }
    }

    /// Weight of the separator.
    #[inline]
    pub fn sep_weight(&self) -> i64 {
        self.wgts[2]
    }

    /// Absolute imbalance `|w0 - w1|`.
    #[inline]
    pub fn imbalance(&self) -> i64 {
        (self.wgts[0] - self.wgts[1]).abs()
    }

    /// Number of separator vertices.
    pub fn sep_count(&self) -> usize {
        self.part.iter().filter(|&&p| p == SEP).count()
    }

    /// Indices of separator vertices.
    pub fn sep_vertices(&self) -> Vec<usize> {
        (0..self.part.len()).filter(|&v| self.part[v] == SEP).collect()
    }

    /// Lexicographic quality key: smaller separator first, then better
    /// balance. Used everywhere a "best of k" decision is taken
    /// (multi-sequential refinement, fold-dup best-pick, GGG tries).
    #[inline]
    pub fn quality_key(&self) -> (i64, i64) {
        (self.sep_weight(), self.imbalance())
    }

    /// Recompute `wgts` from the labels (after a bulk label rewrite).
    pub fn recompute_weights(&mut self, g: &Graph) {
        let mut wgts = [0i64; 3];
        for (v, &p) in self.part.iter().enumerate() {
            wgts[p as usize] += g.vwgt[v];
        }
        self.wgts = wgts;
    }

    /// Validate the separator invariants against `g`:
    /// labels in range, cached weights correct, and **no 0–1 edge**.
    pub fn validate(&self, g: &Graph) -> Result<()> {
        if self.part.len() != g.n() {
            return Err(Error::InvalidGraph(format!(
                "part length {} != n {}",
                self.part.len(),
                g.n()
            )));
        }
        let mut wgts = [0i64; 3];
        for (v, &p) in self.part.iter().enumerate() {
            if p > SEP {
                return Err(Error::InvalidGraph(format!("bad part label {p} at {v}")));
            }
            wgts[p as usize] += g.vwgt[v];
        }
        if wgts != self.wgts {
            return Err(Error::InvalidGraph(format!(
                "cached weights {:?} != actual {:?}",
                self.wgts, wgts
            )));
        }
        for v in 0..g.n() {
            if self.part[v] == SEP {
                continue;
            }
            for &u in g.neighbors(v) {
                let u = u as usize;
                if self.part[u] != SEP && self.part[u] != self.part[v] {
                    return Err(Error::InvalidGraph(format!(
                        "edge {v}({}) -- {u}({}) crosses parts",
                        self.part[v], self.part[u]
                    )));
                }
            }
        }
        Ok(())
    }
}

/// A pluggable refiner for band graphs. The default is sequential vertex
/// FM ([`FmRefiner`]); [`crate::runtime::DiffusionRefiner`] runs the
/// AOT-compiled XLA diffusion kernel first and then polishes with FM
/// (paper §3.3 / future-work §5: diffusion-based methods).
pub trait BandRefiner: Sync {
    /// Refine `band.state` in place; must preserve the separator
    /// invariant and respect `band.locked` (anchors never move).
    fn refine_band(&self, band: &mut BandGraph, rng: &mut Rng);
    /// Human-readable name for logs and ablation benches.
    fn name(&self) -> &'static str;
}

/// The standard sequential vertex-FM band refiner.
#[derive(Clone, Debug, Default)]
pub struct FmRefiner {
    /// FM tuning parameters.
    pub params: FmParams,
}

impl BandRefiner for FmRefiner {
    fn refine_band(&self, band: &mut BandGraph, rng: &mut Rng) {
        fm_refine(&band.graph, &mut band.state, &band.locked, &self.params, rng);
    }

    fn name(&self) -> &'static str {
        "fm"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn from_parts_weights() {
        let g = generators::path(4, 1);
        let s = SepState::from_parts(&g, vec![P0, SEP, P1, P1]);
        assert_eq!(s.wgts, [1, 2, 1]);
        assert_eq!(s.sep_weight(), 1);
        assert_eq!(s.imbalance(), 1);
        assert_eq!(s.sep_count(), 1);
        assert_eq!(s.sep_vertices(), vec![1]);
        s.validate(&g).unwrap();
    }

    #[test]
    fn validate_catches_crossing_edge() {
        let g = generators::path(3, 1);
        let s = SepState::from_parts(&g, vec![P0, P1, P1]);
        assert!(s.validate(&g).is_err());
    }

    #[test]
    fn validate_catches_stale_weights() {
        let g = generators::path(3, 1);
        let mut s = SepState::from_parts(&g, vec![P0, SEP, P1]);
        s.wgts = [3, 0, 0];
        assert!(s.validate(&g).is_err());
    }

    #[test]
    fn quality_key_orders_better_first() {
        let g = generators::path(5, 1);
        let a = SepState::from_parts(&g, vec![P0, P0, SEP, P1, P1]);
        let b = SepState::from_parts(&g, vec![P0, SEP, SEP, P1, P1]);
        assert!(a.quality_key() < b.quality_key());
    }
}
