//! Initial separator computation on the coarsest graph.
//!
//! Greedy graph growing (GGG): grow part 0 by BFS from a random seed until
//! it holds about half of the total vertex weight, take the lighter side
//! of the resulting boundary as the vertex separator, then let the caller
//! refine with FM. Several tries with different seeds are performed and
//! the best state (smallest separator, then best balance) is kept —
//! exactly the "best of k" selection philosophy of §3.2.

use super::{SepState, P0, P1, SEP};
use crate::graph::Graph;
use crate::rng::Rng;
use std::collections::VecDeque;

/// Grow part 0 from `seed` until ≈ half the total weight is consumed.
/// Works on disconnected graphs by restarting from unvisited vertices.
fn grow_half(g: &Graph, seed: usize, rng: &mut Rng) -> Vec<u8> {
    let n = g.n();
    let total = g.total_vwgt();
    let half = total / 2;
    let mut part = vec![P1; n];
    let mut w0 = 0i64;
    let mut queue = VecDeque::new();
    let mut enqueued = vec![false; n];
    queue.push_back(seed);
    enqueued[seed] = true;
    let mut next_probe = 0usize;
    while w0 < half {
        let v = match queue.pop_front() {
            Some(v) => v,
            None => {
                // Disconnected: restart from a random-ish unvisited vertex.
                let mut found = None;
                for _ in 0..4 {
                    let cand = rng.below(n);
                    if !enqueued[cand] {
                        found = Some(cand);
                        break;
                    }
                }
                let v = found.or_else(|| {
                    while next_probe < n && enqueued[next_probe] {
                        next_probe += 1;
                    }
                    (next_probe < n).then_some(next_probe)
                });
                match v {
                    Some(v) => {
                        enqueued[v] = true;
                        v
                    }
                    None => break, // everything consumed
                }
            }
        };
        part[v] = P0;
        w0 += g.vwgt[v];
        for &u in g.neighbors(v) {
            let u = u as usize;
            if !enqueued[u] {
                enqueued[u] = true;
                queue.push_back(u);
            }
        }
    }
    part
}

/// Turn a 2-way partition into a valid vertex-separator state by moving
/// the lighter boundary side into the separator.
pub fn boundary_to_separator(g: &Graph, mut part: Vec<u8>) -> SepState {
    let mut bw = [0i64; 2];
    let mut boundary = [Vec::new(), Vec::new()];
    for v in 0..g.n() {
        let p = part[v];
        if p == SEP {
            continue;
        }
        if g
            .neighbors(v)
            .iter()
            .any(|&u| part[u as usize] != p && part[u as usize] != SEP)
        {
            bw[p as usize] += g.vwgt[v];
            boundary[p as usize].push(v);
        }
    }
    let side = if bw[0] <= bw[1] { 0 } else { 1 };
    for &v in &boundary[side] {
        part[v] = SEP;
    }
    SepState::from_parts(g, part)
}

/// Greedy-graph-growing initial separator: best of `tries` seeds.
pub fn greedy_graph_growing(g: &Graph, tries: usize, rng: &mut Rng) -> SepState {
    let n = g.n();
    if n == 0 {
        return SepState {
            part: Vec::new(),
            wgts: [0; 3],
        };
    }
    if n == 1 {
        return SepState::from_parts(g, vec![P0]);
    }
    let mut best: Option<SepState> = None;
    for t in 0..tries.max(1) {
        let seed = if t == 0 {
            g.pseudo_peripheral(rng.below(n))
        } else {
            rng.below(n)
        };
        let part = grow_half(g, seed, rng);
        let state = boundary_to_separator(g, part);
        debug_assert!(state.validate(g).is_ok());
        if best
            .as_ref()
            .map(|b| state.quality_key() < b.quality_key())
            .unwrap_or(true)
        {
            best = Some(state);
        }
    }
    best.expect("at least one try")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn ggg_on_path_is_balanced_small_sep() {
        let g = generators::path(101, 1);
        let mut rng = Rng::new(1);
        let s = greedy_graph_growing(&g, 4, &mut rng);
        s.validate(&g).unwrap();
        assert!(s.sep_weight() <= 2, "sep weight {}", s.sep_weight());
        assert!(s.imbalance() <= 10, "imbalance {}", s.imbalance());
        assert!(s.wgts[0] > 0 && s.wgts[1] > 0);
    }

    #[test]
    fn ggg_on_grid_scales_like_sqrt() {
        let g = generators::grid2d(20, 20);
        let mut rng = Rng::new(2);
        let s = greedy_graph_growing(&g, 4, &mut rng);
        s.validate(&g).unwrap();
        // A BFS-grown boundary on a 20×20 grid should be ≲ 2 columns.
        assert!(s.sep_weight() <= 44, "sep weight {}", s.sep_weight());
        assert!(s.wgts[0] > 0 && s.wgts[1] > 0);
    }

    #[test]
    fn ggg_handles_disconnected() {
        // Two disjoint paths: the separator can be empty.
        let mut b = crate::graph::GraphBuilder::new(8);
        for v in 1..4 {
            b.add_edge(v - 1, v);
        }
        for v in 5..8 {
            b.add_edge(v - 1, v);
        }
        let g = b.build().unwrap();
        let mut rng = Rng::new(3);
        let s = greedy_graph_growing(&g, 4, &mut rng);
        s.validate(&g).unwrap();
        assert!(s.wgts[0] > 0 && s.wgts[1] > 0);
    }

    #[test]
    fn ggg_single_vertex_and_edge() {
        let g1 = generators::path(1, 1);
        let s1 = greedy_graph_growing(&g1, 2, &mut Rng::new(4));
        s1.validate(&g1).unwrap();
        let g2 = generators::path(2, 1);
        let s2 = greedy_graph_growing(&g2, 2, &mut Rng::new(4));
        s2.validate(&g2).unwrap();
        // All weight is accounted for and at most one vertex separates.
        assert_eq!(s2.wgts.iter().sum::<i64>(), 2);
        assert!(s2.sep_weight() <= 1);
    }

    #[test]
    fn boundary_to_separator_keeps_invariant() {
        let g = generators::grid2d(6, 6);
        // Left half in P0, right half in P1 (crossing edges exist).
        let part: Vec<u8> = (0..36).map(|v| if v % 6 < 3 { P0 } else { P1 }).collect();
        let s = boundary_to_separator(&g, part);
        s.validate(&g).unwrap();
        assert_eq!(s.sep_weight(), 6); // one full column
    }
}
