//! Band-graph extraction (§3.3): keep only vertices at distance ≤ `width`
//! from the separator, replace each discarded side by a single *anchor*
//! vertex of equal weight connected to the last kept layer of its part.
//! Refining the much smaller band graph (with anchors locked) confines
//! the separator to the band — the paper's key quality/scalability lever,
//! with width 3 found optimal.

use super::diffusion::CpuDiffusionRefiner;
use super::{flow, BandRefiner, FmRefiner, SepState, P0, P1, SEP};
use crate::graph::{Graph, GraphBuilder};
use crate::rng::Rng;
use crate::strategy::{RefineMode, SepStrategy};
use crate::trace;

/// A band graph: the extracted subgraph, the map back to parent vertices,
/// the two anchor ids, the separator state restricted to the band, and
/// the lock vector (anchors locked).
#[derive(Clone, Debug)]
pub struct BandGraph {
    /// The band graph itself (band vertices + 2 anchors at the end).
    pub graph: Graph,
    /// `orig[i]` = parent-graph id of band vertex `i` (anchors excluded).
    pub orig: Vec<usize>,
    /// Index of the part-0 anchor (`orig.len()`).
    pub anchor0: usize,
    /// Index of the part-1 anchor (`orig.len() + 1`).
    pub anchor1: usize,
    /// Separator state on the band graph.
    pub state: SepState,
    /// Lock vector for FM: anchors are locked.
    pub locked: Vec<bool>,
}

impl BandGraph {
    /// Number of non-anchor band vertices.
    pub fn band_n(&self) -> usize {
        self.orig.len()
    }
}

/// Extract the band of vertices at distance ≤ `width` from the separator
/// of `state`. Returns `None` when the separator is empty (nothing to
/// refine) — e.g. on disconnected graphs.
pub fn extract_band(g: &Graph, state: &SepState, width: u32) -> Option<BandGraph> {
    let seps = state.sep_vertices();
    if seps.is_empty() {
        return None;
    }
    let dist = g.multi_source_bfs(&seps, width);
    let n = g.n();
    let mut local = vec![u32::MAX; n];
    let mut orig = Vec::new();
    for v in 0..n {
        if dist[v] != u32::MAX {
            local[v] = orig.len() as u32;
            orig.push(v);
        }
    }
    let nb = orig.len();
    let anchor0 = nb;
    let anchor1 = nb + 1;
    let mut b = GraphBuilder::new(nb + 2);
    // Anchor weights = total excluded weight per part (≥ 1 to satisfy the
    // positive-weight invariant when a whole part lies inside the band).
    let mut excl = [0i64; 2];
    for v in 0..n {
        if dist[v] == u32::MAX {
            excl[state.part[v] as usize] += g.vwgt[v];
        }
    }
    b.set_vwgt(anchor0, excl[0].max(1));
    b.set_vwgt(anchor1, excl[1].max(1));
    let mut part = vec![SEP; nb + 2];
    for (i, &ov) in orig.iter().enumerate() {
        part[i] = state.part[ov];
        b.set_vwgt(i, g.vwgt[ov]);
        for (&u, &w) in g.neighbors(ov).iter().zip(g.edge_weights(ov)) {
            let u = u as usize;
            match local[u] {
                u32::MAX => {
                    // Neighbor outside the band: represented by the anchor
                    // of its part (its part equals ov's part, since the
                    // band contains every vertex within `width ≥ 1` of the
                    // separator and parts only touch through it).
                    let a = if state.part[u] == P0 { anchor0 } else { anchor1 };
                    b.add_edge_w(i, a, w);
                }
                lu => {
                    if (lu as usize) > i {
                        b.add_edge_w(i, lu as usize, w);
                    }
                }
            }
        }
    }
    part[anchor0] = P0;
    part[anchor1] = P1;
    let graph = b.build().expect("band graph is structurally valid");
    let state_band = SepState::from_parts(&graph, part);
    let mut locked = vec![false; nb + 2];
    locked[anchor0] = true;
    locked[anchor1] = true;
    Some(BandGraph {
        graph,
        orig,
        anchor0,
        anchor1,
        state: state_band,
        locked,
    })
}

/// Write a refined band state back into the parent separator state.
pub fn project_band(band: &BandGraph, g: &Graph, state: &mut SepState) {
    for (i, &ov) in band.orig.iter().enumerate() {
        state.part[ov] = band.state.part[i];
    }
    state.recompute_weights(g);
    debug_assert!(state.validate(g).is_ok());
}

/// Refine a band under the `refine=` mode of `strat` (DESIGN.md §4):
/// `fm` and `diffusion` force the corresponding refiner regardless of
/// the `refiner=` base object, `flow` runs only the max-flow
/// min-vertex-cut pass, and `auto` (the default ladder) runs the base
/// refiner and then additionally competes the flow cut whenever the
/// band fits the `flowband=` size budget — each stage already commits
/// only strict quality-key improvements, so the result is the best of
/// the ladder. Shared by the sequential uncoarsening path and the
/// distributed multi-sequential selection (`dist::dsep`).
pub fn refine_band_with_mode(
    band: &mut BandGraph,
    base: &dyn BandRefiner,
    strat: &SepStrategy,
    rng: &mut Rng,
) {
    match strat.refine {
        RefineMode::Fm => {
            let _span = trace::scope(trace::Phase::RefineFm);
            FmRefiner {
                params: strat.fm.clone(),
            }
            .refine_band(band, rng)
        }
        RefineMode::Diffusion => {
            let _span = trace::scope(trace::Phase::RefineDiffusion);
            CpuDiffusionRefiner {
                fm: strat.fm.clone(),
                ..CpuDiffusionRefiner::default()
            }
            .refine_band(band, rng)
        }
        RefineMode::Flow => {
            let _span = trace::scope(trace::Phase::RefineFlow);
            flow::flow_refine_band(band);
        }
        RefineMode::Auto => {
            {
                // The base `refiner=` object is FM or diffusion; tag the
                // ladder's first rung with the generic FM phase — the
                // quality events carry the exact knob string.
                let _span = trace::scope(trace::Phase::RefineFm);
                base.refine_band(band, rng);
            }
            if band.graph.n() <= strat.flow_max_band {
                let _span = trace::scope(trace::Phase::RefineFlow);
                flow::flow_refine_band(band);
            }
        }
    }
}

/// One band-refinement step: extract a band of `strat.band_width`, run
/// the `refine=` dispatch over `refiner`, project back. Keeps the
/// better of (refined, original) by quality key — refiners are not
/// required to be monotone. Returns `true` if a band existed.
pub fn band_refine_step(
    g: &Graph,
    state: &mut SepState,
    strat: &SepStrategy,
    refiner: &dyn BandRefiner,
    rng: &mut Rng,
) -> bool {
    let band = {
        let _span = trace::scope(trace::Phase::BandExtract);
        extract_band(g, state, strat.band_width)
    };
    let Some(mut band) = band else {
        return false;
    };
    let before = state.quality_key();
    refine_band_with_mode(&mut band, refiner, strat, rng);
    debug_assert!(band.state.validate(&band.graph).is_ok());
    if band.state.quality_key() < before {
        project_band(&band, g, state);
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::sep::fm::FmParams;
    use crate::sep::initial::greedy_graph_growing;
    use crate::sep::FmRefiner;

    fn mid_grid_state(nx: usize, ny: usize) -> (Graph, SepState) {
        let g = generators::grid2d(nx, ny);
        let mid = nx / 2;
        let part: Vec<u8> = (0..nx * ny)
            .map(|v| {
                let x = v % nx;
                if x < mid {
                    P0
                } else if x == mid {
                    SEP
                } else {
                    P1
                }
            })
            .collect();
        let s = SepState::from_parts(&g, part);
        s.validate(&g).unwrap();
        (g, s)
    }

    #[test]
    fn band_of_column_separator_has_expected_width() {
        let (g, s) = mid_grid_state(11, 7);
        let band = extract_band(&g, &s, 2).unwrap();
        // Columns mid-2 .. mid+2 → 5 columns × 7 rows.
        assert_eq!(band.band_n(), 5 * 7);
        band.graph.validate().unwrap();
        band.state.validate(&band.graph).unwrap();
        // Anchor weights must equal the excluded part weights.
        assert_eq!(band.graph.vwgt[band.anchor0], 3 * 7);
        assert_eq!(band.graph.vwgt[band.anchor1], 3 * 7);
    }

    #[test]
    fn band_state_weights_match_parent() {
        let (g, s) = mid_grid_state(11, 7);
        let band = extract_band(&g, &s, 3).unwrap();
        // Total band weight (with anchors) equals parent total.
        assert_eq!(band.graph.total_vwgt(), g.total_vwgt());
        assert_eq!(band.state.wgts, s.wgts);
    }

    #[test]
    fn empty_separator_yields_none() {
        let g = generators::path(5, 1);
        let s = SepState::from_parts(&g, vec![P0; 5]);
        assert!(extract_band(&g, &s, 3).is_none());
    }

    #[test]
    fn project_band_roundtrip_identity() {
        let (g, mut s) = mid_grid_state(9, 5);
        let before = s.part.clone();
        let band = extract_band(&g, &s, 3).unwrap();
        project_band(&band, &g, &mut s);
        assert_eq!(s.part, before);
    }

    #[test]
    fn band_refine_step_improves_or_keeps() {
        let g = generators::irregular_mesh(16, 16, 5);
        let mut rng = Rng::new(6);
        let mut s = greedy_graph_growing(&g, 3, &mut rng);
        let before = s.quality_key();
        let refiner = FmRefiner {
            params: FmParams::default(),
        };
        let strat = SepStrategy {
            band_width: 3,
            ..SepStrategy::default()
        };
        let had_band = band_refine_step(&g, &mut s, &strat, &refiner, &mut rng);
        assert!(had_band);
        s.validate(&g).unwrap();
        assert!(s.quality_key() <= before);
    }

    #[test]
    fn refined_separator_stays_within_band() {
        // Width-1 band around a mid column: after FM, every separator
        // vertex must be within distance 1 of the original separator.
        let (g, mut s) = mid_grid_state(15, 9);
        let orig_sep = s.sep_vertices();
        let dist = g.multi_source_bfs(&orig_sep, u32::MAX);
        let refiner = FmRefiner {
            params: FmParams::default(),
        };
        let mut rng = Rng::new(7);
        let strat = SepStrategy {
            band_width: 1,
            ..SepStrategy::default()
        };
        band_refine_step(&g, &mut s, &strat, &refiner, &mut rng);
        s.validate(&g).unwrap();
        for v in s.sep_vertices() {
            assert!(dist[v] <= 1, "separator escaped the band at {v}");
        }
    }

    #[test]
    fn whole_graph_band_when_width_large() {
        let (g, s) = mid_grid_state(7, 5);
        let band = extract_band(&g, &s, 100).unwrap();
        assert_eq!(band.band_n(), g.n());
        // Anchors get the minimum weight 1 and are isolated.
        assert_eq!(band.graph.vwgt[band.anchor0], 1);
        assert_eq!(band.graph.degree(band.anchor0), 0);
    }
}
