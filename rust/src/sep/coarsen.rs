//! Sequential heavy-edge-matching coarsening (§3.2's building block).
//!
//! Vertices are visited in random order; an unmatched vertex mates with
//! the unmatched neighbor linked by the heaviest edge (random tie-break,
//! as in Karypis & Kumar [17]). Matched pairs collapse into coarse
//! vertices whose weights are summed; parallel collapsed edges sum their
//! weights so that coarse cuts equal fine cuts.

use crate::graph::Graph;
use crate::rng::Rng;

/// Result of one coarsening level.
#[derive(Clone, Debug)]
pub struct Coarsening {
    /// The coarser graph.
    pub coarse: Graph,
    /// `map[fine] = coarse` vertex id.
    pub map: Vec<u32>,
}

/// One level of heavy-edge-matching coarsening.
pub fn coarsen_hem(g: &Graph, rng: &mut Rng) -> Coarsening {
    let n = g.n();
    let mut mate: Vec<u32> = vec![u32::MAX; n];
    let order = rng.permutation(n);
    for &v in &order {
        if mate[v] != u32::MAX {
            continue;
        }
        // Heaviest unmatched neighbor; random tie-break among the heaviest.
        let mut best: Option<usize> = None;
        let mut best_w = i64::MIN;
        let mut ties = 0usize;
        for (&u, &w) in g.neighbors(v).iter().zip(g.edge_weights(v)) {
            let u = u as usize;
            if mate[u] != u32::MAX {
                continue;
            }
            if w > best_w {
                best_w = w;
                best = Some(u);
                ties = 1;
            } else if w == best_w {
                ties += 1;
                if rng.below(ties) == 0 {
                    best = Some(u);
                }
            }
        }
        match best {
            Some(u) => {
                mate[v] = u as u32;
                mate[u] = v as u32;
            }
            None => mate[v] = v as u32, // singleton
        }
    }
    build_coarse(g, &mate)
}

/// Build the coarse graph from a mating vector (`mate[v] = v` means
/// singleton). Shared with the distributed coarsening, which computes the
/// mating in parallel but builds per-process fragments the same way.
pub fn build_coarse(g: &Graph, mate: &[u32]) -> Coarsening {
    let n = g.n();
    let mut map = vec![u32::MAX; n];
    let mut nc = 0u32;
    for v in 0..n {
        if map[v] != u32::MAX {
            continue;
        }
        let m = mate[v] as usize;
        map[v] = nc;
        if m != v {
            map[m] = nc;
        }
        nc += 1;
    }
    let ncoarse = nc as usize;

    // Count + fill CSR directly (no builder) — this is the hot path of
    // the multilevel scheme (the paper names coarsening its most
    // time-consuming phase). Duplicate coarse edges are merged with a
    // stamp array in O(m) total instead of per-row sorting (§Perf opt 2).
    let mut vwgt = vec![0i64; ncoarse];
    for v in 0..n {
        vwgt[map[v] as usize] += g.vwgt[v];
    }
    // Fine constituents of each coarse vertex, CSR-style.
    let mut members = vec![0u32; n];
    let mut moff = vec![0usize; ncoarse + 1];
    for v in 0..n {
        moff[map[v] as usize + 1] += 1;
    }
    for c in 0..ncoarse {
        moff[c + 1] += moff[c];
    }
    let mut mfill = moff.clone();
    for v in 0..n {
        let c = map[v] as usize;
        members[mfill[c]] = v as u32;
        mfill[c] += 1;
    }
    let mut cxadj = Vec::with_capacity(ncoarse + 1);
    cxadj.push(0usize);
    let mut cadj: Vec<u32> = Vec::with_capacity(g.arcs());
    let mut cewgt: Vec<i64> = Vec::with_capacity(g.arcs());
    // stamp[cu] = current coarse vertex; slot[cu] = index in cadj.
    let mut stamp = vec![u32::MAX; ncoarse];
    let mut slot = vec![0usize; ncoarse];
    for c in 0..ncoarse {
        let row_start = cadj.len();
        for k in moff[c]..moff[c + 1] {
            let v = members[k] as usize;
            for (&u, &w) in g.neighbors(v).iter().zip(g.edge_weights(v)) {
                let cu = map[u as usize];
                if cu as usize == c {
                    continue; // collapsed internal edge
                }
                if stamp[cu as usize] == c as u32 {
                    cewgt[slot[cu as usize]] += w;
                } else {
                    stamp[cu as usize] = c as u32;
                    slot[cu as usize] = cadj.len();
                    cadj.push(cu);
                    cewgt.push(w);
                }
            }
        }
        // Keep rows sorted for deterministic downstream behavior.
        let row_end = cadj.len();
        let mut row: Vec<(u32, i64)> = cadj[row_start..row_end]
            .iter()
            .copied()
            .zip(cewgt[row_start..row_end].iter().copied())
            .collect();
        row.sort_unstable_by_key(|&(u, _)| u);
        for (i, (u, w)) in row.into_iter().enumerate() {
            cadj[row_start + i] = u;
            cewgt[row_start + i] = w;
        }
        cxadj.push(row_end);
    }
    Coarsening {
        coarse: Graph {
            xadj: cxadj,
            adj: cadj,
            vwgt,
            ewgt: cewgt,
        },
        map,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;

    #[test]
    fn coarsening_preserves_total_weight() {
        let g = generators::grid2d(10, 10);
        let mut rng = Rng::new(1);
        let c = coarsen_hem(&g, &mut rng);
        c.coarse.validate().unwrap();
        assert_eq!(c.coarse.total_vwgt(), g.total_vwgt());
        assert!(c.coarse.n() < g.n());
        // HEM on a grid should nearly halve the vertex count.
        assert!(c.coarse.n() <= g.n() * 6 / 10, "coarse n = {}", c.coarse.n());
    }

    #[test]
    fn map_is_onto_and_pairs_are_adjacent_or_self() {
        let g = generators::grid3d(5, 5, 5);
        let mut rng = Rng::new(2);
        let c = coarsen_hem(&g, &mut rng);
        let nc = c.coarse.n();
        let mut seen = vec![0usize; nc];
        for v in 0..g.n() {
            seen[c.map[v] as usize] += 1;
        }
        assert!(seen.iter().all(|&s| (1..=2).contains(&s)));
        // Paired fine vertices must be adjacent in the fine graph.
        for v in 0..g.n() {
            for u in 0..v {
                if c.map[u] == c.map[v] {
                    assert!(g.neighbors(v).contains(&(u as u32)));
                }
            }
        }
    }

    #[test]
    fn collapsed_edge_weights_sum() {
        // Square 0-1-2-3-0. Force mate (0,1) and (2,3): coarse graph is a
        // single edge whose weight is 2 (edges 1-2 and 3-0 collapse).
        let g = generators::cycle(4);
        let mate = vec![1, 0, 3, 2];
        let c = build_coarse(&g, &mate);
        assert_eq!(c.coarse.n(), 2);
        assert_eq!(c.coarse.m(), 1);
        assert_eq!(c.coarse.edge_weights(0), &[2]);
        assert_eq!(c.coarse.vwgt, vec![2, 2]);
        c.coarse.validate().unwrap();
    }

    #[test]
    fn coarsening_chain_terminates() {
        let mut g = generators::grid2d(20, 20);
        let mut rng = Rng::new(3);
        for _ in 0..30 {
            if g.n() <= 10 {
                break;
            }
            let c = coarsen_hem(&g, &mut rng);
            assert!(c.coarse.n() < g.n());
            g = c.coarse;
        }
        assert!(g.n() <= 10);
    }

    #[test]
    fn deterministic_under_seed() {
        let g = generators::grid2d(12, 12);
        let a = coarsen_hem(&g, &mut Rng::new(9));
        let b = coarsen_hem(&g, &mut Rng::new(9));
        assert_eq!(a.map, b.map);
        assert_eq!(a.coarse.adj, b.coarse.adj);
    }
}
