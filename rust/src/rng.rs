//! Deterministic pseudo-random number generation.
//!
//! The paper (§4) fixes the random seed of Scotch "for the sake of
//! reproducibility", noting that ordering quality varies by < 2.2 % across
//! seeds. We follow the same policy: every stochastic component (matching
//! order, initial-separator seeds, FM tie-breaking, multi-sequential seed
//! perturbation) draws from an explicitly seeded [`Rng`], so identical
//! inputs + strategy + seed reproduce identical orderings bit-for-bit.
//!
//! The generator is xoshiro256**, seeded through SplitMix64 — small, fast,
//! and dependency-free (the offline crate set has no `rand`).

/// SplitMix64 step; used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** deterministic PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream, e.g. one per rank or per FM instance.
    /// Streams with distinct `stream` ids are decorrelated by mixing.
    pub fn derive(&self, stream: u64) -> Rng {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA24B_AED4_963E_E407);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform value in `[0, bound)`. `bound` must be > 0.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        // 128-bit multiply-shift; bias negligible for our bounds.
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn unit_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn coin(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn derive_decorrelates_streams() {
        let root = Rng::new(7);
        let mut a = root.derive(0);
        let mut b = root.derive(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn unit_f64_in_unit_interval() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let x = r.unit_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }
}
