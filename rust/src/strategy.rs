//! Strategy configuration — the knob set of the whole system, mirroring
//! Scotch's "strategy strings" in spirit. Every paper-relevant parameter
//! (band width 3, fold-dup threshold of 100 vertices/process, leaf
//! threshold, FM tolerances, refiner choice) lives here so the benches and
//! ablations can sweep them.

use crate::comm::Executor;
use crate::sep::fm::FmParams;
use crate::trace::TraceLevel;
use crate::{Error, Result};
use std::fmt;

/// Which band refiner the pipeline uses (ablation A5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RefinerKind {
    /// Sequential vertex FM only (the paper's default).
    Fm,
    /// CPU diffusion smoothing + FM polish (reference implementation).
    DiffusionCpu,
    /// AOT-compiled XLA diffusion kernel + FM polish (the three-layer
    /// hot path; falls back to CPU when no artifact fits).
    DiffusionXla,
}

/// Which execution engine runs the *distributed* band kernels — the
/// diffusion sweeps (`dist::ddiffusion`) and the band BFS
/// (`dist::dband::bfs_band_dist_engine`) — the `engine=` strategy knob.
///
/// The fallback ladder is always available underneath: per-rank XLA
/// kernel execution when a size bucket fits every rank's slice, the
/// scalar CPU path when it does not (or when no artifacts are loaded —
/// CPU sweeps for diffusion, the frontier BFS for band distances),
/// centralized multi-sequential FM for bands small enough to
/// centralize (see `dist::dsep::band_refine_dist`).
///
/// ```
/// use ptscotch::strategy::{BandEngine, Strategy};
///
/// // Default is Auto; `engine=cpu` pins the scalar sweeps.
/// assert_eq!(Strategy::default().dist.band_engine, BandEngine::Auto);
/// assert_eq!(
///     Strategy::parse("engine=cpu").unwrap().dist.band_engine,
///     BandEngine::Cpu,
/// );
/// assert_eq!(
///     Strategy::parse("engine=xla").unwrap().dist.band_engine,
///     BandEngine::Xla,
/// );
/// assert!(Strategy::parse("engine=quantum").is_err());
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BandEngine {
    /// Use the per-rank XLA kernel when a runtime is loaded, a bucket
    /// fits, and the band is large enough to amortize kernel dispatch
    /// (`dist::ddiffusion::AUTO_XLA_MIN_BAND`); CPU sweeps otherwise.
    #[default]
    Auto,
    /// Always the scalar CPU sweeps; the runtime is never consulted.
    Cpu,
    /// Attempt the per-rank XLA kernel on every distributed band,
    /// regardless of size; still falls back to CPU sweeps when no
    /// artifacts are loaded or no bucket fits some rank's slice.
    Xla,
}

/// Which algorithm refines each extracted band — the `refine=` strategy
/// knob, dispatched by `sep::band::refine_band_with_mode` at every
/// uncoarsening level, sequential and distributed alike (DESIGN.md §4).
///
/// Orthogonal to [`RefinerKind`] (`refiner=`), which picks the *base*
/// refiner object (FM vs CPU/XLA diffusion): `refine=` decides whether
/// that base refiner runs at all and whether the max-flow min-vertex-cut
/// pass (`sep::flow`) competes with it.
///
/// ```
/// use ptscotch::strategy::{RefineMode, Strategy};
///
/// assert_eq!(Strategy::default().sep.refine, RefineMode::Auto);
/// assert_eq!(
///     Strategy::parse("refine=flow").unwrap().sep.refine,
///     RefineMode::Flow,
/// );
/// assert_eq!(
///     Strategy::parse("refine=diffusion").unwrap().sep.refine,
///     RefineMode::Diffusion,
/// );
/// assert!(Strategy::parse("refine=simulated-annealing").is_err());
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum RefineMode {
    /// Sequential vertex FM only, ignoring the `refiner=` base choice.
    Fm,
    /// CPU diffusion smoothing + FM polish only.
    Diffusion,
    /// The max-flow min-vertex-cut pass (`sep::flow`) only, with no FM
    /// polish and no band-size budget — committed, like every refiner,
    /// only when strictly better under the quality key.
    Flow,
    /// Today's ladder: run the `refiner=` base refiner, then also try
    /// the flow cut whenever the band fits the `flowband=` size budget
    /// and keep whichever result wins the quality key.
    #[default]
    Auto,
}

impl RefineMode {
    /// Canonical knob value, as accepted by `refine=` and reported in
    /// trace quality events (DESIGN.md §7).
    pub fn name(self) -> &'static str {
        match self {
            RefineMode::Fm => "fm",
            RefineMode::Diffusion => "diffusion",
            RefineMode::Flow => "flow",
            RefineMode::Auto => "auto",
        }
    }
}

/// Parameters of the multilevel separator computation.
#[derive(Clone, Debug, PartialEq)]
pub struct SepStrategy {
    /// Coarsen until at most this many vertices (paper: "a few hundreds").
    pub coarse_target: usize,
    /// Stop coarsening when a level shrinks less than this ratio.
    pub min_coarsen_ratio: f64,
    /// Band width around the projected separator (paper: 3 is optimal).
    pub band_width: u32,
    /// Greedy-graph-growing tries at the coarsest level.
    pub ggg_tries: usize,
    /// FM refinement parameters.
    pub fm: FmParams,
    /// Band refinement mode (`refine=fm|diffusion|flow|auto`).
    pub refine: RefineMode,
    /// Band-size budget (vertex count, anchors included) under which
    /// [`RefineMode::Auto`] tries the flow cut (`flowband=`). Forced
    /// `refine=flow` ignores the budget.
    pub flow_max_band: usize,
}

impl Default for SepStrategy {
    fn default() -> Self {
        SepStrategy {
            coarse_target: 120,
            min_coarsen_ratio: 0.85,
            band_width: 3,
            ggg_tries: 4,
            fm: FmParams::default(),
            refine: RefineMode::default(),
            flow_max_band: 30_000,
        }
    }
}

/// Which minimum-degree method orders the nested-dissection leaves —
/// the `leafmethod=` strategy knob (§3.1: the paper couples ND with
/// halo approximate minimum degree [10]).
///
/// ```
/// use ptscotch::strategy::{LeafMethod, Strategy};
///
/// // The paper-faithful halo-AMD is the default; `leafmethod=mmd`
/// // pins the exact-degree, halo-blind comparator.
/// assert_eq!(Strategy::default().nd.leaf_method, LeafMethod::Hamd);
/// assert_eq!(
///     Strategy::parse("leafmethod=hamd").unwrap().nd.leaf_method,
///     LeafMethod::Hamd,
/// );
/// assert_eq!(
///     Strategy::parse("leafmethod=mmd").unwrap().nd.leaf_method,
///     LeafMethod::Mmd,
/// );
/// assert!(Strategy::parse("leafmethod=amf").is_err());
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum LeafMethod {
    /// Exact-degree multiple minimum degree on the bare leaf subgraph
    /// (no halo — the pre-HAMD behavior, kept as the comparator).
    Mmd,
    /// Halo approximate minimum degree (`order::hamd`): the leaf plus
    /// its ring of already-numbered separator neighbors.
    #[default]
    Hamd,
}

/// Parameters of nested dissection.
#[derive(Clone, Debug, PartialEq)]
pub struct NdStrategy {
    /// Subgraphs at most this large are ordered by minimum degree
    /// (the paper couples ND with (halo) minimum-degree methods [10]).
    pub leaf_threshold: usize,
    /// Stop dissecting when the separator exceeds this fraction of the
    /// subgraph (e.g. near-cliques) and fall back to minimum degree.
    pub max_sep_fraction: f64,
    /// Which minimum-degree method orders the leaves (`leafmethod=`).
    pub leaf_method: LeafMethod,
}

impl Default for NdStrategy {
    fn default() -> Self {
        NdStrategy {
            leaf_threshold: 120,
            max_sep_fraction: 0.5,
            leaf_method: LeafMethod::default(),
        }
    }
}

/// Parameters of the distributed (PT-Scotch) layer.
#[derive(Clone, Debug, PartialEq)]
pub struct DistStrategy {
    /// Fold-dup starts when the average number of vertices per process
    /// drops below this (paper default strategy: 100).
    pub folddup_threshold: usize,
    /// Enable folding-with-duplication (vs plain centralization) —
    /// ablation A3.
    pub fold_dup: bool,
    /// Overlap the two induced-subgraph builds with an extra thread per
    /// process (§3.1; can be disabled like in the paper).
    pub overlap_folds: bool,
    /// Number of parallel matching rounds before giving up on the few
    /// remaining unmatched vertices (paper: "usually converges in 5").
    pub matching_rounds: usize,
    /// Maximum band-graph size (global vertex count) that may be
    /// centralized on every process for multi-sequential refinement;
    /// larger bands are refined in place by the scalable distributed
    /// diffusion kernel (`dist::ddiffusion`).
    pub max_centralized_band: usize,
    /// Number of damped Jacobi sweeps of the distributed diffusion
    /// kernel on oversized bands (each sweep costs one halo exchange of
    /// the scalar field; paper-scale bands converge within a few dozen).
    pub diffusion_sweeps: usize,
    /// Execution engine for the distributed diffusion sweeps
    /// (`engine=auto|cpu|xla`).
    pub band_engine: BandEngine,
    /// Which executor drives the rank fleet
    /// (`executor=sim|threads|env`, DESIGN.md §3). `None` (the `env`
    /// setting, default) defers to the `PTSCOTCH_EXECUTOR` environment
    /// variable with the serialized simulator as the fallback, so tests
    /// run against the deterministic oracle unless explicitly switched.
    ///
    /// ```
    /// use ptscotch::comm::Executor;
    /// use ptscotch::strategy::Strategy;
    ///
    /// assert_eq!(Strategy::default().dist.executor, None);
    /// assert_eq!(
    ///     Strategy::parse("executor=threads").unwrap().dist.executor,
    ///     Some(Executor::Threads),
    /// );
    /// assert_eq!(
    ///     Strategy::parse("executor=sim").unwrap().dist.executor,
    ///     Some(Executor::Sim),
    /// );
    /// assert_eq!(Strategy::parse("executor=env").unwrap().dist.executor, None);
    /// assert!(Strategy::parse("executor=mpi").is_err());
    /// ```
    pub executor: Option<Executor>,
}

impl Default for DistStrategy {
    fn default() -> Self {
        DistStrategy {
            folddup_threshold: 100,
            fold_dup: true,
            overlap_folds: true,
            matching_rounds: 5,
            max_centralized_band: 4_000_000,
            diffusion_sweeps: 32,
            band_engine: BandEngine::default(),
            executor: None,
        }
    }
}

/// Top-level strategy: everything the ordering pipeline needs.
#[derive(Clone, Debug, PartialEq)]
pub struct Strategy {
    /// Root random seed (fixed by default for reproducibility, §4).
    pub seed: u64,
    /// Separator computation parameters.
    pub sep: SepStrategy,
    /// Nested dissection parameters.
    pub nd: NdStrategy,
    /// Distributed-layer parameters.
    pub dist: DistStrategy,
    /// Band refiner used during uncoarsening.
    pub refiner: RefinerKind,
    /// Span-recorder level — the `trace=off|phases|full` knob
    /// (DESIGN.md §7). `off` (the default) leaves one thread-local
    /// check per instrumentation point and records nothing; `phases`
    /// records the algorithmic phases into a per-run `PhaseProfile`;
    /// `full` additionally records every collective and halo exchange
    /// (what the Chrome-trace export is most useful with).
    ///
    /// ```
    /// use ptscotch::strategy::Strategy;
    /// use ptscotch::trace::TraceLevel;
    ///
    /// assert_eq!(Strategy::default().trace, TraceLevel::Off);
    /// assert_eq!(
    ///     Strategy::parse("trace=phases").unwrap().trace,
    ///     TraceLevel::Phases,
    /// );
    /// assert!(Strategy::parse("trace=loud").is_err());
    /// ```
    pub trace: TraceLevel,
}

impl Default for Strategy {
    fn default() -> Self {
        Strategy {
            seed: 1,
            sep: SepStrategy::default(),
            nd: NdStrategy::default(),
            dist: DistStrategy::default(),
            refiner: RefinerKind::Fm,
            trace: TraceLevel::Off,
        }
    }
}

/// Every `key` accepted by [`Strategy::parse`], in the canonical order
/// the [`Strategy`] `Display` implementation emits them. Unknown keys
/// are rejected with an error that names this list.
pub const VALID_KEYS: &[&str] = &[
    "seed",
    "band",
    "coarse",
    "minratio",
    "ggg",
    "passes",
    "neg",
    "eps",
    "leaf",
    "maxsep",
    "leafmethod",
    "refiner",
    "refine",
    "flowband",
    "engine",
    "executor",
    "folddup",
    "foldthresh",
    "overlap",
    "rounds",
    "maxband",
    "sweeps",
    "trace",
];

impl Strategy {
    /// Parse `key=value` pairs (comma-separated) over the default
    /// strategy, e.g.
    /// `band=3,folddup=1,leaf=120,leafmethod=hamd,refiner=xla,engine=auto,executor=sim,seed=42`.
    ///
    /// ```
    /// use ptscotch::strategy::{LeafMethod, Strategy};
    ///
    /// let s = Strategy::parse("leaf=60,leafmethod=hamd,engine=cpu").unwrap();
    /// assert_eq!(s.nd.leaf_threshold, 60);
    /// assert_eq!(s.nd.leaf_method, LeafMethod::Hamd);
    /// ```
    pub fn parse(spec: &str) -> Result<Strategy> {
        let mut s = Strategy::default();
        for tok in spec.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (k, v) = tok
                .split_once('=')
                .ok_or_else(|| Error::InvalidStrategy(format!("expected key=value, got {tok}")))?;
            let parse_usize = |v: &str| {
                v.parse::<usize>()
                    .map_err(|_| Error::InvalidStrategy(format!("bad integer {v} for {k}")))
            };
            match k {
                "seed" => {
                    s.seed = v
                        .parse()
                        .map_err(|_| Error::InvalidStrategy(format!("bad seed {v}")))?
                }
                "band" => {
                    s.sep.band_width = u32::try_from(parse_usize(v)?).map_err(|_| {
                        Error::InvalidStrategy(format!("band width {v} exceeds u32"))
                    })?
                }
                "coarse" => s.sep.coarse_target = parse_usize(v)?,
                "minratio" => {
                    s.sep.min_coarsen_ratio = v
                        .parse()
                        .map_err(|_| Error::InvalidStrategy(format!("bad minratio {v}")))?
                }
                "maxsep" => {
                    s.nd.max_sep_fraction = v
                        .parse()
                        .map_err(|_| Error::InvalidStrategy(format!("bad maxsep {v}")))?
                }
                "ggg" => s.sep.ggg_tries = parse_usize(v)?,
                "passes" => s.sep.fm.max_passes = parse_usize(v)?,
                "neg" => s.sep.fm.max_neg_moves = parse_usize(v)?,
                "eps" => {
                    s.sep.fm.balance_eps = v
                        .parse()
                        .map_err(|_| Error::InvalidStrategy(format!("bad eps {v}")))?
                }
                "leaf" => s.nd.leaf_threshold = parse_usize(v)?,
                "leafmethod" => {
                    s.nd.leaf_method = match v {
                        "mmd" => LeafMethod::Mmd,
                        "hamd" => LeafMethod::Hamd,
                        _ => {
                            return Err(Error::InvalidStrategy(format!(
                                "unknown leaf method {v} (mmd|hamd)"
                            )))
                        }
                    }
                }
                "folddup" => s.dist.fold_dup = v != "0",
                "foldthresh" => s.dist.folddup_threshold = parse_usize(v)?,
                "overlap" => s.dist.overlap_folds = v != "0",
                "rounds" => s.dist.matching_rounds = parse_usize(v)?,
                "maxband" => s.dist.max_centralized_band = parse_usize(v)?,
                "sweeps" => s.dist.diffusion_sweeps = parse_usize(v)?,
                "executor" => {
                    s.dist.executor = match v {
                        "env" => None,
                        _ => Some(v.parse::<Executor>().map_err(Error::InvalidStrategy)?),
                    }
                }
                "engine" => {
                    s.dist.band_engine = match v {
                        "auto" => BandEngine::Auto,
                        "cpu" => BandEngine::Cpu,
                        "xla" => BandEngine::Xla,
                        _ => {
                            return Err(Error::InvalidStrategy(format!(
                                "unknown engine {v} (auto|cpu|xla)"
                            )))
                        }
                    }
                }
                "refiner" => {
                    s.refiner = match v {
                        "fm" => RefinerKind::Fm,
                        "diffcpu" => RefinerKind::DiffusionCpu,
                        "xla" | "diffxla" => RefinerKind::DiffusionXla,
                        _ => {
                            return Err(Error::InvalidStrategy(format!(
                                "unknown refiner {v} (fm|diffcpu|xla)"
                            )))
                        }
                    }
                }
                "refine" => {
                    s.sep.refine = match v {
                        "fm" => RefineMode::Fm,
                        "diffusion" => RefineMode::Diffusion,
                        "flow" => RefineMode::Flow,
                        "auto" => RefineMode::Auto,
                        _ => {
                            return Err(Error::InvalidStrategy(format!(
                                "unknown refine mode {v} (fm|diffusion|flow|auto)"
                            )))
                        }
                    }
                }
                "flowband" => s.sep.flow_max_band = parse_usize(v)?,
                "trace" => s.trace = v.parse::<TraceLevel>().map_err(Error::InvalidStrategy)?,
                _ => {
                    return Err(Error::InvalidStrategy(format!(
                        "unknown key {k} (valid keys: {})",
                        VALID_KEYS.join(", ")
                    )))
                }
            }
        }
        s.validate()?;
        Ok(s)
    }

    /// Sanity-check parameter ranges.
    pub fn validate(&self) -> Result<()> {
        if self.sep.coarse_target < 2 {
            return Err(Error::InvalidStrategy("coarse_target must be ≥ 2".into()));
        }
        if !(0.0..1.0).contains(&self.sep.fm.balance_eps) {
            return Err(Error::InvalidStrategy("balance_eps must be in [0,1)".into()));
        }
        if self.sep.band_width == 0 {
            return Err(Error::InvalidStrategy("band width must be ≥ 1".into()));
        }
        if !(0.0..=1.0).contains(&self.sep.min_coarsen_ratio) {
            return Err(Error::InvalidStrategy("minratio must be in [0,1]".into()));
        }
        if !(0.0..=1.0).contains(&self.nd.max_sep_fraction) {
            return Err(Error::InvalidStrategy("maxsep must be in [0,1]".into()));
        }
        if self.nd.leaf_threshold < 1 {
            return Err(Error::InvalidStrategy("leaf threshold must be ≥ 1".into()));
        }
        if self.dist.diffusion_sweeps == 0 {
            return Err(Error::InvalidStrategy(
                "diffusion sweeps must be ≥ 1".into(),
            ));
        }
        Ok(())
    }
}

impl fmt::Display for Strategy {
    /// The **canonical form** of the strategy: every [`VALID_KEYS`]
    /// knob, in that fixed order, with its current value — so any two
    /// `Strategy` values compare equal iff their canonical forms are
    /// byte-identical. This string is the strategy component of the
    /// service-layer request fingerprint (DESIGN.md §6), so it must
    /// round-trip through [`Strategy::parse`] losslessly.
    ///
    /// ```
    /// use ptscotch::strategy::Strategy;
    ///
    /// let s = Strategy::parse("band=5, seed=9,folddup=0").unwrap();
    /// let canon = s.to_string();
    /// // Round-trip: parsing the canonical form reproduces it exactly.
    /// assert_eq!(Strategy::parse(&canon).unwrap().to_string(), canon);
    /// assert!(canon.contains("band=5"));
    /// assert!(canon.contains("seed=9"));
    /// assert!(canon.contains("folddup=0"));
    /// ```
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let executor = match self.dist.executor {
            None => "env".to_string(),
            Some(e) => e.name().to_string(),
        };
        let leafmethod = match self.nd.leaf_method {
            LeafMethod::Mmd => "mmd",
            LeafMethod::Hamd => "hamd",
        };
        let refiner = match self.refiner {
            RefinerKind::Fm => "fm",
            RefinerKind::DiffusionCpu => "diffcpu",
            RefinerKind::DiffusionXla => "xla",
        };
        let refine = self.sep.refine.name();
        let engine = match self.dist.band_engine {
            BandEngine::Auto => "auto",
            BandEngine::Cpu => "cpu",
            BandEngine::Xla => "xla",
        };
        write!(
            f,
            "seed={},band={},coarse={},minratio={},ggg={},passes={},neg={},eps={},\
             leaf={},maxsep={},leafmethod={leafmethod},refiner={refiner},refine={refine},\
             flowband={},engine={engine},\
             executor={executor},folddup={},foldthresh={},overlap={},rounds={},\
             maxband={},sweeps={},trace={}",
            self.seed,
            self.sep.band_width,
            self.sep.coarse_target,
            self.sep.min_coarsen_ratio,
            self.sep.ggg_tries,
            self.sep.fm.max_passes,
            self.sep.fm.max_neg_moves,
            self.sep.fm.balance_eps,
            self.nd.leaf_threshold,
            self.nd.max_sep_fraction,
            self.sep.flow_max_band,
            u8::from(self.dist.fold_dup),
            self.dist.folddup_threshold,
            u8::from(self.dist.overlap_folds),
            self.dist.matching_rounds,
            self.dist.max_centralized_band,
            self.dist.diffusion_sweeps,
            self.trace,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let s = Strategy::default();
        assert_eq!(s.sep.band_width, 3); // §3.3
        assert_eq!(s.dist.folddup_threshold, 100); // §4 default strategy
        assert!(s.dist.fold_dup);
        assert_eq!(s.dist.matching_rounds, 5); // §3.2
    }

    #[test]
    fn parse_overrides() {
        let s = Strategy::parse("band=5,leaf=60,refiner=xla,seed=9,folddup=0").unwrap();
        assert_eq!(s.sep.band_width, 5);
        assert_eq!(s.nd.leaf_threshold, 60);
        assert_eq!(s.refiner, RefinerKind::DiffusionXla);
        assert_eq!(s.seed, 9);
        assert!(!s.dist.fold_dup);
    }

    #[test]
    fn parse_rejects_unknown_key() {
        assert!(Strategy::parse("bogus=1").is_err());
    }

    #[test]
    fn parse_rejects_bad_value() {
        assert!(Strategy::parse("band=abc").is_err());
        assert!(Strategy::parse("refiner=quantum").is_err());
        assert!(Strategy::parse("band").is_err());
    }

    #[test]
    fn validate_rejects_zero_band() {
        assert!(Strategy::parse("band=0").is_err());
    }

    #[test]
    fn parse_distributed_band_knobs() {
        let s = Strategy::parse("maxband=500,sweeps=12").unwrap();
        assert_eq!(s.dist.max_centralized_band, 500);
        assert_eq!(s.dist.diffusion_sweeps, 12);
        assert!(Strategy::parse("sweeps=0").is_err());
    }

    #[test]
    fn parse_band_engine_knob() {
        assert_eq!(Strategy::default().dist.band_engine, BandEngine::Auto);
        for (spec, want) in [
            ("engine=auto", BandEngine::Auto),
            ("engine=cpu", BandEngine::Cpu),
            ("engine=xla", BandEngine::Xla),
        ] {
            assert_eq!(Strategy::parse(spec).unwrap().dist.band_engine, want);
        }
        assert!(Strategy::parse("engine=gpuonly").is_err());
    }

    #[test]
    fn parse_executor_knob() {
        assert_eq!(Strategy::default().dist.executor, None);
        assert_eq!(
            Strategy::parse("executor=threads").unwrap().dist.executor,
            Some(Executor::Threads)
        );
        assert_eq!(
            Strategy::parse("executor=sim,leaf=60").unwrap().dist.executor,
            Some(Executor::Sim)
        );
        assert_eq!(Strategy::parse("executor=env").unwrap().dist.executor, None);
        assert!(Strategy::parse("executor=mpi").is_err());
    }

    #[test]
    fn parse_leaf_method_knob() {
        assert_eq!(Strategy::default().nd.leaf_method, LeafMethod::Hamd);
        assert_eq!(
            Strategy::parse("leafmethod=mmd").unwrap().nd.leaf_method,
            LeafMethod::Mmd
        );
        assert_eq!(
            Strategy::parse("leafmethod=hamd,leaf=60").unwrap().nd.leaf_method,
            LeafMethod::Hamd
        );
        assert!(Strategy::parse("leafmethod=amf").is_err());
    }

    #[test]
    fn parse_refine_mode_knob() {
        assert_eq!(Strategy::default().sep.refine, RefineMode::Auto);
        for (spec, want) in [
            ("refine=fm", RefineMode::Fm),
            ("refine=diffusion", RefineMode::Diffusion),
            ("refine=flow", RefineMode::Flow),
            ("refine=auto", RefineMode::Auto),
        ] {
            assert_eq!(Strategy::parse(spec).unwrap().sep.refine, want, "{spec}");
        }
        assert!(Strategy::parse("refine=annealing").is_err());
    }

    #[test]
    fn parse_flowband_knob() {
        assert_eq!(Strategy::default().sep.flow_max_band, 30_000);
        let s = Strategy::parse("flowband=128").unwrap();
        assert_eq!(s.sep.flow_max_band, 128);
        assert!(Strategy::parse("flowband=tiny").is_err());
    }

    #[test]
    fn parse_rejects_band_width_overflow() {
        // `band=` used to truncate silently through `as u32`; it must
        // reject values that do not fit instead.
        assert!(Strategy::parse("band=4294967295").is_ok());
        assert!(Strategy::parse("band=4294967296").is_err());
        assert!(Strategy::parse("band=99999999999").is_err());
    }

    #[test]
    fn parse_empty_is_default() {
        let s = Strategy::parse("").unwrap();
        assert_eq!(s.sep.coarse_target, Strategy::default().sep.coarse_target);
    }

    #[test]
    fn unknown_key_error_names_the_valid_keys() {
        let err = Strategy::parse("bogus=1").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown key bogus"), "{msg}");
        for k in VALID_KEYS {
            assert!(msg.contains(k), "error message misses valid key {k}: {msg}");
        }
    }

    #[test]
    fn display_round_trips_through_parse() {
        // The canonical form is the fingerprint input (DESIGN.md §6):
        // parse(s).to_string() must be a fixed point, for the default
        // and for every knob moved off its default.
        let specs = [
            "",
            "band=5,seed=9,folddup=0",
            "leafmethod=mmd,refiner=diffcpu,engine=cpu,executor=threads",
            "coarse=60,minratio=0.7,ggg=2,passes=3,neg=10,eps=0.1",
            "leaf=40,maxsep=0.4,foldthresh=50,overlap=0,rounds=3,maxband=500,sweeps=4",
            "executor=sim",
        ];
        for spec in specs {
            let s = Strategy::parse(spec).unwrap();
            let canon = s.to_string();
            let back = Strategy::parse(&canon).unwrap();
            assert_eq!(back, s, "{spec} -> {canon}");
            assert_eq!(back.to_string(), canon, "{spec}");
        }
    }

    #[test]
    fn every_knob_round_trips_off_default() {
        // Exhaustive per-knob enumeration: one off-default sample per
        // VALID_KEYS entry. A future knob added to VALID_KEYS without a
        // row here fails the coverage assertion below, so no knob can
        // silently skip the Display→parse→Display contract.
        let samples: &[(&str, &str)] = &[
            ("seed", "9"),
            ("band", "5"),
            ("coarse", "60"),
            ("minratio", "0.7"),
            ("ggg", "2"),
            ("passes", "3"),
            ("neg", "10"),
            ("eps", "0.1"),
            ("leaf", "40"),
            ("maxsep", "0.4"),
            ("leafmethod", "mmd"),
            ("refiner", "diffcpu"),
            ("refine", "flow"),
            ("flowband", "777"),
            ("engine", "cpu"),
            ("executor", "threads"),
            ("folddup", "0"),
            ("foldthresh", "50"),
            ("overlap", "0"),
            ("rounds", "3"),
            ("maxband", "500"),
            ("sweeps", "4"),
            ("trace", "full"),
        ];
        let covered: Vec<&str> = samples.iter().map(|(k, _)| *k).collect();
        assert_eq!(
            covered, VALID_KEYS,
            "every VALID_KEYS knob needs an off-default sample, in order"
        );
        let default_canon = Strategy::default().to_string();
        for &(k, v) in samples {
            let spec = format!("{k}={v}");
            let s = Strategy::parse(&spec).unwrap();
            let canon = s.to_string();
            // The sample value survives into the canonical form…
            assert!(canon.contains(&spec), "{spec} lost in canonical {canon}");
            // …actually moved a knob off its default…
            assert_ne!(canon, default_canon, "{spec} did not change the strategy");
            // …and the canonical form is a parse fixed point.
            let back = Strategy::parse(&canon).unwrap();
            assert_eq!(back, s, "{spec} -> {canon}");
            assert_eq!(back.to_string(), canon, "{spec}");
        }
        // The canonical form lists every knob in VALID_KEYS order.
        let mut pos = 0;
        for k in VALID_KEYS {
            let needle = format!("{k}=");
            let at = default_canon[pos..]
                .find(&needle)
                .unwrap_or_else(|| panic!("canonical form misses {k}: {default_canon}"));
            pos += at + needle.len();
        }
    }

    #[test]
    fn canonical_form_is_equality() {
        // Differently-written but equivalent specs canonicalize to one
        // string; any knob difference changes it.
        let a = Strategy::parse("seed=3,band=3").unwrap();
        let b = Strategy::parse(" band=3 , seed=3 ").unwrap();
        assert_eq!(a.to_string(), b.to_string());
        let c = Strategy::parse("seed=4,band=3").unwrap();
        assert_ne!(a.to_string(), c.to_string());
    }

    #[test]
    fn parse_minratio_and_maxsep_knobs() {
        let s = Strategy::parse("minratio=0.7,maxsep=0.4").unwrap();
        assert!((s.sep.min_coarsen_ratio - 0.7).abs() < 1e-12);
        assert!((s.nd.max_sep_fraction - 0.4).abs() < 1e-12);
        assert!(Strategy::parse("minratio=1.5").is_err());
        assert!(Strategy::parse("maxsep=-0.1").is_err());
    }
}
