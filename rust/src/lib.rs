//! # ptscotch — a reproduction of *PT-Scotch: A tool for efficient parallel graph ordering*
//!
//! (C. Chevalier & F. Pellegrini, Parallel Computing, 2008)
//!
//! This crate implements, from scratch, the full PT-Scotch parallel
//! sparse-matrix ordering stack described in the paper:
//!
//! * a **sequential Scotch-like core**: multilevel vertex-separator
//!   bisection (heavy-edge matching coarsening, greedy-graph-growing
//!   initial separators, vertex Fiduccia–Mattheyses refinement on
//!   width-limited *band graphs*), nested dissection, and minimum-degree
//!   leaf ordering — halo approximate minimum degree by default, with
//!   each leaf seeing its ring of already-numbered separator vertices
//!   ([`sep`], [`order`]);
//! * a **distributed layer** mirroring the paper's MPI algorithms on an
//!   in-process, thread-per-rank communicator with two interchangeable
//!   executors — a serialized deterministic simulator and a
//!   free-running per-peer-mailbox fabric with bit-identical results
//!   (`executor=sim|threads`): distributed graphs with
//!   ghost/halo indexing, parallel probabilistic matching, coarsening with
//!   folding-with-duplication, distributed band extraction,
//!   multi-sequential band refinement and parallel nested dissection
//!   ([`comm`], [`dist`]);
//! * a **ParMETIS-like baseline** reproducing the comparator's failure
//!   modes (strictly-improving parallel refinement, power-of-two-only
//!   folding without duplication) ([`baseline`]);
//! * **quality evaluation**: elimination trees and symbolic Cholesky
//!   factorization producing the paper's NNZ and OPC metrics ([`order`]);
//! * an **XLA/PJRT runtime** that executes the AOT-compiled JAX/Pallas
//!   band-diffusion and min-plus kernels from the Rust hot path
//!   ([`runtime`]);
//! * a **coordinator** exposing the whole system behind one
//!   request/result API and CLI, with a batch service that dedupes
//!   repeated requests by graph fingerprint ([`coordinator`]).
//!
//! See `DESIGN.md` for the paper→module map and `EXPERIMENTS.md` for the
//! reproduced tables and figures.
//!
//! # Quickstart
//!
//! Order a sparse-matrix graph with parallel nested dissection on two
//! emulated ranks and read off the paper's quality metrics plus the
//! solver-facing block structure:
//!
//! ```
//! use ptscotch::coordinator::{Engine, OrderingRequest, OrderingService};
//! use ptscotch::graph::generators;
//!
//! let g = generators::grid2d(12, 12); // a 144-unknown 5-point mesh
//! let svc = OrderingService::new_cpu_only();
//! let req = OrderingRequest::new(&g).engine(Engine::PtScotch { p: 2 });
//! let res = svc.run(&req).expect("ordering succeeds");
//! res.ordering.validate().expect("valid permutation");
//! res.blocks.validate(g.n()).expect("postordered block forest");
//! assert!(res.stats.opc > 0.0); // operation count of the factorization
//! assert!(res.stats.nnz >= g.n() as u64); // fill-in of the L factor
//! ```

#![deny(missing_docs)]

pub mod baseline;
pub mod comm;
pub mod coordinator;
pub mod dist;
pub mod error;
pub mod graph;
pub mod order;
pub mod rng;
pub mod runtime;
pub mod sep;
pub mod strategy;
pub mod trace;

pub use error::{Error, Result};
pub use graph::Graph;
pub use order::{Ordering, SymbolicStats};
pub use strategy::Strategy;
