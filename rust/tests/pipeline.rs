//! Cross-module integration tests: the whole ordering system exercised
//! through the public coordinator API on every graph family, plus the
//! paper's structural claims that don't need the XLA artifacts.

use ptscotch::coordinator::{Engine, OrderingRequest, OrderingResult, OrderingService};
use ptscotch::graph::{generators, io, Graph};
use ptscotch::order::{symbolic_cholesky, Ordering};
use ptscotch::strategy::Strategy;

fn service() -> OrderingService {
    OrderingService::new_cpu_only()
}

/// Run one request through the builder API.
fn order(
    svc: &OrderingService,
    g: &Graph,
    engine: Engine,
    strat: &Strategy,
) -> ptscotch::Result<OrderingResult> {
    svc.run(&OrderingRequest::new(g).strategy(strat.clone()).engine(engine))
}

#[test]
fn every_family_orders_validly_sequentially() {
    let svc = service();
    let strat = Strategy::default();
    for (name, g) in [
        ("grid2d", generators::grid2d(24, 24)),
        ("grid3d", generators::grid3d(7, 7, 7)),
        ("grid3d27", generators::grid3d_27pt(5, 5, 5)),
        ("audikw", generators::audikw_like(6, 6, 6, 0.05, 20, 1)),
        ("cage", generators::cage_like(700, 6, 2)),
        ("qimonda", generators::qimonda_like(900, 3)),
        ("thread", generators::thread_like(260, 60, 4)),
    ] {
        let rep = order(&svc, &g, Engine::Sequential, &strat)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        rep.ordering.validate().unwrap();
        // Natural order is already near-optimal for banded-dense
        // matrices like `thread`; the fill-reduction claim applies to
        // the sparse families.
        if name != "thread" {
            let natural = symbolic_cholesky(&g, &Ordering::identity(g.n()));
            assert!(
                rep.stats.opc <= natural.opc * 1.05,
                "{name}: ordered OPC {} worse than natural {}",
                rep.stats.opc,
                natural.opc
            );
        }
    }
}

#[test]
fn parallel_matches_quality_class_across_p() {
    let svc = service();
    let strat = Strategy::default();
    let g = generators::grid2d(26, 26);
    let seq = order(&svc, &g, Engine::Sequential, &strat).unwrap();
    for p in [2usize, 3, 4, 6, 8] {
        let rep = order(&svc, &g, Engine::PtScotch { p }, &strat).unwrap();
        rep.ordering.validate().unwrap();
        assert!(
            rep.stats.opc <= seq.stats.opc * 1.6,
            "p={p}: OPC {} vs sequential {}",
            rep.stats.opc,
            seq.stats.opc
        );
    }
}

#[test]
fn quality_flat_in_p_for_ptscotch() {
    // The paper's central claim (Tables 2–3): PT-Scotch ordering quality
    // does not decrease along with the number of processes.
    let svc = service();
    let strat = Strategy::default();
    let g = generators::grid3d(8, 8, 8);
    let opcs: Vec<f64> = [1usize, 2, 4, 8]
        .iter()
        .map(|&p| {
            let e = if p == 1 {
                Engine::Sequential
            } else {
                Engine::PtScotch { p }
            };
            order(&svc, &g, e, &strat).unwrap().stats.opc
        })
        .collect();
    let best = opcs.iter().cloned().fold(f64::INFINITY, f64::min);
    let worst = opcs.iter().cloned().fold(0.0, f64::max);
    assert!(
        worst / best < 1.7,
        "OPC should stay flat across p: {opcs:?}"
    );
}

#[test]
fn band_width_three_is_no_worse_than_one() {
    // §3.3: width-3 band refinement preserves (usually improves) quality
    // vs narrower bands.
    let svc = service();
    let g = generators::irregular_mesh(30, 30, 7);
    let w1 = order(&svc, &g, Engine::Sequential, &Strategy::parse("band=1").unwrap()).unwrap();
    let w3 = order(&svc, &g, Engine::Sequential, &Strategy::parse("band=3").unwrap()).unwrap();
    assert!(
        w3.stats.opc <= w1.stats.opc * 1.25,
        "band=3 OPC {} should compete with band=1 {}",
        w3.stats.opc,
        w1.stats.opc
    );
}

#[test]
fn seed_variance_is_small() {
    // §4: max OPC variation across seeds < 2.2% at 64 procs on the
    // paper's graphs; on our small instances allow a looser but still
    // tight band at p = 4.
    let svc = service();
    let g = generators::grid3d(7, 7, 7);
    let mut opcs = Vec::new();
    for seed in 1..=5u64 {
        let strat = Strategy::parse(&format!("seed={seed}")).unwrap();
        opcs.push(order(&svc, &g, Engine::PtScotch { p: 4 }, &strat).unwrap().stats.opc);
    }
    let best = opcs.iter().cloned().fold(f64::INFINITY, f64::min);
    let worst = opcs.iter().cloned().fold(0.0, f64::max);
    assert!(
        worst / best < 1.30,
        "seed variance too high: {opcs:?}"
    );
}

#[test]
fn chaco_roundtrip_preserves_ordering_quality() {
    let g = generators::irregular_mesh(16, 16, 2);
    let mut buf = Vec::new();
    io::write_chaco(&g, &mut buf).unwrap();
    let g2 = io::read_chaco(&buf[..]).unwrap();
    let svc = service();
    let strat = Strategy::default();
    let a = order(&svc, &g, Engine::Sequential, &strat).unwrap();
    let b = order(&svc, &g2, Engine::Sequential, &strat).unwrap();
    assert_eq!(a.stats.nnz, b.stats.nnz);
    assert_eq!(a.ordering.iperm, b.ordering.iperm);
}

#[test]
fn overlap_strategy_toggle_gives_same_result() {
    // §3.1: the extra-thread overlap is a performance feature and "can be
    // disabled when the communication system is not thread-safe" — it
    // must not change results.
    let svc = service();
    let g = generators::grid2d(20, 20);
    let on = order(&svc, &g, Engine::PtScotch { p: 4 }, &Strategy::parse("overlap=1").unwrap())
        .unwrap();
    let off = order(&svc, &g, Engine::PtScotch { p: 4 }, &Strategy::parse("overlap=0").unwrap())
        .unwrap();
    assert_eq!(on.ordering.iperm, off.ordering.iperm);
}

#[test]
fn separator_indices_are_topmost_at_every_level() {
    // §2.2/§3.1: separator vertices take the highest indices available;
    // check the top-level one on a graph with an obvious separator.
    let svc = service();
    let g = generators::grid2d(40, 8);
    let strat = Strategy::parse("leaf=30").unwrap();
    let rep = order(&svc, &g, Engine::Sequential, &strat).unwrap();
    // The ~8 highest-numbered unknowns must form a column (x constant).
    let n = g.n();
    let top: Vec<usize> = (n - 8..n).map(|k| rep.ordering.iperm[k] % 40).collect();
    let first = top[0];
    assert!(
        top.iter().all(|&x| x.abs_diff(first) <= 1),
        "top unknowns are not a column-ish separator: {top:?}"
    );
}

#[test]
fn parmetis_like_quality_degrades_or_stagnates_with_p() {
    let svc = service();
    let strat = Strategy::default();
    let g = generators::grid2d(26, 26);
    let p2 = order(&svc, &g, Engine::ParMetisLike { p: 2 }, &strat).unwrap();
    let p8 = order(&svc, &g, Engine::ParMetisLike { p: 8 }, &strat).unwrap();
    // The baseline must not *improve* markedly with p (the paper shows it
    // worsening dramatically).
    assert!(
        p8.stats.opc >= p2.stats.opc * 0.85,
        "baseline unexpectedly improved with p: {} -> {}",
        p2.stats.opc,
        p8.stats.opc
    );
}
