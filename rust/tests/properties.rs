//! Property-based invariant tests (hand-rolled seed sweeps — the
//! offline crate set has no proptest). Each property is exercised over
//! many random graphs and seeds; failures print the generating seed.

use ptscotch::comm;
use ptscotch::dist::dgraph::DGraph;
use ptscotch::dist::dsep::dist_validate_separator;
use ptscotch::dist::matching::parallel_match;
use ptscotch::graph::{generators, Graph, GraphBuilder};
use ptscotch::order::{symbolic_cholesky, Ordering};
use ptscotch::rng::Rng;
use ptscotch::sep::band::extract_band;
use ptscotch::sep::fm::{fm_refine, FmParams};
use ptscotch::sep::initial::greedy_graph_growing;
use ptscotch::sep::{multilevel_separator, FmRefiner, SepState, SEP};
use ptscotch::strategy::{SepStrategy, Strategy};
use std::sync::Arc;

/// Run one request through the builder API.
fn order(
    svc: &ptscotch::coordinator::OrderingService,
    g: &Graph,
    engine: ptscotch::coordinator::Engine,
    strat: &Strategy,
) -> ptscotch::Result<ptscotch::coordinator::OrderingResult> {
    use ptscotch::coordinator::OrderingRequest;
    svc.run(&OrderingRequest::new(g).strategy(strat.clone()).engine(engine))
}

/// Random connected graph: a spanning path plus `extra` random edges.
fn random_graph(seed: u64, n: usize, extra: usize) -> Graph {
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new(n);
    for v in 1..n {
        b.add_edge(v - 1, v);
    }
    for _ in 0..extra {
        let u = rng.below(n);
        let v = rng.below(n);
        if u != v {
            b.add_edge_w(u, v, 1 + rng.below(3) as i64);
        }
    }
    b.build().unwrap()
}

#[test]
fn prop_fm_preserves_invariant_and_never_worsens() {
    for seed in 0..30u64 {
        let n = 40 + (seed as usize * 13) % 160;
        let g = random_graph(seed, n, n);
        let mut rng = Rng::new(seed ^ 0xF);
        let mut s = greedy_graph_growing(&g, 2, &mut rng);
        let before = s.quality_key();
        fm_refine(&g, &mut s, &[], &FmParams::default(), &mut rng);
        s.validate(&g).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert!(s.quality_key() <= before, "seed {seed} worsened");
    }
}

#[test]
fn prop_multilevel_separator_valid_on_random_graphs() {
    let strat = SepStrategy::default();
    let refiner = FmRefiner::default();
    for seed in 0..20u64 {
        let n = 150 + (seed as usize * 37) % 400;
        let g = random_graph(seed, n, n / 2);
        let mut rng = Rng::new(seed);
        let s = multilevel_separator(&g, &strat, &refiner, &mut rng);
        s.validate(&g).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        // Both sides nonempty unless the separator is huge (dense case).
        assert!(
            s.wgts[0] > 0 && s.wgts[1] > 0 || s.sep_weight() as usize > n / 2,
            "seed {seed}: degenerate split {:?}",
            s.wgts
        );
    }
}

#[test]
fn prop_band_total_weight_conserved() {
    for seed in 0..20u64 {
        let g = generators::irregular_mesh(12 + (seed as usize % 6), 10, seed);
        let mut rng = Rng::new(seed);
        let s = greedy_graph_growing(&g, 2, &mut rng);
        if s.sep_count() == 0 {
            continue;
        }
        for width in 1..=4u32 {
            let band = extract_band(&g, &s, width).unwrap();
            band.graph
                .validate()
                .unwrap_or_else(|e| panic!("seed {seed} w{width}: {e}"));
            // Band + anchors carry the whole weight (within the +1-per-
            // empty-anchor slack).
            let slack = 2;
            assert!(
                (band.graph.total_vwgt() - g.total_vwgt()).abs() <= slack,
                "seed {seed} w{width}: weight drift"
            );
            // Separator weight unchanged by extraction.
            assert_eq!(band.state.sep_weight(), s.sep_weight());
        }
    }
}

#[test]
fn prop_symbolic_factorization_permutation_invariants() {
    // NNZ and OPC must be ≥ the matrix itself, and identical orderings
    // must give identical stats.
    for seed in 0..15u64 {
        let g = random_graph(seed, 60, 100);
        let mut rng = Rng::new(seed);
        let o = Ordering::from_iperm(rng.permutation(60)).unwrap();
        let s1 = symbolic_cholesky(&g, &o);
        let s2 = symbolic_cholesky(&g, &o);
        assert_eq!(s1, s2);
        assert!(s1.nnz >= (g.m() + g.n()) as u64);
        assert!(s1.opc >= s1.nnz as f64);
    }
}

#[test]
fn prop_nd_ordering_is_permutation_on_random_graphs() {
    let svc = ptscotch::coordinator::OrderingService::new_cpu_only();
    for seed in 0..10u64 {
        let g = random_graph(seed, 300 + seed as usize * 40, 500);
        let strat = Strategy::parse(&format!("seed={seed}")).unwrap();
        let rep = order(&svc, &g, ptscotch::coordinator::Engine::Sequential, &strat).unwrap();
        rep.ordering
            .validate()
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    }
}

#[test]
fn prop_parallel_matching_symmetric_across_p_and_seeds() {
    for seed in 0..6u64 {
        for p in [2usize, 3, 5] {
            let g = Arc::new(random_graph(seed, 240, 300));
            let gref = g.clone();
            let (res, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let mut rng = Rng::new(seed).derive(c.global_rank() as u64);
                let mate = parallel_match(&c, &dg, 5, &mut rng);
                (dg.base(), mate)
            });
            let n = gref.n();
            let mut mate = vec![0u64; n];
            for (base, m) in res {
                for (i, &x) in m.iter().enumerate() {
                    mate[base as usize + i] = x;
                }
            }
            for v in 0..n {
                let m = mate[v] as usize;
                assert_eq!(
                    mate[m] as usize, v,
                    "seed {seed} p={p}: asymmetric at {v}"
                );
                if m != v {
                    assert!(
                        gref.neighbors(v).contains(&(m as u32)),
                        "seed {seed} p={p}: non-adjacent pair"
                    );
                }
            }
        }
    }
}

/// The seed level-scan band BFS, kept verbatim as the reference the
/// frontier rewrite must reproduce: one full-vector halo exchange and a
/// full clone + rescan of the distance vector per level.
fn level_scan_reference(
    c: &ptscotch::comm::Comm,
    dg: &DGraph,
    part: &[u8],
    width: u32,
) -> Vec<u32> {
    let nloc = dg.nloc();
    let mut dist: Vec<u32> = part
        .iter()
        .map(|&x| if x == SEP { 0 } else { u32::MAX })
        .collect();
    for _ in 0..width {
        let ghost_dist = dg.halo_exchange(c, &dist);
        let prev = dist.clone();
        for v in 0..nloc {
            if prev[v] != u32::MAX {
                continue;
            }
            let mut best = u32::MAX;
            for &a in dg.neighbors_gst(v) {
                let a = a as usize;
                let da = if a < nloc { prev[a] } else { ghost_dist[a - nloc] };
                if da != u32::MAX && da + 1 < best {
                    best = da + 1;
                }
            }
            dist[v] = best;
        }
    }
    dist
}

#[test]
fn prop_frontier_bfs_matches_level_scan_reference() {
    // The frontier-driven `band_distances` must equal the seed
    // level-scan on random graphs for p ∈ {2..5}, arbitrary (not
    // necessarily valid-separator) source placements, and all band
    // widths the pipeline uses.
    use ptscotch::dist::dband::band_distances;

    for (seed, p) in [(0u64, 2usize), (1, 3), (2, 4), (3, 5), (4, 4)] {
        let n = 200 + (seed as usize * 53) % 200;
        let g = Arc::new(random_graph(seed, n, n / 2));
        for width in [1u32, 2, 3, 4] {
            let g = g.clone();
            let (ok, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                // Sources from a global hash every rank evaluates
                // identically (~1/8 of the vertices).
                let part: Vec<u8> = (0..dg.nloc())
                    .map(|v| {
                        let gid = dg.glb(v).wrapping_add(seed);
                        if gid.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 61 == 0 {
                            SEP
                        } else {
                            ptscotch::sep::P0
                        }
                    })
                    .collect();
                let want = level_scan_reference(&c, &dg, &part, width);
                let got = band_distances(&c, &dg, &part, width);
                got == want
            });
            assert!(
                ok.iter().all(|&x| x),
                "seed {seed} p={p} width={width}: frontier BFS diverged from level scan"
            );
        }
    }
}

#[test]
fn prop_bfs_engine_dispatch_stub_fallback_matches_frontier_bfs() {
    // The acceptance criterion for the min-plus engine: with the
    // stubbed XLA path (no runtime handle loads offline), every engine
    // setting must produce band distances identical to the CPU frontier
    // BFS for p ∈ {2..5} on random graphs, with the verdict agreed by
    // allreduce (`used_xla` false everywhere).
    use ptscotch::dist::dband::{band_distances, bfs_band_dist_engine};
    use ptscotch::strategy::BandEngine;

    for (seed, p) in [(0u64, 2usize), (1, 3), (2, 4), (3, 5)] {
        let n = 240 + (seed as usize * 37) % 160;
        let g = random_graph(seed, n, n / 2);
        let mut rng = Rng::new(seed ^ 0xBF5);
        let s = multilevel_separator(&g, &SepStrategy::default(), &FmRefiner::default(), &mut rng);
        if s.sep_count() == 0 {
            continue;
        }
        let ga = Arc::new(g);
        let proj = Arc::new(s.part);
        for engine in [BandEngine::Auto, BandEngine::Cpu, BandEngine::Xla] {
            let g = ga.clone();
            let proj = proj.clone();
            let (ok, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let part: Vec<u8> = (0..dg.nloc())
                    .map(|v| proj[dg.glb(v) as usize])
                    .collect();
                let want = band_distances(&c, &dg, &part, 3);
                let (got, used_xla) = bfs_band_dist_engine(&c, &dg, &part, 3, engine, None);
                !used_xla && got == want
            });
            assert!(
                ok.iter().all(|&x| x),
                "seed {seed} p={p} engine={engine:?}: BFS dispatch diverged"
            );
        }
    }
}

#[test]
fn prop_dist_diffusion_refinement_never_worse_than_projection() {
    // The scalable band path (global_band > max_centralized_band, which
    // previously kept the projection untouched): on grid graphs across
    // rank counts and seed-jittered separator positions, the
    // diffusion-refined separator must always validate and never exceed
    // the projected separator's size. Swept in both regimes — forced
    // distributed (maxband=1) and default centralized — so the two
    // paths stay mutually consistent.
    use ptscotch::comm::MemTracker;
    use ptscotch::dist::dsep::band_refine_dist;

    for (seed, p, maxband) in [
        (0u64, 4usize, 1usize),
        (1, 4, 1),
        (2, 5, 1),
        (3, 3, 1),
        (4, 4, usize::MAX),
    ] {
        let nx = 64 + (seed as usize * 7) % 17;
        let ny = 64;
        let g = Arc::new(generators::grid2d(nx, ny));
        // A valid but deliberately suboptimal projection: a 2-thick
        // column separator whose position jitters with the seed.
        let mid = nx / 3 + (seed as usize * 5) % (nx / 3);
        let proj = generators::column_separator_part(nx, ny, mid, 2);
        let sep_before = proj.iter().filter(|&&x| x == SEP).count() as i64;
        let (res, _) = comm::run(p, move |c| {
            let dg = DGraph::from_global(&c, &g);
            let mut part: Vec<u8> = (0..dg.nloc())
                .map(|v| proj[dg.glb(v) as usize])
                .collect();
            let strat = Strategy::parse(&format!(
                "seed={seed},sweeps=24,maxband={}",
                if maxband == usize::MAX { 4_000_000 } else { maxband }
            ))
            .unwrap();
            let refiner = FmRefiner::default();
            let rng = Rng::new(strat.seed);
            let mem = MemTracker::new();
            band_refine_dist(&c, &dg, &mut part, &strat, &refiner, None, &rng, &mem);
            let valid = dist_validate_separator(&c, &dg, &part);
            let sep_now = part.iter().filter(|&&x| x == SEP).count() as i64;
            (valid, sep_now)
        });
        assert!(
            res.iter().all(|&(valid, _)| valid),
            "seed {seed} p={p} maxband={maxband}: invalid refined separator"
        );
        let sep_after: i64 = res.iter().map(|&(_, s)| s).sum();
        assert!(
            sep_after <= sep_before,
            "seed {seed} p={p} maxband={maxband}: separator grew {sep_after} > {sep_before}"
        );
        assert!(sep_after > 0, "seed {seed} p={p}: separator vanished");
    }
}

#[test]
fn prop_engine_dispatch_stub_fallback_matches_cpu_sweeps() {
    // The engine-dispatch ladder under the offline `xla-stub`: no
    // artifacts can load, so the dispatcher must fall back to the CPU
    // sweeps under *every* engine setting and produce labels identical
    // to calling `diffuse_band_dist` directly — for random graphs,
    // seeds and rank counts.
    use ptscotch::dist::dband::{band_distances, extract_dband};
    use ptscotch::dist::ddiffusion::{
        diffuse_band_dist, diffuse_band_dist_engine, DIST_DIFFUSION_DAMPING,
    };
    use ptscotch::strategy::BandEngine;

    for (seed, p) in [(0u64, 2usize), (1, 3), (2, 4), (3, 5)] {
        // A valid projected separator on a random graph, computed
        // sequentially and block-distributed like the pipeline does.
        let n = 300 + (seed as usize * 61) % 200;
        let g = random_graph(seed, n, n / 2);
        let mut rng = Rng::new(seed ^ 0xD15);
        let s = multilevel_separator(&g, &SepStrategy::default(), &FmRefiner::default(), &mut rng);
        if s.sep_count() == 0 {
            continue;
        }
        let ga = Arc::new(g);
        let proj = Arc::new(s.part);
        for engine in [BandEngine::Auto, BandEngine::Cpu, BandEngine::Xla] {
            let g = ga.clone();
            let proj = proj.clone();
            let (ok, _) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                let part: Vec<u8> = (0..dg.nloc())
                    .map(|v| proj[dg.glb(v) as usize])
                    .collect();
                let dist = band_distances(&c, &dg, &part, 3);
                let band = extract_dband(&c, &dg, &part, &dist);
                let want = diffuse_band_dist(&c, &band, 16, DIST_DIFFUSION_DAMPING);
                // No runtime handle exists offline — exactly what the
                // coordinator passes when artifacts fail to load.
                let (got, used_xla) =
                    diffuse_band_dist_engine(&c, &band, 16, DIST_DIFFUSION_DAMPING, engine, None);
                !used_xla && got == want
            });
            assert!(
                ok.iter().all(|&x| x),
                "seed {seed} p={p} engine={engine:?}: dispatch diverged from CPU sweeps"
            );
        }
    }
}

#[test]
fn prop_parallel_order_valid_with_forced_distributed_bands() {
    // End-to-end: the full parallel ordering pipeline with
    // `max_centralized_band` forced tiny, so *every* uncoarsening level
    // takes the distributed diffusion path instead of centralizing.
    let svc = ptscotch::coordinator::OrderingService::new_cpu_only();
    for (seed, p) in [(0u64, 4usize), (1, 5)] {
        let g = generators::grid2d(40, 40);
        let strat = Strategy::parse(&format!("seed={seed},maxband=8,sweeps=16")).unwrap();
        let rep = order(&svc, &g, ptscotch::coordinator::Engine::PtScotch { p }, &strat).unwrap();
        rep.ordering
            .validate()
            .unwrap_or_else(|e| panic!("seed {seed} p={p}: {e}"));
    }
}

#[test]
fn prop_distributed_separator_valid_across_p() {
    for (seed, p) in [(1u64, 2usize), (2, 3), (3, 4), (4, 5)] {
        let g = Arc::new(random_graph(seed, 600, 900));
        let (ok, _) = comm::run(p, move |c| {
            let dg = DGraph::from_global(&c, &g);
            let strat = Strategy::default();
            let refiner = FmRefiner::default();
            let rng = Rng::new(strat.seed);
            let mem = ptscotch::comm::MemTracker::new();
            let part =
                ptscotch::dist::dsep::dist_separator(&c, &dg, &strat, &refiner, None, &rng, &mem);
            dist_validate_separator(&c, &dg, &part)
        });
        assert!(ok.iter().all(|&x| x), "seed {seed} p={p}");
    }
}

#[test]
fn prop_engines_agree_on_fill_lower_bound() {
    // Any valid ordering of the same graph has NNZ ≥ nnz(A) + n; engines
    // differ in quality but never in validity.
    let svc = ptscotch::coordinator::OrderingService::new_cpu_only();
    let g = random_graph(9, 500, 700);
    let lb = (g.m() + g.n()) as u64;
    use ptscotch::coordinator::Engine;
    for engine in [
        Engine::Sequential,
        Engine::PtScotch { p: 3 },
        Engine::ParMetisLike { p: 4 },
    ] {
        let rep = order(&svc, &g, engine, &Strategy::default()).unwrap();
        assert!(rep.stats.nnz >= lb, "{engine:?}");
    }
}

#[test]
fn prop_hamd_orders_exactly_the_non_halo_vertices() {
    // HAMD invariant (a): for random graphs and random halo sets, the
    // result is a permutation of exactly the core vertices, and the
    // supervariable blocks tile it with consecutive ranges.
    use ptscotch::order::hamd;

    for seed in 0..12u64 {
        let n = 50 + (seed as usize * 23) % 150;
        let g = random_graph(seed, n, n);
        let mut rng = Rng::new(seed ^ 0x4A10);
        let halo: Vec<bool> = (0..n).map(|_| rng.below(5) == 0).collect();
        let r = hamd(&g, &halo);
        let mut got = r.order.clone();
        got.sort_unstable();
        let want: Vec<usize> = (0..n).filter(|&v| !halo[v]).collect();
        assert_eq!(got, want, "seed {seed}: not a core permutation");
        let mut covered = 0;
        for &(s, l) in &r.blocks {
            assert_eq!(s, covered, "seed {seed}: blocks out of sequence");
            assert!(l >= 1, "seed {seed}: empty block");
            covered += l;
        }
        assert_eq!(covered, r.order.len(), "seed {seed}: blocks do not tile");
    }
}

#[test]
fn prop_hamd_empty_halo_tracks_exact_mmd_within_10pct() {
    // HAMD invariant (b): with an empty halo the approximate-degree
    // ordering must stay within 10% OPC of the exact-degree MMD across
    // the generator suite (in practice the supervariable machinery
    // makes it slightly *better* on meshes).
    use ptscotch::order::hamd;
    use ptscotch::order::mmd::minimum_degree;

    let mut suite: Vec<(String, Graph)> = vec![
        ("grid2d".into(), generators::grid2d(16, 16)),
        ("grid3d".into(), generators::grid3d(8, 8, 8)),
    ];
    for seed in 1..=5u64 {
        suite.push((
            format!("irregular_mesh seed {seed}"),
            generators::irregular_mesh(14, 12, seed),
        ));
    }
    for (name, g) in &suite {
        let no_halo = vec![false; g.n()];
        let o_amd = Ordering::from_iperm(hamd(g, &no_halo).order).unwrap();
        let o_mmd = Ordering::from_iperm(minimum_degree(g)).unwrap();
        let s_amd = symbolic_cholesky(g, &o_amd);
        let s_mmd = symbolic_cholesky(g, &o_mmd);
        assert!(
            s_amd.opc <= s_mmd.opc * 1.10,
            "{name}: HAMD opc {:.4e} > 1.1 × MMD opc {:.4e}",
            s_amd.opc,
            s_mmd.opc
        );
    }
}

#[test]
fn prop_hamd_supervariable_members_consecutive() {
    // HAMD invariant (c): plant groups of indistinguishable vertices
    // (identical neighborhoods into a random host graph) and verify
    // each group ends up in consecutive order positions.
    use ptscotch::order::hamd;

    for seed in 0..8u64 {
        let host = 40 + (seed as usize * 11) % 60;
        let twins = 3;
        let n = host + twins;
        let mut rng = Rng::new(seed ^ 0x7713);
        let mut b = GraphBuilder::new(n);
        for v in 1..host {
            b.add_edge(v - 1, v);
        }
        for _ in 0..host / 4 {
            let u = rng.below(host);
            let v = rng.below(host);
            if u != v {
                b.add_edge(u, v);
            }
        }
        // The twins host..host+3 all see exactly the same 10 anchors
        // (and nothing else). Their degree of 10 keeps them out of the
        // minimum-degree buckets until some anchor is eliminated — at
        // which point they land in the same pivot element, hash equal,
        // and merge into one supervariable.
        let anchors: Vec<usize> = (0..10).map(|k| (k * host / 10 + 1) % host).collect();
        for t in host..n {
            for &a in &anchors {
                b.add_edge(t, a);
            }
        }
        let g = b.build().unwrap();
        let r = hamd(&g, &vec![false; n]);
        let mut pos: Vec<usize> = (host..n)
            .map(|t| r.order.iter().position(|&v| v == t).unwrap())
            .collect();
        pos.sort_unstable();
        assert!(
            pos.windows(2).all(|w| w[1] == w[0] + 1),
            "seed {seed}: twin positions not consecutive: {pos:?}"
        );
    }
}

#[test]
fn prop_parallel_order_hamd_valid_and_deterministic_across_p() {
    // The halo ring carried through the distributed recursion must
    // never compromise validity or the fixed-seed determinism, for any
    // rank count and leaf method.
    let svc = ptscotch::coordinator::OrderingService::new_cpu_only();
    for (seed, p) in [(0u64, 2usize), (1, 3), (2, 5)] {
        let g = random_graph(seed, 500, 700);
        for method in ["hamd", "mmd"] {
            let strat = Strategy::parse(&format!("seed={seed},leafmethod={method}")).unwrap();
            let eng = ptscotch::coordinator::Engine::PtScotch { p };
            let a = order(&svc, &g, eng, &strat).unwrap();
            a.ordering
                .validate()
                .unwrap_or_else(|e| panic!("seed {seed} p={p} {method}: {e}"));
            let b = order(&svc, &g, eng, &strat).unwrap();
            assert_eq!(
                a.ordering.iperm, b.ordering.iperm,
                "seed {seed} p={p} {method}: nondeterministic"
            );
        }
    }
}

#[test]
fn prop_sepstate_weights_always_consistent_after_pipeline() {
    // Run the full multilevel machinery and re-derive weights from labels.
    let strat = SepStrategy::default();
    let refiner = FmRefiner::default();
    for seed in 20..30u64 {
        let g = generators::irregular_mesh(20, 16, seed);
        let mut rng = Rng::new(seed);
        let s = multilevel_separator(&g, &strat, &refiner, &mut rng);
        let rebuilt = SepState::from_parts(&g, s.part.clone());
        assert_eq!(rebuilt.wgts, s.wgts, "seed {seed}");
        let sep_cnt = s.part.iter().filter(|&&p| p == SEP).count();
        assert_eq!(sep_cnt, s.sep_count(), "seed {seed}");
    }
}
