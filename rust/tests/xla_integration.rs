//! End-to-end integration of the three-layer stack: Rust loads the
//! AOT-compiled JAX/Pallas artifacts through PJRT and must reproduce the
//! pure-Rust reference numerics exactly (same recurrence, f32).
//!
//! These tests are skipped (not failed) when `artifacts/` has not been
//! built — run `make test-xla`, which builds them first. They require
//! the real `xla` crate (not the offline stub in `rust/xla-stub/`);
//! with the stub, leave `artifacts/` absent so the tests skip.

use ptscotch::graph::generators;
use ptscotch::rng::Rng;
use ptscotch::runtime::{load_shared, pack_ell, DiffusionRefiner, XlaRuntime};
use ptscotch::sep::band::extract_band;
use ptscotch::sep::diffusion::{diffusion_iterations, initial_field};
use ptscotch::sep::{SepState, P0, P1, SEP};
use std::path::PathBuf;

fn artifact_dir() -> Option<PathBuf> {
    let dir = std::env::var_os("PTSCOTCH_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| {
            // Tests run from the crate root.
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        });
    dir.join("manifest.txt").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifact_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: no artifacts (run `make artifacts`)");
                return;
            }
        }
    };
}

fn column_band(nx: usize, ny: usize, width: u32) -> ptscotch::sep::band::BandGraph {
    let g = generators::grid2d(nx, ny);
    let part: Vec<u8> = (0..nx * ny)
        .map(|v| {
            let x = v % nx;
            use std::cmp::Ordering::*;
            match x.cmp(&(nx / 2)) {
                Less => P0,
                Equal => SEP,
                Greater => P1,
            }
        })
        .collect();
    let state = SepState::from_parts(&g, part);
    extract_band(&g, &state, width).unwrap()
}

#[test]
fn diffusion_artifact_matches_rust_reference() {
    let dir = require_artifacts!();
    let rt = XlaRuntime::load(&dir).expect("load artifacts");
    let band = column_band(17, 11, 3);
    let g = &band.graph;
    let bucket = rt
        .fit_diffusion(g.n(), g.max_degree())
        .expect("a bucket fits this band");
    let ell = pack_ell(g, bucket.n, bucket.d).unwrap();

    let x0 = initial_field(&band.state);
    let mut x = vec![0f32; bucket.n];
    x[..g.n()].copy_from_slice(&x0);
    x[band.anchor0] = -1.0;
    x[band.anchor1] = 1.0;
    let mut mask = vec![0f32; bucket.n];
    let mut vals = vec![0f32; bucket.n];
    mask[band.anchor0] = 1.0;
    vals[band.anchor0] = -1.0;
    mask[band.anchor1] = 1.0;
    vals[band.anchor1] = 1.0;

    let got = rt
        .diffusion_step(bucket, &x, &mask, &vals, &ell)
        .expect("execute diffusion artifact");

    let want = diffusion_iterations(
        g,
        x0,
        band.anchor0,
        band.anchor1,
        rt.steps_per_call,
        0.95,
    );
    for v in 0..g.n() {
        assert!(
            (got[v] - want[v]).abs() < 1e-5,
            "vertex {v}: xla {} vs rust {}",
            got[v],
            want[v]
        );
    }
    // Padded rows stay identically zero.
    for v in g.n()..bucket.n {
        assert_eq!(got[v], 0.0, "padded row {v}");
    }
}

#[test]
fn minplus_artifact_computes_bfs_layers() {
    let dir = require_artifacts!();
    let rt = XlaRuntime::load(&dir).expect("load artifacts");
    let g = generators::cycle(64);
    let bucket = rt.fit_minplus(64, 2).expect("bucket");
    let ell = pack_ell(&g, bucket.n, bucket.d).unwrap();
    const INF: f32 = 3.0e38;
    let mut dist = vec![INF; bucket.n];
    dist[0] = 0.0;
    for _ in 0..32 {
        dist = rt.minplus_step(bucket, &dist, &ell).expect("execute");
    }
    for v in 0..64usize {
        let want = v.min(64 - v) as f32;
        assert_eq!(dist[v], want, "vertex {v}");
    }
    // Unreached padded rows stay at +inf.
    assert!(dist[100] > 1.0e38);
}

#[test]
fn xla_refiner_improves_band_and_stays_valid() {
    let dir = require_artifacts!();
    let rt = load_shared(&dir).expect("load artifacts");
    let refiner = DiffusionRefiner::new(rt);
    // A wiggly separator on an irregular mesh the refiner must clean up.
    let g = generators::irregular_mesh(20, 14, 3);
    let nx = 20;
    let mut part: Vec<u8> = (0..g.n())
        .map(|v| {
            let x = v % nx;
            let wiggle = (v / nx) % 3;
            let cut = 9 + wiggle;
            use std::cmp::Ordering::*;
            match x.cmp(&cut) {
                Less => P0,
                Equal => SEP,
                Greater => P1,
            }
        })
        .collect();
    // The irregular mesh has diagonals; cover any crossing edge so the
    // starting state satisfies the separator invariant.
    for v in 0..g.n() {
        if part[v] == SEP {
            continue;
        }
        for &u in g.neighbors(v) {
            let u = u as usize;
            if part[u] != SEP && part[u] != part[v] {
                part[v] = SEP;
                break;
            }
        }
    }
    let state = SepState::from_parts(&g, part);
    state.validate(&g).unwrap();
    let mut band = extract_band(&g, &state, 3).unwrap();
    let before = band.state.quality_key();
    let mut rng = Rng::new(11);
    refiner.refine_band(&mut band, &mut rng);
    band.state.validate(&band.graph).unwrap();
    assert!(
        band.state.quality_key() <= before,
        "refiner worsened the band: {:?} -> {:?}",
        before,
        band.state.quality_key()
    );
    assert!(
        refiner.xla_calls.load(std::sync::atomic::Ordering::Relaxed) > 0,
        "the XLA path must actually execute"
    );
}

#[test]
fn bucket_fallback_on_oversize_band() {
    let dir = require_artifacts!();
    let rt = load_shared(&dir).expect("load artifacts");
    let refiner = DiffusionRefiner::new(rt);
    // Degree 120 > bucket width 32 → CPU fallback must kick in.
    let g = generators::thread_like(300, 120, 5);
    let part: Vec<u8> = (0..g.n())
        .map(|v| {
            use std::cmp::Ordering::*;
            match v.cmp(&150) {
                Less => P0,
                Equal => SEP,
                Greater => P1,
            }
        })
        .collect();
    let mut state = SepState::from_parts(&g, part);
    // Make it a valid separator first: cover crossing edges.
    for v in 0..g.n() {
        if state.part[v] == SEP {
            continue;
        }
        for &u in g.neighbors(v) {
            let u = u as usize;
            if state.part[u] != SEP && state.part[u] != state.part[v] {
                state.part[v] = SEP;
                break;
            }
        }
    }
    let state = SepState::from_parts(&g, state.part);
    state.validate(&g).unwrap();
    if let Some(mut band) = extract_band(&g, &state, 2) {
        let mut rng = Rng::new(3);
        refiner.refine_band(&mut band, &mut rng);
        band.state.validate(&band.graph).unwrap();
        assert!(
            refiner.fallbacks.load(std::sync::atomic::Ordering::Relaxed) > 0,
            "oversize band must fall back to CPU"
        );
    }
}

#[test]
fn full_parallel_ordering_with_xla_refiner() {
    let dir = require_artifacts!();
    use ptscotch::coordinator::{Engine, OrderingRequest, OrderingService};
    use ptscotch::strategy::Strategy;
    let svc = OrderingService::new(&dir);
    assert!(svc.has_xla());
    let strat = Strategy::parse("refiner=xla").unwrap();
    let g = generators::grid2d(24, 24);
    let req = OrderingRequest::new(&g).strategy(strat).engine(Engine::PtScotch { p: 4 });
    let rep = svc.run(&req).expect("xla-backed parallel ordering");
    rep.ordering.validate().unwrap();
    // Quality must stay in the same class as the FM-only pipeline.
    let fm_req = OrderingRequest::new(&g).engine(Engine::PtScotch { p: 4 });
    let fm = svc.run(&fm_req).unwrap();
    assert!(
        rep.stats.opc <= fm.stats.opc * 1.3,
        "xla refiner opc {} vs fm {}",
        rep.stats.opc,
        fm.stats.opc
    );
}

#[test]
fn bfs_engine_with_artifacts_matches_cpu_frontier() {
    // The fused min-plus BFS path end-to-end on real artifacts: with a
    // loaded runtime and `engine=xla`, the per-rank fused levels must
    // reproduce the CPU frontier BFS exactly and report that the XLA
    // engine actually executed (the 64×24 grid slice fits the 1024-row
    // bucket at p = 4).
    let dir = require_artifacts!();
    use ptscotch::comm;
    use ptscotch::dist::dband::{band_distances, bfs_band_dist_engine};
    use ptscotch::dist::dgraph::DGraph;
    use ptscotch::strategy::BandEngine;
    use std::sync::Arc;

    let rt = load_shared(&dir).expect("load artifacts");
    let (nx, ny) = (64usize, 24usize);
    let g = Arc::new(generators::grid2d(nx, ny));
    let proj = Arc::new(generators::column_separator_part(nx, ny, nx / 2, 2));
    let (ok, _) = comm::run(4, move |c| {
        let dg = DGraph::from_global(&c, &g);
        let part: Vec<u8> = (0..dg.nloc())
            .map(|v| proj[dg.glb(v) as usize])
            .collect();
        let want = band_distances(&c, &dg, &part, 3);
        let (got, used_xla) =
            bfs_band_dist_engine(&c, &dg, &part, 3, BandEngine::Xla, Some(&rt));
        used_xla && got == want
    });
    assert!(ok.iter().all(|&x| x), "fused min-plus BFS diverged");
}
