//! Traffic-regression tests for the persistent halo plans: the
//! [`ptscotch::dist::dgraph::HaloPlan`] must *strictly* reduce bytes and
//! messages on the wire against the seed implementation's per-call
//! request wave, and plans must stay correct across the
//! `fold → Comm::split` re-ranking of the nested-dissection recursion.
//! The baseline is measured in-process (the seed exchange algorithm is
//! kept verbatim below), so the comparison is exact on any host.

use ptscotch::comm::{self, Comm, Executor};
use ptscotch::dist::dgraph::DGraph;
use ptscotch::graph::generators;
use std::sync::Arc;

/// The seed implementation of the halo update, kept verbatim as the
/// regression baseline: every call re-derives the want lists and pays a
/// request `alltoallv` before the data `alltoallv`.
fn legacy_halo_exchange<T: Clone + Send + 'static>(
    dg: &DGraph,
    comm: &Comm,
    vals: &[T],
) -> Vec<T> {
    let p = comm.size();
    let mut want: Vec<Vec<u64>> = vec![Vec::new(); p];
    for &g in &dg.ghosts {
        want[dg.owner(g)].push(g);
    }
    let reqs = comm.alltoallv(want);
    let base = dg.base();
    let reply: Vec<Vec<T>> = reqs
        .iter()
        .map(|ids| {
            ids.iter()
                .map(|&g| vals[(g - base) as usize].clone())
                .collect()
        })
        .collect();
    comm.alltoallv(reply).concat()
}

/// The fixed workload: the exchange cadence of one distributed
/// uncoarsening step — 5 matching rounds (one `u8` flag exchange plus
/// one `u64` proposal exchange each, `parallel_match`'s cadence) and 16
/// diffusion sweeps (one `f32` field exchange each, `cpu_sweeps`'
/// cadence) — with the transport selected by `legacy`.
fn run_workload(c: &Comm, dg: &DGraph, legacy: bool) -> f32 {
    let nloc = dg.nloc();
    for r in 0..5usize {
        let flags: Vec<u8> = (0..nloc).map(|v| ((v + r) % 2) as u8).collect();
        let _ = if legacy {
            legacy_halo_exchange(dg, c, &flags)
        } else {
            dg.halo_exchange(c, &flags)
        };
        let props: Vec<u64> = (0..nloc).map(|v| dg.glb(v)).collect();
        let _ = if legacy {
            legacy_halo_exchange(dg, c, &props)
        } else {
            dg.halo_exchange(c, &props)
        };
    }
    let mut x: Vec<f32> = (0..nloc).map(|v| (v as f32 * 0.37).sin()).collect();
    let mut acc = 0f32;
    for _ in 0..16usize {
        let gx = if legacy {
            legacy_halo_exchange(dg, c, &x)
        } else {
            dg.halo_exchange(c, &x)
        };
        acc += gx.iter().sum::<f32>();
        for xv in &mut x {
            *xv *= 0.5;
        }
    }
    acc
}

#[test]
fn halo_plan_strictly_reduces_traffic_vs_seed_exchange() {
    // Same graph, same construction (the plan round is paid in both
    // runs), same exchange cadence and payloads — the only difference
    // is the transport under the halo, so the deltas are exactly the
    // request waves the plan eliminates.
    let g = Arc::new(generators::grid2d(24, 18));
    for p in [2usize, 4, 5] {
        let measure = |legacy: bool| {
            let g = g.clone();
            let (vals, stats) = comm::run(p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                run_workload(&c, &dg, legacy)
            });
            (vals, stats.total_bytes(), stats.total_msgs())
        };
        let (seed_vals, seed_bytes, seed_msgs) = measure(true);
        let (plan_vals, plan_bytes, plan_msgs) = measure(false);
        // Identical results…
        assert_eq!(seed_vals, plan_vals, "p={p}: transports diverged");
        // …with strictly less traffic on both axes.
        assert!(
            plan_bytes < seed_bytes,
            "p={p}: plan bytes {plan_bytes} not below seed {seed_bytes}"
        );
        assert!(
            plan_msgs < seed_msgs,
            "p={p}: plan msgs {plan_msgs} not below seed {seed_msgs}"
        );
        // The message delta is exactly one request alltoallv per call:
        // 26 calls × p(p-1) messages.
        let calls = (5 * 2 + 16) as u64;
        assert_eq!(
            seed_msgs - plan_msgs,
            calls * (p * (p - 1)) as u64,
            "p={p}: unexpected message delta"
        );
    }
}

#[test]
fn threaded_executor_reports_identical_traffic_counters() {
    // The stats counters are atomics updated from p free-running
    // threads under `executor=threads`; this pins them to the
    // serialized simulator's values on the exact workload above, so a
    // lost or double-counted update (a counter race) shows up as an
    // inequality rather than flakiness.
    let g = Arc::new(generators::grid2d(24, 18));
    for p in [2usize, 5] {
        let measure = |exec: Executor| {
            let g = g.clone();
            let (vals, stats) = comm::run_on(exec, p, move |c| {
                let dg = DGraph::from_global(&c, &g);
                run_workload(&c, &dg, false)
            });
            (vals, stats.bytes_sent, stats.msgs_sent)
        };
        let (sim_vals, sim_bytes, sim_msgs) = measure(Executor::Sim);
        let (thr_vals, thr_bytes, thr_msgs) = measure(Executor::Threads);
        assert_eq!(sim_vals, thr_vals, "p={p}: results diverged");
        assert_eq!(sim_bytes, thr_bytes, "p={p}: per-rank sent bytes");
        assert_eq!(sim_msgs, thr_msgs, "p={p}: per-rank sent messages");
    }
}

#[test]
fn plans_stay_correct_across_split_subgroups_in_dnd_recursion() {
    // End-to-end parallel nested dissection at non-power-of-two rank
    // counts exercises the fold → split path at every level: the folded
    // graphs' plans are built through the parent communicator and used
    // on the sub-communicator after the split. A misrouted plan would
    // corrupt ghost values and invalidate the permutation.
    use ptscotch::coordinator::{Engine, OrderingRequest, OrderingService};
    let svc = OrderingService::new_cpu_only();
    for p in [3usize, 5] {
        let g = generators::grid2d(20, 20);
        let strat = ptscotch::strategy::Strategy::parse("seed=4").unwrap();
        let req = OrderingRequest::new(&g).strategy(strat).engine(Engine::PtScotch { p });
        let rep = svc.run(&req).unwrap();
        rep.ordering
            .validate()
            .unwrap_or_else(|e| panic!("p={p}: {e}"));
    }
}

#[test]
fn fetch_at_answers_without_plan_overhead_growth() {
    // `fetch_at` keeps its request wave (ids are call-specific) but
    // must still answer correctly after the plan refactor, including
    // duplicate and empty query sets.
    let g = Arc::new(generators::grid2d(9, 5));
    let (ok, _) = comm::run(3, move |c| {
        let dg = DGraph::from_global(&c, &g);
        let vals: Vec<i64> = (0..dg.nloc()).map(|v| dg.glb(v) as i64 * 3).collect();
        // Duplicates, reversed order, and rank-dependent emptiness.
        let idx: Vec<u64> = if c.rank() == 1 {
            Vec::new()
        } else {
            (0..dg.nglb).rev().step_by(2).flat_map(|i| [i, i]).collect()
        };
        let got = dg.fetch_at(&c, &idx, &vals);
        got.len() == idx.len()
            && got
                .iter()
                .zip(&idx)
                .all(|(&gv, &i)| gv == i as i64 * 3)
    });
    assert!(ok.iter().all(|&x| x));
}
