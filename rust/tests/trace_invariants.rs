//! Invariant suite for the phase-attributed tracing layer (DESIGN.md
//! §7). Three contracts are enforced here, on real ordering runs:
//!
//! 1. **Well-formedness** — every rank's event stream replays into a
//!    properly nested span forest with exactly one `run` root, and the
//!    root's inclusive counter deltas reproduce the rank's run-total
//!    traffic counters *exactly* (the recorder snapshots the very
//!    atomics the telemetry reports, so nothing can drift). The merged
//!    [`PhaseProfile`]'s exclusive columns tile back to the same
//!    totals.
//! 2. **Observer neutrality** — a `trace=off` run is bit-identical
//!    (permutation, blocks, bytes, msgs, transport ops) to a
//!    `trace=full` run of the same request, across the generator
//!    suite, rank counts and both executors. Tracing may never perturb
//!    what it observes.
//! 3. **Export fidelity** — the Chrome trace-event JSON is
//!    syntactically sound and carries exactly
//!    [`chrome::event_count`] events, and [`chrome::write`] puts the
//!    same bytes on disk that [`chrome::render`] returns.

use ptscotch::coordinator::{Engine, OrderingRequest, OrderingResult, OrderingService};
use ptscotch::graph::{generators, Graph};
use ptscotch::strategy::Strategy;
use ptscotch::trace::profile::{replay, COL_BYTES, COL_MSGS, COL_OPS};
use ptscotch::trace::{chrome, Phase, TraceLevel, CTR_BYTES, CTR_MSGS, CTR_OPS};

/// Run one ordering with the given executor and trace level.
fn order_traced(
    svc: &OrderingService,
    g: &Graph,
    engine: Engine,
    exec: &str,
    trace: &str,
) -> OrderingResult {
    let strat = Strategy::parse(&format!("executor={exec},seed=13,trace={trace}")).unwrap();
    let req = OrderingRequest::new(g).strategy(strat).engine(engine);
    svc.run(&req).unwrap()
}

#[test]
fn spans_nest_and_counter_deltas_tile_to_run_totals() {
    let g = generators::grid3d(7, 7, 7);
    let svc = OrderingService::new_cpu_only();
    for exec in ["sim", "threads"] {
        let res = order_traced(&svc, &g, Engine::PtScotch { p: 4 }, exec, "full");
        assert_eq!(res.traces.len(), 4, "{exec}: one trace per rank");
        for (r, t) in res.traces.iter().enumerate() {
            assert_eq!(t.rank, r, "{exec}: traces in rank order");
            assert_eq!(t.level, TraceLevel::Full, "{exec}");
            // Replay validates the nesting discipline (close matches
            // innermost open, monotone clocks/counters, empty stack).
            let spans = replay(&t.events)
                .unwrap_or_else(|e| panic!("{exec} rank {r}: malformed trace: {e}"));
            assert!(!spans.is_empty(), "{exec} rank {r}: no spans");
            let roots: Vec<_> = spans.iter().filter(|s| s.parent == usize::MAX).collect();
            assert_eq!(roots.len(), 1, "{exec} rank {r}: exactly one root span");
            let root = roots[0];
            assert_eq!(root.phase, Phase::Run, "{exec} rank {r}");
            // The root's inclusive deltas ARE the rank's run totals:
            // the probe reads the same atomics the snapshot reports.
            assert_eq!(
                root.incl[CTR_BYTES], res.bytes_sent_per_rank[r],
                "{exec} rank {r}: bytes"
            );
            assert_eq!(
                root.incl[CTR_MSGS], res.msgs_sent_per_rank[r],
                "{exec} rank {r}: msgs"
            );
            assert_eq!(
                root.incl[CTR_OPS], res.transport_ops_per_rank[r],
                "{exec} rank {r}: transport ops"
            );
        }
        // The merged profile's exclusive columns tile to the totals.
        let prof = res.profile.as_ref().expect("profile built when traced");
        assert_eq!(
            prof.total(COL_BYTES),
            res.bytes_sent_per_rank.iter().sum::<u64>(),
            "{exec}: profile bytes tile"
        );
        assert_eq!(
            prof.total(COL_MSGS),
            res.msgs_sent_per_rank.iter().sum::<u64>(),
            "{exec}: profile msgs tile"
        );
        assert_eq!(
            prof.total(COL_OPS),
            res.transport_ops_per_rank.iter().sum::<u64>(),
            "{exec}: profile ops tile"
        );
        // grid3d on 4 ranks has distributed levels, so per-ND-node
        // quality events were recorded, and the tail fraction is a
        // fraction.
        let quality: usize = res.traces.iter().map(|t| t.quality.len()).sum();
        assert!(quality >= 1, "{exec}: no quality events");
        let tail = prof.sequential_tail_fraction();
        assert!((0.0..=1.0).contains(&tail), "{exec}: tail {tail}");
        // The rendered table mentions the run root and the span count.
        let table = format!("{prof}");
        assert!(table.contains("run"), "{table}");
        assert!(table.contains("phase profile (p = 4"), "{table}");
    }
}

#[test]
fn trace_off_runs_are_bit_identical_to_traced_runs() {
    let suite: Vec<(&str, Graph)> = vec![
        ("grid2d", generators::grid2d(12, 12)),
        ("grid3d", generators::grid3d(6, 6, 6)),
        ("cage", generators::cage_like(500, 8, 2)),
    ];
    let svc = OrderingService::new_cpu_only();
    for (name, g) in &suite {
        for p in [1usize, 2, 4] {
            for exec in ["sim", "threads"] {
                let engine = Engine::PtScotch { p };
                let off = order_traced(&svc, g, engine, exec, "off");
                let full = order_traced(&svc, g, engine, exec, "full");
                let ctx = format!("{name} p={p} {exec}");
                assert_eq!(off.ordering.perm, full.ordering.perm, "{ctx}: perm");
                assert_eq!(off.ordering.iperm, full.ordering.iperm, "{ctx}: iperm");
                assert_eq!(off.blocks, full.blocks, "{ctx}: blocks");
                assert_eq!(
                    off.bytes_sent_per_rank, full.bytes_sent_per_rank,
                    "{ctx}: bytes"
                );
                assert_eq!(
                    off.msgs_sent_per_rank, full.msgs_sent_per_rank,
                    "{ctx}: msgs"
                );
                assert_eq!(
                    off.transport_ops_per_rank, full.transport_ops_per_rank,
                    "{ctx}: transport ops"
                );
                assert!(off.traces.is_empty(), "{ctx}: off run recorded traces");
                assert!(off.profile.is_none(), "{ctx}: off run built a profile");
                assert_eq!(full.traces.len(), p, "{ctx}: traced run trace count");
            }
        }
    }
}

/// Minimal structural JSON check: balanced braces/brackets outside
/// string literals, string escapes honored, nothing after the top
/// value. Not a full parser — enough to reject the usual
/// hand-rendering failures (truncation, stray commas in keys,
/// unescaped quotes) that would make Perfetto refuse the file.
fn assert_json_balanced(s: &str) {
    let (mut depth, mut in_str, mut esc) = (0i64, false, false);
    for (i, c) in s.char_indices() {
        if in_str {
            if esc {
                esc = false;
            } else if c == '\\' {
                esc = true;
            } else if c == '"' {
                in_str = false;
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '{' | '[' => depth += 1,
            '}' | ']' => {
                depth -= 1;
                assert!(depth >= 0, "unbalanced close at byte {i}");
            }
            _ => {}
        }
    }
    assert!(!in_str, "unterminated string");
    assert_eq!(depth, 0, "unbalanced JSON");
}

#[test]
fn chrome_export_is_balanced_and_round_trips_event_count() {
    let g = generators::grid3d(6, 6, 6);
    let svc = OrderingService::new_cpu_only();
    let res = order_traced(&svc, &g, Engine::PtScotch { p: 2 }, "sim", "full");
    let json = chrome::render(&res.traces).unwrap();
    assert_json_balanced(&json);
    assert!(json.starts_with("{\"traceEvents\":["), "envelope");
    // Event-count round trip: the serialized stream carries exactly
    // one "X" complete event per span, one "M" metadata event per
    // rank, and one "i" instant per quality event.
    let count = |needle: &str| json.matches(needle).count();
    let spans: usize = res.traces.iter().map(|t| t.events.len() / 2).sum();
    let quality: usize = res.traces.iter().map(|t| t.quality.len()).sum();
    assert_eq!(count("\"ph\":\"X\""), spans, "complete events");
    assert_eq!(count("\"ph\":\"M\""), res.traces.len(), "metadata events");
    assert_eq!(count("\"ph\":\"i\""), quality, "instant events");
    assert_eq!(
        count("\"ph\":"),
        chrome::event_count(&res.traces),
        "event_count round trip"
    );
    // write() puts exactly render()'s bytes on disk.
    let path = std::env::temp_dir().join(format!("ptscotch-trace-{}.json", std::process::id()));
    chrome::write(&path, &res.traces).unwrap();
    let disk = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(disk, json, "write/render agreement");
}

#[test]
fn sequential_engine_records_a_probe_free_trace() {
    let g = generators::grid2d(16, 16);
    let svc = OrderingService::new_cpu_only();
    let res = order_traced(&svc, &g, Engine::Sequential, "sim", "phases");
    assert_eq!(res.traces.len(), 1, "one pseudo-rank");
    let t = &res.traces[0];
    assert_eq!(t.rank, 0);
    // No fleet, no probe: every counter snapshot is zero, so every
    // profile counter column is zero — only wall time is attributed.
    assert!(
        t.events.iter().all(|e| e.ctrs == [0; 4]),
        "sequential events must carry zero counter snapshots"
    );
    let spans = replay(&t.events).unwrap();
    assert_eq!(
        spans.iter().filter(|s| s.parent == usize::MAX).count(),
        1,
        "one run root"
    );
    assert!(
        spans.iter().any(|s| s.phase == Phase::LeafOrder),
        "sequential ND orders leaves"
    );
    let prof = res.profile.as_ref().expect("profile");
    assert_eq!(prof.total(COL_BYTES), 0);
    assert_eq!(prof.total(COL_MSGS), 0);
    let tail = prof.sequential_tail_fraction();
    assert!((0.0..=1.0).contains(&tail), "tail {tail}");
}
