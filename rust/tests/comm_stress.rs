//! Randomized stress tests for the comm primitives under both
//! executors (DESIGN.md §3). Every rank replays the same scripted Rng,
//! so all ranks draw identical op sequences and parameters; the ops
//! themselves (`alltoallv`, `allreduce`, `barrier`, `bcast`, `split`,
//! tag-shuffled p2p) are chosen to collide tags, cross sub-communicator
//! boundaries and leave messages in flight across collectives. The
//! transport's own stall deadline (DESIGN.md §3.2) converts a deadlock
//! into a structured `FleetStalled` error instead of a hang — no
//! test-local watchdog thread needed — and the per-seed accumulator
//! must agree between the serialized simulator and the free-running
//! threaded fabric.

use ptscotch::comm::{self, Executor, RunConfig};
use ptscotch::rng::Rng;
use std::time::Duration;

/// A deliberately tight stall deadline: the stress programs never
/// legitimately go this long without fleet-wide transport progress, so
/// a deadlock (lost wakeup, tag mismatch, split desync) fails the
/// suite within seconds as `FleetStalled` instead of wedging it.
const TIGHT_DEADLINE: Duration = Duration::from_secs(2);

/// The suite deadline, scalable via `PTSCOTCH_STRESS_DEADLINE_SECS`
/// for slow environments: the TSan targets (`make tsan`, the ci.yml
/// tsan job) set 20, because thread sanitizer slows execution 5–15×
/// and a rank legitimately parked a few seconds on one wait must not
/// flake as `FleetStalled`.
fn tight_deadline() -> Duration {
    std::env::var("PTSCOTCH_STRESS_DEADLINE_SECS")
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .map(Duration::from_secs)
        .unwrap_or(TIGHT_DEADLINE)
}

/// Run `f` on `p` ranks under `exec` with the tight stall deadline. A
/// hung fleet surfaces as `Err(FleetStalled)` and a rank panic as
/// `Err(RankPanicked)`; both fail the test with the structured message.
fn run_tight<R, F>(exec: Executor, p: usize, f: F) -> Vec<R>
where
    R: Send + 'static,
    F: Fn(comm::Comm) -> R + Send + Sync + 'static,
{
    let cfg = RunConfig {
        fault: None,
        stall_deadline: tight_deadline(),
        ..RunConfig::default()
    };
    match comm::try_run_with(exec, p, cfg, f) {
        Ok((res, _)) => res,
        Err(e) => panic!("{exec} fleet p={p}: {e}"),
    }
}

/// One scripted stress program. Every rank draws the identical op
/// script from `seed`; the return value folds every observable result
/// into one checksum compared across executors.
fn stress_program(c: &comm::Comm, seed: u64) -> u64 {
    let p = c.size();
    let me = c.rank();
    let mut script = Rng::new(seed);
    let mut acc = 0u64;
    let ops = 24 + script.below(16);
    for op_idx in 0..ops {
        match script.below(6) {
            // alltoallv with per-pair payload sizes drawn from the
            // script; verify by checksumming what arrives (the sender
            // encodes (src, dst, slot) so misrouting is detectable).
            0 => {
                let mut sizes = vec![0usize; p * p];
                for s in &mut sizes {
                    *s = script.below(7);
                }
                let out: Vec<Vec<u64>> = (0..p)
                    .map(|dst| {
                        (0..sizes[me * p + dst])
                            .map(|k| ((me as u64) << 32) | ((dst as u64) << 16) | k as u64)
                            .collect()
                    })
                    .collect();
                let got = c.alltoallv(out);
                for (src, block) in got.iter().enumerate() {
                    assert_eq!(block.len(), sizes[src * p + me], "misrouted alltoallv");
                    for (k, &v) in block.iter().enumerate() {
                        assert_eq!(
                            v,
                            ((src as u64) << 32) | ((me as u64) << 16) | k as u64,
                            "corrupted alltoallv payload"
                        );
                        acc = acc.wrapping_mul(31).wrapping_add(v);
                    }
                }
            }
            // allreduce cross-checked against allgatherv of the same
            // contribution.
            1 => {
                let mine = script.next_u64() ^ ((me as u64) << 48) ^ op_idx as u64;
                let red = c.allreduce(mine, |a, b| a.wrapping_add(b));
                let all = c.allgatherv(vec![mine]);
                let gathered = all.iter().flatten().fold(0u64, |a, &b| a.wrapping_add(b));
                assert_eq!(red, gathered, "allreduce disagrees with allgatherv");
                acc = acc.wrapping_mul(31).wrapping_add(red);
            }
            // barrier (with exscan to make it observable).
            2 => {
                c.barrier();
                acc = acc.wrapping_mul(31).wrapping_add(c.exscan_sum(1 + me as u64));
            }
            // bcast: the payload is drawn from the shared script so
            // every rank verifies it exactly.
            3 => {
                let root = script.below(p);
                let len = 1 + script.below(5);
                let payload: Vec<u64> = (0..len).map(|_| script.next_u64()).collect();
                let got = c.bcast(root, (me == root).then(|| payload.clone()));
                assert_eq!(got, payload, "bcast diverged from script");
                acc = acc.wrapping_mul(31).wrapping_add(got.iter().sum::<u64>());
            }
            // split by color, then run a verified collective inside the
            // sub-communicator before it drops.
            4 => {
                let k = 1 + script.below(p);
                let sub = c.split(me % k);
                let members = (0..p).filter(|r| r % k == me % k).count();
                assert_eq!(sub.size(), members, "split subgroup size");
                assert_eq!(sub.rank(), me / k, "split re-ranking");
                let s = sub.allreduce_sum(1 + me as i64);
                let expect: i64 = (0..p).filter(|r| r % k == me % k).map(|r| 1 + r as i64).sum();
                assert_eq!(s, expect, "collective inside split subgroup");
                acc = acc.wrapping_mul(31).wrapping_add(s as u64);
            }
            // Tag-shuffled p2p ring: everyone sends to the next rank
            // on several tags at once and receives them in a different
            // (scripted) order, exercising out-of-order tag matching.
            _ => {
                if p > 1 {
                    let tags: Vec<u64> = (0..3).map(|_| 1000 + script.below(50) as u64).collect();
                    let mut uniq = tags.clone();
                    uniq.sort_unstable();
                    uniq.dedup();
                    for &t in &uniq {
                        c.send((me + 1) % p, t, vec![t.wrapping_mul(me as u64 + 1), op_idx as u64]);
                    }
                    // Receive in reverse tag order to force queue scans
                    // past non-matching packets.
                    let prev = (me + p - 1) % p;
                    for &t in uniq.iter().rev() {
                        let got = c.recv::<u64>(prev, t);
                        assert_eq!(got, vec![t.wrapping_mul(prev as u64 + 1), op_idx as u64]);
                        acc = acc.wrapping_mul(31).wrapping_add(got[0]);
                    }
                }
            }
        }
    }
    acc
}

#[test]
fn randomized_interleavings_agree_across_executors() {
    for p in [2usize, 3, 5, 8] {
        for seed in [1u64, 17, 4242] {
            let run = |exec| run_tight(exec, p, move |c| stress_program(&c, seed));
            let sim = run(Executor::Sim);
            let thr = run(Executor::Threads);
            assert_eq!(sim, thr, "p={p} seed={seed}: executors diverged");
            // All ranks fold the same script, so ranks must agree on
            // the collective-only part being nonzero.
            assert!(sim.iter().all(|&a| a != 0), "p={p} seed={seed}: empty run");
        }
    }
}

#[test]
fn overlap_clones_stress_both_executors() {
    // The §3.1 shape, concentrated: every rank runs a scoped overlap
    // thread doing a full collective sequence on a tag-scoped clone
    // while the main thread runs another on the base communicator.
    for exec in [Executor::Sim, Executor::Threads] {
        let res = run_tight(exec, 4, move |c| {
            let oc = c.overlap_context(9);
            let (bg, fg) = std::thread::scope(|s| {
                // `move` takes the owned clone: `Comm` is Send, not Sync.
                let h = s.spawn(move || {
                    let mut acc = 0u64;
                    for i in 0..8u64 {
                        let red = oc.allreduce(i + oc.rank() as u64, u64::wrapping_add);
                        acc = acc.wrapping_add(red);
                        let all = oc.allgatherv(vec![oc.rank() as u64 * i]);
                        acc = acc.wrapping_add(all.iter().flatten().sum::<u64>());
                    }
                    acc
                });
                let mut acc = 0u64;
                for i in 0..8u64 {
                    let v = c.alltoallv((0..c.size()).map(|d| vec![i + d as u64]).collect());
                    acc = acc.wrapping_add(v.iter().flatten().sum::<u64>());
                    c.barrier();
                }
                // acc is rank-dependent (each rank received i + rank);
                // reduce it so the ranks-agree assertion below holds.
                (h.join().expect("overlap thread"), c.allreduce(acc, u64::wrapping_add))
            });
            (bg, fg)
        });
        // Collectives give every rank the same folded values.
        assert!(res.windows(2).all(|w| w[0] == w[1]), "{exec}: ranks diverged");
    }
}
