//! Simulator-differential tests for the threaded executor (DESIGN.md
//! §3): for every graph family, rank count, leaf method and band
//! engine, `parallel_order` under `executor=threads` must return
//! bit-identical permutations and telemetry counters to the serialized
//! simulator oracle with the same seed. The simulator imposes a total
//! order on every transport operation, so agreement here proves the
//! free-running fabric's scheduling freedom never leaks into results.

use ptscotch::coordinator::{Engine, OrderingRequest, OrderingResult, OrderingService};
use ptscotch::graph::{generators, Graph};
use ptscotch::strategy::Strategy;

/// Order `g` on `p` ranks with the given extra strategy knobs under one
/// executor.
fn order_on(svc: &OrderingService, g: &Graph, p: usize, exec: &str, knobs: &str) -> OrderingResult {
    let spec = format!("executor={exec},seed=11,{knobs}");
    let strat = Strategy::parse(spec.trim_end_matches(',')).unwrap();
    let req = OrderingRequest::new(g).strategy(strat).engine(Engine::PtScotch { p });
    svc.run(&req).unwrap()
}

/// Assert every deterministic field of two results matches.
fn assert_reports_identical(sim: &OrderingResult, thr: &OrderingResult, ctx: &str) {
    assert_eq!(sim.ordering.perm, thr.ordering.perm, "{ctx}: perm");
    assert_eq!(sim.ordering.iperm, thr.ordering.iperm, "{ctx}: iperm");
    assert_eq!(sim.blocks, thr.blocks, "{ctx}: blocks");
    assert_eq!(sim.bytes_sent_per_rank, thr.bytes_sent_per_rank, "{ctx}: bytes");
    assert_eq!(sim.msgs_sent_per_rank, thr.msgs_sent_per_rank, "{ctx}: msgs");
    assert_eq!(
        sim.transport_ops_per_rank, thr.transport_ops_per_rank,
        "{ctx}: transport ops"
    );
    assert_eq!(sim.peak_mem_per_rank, thr.peak_mem_per_rank, "{ctx}: peak mem");
    assert_eq!(sim.stats.nnz, thr.stats.nnz, "{ctx}: nnz");
    assert_eq!(sim.stats.opc, thr.stats.opc, "{ctx}: opc");
    assert_eq!(sim.stats.tree_height, thr.stats.tree_height, "{ctx}: tree height");
}

#[test]
fn threads_match_simulator_across_generator_suite_and_rank_counts() {
    let suite: Vec<(&str, Graph)> = vec![
        ("grid2d", generators::grid2d(16, 16)),
        ("grid3d", generators::grid3d(7, 7, 7)),
        ("irregular", generators::irregular_mesh(14, 14, 7)),
        ("cage", generators::cage_like(700, 8, 2)),
        ("thread", generators::thread_like(260, 60, 4)),
    ];
    let svc = OrderingService::new_cpu_only();
    for (name, g) in &suite {
        for p in [2usize, 3, 4, 5, 8] {
            let sim = order_on(&svc, g, p, "sim", "");
            let thr = order_on(&svc, g, p, "threads", "");
            assert_reports_identical(&sim, &thr, &format!("{name} p={p}"));
            sim.ordering
                .validate()
                .unwrap_or_else(|e| panic!("{name} p={p}: {e}"));
        }
    }
}

#[test]
fn threads_match_simulator_across_leaf_methods_and_engines() {
    // The leaf-method and band-engine knobs change the work each rank
    // does (HAMD halo carriage, fused XLA levels vs scalar sweeps) but
    // must not open a schedule dependence. Without loaded artifacts the
    // xla engine collectively degrades to the cpu path — the
    // differential claim is sim ≡ threads per configuration, which
    // still exercises the engine-agreement collective under both
    // fabrics.
    let svc = OrderingService::new_cpu_only();
    let graphs: Vec<(&str, Graph)> = vec![
        ("grid3d", generators::grid3d(7, 7, 7)),
        ("irregular", generators::irregular_mesh(12, 12, 3)),
    ];
    for (name, g) in &graphs {
        for p in [3usize, 5] {
            for leaf in ["mmd", "hamd"] {
                for engine in ["cpu", "xla"] {
                    let knobs = format!("leafmethod={leaf},engine={engine}");
                    let sim = order_on(&svc, g, p, "sim", &knobs);
                    let thr = order_on(&svc, g, p, "threads", &knobs);
                    let ctx = format!("{name} p={p} {knobs}");
                    assert_reports_identical(&sim, &thr, &ctx);
                }
            }
        }
    }
}

#[test]
fn threaded_executor_is_deterministic_across_repeated_runs() {
    // Two threaded runs see different OS schedules; identical output
    // shows the determinism comes from the program, not from luck with
    // one interleaving.
    let svc = OrderingService::new_cpu_only();
    let g = generators::irregular_mesh(13, 13, 5);
    let a = order_on(&svc, &g, 5, "threads", "folddup=1,overlap=1");
    let b = order_on(&svc, &g, 5, "threads", "folddup=1,overlap=1");
    assert_reports_identical(&a, &b, "threads run-to-run");
}

#[test]
fn fold_duplication_and_overlap_survive_both_executors() {
    // fold-with-duplication plus the §3.1 overlap thread is the
    // hardest concurrency shape: an extra scoped thread per rank talks
    // through a tag-scoped communicator clone while the main thread
    // keeps folding. Both executors must agree bit-for-bit.
    let svc = OrderingService::new_cpu_only();
    let g = generators::grid3d(6, 6, 6);
    for p in [4usize, 8] {
        for knobs in ["folddup=1,overlap=1", "folddup=1,overlap=0", "folddup=0"] {
            let sim = order_on(&svc, &g, p, "sim", knobs);
            let thr = order_on(&svc, &g, p, "threads", knobs);
            assert_reports_identical(&sim, &thr, &format!("p={p} {knobs}"));
        }
    }
}
