//! Service-layer tests (DESIGN.md §6): the batch coordinator's
//! fingerprint cache must serve bit-identical results with zero rank
//! work, in-batch duplicates must coalesce onto one fleet job, mixed
//! concurrent batches must stay deterministic under the threaded
//! executor, and every result must carry a valid postordered
//! `BlockOrdering` across the generator suite at p ∈ {1, 4}.

use ptscotch::coordinator::{
    BatchCoordinator, Engine, OrderingRequest, OrderingService, Served, ServiceConfig,
};
use ptscotch::graph::{generators, Graph};
use std::sync::Arc;

fn suite() -> Vec<(&'static str, Graph)> {
    vec![
        ("grid2d", generators::grid2d(18, 18)),
        ("grid3d", generators::grid3d(6, 6, 6)),
        ("irregular", generators::irregular_mesh(14, 14, 3)),
        ("cage", generators::cage_like(400, 6, 2)),
        ("qimonda", generators::qimonda_like(500, 3)),
    ]
}

#[test]
fn cache_hits_are_bit_identical_across_executors() {
    // Determinism is what makes the cache sound: the same computation
    // under the serialized simulator and under the free-running
    // threaded fabric yields one bit pattern (DESIGN.md §3), so a
    // cached result is indistinguishable from a recomputation on
    // either executor.
    let coord = BatchCoordinator::new(OrderingService::new_cpu_only());
    let g = Arc::new(generators::grid2d(20, 20));
    let req = |exec: &str| {
        OrderingRequest::from_arc(Arc::clone(&g))
            .parse_strategy(&format!("executor={exec},seed=3"))
            .unwrap()
            .engine(Engine::PtScotch { p: 4 })
            .tag(exec)
    };
    let cold = coord.submit(vec![req("sim"), req("threads")]);
    assert!(cold.iter().all(|r| r.served == Served::Miss));
    let sim = cold[0].result.as_ref().unwrap();
    let thr = cold[1].result.as_ref().unwrap();
    assert_eq!(sim.ordering, thr.ordering);
    assert_eq!(sim.blocks, thr.blocks);
    assert_eq!(sim.bytes_sent_per_rank, thr.bytes_sent_per_rank);
    assert_eq!(sim.msgs_sent_per_rank, thr.msgs_sent_per_rank);
    // Replays under either executor knob are cache hits sharing the
    // exact allocation of the first computation: bit-identity for free.
    let warm = coord.submit(vec![req("threads"), req("sim")]);
    assert!(warm.iter().all(|r| r.served == Served::Hit));
    assert!(Arc::ptr_eq(thr, warm[0].result.as_ref().unwrap()));
    assert!(Arc::ptr_eq(sim, warm[1].result.as_ref().unwrap()));
    assert_eq!(coord.metrics().jobs_run, 2);
}

#[test]
fn fingerprints_do_not_collide_across_the_suite() {
    // Every distinct (graph, strategy, engine) combination across the
    // generator suite must map to a distinct 128-bit fingerprint — a
    // collision would silently serve one problem's ordering for
    // another's.
    let mut fps = Vec::new();
    for (_, g) in suite() {
        let g = Arc::new(g);
        for spec in ["seed=1", "seed=2", "band=5"] {
            let engines = [
                Engine::Sequential,
                Engine::PtScotch { p: 2 },
                Engine::PtScotch { p: 4 },
                Engine::ParMetisLike { p: 4 },
            ];
            for engine in engines {
                let fp = OrderingRequest::from_arc(Arc::clone(&g))
                    .parse_strategy(spec)
                    .unwrap()
                    .engine(engine)
                    .fingerprint();
                fps.push(fp);
            }
        }
    }
    let n = fps.len();
    fps.sort_unstable();
    fps.dedup();
    assert_eq!(fps.len(), n, "fingerprint collision across distinct requests");
}

#[test]
fn replaying_one_request_n_times_runs_one_fleet_job() {
    // The headline service property: the same graph + strategy
    // submitted N times performs exactly one full ordering. In-batch
    // duplicates coalesce onto the leader's job; later rounds are
    // cache hits with zero rank work, so the fleet's traffic counters
    // stay those of the single run.
    let coord = BatchCoordinator::with_config(
        OrderingService::new_cpu_only(),
        ServiceConfig {
            cache_capacity: 8,
            max_in_flight: 4,
        },
    );
    let g = Arc::new(generators::grid3d(6, 6, 6));
    let mk = |i: usize| {
        OrderingRequest::from_arc(Arc::clone(&g))
            .engine(Engine::PtScotch { p: 4 })
            .tag(format!("client-{i}"))
    };
    let first = coord.submit((0..4).map(mk).collect());
    assert_eq!(first[0].served, Served::Miss);
    let lead = first[0].result.as_ref().unwrap();
    for r in &first[1..] {
        assert_eq!(r.served, Served::Coalesced);
        assert!(Arc::ptr_eq(lead, r.result.as_ref().unwrap()));
    }
    for round in 0..3 {
        let replies = coord.submit((0..4).map(mk).collect());
        for r in &replies {
            assert_eq!(r.served, Served::Hit, "round {round}");
            assert_eq!(r.run_seconds, 0.0, "round {round}: hits do no rank work");
            let res = r.result.as_ref().unwrap();
            assert!(Arc::ptr_eq(lead, res), "round {round}: not the cached result");
            // Flat rank-pool traffic: replays add zero bytes/messages.
            assert_eq!(res.bytes_sent_per_rank, lead.bytes_sent_per_rank);
            assert_eq!(res.msgs_sent_per_rank, lead.msgs_sent_per_rank);
        }
    }
    let m = coord.metrics();
    assert_eq!(m.jobs_run, 1, "16 requests must cost exactly one ordering");
    assert_eq!((m.misses, m.coalesced, m.hits), (1, 3, 12));
    assert_eq!(m.requests(), 16);
}

#[test]
fn mixed_concurrent_batches_are_deterministic_under_threads() {
    // A mixed batch schedules several distinct jobs concurrently, each
    // launching its own thread fleet under `executor=threads`. Two
    // fresh coordinators must produce bit-identical results for every
    // request, and both must agree with the serialized simulator
    // oracle — concurrency between jobs must not leak into results any
    // more than concurrency within a fleet does.
    let g1 = Arc::new(generators::grid2d(16, 16));
    let g2 = Arc::new(generators::grid3d(5, 5, 5));
    let batch = |exec: &str| {
        vec![
            OrderingRequest::from_arc(Arc::clone(&g1))
                .parse_strategy(&format!("executor={exec},seed=2"))
                .unwrap()
                .engine(Engine::PtScotch { p: 3 })
                .tag("g1-pts3"),
            OrderingRequest::from_arc(Arc::clone(&g2))
                .parse_strategy(&format!("executor={exec},seed=2"))
                .unwrap()
                .engine(Engine::PtScotch { p: 4 })
                .tag("g2-pts4"),
            OrderingRequest::from_arc(Arc::clone(&g1))
                .parse_strategy(&format!("executor={exec},seed=5"))
                .unwrap()
                .engine(Engine::ParMetisLike { p: 4 })
                .tag("g1-pm4"),
            OrderingRequest::from_arc(Arc::clone(&g2))
                .parse_strategy(&format!("executor={exec},seed=2"))
                .unwrap()
                .tag("g2-seq"),
        ]
    };
    let run_batch = |exec: &str| {
        let coord = BatchCoordinator::with_config(
            OrderingService::new_cpu_only(),
            ServiceConfig {
                cache_capacity: 16,
                max_in_flight: 4,
            },
        );
        let replies = coord.submit(batch(exec));
        assert!(replies.iter().all(|r| r.served == Served::Miss));
        replies
    };
    let a = run_batch("threads");
    let b = run_batch("threads");
    let oracle = run_batch("sim");
    for ((ra, rb), ro) in a.iter().zip(&b).zip(&oracle) {
        let tag = &ra.tag;
        let ra = ra.result.as_ref().unwrap();
        let rb = rb.result.as_ref().unwrap();
        let ro = ro.result.as_ref().unwrap();
        assert_eq!(ra.ordering, rb.ordering, "{tag}: threads run-to-run");
        assert_eq!(ra.blocks, rb.blocks, "{tag}: threads run-to-run");
        assert_eq!(ra.ordering, ro.ordering, "{tag}: threads vs sim oracle");
        assert_eq!(ra.blocks, ro.blocks, "{tag}: threads vs sim oracle");
        assert_eq!(ra.bytes_sent_per_rank, ro.bytes_sent_per_rank, "{tag}: bytes");
        assert_eq!(ra.msgs_sent_per_rank, ro.msgs_sent_per_rank, "{tag}: msgs");
    }
}

#[test]
fn block_ordering_is_a_postordered_forest_across_the_suite() {
    // The solver-facing contract: for every graph family at p ∈ {1, 4}
    // the result's `BlockOrdering` tiles 0..n with non-empty supernode
    // ranges and its block tree is a postordered forest — every
    // non-root block's parent comes later, so children complete before
    // their parent when a supernodal solver walks blocks in order.
    let svc = OrderingService::new_cpu_only();
    for (name, g) in suite() {
        let g = Arc::new(g);
        for p in [1usize, 4] {
            let engine = if p == 1 {
                Engine::Sequential
            } else {
                Engine::PtScotch { p }
            };
            let req = OrderingRequest::from_arc(Arc::clone(&g)).engine(engine);
            let res = svc.run(&req).unwrap_or_else(|e| panic!("{name} p={p}: {e}"));
            let blocks = &res.blocks;
            blocks.validate(g.n()).unwrap_or_else(|e| panic!("{name} p={p}: {e}"));
            assert_eq!(blocks.n(), g.n(), "{name} p={p}: ranges must tile 0..n");
            let mut roots = 0usize;
            for b in 0..blocks.cblk {
                let parent = blocks.tree[b];
                if parent == usize::MAX {
                    roots += 1;
                } else {
                    assert!(
                        parent > b && parent < blocks.cblk,
                        "{name} p={p}: block {b} has parent {parent}"
                    );
                }
            }
            assert!(roots >= 1, "{name} p={p}: forest needs at least one root");
            for b in 0..blocks.cblk {
                assert!(
                    blocks.range[b] < blocks.range[b + 1],
                    "{name} p={p}: empty block {b}"
                );
                for col in blocks.range[b]..blocks.range[b + 1] {
                    assert_eq!(blocks.block_of(col), b, "{name} p={p}: col {col}");
                }
            }
        }
    }
}
