//! Fault-injection suite (DESIGN.md §3.2, §6): scripted rank faults
//! against real orderings on both executors.
//!
//! The contract under test, per (graph, p, executor):
//!
//! * a scripted panic at *any* transport-op index returns
//!   `Err(RankPanicked)` from the fallible run path within the stall
//!   deadline — the process neither aborts nor hangs;
//! * injected delays never change `perm`/`iperm` or the traffic
//!   counters (the determinism contract is schedule-independent, and a
//!   delay is just a schedule perturbation);
//! * an injected stall surfaces as `Err(FleetStalled)` once the
//!   deadline expires;
//! * the `BatchCoordinator` recovery ladder turns one-shot faults into
//!   served requests: retry on the next rung, sequential degradation
//!   on the last — with the metrics and report routes to prove it.

use ptscotch::comm::{self, FaultPlan};
use ptscotch::coordinator::{
    BatchCoordinator, Engine, OrderingRequest, OrderingService, Route, Served, ServiceConfig,
};
use ptscotch::graph::{generators, Graph};
use ptscotch::strategy::Strategy;
use ptscotch::Error;
use std::time::Duration;

/// The graphs the sweep runs over — small enough to order repeatedly,
/// shaped differently enough (regular grid vs irregular mesh) to push
/// distinct collective schedules through the fault hook.
fn suite() -> Vec<(&'static str, Graph)> {
    vec![
        ("grid2d", generators::grid2d(12, 12)),
        ("irregular", generators::irregular_mesh(10, 10, 5)),
    ]
}

/// A CPU-only service with `deadline_secs` as its stall deadline and an
/// optional scripted fault plan.
fn svc_with(plan: Option<FaultPlan>, deadline_secs: u64) -> OrderingService {
    let svc =
        OrderingService::new_cpu_only().with_stall_deadline(Duration::from_secs(deadline_secs));
    match plan {
        Some(p) => svc.with_fault_plan(p),
        None => svc,
    }
}

/// A PtScotch-engine request pinned to `exec` with the suite seed.
/// `overlap=0` pins the op-index coordinate system: with the §3.1
/// overlap thread on, a rank's two transport threads interleave into
/// its shared op counter in schedule-dependent order, so "rank r's
/// Nth op" would not name a fixed program point (comm::fault docs).
fn order_req(g: &Graph, p: usize, exec: &str) -> OrderingRequest {
    let strat = Strategy::parse(&format!("executor={exec},seed=11,overlap=0")).unwrap();
    OrderingRequest::new(g)
        .strategy(strat)
        .engine(Engine::PtScotch { p })
}

#[test]
fn scripted_panic_at_sampled_ops_errors_within_deadline() {
    // For every (graph, p, executor): learn the victim rank's total op
    // count from a fault-free run, then re-run with a scripted panic at
    // a sample of op indices spanning that range. Every injection must
    // come back as RankPanicked naming the victim — a propagation bug
    // would surface as FleetStalled (the 30s deadline) or a hang, both
    // failing the match.
    for (name, g) in &suite() {
        for p in [2usize, 4, 5] {
            for exec in ["sim", "threads"] {
                let victim = p - 1;
                let clean = svc_with(None, 30)
                    .run(&order_req(g, p, exec))
                    .unwrap_or_else(|e| panic!("{name} p={p} {exec}: clean run failed: {e}"));
                let total = clean.transport_ops_per_rank[victim];
                assert!(total > 0, "{name} p={p} {exec}: victim ran no transport ops");
                let step = (total / 5).max(1);
                for op in (0..total).step_by(step as usize) {
                    let plan = FaultPlan::new().panic_at(victim, op);
                    let err = svc_with(Some(plan), 30)
                        .run(&order_req(g, p, exec))
                        .expect_err("injected panic must fail the run");
                    match err {
                        Error::RankPanicked { rank, ref message } => {
                            assert_eq!(rank, victim, "{name} p={p} {exec} op={op}");
                            assert!(
                                message.contains("injected panic"),
                                "{name} p={p} {exec} op={op}: {message}"
                            );
                        }
                        other => {
                            panic!("{name} p={p} {exec} op={op}: expected RankPanicked, got {other}")
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn injected_delays_never_change_the_ordering() {
    // Delays perturb the schedule without changing any message; by the
    // determinism contract the permutation and traffic counters must be
    // bit-identical to the fault-free run on both executors.
    for (name, g) in &suite() {
        for exec in ["sim", "threads"] {
            let p = 4;
            let clean = svc_with(None, 30).run(&order_req(g, p, exec)).unwrap();
            let plan = FaultPlan::new()
                .delay_at(0, 7, 20)
                .delay_at(2, 19, 10)
                .delay_at(3, 3, 30);
            let slow = svc_with(Some(plan), 30)
                .run(&order_req(g, p, exec))
                .unwrap_or_else(|e| panic!("{name} {exec}: delayed run failed: {e}"));
            let ctx = format!("{name} {exec}");
            assert_eq!(clean.ordering.perm, slow.ordering.perm, "{ctx}: perm");
            assert_eq!(clean.ordering.iperm, slow.ordering.iperm, "{ctx}: iperm");
            assert_eq!(
                clean.bytes_sent_per_rank, slow.bytes_sent_per_rank,
                "{ctx}: bytes"
            );
            assert_eq!(
                clean.msgs_sent_per_rank, slow.msgs_sent_per_rank,
                "{ctx}: msgs"
            );
            assert_eq!(
                clean.transport_ops_per_rank, slow.transport_ops_per_rank,
                "{ctx}: transport ops"
            );
        }
    }
}

#[test]
fn injected_stall_becomes_fleet_stalled_not_a_hang() {
    let g = generators::grid2d(12, 12);
    for exec in ["sim", "threads"] {
        let t0 = std::time::Instant::now();
        let plan = FaultPlan::new().stall_at(1, 10);
        let err = svc_with(Some(plan), 2)
            .run(&order_req(&g, 3, exec))
            .expect_err("stalled fleet must fail");
        assert!(
            matches!(err, Error::FleetStalled { .. }),
            "{exec}: expected FleetStalled, got {err}"
        );
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "{exec}: stall detection took {:?}",
            t0.elapsed()
        );
    }
}

#[test]
fn coordinator_retries_one_shot_fault_to_a_bit_identical_result() {
    // max_retries=1 + a single one-shot panic: the batch completes with
    // retries=1, errors=0, and the recovered ordering is the exact one
    // a fault-free service produces.
    let g = generators::grid2d(12, 12);
    for exec in ["sim", "threads"] {
        let plan = FaultPlan::new().panic_at(1, 25);
        let coord = BatchCoordinator::with_config(
            svc_with(Some(plan), 30),
            ServiceConfig {
                max_retries: 1,
                retry_backoff_ms: 1,
                ..ServiceConfig::default()
            },
        );
        let reply = coord.request(order_req(&g, 3, exec));
        assert_eq!(reply.served, Served::Miss, "{exec}");
        assert_eq!((reply.attempts, reply.route), (2, Route::Retried), "{exec}");
        let recovered = reply.result.expect("retry must recover the request");
        let m = coord.metrics();
        assert_eq!(
            (m.retries, m.aborts, m.errors, m.degraded),
            (1, 1, 0, 0),
            "{exec}"
        );
        let reference = svc_with(None, 30).run(&order_req(&g, 3, exec)).unwrap();
        assert_eq!(recovered.ordering.iperm, reference.ordering.iperm, "{exec}");
    }
}

#[test]
fn exhausted_ladder_degrades_to_the_sequential_reference() {
    // Enough one-shot triggers to kill the first attempt and its only
    // retry: the ladder must fall back to the sequential engine, serve
    // the request (errors=0), and keep the degraded result out of the
    // cache so the parallel fingerprint is never poisoned.
    let g = generators::grid2d(12, 12);
    let plan = FaultPlan::new()
        .panic_at(0, 5)
        .panic_at(0, 5)
        .panic_at(0, 5)
        .panic_at(0, 5);
    let coord = BatchCoordinator::with_config(
        svc_with(Some(plan), 30),
        ServiceConfig {
            max_retries: 1,
            retry_backoff_ms: 1,
            ..ServiceConfig::default()
        },
    );
    let req = order_req(&g, 2, "sim");
    let reply = coord.request(req.clone());
    assert_eq!((reply.attempts, reply.route), (3, Route::Degraded));
    let degraded = reply.result.expect("degradation must serve the request");
    let m = coord.metrics();
    assert_eq!((m.retries, m.aborts, m.errors, m.degraded), (1, 2, 0, 1));
    // The degraded ordering is the sequential one for the same strategy.
    let seq = OrderingService::new_cpu_only()
        .run(&req.clone().engine(Engine::Sequential))
        .unwrap();
    assert_eq!(degraded.ordering.iperm, seq.ordering.iperm);
    // Not cached: the same request misses again (two triggers remain, so
    // it degrades again rather than serving a stale sequential hit).
    let again = coord.request(req);
    assert_eq!(again.served, Served::Miss);
    assert_eq!(again.route, Route::Degraded);
}

#[test]
fn malformed_fault_spec_is_a_structured_bad_env_error() {
    // The env grammar itself (no env mutation here — parse() is the
    // same code path from_env() uses, and tests run concurrently).
    for spec in ["0@panic", "1@5:explode", "one@2:stall"] {
        let err = FaultPlan::parse(spec).unwrap_err();
        assert!(
            matches!(err, Error::BadEnv(_)),
            "{spec:?}: expected BadEnv, got {err}"
        );
    }
    // And a well-formed spec round-trips through the comm re-exports.
    let plan = FaultPlan::parse("0@3:delay(5);1@9:panic").unwrap();
    assert_eq!(plan.len(), 2);
    assert_eq!(comm::FAULT_ENV, "PTSCOTCH_FAULT");
}
