//! Refiner-differential property suite for the flow-based band refiner
//! (`sep::flow`, DESIGN.md §4): across the generator suite and rank
//! counts p ∈ {1, 2, 4, 5}, (a) every flow cut is a *valid separator* —
//! removing it genuinely disconnects the two sides, proven by
//! reachability, not just by edge inspection — (b) the flow-refined
//! quality key is never worse than the unrefined projection it started
//! from, and (c) `refine=auto` (and forced `refine=flow`) stays
//! bit-identical between `executor=sim` and `executor=threads` — the
//! flow pass is deterministic and adds no collective traffic, so it
//! must not open a schedule dependence in the best-of-p selection.

use ptscotch::coordinator::{Engine, OrderingRequest, OrderingResult, OrderingService};
use ptscotch::graph::{generators, Graph};
use ptscotch::rng::Rng;
use ptscotch::sep::initial::greedy_graph_growing;
use ptscotch::sep::{
    extract_band, flow_candidate, flow_refine_band, multilevel_separator, FmRefiner, SepState, P0,
    P1, SEP,
};
use ptscotch::strategy::{SepStrategy, Strategy};

/// The shared generator suite of the differential tests.
fn suite() -> Vec<(&'static str, Graph)> {
    vec![
        ("grid2d", generators::grid2d(16, 16)),
        ("grid3d", generators::grid3d(7, 7, 7)),
        ("irregular", generators::irregular_mesh(14, 14, 7)),
        ("cage", generators::cage_like(700, 8, 2)),
        ("thread", generators::thread_like(260, 60, 4)),
    ]
}

/// The rank counts the end-to-end properties sweep.
const RANK_COUNTS: [usize; 4] = [1, 2, 4, 5];

/// Prove the separator property by reachability: walking the graph from
/// the part-0 side without ever stepping on a separator vertex must
/// stay inside part 0. This is the "removing the cut disconnects the
/// two sides" statement itself, independent of `SepState::validate`'s
/// edge scan.
fn assert_separator_disconnects(g: &Graph, state: &SepState, ctx: &str) {
    let mut seen = vec![false; g.n()];
    let mut stack: Vec<usize> = Vec::new();
    for v in 0..g.n() {
        if state.part[v] == P0 {
            seen[v] = true;
            stack.push(v);
        }
    }
    while let Some(v) = stack.pop() {
        for &u in g.neighbors(v) {
            let u = u as usize;
            if state.part[u] == SEP {
                continue;
            }
            assert_eq!(
                state.part[u],
                P0,
                "{ctx}: part-1 vertex {u} reachable from part 0 without crossing the separator"
            );
            if !seen[u] {
                seen[u] = true;
                stack.push(u);
            }
        }
    }
}

/// Order `g` on `p` ranks with extra strategy knobs under one executor.
fn order_on(svc: &OrderingService, g: &Graph, p: usize, exec: &str, knobs: &str) -> OrderingResult {
    let spec = format!("executor={exec},seed=11,{knobs}");
    let strat = Strategy::parse(spec.trim_end_matches(',')).unwrap();
    let req = OrderingRequest::new(g).strategy(strat).engine(Engine::PtScotch { p });
    svc.run(&req).unwrap()
}

/// Assert every deterministic field of two results matches.
fn assert_reports_identical(a: &OrderingResult, b: &OrderingResult, ctx: &str) {
    assert_eq!(a.ordering.perm, b.ordering.perm, "{ctx}: perm");
    assert_eq!(a.ordering.iperm, b.ordering.iperm, "{ctx}: iperm");
    assert_eq!(a.blocks, b.blocks, "{ctx}: blocks");
    assert_eq!(a.bytes_sent_per_rank, b.bytes_sent_per_rank, "{ctx}: bytes");
    assert_eq!(a.msgs_sent_per_rank, b.msgs_sent_per_rank, "{ctx}: msgs");
    assert_eq!(a.peak_mem_per_rank, b.peak_mem_per_rank, "{ctx}: peak mem");
    assert_eq!(a.stats.nnz, b.stats.nnz, "{ctx}: nnz");
    assert_eq!(a.stats.opc, b.stats.opc, "{ctx}: opc");
    assert_eq!(a.stats.tree_height, b.stats.tree_height, "{ctx}: tree height");
}

#[test]
fn flow_cuts_are_valid_separators_on_multilevel_bands() {
    // Property (a) at the band level, where the flow pass actually
    // runs: for bands extracted around real multilevel separators at
    // every paper-relevant width, the flow candidate is a valid
    // separator state whose removal disconnects the sides, and its cut
    // weight never exceeds the separator it started from.
    let strat = SepStrategy::default();
    let refiner = FmRefiner::default();
    for (name, g) in &suite() {
        for seed in [1u64, 2] {
            let mut rng = Rng::new(seed);
            let state = multilevel_separator(g, &strat, &refiner, &mut rng);
            state.validate(g).unwrap();
            for width in [1u32, 2, 3] {
                let Some(band) = extract_band(g, &state, width) else {
                    continue;
                };
                let ctx = format!("{name} seed={seed} width={width}");
                let Some(cand) = flow_candidate(&band) else {
                    continue;
                };
                cand.validate(&band.graph)
                    .unwrap_or_else(|e| panic!("{ctx}: {e}"));
                assert_separator_disconnects(&band.graph, &cand, &ctx);
                assert!(
                    cand.sep_weight() <= band.state.sep_weight(),
                    "{ctx}: flow cut {} above the current separator {}",
                    cand.sep_weight(),
                    band.state.sep_weight()
                );
                // Anchors never end up in the cut (they are terminals).
                assert_eq!(cand.part[band.anchor0], P0, "{ctx}: anchor0 moved");
                assert_eq!(cand.part[band.anchor1], P1, "{ctx}: anchor1 moved");
            }
        }
    }
}

#[test]
fn flow_refinement_never_worse_than_unrefined_projection() {
    // Property (b): starting from *unrefined* initial separators (the
    // shape a projection has before any band pass), the committed flow
    // result never degrades the quality key, and keeps the state valid.
    for (name, g) in &suite() {
        for seed in [3u64, 4, 5] {
            let mut rng = Rng::new(seed);
            let state = greedy_graph_growing(g, 2, &mut rng);
            state.validate(g).unwrap();
            for width in [1u32, 3] {
                let Some(mut band) = extract_band(g, &state, width) else {
                    continue;
                };
                let before = band.state.quality_key();
                flow_refine_band(&mut band);
                band.state.validate(&band.graph).unwrap();
                assert!(
                    band.state.quality_key() <= before,
                    "{name} seed={seed} width={width}: flow degraded {:?} -> {:?}",
                    before,
                    band.state.quality_key()
                );
            }
        }
    }
}

#[test]
fn forced_flow_orderings_valid_across_suite_and_rank_counts() {
    // Property (a) end-to-end: `refine=flow` replaces every band pass
    // (sequential levels and the distributed best-of-p alike) with the
    // flow cut alone; the full pipeline must still produce valid
    // permutations and block trees everywhere.
    let svc = OrderingService::new_cpu_only();
    for (name, g) in &suite() {
        for p in RANK_COUNTS {
            let res = order_on(&svc, g, p, "sim", "refine=flow");
            res.ordering
                .validate()
                .unwrap_or_else(|e| panic!("{name} p={p}: {e}"));
            res.blocks
                .validate(g.n())
                .unwrap_or_else(|e| panic!("{name} p={p}: {e}"));
        }
    }
}

#[test]
fn refine_auto_bit_identical_across_executors() {
    // Property (c): the default ladder with the flow stage on top must
    // not introduce any schedule dependence — sim and threads agree
    // bit-for-bit on every deterministic field at every rank count.
    let svc = OrderingService::new_cpu_only();
    for (name, g) in &suite() {
        for p in RANK_COUNTS {
            let sim = order_on(&svc, g, p, "sim", "refine=auto");
            let thr = order_on(&svc, g, p, "threads", "refine=auto");
            assert_reports_identical(&sim, &thr, &format!("{name} p={p} refine=auto"));
        }
    }
}

#[test]
fn forced_flow_bit_identical_across_executors() {
    // Forced flow exercises the distributed best-of-p selection with a
    // fully deterministic refiner: every rank computes the same cut, so
    // the winner pick must agree across fabrics too.
    let svc = OrderingService::new_cpu_only();
    let graphs: Vec<(&'static str, Graph)> = vec![
        ("grid3d", generators::grid3d(7, 7, 7)),
        ("irregular", generators::irregular_mesh(12, 12, 3)),
    ];
    for (name, g) in &graphs {
        for p in [2usize, 5] {
            let sim = order_on(&svc, g, p, "sim", "refine=flow");
            let thr = order_on(&svc, g, p, "threads", "refine=flow");
            assert_reports_identical(&sim, &thr, &format!("{name} p={p} refine=flow"));
        }
    }
}

#[test]
fn zero_flow_budget_reduces_auto_to_the_base_refiner() {
    // `flowband=0` starves the auto ladder of its flow stage, which
    // must make it bit-identical to forcing the base FM refiner — the
    // budget knob really is the only thing gating the flow pass.
    let svc = OrderingService::new_cpu_only();
    let g = generators::irregular_mesh(14, 14, 7);
    for p in [2usize, 5] {
        let starved = order_on(&svc, &g, p, "sim", "refine=auto,flowband=0");
        let fm = order_on(&svc, &g, p, "sim", "refine=fm");
        assert_reports_identical(&starved, &fm, &format!("p={p} flowband=0 vs fm"));
    }
}
