//! Per-process memory scaling — a runnable version of the paper's
//! Figures 10–11 (memory used per process vs number of processes, with
//! the audikw1 imbalance effect and the cage15 ghost-explosion effect).
//!
//! ```bash
//! cargo run --release --offline --example memory_scaling
//! ```

use ptscotch::coordinator::{Engine, OrderingRequest, OrderingService};
use ptscotch::graph::generators;
use std::sync::Arc;

fn main() {
    let svc = OrderingService::new_cpu_only();
    for (name, g) in [
        (
            "audikw-like (high-degree cluster → imbalance)",
            generators::audikw_like(9, 9, 9, 0.03, 40, 1),
        ),
        (
            "cage-like (expander → ghost growth)",
            generators::cage_like(6000, 8, 2),
        ),
    ] {
        let g = Arc::new(g);
        println!("{name}: |V|={} |E|={}", g.n(), g.m());
        println!(
            "{:>4} {:>12} {:>12} {:>12} {:>10}",
            "p", "mem min", "mem avg", "mem max", "max/avg"
        );
        for p in [2usize, 4, 8, 16] {
            let req = OrderingRequest::from_arc(Arc::clone(&g)).engine(Engine::PtScotch { p });
            let res = svc.run(&req).unwrap();
            let (mn, avg, mx) = res.mem_min_avg_max();
            println!(
                "{:>4} {:>10} KB {:>10.0} KB {:>10} KB {:>10.2}",
                p,
                mn / 1024,
                avg / 1024.0,
                mx / 1024,
                mx as f64 / avg.max(1.0)
            );
        }
        println!();
    }
    println!("Expected shape (paper Figs. 10–11): per-process average falls");
    println!("as p grows (good memory scalability), but the max/avg ratio is");
    println!("high for audikw-like because one rank owns the contiguous");
    println!("high-degree cluster, and cage-like stops scaling early because");
    println!("ghost vertices multiply with the partition count.");
}
