//! End-to-end driver (the EXPERIMENTS.md §E2E workload): run the FULL
//! three-layer system on a real small workload and report the paper's
//! headline metric.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example ordering_pipeline
//! ```
//!
//! Pipeline: generate a ~50k-unknown 3D mesh → PT-Scotch parallel nested
//! dissection on 8 simulated ranks with the **XLA diffusion band
//! refiner** (the AOT-compiled Pallas kernel on the request path) →
//! symbolic Cholesky → OPC/NNZ vs the sequential reference and the
//! ParMETIS-like baseline, plus per-rank memory and traffic. Requests
//! go through the batch coordinator, so the closing replay is served
//! from the fingerprint cache with zero rank work (DESIGN.md §6).

use ptscotch::coordinator::{
    BatchCoordinator, Engine, OrderingRequest, OrderingService, PhaseTimer, Served,
};
use ptscotch::graph::generators;
use ptscotch::runtime::XlaRuntime;
use ptscotch::strategy::Strategy;
use std::sync::Arc;

fn main() {
    let mut timer = PhaseTimer::new();
    // ~46k unknowns: large enough to be a real workload on one core,
    // small enough to finish in seconds.
    let g = Arc::new(generators::grid3d(36, 36, 36));
    timer.lap("generate");
    println!(
        "workload: grid3d 36^3 — |V|={} |E|={} ({} B CSR)",
        g.n(),
        g.m(),
        g.footprint_bytes()
    );

    let coord = BatchCoordinator::new(OrderingService::new(&XlaRuntime::default_dir()));
    let xla_ok = coord.service().has_xla();
    println!("XLA runtime: {}", if xla_ok { "loaded" } else { "MISSING — run `make artifacts`" });

    // The three-layer hot path: XLA diffusion refiner when available.
    let strat = if xla_ok {
        Strategy::parse("refiner=xla").unwrap()
    } else {
        Strategy::default()
    };
    let p = 8;
    let pts_req = OrderingRequest::from_arc(Arc::clone(&g))
        .strategy(strat)
        .engine(Engine::PtScotch { p })
        .tag("pts");
    let pts = coord
        .request(pts_req.clone())
        .result
        .expect("pt-scotch ordering");
    timer.lap("pt-scotch p=8");
    let seq_req = OrderingRequest::from_arc(Arc::clone(&g)).tag("seq");
    let seq = coord.request(seq_req).result.expect("sequential ordering");
    timer.lap("sequential");
    let pm_req = OrderingRequest::from_arc(Arc::clone(&g))
        .engine(Engine::ParMetisLike { p })
        .tag("pm");
    let pm = coord.request(pm_req).result.expect("baseline ordering");
    timer.lap("parmetis-like p=8");

    println!();
    println!(
        "{:<24} {:>12} {:>12} {:>7} {:>8}",
        "engine", "OPC", "NNZ(L)", "height", "t(s)"
    );
    for (name, rep) in [
        (format!("pt-scotch p={p} ({})", if xla_ok { "xla" } else { "fm" }), &pts),
        ("sequential scotch".to_string(), &seq),
        (format!("parmetis-like p={p}"), &pm),
    ] {
        println!(
            "{:<24} {:>12.4e} {:>12} {:>7} {:>8.2}",
            name, rep.stats.opc, rep.stats.nnz, rep.stats.tree_height, rep.wall_seconds
        );
    }

    let (mn, avg, mx) = pts.mem_min_avg_max();
    println!();
    println!(
        "pt-scotch per-rank peak memory: min {} KiB / avg {:.0} KiB / max {} KiB",
        mn / 1024,
        avg / 1024.0,
        mx / 1024
    );
    println!(
        "pt-scotch comm: {} KiB total, {} msgs",
        pts.total_comm_bytes() / 1024,
        pts.msgs_sent_per_rank.iter().sum::<u64>()
    );
    println!("phases: {}", timer.summary());

    // Headline check (paper Tables 2–3): parallel quality ≈ sequential.
    let ratio = pts.stats.opc / seq.stats.opc;
    println!();
    println!(
        "headline: OPC(PTS p={p}) / OPC(seq) = {ratio:.3}  (paper: ≈1, often <1; \
         baseline ratio = {:.3})",
        pm.stats.opc / seq.stats.opc
    );
    assert!(ratio < 1.6, "parallel quality regressed: {ratio}");

    // Service layer: replaying the same request is a cache hit with a
    // bit-identical result and zero rank work.
    let replay = coord.request(pts_req);
    assert_eq!(replay.served, Served::Hit);
    let replayed = replay.result.expect("cached ordering");
    assert_eq!(replayed.ordering, pts.ordering);
    assert_eq!(replayed.blocks, pts.blocks);
    let m = coord.metrics();
    println!(
        "service: {} requests, {} orderings run, hit-rate {:.0}% on replay",
        m.requests(),
        m.jobs_run,
        m.hit_rate() * 100.0
    );
    println!("E2E OK");
}
