//! Quickstart: order one sparse matrix three ways and compare quality.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```
//!
//! Orders a 3D mesh with (1) the sequential Scotch-like pipeline,
//! (2) PT-Scotch parallel nested dissection on 4 simulated ranks, and
//! (3) the ParMETIS-like baseline, printing the paper's two quality
//! metrics (OPC and NNZ) for each.

use ptscotch::coordinator::{Engine, OrderingRequest, OrderingService};
use ptscotch::graph::generators;
use ptscotch::runtime::XlaRuntime;
use std::sync::Arc;

fn main() {
    // A 16×16×16 7-point mesh: 4096 unknowns, the classic ND test case.
    let g = Arc::new(generators::grid3d(16, 16, 16));
    println!(
        "graph: grid3d 16^3  |V|={} |E|={} avg degree {:.2}",
        g.n(),
        g.m(),
        g.avg_degree()
    );

    let svc = OrderingService::new(&XlaRuntime::default_dir());
    println!(
        "XLA artifacts: {}",
        if svc.has_xla() { "loaded" } else { "not built (CPU-only run; `make artifacts`)" }
    );
    println!(
        "{:<22} {:>12} {:>12} {:>6} {:>6} {:>8}",
        "engine", "OPC", "NNZ(L)", "fill", "cblk", "t(s)"
    );
    for (name, engine) in [
        ("sequential", Engine::Sequential),
        ("pt-scotch p=4", Engine::PtScotch { p: 4 }),
        ("parmetis-like p=4", Engine::ParMetisLike { p: 4 }),
    ] {
        let req = OrderingRequest::from_arc(Arc::clone(&g)).engine(engine);
        let res = svc.run(&req).expect("ordering");
        println!(
            "{:<22} {:>12.4e} {:>12} {:>6.2} {:>6} {:>8.2}",
            name,
            res.stats.opc,
            res.stats.nnz,
            res.stats.fill_ratio,
            res.blocks.cblk,
            res.wall_seconds
        );
    }
    println!();
    println!("Lower OPC/NNZ is better; PT-Scotch should track the sequential");
    println!("quality while the baseline drifts as rank counts grow (see the");
    println!("fig6_9 bench for the full curves).");
}
