//! Engine comparison across process counts — a compact, runnable version
//! of the paper's Figures 6 and 8 (OPC vs P for PT-Scotch vs ParMETIS).
//!
//! ```bash
//! cargo run --release --offline --example compare_engines [scale]
//! ```

use ptscotch::coordinator::{Engine, OrderingService};
use ptscotch::graph::generators;
use ptscotch::runtime::XlaRuntime;
use ptscotch::strategy::Strategy;

fn main() {
    let scale: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let g = generators::audikw_like(8 * scale, 8 * scale, 8 * scale, 0.02, 30, 1);
    println!(
        "graph: audikw-like |V|={} |E|={} max degree {}",
        g.n(),
        g.m(),
        g.max_degree()
    );
    let svc = OrderingService::new(&XlaRuntime::default_dir());
    let strat = Strategy::default();
    let seq = svc.order(&g, Engine::Sequential, &strat).unwrap();
    println!("sequential O_SS = {:.4e}", seq.stats.opc);
    println!();
    println!("{:>4} {:>14} {:>14} {:>10} {:>10}", "p", "O_PTS", "O_PM", "t_PTS", "t_PM");
    for p in [2usize, 3, 4, 6, 8] {
        let pts = svc.order(&g, Engine::PtScotch { p }, &strat).unwrap();
        let pm = if p.is_power_of_two() {
            match svc.order(&g, Engine::ParMetisLike { p }, &strat) {
                Ok(r) => format!("{:.4e}", r.stats.opc),
                Err(e) => format!("† {e}"),
            }
        } else {
            "† non-pow2".to_string() // the paper's dagger: PM cannot run
        };
        let tpm = if p.is_power_of_two() {
            svc.order(&g, Engine::ParMetisLike { p }, &strat)
                .map(|r| format!("{:.2}", r.wall_seconds))
                .unwrap_or_else(|_| "—".into())
        } else {
            "—".into()
        };
        println!(
            "{:>4} {:>14.4e} {:>14} {:>10.2} {:>10}",
            p, pts.stats.opc, pm, pts.wall_seconds, tpm
        );
    }
    println!();
    println!("(† marks configurations the baseline cannot run — the paper's");
    println!(" Tables 2–3 use the same symbol for ParMETIS failures.)");
}
