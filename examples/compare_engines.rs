//! Engine comparison across process counts — a compact, runnable version
//! of the paper's Figures 6 and 8 (OPC vs P for PT-Scotch vs ParMETIS).
//!
//! ```bash
//! cargo run --release --offline --example compare_engines [scale]
//! ```

use ptscotch::coordinator::{Engine, OrderingRequest, OrderingService};
use ptscotch::graph::generators;
use ptscotch::runtime::XlaRuntime;
use std::sync::Arc;

fn main() {
    let scale: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let g = Arc::new(generators::audikw_like(
        8 * scale,
        8 * scale,
        8 * scale,
        0.02,
        30,
        1,
    ));
    println!(
        "graph: audikw-like |V|={} |E|={} max degree {}",
        g.n(),
        g.m(),
        g.max_degree()
    );
    let svc = OrderingService::new(&XlaRuntime::default_dir());
    let run = |engine| svc.run(&OrderingRequest::from_arc(Arc::clone(&g)).engine(engine));
    let seq = run(Engine::Sequential).unwrap();
    println!("sequential O_SS = {:.4e}", seq.stats.opc);
    println!();
    println!("{:>4} {:>14} {:>14} {:>10} {:>10}", "p", "O_PTS", "O_PM", "t_PTS", "t_PM");
    for p in [2usize, 3, 4, 6, 8] {
        let pts = run(Engine::PtScotch { p }).unwrap();
        let pm = if p.is_power_of_two() {
            match run(Engine::ParMetisLike { p }) {
                Ok(r) => format!("{:.4e}", r.stats.opc),
                Err(e) => format!("† {e}"),
            }
        } else {
            "† non-pow2".to_string() // the paper's dagger: PM cannot run
        };
        let tpm = if p.is_power_of_two() {
            run(Engine::ParMetisLike { p })
                .map(|r| format!("{:.2}", r.wall_seconds))
                .unwrap_or_else(|_| "—".into())
        } else {
            "—".into()
        };
        println!(
            "{:>4} {:>14.4e} {:>14} {:>10.2} {:>10}",
            p, pts.stats.opc, pm, pts.wall_seconds, tpm
        );
    }
    println!();
    println!("(† marks configurations the baseline cannot run — the paper's");
    println!(" Tables 2–3 use the same symbol for ParMETIS failures.)");
}
