"""Layer-2 JAX models: the compute graphs that get AOT-lowered.

Each model is a fixed-shape composition of the Layer-1 Pallas kernels
(:mod:`compile.kernels.ell_spmv`) plus the anchor-clamping logic of the
banded diffusion smoother. ``aot.py`` lowers one HLO text file per
(model, bucket) pair; the Rust runtime loads and executes them from the
band-refinement hot path.

Semantics contract (shared with Rust ``sep::diffusion`` and pinned by
``runtime::ell::ell_fused_reference`` on the Rust side):
  * the fixed-value clamp ``x = mask·vals + (1-mask)·x`` runs **before**
    every averaging step and once after the last — equivalent to
    clamping after every step when the initial field already has the
    clamped entries set;
  * padded rows/lanes carry weight 0 and decay to 0;
  * all arithmetic is f32.

Clamping covers two row kinds, indistinguishable to the kernel:
  * **anchors** (both call paths): ``vals`` ∓1, rows packed empty;
  * **ghost rows** (distributed per-rank path, ``dist::ddiffusion``):
    each rank packs its band slice as ``[local rows | ghost rows]`` and
    sets ``mask`` 1 on every ghost row with ``vals`` holding the
    neighbor values of the latest halo exchange. The kernel thus treats
    ghosts as fixed boundary conditions for the ``STEPS_PER_CALL``
    fused sweeps of one call; the caller re-fills them from a fresh
    halo exchange between calls. Ghost rows are packed empty (weight
    0), so their outputs are never computed — only gathered.
"""

import jax.numpy as jnp

from .kernels import ell_spmv

#: Diffusion iterations fused into one artifact call. Unrolled (not
#: ``fori_loop``) so XLA fuses the whole chain into one fixed pipeline.
STEPS_PER_CALL = 8

#: Damping factor baked into the artifacts (matches the Rust
#: ``CpuDiffusionRefiner`` default).
DAMPING = 0.95


def diffusion_steps(x, fixed_mask, fixed_vals, nbr, w):
    """K fused steps of the banded diffusion smoother (L2 model).

    Args:
      x: ``f32[n]`` field (anchors already at their clamp values).
      fixed_mask: ``f32[n]`` 1.0 where the value is clamped (anchors).
      fixed_vals: ``f32[n]`` clamp values (∓1 at the anchors).
      nbr: ``i32[n, d]`` ELL neighbor table.
      w: ``f32[n, d]`` ELL weights (0 = padding).

    Returns:
      1-tuple of the ``f32[n]`` field after ``STEPS_PER_CALL`` steps
      (tuple because the AOT bridge lowers with ``return_tuple=True``).
    """
    for _ in range(STEPS_PER_CALL):
        x = fixed_mask * fixed_vals + (1.0 - fixed_mask) * x
        x = ell_spmv.ell_wavg(x, nbr, w, damping=DAMPING)
    x = fixed_mask * fixed_vals + (1.0 - fixed_mask) * x
    return (x,)


def minplus_step(dist, nbr, w):
    """One BFS/min-plus relaxation (L2 model around the L1 kernel).

    Semantics contract (pinned on the Rust side by
    ``runtime::ell::ell_minplus_reference`` and consumed per rank by
    ``dist::dband::bfs_band_dist_engine``):
      * ``out[v] = min(dist[v], min over unpadded lanes of
        dist[nbr[v,k]] + 1)`` — hop counts: the ``+1`` is per arc
        regardless of weight; ``w > 0`` only gates padding;
      * rows packed **empty** (all weights 0) keep their value — that is
        how the distributed band BFS treats ghost rows as fixed boundary
        distances between halo exchanges: each rank packs its slice as
        ``[local rows | ghost rows]`` (``runtime::pack_ell_dist``), runs
        several fused relaxations per call, and re-fills the ghost slots
        from a fresh halo exchange between calls;
      * unreached distances are ``3.0e38`` (≈ +inf, and ``+ 1.0`` is a
        no-op at f32 precision, so relaxation through an unreached
        neighbor can never win the min).
    """
    return (ell_spmv.ell_minplus(dist, nbr, w),)


def example_args(n: int, d: int, kernel: str):
    """Shape specs used to lower a bucket."""
    f32 = jnp.float32
    i32 = jnp.int32
    import jax

    vec = jax.ShapeDtypeStruct((n,), f32)
    tab_i = jax.ShapeDtypeStruct((n, d), i32)
    tab_f = jax.ShapeDtypeStruct((n, d), f32)
    if kernel == "diffusion":
        return (vec, vec, vec, tab_i, tab_f)
    if kernel == "minplus":
        return (vec, tab_i, tab_f)
    raise ValueError(f"unknown kernel {kernel}")
