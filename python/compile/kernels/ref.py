"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These define the exact semantics the kernels must reproduce; pytest
(``python/tests/test_kernel.py``) asserts allclose between kernel and
oracle over hypothesis-generated shapes, graphs and fields, and the Rust
reference (``sep::diffusion::diffusion_iterations``) implements the same
recurrence on the unpacked CSR graph.
"""

import jax.numpy as jnp

INF = jnp.float32(3.0e38)


def ell_wavg_ref(x, nbr, w, *, damping: float = 0.95):
    """Reference damped weighted average over an ELL block."""
    gathered = x[nbr]                       # (n, d)
    num = jnp.sum(w * gathered, axis=1)
    den = jnp.sum(w, axis=1)
    return jnp.where(den > 0.0, damping * num / jnp.maximum(den, 1e-30), 0.0)


def ell_minplus_ref(dist, nbr, w):
    """Reference one-step min-plus relaxation over an ELL block."""
    gathered = dist[nbr]
    candidates = jnp.where(w > 0.0, gathered + 1.0, INF)
    return jnp.minimum(dist, jnp.min(candidates, axis=1))


def diffusion_ref(x, fixed_mask, fixed_vals, nbr, w, *, steps: int, damping: float = 0.95):
    """Reference K-step banded diffusion with clamped anchors.

    Matches Rust ``diffusion_iterations``: the clamp is applied before
    every gather and once more after the final step.
    """
    for _ in range(steps):
        x = fixed_mask * fixed_vals + (1.0 - fixed_mask) * x
        x = ell_wavg_ref(x, nbr, w, damping=damping)
    return fixed_mask * fixed_vals + (1.0 - fixed_mask) * x
